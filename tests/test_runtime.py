"""repro.runtime — online cross-iteration tuning (paper §4, Fig. 10).

All deterministic and CPU-safe: the tuner is driven by synthetic latency
surfaces (fake clock), the profiler's analytical fallback is checked
against the model, and the DynamicGNNEngine runs real (1-device-mesh)
training to prove the config swaps never perturb the math.
"""
import json
import math
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.core as C
from repro.core.autotune import WorkloadShape, estimate_latency
from repro.dist import flat_ring_mesh
from repro.runtime import (AggregateProfiler, ConfigCache, DynamicGNNEngine,
                           LatencyWindow, OnlineTuner, ProfileConfig,
                           make_vmem_check, shape_drift, time_jitted)

PS = (1, 2, 4, 8, 16, 32)
DIST = (1, 2, 4, 8)
PB = (1, 2, 4, 8)


def _drive(tuner, surface):
    """Run the propose/observe loop to convergence; return #measurements."""
    while not tuner.converged:
        c = tuner.propose()
        tuner.observe(surface(c["ps"], c["dist"], c["pb"]))
    return tuner.measured


# ---------------------------------------------------------------------------
# tuner: convergence, retreat, drift, budget
# ---------------------------------------------------------------------------

def test_tuner_converges_near_optimum_within_12_measurements():
    """Acceptance: ≤ 12 measurements, within 5% of the exhaustive optimum."""

    def surface(ps, dist, pb):  # separable bowl, optimum at (4, 2, 2)
        return (1.0 + 0.10 * (math.log2(ps) - 2) ** 2
                + 0.20 * (math.log2(dist) - 1) ** 2
                + 0.05 * (math.log2(pb) - 1) ** 2)

    t = OnlineTuner(PS, DIST, PB)
    n = _drive(t, surface)
    exhaustive = min(surface(p, d, b) for p in PS for d in DIST for b in PB)
    assert n <= 12, n
    assert t.best_latency <= 1.05 * exhaustive
    assert t.best == dict(ps=4, dist=2, pb=2)


def test_tuner_matches_offline_search_on_model_surface():
    """Same control flow as cross_iteration_optimize ⇒ never a worse pick."""
    g = C.power_law(800, avg_degree=8.0, locality=0.3, seed=3)
    w = WorkloadShape.from_graph(g, 8, 64)
    surface = lambda ps, dist, pb: estimate_latency(w, ps, dist, pb)
    t = OnlineTuner(PS, DIST, PB)
    _drive(t, surface)
    off = C.cross_iteration_optimize(
        surface, ps_space=PS, dist_space=DIST, pb_space=PB)
    assert t.best_latency <= off.best_latency + 1e-15


def test_retreat_rule_fires():
    """pb stuck at its floor for the climbed ps, but ps-retreat + pb wins —
    the paper's 'decrease ps to its second-highest value' rule."""

    def surface(ps, dist, pb):
        lat = 10.0 - 1.0 * min(math.log2(ps), 3)     # ps climb → ps=8
        lat += 0.5 * (dist - 1)                      # dist stays at 1
        if pb > 1:
            lat += 2.0 if ps >= 8 else -1.5          # pb only helps at ps=4
        return lat

    t = OnlineTuner(PS, DIST, PB)
    _drive(t, surface)
    assert t.best == dict(ps=4, dist=1, pb=2)
    probed = {(c["ps"], c["pb"]) for c, _l in t.trajectory}
    assert (8, 1) in probed and (4, 2) in probed  # climbed, then retreated


def test_drift_reopens_search_with_warm_start():
    base = WorkloadShape(n_dev=4, d_feat=32, rows_per_dev=100,
                         local_edges_max=1000, remote_edges_max=400)
    t = OnlineTuner((1, 2, 4), (1, 2), (1, 2))
    assert not t.observe_shape(base)
    _drive(t, lambda ps, dist, pb: 1.0 + abs(ps - 2) + dist + pb)
    best = t.best
    assert t.converged
    # small wiggle: no re-open
    near = WorkloadShape(4, 32, 105, 1050, 420)
    assert not t.observe_shape(near)
    assert t.converged
    # +50% remote edges: re-open, warm-started from the old best
    far = WorkloadShape(4, 32, 100, 1000, 600)
    assert t.observe_shape(far)
    assert not t.converged
    assert t.propose() == best
    assert t.reopens == 1


def test_adopt_reopen_validates_with_single_measurement():
    """Cluster shared-cache path: reopen(mode='adopt') measures exactly
    the warm config, then converges; infeasible warm falls back to a
    full search."""
    t = OnlineTuner((2, 4, 8), (1, 2), (1,))
    _drive(t, lambda ps, dist, pb: 1.0 + abs(ps - 4) + dist)
    m0 = t.measured
    t.reopen(warm_start=dict(ps=4, dist=2, pb=1), mode="adopt")
    assert not t.converged
    assert t.propose() == dict(ps=4, dist=2, pb=1)
    t.observe(0.9)
    assert t.converged
    assert t.measured - m0 == 1
    assert t.best == dict(ps=4, dist=2, pb=1)
    assert t.reopens == 1
    # a VMEM-infeasible warm config must NOT be adopted
    t2 = OnlineTuner((2, 4), (1,), (1,), vmem_check=lambda ps, d, pb: ps < 8)
    _drive(t2, lambda *_: 1.0)
    t2.reopen(warm_start=dict(ps=8, dist=1, pb=1), mode="adopt")
    assert not t2.converged and t2.propose()["ps"] < 8


def test_per_layer_adopt_reopen_and_resize_fallback():
    from repro.runtime import PerLayerTuner

    p = PerLayerTuner(3, (2, 4), (1, 2), (1,))
    while not p.converged:
        p.observe(1.0)
    warm = [dict(ps=2, dist=1, pb=1), dict(ps=4, dist=2, pb=1),
            dict(ps=2, dist=2, pb=1)]
    m0 = p.measured
    p.reopen(warm_start=warm, mode="adopt")
    assert p.propose() == warm
    p.observe(0.5)
    assert p.converged and p.best == warm and p.measured - m0 == 1
    # wrong layer count: resized (like reconfigure), searched, not raised
    p.reopen(warm_start=warm[:2], mode="adopt")
    assert not p.converged and len(p.propose()) == 3


def test_retune_from_cache_adopts_shared_entry():
    """DynamicGNNEngine.retune(force=True, from_cache=True) pulls the
    sibling-committed entry and closes its search after one window."""
    g = C.power_law(200, avg_degree=5.0, locality=0.3, seed=7)
    mesh = flat_ring_mesh(1)
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "tuned.json")
        e1 = DynamicGNNEngine.build(
            g, mesh, d_feat=8, ps_space=(2, 4, 8), dist_space=(1, 2),
            pb_space=(1,), window=ProfileConfig(warmup=0, iters=1),
            cache_path=path)
        while not e1.tuner.converged:
            e1.observe_step(1e-3)
        # sibling engine, same shape/hardware, converged on its own
        e2 = DynamicGNNEngine.build(
            g, mesh, d_feat=8, ps_space=(2, 4, 8), dist_space=(1, 2),
            pb_space=(1,), window=ProfileConfig(warmup=0, iters=1),
            cache_path=path)
        while not e2.tuner.converged:
            e2.observe_step(2e-3)
        cached = ConfigCache(path).get(e2.shape)  # latest committed entry
        assert cached is not None
        m0 = e2.tuner.measured
        assert e2.retune(force=True, from_cache=True)
        assert e2.config == cached             # proposed = adopted entry
        e2.observe_step(1e-3)                  # single validation window
        assert e2.tuner.converged
        assert e2.tuner.measured - m0 == 1


def test_budget_caps_measurements():
    t = OnlineTuner(PS, DIST, PB, budget=4)
    n = _drive(t, lambda ps, dist, pb: 1.0 / ps)  # monotone: wants ps=32
    assert n == 4
    assert t.converged
    assert t.best is not None  # best-so-far is still committed


def test_vmem_check_rejects_without_spending_measurements():
    w = WorkloadShape(n_dev=4, d_feat=512, rows_per_dev=4096,
                      local_edges_max=10000, remote_edges_max=5000)
    check = make_vmem_check(w)
    assert check(1, 8, 1)            # small config fits
    assert not check(32, 1, 16)      # big block + dist=1 double buffer: no
    t = OnlineTuner((1, 32), (1,), (1, 16), vmem_check=lambda *k: k[0] < 32)
    calls = []

    def surface(ps, dist, pb):
        calls.append((ps, dist, pb))
        return 1.0 / pb

    _drive(t, surface)
    assert all(c[0] < 32 for c in calls)  # rejected configs never measured
    assert t.table[(32, 1, 1)] == math.inf


# ---------------------------------------------------------------------------
# profiler
# ---------------------------------------------------------------------------

def test_latency_window_warmup_and_percentile():
    w = LatencyWindow(ProfileConfig(warmup=2, iters=3, percentile=50.0))
    for dt in (99.0, 98.0):  # compile-tainted samples: dropped
        assert not w.add(dt)
    assert not w.add(3.0)
    assert not w.add(1.0)
    assert w.add(2.0)
    assert w.ready
    assert w.value() == 2.0  # median of (3, 1, 2), warmups excluded
    w.reset()
    assert not w.ready


def test_time_jitted_fake_clock():
    ticks = iter(range(100))
    calls = []

    def fn(x):
        calls.append(1)
        return jnp.asarray(x)

    t = time_jitted(fn, 1.0, cfg=ProfileConfig(warmup=2, iters=3),
                    clock=lambda: float(next(ticks)))
    assert len(calls) == 5          # warmup + iters
    assert t == 1.0                 # every (stop - start) == 1 tick


def test_profiler_model_fallback_matches_estimate():
    g = C.power_law(300, avg_degree=6.0, locality=0.3, seed=2)
    prof = AggregateProfiler(g, None, 32, mode="auto")  # no mesh ⇒ model
    assert not prof.measuring
    w = prof.workload_shape()
    assert w.n_dev == 1
    assert prof(4, 1, 2) == estimate_latency(w, 4, 1, 2)
    with pytest.raises(RuntimeError):
        AggregateProfiler(g, None, 32, mode="measure").measuring


def test_profiler_measures_and_memoizes():
    g = C.power_law(200, avg_degree=5.0, locality=0.3, seed=4)
    prof = AggregateProfiler(g, flat_ring_mesh(1), 8, mode="measure",
                             profile=ProfileConfig(warmup=1, iters=1))
    a = prof(2, 1, 1)
    assert a > 0
    assert prof(2, 1, 1) == a  # memoized, not re-timed


# ---------------------------------------------------------------------------
# cache
# ---------------------------------------------------------------------------

def test_cache_roundtrip_corruption_and_atomicity():
    shape = WorkloadShape(n_dev=2, d_feat=16, rows_per_dev=50,
                          local_edges_max=200, remote_edges_max=80)
    other = WorkloadShape(n_dev=2, d_feat=16, rows_per_dev=51,
                          local_edges_max=200, remote_edges_max=80)
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "sub", "tuned.json")
        cache = ConfigCache(path, hw="test:hw:2")
        assert cache.get(shape) is None
        cache.put(shape, dict(ps=8, dist=2, pb=4), 1.5e-3)
        assert cache.get(shape) == dict(ps=8, dist=2, pb=4)
        assert cache.get(other) is None          # different shape, no hit
        # a second instance re-reads from disk
        assert ConfigCache(path, hw="test:hw:2").get(shape) == \
            dict(ps=8, dist=2, pb=4)
        # different hardware fingerprint: miss
        assert ConfigCache(path, hw="other:hw:8").get(shape) is None
        # two entries coexist
        cache.put(other, dict(ps=2, dist=1, pb=1), 2e-3)
        assert len(cache) == 2
        # corruption is survivable: unreadable file reads as empty...
        with open(path, "w") as f:
            f.write("{ not json")
        assert cache.get(shape) is None
        # ...and the next put starts a fresh, valid file
        cache.put(shape, dict(ps=4, dist=1, pb=2), 1e-3)
        assert cache.get(shape) == dict(ps=4, dist=1, pb=2)
        with open(path) as f:
            assert json.load(f)["version"] == 5
        # no stray tmp files left behind
        assert all(not fn.endswith(".tmp") for fn in os.listdir(d))


# ---------------------------------------------------------------------------
# DynamicGNNEngine
# ---------------------------------------------------------------------------

def _gnn_setup(n=160, d=12, ncls=4, seed=0):
    from repro.train.data import graph_features
    from repro.train.optimizer import AdamWConfig, adamw_init

    g = C.power_law(n, avg_degree=6.0, locality=0.3, seed=seed)
    x, y, mask = graph_features(g.num_nodes, d, ncls, seed=seed)
    init, apply, kw = C.MODEL_ZOO["gcn"]
    params = init(jax.random.key(seed), d, ncls, **kw)
    ocfg = AdamWConfig(lr=5e-3, warmup_steps=2, total_steps=60,
                       weight_decay=0.0)
    return g, x, y, mask, apply, params, adamw_init(params), ocfg


def _make_step(eng, apply, x, y, mask, ocfg):
    from repro.train.optimizer import adamw_update

    pad1 = lambda a: C.pad_table(eng.plan.bounds, eng.plan.rows_per_dev,
                                 a[:, None])[:, 0]
    xp = eng.shard(eng.pad(x))
    yp = jnp.asarray(pad1(y.astype(np.int32)))
    mp = jnp.asarray(pad1(mask.astype(np.float32)))

    @jax.jit
    def step(params, opt):
        loss, grads = jax.value_and_grad(lambda p: C.masked_cross_entropy(
            apply(p, eng, xp), yp, mp))(params)
        params, opt, _ = adamw_update(grads, opt, params, ocfg)
        return params, opt, loss

    return step


def test_dynamic_engine_tunes_rebuilds_and_stays_correct():
    g, x, *_ = _gnn_setup()
    eng = DynamicGNNEngine.build(
        g, flat_ring_mesh(1), d_feat=x.shape[1],
        ps_space=(1, 2, 4), dist_space=(1, 2), pb_space=(1, 2),
        window=ProfileConfig(warmup=1, iters=1))
    gsl = g.with_self_loops()
    ref = C.reference_aggregate(gsl.indptr, gsl.indices, x)
    fake = lambda c: 1.0 + 0.5 * abs(c["ps"] - 2) + 0.3 * (c["dist"] - 1) \
        + 0.2 * (c["pb"] - 1)
    rebuilds = 0
    for _ in range(80):
        out = C.unpad_embeddings(
            eng.plan, np.asarray(eng.aggregate(eng.shard(eng.pad(x)))))
        np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-4)
        rebuilds += bool(eng.observe_step(fake(eng.config)))
        if eng.committed:
            break
    assert eng.committed
    assert eng.config == dict(ps=2, dist=1, pb=1)
    assert rebuilds >= 2                      # search actually moved
    assert eng.history[0][0] == 0             # initial config recorded
    assert eng.history[-1][1] == eng.config


def test_dynamic_engine_bitwise_matches_static_after_commit():
    """Acceptance: dynamic-tuned training == static run at the tuner's
    final config, bitwise, config-for-config (post-commit segment)."""
    g, x, y, mask, apply, params, opt, ocfg = _gnn_setup()
    mesh = flat_ring_mesh(1)
    eng = DynamicGNNEngine.build(
        g, mesh, d_feat=x.shape[1],
        ps_space=(1, 2, 4), dist_space=(1, 2), pb_space=(1, 2),
        window=ProfileConfig(warmup=0, iters=1))
    fake = lambda c: 1.0 + abs(c["ps"] - 4) + 0.5 * (c["dist"] - 1) \
        + 0.25 * (c["pb"] - 1)
    step = _make_step(eng, apply, x, y, mask, ocfg)
    for _ in range(40):
        params, opt, _loss = step(params, opt)
        if eng.observe_step(fake(eng.config)):
            step = _make_step(eng, apply, x, y, mask, ocfg)
        if eng.committed:
            break
    assert eng.committed and eng.config == dict(ps=4, dist=1, pb=1)
    snap_p = jax.tree.map(np.asarray, params)
    snap_o = jax.tree.map(np.asarray, opt)

    dyn_losses = []
    for _ in range(5):
        params, opt, loss = step(params, opt)
        dyn_losses.append(float(loss))

    static = C.GNNEngine.build(g, mesh, **eng.config)
    sstep = _make_step(static, apply, x, y, mask, ocfg)
    sp = jax.tree.map(jnp.asarray, snap_p)
    so = jax.tree.map(jnp.asarray, snap_o)
    st_losses = []
    for _ in range(5):
        sp, so, loss = sstep(sp, so)
        st_losses.append(float(loss))
    assert dyn_losses == st_losses  # bitwise, not allclose


def test_dynamic_engine_warm_starts_from_cache():
    g, x, *_ = _gnn_setup(seed=5)
    mesh = flat_ring_mesh(1)
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "tuned.json")
        e1 = DynamicGNNEngine.build(
            g, mesh, d_feat=x.shape[1], ps_space=(1, 2, 4),
            dist_space=(1, 2), pb_space=(1, 2),
            window=ProfileConfig(warmup=0, iters=1), cache_path=path)
        fake = lambda c: 1.0 + abs(c["ps"] - 2) + 0.5 * (c["dist"] - 2)
        for _ in range(40):
            e1.observe_step(fake(e1.config))
            if e1.committed:
                break
        assert e1.committed
        best = e1.config
        assert ConfigCache(path).get(e1.shape) == best
        # second engine: the cached config is the FIRST thing it runs
        e2 = DynamicGNNEngine.build(
            g, mesh, d_feat=x.shape[1], ps_space=(1, 2, 4),
            dist_space=(1, 2), pb_space=(1, 2), cache_path=path)
        assert e2.config == best


def test_tuner_climbs_fanout_and_batch_on_per_seed_latency():
    """The sampling-geometry knobs ride the same hill-climb as cap/k:
    fanout climbs after k, batch last, each retreating on a worse probe.
    The surface is per-seed latency, so a bigger batch that amortizes
    fixed overhead genuinely wins."""

    def surface(c):
        # fixed 2ms dispatch amortized over the batch + per-seed cost
        # that grows with fanout; optimum at (fanout=4, batch=256)
        return 2.0 / c["batch"] + 0.001 * c["fanout"] ** 2

    t = OnlineTuner((4,), (1,), (1,), fanout_space=(4, 8, 16),
                    batch_space=(64, 128, 256))
    while not t.converged:
        t.observe(surface(t.propose()))
    assert t.best == dict(ps=4, dist=1, pb=1, fanout=4, batch=256)
    assert t.measured <= 12, t.measured


def test_dynamic_engine_roundtrips_fanout_batch_via_cache():
    g, x, *_ = _gnn_setup(seed=9)
    mesh = flat_ring_mesh(1)
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "tuned.json")
        e1 = DynamicGNNEngine.build(
            g, mesh, d_feat=x.shape[1], ps_space=(4,), dist_space=(1,),
            pb_space=(1,), fanout_space=(4, 8), batch_space=(64, 128),
            window=ProfileConfig(warmup=0, iters=1), cache_path=path)
        fake = lambda c: 0.5 / c["batch"] + 0.01 * c["fanout"]
        for _ in range(40):
            e1.observe_step(fake(e1.config))
            if e1.committed:
                break
        assert e1.committed
        best = e1.config
        assert best["fanout"] == 4 and best["batch"] == 128
        assert e1.sample_fanout == 4 and e1.sample_batch == 128
        assert ConfigCache(path).get(e1.shape) == best
        # the ring plan never sees the sampling knobs
        assert not hasattr(e1.plan, "fanout")
        # second engine warm-starts on the full 5-knob config
        e2 = DynamicGNNEngine.build(
            g, mesh, d_feat=x.shape[1], ps_space=(4,), dist_space=(1,),
            pb_space=(1,), fanout_space=(4, 8), batch_space=(64, 128),
            cache_path=path)
        assert e2.config == best
        assert e2.sample_fanout == 4 and e2.sample_batch == 128


def test_dynamic_engine_drift_retune():
    g, x, *_ = _gnn_setup(seed=6)
    mesh = flat_ring_mesh(1)
    eng = DynamicGNNEngine.build(
        g, mesh, d_feat=x.shape[1], ps_space=(1, 2), dist_space=(1,),
        pb_space=(1,), window=ProfileConfig(warmup=0, iters=1))
    for _ in range(20):
        eng.observe_step(1.0 / eng.config["ps"])
        if eng.committed:
            break
    assert eng.committed
    # same graph: no drift, engine untouched
    assert not eng.retune()
    # a much denser graph: shape drifts past threshold → search re-opens
    g2 = C.power_law(g.num_nodes, avg_degree=14.0, locality=0.3, seed=7)
    assert eng.retune(graph=g2)
    assert not eng.committed
    assert eng.tuner.reopens == 1
    # and the engine now aggregates the NEW topology correctly
    g2sl = g2.with_self_loops()
    ref = C.reference_aggregate(g2sl.indptr, g2sl.indices, x)
    out = C.unpad_embeddings(
        eng.plan, np.asarray(eng.aggregate(eng.shard(eng.pad(x)))))
    np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-4)


def test_engine_pb_knob_threads_to_kernel_path():
    """pb reaches the blocked Pallas kernel (interpret mode on CPU) and
    does not change the math."""
    g = C.power_law(80, avg_degree=5.0, locality=0.3, seed=8)
    x = np.random.default_rng(0).normal(size=(80, 8)).astype(np.float32)
    mesh = flat_ring_mesh(1)
    ref_eng = C.GNNEngine.build(g, mesh, ps=4)
    ref = np.asarray(ref_eng.aggregate(ref_eng.shard(ref_eng.pad(x))))
    ker = C.GNNEngine.build(g, mesh, ps=4, pb=2, use_kernel=True)
    assert ker.config == dict(ps=4, dist=1, pb=2)
    got = np.asarray(ker.aggregate(ker.shard(ker.pad(x))))
    np.testing.assert_allclose(got, ref, rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# Trainer dynamic-tune hook
# ---------------------------------------------------------------------------

def test_trainer_tune_cb_swaps_step_fn():
    from repro.train import Trainer, TrainState

    def mk_step(scale):
        def step(params, opt, batch):
            return params, opt, dict(loss=jnp.asarray(scale, jnp.float32))
        return step

    def data_it():
        while True:
            yield {}

    swaps = []

    def tune_cb(dt, step):
        assert dt >= 0.0
        if step == 3 and not swaps:
            swaps.append(step)
            return mk_step(7.0)
        return None

    # log_every=1: the step-fn swap clears the watchdog window on a
    # logging step — the log line must not index the emptied history
    tr = Trainer(mk_step(1.0), data_it(), TrainState(None, None),
                 log_every=1, log_fn=lambda *_: None, tune_cb=tune_cb)
    losses = tr.run(6)
    assert tr.retunes == 1
    assert losses[:4] == [1.0] * 4 and losses[4:] == [7.0] * 2
