"""8-device shard_map equivalence: MGG ring (all knobs) + baselines vs oracle."""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np, jax, jax.numpy as jnp
import repro.core as C
from repro.dist import flat_ring_mesh

g = C.power_law(400, avg_degree=9.0, locality=0.35, seed=11)
x = np.random.default_rng(3).normal(size=(g.num_nodes, 23)).astype(np.float32)
want = C.reference_aggregate(g.indptr, g.indices, x)
mesh = flat_ring_mesh(8)
for ps, dist, il, kern in [(4,1,True,False),(16,2,False,False),(8,4,True,False),(8,1,True,True)]:
    plan = C.build_plan(g, 8, ps=ps, dist=dist)
    out = C.mgg_aggregate(jnp.asarray(C.pad_embeddings(plan, x)), plan, mesh,
                          interleave=il, use_kernel=kern)
    got = C.unpad_embeddings(plan, np.asarray(out))
    err = np.abs(got - want).max()
    assert err < 1e-3, (ps, dist, il, kern, err)
bounds = C.edge_balanced_node_split(g.indptr, 8)
nbrs, mask, tgt, rows = C.build_bulk_plan(g, 8, ps=16)
xb = C.pad_table(bounds, rows, x)
out = C.bulk_aggregate(jnp.asarray(xb), nbrs, mask, tgt, rows, mesh)
assert np.abs(C.unpad_table(bounds, rows, np.asarray(out)) - want).max() < 1e-3
# grads through the multi-device ring
plan = C.build_plan(g, 8, ps=8, dist=2)
xp = jnp.asarray(C.pad_embeddings(plan, x))
gr = jax.grad(lambda z: (C.mgg_aggregate(z, plan, mesh) ** 2).sum())(xp)
assert np.isfinite(np.asarray(gr)).all() and float(jnp.abs(gr).sum()) > 0
# fused update over the 8-device ring: (A x) @ W per-tile == oracle @ W
w = np.random.default_rng(5).normal(size=(23, 9)).astype(np.float32)
outf = C.mgg_aggregate(xp, plan, mesh, update_w=jnp.asarray(w))
gotf = C.unpad_embeddings(plan, np.asarray(outf))
errf = np.abs(gotf - want @ w).max() / max(1.0, np.abs(want @ w).max())
assert errf < 1e-3, errf
# per-layer engine, mixed (ps, dist) schedules, shared layout, 8 devices
eng_pl = C.GNNEngine.build(g, mesh, layer_configs=[
    dict(ps=4, dist=2), dict(ps=16, dist=1)])
eng_1p = C.GNNEngine.build(g, mesh, ps=8, dist=2)
init, apply, kw = C.MODEL_ZOO["gcn"]
params = init(jax.random.key(0), 23, 5, **kw)
o_pl = C.unpad_embeddings(eng_pl.plan, np.asarray(
    apply(params, eng_pl, eng_pl.shard(eng_pl.pad(x)))))
o_1p = C.unpad_embeddings(eng_1p.plan, np.asarray(
    apply(params, eng_1p, eng_1p.shard(eng_1p.pad(x)))))
assert np.abs(o_pl - o_1p).max() < 1e-3
# fused engine == unfused engine on the 8-device ring
eng_fu = C.GNNEngine.build(g, mesh, ps=8, dist=2, fuse_update=True)
o_fu = C.unpad_embeddings(eng_fu.plan, np.asarray(
    apply(params, eng_fu, eng_fu.shard(eng_fu.pad(x)))))
assert np.abs(o_fu - o_1p).max() < 2e-3
print("PASSED")
