"""Ring-pipelined TP matmuls == XLA SPMD collectives (8-device mesh).

A dense smoke config runs forward + loss twice on a (data=2, model=4)
mesh: once with the default GSPMD collectives, once with
``DistCtx(use_ring_tp=True)`` routing the TP matmuls through
``ring_allgather_matmul`` / ``matmul_reducescatter``.  Same math, different
schedule ⇒ logits/loss/grads must agree to float32 tolerance.
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.dist import make_mesh
from repro.models import transformer as T

cfg = configs.get_smoke_config("codeqwen1.5-7b")
cfg = dataclasses.replace(cfg, param_dtype="float32",
                          compute_dtype="float32", remat=False)
mesh = make_mesh((2, 4), ("data", "model"))
B, S = 4, 16
assert S % 4 == 0 and B % 2 == 0

params = T.init_params(jax.random.key(0), cfg, vocab_multiple=4)
tokens = jnp.asarray(
    np.random.default_rng(0).integers(0, cfg.vocab, size=(B, S)), jnp.int32)

ctx_ref = T.DistCtx(mesh=mesh)
ctx_ring = T.DistCtx(mesh=mesh, use_ring_tp=True)

logits_ref, _ = jax.jit(
    lambda p, t: T.forward(p, cfg, t, ctx=ctx_ref))(params, tokens)
logits_ring, _ = jax.jit(
    lambda p, t: T.forward(p, cfg, t, ctx=ctx_ring))(params, tokens)
np.testing.assert_allclose(np.asarray(logits_ring), np.asarray(logits_ref),
                           rtol=2e-4, atol=2e-4)

loss_ref, grads_ref = jax.jit(jax.value_and_grad(
    lambda p: T.loss_fn(p, cfg, {"tokens": tokens}, ctx=ctx_ref)[0]))(params)
loss_ring, grads_ring = jax.jit(jax.value_and_grad(
    lambda p: T.loss_fn(p, cfg, {"tokens": tokens}, ctx=ctx_ring)[0]))(params)
np.testing.assert_allclose(float(loss_ring), float(loss_ref),
                           rtol=1e-5, atol=1e-6)
for a, b in zip(jax.tree.leaves(grads_ring), jax.tree.leaves(grads_ref)):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=5e-3, atol=5e-4)

# decode path: S=1 does not divide the model axis — the flag must fall
# back to the plain matmul and still produce identical next-token logits.
cache = T.init_cache(cfg, B, 8, jnp.float32)
lr, _ = T.prefill(params, cfg, tokens[:, :8], cache, ctx=ctx_ref)
lg, _ = T.prefill(params, cfg, tokens[:, :8], cache, ctx=ctx_ring)
np.testing.assert_allclose(np.asarray(lg), np.asarray(lr),
                           rtol=2e-4, atol=2e-4)

print("ring-TP == SPMD: logits/loss/grads/prefill agree")
print("PASSED")
