"""8-device sparse-ring equivalence: the compressed-payload pipeline vs the
dense one.  (a) k == D is bitwise-equal to dense across every schedule knob
(plain, no-interleave, fused ·W, streamed at any cache capacity); (b) at
k < D the output is deterministic across ring sizes — property-swept with
integer-valued features so fp sums are exact."""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np, jax, jax.numpy as jnp
import repro.core as C
from repro.core.pipeline import (mgg_aggregate_sparse_streamed,
                                 mgg_aggregate_streamed)
from repro.dist import flat_ring_mesh
from repro.store import FeatureStore, TieredFeatures
from repro.testing.hypo import given, settings, strategies as st
from jax.sharding import NamedSharding, PartitionSpec as P

g = C.power_law(400, avg_degree=9.0, locality=0.35, seed=11)
N, D = g.num_nodes, 23
x = np.random.default_rng(3).normal(size=(N, D)).astype(np.float32)
mesh = flat_ring_mesh(8)
bits = lambda a: np.asarray(a).view(np.uint32)

# -- (a) k == D: bitwise-equal to the dense ring, every schedule knob ------
for ps, dist, il in [(4, 1, True), (16, 2, False), (8, 4, True)]:
    plan = C.build_plan(g, 8, ps=ps, dist=dist)
    xp = jnp.asarray(C.pad_embeddings(plan, x))
    dense = C.mgg_aggregate(xp, plan, mesh, interleave=il)
    sparse = C.mgg_aggregate_sparse(xp, plan, mesh, k=D, interleave=il)
    assert (bits(dense) == bits(sparse)).all(), (ps, dist, il)

plan = C.build_plan(g, 8, ps=8, dist=2)
xp = jnp.asarray(C.pad_embeddings(plan, x))

# fused ·W inside the ring step
w = jnp.asarray(np.random.default_rng(5).normal(size=(D, 9))
                .astype(np.float32))
assert (bits(C.mgg_aggregate(xp, plan, mesh, update_w=w)) ==
        bits(C.mgg_aggregate_sparse(xp, plan, mesh, k=D, update_w=w))).all()

# streamed (tiered-store) ring, any capacity: sparse k == D ≡ dense streamed
shard = lambda a: jax.device_put(a, NamedSharding(mesh, P("ring", None)))
for cap in (0, N // 3):
    tiers = TieredFeatures(FeatureStore(x), plan, cap, shard=shard)
    if cap:
        tiers.admit(np.argsort(-g.degrees)[:cap].tolist())
    dense_s = mgg_aggregate_streamed(tiers.chunk_fetcher(), plan, mesh)
    sparse_s = mgg_aggregate_sparse_streamed(
        tiers.chunk_fetcher(), plan, mesh, k=D)
    assert (bits(dense_s) == bits(sparse_s)).all(), cap

# grads flow through the compressed ring (top-k is differentiable in values)
gr = jax.grad(lambda z: (C.mgg_aggregate_sparse(z, plan, mesh, k=7) ** 2)
              .sum())(xp)
assert np.isfinite(np.asarray(gr)).all() and float(jnp.abs(gr).sum()) > 0

# -- (b) k < D: deterministic across ring sizes ----------------------------
# Integer-valued features make every partial sum exact, so "same multiset
# of neighbors, any ring decomposition" must reproduce the bits; top-k ties
# resolve identically because selection happens per-row BEFORE the ring.
xi = np.random.default_rng(9).integers(-4, 5, size=(N, D)) \
    .astype(np.float32)
MESHES = {n: flat_ring_mesh(n) for n in (2, 4, 8)}


@given(st.integers(1, D), st.sampled_from((4, 8, 16)),
       st.sampled_from((1, 2, 4)), st.integers(0, 99))
@settings(max_examples=6, deadline=None)
def prop_ring_size_invariant(k, ps, dist, seed):
    xs = xi * (1 + seed % 3)          # vary magnitudes, stay integer-valued
    outs = []
    for n, m in MESHES.items():
        plan = C.build_plan(g, n, ps=ps, dist=dist)
        out = C.mgg_aggregate_sparse(
            jnp.asarray(C.pad_embeddings(plan, xs)), plan, m, k=k)
        outs.append(C.unpad_embeddings(plan, np.asarray(out)))
    for o in outs[1:]:
        assert (bits(outs[0]) == bits(o)).all(), (k, ps, dist)


prop_ring_size_invariant()
print("PASSED")
