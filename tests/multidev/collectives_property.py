"""Property sweep: pipelined collectives vs dense references across device
counts (2, 4, 8), chunk counts, and non-divisible shapes.

Runs in ONE 8-device subprocess: sub-meshes are carved out of the process
devices (repro.dist.make_mesh accepts fewer devices than the process has),
so every device count shares the interpreter.  The degenerate 1-device ring
is covered in-process by tests/test_collectives.py.
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.dist import (ef_allreduce_mean, ef_state_init, make_mesh,
                        matmul_reducescatter, pipelined_all_to_all,
                        ring_allgather_matmul)

from repro.testing.hypo import given, settings, strategies as st

N_DEVS = (2, 4, 8)
MESHES = {n: make_mesh((n,), ("x",)) for n in N_DEVS}


@given(st.sampled_from(N_DEVS), st.integers(1, 6), st.integers(1, 37),
       st.integers(1, 19), st.integers(0, 99))
@settings(max_examples=30, deadline=None)
def prop_allgather_matmul(n, m_local, k, p, seed):
    """Every shard reconstructs gather(A) @ B exactly (k, p arbitrary)."""
    mesh = MESHES[n]
    rng = np.random.default_rng(seed)
    a = jnp.asarray(rng.normal(size=(n * m_local, k)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(k, p)), jnp.float32)
    fn = jax.shard_map(lambda x, w: ring_allgather_matmul(x, w, "x"),
                       mesh=mesh, in_specs=(P("x"), P()), out_specs=P("x"),
                       check_vma=False)
    out = np.asarray(fn(a, b))                      # (n · n·m_local, p)
    want = np.asarray(a @ b)
    for dev in range(n):                            # each shard's full copy
        got = out[dev * n * m_local:(dev + 1) * n * m_local]
        np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-5)


@given(st.sampled_from(N_DEVS), st.integers(1, 40), st.integers(1, 4),
       st.integers(1, 11), st.integers(0, 99))
@settings(max_examples=30, deadline=None)
def prop_matmul_reducescatter(n, m, k_local, p, seed):
    """Scattered row blocks of sum_k(A_k @ B_k); m NOT necessarily
    divisible by n (rows zero-pad to n·ceil(m/n))."""
    mesh = MESHES[n]
    rng = np.random.default_rng(seed)
    a = jnp.asarray(rng.normal(size=(m, n * k_local)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(n * k_local, p)), jnp.float32)
    fn = jax.shard_map(lambda x, w: matmul_reducescatter(x, w, "x"),
                       mesh=mesh, in_specs=(P(None, "x"), P("x", None)),
                       out_specs=P("x"), check_vma=False)
    out = np.asarray(fn(a, b))                      # (n·ceil(m/n), p)
    want = np.asarray(a @ b)
    np.testing.assert_allclose(out[:m], want, rtol=2e-4, atol=2e-5)
    assert np.abs(out[m:]).max(initial=0.0) == 0.0  # pad rows stay zero


@given(st.sampled_from(N_DEVS), st.integers(1, 3), st.integers(1, 23),
       st.integers(1, 8), st.integers(1, 3), st.integers(0, 99))
@settings(max_examples=30, deadline=None)
def prop_pipelined_all_to_all(n, rows, width, chunks, depth, seed):
    """a2a → fn → inverse a2a == fn elementwise, any chunk count (chunks
    may exceed or not divide the chunk axis — uneven pieces).  The *split*
    axis must stay n-divisible per shard (lax.all_to_all contract), hence
    the n²·rows global extent."""
    mesh = MESHES[n]
    rng = np.random.default_rng(seed)
    z = jnp.asarray(rng.normal(size=(n * n * rows, width, depth)),
                    jnp.float32)
    fn = jax.shard_map(
        lambda x: pipelined_all_to_all(
            x, "x", lambda c: 2.0 * c + 1.0, split_axis=0, concat_axis=1,
            chunk_axis=1, chunks=chunks),
        mesh=mesh, in_specs=(P("x"),), out_specs=P("x"), check_vma=False)
    np.testing.assert_allclose(np.asarray(fn(z)), 2.0 * np.asarray(z) + 1.0,
                               rtol=1e-6, atol=1e-6)


@given(st.sampled_from(N_DEVS), st.integers(1, 16), st.integers(1, 9),
       st.integers(0, 99))
@settings(max_examples=10, deadline=None)
def prop_ef_allreduce_telescopes(n, rows, cols, seed):
    """Error feedback: accumulated compressed means converge to the
    accumulated true mean (residual telescopes to the final e_T)."""
    mesh = MESHES[n]
    rng = np.random.default_rng(seed)
    g = {"w": jnp.asarray(rng.normal(size=(rows, cols)), jnp.float32)}
    err = ef_state_init(g)
    acc = np.zeros((rows, cols), np.float32)
    steps = 8
    for _ in range(steps):
        mean, err = ef_allreduce_mean(g, err, mesh, ("x",), {"w": P()})
        acc += np.asarray(mean["w"])
    scale = max(float(np.abs(np.asarray(g["w"])).max()), 1e-6)
    assert np.abs(acc / steps - np.asarray(g["w"])).max() / scale < 0.02


if __name__ == "__main__":
    for prop in (prop_allgather_matmul, prop_matmul_reducescatter,
                 prop_pipelined_all_to_all, prop_ef_allreduce_telescopes):
        prop()
        print("ok:", prop.__name__)
    print("PASSED")
