"""8-device pipelined collectives vs dense references."""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.dist import (make_mesh, ring_allgather_matmul, matmul_reducescatter,
                        pipelined_all_to_all, ef_state_init, ef_allreduce_mean)

mesh = make_mesh((8,), ("x",))
rng = np.random.default_rng(0)
x = jnp.asarray(rng.normal(size=(32, 48)), jnp.float32)
w = jnp.asarray(rng.normal(size=(48, 6)), jnp.float32)
rs = jax.shard_map(lambda a, b: matmul_reducescatter(a, b, "x"), mesh=mesh,
                   in_specs=(P(None, "x"), P("x", None)), out_specs=P("x"), check_vma=False)
assert np.abs(np.asarray(rs(x, w)) - np.asarray(x @ w)).max() < 1e-3
xs = jnp.asarray(rng.normal(size=(64, 16)), jnp.float32)
w1 = jnp.asarray(rng.normal(size=(16, 8)), jnp.float32)
ag = jax.shard_map(lambda a, b: ring_allgather_matmul(a, b, "x"), mesh=mesh,
                   in_specs=(P("x"), P()), out_specs=P("x"), check_vma=False)
assert np.abs(np.asarray(ag(xs, w1))[:64] - np.asarray(xs @ w1)).max() < 1e-4
zz = jnp.asarray(rng.normal(size=(64, 16, 4)), jnp.float32)
a2a = jax.shard_map(lambda z: pipelined_all_to_all(
        z, "x", lambda c: c * 3.0, split_axis=0, concat_axis=1, chunk_axis=1, chunks=4),
    mesh=mesh, in_specs=(P("x"),), out_specs=P("x"), check_vma=False)
assert np.allclose(np.asarray(a2a(zz)), np.asarray(zz) * 3.0)
# error-feedback compression: quantization error decays via feedback
g = {"a": jnp.asarray(rng.normal(size=(16, 6)), jnp.float32)}
err = ef_state_init(g)
acc = np.zeros((16, 6), np.float32)
true = np.asarray(g["a"])
for _ in range(8):
    mean, err = ef_allreduce_mean(g, err, mesh, ("x",), {"a": P()})
    acc += np.asarray(mean["a"])
# accumulated compressed means converge to accumulated true mean
rel = np.abs(acc / 8 - true).max() / np.abs(true).max()
assert rel < 0.02, rel
print("PASSED")
