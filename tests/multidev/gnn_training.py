"""8-device end-to-end GCN training: loss must drop on learnable features."""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np, jax, jax.numpy as jnp
import repro.core as C
from repro.dist import flat_ring_mesh
from repro.train.data import graph_features
from repro.train.optimizer import AdamWConfig, adamw_init, adamw_update

g = C.power_law(600, avg_degree=8.0, locality=0.4, seed=7)
D, ncls = 24, 6
x, y, train_mask = graph_features(g.num_nodes, D, ncls, seed=1)
mesh = flat_ring_mesh(8)
eng = C.GNNEngine.build(g, mesh, ps=8, dist=1)
xp = eng.shard(eng.pad(x))
pad1 = lambda a: C.pad_table(eng.plan.bounds, eng.plan.rows_per_dev, a[:, None])[:, 0]
yp = jnp.asarray(pad1(y.astype(np.int32)))
mp = jnp.asarray(pad1(train_mask.astype(np.float32)))
init, apply, kw = C.MODEL_ZOO["gcn"]
params = init(jax.random.key(0), D, ncls, **kw)
opt = adamw_init(params)
# lr tuned for the 25-step budget: aggregation over random-label neighbors
# dilutes the class signal, so 5e-3 plateaus just under the asserted drop
ocfg = AdamWConfig(lr=2e-2, warmup_steps=2, total_steps=40, weight_decay=0.0)

@jax.jit
def step(params, opt):
    def loss_fn(p):
        return C.masked_cross_entropy(apply(p, eng, xp), yp, mp)
    loss, grads = jax.value_and_grad(loss_fn)(params)
    params, opt, _ = adamw_update(grads, opt, params, ocfg)
    return params, opt, loss

losses = []
for i in range(25):
    params, opt, loss = step(params, opt)
    losses.append(float(loss))
assert losses[-1] < losses[0] - 0.3, losses
print("loss", losses[0], "->", losses[-1])
print("PASSED")
