"""End-to-end dry-run machinery on a 2×2 fake mesh: build_cell → jit →
lower → compile → cost/collective extraction (same code path as the
512-device production dry-run)."""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import numpy as np, jax
from repro.dist import make_mesh
from repro.launch.cells import build_cell
from repro.launch.dryrun import cost_analysis_dict, parse_collectives

mesh = make_mesh((2, 2), ("data", "model"))
for arch, shape in [("granite-moe-1b-a400m", "train_4k"),
                    ("xlstm-125m", "decode_32k"),
                    ("whisper-base", "prefill_32k")]:
    cell = build_cell(arch, shape, mesh)
    with mesh:
        lowered = jax.jit(cell.fn, in_shardings=cell.in_shardings,
                          donate_argnums=cell.donate_argnums).lower(*cell.args)
        compiled = lowered.compile()
    cost = cost_analysis_dict(compiled)
    coll = parse_collectives(compiled.as_text())
    assert float(cost.get("flops", 0)) > 0, (arch, shape)
    print(arch, shape, "flops=%.3e" % float(cost["flops"]),
          "coll=%.3e" % coll["total_bytes"])
# train cells must emit collectives (DP grad reduce at minimum)
print("PASSED")
