"""8-device sampled mini-batch path: fanout-bounded GraphSAGE blocks over
the tiered store must (a) be bitwise-identical to a dense jnp.take oracle
applied to the same sampled blocks, at every hot-cache capacity including
zero, (b) never retrace after the first step — fixed block shapes are the
whole point of the padded format — and (c) chain correctly (outer block's
src ids ARE the inner block's dst ids, dst-first)."""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np, jax
import jax.numpy as jnp
import repro.core as C
from repro.sample import block_tree, sample_blocks, seed_batches
from repro.store import FeatureStore, TieredFeatures

assert len(jax.devices()) == 8

g = C.power_law(900, avg_degree=9.0, locality=0.4, seed=11)
N, D, NCLS = g.num_nodes, 24, 5
x = np.random.default_rng(3).normal(size=(N, D)).astype(np.float32)
init, _, kw = C.MODEL_ZOO["sage"]
params = init(jax.random.key(0), D, NCLS, **kw)
n_layers = len(params["layers"])
FANOUT, BATCH = 5, 64

rng = np.random.default_rng(0)
seeds = rng.choice(N, BATCH, replace=False).astype(np.int64)
blocks = sample_blocks(g, seeds, [FANOUT] * n_layers, batch=BATCH, rng=rng)

# -- (c) block chaining: dst-first, outer src == inner dst ----------------
for outer, inner in zip(blocks, blocks[1:]):
    assert np.array_equal(outer.src_ids[:outer.num_dst], inner.src_ids), \
        "outer block's dst prefix must be the inner block's src ids"
for b in blocks:
    assert np.array_equal(b.src_ids[:b.num_dst],
                          np.pad(b.src_ids[:b.num_dst], (0, 0))), "sanity"

# -- independent dense oracle over the SAME blocks ------------------------
def oracle(params, h, blocks_py):
    """Plain-jnp re-derivation of apply_blocks: materialize each level's
    neighbor rows with take (sentinel row appended by hand), mean-reduce,
    dense self+nbr update.  Written against Block objects directly, not
    block_tree, so a bug in the tree packing would show up too."""
    for i, (layer, b) in enumerate(zip(params["layers"], blocks_py)):
        buf = jnp.concatenate([h, jnp.zeros((1, h.shape[1]), h.dtype)], 0)
        nb = jnp.take(buf, jnp.asarray(b.nbr), axis=0)       # (nd, f, d)
        m = jnp.asarray(b.mask)[..., None]
        s = (nb * m).sum(axis=1)
        deg = jnp.maximum(jnp.asarray(b.mask).sum(-1), 1.0)[:, None]
        dense = lambda p, v: v @ p["w"] + p["b"]
        h = dense(layer["self"], h[:b.num_dst]) + dense(layer["nbr"], s / deg)
        if i < len(params["layers"]) - 1:
            h = jax.nn.relu(h)
    return h

bits = lambda a: np.asarray(a).view(np.uint32)

# -- (a) bitwise vs oracle at every capacity, including 0 -----------------
# np.where, not a mask-multiply: 0 * negative is -0.0, and the padded rows
# must be +0.0 bits exactly like gather_rows produces
h_full = jnp.asarray(np.where((blocks[0].src_ids >= 0)[:, None],
                              x[np.clip(blocks[0].src_ids, 0, None)],
                              np.float32(0.0)))
want = oracle(params, h_full, blocks)
for cap in (0, N // 7, N):
    tiers = TieredFeatures(FeatureStore(x), None, capacity=cap)
    if cap:
        tiers.admit(np.argsort(-g.degrees)[:cap])
    h0 = tiers.gather_rows(blocks[0].src_ids)
    assert np.array_equal(bits(h0), bits(h_full)), \
        f"gather_rows changed bits at capacity {cap}"
    got = C.apply_blocks("sage", params, h0, block_tree(blocks))
    assert np.array_equal(bits(got), bits(want)), \
        f"apply_blocks != dense oracle at capacity {cap}"

# -- (b) zero retraces across resampled batches ---------------------------
fwd = jax.jit(lambda p, h, t: C.apply_blocks("sage", p, h, t))
tiers = TieredFeatures(FeatureStore(x), None, capacity=N // 7)
tiers.admit(np.argsort(-g.degrees)[:N // 7])
ids = rng.choice(N, 200, replace=False)
for i, (sb, valid) in enumerate(seed_batches(ids, BATCH, rng=rng)):
    blks = sample_blocks(g, sb, [FANOUT] * n_layers, batch=BATCH, rng=rng)
    out = fwd(params, tiers.gather_rows(blks[0].src_ids), block_tree(blks))
    jax.block_until_ready(out)
    # last batch is short (200 % 64 seeds) — shapes must STILL be fixed
    assert out.shape == (BATCH, NCLS)
assert fwd._cache_size() == 1, \
    f"sampled step retraced: {fwd._cache_size()} cache entries"

print("PASSED")
