"""8-device cluster serving: two replicas on DISJOINT 4-device halves of
the mesh, behind the locality router with a shared ConfigCache.  A
mid-run hot-set rotation must trigger at least one staggered
(drain → shadow-retune → rejoin) cycle while nothing is dropped
cluster-wide and tail answers equal each replica's offline forward."""
import os
import tempfile
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np, jax
import repro.core as C
from repro.dist import make_mesh
from repro.runtime import DynamicGNNEngine, ProfileConfig
from repro.serve import (GNNServeEngine, LocalityRouter, ServeCluster,
                         TrafficPhase, WorkloadStats, ZipfTraffic)

devs = jax.devices()
assert len(devs) == 8
mesh_lo = make_mesh((4,), ("ring",), devices=devs[:4])
mesh_hi = make_mesh((4,), ("ring",), devices=devs[4:])
assert not (set(mesh_lo.devices.flat) & set(mesh_hi.devices.flat))

g = C.power_law(600, avg_degree=8.0, locality=0.4, seed=5)
D, ncls = 16, 6
x = np.random.default_rng(5).normal(size=(g.num_nodes, D)).astype(np.float32)
init, apply, kw = C.MODEL_ZOO["gcn"]
params = init(jax.random.key(0), D, ncls, **kw)

cache_path = os.path.join(tempfile.mkdtemp(prefix="serve-cluster-"),
                          "tuned.json")
replicas = []
for mesh in (mesh_lo, mesh_hi):
    eng = DynamicGNNEngine.build(
        g, mesh, d_feat=D, ps_space=(2, 4, 8), dist_space=(1, 2),
        pb_space=(1,), window=ProfileConfig(warmup=1, iters=1),
        cache_path=cache_path)
    replicas.append(GNNServeEngine(
        eng, params, "gcn", x, g, slots=8,
        stats=WorkloadStats(window=8, top_k=8), drift_threshold=0.5,
        check_every=2, min_records=4))

# each replica's PGAS feature table lives entirely on ITS device half
for srv, mesh in zip(replicas, (mesh_lo, mesh_hi)):
    placed = {d for buf in (srv.xp,) for d in buf.sharding.device_set}
    assert placed <= set(mesh.devices.flat), (placed, mesh)

cluster = ServeCluster(replicas, router=LocalityRouter(), log_fn=print)

# phase 1 is long enough that BOTH replicas' initial searches commit on
# steady traffic (each replica only sees ~half the stream), so the
# rotation lands on converged engines and must re-open them
phases = [
    TrafficPhase(requests=140, alpha=1.3, rate=100.0, seeds_max=4),
    TrafficPhase(requests=100, alpha=1.3, rate=100.0, rotate=True,
                 seeds_max=4),
]
results = cluster.run_trace(ZipfTraffic(g.num_nodes, D, phases, seed=9))
rep = cluster.report()
print("report:", {k: v for k, v in rep.items() if k != "per_replica"})

assert len(results) == 240 and rep["served"] == 240, rep
assert rep["dropped"] == 0, rep
assert rep["staggered_retunes"] >= 1, \
    f"no staggered retune fired under rotation: {rep}"
# the token is exclusive: every coordinated retune ran start-to-finish
# (the log records one completed cycle per token grant)
assert len(rep["retune_log"]) == rep["staggered_retunes"]
# both replicas took traffic (locality hashing spreads the hot sets)
served_by = {cluster.replica_of(r.request_id) for r in results}
assert served_by == {0, 1}, served_by

# tail correctness per replica under its final committed config
offline = {}
for r in results[-10:]:
    i = cluster.replica_of(r.request_id)
    if i not in offline:
        srv = replicas[i]
        xp = srv.eng.shard(srv.eng.pad(srv.x))
        offline[i] = C.unpad_embeddings(srv.eng.plan, np.asarray(
            jax.jit(lambda p, t: apply(p, srv.eng, t))(params, xp)))
    np.testing.assert_allclose(r.logits, offline[i][r.seeds],
                               rtol=1e-5, atol=1e-5)

print("PASSED")
