"""8-device GNN serving: Zipfian traffic with a mid-run hot-set rotation
must trigger a traffic-drift retune while serving stays correct — served
logits equal the offline full-graph forward, nothing is dropped, and the
layer-1 cache reports hits."""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np, jax
import repro.core as C
from repro.dist import flat_ring_mesh
from repro.runtime import DynamicGNNEngine, ProfileConfig
from repro.serve import (GNNServeEngine, TrafficPhase, WorkloadStats,
                         ZipfTraffic, run_trace)

g = C.power_law(600, avg_degree=8.0, locality=0.4, seed=5)
D, ncls = 16, 6
x = np.random.default_rng(5).normal(size=(g.num_nodes, D)).astype(np.float32)
mesh = flat_ring_mesh(8)
eng = DynamicGNNEngine.build(
    g, mesh, d_feat=D, ps_space=(2, 4, 8), dist_space=(1, 2), pb_space=(1,),
    window=ProfileConfig(warmup=1, iters=1))
init, apply, kw = C.MODEL_ZOO["gcn"]
params = init(jax.random.key(0), D, ncls, **kw)
srv = GNNServeEngine(eng, params, "gcn", x, g, slots=8,
                     stats=WorkloadStats(window=8, top_k=8),
                     drift_threshold=0.5, check_every=2, min_records=4)

phases = [
    TrafficPhase(requests=60, alpha=1.3, rate=100.0, seeds_max=4),
    TrafficPhase(requests=60, alpha=1.3, rate=100.0, rotate=True,
                 seeds_max=4),
]
results = run_trace(srv, ZipfTraffic(g.num_nodes, D, phases, seed=9))
rep = srv.report()
print("report:", rep)

assert len(results) == 120 and rep["dropped"] == 0, rep
assert rep["retunes"] >= 1, f"no traffic-drift retune fired: {rep}"
assert eng.tuner.reopens >= 1
assert rep["cache_hit_rate"] > 0, rep
assert any(r.cached for r in results)

# correctness across the ring: the tail of the trace (served under the
# final committed config) equals the offline jitted full-graph forward
xp = eng.shard(eng.pad(srv.x))
offline = C.unpad_embeddings(
    eng.plan, np.asarray(jax.jit(lambda p, t: apply(p, eng, t))(params, xp)))
for r in results[-10:]:
    np.testing.assert_allclose(r.logits, offline[r.seeds],
                               rtol=1e-5, atol=1e-5)

# a static single-config engine must serve bitwise-identical to offline
eng_s = C.GNNEngine.build(g, mesh, ps=8, dist=2)
srv_s = GNNServeEngine(eng_s, params, "gcn", x, g, slots=8)
off_s = C.unpad_embeddings(
    eng_s.plan,
    np.asarray(jax.jit(lambda p, t: apply(p, eng_s, t))(
        params, eng_s.shard(eng_s.pad(x)))))
for ev in ZipfTraffic(g.num_nodes, D,
                      [TrafficPhase(requests=12, seeds_max=4)], seed=3):
    srv_s.submit(ev.seeds, t=ev.t)
for r in srv_s.drain():
    assert np.array_equal(r.logits, off_s[r.seeds])
assert srv_s.cache.hit_rate > 0

print("PASSED")
