"""8-device tiered feature storage: streamed aggregation over a host
FeatureStore + device HotFeatureCache must (a) match the all-resident
ring within scatter-order tolerance, (b) be bitwise-identical across
capacities through the streamed path, (c) overlap prefetch with the ring
(structural count), and (d) serve logits bitwise-equal to the resident
serving path — including after live feature updates."""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np, jax
import repro.core as C
from repro.core.pipeline import mgg_aggregate_streamed
from repro.dist import flat_ring_mesh
from repro.runtime import DynamicGNNEngine, ProfileConfig
from repro.serve import GNNServeEngine, TrafficPhase, ZipfTraffic, run_trace
from repro.store import FeatureStore, TieredFeatures
from jax.sharding import NamedSharding, PartitionSpec as P

g = C.power_law(600, avg_degree=8.0, locality=0.4, seed=7)
N, D = g.num_nodes, 16
x = np.random.default_rng(7).normal(size=(N, D)).astype(np.float32)
mesh = flat_ring_mesh(8)
shard = lambda a: jax.device_put(a, NamedSharding(mesh, P("ring", None)))

# -- streamed aggregation vs the resident ring, across capacities ---------
import jax.numpy as jnp
plan = C.build_plan(g, 8, ps=8, dist=2)
resident = np.asarray(C.mgg_aggregate(
    jnp.asarray(C.pad_embeddings(plan, x)), plan, mesh, interleave=True))

outs, stats_by_cap = {}, {}
for cap in (0, N // 3, N):
    tiers = TieredFeatures(FeatureStore(x), plan, cap, shard=shard)
    if cap:
        tiers.admit(np.argsort(-g.degrees)[:cap].tolist())
    st = {}
    outs[cap] = np.asarray(mgg_aggregate_streamed(
        tiers.chunk_fetcher(), plan, mesh, stats=st))
    stats_by_cap[cap] = st
assert np.array_equal(outs[0], outs[N // 3]), "capacity changed the bits"
assert np.array_equal(outs[0], outs[N]), "capacity changed the bits"
np.testing.assert_allclose(outs[0], resident, rtol=2e-5, atol=2e-5)
# double-buffered prefetch actually issued (dist − 1 per call)
assert all(s["prefetch_issued"] == 1 for s in stats_by_cap.values()), \
    stats_by_cap

# padded_table assembles the exact resident table, bit for bit
tiers = TieredFeatures(FeatureStore(x), plan, N // 3, shard=shard)
tiers.admit(np.argsort(-g.degrees)[:N // 3].tolist())
assert np.array_equal(np.asarray(tiers.padded_table()),
                      C.pad_embeddings(plan, x))
rep = tiers.report()
assert rep["cache_rows_served"] > 0 and rep["host_rows_streamed"] > 0, rep

# -- tiered serving ≡ resident serving, with live updates ------------------
init, apply, kw = C.MODEL_ZOO["gcn"]
params = init(jax.random.key(0), D, 6, **kw)
phases = [TrafficPhase(requests=60, alpha=1.2, rate=100.0, seeds_max=4,
                       update_frac=0.05)]

def serve(**extra):
    eng = C.GNNEngine.build(g, mesh, ps=8, dist=2)
    srv = GNNServeEngine(eng, params, "gcn", x, g, slots=8, **extra)
    return srv, run_trace(srv, ZipfTraffic(N, D, phases, seed=11))

srv_res, r_res = serve()
srv_tier, r_tier = serve(feature_capacity=N // 3)
assert len(r_res) == len(r_tier) > 0
for a, b in zip(r_res, r_tier):
    assert np.array_equal(a.logits, b.logits), \
        "tiered serving diverged from resident serving"
trep = srv_tier.report()["tiers"]
assert trep["store_updates"] > 0, trep          # updates flowed via store
assert trep["cache_rows_served"] > 0, trep      # hot tier used
assert srv_tier.report()["cache_hit_rate"] > 0  # h1 cache still works

# -- dynamic engine: the cap knob reaches the tiers on rebuild -------------
deng = DynamicGNNEngine.build(
    g, mesh, d_feat=D, ps_space=(4, 8), dist_space=(1, 2), pb_space=(1,),
    cap_space=(0, N // 4, N), window=ProfileConfig(warmup=0, iters=1))
srv_d = GNNServeEngine(deng, params, "gcn", x, g, slots=8,
                       feature_capacity=None, feature_store=FeatureStore(x))
assert srv_d.tiers is not None
run_trace(srv_d, ZipfTraffic(N, D, [TrafficPhase(requests=80, seeds_max=4)],
                             seed=13))
assert deng.tuner.converged
cap = deng.feature_capacity
assert cap is not None and srv_d.tiers.capacity == cap, \
    (cap, srv_d.tiers.capacity)

print("PASSED")
