"""Elastic scaling: a checkpoint saved under a 2-device mesh restores onto
an 8-device mesh with different sharding — the restart path for a resized
cluster (DESIGN.md §5.5)."""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import tempfile
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.dist import make_mesh
from repro.train import checkpoint as ck

tree = dict(w=jnp.arange(64 * 16, dtype=jnp.float32).reshape(64, 16),
            b=jnp.ones((64,), jnp.bfloat16))
mesh2 = make_mesh((2,), ("data",))
sh2 = dict(w=NamedSharding(mesh2, P("data", None)),
           b=NamedSharding(mesh2, P("data")))
tree2 = jax.tree.map(lambda x, s: jax.device_put(x, s), tree, sh2)
with tempfile.TemporaryDirectory() as d:
    ck.save(d, 7, tree2)
    # restore onto an 8-way mesh with a DIFFERENT layout
    mesh8 = make_mesh((8,), ("data",))
    sh8 = dict(w=NamedSharding(mesh8, P(None, "data")),  # other dim!
               b=NamedSharding(mesh8, P("data")))
    out = ck.restore(d, 7, tree, sh8)
    assert out["w"].sharding == sh8["w"]
    np.testing.assert_array_equal(np.asarray(out["w"]), np.asarray(tree["w"]))
    np.testing.assert_array_equal(
        np.asarray(out["b"], np.float32), np.asarray(tree["b"], np.float32))
    assert out["b"].dtype == jnp.bfloat16
print("PASSED")
