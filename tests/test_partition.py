"""Property tests (hypothesis) for the paper's workload-management invariants
(§3.1): edge balance, locality split exactness, neighbor-partition coverage,
and the PGAS placement roundtrip."""
import numpy as np
import pytest
from repro.testing.hypo import given, settings, strategies as st

from repro.core import (
    CSRGraph, build_plan, edge_balanced_node_split, erdos_renyi,
    locality_edge_split, neighbor_partitions, pad_embeddings, power_law,
    unpad_embeddings,
)


def graphs(draw):
    n = draw(st.integers(8, 300))
    deg = draw(st.floats(0.5, 12.0))
    kind = draw(st.sampled_from(["er", "pl"]))
    seed = draw(st.integers(0, 10_000))
    if kind == "er":
        return erdos_renyi(n, deg, seed)
    return power_law(n, deg, locality=draw(st.floats(0, 0.8)), seed=seed)


graph_st = st.composite(graphs)()


@given(graph_st, st.integers(1, 12))
@settings(max_examples=40, deadline=None)
def test_edge_balanced_split_invariants(g, parts):
    bounds = edge_balanced_node_split(g.indptr, parts)
    assert bounds[0] == 0 and bounds[-1] == g.num_nodes
    assert (np.diff(bounds) >= 0).all()
    per = [int(g.indptr[bounds[p + 1]] - g.indptr[bounds[p]])
           for p in range(parts)]
    assert sum(per) == g.num_edges
    # Algorithm 1 guarantee: every partition stops at the first node whose
    # cumulative edges reach lastPos + ceil(E/P), so a partition exceeds the
    # target by at most the degree of its final node.
    target = -(-g.num_edges // parts)
    max_deg = int(g.degrees.max()) if g.num_nodes else 0
    assert max(per) <= target + max_deg


@given(graph_st, st.integers(1, 8))
@settings(max_examples=30, deadline=None)
def test_locality_split_exact(g, parts):
    bounds = edge_balanced_node_split(g.indptr, parts)
    tot = 0
    for p in range(parts):
        vg = locality_edge_split(g, bounds, p)
        assert vg.local.num_nodes == vg.remote.num_nodes == vg.ub - vg.lb
        if vg.local.num_edges:
            assert (vg.local.indices >= vg.lb).all()
            assert (vg.local.indices < vg.ub).all()
        if vg.remote.num_edges:
            outside = (vg.remote.indices < vg.lb) | (vg.remote.indices >= vg.ub)
            assert outside.all()
        # row-wise edge conservation
        for v in range(vg.ub - vg.lb):
            got = sorted(vg.local.row(v).tolist() + vg.remote.row(v).tolist())
            want = sorted(g.row(vg.lb + v).tolist())
            assert got == want
        tot += vg.local.num_edges + vg.remote.num_edges
    assert tot == g.num_edges


@given(graph_st, st.integers(1, 33))
@settings(max_examples=30, deadline=None)
def test_neighbor_partitions_cover(g, ps):
    parts = neighbor_partitions(g, ps)
    assert parts.mask.sum() == g.num_edges
    # per-partition: at most ps valid slots, single target node
    sizes = parts.mask.sum(1)
    assert (sizes <= ps).all()
    # reconstruct each node's neighbor multiset
    for v in range(g.num_nodes):
        sel = parts.targets == v
        got = sorted(parts.nbrs[sel][parts.mask[sel]].tolist())
        assert got == sorted(g.row(v).tolist())


@given(graph_st, st.integers(1, 8), st.integers(1, 8), st.integers(1, 4))
@settings(max_examples=20, deadline=None)
def test_plan_shapes_and_roundtrip(g, n_dev, ps, dist):
    plan = build_plan(g, n_dev, ps=ps, dist=dist)
    assert plan.rows_per_dev % dist == 0
    assert plan.remote_nbrs.shape[1] == max(1, (n_dev - 1) * dist)
    # every remote offset stays within one ring tile
    assert plan.remote_nbrs.max(initial=0) < plan.tile_rows
    x = np.random.default_rng(0).normal(
        size=(g.num_nodes, 3)).astype(np.float32)
    assert np.array_equal(unpad_embeddings(plan, pad_embeddings(plan, x)), x)
    # edge conservation across local+remote partitions
    edges = int(plan.local_mask.sum() + plan.remote_mask.sum())
    assert edges == g.num_edges


def test_split_matches_paper_algorithm_semantics():
    # hand-checkable case: 6 nodes, degrees [4, 1, 1, 4, 1, 1], 2 parts
    indptr = np.array([0, 4, 5, 6, 10, 11, 12])
    bounds = edge_balanced_node_split(indptr, 2)
    # target = 6 edges per part; node 0..1 gives 5, node 0..2 gives 6 → cut at 2
    assert bounds.tolist() == [0, 2, 6] or bounds.tolist() == [0, 3, 6]
    per = [indptr[bounds[1]] - 0, indptr[-1] - indptr[bounds[1]]]
    assert abs(per[0] - per[1]) <= 4
