"""repro.serve.cluster: routing policies, single-replica bitwise
equivalence, permutation-invariant multi-replica results, and the
staggered drain → retune → rejoin protocol with shared-ConfigCache warm
starts (the 8-device disjoint-halves path runs via
tests/multidev/serve_cluster.py through test_system.py)."""
import os

import numpy as np
import jax
import pytest

import repro.core as C
from repro.dist import flat_ring_mesh
from repro.runtime import DynamicGNNEngine, ProfileConfig
from repro.serve import (GNNServeEngine, LeastLoadRouter, LocalityRouter,
                         ServeCluster, TrafficPhase, WorkloadStats,
                         ZipfTraffic, make_router, run_trace)
from repro.serve.router import _mix


def _graph_setup(seed=0, n=240):
    g = C.power_law(n, avg_degree=6.0, locality=0.3, seed=seed)
    D, ncls = 12, 5
    x = np.random.default_rng(seed).normal(
        size=(g.num_nodes, D)).astype(np.float32)
    init, apply, kw = C.MODEL_ZOO["gcn"]
    params = init(jax.random.key(seed), D, ncls, **kw)
    return g, x, params, apply


def _static_serve(g, x, params, slots=4):
    eng = C.GNNEngine.build(g, flat_ring_mesh(1), ps=8, dist=1)
    return GNNServeEngine(eng, params, "gcn", x, g, slots=slots)


def _dynamic_serve(g, x, params, cache_path, slots=4,
                   drift_threshold=0.5):
    """drift_threshold > 1 makes organic retunes impossible (drift is
    bounded in [0, 1]) — the token/adoption tests drive the gate by hand
    and need a deterministic retune count."""
    eng = DynamicGNNEngine.build(
        g, flat_ring_mesh(1), d_feat=x.shape[1], ps_space=(2, 4, 8),
        dist_space=(1, 2), pb_space=(1,),
        window=ProfileConfig(warmup=0, iters=1), cache_path=cache_path)
    return GNNServeEngine(eng, params, "gcn", x, g, slots=slots,
                          stats=WorkloadStats(window=8, top_k=8),
                          drift_threshold=drift_threshold, check_every=2,
                          min_records=4)


# ---------------------------------------------------------------------------
# routers
# ---------------------------------------------------------------------------

def test_make_router_and_names():
    assert make_router("load").name == "load"
    assert make_router("locality").name == "locality"
    with pytest.raises(ValueError):
        make_router("random")


def test_least_load_router_picks_emptiest_available():
    class Fake:
        def __init__(self, pending):
            self.pending_seeds = pending
    reps = [Fake(5), Fake(1), Fake(3)]
    r = LeastLoadRouter()
    assert r.pick(np.array([1]), reps, [0, 1, 2]) == 1
    assert r.pick(np.array([1]), reps, [0, 2]) == 2     # 1 out of rotation
    with pytest.raises(ValueError):
        r.pick(np.array([1]), reps, [])


def test_locality_router_is_affine_and_falls_back_on_load():
    class FakeCache:
        def ready(self, _seeds):
            return False

    class Fake:
        def __init__(self, pending):
            self.pending_seeds = pending
            self.slots = 4
            self.cache = FakeCache()

    reps = [Fake(0), Fake(0)]
    r = LocalityRouter(load_slack=1.0)
    seeds = np.array([7, 42])
    home = r.pick(seeds, reps, [0, 1])
    # deterministic affinity: same seeds → same replica, stable anchor
    assert home == _mix(min((7, 42), key=_mix)) % 2
    for _ in range(5):
        assert r.pick(seeds, reps, [0, 1]) == home
    # a superset request sharing the anchor co-locates
    assert r.pick(np.array([7, 42, 99999]), reps, [0, 1]) in (home,
                                                              (home + 1) % 2)
    # home out of rotation → least load among the rest
    assert r.pick(seeds, reps, [1 - home]) == 1 - home
    # home overloaded past the slack → least load fallback
    reps[home].pending_seeds = 100
    assert r.pick(seeds, reps, [0, 1]) == 1 - home


def test_locality_router_prefers_cache_ready_fallback():
    class FakeCache:
        def __init__(self, ready):
            self._r = ready

        def ready(self, _seeds):
            return self._r

    class Fake:
        def __init__(self, pending, ready):
            self.pending_seeds = pending
            self.slots = 4
            self.cache = FakeCache(ready)

    r = LocalityRouter(load_slack=0.0)
    seeds = np.array([5])
    home = _mix(5) % 3
    reps = [Fake(0, False), Fake(0, False), Fake(0, False)]
    reps[home].pending_seeds = 50               # overloaded home
    ready_i = (home + 1) % 3
    reps[ready_i] = Fake(10, True)              # ready but busier than...
    other = (home + 2) % 3                      # ...the cold replica
    assert reps[other].pending_seeds == 0
    assert r.pick(seeds, reps, [0, 1, 2]) == ready_i


# ---------------------------------------------------------------------------
# single-replica equivalence + multi-replica permutation invariance
# ---------------------------------------------------------------------------

def _fig11_like_trace(g, d, seed=7, update_frac=0.1):
    phases = [
        TrafficPhase(requests=20, alpha=1.3, rate=150.0, seeds_max=3,
                     update_frac=update_frac),
        TrafficPhase(requests=20, alpha=1.3, rate=500.0, rotate=True,
                     seeds_max=3, update_frac=update_frac),
    ]
    return ZipfTraffic(g.num_nodes, d, phases, seed=seed)


@pytest.mark.parametrize("router", ["load", "locality"])
def test_cluster_of_one_is_bitwise_identical_to_bare_engine(router):
    g, x, params, _apply = _graph_setup()
    bare = _static_serve(g, x, params)
    res_bare = run_trace(bare, _fig11_like_trace(g, x.shape[1]))

    solo = _static_serve(g, x, params)
    cluster = ServeCluster([solo], router=make_router(router))
    res_cluster = cluster.run_trace(_fig11_like_trace(g, x.shape[1]))

    assert len(res_bare) == len(res_cluster) > 0
    for ra, rb in zip(res_bare, res_cluster):
        assert ra.request_id == rb.request_id
        assert ra.cached == rb.cached
        np.testing.assert_array_equal(ra.seeds, rb.seeds)
        np.testing.assert_array_equal(ra.logits, rb.logits)   # bitwise
    rep = cluster.report()
    assert rep["dropped"] == 0 and rep["served"] == len(res_bare)


@pytest.mark.parametrize("router", ["load", "locality"])
def test_cluster_results_permutation_invariant_vs_single_engine(router):
    """Any routing policy must serve the same answers the single engine
    serves for the same request stream (updates excluded: their relative
    order vs queued requests is the one thing routing may reorder)."""
    g, x, params, _apply = _graph_setup(seed=1)
    bare = _static_serve(g, x, params)
    res_bare = run_trace(bare, _fig11_like_trace(g, x.shape[1], seed=5,
                                                 update_frac=0.0))
    by_id = {r.request_id: r for r in res_bare}

    replicas = [_static_serve(g, x, params) for _ in range(3)]
    cluster = ServeCluster(replicas, router=make_router(router))
    res_c = cluster.run_trace(_fig11_like_trace(g, x.shape[1], seed=5,
                                                update_frac=0.0))
    assert sorted(r.request_id for r in res_c) == \
        sorted(by_id)                                   # same request set
    for r in res_c:
        ref = by_id[r.request_id]
        np.testing.assert_array_equal(r.seeds, ref.seeds)
        np.testing.assert_array_equal(r.logits, ref.logits)
    # with >1 replica at least two of them actually served something
    served = {cluster.replica_of(r.request_id) for r in res_c}
    assert len(served) >= 2


def test_update_features_fans_out_to_every_replica():
    g, x, params, _apply = _graph_setup(seed=2)
    replicas = [_static_serve(g, x, params) for _ in range(2)]
    cluster = ServeCluster(replicas)
    n_inv = cluster.update_features(5, 2.0 * np.ones(x.shape[1],
                                                     np.float32))
    assert n_inv == 0                        # caches still cold: no rows
    for r in replicas:
        np.testing.assert_array_equal(r.x[5], 2.0 * np.ones(x.shape[1]))


def test_cluster_rejects_replicas_with_history():
    g, x, params, _apply = _graph_setup(seed=4, n=120)
    srv = _static_serve(g, x, params)
    srv.submit(np.array([1]))
    srv.step()
    with pytest.raises(ValueError):
        ServeCluster([srv])


# ---------------------------------------------------------------------------
# staggered retunes + shared-cache warm start
# ---------------------------------------------------------------------------

def _pump_to_completion(cluster, limit=300):
    for _ in range(limit):
        cluster.pump()
        if cluster._token is None:
            return
    raise AssertionError("coordinated retune never completed")


def test_shared_cache_adoption_visits_strictly_fewer_configs(tmp_path):
    """Acceptance: a retune paid for on one replica warm-starts the other
    from the shared ConfigCache — the second search visits strictly fewer
    configs (single adopt-validation measurement).  Adoption requires the
    drift signals to OVERLAP (replica 1 was already waiting when replica
    0 committed), which is what rules out stale-epoch adoption."""
    g, x, params, _apply = _graph_setup(seed=3)
    cache_path = str(tmp_path / "tuned.json")
    r0 = _dynamic_serve(g, x, params, cache_path, drift_threshold=1.1)
    r1 = _dynamic_serve(g, x, params, cache_path, drift_threshold=1.1)
    cluster = ServeCluster([r0, r1], router=LeastLoadRouter())

    # converge both initial searches on steady traffic
    for rnd in range(6):
        if not (r0._tuning or r1._tuning):
            break
        cluster.run_trace(ZipfTraffic(g.num_nodes, x.shape[1], [
            TrafficPhase(requests=40, alpha=1.3, rate=100.0,
                         seeds_max=3)], seed=20 + rnd))
    assert not (r0._tuning or r1._tuning)

    # replica 0 drifts first: full re-search on shadow traffic
    assert r0.retune_gate(r0, 1.0) is False      # token acquired, not inline
    assert cluster._token == 0
    # replica 1's drift fires while 0 is still searching → deferred wait
    assert r1.retune_gate(r1, 1.0) is False
    assert cluster._token == 0
    _pump_to_completion(cluster)
    first = cluster.retune_log[-1]
    assert first["replica"] == 0 and first["committed"]
    assert not first["from_cache"]
    assert first["search_size"] >= 2             # actually searched

    # replica 1 re-asks: its wait overlapped 0's commit → adopt
    assert r1.retune_gate(r1, 1.0) is False
    assert cluster._token == 1
    _pump_to_completion(cluster)
    second = cluster.retune_log[-1]
    assert second["replica"] == 1 and second["committed"]
    assert second["from_cache"]
    assert second["search_size"] == 1            # one validation measurement
    assert second["search_size"] < first["search_size"]
    assert r1.config == r0.config                # adopted the same optimum
    assert os.path.exists(cache_path)


def test_fresh_drift_after_commit_does_not_adopt_stale_entry(tmp_path):
    """A drift that fires only AFTER a sibling's commit belongs to a new
    traffic epoch — the replica must re-search, not adopt the (possibly
    stale) cache entry."""
    g, x, params, _apply = _graph_setup(seed=8)
    cache_path = str(tmp_path / "tuned.json")
    r0 = _dynamic_serve(g, x, params, cache_path, drift_threshold=1.1)
    r1 = _dynamic_serve(g, x, params, cache_path, drift_threshold=1.1)
    cluster = ServeCluster([r0, r1], router=LeastLoadRouter())
    for rnd in range(6):
        if not (r0._tuning or r1._tuning):
            break
        cluster.run_trace(ZipfTraffic(g.num_nodes, x.shape[1], [
            TrafficPhase(requests=40, alpha=1.3, rate=100.0,
                         seeds_max=3)], seed=60 + rnd))
    assert not (r0._tuning or r1._tuning)

    assert r0.retune_gate(r0, 1.0) is False
    _pump_to_completion(cluster)
    assert cluster.retune_log[-1]["committed"]

    # replica 1's signal fires fresh, with no overlap with r0's search
    assert r1.retune_gate(r1, 1.0) is False
    assert cluster._token == 1
    _pump_to_completion(cluster)
    last = cluster.retune_log[-1]
    assert last["replica"] == 1 and last["committed"]
    assert not last["from_cache"]
    assert last["search_size"] >= 2


def test_retune_token_is_exclusive_and_deferred_counted(tmp_path):
    g, x, params, _apply = _graph_setup(seed=6)
    cache_path = str(tmp_path / "tuned.json")
    r0 = _dynamic_serve(g, x, params, cache_path, drift_threshold=1.1)
    r1 = _dynamic_serve(g, x, params, cache_path, drift_threshold=1.1)
    cluster = ServeCluster([r0, r1], router=LeastLoadRouter())
    for rnd in range(6):
        if not (r0._tuning or r1._tuning):
            break
        cluster.run_trace(ZipfTraffic(g.num_nodes, x.shape[1], [
            TrafficPhase(requests=40, alpha=1.3, rate=100.0,
                         seeds_max=3)], seed=40 + rnd))
    assert not (r0._tuning or r1._tuning)
    assert r0.retune_gate(r0, 1.0) is False
    assert cluster._token == 0
    # while replica 0 holds the token, replica 1 is deferred...
    assert r1.retune_gate(r1, 1.0) is False
    assert cluster._token == 0
    assert cluster.deferred_retunes == 1
    # ...and replica 0 re-asking is a no-op, not a second schedule
    assert r0.retune_gate(r0, 1.0) is False
    assert cluster.staggered_retunes == 1
    _pump_to_completion(cluster)
    assert cluster._token is None


def test_cluster_trace_with_drift_zero_drops_and_staggered_retune(tmp_path):
    """End-to-end: rotation + burst over 2 dynamic replicas — every
    request answered, ≥1 coordinated (drain → retune → rejoin) cycle, and
    tail answers equal to each replica's offline forward."""
    g, x, params, apply = _graph_setup(seed=5, n=300)
    cache_path = str(tmp_path / "tuned.json")
    replicas = [_dynamic_serve(g, x, params, cache_path)
                for _ in range(2)]
    cluster = ServeCluster(replicas, router=LocalityRouter())
    phases = [
        TrafficPhase(requests=50, alpha=1.4, rate=100.0, seeds_max=3),
        TrafficPhase(requests=50, alpha=1.4, rate=400.0, rotate=True,
                     seeds_max=3),
    ]
    results = cluster.run_trace(
        ZipfTraffic(g.num_nodes, x.shape[1], phases, seed=11))
    rep = cluster.report()
    assert rep["served"] == len(results) == 100
    assert rep["dropped"] == 0
    assert rep["staggered_retunes"] >= 1, rep
    assert all(e["shadow_batches"] > 0 or not e["committed"]
               for e in rep["retune_log"])
    # tail correctness under each replica's final committed config
    offline = {}
    for r in results[-8:]:
        i = cluster.replica_of(r.request_id)
        if i not in offline:
            srv = replicas[i]
            eng = srv.eng
            xp = eng.shard(eng.pad(srv.x))
            offline[i] = C.unpad_embeddings(eng.plan, np.asarray(
                jax.jit(lambda p, t: apply(p, eng, t))(params, xp)))
        np.testing.assert_allclose(r.logits, offline[i][r.seeds],
                                   rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# observability: cluster counters reconcile with per-replica counters
# ---------------------------------------------------------------------------

def test_cluster_report_counters_equal_per_replica_sums(tmp_path):
    """Every counter in ServeCluster.report() must equal the fold of the
    per-replica counters: the cluster's own registry series and the
    replicas' label-scoped series are two views of the same traffic, and
    the registry rewrite must keep them consistent."""
    from repro.obs import MetricsRegistry

    g, x, params, _apply = _graph_setup(seed=5, n=300)
    registry = MetricsRegistry()
    cache_path = str(tmp_path / "tuned.json")

    def replica(i):
        eng = DynamicGNNEngine.build(
            g, flat_ring_mesh(1), d_feat=x.shape[1], ps_space=(2, 4, 8),
            dist_space=(1, 2), pb_space=(1,),
            window=ProfileConfig(warmup=0, iters=1), cache_path=cache_path,
            metrics=registry)
        return GNNServeEngine(eng, params, "gcn", x, g, slots=4,
                              stats=WorkloadStats(window=8, top_k=8),
                              check_every=2, min_records=4,
                              feature_capacity=32,
                              metrics=registry, obs_labels={"replica": i})

    replicas = [replica(i) for i in range(2)]
    cluster = ServeCluster(replicas, router=LocalityRouter(),
                           metrics=registry)
    phases = [
        TrafficPhase(requests=40, alpha=1.4, rate=100.0, seeds_max=3),
        TrafficPhase(requests=40, alpha=1.4, rate=400.0, rotate=True,
                     seeds_max=3),
    ]
    results = cluster.run_trace(
        ZipfTraffic(g.num_nodes, x.shape[1], phases, seed=11))
    rep = cluster.report()
    per = rep["per_replica"]

    assert rep["served"] == len(results) == 80
    # replica-side `served` already excludes shadow-replay batches, so
    # the cluster's user-visible count is exactly the per-replica sum
    assert rep["served"] == sum(p["served"] for p in per)
    # the replica-side shadow flag and the cluster-side gid bookkeeping
    # count the exact same replayed batches
    assert rep["shadow_served"] == sum(p["shadow_served"] for p in per)
    assert rep["dropped"] == sum(p["dropped"] for p in per)
    tiers = [p["tiers"] for p in per if p.get("tiers")]
    assert len(tiers) == 2
    assert rep["host_rows_streamed"] == sum(
        t["host_rows_streamed"] for t in tiers)
    assert rep["cache_rows_served"] == sum(
        t["cache_rows_served"] for t in tiers)

    # the shared registry's label-summed totals agree with both views
    assert registry.counter_total("serve.served") == rep["served"]
    assert registry.counter_total("serve.served") == \
        sum(p["served"] for p in per)
    assert registry.counter_total("serve.shadow_served") == \
        rep["shadow_served"]
    assert registry.counter_total("cluster.user_served") == rep["served"]
    assert registry.counter_total("store.host_rows_streamed") == \
        rep["host_rows_streamed"]
    assert registry.counter_total("store.cache_rows_served") == \
        rep["cache_rows_served"]
