"""repro.serve.gnn: frontier extraction, layer-1 cache, drift → retune,
and served-vs-offline equality (single device; the 8-device path runs via
tests/multidev/serve_gnn.py through test_system.py)."""
import numpy as np
import jax
import pytest

import repro.core as C
from repro.dist import flat_ring_mesh
from repro.runtime import DynamicGNNEngine, ProfileConfig
from repro.serve import (GNNServeEngine, HotNodeCache, TrafficPhase,
                         WorkloadStats, ZipfTraffic, run_trace)


def _reference_khop(g, seeds, k):
    """Naive per-node BFS over in-edges (the oracle for khop_in_frontier)."""
    seen = set(int(s) for s in seeds)
    frontier = set(seen)
    for _ in range(k):
        nxt = set()
        for v in frontier:
            nxt.update(int(u) for u in g.row(v))
        frontier = nxt - seen
        seen |= frontier
    return np.array(sorted(seen), dtype=np.int64)


def _setup(model="gcn", n=240, n_dev=1, seed=0, dynamic=False):
    g = C.power_law(n, avg_degree=6.0, locality=0.3, seed=seed)
    D, ncls = 12, 5
    x = np.random.default_rng(seed).normal(
        size=(g.num_nodes, D)).astype(np.float32)
    mesh = flat_ring_mesh(n_dev)
    if dynamic:
        eng = DynamicGNNEngine.build(
            g, mesh, d_feat=D, ps_space=(4, 8), dist_space=(1,),
            pb_space=(1,), window=ProfileConfig(warmup=1, iters=1))
    else:
        eng = C.GNNEngine.build(g, mesh, ps=8, dist=1)
    init, apply, kw = C.MODEL_ZOO[model]
    params = init(jax.random.key(seed), D, ncls, **kw)
    return g, x, eng, params, apply


# ---------------------------------------------------------------------------
# frontier extraction
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("k", [0, 1, 2, 3])
def test_khop_frontier_matches_reference(k):
    g = C.power_law(150, avg_degree=5.0, seed=3).with_self_loops()
    rng = np.random.default_rng(k)
    seeds = rng.choice(g.num_nodes, size=4, replace=False)
    got = C.khop_in_frontier(g, seeds, k)
    ref = _reference_khop(g, seeds, k)
    np.testing.assert_array_equal(got, ref)


def test_neighbors_of_concatenates_rows():
    g = C.power_law(80, avg_degree=4.0, seed=1)
    nodes = np.array([0, 17, 42, 17])
    got = C.neighbors_of(g, nodes)
    ref = np.concatenate([g.row(v) for v in nodes]) if len(nodes) else []
    np.testing.assert_array_equal(got, ref)


def test_transpose_is_reverse_graph():
    g = C.power_law(60, avg_degree=4.0, seed=2)
    rev = g.transpose()
    np.testing.assert_allclose(rev.to_dense(), g.to_dense().T)


# ---------------------------------------------------------------------------
# hot-node cache
# ---------------------------------------------------------------------------

def test_hotcache_hit_miss_and_invalidate():
    cache = HotNodeCache(10)
    assert cache.lookup(np.array([1, 2, 3])) == 3   # cold: all miss
    cache.store(object())
    assert cache.lookup(np.array([1, 2])) == 0      # warm: all hit
    assert cache.ready(np.array([1, 2]))
    n = cache.invalidate(np.array([2, 5]))
    assert n == 2
    assert not cache.ready(np.array([1, 2]))
    assert cache.ready(np.array([1, 3]))
    assert cache.lookup(np.array([2])) == 1
    assert 0.0 < cache.hit_rate < 1.0


def test_hotcache_capacity_keeps_only_hot_nodes():
    cache = HotNodeCache(10, capacity=2)
    cache.store(object(), hot_nodes=[7, 3, 5])
    assert cache.ready(np.array([7, 3]))
    assert not cache.ready(np.array([5]))


def test_serving_cache_invalidation_tracks_reverse_edges():
    g, x, eng, params, apply = _setup()
    srv = GNNServeEngine(eng, params, "gcn", x, g, slots=4)
    srv.submit(np.array([1, 2]))
    srv.step()                                       # full pass → cache warm
    assert srv.cache.valid.all()
    node = 5
    dirty = srv.g_full.transpose().row(node)
    n_inv = srv.update_features(node, np.ones(x.shape[1], np.float32))
    assert n_inv == len(dirty)
    assert not srv.cache.valid[dirty].any()
    mask = np.ones(g.num_nodes, bool)
    mask[dirty] = False
    assert srv.cache.valid[mask].all()               # everyone else untouched


# ---------------------------------------------------------------------------
# served outputs == offline full-graph inference
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("model", ["gcn", "gin", "sage"])
def test_served_logits_bitwise_match_offline(model):
    g, x, eng, params, apply = _setup(model=model)
    srv = GNNServeEngine(eng, params, model, x, g, slots=4)
    traffic = ZipfTraffic(g.num_nodes, x.shape[1], [
        TrafficPhase(requests=20, alpha=1.2, seeds_max=3)], seed=7)
    results = run_trace(srv, traffic)
    assert len(results) == 20 and srv.report()["dropped"] == 0
    assert any(r.cached for r in results)            # cache path exercised
    assert srv.cache.hit_rate > 0
    # offline reference: the jitted full-graph forward (jit, like serving)
    xp = eng.shard(eng.pad(srv.x))
    offline = C.unpad_embeddings(
        eng.plan, np.asarray(jax.jit(lambda p, t: apply(p, eng, t))(
            params, xp)))
    for r in results:
        np.testing.assert_array_equal(r.logits, offline[r.seeds])


def test_deep_feature_update_not_served_stale():
    """Cached serving must gate on the (k-1)-hop frontier: with a 3-layer
    GCN, a feature update 2 reverse hops from the seed dirties h₁ rows
    outside the seed's 1-hop frontier — the next request must NOT be
    served from the cache with stale logits."""
    g = C.power_law(240, avg_degree=6.0, locality=0.3, seed=0)
    D, ncls = 12, 5
    x = np.random.default_rng(0).normal(
        size=(g.num_nodes, D)).astype(np.float32)
    eng = C.GNNEngine.build(g, flat_ring_mesh(1), ps=8, dist=1)
    params = C.MODEL_ZOO["gcn"][0](jax.random.key(0), D, ncls,
                                   hidden=16, num_layers=3)
    srv = GNNServeEngine(eng, params, "gcn", x, g, slots=4)
    seed = 3
    srv.submit(np.array([seed]))
    srv.step()                                       # warm the cache
    # a node at exactly 2 hops (outside the 1-hop frontier)
    f1 = C.khop_in_frontier(srv.g_full, np.array([seed]), 1)
    f2 = C.khop_in_frontier(srv.g_full, np.array([seed]), 2)
    deep = np.setdiff1d(f2, f1)
    if deep.size == 0:
        pytest.skip("graph too dense: no strictly-2-hop node")
    srv.update_features(int(deep[0]), 7.0 * np.ones(D, np.float32))
    srv.submit(np.array([seed]))
    (r,) = srv.step()
    xp = eng.shard(eng.pad(srv.x))
    apply = C.MODEL_ZOO["gcn"][1]
    offline = C.unpad_embeddings(
        eng.plan, np.asarray(jax.jit(lambda p, t: apply(p, eng, t))(
            params, xp)))
    np.testing.assert_array_equal(r.logits, offline[[seed]])


def test_feature_update_changes_served_logits_consistently():
    g, x, eng, params, apply = _setup()
    srv = GNNServeEngine(eng, params, "gcn", x, g, slots=4)
    seeds = np.array([3, 4])
    srv.submit(seeds)
    before = srv.step()[0].logits
    # update a node inside the seeds' receptive field
    target = int(C.khop_in_frontier(srv.g_full, seeds, 2)[0])
    srv.update_features(target, 5.0 * np.ones(x.shape[1], np.float32))
    srv.submit(seeds)
    after = srv.step()[0].logits
    assert not np.array_equal(before, after)
    xp = eng.shard(eng.pad(srv.x))
    offline = C.unpad_embeddings(
        eng.plan, np.asarray(jax.jit(lambda p, t: apply(p, eng, t))(
            params, xp)))
    np.testing.assert_array_equal(after, offline[seeds])


# ---------------------------------------------------------------------------
# stats + drift → retune
# ---------------------------------------------------------------------------

def test_workload_stats_rate_and_drift():
    s = WorkloadStats(window=8, top_k=4)
    for i in range(8):
        s.record(t=i * 0.1, seeds=np.array([1, 2, 3]), frontier_size=20)
    base = s.snapshot()
    assert base.rate == pytest.approx(10.0)
    assert base.mean_frontier == pytest.approx(20.0)
    assert base.hot_nodes == (1, 2, 3)
    assert WorkloadStats.drift(base, base) == 0.0
    # rotate the hot set: drift must hit 1 - overlap = 1
    for i in range(8, 16):
        s.record(t=i * 0.1, seeds=np.array([7, 8, 9]), frontier_size=20)
    rot = s.snapshot()
    assert WorkloadStats.drift(base, rot) == pytest.approx(1.0)
    # burst: 4x the rate on the same nodes — drift is the symmetric
    # relative change |40-10|/40, keeping the score bounded in [0, 1]
    s2 = WorkloadStats(window=8, top_k=4)
    for i in range(8):
        s2.record(t=i * 0.025, seeds=np.array([1, 2, 3]), frontier_size=20)
    burst = s2.snapshot()
    assert WorkloadStats.drift(base, burst) == pytest.approx(0.75)


def test_traffic_drift_triggers_forced_retune():
    g, x, eng, params, apply = _setup(dynamic=True)
    srv = GNNServeEngine(eng, params, "gcn", x, g, slots=4,
                         stats=WorkloadStats(window=8, top_k=8),
                         drift_threshold=0.5, check_every=2, min_records=4)
    phases = [
        TrafficPhase(requests=40, alpha=1.4, rate=100.0, seeds_max=3),
        TrafficPhase(requests=40, alpha=1.4, rate=400.0, rotate=True,
                     seeds_max=3),
    ]
    traffic = ZipfTraffic(g.num_nodes, x.shape[1], phases, seed=11)
    results = run_trace(srv, traffic)
    rep = srv.report()
    assert rep["dropped"] == 0 and len(results) == 80
    assert rep["retunes"] >= 1                        # drift re-opened search
    assert eng.tuner.reopens >= 1
    assert rep["cache_hit_rate"] > 0
    # serving survived the retune: post-drift answers equal offline under
    # the FINAL committed config (allclose: earlier configs reorder sums)
    xp = eng.shard(eng.pad(srv.x))
    offline = C.unpad_embeddings(
        eng.plan, np.asarray(jax.jit(lambda p, t: apply(p, eng, t))(
            params, xp)))
    tail = results[-5:]
    for r in tail:
        np.testing.assert_allclose(r.logits, offline[r.seeds],
                                   rtol=1e-5, atol=1e-5)


def test_oversized_request_rejected_at_admission():
    g, x, eng, params, _ = _setup()
    srv = GNNServeEngine(eng, params, "gcn", x, g, slots=2)
    with pytest.raises(ValueError):
        srv.submit(np.array([1, 2, 3]))
    with pytest.raises(ValueError):
        srv.submit(np.array([g.num_nodes + 5]))


# ---------------------------------------------------------------------------
# hot-set persistence across serve restarts
# ---------------------------------------------------------------------------

def test_hot_set_persists_across_restart(tmp_path):
    import os

    g, x, eng, params, _ = _setup()
    path = str(tmp_path / "hot.json")
    phases = [TrafficPhase(requests=40, alpha=1.3, rate=100.0, seeds_max=4)]

    srv = GNNServeEngine(eng, params, "gcn", x, g, slots=4,
                         feature_capacity=24, hotset_path=path)
    run_trace(srv, ZipfTraffic(g.num_nodes, x.shape[1], phases, seed=3))
    ids = srv.tiers.cache.resident_ids()
    assert ids.size > 0 and os.path.exists(path)

    # a fresh engine warm-loads the same admitted set before any traffic
    srv2 = GNNServeEngine(eng, params, "gcn", x, g, slots=4,
                          feature_capacity=24, hotset_path=path)
    np.testing.assert_array_equal(srv2.tiers.cache.resident_ids(), ids)
    # ids are a hint, not cached bits: rows were refetched from the store
    np.testing.assert_array_equal(
        np.asarray(srv2.tiers.cache.table)[
            srv2.tiers.cache.slots(ids)], x[ids])

    # corrupt sidecar ⇒ silent cold start, exactly as before the feature
    bad = tmp_path / "bad.json"
    bad.write_text("{not json")
    srv3 = GNNServeEngine(eng, params, "gcn", x, g, slots=4,
                          feature_capacity=24, hotset_path=str(bad))
    assert srv3.tiers.cache.resident_rows == 0


def test_sampled_frontier_bounded_and_subset_of_exact():
    """``frontier_fanout`` swaps the *stats-side* frontier measurement to
    the fanout-bounded sampled one (size ≤ slots·(fanout+1)^k) while the
    cache-gating frontier stays exact — a sampled frontier may miss a
    dirty row, so correctness never rides on it."""
    g, x, eng, params, _ = _setup(model="gcn")
    srv = GNNServeEngine(eng, params, "gcn", x, g, slots=4,
                         frontier_fanout=3, frontier_seed=7)
    seeds = np.array([1, 2, 7, 2])          # duplicates must be deduped
    f = srv.sampled_frontier(seeds)
    exact = C.khop_in_frontier(srv.g_full, np.unique(seeds), srv.k_hops)
    assert set(f.tolist()) <= set(exact.tolist())
    assert set(np.unique(seeds).tolist()) <= set(f.tolist())
    assert f.size <= 3 * (3 + 1) ** srv.k_hops
    np.testing.assert_array_equal(f, np.unique(f))   # sorted unique ids

    # served answers are untouched by the sampled measurement
    srv.submit(np.array([1, 2]))
    (res,) = srv.step()
    srv_exact = GNNServeEngine(eng, params, "gcn", x, g, slots=4)
    srv_exact.submit(np.array([1, 2]))
    (res_exact,) = srv_exact.step()
    np.testing.assert_array_equal(res.logits, res_exact.logits)
    # the recorded frontier size is the bounded sampled one
    _t, _n, fk, _ids, _r = srv.stats._events[-1]
    assert fk <= 2 * (3 + 1) ** srv.k_hops

    # without the knob the method is an explicit error, not a silent 0
    with pytest.raises(ValueError):
        srv_exact.sampled_frontier(seeds)


def test_replicas_get_distinct_hotset_sidecars(tmp_path):
    """Regression: N replicas share ONE ConfigCache path (by design — the
    tuned config is per-workload, not per-replica), and the hotset sidecar
    used to be derived from it verbatim, so every replica clobbered the
    same ``<cache>.hotset.json``.  The sidecar must be per-replica: each
    replica's traffic shapes its own hot set."""
    import os

    g, x, _eng, params, _ = _setup(dynamic=True)
    cache_path = str(tmp_path / "tuned.json")

    def mk(replica, seed):
        geng = DynamicGNNEngine.build(
            g, flat_ring_mesh(1), d_feat=x.shape[1], ps_space=(4, 8),
            dist_space=(1,), pb_space=(1,),
            window=ProfileConfig(warmup=1, iters=1), cache_path=cache_path)
        labels = {} if replica is None else {"replica": replica}
        srv = GNNServeEngine(geng, params, "gcn", x, g, slots=4,
                             feature_capacity=24, obs_labels=labels)
        phases = [TrafficPhase(requests=40, alpha=1.3, rate=100.0,
                               seeds_max=4)]
        run_trace(srv, ZipfTraffic(g.num_nodes, x.shape[1], phases,
                                   seed=seed))
        return srv

    srv0, srv1 = mk(0, seed=3), mk(1, seed=11)
    assert srv0._hotset_path == cache_path + ".hotset.r0.json"
    assert srv1._hotset_path == cache_path + ".hotset.r1.json"
    assert os.path.exists(srv0._hotset_path)
    assert os.path.exists(srv1._hotset_path)
    ids0 = srv0.tiers.cache.resident_ids()
    ids1 = srv1.tiers.cache.resident_ids()
    assert ids0.size and ids1.size

    # round-trip: each fresh replica warm-loads ITS OWN persisted set,
    # untouched by the other replica's traffic
    def warm(replica):
        geng = DynamicGNNEngine.build(
            g, flat_ring_mesh(1), d_feat=x.shape[1], ps_space=(4, 8),
            dist_space=(1,), pb_space=(1,),
            window=ProfileConfig(warmup=1, iters=1), cache_path=cache_path)
        return GNNServeEngine(geng, params, "gcn", x, g, slots=4,
                              feature_capacity=24,
                              obs_labels={"replica": replica})
    np.testing.assert_array_equal(
        np.sort(warm(0).tiers.cache.resident_ids()), np.sort(ids0))
    np.testing.assert_array_equal(
        np.sort(warm(1).tiers.cache.resident_ids()), np.sort(ids1))

    # unlabeled (single-replica) deployments keep the pre-fix path
    srv_solo = mk(None, seed=3)
    assert srv_solo._hotset_path == cache_path + ".hotset.json"
