"""Sharding-rule derivation sanity: specs must respect divisibility and
cover the big parameter dims on the production mesh shapes (validated
abstractly — no 512-device requirement in-process)."""
import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro import configs
from repro.dist.sharding import (ShardingRules, batch_specs, cache_specs,
                                 param_specs)
from repro.models import transformer as T


class FakeMesh:
    """shape/axis_names stand-in (rules only read sizes)."""
    def __init__(self, shape):
        self.shape = dict(shape)
        self.axis_names = tuple(shape)


@pytest.mark.parametrize("arch", configs.ARCH_IDS)
@pytest.mark.parametrize("multi_pod", [False, True])
def test_param_specs_divisible(arch, multi_pod):
    cfg = configs.get_config(arch)
    mesh = FakeMesh({"pod": 2, "data": 16, "model": 16} if multi_pod
                    else {"data": 16, "model": 16})
    rules = ShardingRules(mesh, data_axes=("pod", "data") if multi_pod
                          else ("data",), train=True)
    init = T.init_params if cfg.family != "encdec" else None
    if init is None:
        from repro.models import encdec
        init = encdec.init_params
    abs_p = jax.eval_shape(
        lambda k: init(k, cfg, vocab_multiple=16), jax.random.key(0))
    specs = param_specs(abs_p, rules, cfg.expert_mode)
    n_model_sharded = 0
    for leaf, spec in zip(jax.tree.leaves(abs_p),
                          jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))):
        assert len(spec) <= leaf.ndim
        for dim, entry in zip(leaf.shape, tuple(spec) + (None,) * (leaf.ndim - len(spec))):
            if entry is None:
                continue
            axes = entry if isinstance(entry, tuple) else (entry,)
            total = int(np.prod([mesh.shape[a] for a in axes]))
            assert dim % total == 0, (arch, leaf.shape, spec)
            if "model" in axes:
                n_model_sharded += 1
    assert n_model_sharded >= 3, f"{arch}: too few TP-sharded params"


@pytest.mark.parametrize("arch", ["qwen3-32b", "zamba2-7b", "xlstm-125m",
                                  "granite-moe-1b-a400m"])
def test_cache_specs_divisible(arch):
    cfg = configs.get_config(arch)
    mesh = FakeMesh({"data": 16, "model": 16})
    rules = ShardingRules(mesh, train=False)
    batch = 128
    abs_c = jax.eval_shape(lambda: T.init_cache(cfg, batch, 4096))
    specs = cache_specs(abs_c, rules, batch)
    for leaf, spec in zip(jax.tree.leaves(abs_c),
                          jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))):
        for dim, entry in zip(leaf.shape, tuple(spec)):
            if entry is None:
                continue
            axes = entry if isinstance(entry, tuple) else (entry,)
            total = int(np.prod([mesh.shape[a] for a in axes]))
            assert dim % total == 0, (arch, leaf.shape, spec)


def test_batch_not_sharded_when_indivisible():
    mesh = FakeMesh({"data": 16, "model": 16})
    rules = ShardingRules(mesh, train=False)
    abs_b = jax.eval_shape(
        lambda: jax.numpy.zeros((1, 8), jax.numpy.int32))
    spec = batch_specs(abs_b, rules)
    assert tuple(spec) == (None, None)  # batch 1 cannot shard over 16


def test_fsdp_only_in_train_mode():
    mesh = FakeMesh({"data": 16, "model": 16})
    cfg = configs.get_config("codeqwen1.5-7b")
    abs_p = jax.eval_shape(
        lambda k: T.init_params(k, cfg, vocab_multiple=16), jax.random.key(0))
    for train in (True, False):
        rules = ShardingRules(mesh, train=train)
        specs = param_specs(abs_p, rules, cfg.expert_mode)
        has_data = any(
            "data" in str(s) for s in jax.tree.leaves(
                specs, is_leaf=lambda x: isinstance(x, P)))
        assert has_data == train
