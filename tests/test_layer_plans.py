"""Per-layer pipeline plans + fused update-phase overlap.

Covers the per-layer refactor's contracts:

* plan sharing — every LayerPlan derives from ONE SharedPartition; layers
  with identical (ps, dist) share the same AggregationPlan object; mixed
  ``dist`` layers share one PGAS layout (lcm row padding);
* bitwise equality — a per-layer engine whose layers all carry one config
  is bit-for-bit the old single-plan path;
* fused update — ``(A x) W`` with the per-tile matmul inside the ring
  matches the unfused aggregate-then-matmul path across GCN/GIN/SAGE/GAT
  within the documented tolerance (rtol=atol=2e-4: the two dataflows
  differ only in float summation order), in training (forward + grads)
  and in cached serving;
* per-layer tuning — the PerLayerTuner converges to *different* per-layer
  configs on a skewed-width surface, under a shared budget, warm-started
  from the global config;
* ConfigCache v2 — per-layer entries round-trip; pre-refactor (v1) cache
  files are silently discarded, never a crash.
"""
import json
import math
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.core as C
from repro.core.placement import plan_from_partition
from repro.dist import flat_ring_mesh
from repro.runtime import (ConfigCache, DynamicGNNEngine, PerLayerTuner,
                           ProfileConfig)

RNG = np.random.default_rng(0)

# Documented tolerance for fused-vs-unfused equivalence: the fused path
# computes Σ_s (partial_s @ W), the unfused path (Σ_s partial_s) @ W —
# identical in exact arithmetic, reordered float summation otherwise.
FUSED_RTOL = FUSED_ATOL = 2e-4


def _graph(n=240, d=12, seed=5):
    g = C.power_law(n, avg_degree=7.0, locality=0.4, seed=seed)
    x = RNG.normal(size=(n, d)).astype(np.float32)
    return g, x


def _forward(engine, apply_fn, params, x):
    out = apply_fn(params, engine, engine.shard(engine.pad(x)))
    return C.unpad_embeddings(engine.plan, np.asarray(out))


# ---------------------------------------------------------------------------
# shared partition / plan construction
# ---------------------------------------------------------------------------

def test_plan_from_partition_matches_build_plan():
    g, _ = _graph()
    part = C.build_partition(g, 4)
    for ps, dist in [(4, 1), (8, 2), (16, 4)]:
        a = plan_from_partition(part, ps=ps, dist=dist)
        b = C.build_plan(g, 4, ps=ps, dist=dist)
        for f in ("local_nbrs", "local_mask", "local_targets", "remote_nbrs",
                  "remote_mask", "remote_targets", "bounds", "node_counts"):
            np.testing.assert_array_equal(getattr(a, f), getattr(b, f))
        assert (a.rows_per_dev, a.tile_rows) == (b.rows_per_dev, b.tile_rows)


def test_identical_layer_configs_share_one_plan_object():
    g, _ = _graph()
    plans = C.build_layer_plans(g, 2, [dict(ps=8, dist=2), dict(ps=8, dist=2),
                                       dict(ps=4, dist=2)])
    assert plans[0].plan is plans[1].plan          # no duplicated tables
    assert plans[2].plan is not plans[0].plan
    assert plans[0].config == dict(ps=8, dist=2, pb=1)


def test_mixed_dist_layers_share_pgas_layout():
    g, x = _graph()
    # layout invariants on a 2-device split (host-side, no mesh needed)
    plans = C.build_layer_plans(g, 2, [dict(ps=4, dist=3), dict(ps=8, dist=2)])
    p0, p1 = plans[0].plan, plans[1].plan
    assert p0.rows_per_dev == p1.rows_per_dev      # one embedding layout
    assert p0.rows_per_dev % 6 == 0                # lcm(3, 2) padding
    assert (p0.tile_rows * 3 == p0.rows_per_dev
            and p1.tile_rows * 2 == p1.rows_per_dev)
    # both schedules aggregate correctly over that shared layout (1-device
    # mesh here; the 8-device ring runs in tests/multidev/mgg_equivalence.py)
    want = C.reference_aggregate(g.indptr, g.indices, x)
    mesh = flat_ring_mesh(1)
    plans1 = C.build_layer_plans(g, 1, [dict(ps=4, dist=3),
                                        dict(ps=8, dist=2)])
    q0, q1 = plans1[0].plan, plans1[1].plan
    assert q0.rows_per_dev == q1.rows_per_dev and q0.rows_per_dev % 6 == 0
    xp = jnp.asarray(C.pad_embeddings(q0, x))
    for p in (q0, q1):
        got = C.unpad_embeddings(p, np.asarray(
            C.mgg_aggregate(xp, p, mesh)))
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# per-layer engine == single-plan engine (bitwise) when configs coincide
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("model", ["gcn", "gin", "sage", "gat"])
def test_per_layer_engine_bitwise_matches_single_plan(model):
    g, x = _graph()
    mesh = flat_ring_mesh(1)
    init, apply_fn, kw = C.MODEL_ZOO[model]
    params = init(jax.random.key(3), x.shape[1], 5, **kw)
    single = C.GNNEngine.build(g, mesh, ps=8, dist=2)
    n_layers = len(params["layers"])
    per_layer = C.GNNEngine.build(
        g, mesh, layer_configs=[dict(ps=8, dist=2)] * n_layers)
    assert per_layer.per_layer and not single.per_layer
    got = _forward(per_layer, apply_fn, params, x)
    want = _forward(single, apply_fn, params, x)
    np.testing.assert_array_equal(got, want)       # bitwise, not allclose


def test_per_layer_engine_distinct_configs_still_correct():
    g, x = _graph()
    mesh = flat_ring_mesh(1)
    init, apply_fn, kw = C.MODEL_ZOO["gcn"]
    params = init(jax.random.key(3), x.shape[1], 5, **kw)
    ref = _forward(C.GNNEngine.build(g, mesh, ps=8, dist=1),
                   apply_fn, params, x)
    eng = C.GNNEngine.build(g, mesh, layer_configs=[
        dict(ps=16, dist=2, interleave=False), dict(ps=2, dist=1)])
    assert eng.layer_configs[0] != eng.layer_configs[1]
    np.testing.assert_allclose(_forward(eng, apply_fn, params, x), ref,
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# fused update == unfused (documented tolerance), all four models
# ---------------------------------------------------------------------------

def test_fused_mgg_aggregate_matches_matmul_after_ring():
    g, x = _graph()
    w = RNG.normal(size=(x.shape[1], 7)).astype(np.float32)
    want = C.reference_aggregate(g.indptr, g.indices, x) @ w
    mesh = flat_ring_mesh(1)   # the 8-dev ring: tests/multidev/mgg_equivalence
    for ps, dist, interleave in [(4, 1, True), (8, 2, True), (16, 2, False)]:
        plan = C.build_plan(g, 1, ps=ps, dist=dist)
        out = C.mgg_aggregate(
            jnp.asarray(C.pad_embeddings(plan, x)), plan, mesh,
            interleave=interleave, update_w=jnp.asarray(w))
        got = C.unpad_embeddings(plan, np.asarray(out))
        np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-3)


@pytest.mark.parametrize("model", ["gcn", "gin", "sage", "gat"])
def test_fused_update_matches_unfused_forward_and_grads(model):
    g, x = _graph()
    mesh = flat_ring_mesh(1)
    init, apply_fn, kw = C.MODEL_ZOO[model]
    params = init(jax.random.key(7), x.shape[1], 5, **kw)
    unfused = C.GNNEngine.build(g, mesh, ps=8, dist=2)
    fused = C.GNNEngine.build(g, mesh, ps=8, dist=2, fuse_update=True)
    assert all(lp.fuse_update for lp in fused.layer_plans)
    np.testing.assert_allclose(
        _forward(fused, apply_fn, params, x),
        _forward(unfused, apply_fn, params, x),
        rtol=FUSED_RTOL, atol=FUSED_ATOL)

    # training: gradients through the fused ring match the unfused ones
    def loss(p, eng):
        xp = eng.shard(eng.pad(x))
        return (apply_fn(p, eng, xp).astype(jnp.float32) ** 2).mean()

    gu = jax.grad(lambda p: loss(p, unfused))(params)
    gf = jax.grad(lambda p: loss(p, fused))(params)
    for a, b in zip(jax.tree.leaves(gu), jax.tree.leaves(gf)):
        np.testing.assert_allclose(np.asarray(b), np.asarray(a),
                                   rtol=1e-3, atol=1e-3)


@pytest.mark.parametrize("model", ["gcn", "gin", "sage", "gat"])
def test_fused_cached_serving_matches_unfused_offline(model):
    """Cached serving on a fused engine: bitwise vs the fused offline
    forward (same stage functions), tolerance vs the unfused path."""
    from repro.serve import GNNServeEngine, TrafficPhase, ZipfTraffic, \
        run_trace

    g, x = _graph(seed=9)
    mesh = flat_ring_mesh(1)
    init, apply_fn, kw = C.MODEL_ZOO[model]
    params = init(jax.random.key(1), x.shape[1], 5, **kw)
    fused = C.GNNEngine.build(g, mesh, ps=8, dist=1, fuse_update=True)
    srv = GNNServeEngine(fused, params, model, x, g, slots=4)
    traffic = ZipfTraffic(g.num_nodes, x.shape[1], [
        TrafficPhase(requests=12, alpha=1.2, seeds_max=3)], seed=2)
    results = run_trace(srv, traffic)
    assert any(r.cached for r in results)

    # offline references must be JITTED like the serve steps (eager XLA
    # fuses differently in the low bits)
    def _jit_forward(eng):
        xp = eng.shard(eng.pad(x))
        out = jax.jit(lambda p, t: apply_fn(p, eng, t))(params, xp)
        return C.unpad_embeddings(eng.plan, np.asarray(out))

    off_fused = _jit_forward(fused)
    off_unfused = _jit_forward(C.GNNEngine.build(g, mesh, ps=8, dist=1))
    for r in results:
        np.testing.assert_array_equal(r.logits, off_fused[r.seeds])
        np.testing.assert_allclose(r.logits, off_unfused[r.seeds],
                                   rtol=FUSED_RTOL, atol=FUSED_ATOL)


# ---------------------------------------------------------------------------
# per-layer tuning
# ---------------------------------------------------------------------------

def _skewed_surface(widths, cfgs):
    """Deterministic skewed-width latency: wide layers are bandwidth-bound
    (ps overhead amortized → want large ps), narrow layers are
    overhead-bound (padding waste dominates → want small ps).  The measured
    analogue runs as benchmarks/fig9_ablations.py fig9c (CI --smoke)."""
    t = 0.0
    for w, c in zip(widths, cfgs):
        opt = 16 if w >= 64 else 2
        t += (w / 64.0) * (1.0 + 0.3 * abs(math.log2(c["ps"])
                                           - math.log2(opt))
                           + 0.1 * (c["dist"] - 1) + 0.05 * (c["pb"] - 1))
    return t


def test_per_layer_tuner_converges_to_distinct_configs():
    widths = (96, 8)  # skewed: wide input layer, narrow hidden layer
    t = PerLayerTuner(2, (2, 4, 8, 16), (1, 2), (1,), budget=40)
    while not t.converged:
        t.observe(_skewed_surface(widths, t.propose()))
    best = t.best
    assert best[0]["ps"] == 16 and best[1]["ps"] == 2
    assert best[0] != best[1]                    # ≥ 2 distinct configs
    assert t.measured <= 40


def test_per_layer_tuner_budget_and_warm_start():
    widths = (96, 8)
    # warm start from a global config: it is the FIRST thing measured
    t = PerLayerTuner(2, (2, 4, 8, 16), (1, 2), (1,),
                      warm_start=dict(ps=8, dist=1, pb=1))
    first = t.propose()
    assert first == [dict(ps=8, dist=1, pb=1)] * 2
    while not t.converged:
        t.observe(_skewed_surface(widths, t.propose()))
    full_measurements = t.measured
    # a hard budget commits the best-seen and stops
    tb = PerLayerTuner(2, (2, 4, 8, 16), (1, 2), (1,), budget=3)
    while not tb.converged:
        tb.observe(_skewed_surface(widths, tb.propose()))
    assert tb.measured == 3 < full_measurements
    assert tb.best is not None


def test_per_layer_tuner_reopen_warm_starts_from_best():
    widths = (96, 8)
    t = PerLayerTuner(2, (2, 4, 8, 16), (1,), (1,))
    while not t.converged:
        t.observe(_skewed_surface(widths, t.propose()))
    best = t.best
    t.reopen()
    assert t.reopens == 1 and not t.converged
    assert t.propose() == best  # per-layer warm start, no global re-phase


def test_per_layer_dynamic_engine_commits_distinct_configs():
    g, x = _graph(n=160)
    eng = DynamicGNNEngine.build(
        g, flat_ring_mesh(1), d_feat=x.shape[1], layer_dims=[96, 8],
        ps_space=(2, 4, 8, 16), dist_space=(1, 2), pb_space=(1,),
        window=ProfileConfig(warmup=0, iters=1))
    assert eng.per_layer
    gsl = g.with_self_loops()
    ref = C.reference_aggregate(gsl.indptr, gsl.indices, x)
    for _ in range(200):
        out = C.unpad_embeddings(
            eng.plan, np.asarray(eng.aggregate(eng.shard(eng.pad(x)))))
        np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-4)
        eng.observe_step(_skewed_surface((96, 8), eng.config["layers"]))
        if eng.committed:
            break
    assert eng.committed
    layers = eng.config["layers"]
    assert layers[0]["ps"] == 16 and layers[1]["ps"] == 2
    assert len({tuple(sorted(c.items())) for c in layers}) >= 2
    # the live engine really runs per-layer plans
    assert eng.engine.per_layer
    assert eng.layer_configs == layers


def test_per_layer_dynamic_engine_bitwise_matches_static_per_layer():
    g, x = _graph(n=160)
    mesh = flat_ring_mesh(1)
    init, apply_fn, kw = C.MODEL_ZOO["gcn"]
    params = init(jax.random.key(0), x.shape[1], 4, **kw)
    eng = DynamicGNNEngine.build(
        g, mesh, d_feat=x.shape[1], layer_dims=[96, 8],
        ps_space=(2, 4), dist_space=(1,), pb_space=(1,),
        window=ProfileConfig(warmup=0, iters=1))
    for _ in range(100):
        eng.observe_step(_skewed_surface((96, 8), eng.config["layers"]))
        if eng.committed:
            break
    assert eng.committed
    static = C.GNNEngine.build(g, mesh, layer_configs=eng.config["layers"])
    np.testing.assert_array_equal(_forward(eng.engine, apply_fn, params, x),
                                  _forward(static, apply_fn, params, x))


def test_per_layer_retune_resizes_tuner_on_layer_count_change():
    """retune(layer_dims=...) with a NEW layer count resizes the search:
    proposals carry one config per live layer, fresh feasibility checks
    are built from the live shapes, and the committed cache entry has
    matching lengths (so warm start keeps working)."""
    g, x = _graph(n=160)
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "tuned.json")
        eng = DynamicGNNEngine.build(
            g, flat_ring_mesh(1), d_feat=x.shape[1], layer_dims=[96, 8],
            ps_space=(2, 4), dist_space=(1,), pb_space=(1,),
            window=ProfileConfig(warmup=0, iters=1), cache_path=path)
        for _ in range(100):
            eng.observe_step(_skewed_surface((96, 8), eng.config["layers"]))
            if eng.committed:
                break
        assert eng.committed
        # the model grew a layer
        assert eng.retune(layer_dims=[96, 8, 8])
        assert eng.tuner.num_layers == 3
        assert len(eng.tuner.vmem_checks) == 3
        assert len(eng.config["layers"]) == 3       # proposals resized
        assert len(eng.engine.layer_plans) == 3     # engine rebuilt to match
        for _ in range(200):
            eng.observe_step(_skewed_surface(
                (96, 8, 8), eng.config["layers"]))
            if eng.committed:
                break
        assert eng.committed and len(eng.config["layers"]) == 3
        # the committed per-layer entry round-trips at the new length
        from repro.core.autotune import layer_workload_shapes
        shapes3 = layer_workload_shapes(g.with_self_loops(), 1, [96, 8, 8])
        assert ConfigCache(path).get_layers(shapes3) == eng.config["layers"]


def test_dynamic_engine_reuses_partition_across_tuner_moves():
    """Tuner moves re-derive schedules only — the node split + locality
    split (SharedPartition) is built once and reused until the topology
    changes (retune(graph=...))."""
    g, x = _graph(n=160)
    eng = DynamicGNNEngine.build(
        g, flat_ring_mesh(1), d_feat=x.shape[1], layer_dims=[96, 8],
        ps_space=(2, 4, 8), dist_space=(1, 2), pb_space=(1,),
        window=ProfileConfig(warmup=0, iters=1))
    part0 = eng.engine.partition
    assert part0 is not None
    rebuilds = 0
    for _ in range(200):
        rebuilds += bool(eng.observe_step(
            _skewed_surface((96, 8), eng.config["layers"])))
        if eng.committed:
            break
    assert eng.committed and rebuilds >= 2
    assert eng.engine.partition is part0          # shared across every move
    # a topology change invalidates it
    g2 = C.power_law(g.num_nodes, avg_degree=12.0, locality=0.3, seed=1)
    eng.retune(graph=g2)
    assert eng.engine.partition is not part0


def test_pipeline_latency_model_sums_per_layer_terms():
    from repro.core.autotune import (estimate_latency,
                                     estimate_pipeline_latency)

    g, _ = _graph()
    shapes = C.layer_workload_shapes(g, 4, [96, 8])
    assert [s.d_feat for s in shapes] == [96, 8]
    assert shapes[0].local_edges_max == shapes[1].local_edges_max
    cfgs = [dict(ps=16, dist=2, pb=1), dict(ps=2, dist=1, pb=1)]
    total = estimate_pipeline_latency(shapes, cfgs)
    assert total == pytest.approx(sum(
        estimate_latency(s, c["ps"], c["dist"], c["pb"])
        for s, c in zip(shapes, cfgs)))
    # the update term: fused folds FLOPs under the ring steps, unfused pays
    # them serially after — fused is never modeled slower
    fused = estimate_pipeline_latency(shapes, cfgs, d_outs=[16, 4], fuse=True)
    unfused = estimate_pipeline_latency(shapes, cfgs, d_outs=[16, 4])
    assert fused <= unfused
    assert unfused > total  # the update phase costs something
    with pytest.raises(ValueError):
        estimate_pipeline_latency(shapes, cfgs[:1])


# ---------------------------------------------------------------------------
# ConfigCache v2
# ---------------------------------------------------------------------------

def test_cache_per_layer_roundtrip_and_warm_start():
    from repro.core.autotune import WorkloadShape

    shapes = [WorkloadShape(n_dev=2, d_feat=96, rows_per_dev=50,
                            local_edges_max=200, remote_edges_max=80),
              WorkloadShape(n_dev=2, d_feat=8, rows_per_dev=50,
                            local_edges_max=200, remote_edges_max=80)]
    cfgs = [dict(ps=16, dist=1, pb=1), dict(ps=2, dist=1, pb=1)]
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "tuned.json")
        cache = ConfigCache(path, hw="test:hw:2")
        assert cache.get_layers(shapes) is None
        cache.put_layers(shapes, cfgs, 1.5e-3)
        assert cache.get_layers(shapes) == cfgs
        # per-layer and global entries coexist under distinct keys
        cache.put(shapes[0], dict(ps=4, dist=2, pb=1), 2e-3)
        assert cache.get(shapes[0]) == dict(ps=4, dist=2, pb=1)
        assert cache.get_layers(shapes) == cfgs
        # a different width stack misses
        other = [shapes[0], shapes[0].with_d_feat(16)]
        assert cache.get_layers(other) is None


def test_cache_v1_files_discarded_with_one_warning():
    """Pre-refactor cache files (schema v1) read as empty — never a crash,
    a single RuntimeWarning per path (PR-5: the discard is no longer
    silent), and the next put writes a clean current-schema file."""
    import pytest
    from repro.core.autotune import WorkloadShape

    shape = WorkloadShape(n_dev=2, d_feat=16, rows_per_dev=50,
                          local_edges_max=200, remote_edges_max=80)
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "tuned.json")
        cache = ConfigCache(path, hw="test:hw:2")
        v1 = dict(version=1, entries={
            cache.key(shape): dict(config=dict(ps=8, dist=2, pb=4),
                                   latency=1e-3)})
        with open(path, "w") as f:
            json.dump(v1, f)
        with pytest.warns(RuntimeWarning, match="schema version 1"):
            assert cache.get(shape) is None        # discarded, no crash
        assert cache.get_layers([shape]) is None   # warned once already
        assert len(cache) == 0
        cache.put(shape, dict(ps=4, dist=1, pb=1), 1e-3)
        assert cache.get(shape) == dict(ps=4, dist=1, pb=1)
        with open(path) as f:
            assert json.load(f)["version"] == 5


def test_per_layer_warm_starts_from_global_cache_entry():
    """A previous GLOBAL run's cached config seeds the per-layer search —
    including for unfused GCN, whose aggregation widths exclude the input
    d_feat the global entry is keyed under."""
    g, x = _graph(n=160, d=96)
    mesh = flat_ring_mesh(1)
    init, _apply, kw = C.MODEL_ZOO["gcn"]
    params = init(jax.random.key(0), 96, 4, **kw)
    dims = C.aggregation_widths("gcn", params)    # [16, 4]: no 96 anywhere
    assert 96 not in dims
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "tuned.json")
        e1 = DynamicGNNEngine.build(
            g, mesh, d_feat=96, ps_space=(2, 4, 8), dist_space=(1,),
            pb_space=(1,), window=ProfileConfig(warmup=0, iters=1),
            cache_path=path)
        for _ in range(100):
            e1.observe_step(1.0 + abs(e1.config["ps"] - 4))
            if e1.committed:
                break
        assert e1.committed and e1.config["ps"] == 4
        e2 = DynamicGNNEngine.build(
            g, mesh, d_feat=96, layer_dims=dims,
            ps_space=(2, 4, 8), dist_space=(1,), pb_space=(1,),
            window=ProfileConfig(warmup=0, iters=1), cache_path=path)
        # global entry found → the warm global config is measured first
        assert e2.config["layers"] == [dict(ps=4, dist=1, pb=1)] * len(dims)


def test_per_layer_retune_takes_layer_dims_not_d_feat():
    g, x = _graph(n=160)
    eng = DynamicGNNEngine.build(
        g, flat_ring_mesh(1), d_feat=x.shape[1], layer_dims=[96, 8],
        ps_space=(2, 4), dist_space=(1,), pb_space=(1,),
        window=ProfileConfig(warmup=0, iters=1))
    for _ in range(100):
        eng.observe_step(_skewed_surface((96, 8), eng.config["layers"]))
        if eng.committed:
            break
    assert eng.committed
    # the UNCHANGED model d_feat is fine (e.g. reporting graph growth only),
    # even though per-layer mode stores the max aggregation width internally
    assert not eng.retune(d_feat=x.shape[1])
    # a lone changed d_feat cannot describe per-layer widths: explicit error
    with pytest.raises(ValueError):
        eng.retune(d_feat=512)
    # widths reported per layer re-open the search past the drift threshold
    assert eng.retune(layer_dims=[512, 8])
    assert eng.layer_dims == [512, 8] and not eng.committed
    assert eng.tuner.reopens == 1


def test_per_layer_dynamic_engine_warm_starts_from_layer_cache():
    g, x = _graph(n=160)
    mesh = flat_ring_mesh(1)
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "tuned.json")
        e1 = DynamicGNNEngine.build(
            g, mesh, d_feat=x.shape[1], layer_dims=[96, 8],
            ps_space=(2, 4, 8, 16), dist_space=(1,), pb_space=(1,),
            window=ProfileConfig(warmup=0, iters=1), cache_path=path)
        for _ in range(200):
            e1.observe_step(_skewed_surface((96, 8), e1.config["layers"]))
            if e1.committed:
                break
        assert e1.committed
        best = e1.config["layers"]
        # second engine: the cached per-layer stack is its starting config
        e2 = DynamicGNNEngine.build(
            g, mesh, d_feat=x.shape[1], layer_dims=[96, 8],
            ps_space=(2, 4, 8, 16), dist_space=(1,), pb_space=(1,),
            cache_path=path)
        assert e2.config["layers"] == best
