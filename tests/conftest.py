"""Test bootstrap: puts src/ on sys.path.

Deliberately does NOT set XLA_FLAGS / device counts — unit tests must see
the real single CPU device.  Multi-device behaviour is exercised through
subprocess tests (tests/multidev/), each of which sets
``--xla_force_host_platform_device_count`` before importing jax.
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
