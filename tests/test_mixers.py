"""Sequence-mixer oracles: the chunked Mamba2/mLSTM algorithms must equal
their step-by-step recurrences, and apply/step must be consistent."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import ssm, xlstm

RNG = np.random.default_rng(0)


def _zcfg(chunk):
    cfg = configs.get_smoke_config("zamba2-7b")
    return dataclasses.replace(cfg, ssm_chunk=chunk)


@pytest.mark.parametrize("chunk", [4, 8, 16])
def test_ssd_chunked_equals_stepwise(chunk):
    """Chunked SSD over a sequence == feeding tokens one by one (decode)."""
    cfg = _zcfg(chunk)
    p = ssm.ssm_init(jax.random.key(0), cfg)
    b, s = 2, 16
    x = jnp.asarray(RNG.normal(size=(b, s, cfg.d_model)), jnp.float32)
    st0 = ssm.ssm_state_init(cfg, b)
    y_seq, st_seq = ssm.ssm_apply(p, x, cfg, state=st0)
    st = ssm.ssm_state_init(cfg, b)
    ys = []
    for t in range(s):
        yt, st = ssm.ssm_step(p, x[:, t : t + 1], cfg, st)
        ys.append(yt)
    y_step = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_seq), np.asarray(y_step),
                               rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(st_seq["h"]), np.asarray(st["h"]),
                               rtol=2e-3, atol=2e-3)


def test_ssd_chunk_size_invariance():
    b, s = 2, 24
    x = jnp.asarray(RNG.normal(size=(b, s, 64)), jnp.float32)
    outs = []
    for chunk in (4, 8, 24):
        cfg = _zcfg(chunk)
        p = ssm.ssm_init(jax.random.key(1), cfg)
        y, _ = ssm.ssm_apply(p, x, cfg, state=ssm.ssm_state_init(cfg, b))
        outs.append(np.asarray(y))
    np.testing.assert_allclose(outs[0], outs[1], rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(outs[0], outs[2], rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("chunk", [4, 8])
def test_mlstm_chunked_equals_stepwise(chunk):
    cfg = dataclasses.replace(configs.get_smoke_config("xlstm-125m"),
                              ssm_chunk=chunk)
    p = xlstm.mlstm_init(jax.random.key(0), cfg)
    b, s = 2, 16
    x = jnp.asarray(RNG.normal(size=(b, s, cfg.d_model)), jnp.float32)
    y_seq, st_seq = xlstm.mlstm_apply(
        p, x, cfg, state=xlstm.mlstm_state_init(cfg, b))
    st = xlstm.mlstm_state_init(cfg, b)
    ys = []
    for t in range(s):
        yt, st = xlstm.mlstm_step(p, x[:, t : t + 1], cfg, st)
        ys.append(yt)
    np.testing.assert_allclose(
        np.asarray(y_seq), np.asarray(jnp.concatenate(ys, axis=1)),
        rtol=3e-3, atol=3e-3)
    np.testing.assert_allclose(np.asarray(st_seq["c"]), np.asarray(st["c"]),
                               rtol=3e-3, atol=3e-3)


def test_slstm_apply_step_consistency():
    cfg = configs.get_smoke_config("xlstm-125m")
    p = xlstm.slstm_init(jax.random.key(0), cfg)
    b, s = 2, 12
    x = jnp.asarray(RNG.normal(size=(b, s, cfg.d_model)), jnp.float32)
    y_seq, st_seq = xlstm.slstm_apply(
        p, x, cfg, state=xlstm.slstm_state_init(cfg, b))
    st = xlstm.slstm_state_init(cfg, b)
    ys = []
    for t in range(s):
        yt, st = xlstm.slstm_step(p, x[:, t : t + 1], cfg, st)
        ys.append(yt)
    np.testing.assert_allclose(
        np.asarray(y_seq), np.asarray(jnp.concatenate(ys, axis=1)),
        rtol=1e-4, atol=1e-4)


def test_ssd_naive_recurrence_oracle():
    """Chunked SSD vs a literal h_t = e^{aΔ}h + Δ·x⊗B; y = C·h loop."""
    cfg = _zcfg(chunk=8)
    p = ssm.ssm_init(jax.random.key(2), cfg)
    b, s = 1, 16
    x = jnp.asarray(RNG.normal(size=(b, s, cfg.d_model)), jnp.float32)
    y, _ = ssm.ssm_apply(p, x, cfg, state=ssm.ssm_state_init(cfg, b))
    # naive recompute of the inner SSD from the same projections
    d_in = cfg.d_model * cfg.ssm_expand
    heads = d_in // cfg.ssm_headdim
    n = cfg.ssm_state
    z, xbc, dt_raw = ssm._split_proj(p, x, cfg)
    xbc, _ = ssm._causal_conv(xbc, p["conv_w"], None)
    xs = np.asarray(xbc[..., :d_in]).reshape(b, s, heads, cfg.ssm_headdim)
    bm = np.asarray(xbc[..., d_in:d_in + n])
    cm = np.asarray(xbc[..., d_in + n:])
    dt = np.asarray(jax.nn.softplus(dt_raw + p["dt_bias"][None, None]))
    a = -np.exp(np.asarray(p["a_log"]))
    h = np.zeros((b, heads, cfg.ssm_headdim, n))
    ys = np.zeros((b, s, heads, cfg.ssm_headdim))
    for t in range(s):
        h = h * np.exp(dt[:, t] * a)[0][None, :, None, None] + np.einsum(
            "bh,bhp,bn->bhpn", dt[:, t], xs[:, t], bm[:, t])
        ys[:, t] = np.einsum("bn,bhpn->bhp", cm[:, t], h)
    ys += xs * np.asarray(p["d_skip"])[None, None, :, None]
    yref = ys.reshape(b, s, d_in)
    ynorm = ssm.rms_norm(jnp.asarray(yref, jnp.float32)
                         * jax.nn.silu(z), p["norm_w"], cfg.norm_eps)
    yout = ynorm @ p["out_proj"]["w"]
    np.testing.assert_allclose(np.asarray(y), np.asarray(yout),
                               rtol=2e-3, atol=2e-3)
