"""Flash-attention Pallas kernel vs a dense softmax oracle (interpret
mode), sweeping GQA group sizes, causal/windowed masking, odd shapes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import flash_attention

RNG = np.random.default_rng(0)


def _dense_oracle(q, k, v, causal, window):
    b, s, h, hd = q.shape
    kvh = k.shape[2]
    rep = h // kvh
    kf = jnp.repeat(k, rep, axis=2).astype(jnp.float32)
    vf = jnp.repeat(v, rep, axis=2).astype(jnp.float32)
    s_ = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32), kf) * hd**-0.5
    qp = jnp.arange(s)[:, None]
    kp = jnp.arange(s)[None, :]
    ok = jnp.ones((s, s), bool)
    if causal:
        ok &= kp <= qp
    if window > 0:
        ok &= (qp - kp) < window
    s_ = jnp.where(ok[None, None], s_, -1e30)
    p = jax.nn.softmax(s_, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", p, vf).astype(q.dtype)


CASES = [
    # (B, S, H, KV, hd, causal, window, bq, bk)
    (2, 64, 4, 4, 16, True, 0, 16, 16),
    (1, 128, 8, 2, 32, True, 0, 32, 64),     # GQA 4:1
    (2, 96, 4, 1, 16, True, 32, 32, 32),     # MQA + sliding window
    (1, 50, 2, 2, 8, True, 0, 128, 128),     # odd seq → single block
    (1, 64, 4, 4, 16, False, 0, 16, 16),     # bidirectional (encoder)
]


@pytest.mark.parametrize("b,s,h,kv,hd,causal,window,bq,bk", CASES)
def test_flash_matches_dense(b, s, h, kv, hd, causal, window, bq, bk):
    q = jnp.asarray(RNG.normal(size=(b, s, h, hd)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(b, s, kv, hd)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(b, s, kv, hd)), jnp.float32)
    from repro.kernels.flash_attention import flash_attention_call
    got = flash_attention_call(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3), causal=causal, window=window,
        bq=bq, bk=bk, interpret=True).transpose(0, 2, 1, 3)
    want = _dense_oracle(q, k, v, causal, window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_flash_matches_model_chunked_attention():
    """Cross-check against the model's streaming-softmax implementation."""
    from repro.models.layers import _chunked_softmax_attention
    b, s, h, kv, hd = 2, 48, 4, 2, 16
    q = jnp.asarray(RNG.normal(size=(b, s, h, hd)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(b, s, kv, hd)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(b, s, kv, hd)), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    want = _chunked_softmax_attention(q, k, v, pos, pos, 0, chunk=16)
    got = flash_attention(q, k, v, causal=True, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=3e-5, atol=3e-5)


def test_flash_bf16():
    b, s, h, hd = 1, 32, 2, 16
    q = jnp.asarray(RNG.normal(size=(b, s, h, hd)), jnp.bfloat16)
    k = jnp.asarray(RNG.normal(size=(b, s, h, hd)), jnp.bfloat16)
    v = jnp.asarray(RNG.normal(size=(b, s, h, hd)), jnp.bfloat16)
    got = flash_attention(q, k, v, interpret=True)
    want = _dense_oracle(q, k, v, True, 0)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        rtol=3e-2, atol=3e-2)


def test_model_forward_with_flash_flag():
    """cfg.use_flash_attention must not change the model's logits."""
    import dataclasses
    from repro import configs
    from repro.models import transformer as T
    cfg = dataclasses.replace(configs.get_smoke_config("mistral-nemo-12b"),
                              compute_dtype="float32", remat=False)
    params = T.init_params(jax.random.key(0), cfg, vocab_multiple=4)
    toks = jnp.asarray(RNG.integers(1, cfg.vocab, (2, 16)), jnp.int32)
    base, _ = T.forward(params, cfg, toks)
    cfg2 = dataclasses.replace(cfg, use_flash_attention=True)
    fast, _ = T.forward(params, cfg2, toks)
    np.testing.assert_allclose(np.asarray(base), np.asarray(fast),
                               rtol=2e-4, atol=2e-4)
