"""Property tests for the tiered feature path (repro.store): the three
acceptance guarantees of the memory-bound regime —

1. the streamed (cached + prefetch) forward is **bitwise-equal** to the
   all-resident forward at ANY capacity (in particular any capacity that
   covers the working set), because assembly is sourcing-independent;
2. the feature-cache hit rate is **monotone in capacity** under
   hottest-first admission (prefix property: the rows resident at
   capacity c are a subset of those resident at any c' ≥ c);
3. after ``update_features`` no assembly — prefetched or not — ever
   serves the stale row.
"""
import numpy as np

from repro.testing.hypo import given, settings, strategies as st

import repro.core as C
from repro.core.pipeline import mgg_aggregate_streamed
from repro.dist import flat_ring_mesh
from repro.store import FeatureStore, HotFeatureCache, TieredFeatures

_MESH = {}


def _mesh():
    if not _MESH:
        _MESH["v"] = flat_ring_mesh(1)
    return _MESH["v"]


def cases(draw):
    n = draw(st.integers(20, 120))
    d = draw(st.integers(2, 12))
    seed = draw(st.integers(0, 10_000))
    g = C.power_law(n, avg_degree=draw(st.floats(2.0, 6.0)),
                    locality=draw(st.floats(0.0, 0.6)), seed=seed)
    x = np.random.default_rng(seed).normal(size=(n, d)).astype(np.float32)
    dist = draw(st.sampled_from([1, 2, 3]))
    cap = draw(st.integers(0, n))
    return g, x, dist, cap


case_st = st.composite(cases)()


def _tiers(g, x, dist, cap, store=None):
    plan = C.build_plan(g, 1, ps=4, dist=dist)
    t = TieredFeatures(store or FeatureStore(x), plan, cap)
    if cap:
        # hottest-first by degree; any hot list exercises the same paths
        t.admit(np.argsort(-g.degrees)[:cap].tolist())
    return t, plan


@given(case_st)
@settings(max_examples=15, deadline=None)
def test_streamed_forward_bitwise_equal_any_capacity(case):
    """Guarantee 1: capacity (0, partial, ≥ working set) never changes a
    single bit of the streamed aggregation output."""
    g, x, dist, cap = case
    t_cap, plan = _tiers(g, x, dist, cap)
    t_all, _ = _tiers(g, x, dist, g.num_nodes)    # capacity ⊇ working set
    t_none, _ = _tiers(g, x, dist, 0)
    outs = [np.asarray(mgg_aggregate_streamed(t.chunk_fetcher(), plan,
                                              _mesh()))
            for t in (t_cap, t_all, t_none)]
    assert np.array_equal(outs[0], outs[1])
    assert np.array_equal(outs[0], outs[2])
    # and assembly reproduces the resident padded table bit for bit
    np.testing.assert_array_equal(np.asarray(t_cap.padded_table()),
                                  C.pad_embeddings(plan, x))


def hit_cases(draw):
    n = draw(st.integers(20, 120))
    seed = draw(st.integers(0, 10_000))
    caps = sorted({draw(st.integers(0, n)) for _ in range(4)})
    n_lookups = draw(st.integers(1, 6))
    return n, seed, caps, n_lookups


hit_case_st = st.composite(hit_cases)()


@given(hit_case_st)
@settings(max_examples=25, deadline=None)
def test_hit_rate_monotone_in_capacity(case):
    """Guarantee 2: for one hot list and one lookup sequence, a larger
    cache never hits less — hottest-first admission is a prefix policy."""
    n, seed, caps, n_lookups = case
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(n, 4)).astype(np.float32)
    hot = rng.permutation(n)                      # hottest-first ranking
    lookups = [rng.integers(0, n, size=rng.integers(1, 16))
               for _ in range(n_lookups)]
    hits = []
    for cap in caps:
        store = FeatureStore(x)
        c = HotFeatureCache(n, cap, store.d_feat)
        c.admit(hot.tolist(), store)
        for ids in lookups:
            c.slots(ids.astype(np.int64))
        hits.append(c.hits)
    assert hits == sorted(hits), (caps, hits)


def update_cases(draw):
    n = draw(st.integers(20, 100))
    seed = draw(st.integers(0, 10_000))
    dist = draw(st.sampled_from([1, 2, 3]))
    cap = draw(st.integers(1, n))
    n_updates = draw(st.integers(1, 8))
    return n, seed, dist, cap, n_updates


update_case_st = st.composite(update_cases)()


@given(update_case_st)
@settings(max_examples=15, deadline=None)
def test_no_stale_row_after_update(case):
    """Guarantee 3: interleaving updates with assemblies (so updated rows
    may sit resident in the hot tier AND inside already-fetched chunks),
    every later assembly serves the store's current bits."""
    n, seed, dist, cap, n_updates = case
    rng = np.random.default_rng(seed)
    g = C.power_law(n, avg_degree=4.0, seed=seed)
    x = rng.normal(size=(n, 4)).astype(np.float32)
    t, plan = _tiers(g, x, dist, cap)
    expect = x.copy()
    t.padded_table()                              # warm: chunks fetched once
    for _ in range(n_updates):
        v = int(rng.integers(0, n))
        val = rng.normal(size=x.shape[1]).astype(np.float32)
        t.update(v, val)
        expect[v] = val
        np.testing.assert_array_equal(np.asarray(t.padded_table()),
                                      C.pad_embeddings(plan, expect))
        out = np.asarray(mgg_aggregate_streamed(t.chunk_fetcher(), plan,
                                                _mesh()))
        t_ref, _ = _tiers(g, expect, dist, 0)
        ref = np.asarray(mgg_aggregate_streamed(t_ref.chunk_fetcher(), plan,
                                                _mesh()))
        assert np.array_equal(out, ref), "stale row served after update"
