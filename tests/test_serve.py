"""Serving engine: greedy generation consistency vs direct forward."""
import numpy as np
import jax
import jax.numpy as jnp
import dataclasses

from repro import configs
from repro.models import transformer as T
from repro.serve import ServeEngine


def test_greedy_generation_matches_forward():
    cfg = dataclasses.replace(configs.get_smoke_config("codeqwen1.5-7b"),
                              compute_dtype="float32", remat=False)
    params = T.init_params(jax.random.key(0), cfg, vocab_multiple=4)
    eng = ServeEngine(params, cfg, batch_slots=2, max_seq=64)
    prompt = np.array([3, 1, 4, 1, 5], np.int32)
    res = eng.generate([prompt], max_new=5, temperature=0.0)[0]
    # replay: argmax continuation via full forward each step
    seq = prompt.tolist()
    for t in res.tokens:
        logits, _ = T.forward(params, cfg, jnp.asarray([seq], jnp.int32))
        nxt = int(np.argmax(np.asarray(logits[0, -1])))
        assert nxt == t, (seq, nxt, t)
        seq.append(nxt)


def test_wave_batching_multiple_prompts():
    cfg = configs.get_smoke_config("granite-moe-1b-a400m")
    params = T.init_params(jax.random.key(1), cfg, vocab_multiple=4)
    eng = ServeEngine(params, cfg, batch_slots=2, max_seq=64)
    prompts = [np.array([1, 2], np.int32), np.array([3], np.int32),
               np.array([4, 5, 6], np.int32)]
    res = eng.generate(prompts, max_new=4)
    assert len(res) == 3
    assert all(len(r.tokens) == 4 for r in res)
    assert all(0 <= t for r in res for t in r.tokens)
