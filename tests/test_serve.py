"""Serving engine: greedy generation consistency vs direct forward."""
import numpy as np
import jax
import jax.numpy as jnp
import dataclasses

from repro import configs
from repro.models import transformer as T
from repro.serve import ServeEngine


def test_greedy_generation_matches_forward():
    cfg = dataclasses.replace(configs.get_smoke_config("codeqwen1.5-7b"),
                              compute_dtype="float32", remat=False)
    params = T.init_params(jax.random.key(0), cfg, vocab_multiple=4)
    eng = ServeEngine(params, cfg, batch_slots=2, max_seq=64)
    prompt = np.array([3, 1, 4, 1, 5], np.int32)
    res = eng.generate([prompt], max_new=5, temperature=0.0)[0]
    # replay: argmax continuation via full forward each step
    seq = prompt.tolist()
    for t in res.tokens:
        logits, _ = T.forward(params, cfg, jnp.asarray([seq], jnp.int32))
        nxt = int(np.argmax(np.asarray(logits[0, -1])))
        assert nxt == t, (seq, nxt, t)
        seq.append(nxt)


def test_wave_batching_multiple_prompts():
    cfg = configs.get_smoke_config("granite-moe-1b-a400m")
    params = T.init_params(jax.random.key(1), cfg, vocab_multiple=4)
    eng = ServeEngine(params, cfg, batch_slots=2, max_seq=64)
    prompts = [np.array([1, 2], np.int32), np.array([3], np.int32),
               np.array([4, 5, 6], np.int32)]
    res = eng.generate(prompts, max_new=4)
    assert len(res) == 3
    assert all(len(r.tokens) == 4 for r in res)
    assert all(0 <= t for r in res for t in r.tokens)


def test_continuous_batching_matches_solo_runs():
    """Per-slot prefill + cache scatter keeps slots isolated: batching 5
    prompts through 2 slots must reproduce each prompt's solo generation."""
    cfg = dataclasses.replace(configs.get_smoke_config("codeqwen1.5-7b"),
                              compute_dtype="float32", remat=False)
    params = T.init_params(jax.random.key(2), cfg, vocab_multiple=4)
    eng = ServeEngine(params, cfg, batch_slots=2, max_seq=64)
    rng = np.random.default_rng(3)
    prompts = [rng.integers(1, cfg.vocab, size=rng.integers(2, 7))
               .astype(np.int32) for _ in range(5)]
    batched = eng.generate(prompts, max_new=6)
    for i, p in enumerate(prompts):
        solo = eng.generate([p], max_new=6)[0]
        assert batched[i].tokens == solo.tokens, (i, batched[i], solo)


def test_eos_frees_slot_for_refill():
    """A slot finishing on EOS must hand its slot to the next queued
    request (continuous refill), and the EOS token terminates its output."""
    cfg = dataclasses.replace(configs.get_smoke_config("codeqwen1.5-7b"),
                              compute_dtype="float32", remat=False)
    params = T.init_params(jax.random.key(4), cfg, vocab_multiple=4)
    probe = ServeEngine(params, cfg, batch_slots=1, max_seq=64)
    prompt = np.array([5, 2, 7], np.int32)
    free_run = probe.generate([prompt], max_new=6)[0]
    # EOS := the LAST first-occurrence in the stream, so truncation happens
    # mid-stream at a known position (cut = that value's first appearance)
    cut = max(i for i, t in enumerate(free_run.tokens)
              if t not in free_run.tokens[:i])
    eos = free_run.tokens[cut]
    assert cut > 0  # the run must actually exercise mid-stream truncation

    eng = ServeEngine(params, cfg, batch_slots=1, max_seq=64, eos_id=eos)
    prompts = [prompt, np.array([1, 3], np.int32)]
    res = eng.generate(prompts, max_new=6)
    assert res[0].tokens == free_run.tokens[:cut + 1]  # truncated at EOS
    assert res[0].tokens[-1] == eos
    assert len(res[1].tokens) >= 1                    # refilled + served
