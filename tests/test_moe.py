"""MoE dispatch/combine vs a dense reference (all experts on all tokens)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import moe

RNG = np.random.default_rng(0)


def _cfg(**kw):
    cfg = configs.get_smoke_config("granite-moe-1b-a400m")
    return dataclasses.replace(cfg, **kw) if kw else cfg


def _dense_reference(p, x, cfg):
    """y_t = Σ_k gate_k · FFN_{e_k}(x_t), computing every expert densely."""
    b, s, d = x.shape
    x2 = np.asarray(x).reshape(-1, d)
    gates, tope = moe._route(p, jnp.asarray(x2), cfg)
    gates, tope = np.asarray(gates), np.asarray(tope)
    wu, wd = np.asarray(p["w_up"]), np.asarray(p["w_down"])
    wg = np.asarray(p["w_gate"]) if "w_gate" in p else None
    out = np.zeros_like(x2)
    for t in range(x2.shape[0]):
        for k in range(cfg.top_k):
            e = tope[t, k]
            if wg is not None:
                g = x2[t] @ wg[e]
                h = (g / (1 + np.exp(-g))) * (x2[t] @ wu[e])
            else:
                h = x2[t] @ wu[e]
                h = 0.5 * h * (1 + np.tanh(np.sqrt(2 / np.pi)
                                           * (h + 0.044715 * h ** 3)))
            out[t] += gates[t, k] * (h @ wd[e])
    return out.reshape(b, s, d)


@pytest.mark.parametrize("top_k", [1, 2, 4])
def test_moe_matches_dense_reference(top_k):
    cfg = _cfg(top_k=top_k)
    p = moe.moe_init(jax.random.key(0), cfg)
    x = jnp.asarray(RNG.normal(size=(2, 8, cfg.d_model)), jnp.float32)
    # huge capacity ⇒ no token drops ⇒ exact match with the dense reference
    got = moe.moe_apply(p, x, cfg, capacity_factor=float(cfg.n_experts))
    want = _dense_reference(p, x, cfg)
    np.testing.assert_allclose(np.asarray(got), want, rtol=2e-3, atol=2e-3)


def test_capacity_drops_are_bounded():
    cfg = _cfg(top_k=2)
    p = moe.moe_init(jax.random.key(1), cfg)
    x = jnp.asarray(RNG.normal(size=(2, 16, cfg.d_model)), jnp.float32)
    tight = moe.moe_apply(p, x, cfg, capacity_factor=1.0)
    loose = moe.moe_apply(p, x, cfg, capacity_factor=float(cfg.n_experts))
    # tight capacity may drop tokens but output must stay finite and close
    assert np.isfinite(np.asarray(tight)).all()
    # at least half the tokens should be identical (not dropped)
    same = np.isclose(np.asarray(tight), np.asarray(loose),
                      rtol=1e-4, atol=1e-4).all(axis=-1)
    assert same.mean() > 0.5


def test_dispatch_indices_invariants():
    tope = jnp.asarray(RNG.integers(0, 8, (32, 2)), jnp.int32)
    slot_token, slot_valid, pair_slot, pair_kept = moe._dispatch_indices(
        tope, 8, capacity=6)
    st, sv = np.asarray(slot_token), np.asarray(slot_valid)
    kept = np.asarray(pair_kept)
    # every kept (token, k) pair appears in exactly one valid slot of the
    # right expert
    tope_np = np.asarray(tope)
    count = 0
    for e in range(8):
        toks = st[e][sv[e]]
        for tok in toks:
            assert (tope_np[tok] == e).any()
            count += 1
    assert count == kept.sum()
    # valid slots per expert ≤ capacity and equal to min(count_e, capacity)
    flat = tope_np.reshape(-1)
    for e in range(8):
        assert sv[e].sum() == min((flat == e).sum(), 6)


def test_ep_shard_single_device_equals_tp_path():
    from repro.dist import make_mesh
    cfg = _cfg(top_k=2)
    p = moe.moe_init(jax.random.key(2), cfg)
    x = jnp.asarray(RNG.normal(size=(2, 8, cfg.d_model)), jnp.float32)
    mesh = make_mesh((1, 1), ("data", "model"))
    want = moe.moe_apply(p, x, cfg, capacity_factor=1.25)
    for chunks in (1, 2, 4):
        got = moe.moe_apply_ep_shard(p, x, cfg, mesh,
                                     pipeline_chunks=chunks)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-4)
