"""Property tests for the serving runtime's decision signals: the
WorkloadStats drift score (zero on identical windows, bounded in [0, 1],
monotone in hot-set turnover) and HotNodeCache invalidation soundness
(after a feature write at v, nothing whose layer-1 aggregate reads v is
ever served from the cache).  Part of the PR-5 test-tier hardening —
these are exactly the components the serving cluster's routing and
staggered-retune decisions lean on."""
import numpy as np
import jax
import pytest

from repro.testing.hypo import given, settings, strategies as st

import repro.core as C
from repro.dist import flat_ring_mesh
from repro.serve import GNNServeEngine, HotNodeCache, TrafficSnapshot, \
    WorkloadStats


# ---------------------------------------------------------------------------
# WorkloadStats.drift
# ---------------------------------------------------------------------------

def snapshots(draw):
    n_hot = draw(st.integers(0, 12))
    hot = tuple(draw(st.lists(st.integers(0, 500), min_size=n_hot,
                              max_size=n_hot)))
    return TrafficSnapshot(
        requests=draw(st.integers(1, 10_000)),
        rate=draw(st.floats(0.0, 5_000.0)),
        mean_seeds=draw(st.floats(1.0, 8.0)),
        mean_frontier=draw(st.floats(0.0, 4_000.0)),
        hot_nodes=tuple(dict.fromkeys(hot)),   # unique, order-preserving
    )


snapshot_st = st.composite(snapshots)()


@given(snapshot_st)
@settings(max_examples=60, deadline=None)
def test_drift_zero_for_identical_windows(snap):
    assert WorkloadStats.drift(snap, snap) == 0.0


@given(snapshot_st, snapshot_st)
@settings(max_examples=60, deadline=None)
def test_drift_bounded_in_unit_interval(a, b):
    d = WorkloadStats.drift(a, b)
    assert 0.0 <= d <= 1.0


@given(st.integers(1, 16), st.integers(0, 16), st.integers(0, 16),
       st.floats(10.0, 500.0), st.floats(5.0, 300.0))
@settings(max_examples=60, deadline=None)
def test_drift_monotone_in_hot_set_turnover(k, o1, o2, rate, frontier):
    """With rate/frontier pinned, less hot-set overlap ⇒ no less drift."""
    o1, o2 = min(o1, k), min(o2, k)
    if o1 > o2:
        o1, o2 = o2, o1

    def snap(overlap):
        # `overlap` ids shared with the baseline, the rest disjoint
        hot = tuple(range(overlap)) + tuple(range(1000, 1000 + k - overlap))
        return TrafficSnapshot(requests=100, rate=rate, mean_seeds=2.0,
                               mean_frontier=frontier, hot_nodes=hot)

    base = snap(k)                      # identical hot set
    assert WorkloadStats.drift(base, snap(o1)) >= \
        WorkloadStats.drift(base, snap(o2))
    # exact turnover value when only the hot set moves
    assert WorkloadStats.drift(base, snap(o1)) == \
        pytest.approx(1.0 - o1 / k)


# ---------------------------------------------------------------------------
# HotNodeCache invalidation soundness (cache + CSRGraph.transpose level)
# ---------------------------------------------------------------------------

def inv_cases(draw):
    n = draw(st.integers(12, 160))
    deg = draw(st.floats(1.0, 8.0))
    seed = draw(st.integers(0, 10_000))
    g = C.power_law(n, deg, locality=draw(st.floats(0.0, 0.7)),
                    seed=seed).with_self_loops()
    v = draw(st.integers(0, n - 1))
    return g, v


inv_case_st = st.composite(inv_cases)()


@given(inv_case_st)
@settings(max_examples=25, deadline=None)
def test_reverse_edge_invalidation_covers_in_frontier(case):
    """cache.invalidate(g.transpose().row(v)) must dirty EVERY node whose
    1-hop in-frontier contains v — i.e. every u with v ∈ g.row(u) — and
    nothing else."""
    g, v = case
    cache = HotNodeCache(g.num_nodes)
    cache.store(object())
    dirty = g.transpose().row(v)
    cache.invalidate(dirty)
    reads_v = np.array([v in set(g.row(u).tolist())
                        for u in range(g.num_nodes)])
    for u in range(g.num_nodes):
        if reads_v[u]:
            assert not cache.ready(np.array([u])), (u, v)
        else:
            assert cache.ready(np.array([u])), (u, v)


# ---------------------------------------------------------------------------
# HotNodeCache capacity-policy regressions
# ---------------------------------------------------------------------------

def test_store_capacity_without_hot_list_marks_nothing_valid():
    """Regression: a capacity-bounded cache given NO hot list must mark
    ZERO rows valid — the old behavior fell back to all-valid, silently
    disabling the memory bound."""
    cache = HotNodeCache(32, capacity=8)
    cache.store(object(), hot_nodes=None)
    assert not cache.valid.any()
    assert cache.lookup(np.arange(32)) == 32      # every row is a miss
    cache.store(object(), hot_nodes=[3, 5])
    assert cache.valid.sum() == 2
    assert cache.ready(np.array([3, 5]))
    assert not cache.ready(np.array([3, 4]))


def test_store_capacity_truncates_hot_list():
    cache = HotNodeCache(32, capacity=2)
    cache.store(object(), hot_nodes=[7, 9, 11, 13])   # hottest first
    assert cache.valid.sum() == 2
    assert cache.ready(np.array([7, 9]))
    assert not cache.ready(np.array([11]))


@given(st.integers(1, 40), st.integers(1, 6))
@settings(max_examples=40, deadline=None)
def test_invalidate_counts_unique_rows_only(n, dup):
    """Regression: duplicate ids in an invalidation batch (a transpose
    row can repeat under multi-edges) must count each row ONCE — the
    return value feeds invalidation accounting."""
    cache = HotNodeCache(n)
    cache.store(object())
    ids = np.repeat(np.arange(n, dtype=np.int64)[: max(1, n // 2)], dup)
    dirtied = cache.invalidate(ids)
    assert dirtied == max(1, n // 2)              # unique rows, not len(ids)
    assert cache.invalidate(ids) == 0             # second pass: already dirty


# ---------------------------------------------------------------------------
# WorkloadStats under a frozen clock (replayed shadow traffic)
# ---------------------------------------------------------------------------

@given(st.integers(1, 12), st.floats(10.0, 1000.0))
@settings(max_examples=30, deadline=None)
def test_frozen_clock_window_carries_last_rate(n_frozen, rate):
    """Regression: once every batch in the window shares one timestamp
    (shadow replay under a frozen clock), the snapshot must carry the
    last measured rate instead of collapsing to 0 — a zero rate against
    a live baseline reads as full drift and triggers a spurious retune."""
    stats = WorkloadStats(window=8)
    seeds = np.array([1, 2], dtype=np.int64)
    for i in range(9):                           # live phase: real spacing
        stats.record(i / rate, seeds, 10)
    live = stats.snapshot().rate
    assert live > 0
    for _ in range(n_frozen):                    # frozen clock from here on
        stats.record(9.0 / rate, seeds, 10)
    frozen = stats.snapshot().rate
    assert frozen > 0, "frozen-clock window collapsed the rate to zero"
    if n_frozen >= 8:                            # window fully degenerate
        assert frozen == pytest.approx(stats._last_rate)
    base = stats.snapshot()
    drift = WorkloadStats.drift(
        TrafficSnapshot(base.requests, live, base.mean_seeds,
                        base.mean_frontier, base.hot_nodes), base)
    assert drift < 1.0, "frozen clock faked a full-drift rate change"


# ---------------------------------------------------------------------------
# end-to-end: update_features(v) never leaves a stale cached answer
# ---------------------------------------------------------------------------

_SERVE_SETUP = {}


def _serve_setup():
    """Built once per module (not a fixture: the hypo shim fills drawn
    values positionally, so drawn args must be the only parameters)."""
    if not _SERVE_SETUP:
        g = C.power_law(200, avg_degree=5.0, locality=0.3, seed=3)
        D, ncls = 8, 4
        x = np.random.default_rng(3).normal(
            size=(g.num_nodes, D)).astype(np.float32)
        eng = C.GNNEngine.build(g, flat_ring_mesh(1), ps=4, dist=1)
        init, apply, kw = C.MODEL_ZOO["gcn"]
        params = init(jax.random.key(3), D, ncls, **kw)
        _SERVE_SETUP["v"] = (g, x, eng, params, apply)
    return _SERVE_SETUP["v"]


@given(st.integers(0, 199), st.integers(0, 10_000))
@settings(max_examples=8, deadline=None)
def test_update_features_never_serves_stale(v, seed_pick):
    """After update_features(v), any request whose cached pass would read
    a dirtied h₁ row must take the FULL pass — and its logits must equal
    the offline forward over the updated features."""
    g, x, eng, params, apply = _serve_setup()
    srv = GNNServeEngine(eng, params, "gcn", x, g, slots=4)
    rev = srv.g_full.transpose()
    readers = rev.row(v)                      # h₁ rows that aggregate v
    if readers.size == 0:
        return
    seed = int(readers[seed_pick % readers.size])
    srv.submit(np.array([seed]))
    srv.step()                                # warm the cache
    srv.update_features(int(v), 3.0 * np.ones(x.shape[1], np.float32))
    srv.submit(np.array([seed]))
    (r,) = srv.step()
    assert not r.cached                       # stale row ⇒ full pass forced
    xp = eng.shard(eng.pad(srv.x))
    offline = C.unpad_embeddings(
        eng.plan, np.asarray(jax.jit(lambda p, t: apply(p, eng, t))(
            params, xp)))
    np.testing.assert_array_equal(r.logits, offline[[seed]])
