"""Examples smoke: the public entry point must keep running end-to-end.

quickstart.py is the README's first command — it forces its own 8-device
CPU ring (XLA flag set before the jax import), so it runs through the
same subprocess harness as the multidev scripts.
"""
import os

from test_system import _run  # tests/ is on sys.path under pytest

EXAMPLES = os.path.join(os.path.dirname(__file__), "..", "examples")


def test_quickstart_runs_clean():
    out = _run("quickstart.py", directory=EXAMPLES)
    assert "max |err| vs dense oracle" in out
    assert "autotuned knobs" in out
