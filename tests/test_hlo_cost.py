"""Oracle tests for the trip-count-aware HLO analyzer: scanned loops must
cost the same as their unrolled equivalents."""
import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.launch.hlo_cost import analyze


def _cost(fn, *args):
    txt = jax.jit(fn).lower(*args).compile().as_text()
    return analyze(txt)


def test_scan_matches_unrolled_flops():
    d, L = 64, 8
    w = jnp.ones((L, d, d), jnp.float32)
    x = jnp.ones((d, d), jnp.float32)

    def scanned(x, w):
        return lax.scan(lambda h, wl: (h @ wl, None), x, w)[0]

    def unrolled(x, w):
        for i in range(L):
            x = x @ w[i]
        return x

    cs, cu = _cost(scanned, x, w), _cost(unrolled, x, w)
    expected = L * 2 * d ** 3
    assert cs.dot_flops == expected, (cs.dot_flops, expected)
    assert cu.dot_flops == expected
    assert list(cs.while_trips.values()) == [L]


def test_nested_scan_multiplies():
    d, L1, L2 = 32, 3, 5
    w = jnp.ones((L1, L2, d, d), jnp.float32)
    x = jnp.ones((d, d), jnp.float32)

    def fn(x, w):
        def outer(h, wg):
            h2 = lax.scan(lambda h, wl: (h @ wl, None), h, wg)[0]
            return h2, None
        return lax.scan(outer, x, w)[0]

    c = _cost(fn, x, w)
    assert c.dot_flops == L1 * L2 * 2 * d ** 3


def test_grad_with_remat_counts_recompute():
    d, L = 32, 4
    w = jnp.ones((L, d, d), jnp.float32)
    x = jnp.ones((d, d), jnp.float32)

    def loss(x, w):
        def body(h, wl):
            return h @ wl, None
        return lax.scan(jax.checkpoint(body), x, w)[0].sum()

    c = _cost(lambda x, w: jax.grad(loss, argnums=1)(x, w), x, w)
    # fwd (1) + remat-fwd (1) + bwd (2 dots per layer) = 4 matmuls/layer
    expected = 4 * L * 2 * d ** 3
    assert abs(c.dot_flops - expected) / expected < 0.35, (
        c.dot_flops, expected)


def test_collectives_inside_loop_are_trip_multiplied():
    import os
    # single device: use a degenerate mesh with axis size 1? ppermute needs
    # shard_map; use psum_scatter-free path: just check while×collective via
    # a fori_loop of all_gather on a 1-device mesh.
    from jax.sharding import PartitionSpec as P
    mesh = jax.sharding.Mesh(np.array(jax.devices()[:1]), ("r",))

    def body_fn(x):
        def step(i, acc):
            g = lax.all_gather(acc, "r", axis=0, tiled=True)
            return g * 0.5
        return lax.fori_loop(0, 7, step, x)

    fn = jax.shard_map(body_fn, mesh=mesh, in_specs=P("r"),
                       out_specs=P("r"), check_vma=False)
    x = jnp.ones((4, 4), jnp.float32)
    txt = jax.jit(fn).lower(x).compile().as_text()
    c = analyze(txt)
    if "all-gather" in c.collectives:
        assert c.collectives["all-gather"]["count"] == 7
    # trip count recognized either way
    assert 7 in c.while_trips.values()


def test_bytes_positive_and_scale_with_trips():
    d = 64
    x = jnp.ones((d, d), jnp.float32)
    w2 = jnp.ones((2, d, d), jnp.float32)
    w8 = jnp.ones((8, d, d), jnp.float32)
    f = lambda x, w: lax.scan(lambda h, wl: (h @ wl, None), x, w)[0]
    c2, c8 = _cost(f, x, w2), _cost(f, x, w8)
    assert c8.bytes_accessed > 2.5 * c2.bytes_accessed
