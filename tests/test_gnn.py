"""GNN model correctness vs dense-matrix references (paper Eq. 4/5)."""
import jax
import jax.numpy as jnp
import numpy as np

import repro.core as C
from repro.dist import flat_ring_mesh

RNG = np.random.default_rng(0)


def _setup(n=120, d=12, ncls=5):
    g = C.power_law(n, avg_degree=6.0, locality=0.3, seed=9)
    x = RNG.normal(size=(n, d)).astype(np.float32)
    eng = C.GNNEngine.build(g, flat_ring_mesh(1), ps=8)
    return g, x, eng


def test_gcn_layer_matches_dense():
    """Â relu(Â X W¹) W² via the engine == dense normalized adjacency."""
    g, x, eng = _setup()
    init, apply, kw = C.MODEL_ZOO["gcn"]
    params = init(jax.random.key(0), x.shape[1], 5, **kw)
    got = C.unpad_embeddings(
        eng.plan, np.asarray(apply(params, eng, eng.shard(eng.pad(x)))))
    # dense reference
    gsl = g.with_self_loops()
    a = gsl.to_dense()
    dinv = 1.0 / np.sqrt(np.maximum(a.sum(1), 1.0))
    ahat = dinv[:, None] * a * dinv[None, :]
    w1, b1 = np.asarray(params["layers"][0]["w"]), np.asarray(
        params["layers"][0]["b"])
    w2, b2 = np.asarray(params["layers"][1]["w"]), np.asarray(
        params["layers"][1]["b"])
    # bias is applied post-aggregation (PyG convention), so every engine
    # dataflow (aggregate-first / transform-first / fused) matches this one
    # reference: Â (X W) + b == (Â X) W + b
    h = np.maximum(ahat @ (x @ w1) + b1, 0)
    want = ahat @ (h @ w2) + b2
    np.testing.assert_allclose(got, want, rtol=5e-3, atol=5e-3)


def test_gin_layer_matches_dense():
    g, x, eng = _setup()
    init, apply, kw = C.MODEL_ZOO["gin"]
    params = init(jax.random.key(1), x.shape[1], 5, **kw)
    got = C.unpad_embeddings(
        eng.plan, np.asarray(apply(params, eng, eng.shard(eng.pad(x)))))
    a = g.with_self_loops().to_dense()
    h = x
    for layer in params["layers"]:
        eps = float(layer["eps"])
        z = a @ h + eps * h
        z = np.maximum(z @ np.asarray(layer["mlp1"]["w"])
                       + np.asarray(layer["mlp1"]["b"]), 0)
        h = np.maximum(z @ np.asarray(layer["mlp2"]["w"])
                       + np.asarray(layer["mlp2"]["b"]), 0)
    want = h @ np.asarray(params["head"]["w"]) + np.asarray(
        params["head"]["b"])
    np.testing.assert_allclose(got, want, rtol=5e-3, atol=5e-3)


def test_paper_model_settings():
    """The zoo pins the paper's exact settings (§5 Benchmarks)."""
    assert C.MODEL_ZOO["gcn"][2] == dict(hidden=16, num_layers=2)
    assert C.MODEL_ZOO["gin"][2] == dict(hidden=64, num_layers=5)


def test_autotuner_converges_fast():
    """Paper §5.3: the cross-iteration search needs ~10 trials."""
    g = C.power_law(2000, avg_degree=16.0, locality=0.3, seed=3)
    w = C.WorkloadShape.from_graph(g, 8, 128)
    res = C.cross_iteration_optimize(
        lambda ps, dist, pb: C.estimate_latency(w, ps, dist, pb))
    assert res.num_trials <= 16
    base = C.estimate_latency(w, 1, 1, 1)
    assert res.best_latency <= base  # never worse than the initial config


def test_gat_layer_matches_dense():
    """GATv1 via two sum-aggregations == dense per-edge softmax reference."""
    g, x, eng = _setup()
    init, apply, kw = C.MODEL_ZOO["gat"]
    params = init(jax.random.key(2), x.shape[1], 5, **kw)
    got = C.unpad_embeddings(
        eng.plan, np.asarray(apply(params, eng, eng.shard(eng.pad(x)))))
    a = g.with_self_loops().to_dense()
    h = x
    nlayers = len(params["layers"])
    for i, layer in enumerate(params["layers"]):
        nh = layer["a_l"].shape[0]
        z = h @ np.asarray(layer["w"]["w"]) + np.asarray(layer["w"]["b"])
        n, total = z.shape
        hd = total // nh
        zh = z.reshape(n, nh, hd)
        s = np.einsum("nhd,hd->nh", zh, np.asarray(layer["a_l"]))
        s = np.where(s >= 0, s, 0.2 * s)  # leaky relu
        e = np.exp(s)
        out = np.zeros_like(zh)
        for head in range(nh):
            # per-destination softmax over in-neighbors (source-decomposed)
            wsum = a @ (e[:, head][:, None] * zh[:, head])
            norm = a @ e[:, head]
            out[:, head] = wsum / np.maximum(norm, 1e-9)[:, None]
        h = out.reshape(n, total)
        if i < nlayers - 1:
            h = np.where(h > 0, h, np.exp(np.minimum(h, 0)) - 1)  # elu
    np.testing.assert_allclose(got, h, rtol=5e-3, atol=5e-3)


def test_gat_trains():
    g, x, eng = _setup(n=200, d=16, ncls=4)
    from repro.train.optimizer import AdamWConfig, adamw_init, adamw_update
    from repro.train.data import graph_features
    xf, y, mask = graph_features(g.num_nodes, 16, 4, seed=5)
    init, apply, kw = C.MODEL_ZOO["gat"]
    params = init(jax.random.key(0), 16, 4, **kw)
    opt = adamw_init(params)
    xp = eng.shard(eng.pad(xf))
    pad1 = lambda a: C.pad_table(eng.plan.bounds, eng.plan.rows_per_dev,
                                 a[:, None])[:, 0]
    yp = jnp.asarray(pad1(y.astype(np.int32)))
    mp = jnp.asarray(pad1(mask.astype(np.float32)))
    ocfg = AdamWConfig(lr=5e-3, warmup_steps=2, total_steps=20,
                      weight_decay=0.0)

    @jax.jit
    def step(params, opt):
        loss, grads = jax.value_and_grad(lambda p: C.masked_cross_entropy(
            apply(p, eng, xp), yp, mp))(params)
        params, opt, _ = adamw_update(grads, opt, params, ocfg)
        return params, opt, loss

    losses = []
    for _ in range(15):
        params, opt, loss = step(params, opt)
        losses.append(float(loss))
    assert losses[-1] < losses[0] - 0.1
