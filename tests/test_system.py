"""End-to-end system behaviour: the full dry-run machinery on a small fake
mesh (subprocess), plus cross-substrate integration checks."""
import os
import subprocess
import sys

import numpy as np
import pytest

MULTIDEV = os.path.join(os.path.dirname(__file__), "multidev")


def _run(script, directory=MULTIDEV):
    """Run a self-contained script (sets its own XLA device count before
    importing jax) in a fresh interpreter; assert clean exit."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    r = subprocess.run([sys.executable, os.path.join(directory, script)],
                       capture_output=True, text=True, env=env, timeout=1200)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    return r.stdout


def test_dryrun_machinery_small_mesh():
    out = _run("dryrun_lite.py")
    assert "PASSED" in out


MULTIDEV_SCRIPTS = [
    "collectives.py",        # ring collectives + EF compression vs dense refs
    "mgg_equivalence.py",    # MGG ring (all knobs, per-layer, fused) vs oracle
    "mgg_sparse.py",         # sparse payload: k==D bitwise vs dense, ring-size
                             # determinism property at k<D
    "gnn_training.py",       # end-to-end 8-device GCN training
    "elastic_restore.py",    # 2-dev checkpoint → 8-dev mesh restore
    "collectives_property.py",  # property sweep over 1/2/4/8-dev meshes
    "ring_tp.py",            # ring-pipelined TP matmuls == SPMD defaults
    "serve_gnn.py",          # 8-dev serving: drift → retune, cache, equality
    "serve_cluster.py",      # 2 replicas on disjoint 4-dev halves: staggered
                             # retune, shared cache, zero drops
    "feature_store.py",      # tiered host store + hot cache: streamed ring
                             # bitwise across capacities, prefetch overlap,
                             # tiered serving ≡ resident serving
    "sampled_blocks.py",     # fanout-bounded blocks: bitwise vs dense
                             # oracle at any capacity, zero retraces
]

# dryrun_lite.py runs via test_dryrun_machinery_small_mesh above
_MULTIDEV_NON_PARAMETRIZED = {"dryrun_lite.py"}


@pytest.mark.parametrize("script", MULTIDEV_SCRIPTS)
def test_multidevice_subprocess(script):
    """8 fake CPU devices in a fresh process (XLA flag set pre-import) —
    the pytest process itself must keep seeing exactly one device."""
    assert "PASSED" in _run(script)


def test_every_multidev_script_is_registered():
    """CI guard: a tests/multidev/ script that is not parametrized above
    would exit nonzero in isolation yet never run — i.e. be silently
    skipped.  Fail the suite (and hence the workflow) instead."""
    on_disk = {f for f in os.listdir(MULTIDEV)
               if f.endswith(".py") and not f.startswith("_")}
    registered = set(MULTIDEV_SCRIPTS) | _MULTIDEV_NON_PARAMETRIZED
    missing = on_disk - registered
    assert not missing, (
        f"multidev scripts never executed by the suite: {sorted(missing)} — "
        f"add them to MULTIDEV_SCRIPTS in tests/test_system.py")
    stale = registered - on_disk
    assert not stale, f"registered multidev scripts missing on disk: {stale}"


def test_collective_parser_on_synthetic_hlo():
    from repro.launch.dryrun import parse_collectives
    hlo = """
  %x = bf16[128,256]{1,0} parameter(0)
  %ag = bf16[2048,256]{1,0} all-gather(%x), replica_groups={}
  %ar = f32[64]{0} all-reduce(%y), to_apply=%sum
  %cp.1 = bf16[128,256]{1,0} collective-permute-start(%x), channel_id=3
  %cpd = bf16[128,256]{1,0} collective-permute-done(%cp.1)
"""
    out = parse_collectives(hlo)
    assert out["per_op"]["all-gather"]["bytes"] == 128 * 256 * 2
    assert out["per_op"]["collective-permute"]["count"] == 1
    assert out["n_async"] == 1
    assert "all-reduce" in out["per_op"]


def test_cells_enumeration_covers_assignment():
    from repro.launch.cells import all_cells
    run, skipped = all_cells()
    assert len(run) + len(skipped) == 40  # 10 archs × 4 shapes
    assert len(run) == 33 and len(skipped) == 7
    skipped_archs = {a for a, _, _ in skipped}
    assert skipped_archs == {"codeqwen1.5-7b", "mistral-nemo-12b",
                             "qwen3-32b", "starcoder2-15b", "internvl2-76b",
                             "granite-moe-1b-a400m", "whisper-base"}


def test_input_specs_cover_all_cells():
    import jax
    from repro import configs
    from repro.configs import SHAPES
    from repro.launch.cells import input_specs
    for arch in configs.ARCH_IDS:
        cfg = configs.get_config(arch)
        for shape in SHAPES.values():
            spec = input_specs(cfg, shape)
            assert all(isinstance(v, jax.ShapeDtypeStruct)
                       for v in spec.values())
            if shape.kind != "decode":
                assert spec["tokens"].shape == (shape.global_batch,
                                                shape.seq_len)
