"""Trainer substrate: learning, grad accumulation equivalence, checkpoint
atomicity/roundtrip/retention, restart-from-failure, data determinism."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import transformer as T
from repro.train import (AdamWConfig, LMDataConfig, Trainer, TrainState,
                         adamw_init, lm_batch, make_train_step)
from repro.train import checkpoint as ck


def _setup(accum=1):
    cfg = configs.get_smoke_config("codeqwen1.5-7b")
    params = T.init_params(jax.random.key(0), cfg, vocab_multiple=4)
    opt = adamw_init(params)
    step = make_train_step(cfg, T.DistCtx(),
                           AdamWConfig(lr=1e-3, warmup_steps=5,
                                       total_steps=100),
                           accum_steps=accum)
    dcfg = LMDataConfig(vocab=cfg.vocab, seq_len=24, global_batch=8)
    return cfg, params, opt, jax.jit(step), dcfg


def test_loss_decreases():
    cfg, params, opt, step, dcfg = _setup()
    losses = []
    for s in range(20):
        b = {k: jnp.asarray(v) for k, v in lm_batch(dcfg, s).items()}
        params, opt, m = step(params, opt, b)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.2


def test_grad_accum_matches_full_batch():
    cfg, params, opt, _, dcfg = _setup()
    b = {k: jnp.asarray(v) for k, v in lm_batch(dcfg, 0).items()}
    s1 = jax.jit(make_train_step(cfg, T.DistCtx(),
                                 AdamWConfig(lr=1e-3), accum_steps=1))
    s2 = jax.jit(make_train_step(cfg, T.DistCtx(),
                                 AdamWConfig(lr=1e-3), accum_steps=4))
    p1, _, m1 = s1(params, adamw_init(params), b)
    p2, _, m2 = s2(params, adamw_init(params), b)
    # same data, same math (mean-of-microbatch grads == full-batch grads
    # because every position carries equal weight here)
    for l1, l2 in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(l1, np.float32),
                                   np.asarray(l2, np.float32),
                                   rtol=2e-4, atol=2e-5)


def test_checkpoint_roundtrip_and_retention():
    tree = dict(a=jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
                b=dict(c=jnp.ones((4,), jnp.bfloat16)),
                d=[jnp.zeros((2,), jnp.int32), jnp.ones((1,))])
    with tempfile.TemporaryDirectory() as d:
        for s in (1, 2, 3, 4, 5):
            ck.save(d, s, tree, keep=2)
        steps = sorted(x for x in os.listdir(d) if x.startswith("step-"))
        assert len(steps) == 2  # retention
        assert ck.latest_step(d) == 5
        out = ck.restore(d, 5, tree)
        for l1, l2 in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
            assert l1.dtype == l2.dtype
            np.testing.assert_array_equal(np.asarray(l1, np.float32),
                                          np.asarray(l2, np.float32))


def test_trainer_restores_after_injected_failure():
    cfg, params, opt, step, dcfg = _setup()
    calls = dict(n=0)

    def flaky_step(p, o, b):
        calls["n"] += 1
        if calls["n"] == 12:
            raise RuntimeError("injected preemption")
        return step(p, o, b)

    def data_it():
        s = 0
        while True:
            yield {k: jnp.asarray(v) for k, v in lm_batch(dcfg, s).items()}
            s += 1

    with tempfile.TemporaryDirectory() as d:
        tr = Trainer(flaky_step, data_it(), TrainState(params, opt),
                     workdir=d, ckpt_every=5, log_every=1000,
                     log_fn=lambda *_: None)
        losses = tr.run(15)
        assert tr.restarts == 1
        assert len(losses) >= 15
        assert ck.latest_step(d) == 15


def test_ef_compressed_step_tracks_uncompressed():
    """ef_bits=8 (error-feedback int8 gradient allreduce, the pure-DP wire
    format) must run, carry a live residual, and stay close to the plain
    step's parameter update."""
    from repro.dist import ef_state_init, make_mesh

    cfg = configs.get_smoke_config("codeqwen1.5-7b")
    params = T.init_params(jax.random.key(0), cfg, vocab_multiple=4)
    mesh = make_mesh((1, 1), ("data", "model"))
    ctx = T.DistCtx(mesh=mesh)
    dcfg = LMDataConfig(vocab=cfg.vocab, seq_len=24, global_batch=8)
    b = {k: jnp.asarray(v) for k, v in lm_batch(dcfg, 0).items()}
    s_plain = jax.jit(make_train_step(cfg, ctx, AdamWConfig(lr=1e-3)))
    s_ef = jax.jit(make_train_step(cfg, ctx, AdamWConfig(lr=1e-3),
                                   ef_bits=8))
    p1, _, m1 = s_plain(params, adamw_init(params), b)
    state = (adamw_init(params), ef_state_init(params))
    p2, (_, err), m2 = s_ef(params, state, b)
    # identical loss (the forward pass is untouched)
    assert float(m1["loss"]) == float(m2["loss"])
    # the residual is live (quantization error carried to the next step)
    assert max(float(jnp.abs(e).max()) for e in jax.tree.leaves(err)) > 0
    for a, c in zip(jax.tree.leaves(p2), jax.tree.leaves(p1)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(c, np.float32),
                                   rtol=5e-3, atol=5e-4)


def test_ef_requires_pure_dp_mesh():
    from repro.dist import make_mesh

    cfg = configs.get_smoke_config("codeqwen1.5-7b")
    with pytest.raises(ValueError, match="mesh"):
        make_train_step(cfg, T.DistCtx(), AdamWConfig(), ef_bits=8)
    # a stand-in mesh with a non-trivial model axis is rejected
    class FakeMesh:
        shape = {"data": 1, "model": 2}
    with pytest.raises(ValueError, match="pure-DP"):
        make_train_step(cfg, T.DistCtx(mesh=FakeMesh()), AdamWConfig(),
                        ef_bits=8)


def test_data_determinism_and_restart_alignment():
    dcfg = LMDataConfig(vocab=97, seq_len=16, global_batch=4, seed=3)
    b1 = lm_batch(dcfg, 7)
    b2 = lm_batch(dcfg, 7)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    b3 = lm_batch(dcfg, 8)
    assert not np.array_equal(b1["tokens"], b3["tokens"])
    assert b1["tokens"].min() >= 0 and b1["tokens"].max() < 97
