"""The 10 assigned architectures must carry the EXACT published numbers."""
import pytest

from repro import configs

# (arch, layers, d_model, heads, kv, d_ff, vocab) from the assignment table
TABLE = [
    ("codeqwen1.5-7b", 32, 4096, 32, 32, 13440, 92416, "dense"),
    ("mistral-nemo-12b", 40, 5120, 32, 8, 14336, 131072, "dense"),
    ("qwen3-32b", 64, 5120, 64, 8, 25600, 151936, "dense"),
    ("starcoder2-15b", 40, 6144, 48, 4, 24576, 49152, "dense"),
    ("zamba2-7b", 81, 3584, 32, 32, 14336, 32000, "hybrid"),
    ("internvl2-76b", 80, 8192, 64, 8, 28672, 128256, "vlm"),
    ("mixtral-8x7b", 32, 4096, 32, 8, 14336, 32000, "moe"),
    ("granite-moe-1b-a400m", 24, 1024, 16, 8, 512, 49155, "moe"),
    ("xlstm-125m", 12, 768, 4, 4, 0, 50304, "xlstm"),
    ("whisper-base", 6, 512, 8, 8, 2048, 51865, "encdec"),
]


@pytest.mark.parametrize("arch,L,d,H,kv,ff,V,fam", TABLE)
def test_exact_config(arch, L, d, H, kv, ff, V, fam):
    cfg = configs.get_config(arch)
    assert cfg.n_layers == L and cfg.d_model == d
    assert cfg.n_heads == H and cfg.n_kv_heads == kv
    assert cfg.d_ff == ff and cfg.vocab == V
    assert cfg.family == fam


def test_family_specifics():
    assert configs.get_config("qwen3-32b").qk_norm
    assert configs.get_config("mixtral-8x7b").n_experts == 8
    assert configs.get_config("mixtral-8x7b").top_k == 2
    assert configs.get_config("mixtral-8x7b").sliding_window == 4096
    g = configs.get_config("granite-moe-1b-a400m")
    assert g.n_experts == 32 and g.top_k == 8 and g.expert_mode == "ep"
    z = configs.get_config("zamba2-7b")
    assert z.ssm_state == 64 and z.attn_every > 0
    w = configs.get_config("whisper-base")
    assert w.n_enc_layers == 6 and w.mlp_type == "gelu" and w.norm == "ln"
    assert configs.get_config("starcoder2-15b").mlp_type == "gelu"
    assert configs.get_config("internvl2-76b").n_vis_tokens > 0
    assert configs.get_config("xlstm-125m").xlstm_pattern == ("m", "s")


def test_shape_applicability_rules():
    from repro.configs import SHAPES, shape_applicable
    long = SHAPES["long_500k"]
    runs = [a for a in configs.ARCH_IDS
            if shape_applicable(configs.get_config(a), long)[0]]
    # hybrid + xlstm + SWA-bounded mixtral run; pure full-attention skip
    assert set(runs) == {"zamba2-7b", "xlstm-125m", "mixtral-8x7b"}
    for a in configs.ARCH_IDS:
        cfg = configs.get_config(a)
        for s in ("train_4k", "prefill_32k", "decode_32k"):
            assert shape_applicable(cfg, SHAPES[s])[0]


def test_smoke_configs_stay_in_family():
    for a in configs.ARCH_IDS:
        full, smoke = configs.get_config(a), configs.get_smoke_config(a)
        assert smoke.family == full.family
        assert smoke.d_model <= 128 and smoke.n_layers <= 8
        assert smoke.mlp_type == full.mlp_type and smoke.norm == full.norm
        if full.family == "moe":
            assert smoke.n_experts > 1


def test_param_counts_plausible():
    # sanity: param_count should be within 2× of the nameplate sizes
    approx = {
        "codeqwen1.5-7b": 7e9, "mistral-nemo-12b": 12e9, "qwen3-32b": 32e9,
        "starcoder2-15b": 15e9, "internvl2-76b": 70e9,
        "mixtral-8x7b": 46e9, "xlstm-125m": 125e6,
    }
    for a, n in approx.items():
        got = configs.get_config(a).param_count()
        assert 0.4 * n < got < 2.5 * n, (a, got, n)
