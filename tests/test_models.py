"""Per-arch smoke tests (reduced configs, same family): one forward/train
step on CPU asserting output shapes + no NaNs — plus decode-cache
consistency: prefill+decode logits must match the full-sequence forward."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import encdec as ED
from repro.models import transformer as T

B, S = 2, 24
RNG = np.random.default_rng(0)


def _batch(cfg):
    toks = jnp.asarray(RNG.integers(1, cfg.vocab, (B, S)), jnp.int32)
    batch = dict(tokens=toks)
    if cfg.family == "vlm":
        batch["vis"] = jnp.asarray(
            RNG.normal(size=(B, cfg.n_vis_tokens, cfg.d_model)), jnp.float32)
    if cfg.family == "encdec":
        batch["frames"] = jnp.asarray(
            RNG.normal(size=(B, 12, cfg.d_model)), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", configs.ARCH_IDS)
def test_smoke_forward_and_train_step(arch):
    cfg = configs.get_smoke_config(arch)
    key = jax.random.key(0)
    batch = _batch(cfg)
    if cfg.family == "encdec":
        params = ED.init_params(key, cfg, vocab_multiple=4)
        loss, aux = ED.loss_fn(params, cfg, batch)
    else:
        params = T.init_params(key, cfg, vocab_multiple=4)
        loss, aux = T.loss_fn(params, cfg, batch)
        logits, _ = T.forward(params, cfg, batch["tokens"],
                              vis=batch.get("vis"))
        vp = -(-cfg.vocab // 4) * 4
        assert logits.shape == (B, S, vp)
        assert np.isfinite(np.asarray(logits)).all()
        # one optimizer step must keep everything finite
        from repro.train.optimizer import (AdamWConfig, adamw_init,
                                           adamw_update)
        g = jax.grad(lambda p: T.loss_fn(p, cfg, batch)[0])(params)
        p2, _, m = adamw_update(g, adamw_init(params), params, AdamWConfig())
        assert np.isfinite(float(m["grad_norm"])) and m["grad_norm"] > 0
        assert all(np.isfinite(np.asarray(l, dtype=np.float32)).all()
                   for l in jax.tree.leaves(p2))
    assert np.isfinite(float(loss)) and float(loss) > 0


@pytest.mark.parametrize("arch", [a for a in configs.ARCH_IDS
                                  if configs.get_config(a).family != "encdec"])
def test_decode_consistency_with_forward(arch):
    """prefill(t[:k]) then decode(t[k]) must equal forward(t[:k+1])[k]."""
    cfg = configs.get_smoke_config(arch)
    # disable remat noise; fp32 end to end for a tight comparison.  MoE
    # capacity routing is batch-size dependent (slot ranks shift with the
    # token set) — no-drop capacity makes prefill/decode exactly match.
    cfg = dataclasses.replace(cfg, compute_dtype="float32", remat=False,
                              moe_capacity_factor=float(
                                  max(cfg.n_experts, 1)))
    params = T.init_params(jax.random.key(1), cfg, vocab_multiple=4)
    toks = jnp.asarray(RNG.integers(1, cfg.vocab, (B, 10)), jnp.int32)
    vis = (jnp.asarray(RNG.normal(size=(B, cfg.n_vis_tokens, cfg.d_model)),
                       jnp.float32) if cfg.family == "vlm" else None)
    full_logits, _ = T.forward(params, cfg, toks, vis=vis)
    k = 7
    cache = T.init_cache(cfg, B, 32, dtype=jnp.float32)
    lg, cache = T.prefill(params, cfg, toks[:, :k], cache, vis=vis)
    np.testing.assert_allclose(
        np.asarray(lg), np.asarray(full_logits[:, k - 1]),
        rtol=2e-3, atol=2e-3)
    offset = cfg.n_vis_tokens if cfg.family == "vlm" else 0
    pos = jnp.full((B,), k + offset, jnp.int32)
    lg2, _ = T.decode_step(params, cfg, toks[:, k], pos, cache)
    np.testing.assert_allclose(
        np.asarray(lg2), np.asarray(full_logits[:, k]),
        rtol=2e-3, atol=2e-3)


def test_whisper_prefill_decode_consistency():
    cfg = dataclasses.replace(configs.get_smoke_config("whisper-base"),
                              compute_dtype="float32", remat=False)
    params = ED.init_params(jax.random.key(2), cfg, vocab_multiple=4)
    frames = jnp.asarray(RNG.normal(size=(B, 12, cfg.d_model)), jnp.float32)
    toks = jnp.asarray(RNG.integers(1, cfg.vocab, (B, 10)), jnp.int32)
    enc = ED.encode(params, cfg, frames, remat=False)
    enc_pos = jnp.broadcast_to(jnp.arange(12, dtype=jnp.int32), (B, 12))
    positions = jnp.broadcast_to(jnp.arange(10, dtype=jnp.int32), (B, 10))
    full, _ = ED._decoder(params, cfg, toks, enc, enc_pos,
                          ctx=T.DistCtx(), positions=positions)
    cache = ED.init_cache(cfg, B, 32, n_frames=12, dtype=jnp.float32)
    lg, cache = ED.prefill(params, cfg, frames, toks[:, :7], cache)
    np.testing.assert_allclose(np.asarray(lg), np.asarray(full[:, 6]),
                               rtol=2e-3, atol=2e-3)
    lg2, _ = ED.decode_step(params, cfg, toks[:, 7],
                            jnp.full((B,), 7, jnp.int32), cache)
    np.testing.assert_allclose(np.asarray(lg2), np.asarray(full[:, 7]),
                               rtol=2e-3, atol=2e-3)


def test_sliding_window_masks_old_tokens():
    # no-drop MoE capacity: capacity routing is batch-dependent, which would
    # otherwise leak a far-token perturbation through slot reassignment
    cfg = dataclasses.replace(
        configs.get_smoke_config("mixtral-8x7b"), sliding_window=4,
        compute_dtype="float32", remat=False, moe_capacity_factor=8.0)
    params = T.init_params(jax.random.key(3), cfg, vocab_multiple=4)
    toks = jnp.asarray(RNG.integers(1, cfg.vocab, (1, 12)), jnp.int32)
    lg, _ = T.forward(params, cfg, toks)
    # perturbing a token > window positions back must not change the logits
    toks2 = toks.at[0, 0].set((toks[0, 0] + 1) % cfg.vocab)
    lg2, _ = T.forward(params, cfg, toks2)
    np.testing.assert_allclose(np.asarray(lg[0, -1]), np.asarray(lg2[0, -1]),
                               rtol=1e-4, atol=1e-4)
