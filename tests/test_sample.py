"""repro.sample — fanout-bounded block sampling (single device).

Property checks on the sampler (no replacement, real edges, exact
padding), the block format contract (dst-first chaining, fixed shapes),
planless TieredFeatures.gather_rows, and apply_blocks against a dense
oracle.  The 8-device bitwise/retrace run lives in
tests/multidev/sampled_blocks.py via test_system.py."""
import numpy as np
import jax.numpy as jnp
import pytest

import repro.core as C
from repro.sample import (block_tree, sample_blocks,
                          sampled_khop_frontier, seed_batches)
from repro.store import FeatureStore, TieredFeatures


@pytest.fixture(scope="module")
def g():
    return C.power_law(300, avg_degree=7.0, locality=0.4, seed=2)


# ---------------------------------------------------------------------------
# sampler properties
# ---------------------------------------------------------------------------

def test_block_shapes_fixed_by_batch_and_fanouts(g):
    rng = np.random.default_rng(0)
    for n_seeds in (3, 17, 32):   # shapes must NOT depend on the seed count
        seeds = rng.choice(g.num_nodes, n_seeds, replace=False)
        b2, b1 = sample_blocks(g, seeds, [5, 3], batch=32, rng=rng)
        assert b1.nbr.shape == (32, 3) and b1.src_ids.shape == (32 * 4,)
        assert b2.nbr.shape == (32 * 4, 5)
        assert b2.src_ids.shape == (32 * 4 * 6,)


def test_sampled_neighbors_are_real_edges_without_replacement(g):
    rng = np.random.default_rng(1)
    seeds = rng.choice(g.num_nodes, 24, replace=False)
    (blk,) = sample_blocks(g, seeds, [6], batch=24, rng=rng)
    for r in range(blk.num_dst):
        dst = blk.src_ids[r]
        live = blk.nbr[r][blk.mask[r] > 0]
        if dst < 0:
            assert live.size == 0
            continue
        nb_global = blk.src_ids[live]
        assert len(set(nb_global.tolist())) == live.size, "replacement"
        row = set(g.row(int(dst)).tolist())
        assert set(nb_global.tolist()) <= row
        assert live.size == min(len(row), 6), "under-drew available nbrs"


def test_pad_slots_point_at_sentinel_row(g):
    rng = np.random.default_rng(2)
    seeds = rng.choice(g.num_nodes, 8, replace=False)
    (blk,) = sample_blocks(g, seeds, [4], batch=16, rng=rng)
    pad = blk.mask == 0.0
    assert (blk.nbr[pad] == blk.num_src).all(), \
        "masked slots must index the appended zero sentinel row"


def test_blocks_chain_dst_first(g):
    rng = np.random.default_rng(3)
    seeds = rng.choice(g.num_nodes, 16, replace=False)
    blocks = sample_blocks(g, seeds, [4, 4, 4], batch=16, rng=rng)
    for outer, inner in zip(blocks, blocks[1:]):
        np.testing.assert_array_equal(outer.src_ids[:outer.num_dst],
                                      inner.src_ids)
    # innermost dst prefix is the seed vector itself, original order
    np.testing.assert_array_equal(blocks[-1].src_ids[:len(seeds)], seeds)


def test_sample_blocks_validates_inputs(g):
    with pytest.raises(ValueError):
        sample_blocks(g, np.array([1, 1]), [4], batch=8)   # dup seeds
    with pytest.raises(ValueError):
        sample_blocks(g, np.arange(9), [4], batch=8)       # over batch cap


def test_seed_batches_cover_all_ids_exactly_once():
    ids = np.arange(50)
    seen = []
    for seeds, valid in seed_batches(ids, 16, rng=np.random.default_rng(0)):
        assert seeds.shape == (16,) and valid.shape == (16,)
        assert ((seeds >= 0) == (valid > 0)).all()
        seen.extend(seeds[seeds >= 0].tolist())
    assert sorted(seen) == list(range(50))


def test_sampled_frontier_is_subset_of_exact(g):
    rng = np.random.default_rng(4)
    seeds = rng.choice(g.num_nodes, 6, replace=False)
    samp = sampled_khop_frontier(g, seeds, [3, 3], rng=rng)
    exact = C.khop_in_frontier(g, seeds, 2)
    assert set(samp.tolist()) <= set(exact.tolist())
    assert set(seeds.tolist()) <= set(samp.tolist())


# ---------------------------------------------------------------------------
# planless gather_rows
# ---------------------------------------------------------------------------

def test_gather_rows_bitwise_any_capacity(g):
    x = np.random.default_rng(5).normal(
        size=(g.num_nodes, 9)).astype(np.float32)
    ids = np.array([4, -1, 17, 250, -1, 0], np.int64)
    want = np.where((ids >= 0)[:, None], x[np.clip(ids, 0, None)],
                    np.float32(0.0))
    for cap in (0, 40, g.num_nodes):
        tiers = TieredFeatures(FeatureStore(x), None, capacity=cap)
        if cap:
            tiers.admit(np.argsort(-g.degrees)[:cap])
        got = np.asarray(tiers.gather_rows(ids))
        np.testing.assert_array_equal(got.view(np.uint32),
                                      want.view(np.uint32))
    # rows= pads the buffer beyond the id list
    got = np.asarray(tiers.gather_rows(ids, rows=10))
    assert got.shape == (10, 9) and (got[6:] == 0).all()


def test_gather_rows_rejects_bad_ids_and_planless_chunks(g):
    x = np.zeros((g.num_nodes, 4), np.float32)
    tiers = TieredFeatures(FeatureStore(x), None, capacity=8)
    with pytest.raises(ValueError):
        tiers.gather_rows(np.array([g.num_nodes]))     # out of range
    with pytest.raises(ValueError):
        tiers.gather_rows(np.array([1, 2, 3]), rows=2)  # rows < ids
    with pytest.raises(ValueError):
        tiers.device_chunk(0)                           # needs a plan
    with pytest.raises(ValueError):
        tiers.padded_table()


# ---------------------------------------------------------------------------
# block aggregation vs dense oracle
# ---------------------------------------------------------------------------

def test_apply_blocks_matches_dense_oracle_bitwise(g):
    import jax

    rng = np.random.default_rng(6)
    x = rng.normal(size=(g.num_nodes, 12)).astype(np.float32)
    init, _, kw = C.MODEL_ZOO["sage"]
    params = init(jax.random.key(1), 12, 4, **kw)
    seeds = rng.choice(g.num_nodes, 20, replace=False)
    blocks = sample_blocks(g, seeds, [4] * len(params["layers"]),
                           batch=32, rng=rng)
    h = jnp.asarray(np.where((blocks[0].src_ids >= 0)[:, None],
                             x[np.clip(blocks[0].src_ids, 0, None)],
                             np.float32(0.0)))
    got = C.apply_blocks("sage", params, h, block_tree(blocks))

    for i, (layer, b) in enumerate(zip(params["layers"], blocks)):
        buf = jnp.concatenate([h, jnp.zeros((1, h.shape[1]), h.dtype)], 0)
        nb = jnp.take(buf, jnp.asarray(b.nbr), axis=0)
        s = (nb * jnp.asarray(b.mask)[..., None]).sum(axis=1)
        deg = jnp.maximum(jnp.asarray(b.mask).sum(-1), 1.0)[:, None]
        dense = lambda p, v: v @ p["w"] + p["b"]
        h = dense(layer["self"], h[:b.num_dst]) + dense(layer["nbr"], s / deg)
        if i < len(params["layers"]) - 1:
            h = jax.nn.relu(h)
    np.testing.assert_array_equal(np.asarray(got).view(np.uint32),
                                  np.asarray(h).view(np.uint32))


def test_apply_blocks_rejects_non_sage_and_layer_mismatch(g):
    import jax

    init, _, kw = C.MODEL_ZOO["sage"]
    params = init(jax.random.key(0), 8, 3, **kw)
    seeds = np.arange(4)
    blocks = sample_blocks(g, seeds, [2], batch=4,
                           rng=np.random.default_rng(0))
    h = jnp.zeros((blocks[0].num_src, 8), jnp.float32)
    with pytest.raises(ValueError):
        C.apply_blocks("gcn", params, h, block_tree(blocks))
    with pytest.raises(ValueError):
        C.apply_blocks("sage", params, h, block_tree(blocks))  # 1 blk, 2 lyr
