"""repro.obs: tracer + metrics unit behavior, the Chrome-trace
validator, and the PR's two proofs of innocence — (1) tracing ON
produces bitwise-identical streamed aggregations, served logits and
training losses vs tracing OFF, and (2) the disabled-tracer path adds
bounded (<2%) overhead to an instrumented hot loop."""
import json
import time

import numpy as np
import pytest

import repro.core as C
from repro.core.pipeline import mgg_aggregate_streamed
from repro.dist import flat_ring_mesh
from repro.obs import NULL_TRACER, MetricsRegistry, Tracer
from repro.obs.validate import validate
from repro.serve import GNNServeEngine, TrafficPhase, ZipfTraffic, run_trace
from repro.store import FeatureStore, TieredFeatures
from repro.train import Trainer, TrainState


class FakeClock:
    """Deterministic injectable clock: advances only when told."""

    def __init__(self):
        self.t = 100.0

    def __call__(self):
        return self.t

    def tick(self, dt=1.0):
        self.t += dt


# ---------------------------------------------------------------------------
# tracer
# ---------------------------------------------------------------------------

def test_span_records_complete_event_with_fake_clock():
    clk = FakeClock()
    tr = Tracer(clock=clk)
    with tr.span("work", cat="test", k=1) as sp:
        clk.tick(2.0)
        sp.set(rows=7)
    (ev,) = tr.events()
    assert ev["ph"] == "X" and ev["name"] == "work" and ev["cat"] == "test"
    assert ev["dur"] == pytest.approx(2e6)        # µs
    assert ev["args"] == {"k": 1, "rows": 7}


def test_nested_spans_and_epoch_relative_timestamps():
    clk = FakeClock()
    tr = Tracer(clock=clk)          # epoch = 100.0
    with tr.span("outer"):
        clk.tick(1.0)
        with tr.span("inner"):
            clk.tick(0.5)
        clk.tick(1.0)
    inner, outer = tr.events()      # inner closes first
    assert inner["name"] == "inner" and outer["name"] == "outer"
    assert inner["ts"] == pytest.approx(1e6)      # relative to epoch
    assert outer["ts"] == pytest.approx(0.0)
    assert outer["dur"] == pytest.approx(2.5e6)
    # the inner span nests strictly inside the outer one
    assert inner["ts"] >= outer["ts"]
    assert inner["ts"] + inner["dur"] <= outer["ts"] + outer["dur"]


def test_complete_instant_counter_event_shapes():
    clk = FakeClock()
    tr = Tracer(clock=clk)
    t0 = tr.now()
    clk.tick(3.0)
    tr.complete("retro", t0, tr.now(), cat="c", tid=2, args={"a": 1})
    tr.instant("mark", cat="ev", hit=True)
    tr.counter("depth", queued=4)
    retro, mark, depth = tr.events()
    assert retro["ph"] == "X" and retro["dur"] == pytest.approx(3e6) \
        and retro["tid"] == 2
    assert mark["ph"] == "i" and mark["s"] == "t" \
        and mark["args"] == {"hit": True}
    assert depth["ph"] == "C" and depth["args"] == {"queued": 4.0}


def test_disabled_tracer_is_a_strict_noop():
    tr = Tracer(enabled=False)
    s1 = tr.span("a")
    s2 = tr.span("b", cat="x", k=1)
    assert s1 is s2                  # one preallocated null span, no allocs
    with s1 as sp:
        sp.set(anything=1)
    tr.instant("i")
    tr.counter("c", v=1)
    tr.complete("x", 0.0, 1.0)
    assert len(tr) == 0 and tr.events() == []
    assert len(NULL_TRACER) == 0


def test_ring_buffer_bounds_and_counts_drops():
    clk = FakeClock()
    tr = Tracer(clock=clk, capacity=4)
    for i in range(10):
        tr.instant(f"e{i}")
    assert len(tr) == 4
    assert tr.dropped == 6
    assert [e["name"] for e in tr.events()] == ["e6", "e7", "e8", "e9"]
    assert tr.to_chrome()["otherData"]["dropped_events"] == 6
    tr.clear()
    assert len(tr) == 0 and tr.dropped == 0


def test_dump_chrome_and_jsonl_roundtrip(tmp_path):
    clk = FakeClock()
    tr = Tracer(clock=clk)
    with tr.span("s"):
        clk.tick()
    tr.instant("i")
    chrome, jsonl = str(tmp_path / "t.json"), str(tmp_path / "t.jsonl")
    tr.dump_chrome(chrome)
    tr.dump_jsonl(jsonl)
    doc = json.load(open(chrome))
    assert [e["name"] for e in doc["traceEvents"]] == ["s", "i"]
    lines = [json.loads(l) for l in open(jsonl)]
    assert lines == doc["traceEvents"]


# ---------------------------------------------------------------------------
# metrics
# ---------------------------------------------------------------------------

def test_counter_labeled_series_are_independent_and_total_folds():
    reg = MetricsRegistry()
    reg.counter("req", replica=0).inc(3)
    reg.counter("req", replica=1).inc(4)
    reg.counter("req", replica=0).inc()           # same series object
    assert reg.counter("req", replica=0).value == 4
    assert reg.counter("req", replica=1).value == 4
    assert reg.counter_total("req") == 8
    assert reg.counter_total("other") == 0


def test_gauge_set_inc_dec():
    reg = MetricsRegistry()
    g = reg.gauge("depth")
    g.set(5)
    g.inc(2)
    g.dec()
    assert g.value == 6.0


def test_histogram_exact_stats_and_percentiles():
    reg = MetricsRegistry()
    h = reg.histogram("lat")
    for v in range(1, 101):
        h.observe(float(v))
    s = h.summary()
    assert s["count"] == 100 and s["sum"] == pytest.approx(5050.0)
    assert s["min"] == 1.0 and s["max"] == 100.0
    assert s["mean"] == pytest.approx(50.5)
    assert 45.0 <= s["p50"] <= 55.0
    assert 88.0 <= s["p90"] <= 92.0
    assert 97.0 <= s["p99"] <= 100.0
    assert h.percentile(0) == 1.0 and h.percentile(100) == 100.0


def test_histogram_reservoir_is_bounded_and_recent_biased():
    reg = MetricsRegistry()
    h = reg.histogram("lat", reservoir=8)
    for v in range(1000):
        h.observe(float(v))
    assert h.count == 1000 and len(h._buf) == 8
    assert h.min == 0.0 and h.max == 999.0        # exact despite reservoir
    assert h.percentile(50) >= 900.0              # cyclic overwrite → recent


def test_snapshot_formats_labels_and_dump_json(tmp_path):
    reg = MetricsRegistry()
    reg.counter("served", replica=1).inc(2)
    reg.counter("plain").inc()
    reg.gauge("q").set(3)
    reg.histogram("lat").observe(0.5)
    snap = reg.snapshot()
    assert snap["counters"] == {"served{replica=1}": 2, "plain": 1}
    assert snap["gauges"]["q"] == 3.0
    assert snap["histograms"]["lat"]["count"] == 1
    p = str(tmp_path / "m.json")
    reg.dump_json(p, extra={"audit": [{"event": "probe"}]})
    doc = json.load(open(p))
    assert doc["counters"]["plain"] == 1
    assert doc["audit"] == [{"event": "probe"}]


# ---------------------------------------------------------------------------
# validator
# ---------------------------------------------------------------------------

def _trace_with(events):
    return {"traceEvents": events}


def test_validator_accepts_complete_trace(tmp_path):
    p = str(tmp_path / "good.json")
    json.dump(_trace_with([
        {"ph": "X", "name": "mgg.stream.ring", "ts": 0, "dur": 5},
        {"ph": "X", "name": "mgg.stream.aggregate", "ts": 0, "dur": 9,
         "args": {"overlap_efficiency": 0.4}},
        {"ph": "i", "name": "tuner.probe", "ts": 1},
    ]), open(p, "w"))
    assert validate(p) == []


def test_validator_flags_each_missing_property(tmp_path):
    p = str(tmp_path / "bad.json")
    json.dump(_trace_with([{"ph": "i", "name": "serve.retune", "ts": 0}]),
              open(p, "w"))
    problems = validate(p)
    assert any("ring-step" in s for s in problems)
    assert any("overlap_efficiency" in s for s in problems)
    assert any("tuner" in s for s in problems)

    json.dump(_trace_with([
        {"ph": "X", "name": "mgg.stream.ring", "ts": 0, "dur": 5},
        {"ph": "X", "name": "mgg.stream.aggregate", "ts": 0, "dur": 9,
         "args": {"overlap_efficiency": 0.0}},
        {"ph": "i", "name": "tuner.probe", "ts": 1},
    ]), open(p, "w"))
    assert any("never positive" in s for s in validate(p))


def test_validator_rejects_garbage(tmp_path):
    p = str(tmp_path / "garbage.json")
    open(p, "w").write("not json {")
    assert any("JSON" in s for s in validate(p))
    json.dump({"events": []}, open(p, "w"))
    assert validate(p) == ["no traceEvents list"]


# ---------------------------------------------------------------------------
# innocence proof 1: tracing never changes a computed bit
# ---------------------------------------------------------------------------

def _tiered_setup(n=60, d=8, cap=16, seed=3):
    g = C.power_law(n, avg_degree=5.0, locality=0.3, seed=seed)
    x = np.random.default_rng(seed).normal(size=(n, d)).astype(np.float32)
    plan = C.build_plan(g, 1, ps=4, dist=2)
    t = TieredFeatures(FeatureStore(x), plan, cap)
    if cap:
        t.admit(np.argsort(-g.degrees)[:cap].tolist())
    return t, plan


def test_streamed_aggregation_bitwise_identical_with_tracing():
    t, plan = _tiered_setup()
    mesh = flat_ring_mesh(1)
    off = np.asarray(mgg_aggregate_streamed(t.chunk_fetcher(), plan, mesh))

    tr = Tracer()
    stats = {}
    on = np.asarray(mgg_aggregate_streamed(t.chunk_fetcher(), plan, mesh,
                                           stats=stats, tracer=tr))
    np.testing.assert_array_equal(off, on)        # bitwise
    names = {e["name"] for e in tr.events()}
    assert "mgg.stream.aggregate" in names
    assert any(n.startswith("mgg.stream.") and n != "mgg.stream.aggregate"
               for n in names)
    roll = [e for e in tr.events()
            if e["name"] == "mgg.stream.aggregate"][0]
    assert 0.0 <= roll["args"]["overlap_efficiency"] <= 1.0
    assert stats["overlap_efficiency"] == roll["args"]["overlap_efficiency"]


def _serve_once(tracer=None, metrics=None, seed=9):
    g = C.power_law(150, avg_degree=5.0, locality=0.3, seed=seed)
    d, ncls = 10, 4
    x = np.random.default_rng(seed).normal(
        size=(g.num_nodes, d)).astype(np.float32)
    init, _apply, kw = C.MODEL_ZOO["gcn"]
    import jax
    params = init(jax.random.key(seed), d, ncls, **kw)
    eng = C.GNNEngine.build(g, flat_ring_mesh(1), ps=8, dist=1)
    srv = GNNServeEngine(eng, params, "gcn", x, g, slots=4,
                         feature_capacity=24, tracer=tracer,
                         metrics=metrics)
    phases = [TrafficPhase(requests=16, alpha=1.3, rate=100.0, seeds_max=3,
                           update_frac=0.1)]
    res = run_trace(srv, ZipfTraffic(g.num_nodes, d, phases, seed=seed))
    return srv, res


def test_served_logits_bitwise_identical_with_tracing():
    _, base = _serve_once()
    tr, reg = Tracer(), MetricsRegistry()
    srv, traced = _serve_once(tracer=tr, metrics=reg)
    assert len(base) == len(traced) > 0
    for ra, rb in zip(base, traced):
        assert ra.request_id == rb.request_id
        np.testing.assert_array_equal(ra.logits, rb.logits)   # bitwise
    # the traced run actually recorded the request lifecycle
    names = [e["name"] for e in tr.events()]
    assert names.count("serve.request") == len(traced)
    assert "serve.queue_wait" in names and "serve.aggregate" in names
    # and the registry agrees with the engine's report
    rep = srv.report()
    assert reg.counter_total("serve.served") == rep["served"] == len(traced)
    assert reg.histogram("serve.request_seconds").count == len(traced)


def test_training_losses_bitwise_identical_with_tracing():
    import jax.numpy as jnp

    def step_fn(params, opt, batch):
        loss = jnp.sum((params - batch["x"]) ** 2)
        return params * 0.9, opt, {"loss": loss}

    def data():
        s = 0
        while True:
            yield {"x": jnp.full((4,), float(s % 3))}
            s += 1

    def run(**obs):
        tr = Trainer(step_fn, data(), TrainState(jnp.ones(4), None),
                     log_fn=lambda _s: None, **obs)
        return tr.run(8)

    base = run()
    tracer, reg = Tracer(), MetricsRegistry()
    traced = run(tracer=tracer, metrics=reg)
    assert base == traced                          # bitwise (float equality)
    steps = [e for e in tracer.events() if e["name"] == "train.step"]
    assert len(steps) == 8
    assert reg.histogram("train.step_seconds").count == 8


# ---------------------------------------------------------------------------
# innocence proof 2: the disabled path is cheap
# ---------------------------------------------------------------------------

def test_disabled_tracing_overhead_bounded():
    """The instrumented hot loops guard on ONE attribute check per
    chunk/batch and each guarded region does real device work.  Bound the
    disabled-path cost: the per-iteration price of the full instrumentation
    pattern (span guard + now() guard + metrics-None check) must be <2% of
    even a tiny representative unit of work (one 64×64 matmul — every real
    guarded region does far more)."""
    a = np.random.default_rng(0).normal(size=(64, 64))
    tracer = NULL_TRACER
    metrics = None
    n = 20_000

    def instrumented_overhead():
        # the exact disabled-path sequence the hot loops run per iteration
        tracing = tracer is not None and tracer.enabled
        t0 = time.perf_counter()
        for _ in range(n):
            if tracing:
                t_start = tracer.now()
            if tracing:
                tracer.complete("w", t_start, tracer.now())
            if metrics is not None:
                metrics.histogram("x").observe(0.0)
        return (time.perf_counter() - t0) / n

    def unit_of_work():
        best = float("inf")
        for _ in range(50):
            t0 = time.perf_counter()
            a @ a
            best = min(best, time.perf_counter() - t0)
        return best

    per_iter = min(instrumented_overhead() for _ in range(5))
    work = unit_of_work()
    assert per_iter < 0.02 * work, \
        f"disabled-path overhead {per_iter * 1e9:.0f} ns/iter is not <2% " \
        f"of a minimal work unit ({work * 1e6:.1f} µs)"


# ---------------------------------------------------------------------------
# multi-replica trace merging
# ---------------------------------------------------------------------------

def _replica_trace(tmp_path, idx, fmt="jsonl"):
    clk = FakeClock()
    t = Tracer(clock=clk, pid=idx + 40)      # pid the merge must override
    with t.span(f"work{idx}", cat="test"):
        clk.tick(idx + 1.0)
    t.instant(f"mark{idx}")
    path = str(tmp_path / f"replica{idx}.{fmt}")
    (t.dump_jsonl if fmt == "jsonl" else t.dump_chrome)(path)
    return path


def test_merge_traces_distinct_pids_and_labels(tmp_path):
    from repro.obs import merge_traces

    paths = [_replica_trace(tmp_path, i) for i in range(3)]
    out = str(tmp_path / "merged.json")
    doc = merge_traces(paths, labels=["router", "r0", "r1"], out=out)
    evs = doc["traceEvents"]
    # one process_name metadata event per input, carrying the label
    meta = [e for e in evs if e["ph"] == "M"]
    assert [(m["pid"], m["args"]["name"]) for m in meta] \
        == [(0, "router"), (1, "r0"), (2, "r1")]
    # every replica's events land on its own pid, originals overridden
    for i in range(3):
        mine = [e for e in evs if e["pid"] == i and e["ph"] != "M"]
        assert {e["name"] for e in mine} == {f"work{i}", f"mark{i}"}
        span = next(e for e in mine if e["ph"] == "X")
        assert span["dur"] == pytest.approx((i + 1.0) * 1e6)
    # written file loads back identically
    with open(out) as f:
        assert json.load(f)["traceEvents"] == evs


def test_merge_traces_accepts_chrome_and_jsonl_mixed(tmp_path):
    from repro.obs import merge_traces

    paths = [_replica_trace(tmp_path, 0, fmt="jsonl"),
             _replica_trace(tmp_path, 1, fmt="chrome")]
    doc = merge_traces(paths)                 # default replica<i> labels
    meta = [e for e in doc["traceEvents"] if e["ph"] == "M"]
    assert [m["args"]["name"] for m in meta] == ["replica0", "replica1"]
    by_pid = {i: [e for e in doc["traceEvents"]
                  if e["pid"] == i and e["ph"] != "M"] for i in (0, 1)}
    assert len(by_pid[0]) == 2 and len(by_pid[1]) == 2


def test_merged_trace_is_structurally_valid_chrome_json(tmp_path):
    """Every merged event keeps the ph/name shape the repo's trace
    validator requires (its semantic checks are serve-specific, so only
    the structural contract applies to an arbitrary merge)."""
    from repro.obs import merge_traces

    paths = [_replica_trace(tmp_path, i) for i in range(2)]
    out = str(tmp_path / "merged.json")
    merge_traces(paths, out=out)
    with open(out) as f:
        doc = json.load(f)
    assert isinstance(doc["traceEvents"], list) and doc["traceEvents"]
    for ev in doc["traceEvents"]:
        assert isinstance(ev, dict) and "ph" in ev and "name" in ev, ev
        assert isinstance(ev["pid"], int)
