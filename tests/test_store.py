"""Unit tests for the tiered feature storage layer (repro.store) and the
knobs it adds to the runtime: the host FeatureStore, the device
HotFeatureCache (admission / eviction / invalidation semantics), the
TieredFeatures coordinator, the tuner's cap and fuse dimensions, and the
cost model's host-gather term."""
import math

import numpy as np
import pytest

import repro.core as C
from repro.core.autotune import (FUSE_RING_EFF, TPU_V5E, WorkloadShape,
                                 estimate_latency)
from repro.runtime.tuner import OnlineTuner, PerLayerTuner
from repro.store import FeatureStore, HotFeatureCache, TieredFeatures


def _store(n=40, d=6, seed=0):
    rng = np.random.default_rng(seed)
    return FeatureStore(rng.normal(size=(n, d)).astype(np.float32))


# ---------------------------------------------------------------------------
# FeatureStore
# ---------------------------------------------------------------------------

def test_feature_store_gather_and_accounting():
    s = _store()
    ids = np.array([3, 0, 7, 3], dtype=np.int64)
    rows = s.gather(ids)
    assert rows.flags["C_CONTIGUOUS"]
    np.testing.assert_array_equal(rows, s.x[ids])
    assert s.gathers == 1 and s.rows_gathered == 4
    s.gather(np.zeros(0, dtype=np.int64))         # empty gathers count too
    assert s.gathers == 2 and s.rows_gathered == 4
    assert s.bytes_gathered == 4 * s.d_feat * s.itemsize


def test_feature_store_gather_returns_copy():
    s = _store()
    rows = s.gather(np.array([1]))
    rows[:] = 99.0
    assert not np.any(s.x[1] == 99.0)


def test_feature_store_update_row():
    s = _store()
    v = np.arange(s.d_feat, dtype=np.float32)
    s.update_row(5, v)
    np.testing.assert_array_equal(s.row(5), v)
    assert s.version == 1 and s.updates == 1
    with pytest.raises(ValueError):
        s.update_row(5, np.zeros(s.d_feat + 1, np.float32))


# ---------------------------------------------------------------------------
# HotFeatureCache
# ---------------------------------------------------------------------------

def test_hotfeatures_capacity_clamped():
    s = _store(n=10)
    assert HotFeatureCache(10, 99, s.d_feat).capacity == 10
    assert HotFeatureCache(10, -3, s.d_feat).capacity == 0
    zero = HotFeatureCache(10, 0, s.d_feat)
    assert zero.table is None
    assert zero.admit([1, 2], s) == 0             # nothing admissible


def test_hotfeatures_admit_hottest_first_and_dedupe():
    s = _store(n=20)
    c = HotFeatureCache(20, 3, s.d_feat)
    fetched = c.admit([5, 5, 9, 1, 7], s)         # dup 5; 7 over capacity
    assert fetched == 3
    assert c.resident_rows == 3
    assert c.resident(np.array([5, 9, 1])).all()
    assert not c.resident(np.array([7])).any()
    # rows carry the store's bits
    slots = c.slots(np.array([5, 9, 1], dtype=np.int64))
    np.testing.assert_array_equal(np.asarray(c.table)[slots],
                                  s.x[[5, 9, 1]])


def test_hotfeatures_eviction_of_cold_rows():
    s = _store(n=20)
    c = HotFeatureCache(20, 2, s.d_feat)
    c.admit([1, 2], s)
    fetched = c.admit([2, 3], s)                  # 1 cools off, 3 heats up
    assert fetched == 1
    assert c.resident(np.array([2, 3])).all()
    assert not c.resident(np.array([1])).any()
    assert c.evictions == 1
    # a re-admit of a resident-valid row fetches nothing
    assert c.admit([2, 3], s) == 0


def test_hotfeatures_invalidate_then_readmit_keeps_maps_consistent():
    """Regression: a node re-admitted after invalidation must not leave a
    stale _node_at entry behind — reusing that slot for another node in
    the same admit() used to wipe the fresh mapping and strand the row in
    an unreachable slot, then crash the next admit on exhausted slots."""
    s = _store(n=20)
    c = HotFeatureCache(20, 2, s.d_feat)
    c.admit([0, 1], s)
    c.invalidate(np.array([0, 1]))
    assert c.admit([1, 2], s) == 2                # re-admit 1, admit 2
    assert c.resident(np.array([1, 2])).all()
    slots = c.slots(np.array([1, 2], dtype=np.int64))
    assert (slots >= 0).all() and slots[0] != slots[1]
    np.testing.assert_array_equal(np.asarray(c.table)[slots], s.x[[1, 2]])
    # the same hot set is a no-op, not an AssertionError on leaked slots
    assert c.admit([1, 2], s) == 0
    # slot maps agree: every valid slot round-trips node -> slot -> node
    for slot in range(c.capacity):
        if c._valid[slot]:
            assert c._slot_of[c._node_at[slot]] == slot


def test_hotfeatures_hit_accounting_and_invalidate():
    s = _store(n=20)
    c = HotFeatureCache(20, 4, s.d_feat)
    c.admit([0, 1, 2, 3], s)
    slots = c.slots(np.array([0, 1, 9], dtype=np.int64))
    assert (slots[:2] >= 0).all() and slots[2] == -1
    assert c.hits == 2 and c.misses == 1
    assert c.hit_rate == pytest.approx(2 / 3)
    # invalidate dedupes and returns rows actually dirtied
    assert c.invalidate(np.array([1, 1, 9])) == 1
    assert not c.resident(np.array([1])).any()
    assert c.invalidate(np.array([1])) == 0
    # the freed slot is reusable
    assert c.admit([0, 2, 3, 7], s) == 1
    assert c.resident(np.array([7])).any()


# ---------------------------------------------------------------------------
# TieredFeatures
# ---------------------------------------------------------------------------

def _plan_and_x(n=60, d=5, n_dev=2, **kw):
    g = C.power_law(n, avg_degree=4.0, seed=1)
    plan = C.build_plan(g, n_dev, ps=4, dist=kw.pop("dist", 2))
    x = np.random.default_rng(1).normal(size=(n, d)).astype(np.float32)
    return g, plan, x


def test_tiered_plan_must_cover_store():
    _, plan, x = _plan_and_x()
    with pytest.raises(ValueError):
        TieredFeatures(FeatureStore(x[:-1]), plan, 0)


def test_tiered_padded_table_matches_pad_embeddings():
    _, plan, x = _plan_and_x()
    for cap in (0, 10, 60):
        t = TieredFeatures(FeatureStore(x), plan, cap)
        if cap:
            t.admit(list(range(cap)))
        np.testing.assert_array_equal(np.asarray(t.padded_table()),
                                      C.pad_embeddings(plan, x))


def test_tiered_chunks_tile_the_padded_table():
    _, plan, x = _plan_and_x(dist=3)
    t = TieredFeatures(FeatureStore(x), plan, 0)
    full = C.pad_embeddings(plan, x)
    for c in range(plan.dist):
        chunk = np.asarray(t.device_chunk(c))
        for d in range(plan.n_dev):
            lo = d * plan.rows_per_dev + c * plan.tile_rows
            np.testing.assert_array_equal(
                chunk[d * plan.tile_rows:(d + 1) * plan.tile_rows],
                full[lo:lo + plan.tile_rows])


def test_tiered_set_plan_keeps_cache_rows():
    g, plan, x = _plan_and_x()
    t = TieredFeatures(FeatureStore(x), plan, 12)
    t.admit(list(range(12)))
    assert t.cache.resident_rows == 12
    t.set_plan(C.build_plan(g, 2, ps=4, dist=3))  # tuner move: new layout
    assert t.cache.resident_rows == 12            # keyed by node id
    np.testing.assert_array_equal(np.asarray(t.padded_table()),
                                  C.pad_embeddings(t.plan, x))


def test_tiered_update_invalidates_and_reserves_fresh_bits():
    _, plan, x = _plan_and_x()
    t = TieredFeatures(FeatureStore(x), plan, 12)
    t.admit(list(range(12)))
    v = 7.0 * np.ones(x.shape[1], np.float32)
    t.update(3, v)
    assert not t.cache.resident(np.array([3])).any()
    full = np.asarray(t.padded_table())
    expect = x.copy()
    expect[3] = v
    np.testing.assert_array_equal(full, C.pad_embeddings(plan, expect))


def test_tiered_resize_and_report():
    _, plan, x = _plan_and_x()
    t = TieredFeatures(FeatureStore(x), plan, 12)
    t.admit(list(range(12)))
    t.padded_table()
    before = t.report()
    assert before["host_rows_streamed"] > 0
    t.resize(4)                                   # cold restart
    assert t.capacity == 4 and t.cache.resident_rows == 0
    after = t.report()
    # tiered-level accounting survives the resize
    assert after["host_rows_streamed"] == before["host_rows_streamed"]
    for k in ("capacity", "resident_fraction", "hit_rate",
              "host_bytes_streamed", "cache_rows_served", "admissions",
              "evictions", "store_updates"):
        assert k in after


# ---------------------------------------------------------------------------
# tuner knobs: cap and fuse
# ---------------------------------------------------------------------------

def _drive(tuner, lat_fn, limit=400):
    for _ in range(limit):
        if tuner.converged:
            break
        cfg = tuner.propose()
        if cfg is None:
            break
        tuner.observe(lat_fn(cfg))
    return tuner


def test_online_tuner_cap_dimension():
    t = _drive(
        OnlineTuner((256, 512), (1, 2), (16,), cap_space=(0, 1000, 4000)),
        lambda c: 1.0 / c["ps"] + 0.1 * c["dist"] + 1e-5 * (4000 - c["cap"]))
    assert t.converged and t.best["cap"] == 4000
    # warm start carries the cap
    t2 = OnlineTuner((256, 512), (1, 2), (16,), cap_space=(0, 1000, 4000),
                     warm_start=dict(t.best))
    assert t2.propose()["cap"] == 4000


def test_online_tuner_without_cap_space_unchanged():
    t = _drive(OnlineTuner((256, 512), (1, 2), (16,)),
               lambda c: 1.0 / c["ps"] + 0.1 * c["dist"])
    assert t.converged and set(t.best) == {"ps", "dist", "pb"}


def test_per_layer_tuner_fuse_probe_kept_iff_better():
    def lat(cfgs):
        tot = 0.0
        for i, c in enumerate(cfgs):
            base = 1.0 + 0.1 * c["dist"]
            f = c.get("fuse", False)
            tot += base * (0.8 if (f and i == 0) else (1.3 if f else 1.0))
        return tot

    t = _drive(PerLayerTuner(2, (256,), (1, 2), (16,),
                             fuse_space=(False, True)), lat)
    assert t.converged
    assert t.best[0]["fuse"] is True              # fusion helps layer 0
    assert t.best[1]["fuse"] is False             # and hurts layer 1


def test_per_layer_tuner_cap_pinned_across_layers():
    t = _drive(
        PerLayerTuner(2, (256,), (1, 2), (16,), cap_space=(0, 2000)),
        lambda cfgs: sum(1.0 + 0.1 * c["dist"] for c in cfgs)
        + 1e-4 * (2000 - cfgs[0].get("cap", 0)))
    assert t.converged
    caps = {c["cap"] for c in t.best}
    assert caps == {2000}                         # one shared feature table


def test_per_layer_tuner_without_fuse_space_unchanged():
    t = _drive(PerLayerTuner(2, (256,), (1, 2), (16,)),
               lambda cfgs: sum(1.0 + 0.1 * c["dist"] for c in cfgs))
    assert t.converged and all("fuse" not in c for c in t.best)


# ---------------------------------------------------------------------------
# tuner knob: k (sparse-payload width)
# ---------------------------------------------------------------------------

def test_online_tuner_k_dimension_commits_and_warm_starts():
    # narrower payload ⇒ faster: the climb must land on the smallest k
    t = _drive(
        OnlineTuner((256, 512), (1, 2), (16,), k_space=(8, 16, 32)),
        lambda c: 1.0 / c["ps"] + 0.1 * c["dist"] + 1e-3 * c.get("k", 64))
    assert t.converged and t.best["k"] == 8
    # warm start carries the committed k (cache-restart path)
    t2 = OnlineTuner((256, 512), (1, 2), (16,), k_space=(8, 16, 32),
                     warm_start=dict(t.best))
    assert t2.propose()["k"] == 8


def test_online_tuner_k_kept_only_if_it_measures_faster():
    # index overhead makes every sparse candidate slower ⇒ dense k wins
    t = _drive(
        OnlineTuner((256, 512), (1, 2), (16,), k_space=(8, 16, 32)),
        lambda c: 1.0 / c["ps"] + 0.1 * c["dist"] - 1e-3 * c.get("k", 0))
    assert t.converged and t.best["k"] == 32


def test_online_tuner_k_adopt_reopen():
    """Shared-cache adopt: reopen(mode='adopt') proposes exactly the warm
    config — k included — and converges on one validation window."""
    t = _drive(
        OnlineTuner((256, 512), (1, 2), (16,), k_space=(8, 16, 32)),
        lambda c: 1.0 / c["ps"] + 0.1 * c["dist"] + 1e-3 * c.get("k", 64))
    m0 = t.measured
    warm = dict(ps=512, dist=1, pb=16, k=16)
    t.reopen(warm_start=warm, mode="adopt")
    assert not t.converged
    assert t.propose() == warm
    t.observe(0.1)
    assert t.converged and t.best == warm and t.measured - m0 == 1


def test_per_layer_tuner_k_pinned_across_layers():
    """The accuracy budget is end-to-end, so k (like cap) is climbed
    globally: every layer of the committed config shares one k."""
    t = _drive(
        PerLayerTuner(2, (256,), (1, 2), (16,), k_space=(8, 32)),
        lambda cfgs: sum(1.0 + 0.1 * c["dist"] for c in cfgs)
        + 1e-3 * cfgs[0].get("k", 64))
    assert t.converged
    assert {c["k"] for c in t.best} == {8}


def test_online_tuner_without_k_space_unchanged():
    t = _drive(OnlineTuner((256, 512), (1, 2), (16,)),
               lambda c: 1.0 / c["ps"] + 0.1 * c["dist"])
    assert t.converged and "k" not in t.best


# ---------------------------------------------------------------------------
# cost model: host-gather term + fuse calibration
# ---------------------------------------------------------------------------

_SHAPE = WorkloadShape(n_dev=4, d_feat=64, rows_per_dev=4096,
                       local_edges_max=40_000, remote_edges_max=20_000)


def test_estimate_latency_gather_term_monotone_in_host_rows():
    lats = [estimate_latency(_SHAPE, 16, 2, 16, host_rows=r)
            for r in (0, 1000, 10_000, 100_000, 1_000_000)]
    assert lats == sorted(lats)
    assert lats[0] == estimate_latency(_SHAPE, 16, 2, 16)   # None ≡ 0
    assert lats[-1] > lats[0]                     # huge gathers DO cost


def test_estimate_latency_gather_fill_scales_with_dist():
    """More chunks ⇒ smaller exposed fill (better overlap), as long as the
    gather itself still hides under the ring."""
    rows = 20_000
    l1 = estimate_latency(_SHAPE, 16, 1, 16, host_rows=rows)
    l4 = estimate_latency(_SHAPE, 16, 4, 16, host_rows=rows)
    exp1 = l1 - estimate_latency(_SHAPE, 16, 1, 16)
    exp4 = l4 - estimate_latency(_SHAPE, 16, 4, 16)
    assert exp4 < exp1


def test_estimate_latency_fuse_calibration():
    """The fused path divides the per-step update term by FUSE_RING_EFF
    (< 1: fused ring steps run below peak) — fused must therefore model
    slower than perfect folding but still hide under a transfer-bound
    ring."""
    assert 0.0 < FUSE_RING_EFF <= 1.0
    unfused = estimate_latency(_SHAPE, 16, 2, 16, d_out=64, fuse=False)
    fused = estimate_latency(_SHAPE, 16, 2, 16, d_out=64, fuse=True)
    assert fused != unfused
    assert math.isfinite(fused) and fused > 0


def test_estimate_latency_single_device_pays_full_gather():
    lone = WorkloadShape(n_dev=1, d_feat=64, rows_per_dev=4096,
                         local_edges_max=40_000, remote_edges_max=0)
    base = estimate_latency(lone, 16, 1, 16)
    loaded = estimate_latency(lone, 16, 1, 16, host_rows=50_000)
    assert loaded > base
