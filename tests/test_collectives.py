"""repro.dist collectives — single-device unit/property tests.

The degenerate 1-device ring must reduce every pipelined collective to its
purely local computation (that is what lets the same model code run on one
chip).  Multi-device behaviour (2/4/8 rings, chunk sweeps, non-divisible
shapes) runs as a subprocess sweep: tests/multidev/collectives_property.py,
invoked from tests/test_system.py — the pytest process deliberately keeps
one CPU device (see conftest.py).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.dist import (ef_allreduce_mean, ef_state_init, flat_ring_mesh,
                        matmul_reducescatter, pipelined_all_to_all,
                        quantize_dequantize, ring_allgather_matmul)

from repro.testing.hypo import given, settings, strategies as st

MESH1 = flat_ring_mesh(1)


def _smap(body, in_specs, out_specs=P("ring")):
    return jax.shard_map(body, mesh=MESH1, in_specs=in_specs,
                         out_specs=out_specs, check_vma=False)


@given(st.integers(1, 48), st.integers(1, 33), st.integers(1, 17),
       st.integers(0, 999))
@settings(max_examples=25, deadline=None)
def test_allgather_matmul_degenerate_ring(m, k, p, seed):
    rng = np.random.default_rng(seed)
    a = jnp.asarray(rng.normal(size=(m, k)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(k, p)), jnp.float32)
    fn = _smap(lambda x, w: ring_allgather_matmul(x, w, "ring"),
               (P("ring"), P()))
    np.testing.assert_allclose(np.asarray(fn(a, b)), np.asarray(a @ b),
                               rtol=1e-5, atol=1e-6)


@given(st.integers(1, 48), st.integers(1, 33), st.integers(1, 17),
       st.integers(0, 999))
@settings(max_examples=25, deadline=None)
def test_reducescatter_degenerate_ring(m, k, p, seed):
    rng = np.random.default_rng(seed)
    a = jnp.asarray(rng.normal(size=(m, k)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(k, p)), jnp.float32)
    fn = _smap(lambda x, w: matmul_reducescatter(x, w, "ring"),
               (P(None, "ring"), P("ring", None)))
    np.testing.assert_allclose(np.asarray(fn(a, b)), np.asarray(a @ b),
                               rtol=1e-5, atol=1e-6)


@given(st.integers(1, 24), st.integers(1, 19), st.integers(1, 10),
       st.integers(0, 999))
@settings(max_examples=25, deadline=None)
def test_all_to_all_degenerate_ring(rows, width, chunks, seed):
    """chunks > width and chunks ∤ width both reduce to chunked fn."""
    rng = np.random.default_rng(seed)
    z = jnp.asarray(rng.normal(size=(rows, width)), jnp.float32)
    fn = _smap(lambda x: pipelined_all_to_all(
        x, "ring", lambda c: c * c, split_axis=0, concat_axis=1,
        chunk_axis=1, chunks=chunks), (P("ring"),))
    np.testing.assert_allclose(np.asarray(fn(z)), np.asarray(z) ** 2,
                               rtol=1e-6, atol=1e-6)


def test_all_to_all_empty_chunk_axis():
    """Zero-extent chunk axis: no pieces to pipeline, fn still applies."""
    z = jnp.zeros((4, 0))
    fn = _smap(lambda x: pipelined_all_to_all(
        x, "ring", lambda c: c + 1.0, split_axis=0, concat_axis=1,
        chunk_axis=1, chunks=3), (P("ring"),))
    assert fn(z).shape == (4, 0)


def test_all_to_all_chunk_boundaries_cover_axis():
    """Uneven chunking must partition the axis exactly (no drop/overlap)."""
    z = jnp.arange(21.0).reshape(1, 21)
    fn = _smap(lambda x: pipelined_all_to_all(
        x, "ring", lambda c: c + 1.0, split_axis=0, concat_axis=1,
        chunk_axis=1, chunks=4), (P("ring"),))
    np.testing.assert_array_equal(np.asarray(fn(z)), np.asarray(z) + 1.0)


@given(st.integers(1, 30), st.integers(1, 12), st.integers(0, 999))
@settings(max_examples=25, deadline=None)
def test_quantize_bounded_error(rows, cols, seed):
    rng = np.random.default_rng(seed)
    v = jnp.asarray(rng.normal(size=(rows, cols)), jnp.float32)
    q = quantize_dequantize(v)
    step = float(jnp.max(jnp.abs(v))) / 127.0
    assert float(jnp.abs(v - q).max()) <= 0.5 * step + 1e-7


def test_ef_allreduce_mean_single_device():
    """On a 1-axis the 'allreduce' is the identity on the compressed value,
    and the residual carries exactly the quantization error."""
    rng = np.random.default_rng(3)
    g = {"a": jnp.asarray(rng.normal(size=(8, 5)), jnp.float32)}
    err = ef_state_init(g)
    assert float(jnp.abs(err["a"]).max()) == 0.0
    mean, err = ef_allreduce_mean(g, err, MESH1, ("ring",), {"a": P()})
    np.testing.assert_allclose(np.asarray(mean["a"] + err["a"]),
                               np.asarray(g["a"]), rtol=1e-6, atol=1e-7)


def test_ef_error_decays_under_feedback():
    rng = np.random.default_rng(7)
    g = {"a": jnp.asarray(rng.normal(size=(16, 6)), jnp.float32)}
    err = ef_state_init(g)
    acc = np.zeros((16, 6), np.float32)
    for _ in range(8):
        mean, err = ef_allreduce_mean(g, err, MESH1, ("ring",), {"a": P()})
        acc += np.asarray(mean["a"])
    rel = np.abs(acc / 8 - np.asarray(g["a"])).max() / \
        np.abs(np.asarray(g["a"])).max()
    assert rel < 0.02, rel


def test_make_mesh_rejects_oversubscription():
    with pytest.raises(ValueError, match="devices"):
        flat_ring_mesh(len(jax.devices()) + 1)
