"""Benchmark snapshot diff engine (benchmarks/diff.py) + schema v2.

The acceptance criteria of the continuous-perf PR, as tests:

* a snapshot diffed against itself produces ZERO findings and exit 0;
* an injected ≥20% regression is flagged and exits nonzero;
* snapshots from incompatible machines are refused without ``--force``;
* wobble inside the MAD noise band is NOT flagged.
"""
import copy
import json
import os
import subprocess
import sys

import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks import diff  # noqa: E402
from benchmarks._common import (SNAPSHOT_SCHEMA, TimingSample,  # noqa: E402
                                machine_fingerprint, median_mad_us,
                                sample_fields, sample_stats, write_snapshot)

REPO = os.path.join(os.path.dirname(__file__), "..")


def _row(name, med_us, mad_us=2.0, iters=5):
    return {"name": name, "us_per_call": med_us, "us_median": med_us,
            "us_mad": mad_us, "iters": iters,
            "samples_us": [med_us - mad_us, med_us, med_us + mad_us]}


def _snap(tmp_path, fname, rows_by_module, machine=None):
    snap = {"schema": SNAPSHOT_SCHEMA, "stamp": "2026-08-09T00:00:00Z",
            "machine": machine or machine_fingerprint(), "args": {},
            "modules": rows_by_module}
    path = str(tmp_path / fname)
    with open(path, "w") as f:
        json.dump(snap, f)
    return path


@pytest.fixture
def base_path(tmp_path):
    return _snap(tmp_path, "base.json", {
        "fig9": [_row("fig9a", 100.0), _row("fig9b", 250.0)],
        "fig11": [_row("fig11_serve", 900.0, mad_us=10.0)]})


# -- the three acceptance behaviors ----------------------------------------

def test_self_diff_zero_findings(base_path):
    base = diff.load_snapshot(base_path)
    res = diff.compare(base, base)
    assert res.findings == []
    assert res.compared == 3
    assert diff.main([base_path, base_path]) == 0


def test_injected_regression_flagged(tmp_path, base_path):
    base = diff.load_snapshot(base_path)
    new = copy.deepcopy(base)
    row = new["modules"]["fig9"][0]
    for k in ("us_per_call", "us_median"):
        row[k] = row[k] * 1.25            # +25%: far outside 5·MAD and 10%
    res = diff.compare(base, new)
    regs = res.regressions
    assert len(regs) == 1
    f = regs[0]
    assert (f.module, f.name, f.kind) == ("fig9", "fig9a", "regression")
    assert f.rel == pytest.approx(0.25, abs=0.01)
    new_path = _snap(tmp_path, "new.json", new["modules"])
    assert diff.main([base_path, new_path]) == 1          # gate trips
    assert diff.main([new_path, base_path]) == 0          # improvement: pass


def test_twenty_percent_threshold(base_path):
    """The ISSUE's floor: ≥20% must always trip at default thresholds."""
    base = diff.load_snapshot(base_path)
    new = copy.deepcopy(base)
    for mod in new["modules"].values():
        for row in mod:
            for k in ("us_per_call", "us_median"):
                row[k] = row[k] * 1.20
    res = diff.compare(base, new)
    assert len(res.regressions) == res.compared == 3


def test_cross_machine_refused_without_force(tmp_path, base_path):
    base = diff.load_snapshot(base_path)
    other = dict(base["machine"], device_count=base["machine"]
                 .get("device_count", 1) + 7, device_kind="tpu_v5e")
    new_path = _snap(tmp_path, "other.json",
                     copy.deepcopy(base["modules"]), machine=other)
    new = diff.load_snapshot(new_path)
    with pytest.raises(diff.SnapshotError, match="device"):
        diff.compare(base, new)
    assert diff.main([base_path, new_path]) == 2
    # --force overrides; identical timings ⇒ still zero findings
    res = diff.compare(base, new, force=True)
    assert res.findings == []
    assert diff.main([base_path, new_path, "--force"]) == 0


def test_mad_band_suppresses_noise(base_path):
    """Wobble within mad_mult·MAD (but above min_rel·base would flag it
    if MAD were ignored) stays silent: the band is the MAX of the two."""
    base = diff.load_snapshot(base_path)
    new = copy.deepcopy(base)
    row = new["modules"]["fig11"][0]      # median 900, MAD 10
    for k in ("us_per_call", "us_median"):
        row[k] = row[k] + 40.0            # +4.4% < 5·MAD=50 and < 10% floor
    assert diff.compare(base, new).findings == []
    # past BOTH the MAD band and the relative floor ⇒ flagged
    for k in ("us_per_call", "us_median"):
        row[k] = 900.0 * 1.15             # +15% > 10% floor, +135 > 50
    assert len(diff.compare(base, new).regressions) == 1


def test_min_rel_floor_handles_zero_mad(base_path):
    """Rows without samples (schema v1 / search-result rows) fall back to
    MAD 0 — the relative floor keeps scheduler noise from flagging."""
    base = diff.load_snapshot(base_path)
    for mod in base["modules"].values():
        for row in mod:
            row.pop("us_mad", None)
            row.pop("us_median", None)
            row.pop("samples_us", None)
    new = copy.deepcopy(base)
    new["modules"]["fig9"][0]["us_per_call"] *= 1.05   # 5% < 10% floor
    assert diff.compare(base, new).findings == []
    new["modules"]["fig9"][0]["us_per_call"] = 100.0 * 1.30
    assert len(diff.compare(base, new).regressions) == 1


# -- row accounting --------------------------------------------------------

def test_missing_and_new_rows_reported(base_path):
    base = diff.load_snapshot(base_path)
    new = copy.deepcopy(base)
    del new["modules"]["fig11"]
    new["modules"]["fig9"].append(_row("fig9_new", 77.0))
    res = diff.compare(base, new)
    assert res.missing_in_new == ["fig11/fig11_serve"]
    assert res.new_rows == ["fig9/fig9_new"]
    assert res.compared == 2


def test_new_rows_surface_as_findings_with_latency(base_path):
    """A row only the candidate carries lands in the gate report as a
    'new' finding with its latency — not a silent footnote — and never
    trips the gate (a PR adding a benchmark row must pass its own diff)."""
    base = diff.load_snapshot(base_path)
    new = copy.deepcopy(base)
    new["modules"]["fig9"].append(_row("fig9e_sparsity", 77.0))
    res = diff.compare(base, new)
    news = [f for f in res.findings if f.kind == "new"]
    assert [(f.module, f.name, f.new_us) for f in news] == \
        [("fig9", "fig9e_sparsity", 77.0)]
    assert res.regressions == []
    text = diff.render(res)
    assert "fig9e_sparsity" in text and "77.0us" in text
    assert "not in baseline" in text


def test_render_mentions_findings(base_path):
    base = diff.load_snapshot(base_path)
    new = copy.deepcopy(base)
    new["modules"]["fig9"][0]["us_median"] = 200.0
    new["modules"]["fig9"][0]["us_per_call"] = 200.0
    res = diff.compare(base, new)
    text = diff.render(res, base.get("stamp", ""), new.get("stamp", ""))
    assert "fig9a" in text and "regression" in text.lower()


def test_cli_json_report(tmp_path, base_path):
    base = diff.load_snapshot(base_path)
    new = copy.deepcopy(base)
    new["modules"]["fig9"][1]["us_median"] = 500.0
    new["modules"]["fig9"][1]["us_per_call"] = 500.0
    new_path = _snap(tmp_path, "n.json", new["modules"])
    report = str(tmp_path / "report.json")
    rc = diff.main([base_path, new_path, "--json", report])
    assert rc == 1
    with open(report) as f:
        out = json.load(f)
    assert out["compared"] == 3
    assert [x["name"] for x in out["findings"]] == ["fig9b"]
    assert out["findings"][0]["kind"] == "regression"


def test_cli_runs_as_script(base_path):
    """The CI gate invokes the file directly — exit code is the contract."""
    proc = subprocess.run(
        [sys.executable, os.path.join(REPO, "benchmarks", "diff.py"),
         base_path, base_path],
        capture_output=True, text=True, cwd=REPO)
    assert proc.returncode == 0, proc.stderr
    assert "all rows inside the noise band" in proc.stdout


# -- schema v2 plumbing ----------------------------------------------------

def test_timing_sample_is_float_and_carries_samples():
    t = TimingSample([3e-4, 1e-4, 2e-4])
    assert float(t) == pytest.approx(2e-4)        # median
    assert round(t * 1e6, 1) == 200.0             # old call sites unchanged
    assert t.samples == [1e-4, 2e-4, 3e-4]
    stats = sample_fields(t)
    assert stats["us_median"] == pytest.approx(200.0)
    assert stats["us_mad"] == pytest.approx(100.0)
    assert stats["iters"] == 3
    assert sample_fields(2e-4) == {}              # bare floats: no stats


def test_median_mad_odd_even():
    assert median_mad_us([1e-4, 2e-4, 9e-4])["us_median"] \
        == pytest.approx(200.0)
    # even counts take the upper median (index n//2 of the sorted list)
    st = sample_stats([1e-4, 3e-4])
    assert st["us_median"] == pytest.approx(300.0)
    assert st["us_mad"] == pytest.approx(200.0)
    assert st["iters"] == 2


def test_write_snapshot_schema(tmp_path):
    path = str(tmp_path / "sub" / "snap.json")   # dir auto-created
    write_snapshot(path, {"m": [_row("r", 1.0)]}, {"smoke": True})
    snap = diff.load_snapshot(path)
    assert snap["schema"] == SNAPSHOT_SCHEMA
    assert snap["stamp"].endswith("Z") and "T" in snap["stamp"]
    m = snap["machine"]
    assert "device_count" in m and "backend" in m
    assert snap["args"] == {"smoke": True}


def test_fingerprint_fields():
    m = machine_fingerprint()
    for key in ("backend", "device_kind", "device_count", "python", "jax"):
        assert key in m, key
    assert m["device_count"] >= 1
