"""sLSTM Pallas scan kernel vs the model's per-step cell (interpret mode)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.kernels.slstm_scan import expand_blockdiag, slstm_scan_call
from repro.models import xlstm

RNG = np.random.default_rng(0)


def _model_to_kernel_cols(heads: int, hd: int) -> np.ndarray:
    """Column permutation: model head-major [h0:(z|i|f|o), h1:…] →
    kernel gate-major [z(all h) | i | f | o]."""
    d = heads * hd
    perm = np.zeros(4 * d, np.int64)
    for i in range(heads):
        for g in range(4):
            for u in range(hd):
                perm[g * d + i * hd + u] = i * 4 * hd + g * hd + u
    return perm


@pytest.mark.parametrize("b,s,heads,hd", [(2, 12, 4, 16), (3, 9, 2, 8)])
def test_slstm_kernel_matches_cell(b, s, heads, hd):
    d = heads * hd
    cfg = dataclasses.replace(configs.get_smoke_config("xlstm-125m"),
                              d_model=d, n_heads=heads)
    p = xlstm.slstm_init(jax.random.key(0), cfg)
    x = jnp.asarray(RNG.normal(size=(b, s, d)), jnp.float32)
    xp_model = (x @ p["wx"]["w"]).astype(jnp.float32) + p["bias"][None, None]

    # reference: the model's sequential cell
    st = {k: v.astype(jnp.float32)
          for k, v in xlstm.slstm_state_init(cfg, b).items()}
    hs_ref = []
    for t in range(s):
        st = xlstm._slstm_cell(p, xp_model[:, t], st, cfg)
        hs_ref.append(np.asarray(st["h"]).reshape(b, d))
    hs_ref = np.stack(hs_ref, axis=1)

    # kernel: permute inputs to gate-major layout
    perm = _model_to_kernel_cols(heads, hd)
    xp_k = xp_model[:, :, perm]
    wr_k = expand_blockdiag(p["wr"].astype(jnp.float32))
    # wr maps h → head-major gate cols; permute output cols to gate-major
    state0 = dict(h=jnp.zeros((b, d), jnp.float32),
                  c=jnp.zeros((b, d), jnp.float32),
                  n=jnp.ones((b, d), jnp.float32),
                  m=jnp.zeros((b, d), jnp.float32))
    out, stN = slstm_scan_call(xp_k, wr_k, state0, heads=heads, hd=hd,
                               interpret=True)
    np.testing.assert_allclose(np.asarray(out), hs_ref, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(
        np.asarray(stN["h"]), hs_ref[:, -1], rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(
        np.asarray(stN["c"]),
        np.asarray(st["c"]).reshape(b, d), rtol=2e-4, atol=2e-4)


def test_expand_blockdiag_layout():
    heads, hd = 3, 4
    wr = jnp.asarray(RNG.normal(size=(heads, hd, 4 * hd)), jnp.float32)
    big = expand_blockdiag(wr)
    d = heads * hd
    h = jnp.asarray(RNG.normal(size=(2, d)), jnp.float32)
    # reference: per-head einsum then head-major → gate-major reorder
    rec = jnp.einsum("bhd,hdg->bhg", h.reshape(2, heads, hd), wr)
    got = h @ big
    for g in range(4):
        for i in range(heads):
            np.testing.assert_allclose(
                np.asarray(got[:, g * d + i * hd:(g + 1 - 1) * d
                               + i * hd + hd]),
                np.asarray(rec[:, i, g * hd:(g + 1) * hd]),
                rtol=1e-5, atol=1e-5)
