"""MGG pipelined aggregation vs. the dense oracle — single-device unit tests
here; the 8-device shard_map equivalence runs as a subprocess test (the
pytest process must keep seeing exactly one CPU device)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    build_bulk_plan, build_fetch_plan, build_plan, bulk_aggregate,
    edge_balanced_node_split, fetch_rows_aggregate, mgg_aggregate,
    pad_embeddings, pad_table, power_law, reference_aggregate,
    unpad_embeddings, unpad_table, collective_bytes,
)
from repro.dist import flat_ring_mesh


@pytest.fixture(scope="module")
def small():
    g = power_law(220, avg_degree=7.0, locality=0.4, seed=5)
    x = np.random.default_rng(0).normal(
        size=(g.num_nodes, 19)).astype(np.float32)
    return g, x, reference_aggregate(g.indptr, g.indices, x)


@pytest.mark.parametrize("ps,dist,interleave", [
    (4, 1, True), (16, 1, False), (8, 2, True), (3, 4, True),
])
def test_mgg_single_device(small, ps, dist, interleave):
    g, x, want = small
    plan = build_plan(g, 1, ps=ps, dist=dist)
    mesh = flat_ring_mesh(1)
    out = mgg_aggregate(jnp.asarray(pad_embeddings(plan, x)), plan, mesh,
                        interleave=interleave)
    got = unpad_embeddings(plan, np.asarray(out))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_mgg_with_kernel_single_device(small):
    g, x, want = small
    plan = build_plan(g, 1, ps=8)
    mesh = flat_ring_mesh(1)
    out = mgg_aggregate(jnp.asarray(pad_embeddings(plan, x)), plan, mesh,
                        use_kernel=True)
    got = unpad_embeddings(plan, np.asarray(out))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_bulk_and_fetch_single_device(small):
    g, x, want = small
    bounds = edge_balanced_node_split(g.indptr, 1)
    nbrs, mask, tgt, rows = build_bulk_plan(g, 1, ps=16)
    mesh = flat_ring_mesh(1)
    xb = pad_table(bounds, rows, x)
    out = bulk_aggregate(jnp.asarray(xb), nbrs, mask, tgt, rows, mesh)
    np.testing.assert_allclose(unpad_table(bounds, rows, np.asarray(out)),
                               want, rtol=1e-4, atol=1e-4)
    for page in (1, 16):
        fp = build_fetch_plan(g, 1, ps=16, page_rows=page)
        out = fetch_rows_aggregate(
            jnp.asarray(xb), fp["fetch_rows"], fp["nbrs"], fp["mask"],
            fp["targets"], rows)
        got = unpad_table(bounds, rows,
                          np.asarray(out).reshape(-1, x.shape[1]))
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_collective_bytes_model(small):
    g, _, _ = small
    plan = build_plan(g, 4, ps=8, dist=2)
    b = collective_bytes(plan, d_feat=19, itemsize=4)
    assert b == 3 * plan.rows_per_dev * 19 * 4


def test_gradients_flow_through_ring(small):
    g, x, _ = small
    plan = build_plan(g, 1, ps=8)
    mesh = flat_ring_mesh(1)
    xp = jnp.asarray(pad_embeddings(plan, x))

    def f(z):
        return (mgg_aggregate(z, plan, mesh) ** 2).sum()

    grad = jax.grad(f)(xp)
    assert np.isfinite(np.asarray(grad)).all()
    assert float(jnp.abs(grad).sum()) > 0


# The 8-device subprocess scripts (tests/multidev/) run through
# tests/test_system.py::test_multidevice_subprocess — one harness, one place.
