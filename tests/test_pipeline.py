"""MGG pipelined aggregation vs. the dense oracle — single-device unit tests
here; the 8-device shard_map equivalence runs as a subprocess test (the
pytest process must keep seeing exactly one CPU device)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    build_bulk_plan, build_fetch_plan, build_plan, bulk_aggregate,
    edge_balanced_node_split, fetch_rows_aggregate, mgg_aggregate,
    mgg_aggregate_sparse, pad_embeddings, pad_table, power_law,
    reference_aggregate, sparse_collective_bytes, topk_activation,
    topk_decompress, unpad_embeddings, unpad_table, collective_bytes,
    wire_index_dtype,
)
from repro.dist import flat_ring_mesh
from repro.testing.hypo import given, settings, strategies as st


@pytest.fixture(scope="module")
def small():
    g = power_law(220, avg_degree=7.0, locality=0.4, seed=5)
    x = np.random.default_rng(0).normal(
        size=(g.num_nodes, 19)).astype(np.float32)
    return g, x, reference_aggregate(g.indptr, g.indices, x)


@pytest.mark.parametrize("ps,dist,interleave", [
    (4, 1, True), (16, 1, False), (8, 2, True), (3, 4, True),
])
def test_mgg_single_device(small, ps, dist, interleave):
    g, x, want = small
    plan = build_plan(g, 1, ps=ps, dist=dist)
    mesh = flat_ring_mesh(1)
    out = mgg_aggregate(jnp.asarray(pad_embeddings(plan, x)), plan, mesh,
                        interleave=interleave)
    got = unpad_embeddings(plan, np.asarray(out))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_mgg_with_kernel_single_device(small):
    g, x, want = small
    plan = build_plan(g, 1, ps=8)
    mesh = flat_ring_mesh(1)
    out = mgg_aggregate(jnp.asarray(pad_embeddings(plan, x)), plan, mesh,
                        use_kernel=True)
    got = unpad_embeddings(plan, np.asarray(out))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_bulk_and_fetch_single_device(small):
    g, x, want = small
    bounds = edge_balanced_node_split(g.indptr, 1)
    nbrs, mask, tgt, rows = build_bulk_plan(g, 1, ps=16)
    mesh = flat_ring_mesh(1)
    xb = pad_table(bounds, rows, x)
    out = bulk_aggregate(jnp.asarray(xb), nbrs, mask, tgt, rows, mesh)
    np.testing.assert_allclose(unpad_table(bounds, rows, np.asarray(out)),
                               want, rtol=1e-4, atol=1e-4)
    for page in (1, 16):
        fp = build_fetch_plan(g, 1, ps=16, page_rows=page)
        out = fetch_rows_aggregate(
            jnp.asarray(xb), fp["fetch_rows"], fp["nbrs"], fp["mask"],
            fp["targets"], rows)
        got = unpad_table(bounds, rows,
                          np.asarray(out).reshape(-1, x.shape[1]))
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_collective_bytes_model(small):
    g, _, _ = small
    plan = build_plan(g, 4, ps=8, dist=2)
    b = collective_bytes(plan, d_feat=19, itemsize=4)
    assert b == 3 * plan.rows_per_dev * 19 * 4


def test_gradients_flow_through_ring(small):
    g, x, _ = small
    plan = build_plan(g, 1, ps=8)
    mesh = flat_ring_mesh(1)
    xp = jnp.asarray(pad_embeddings(plan, x))

    def f(z):
        return (mgg_aggregate(z, plan, mesh) ** 2).sum()

    grad = jax.grad(f)(xp)
    assert np.isfinite(np.asarray(grad)).all()
    assert float(jnp.abs(grad).sum()) > 0


# ---------------------------------------------------------------------------
# sparsity-aware aggregation: the top-k payload (single-device unit tests;
# the 8-device sparse ring runs in tests/multidev/mgg_sparse.py)
# ---------------------------------------------------------------------------

def _bits(a):
    return np.asarray(a).view(np.uint32)


def test_topk_roundtrip_is_identity_at_full_width():
    """decompress ∘ compress == id at k == D, bitwise — including -0.0,
    which only survives because the decompress scatter is .set (an .add
    against the zero buffer would turn -0.0 into +0.0)."""
    x = np.random.default_rng(1).normal(size=(37, 24)).astype(np.float32)
    x[3, 5] = -0.0
    v, idx = topk_activation(jnp.asarray(x), 24)
    back = topk_decompress(v, idx, 24)
    np.testing.assert_array_equal(_bits(back), x.view(np.uint32))


@given(st.integers(1, 40), st.integers(1, 64), st.integers(0, 999))
@settings(max_examples=25, deadline=None)
def test_topk_decompress_invariant_to_column_permutation(rows, d, seed):
    """Column ids within a row are distinct (top-k guarantee), so every
    output slot is written at most once: any permutation of the compressed
    columns reproduces the bits exactly."""
    rng = np.random.default_rng(seed)
    k = int(rng.integers(1, d + 1))
    x = rng.normal(size=(rows, d)).astype(np.float32)
    v, idx = topk_activation(jnp.asarray(x), k)
    perm = rng.permutation(k)
    a = topk_decompress(v, idx, d)
    b = topk_decompress(v[:, perm], idx[:, perm], d)
    np.testing.assert_array_equal(_bits(a), _bits(b))


def test_wire_index_dtype_picks_narrowest():
    assert wire_index_dtype(602) == jnp.int16      # reddit width fits
    assert wire_index_dtype(32767) == jnp.int16
    assert wire_index_dtype(32768) == jnp.int32


@pytest.mark.parametrize("d", [32767, 32768])
def test_topk_roundtrip_through_wire_dtype_at_boundary(d):
    """The int16→int32 wire boundary: column ids at the top of the width
    (D-1, D-2, ...) must survive the cast to wire dtype and back.  At
    D = 32767 the wire is int16 and the largest id is 32766 (fits); at
    D = 32768 the wire widens to int32.  An off-by-one in either
    direction shows up as values landing in wrapped-around columns."""
    k = 4
    rng = np.random.default_rng(7)
    x = -np.abs(rng.normal(size=(3, d))).astype(np.float32)
    hot = np.array([d - 1, d - 2, d // 2, 0])
    for r in range(3):
        x[r, hot] = np.float32([4.0, 3.0, 2.0, 1.0])
    v, idx = topk_activation(jnp.asarray(x), k)
    wire = idx.astype(wire_index_dtype(d))         # what rides the ring
    assert int(jnp.max(wire)) == d - 1             # no wraparound
    back = topk_decompress(v, wire, d)
    want = np.zeros_like(x)
    for r in range(3):
        want[r, hot] = x[r, hot]
    np.testing.assert_array_equal(_bits(back), want.view(np.uint32))


def test_sparse_collective_bytes_model(small):
    g, _, _ = small
    plan = build_plan(g, 4, ps=8, dist=2)
    dense = collective_bytes(plan, d_feat=96)
    quarter = sparse_collective_bytes(plan, 96, 24)
    # k = D/4 with int16 ids: 24·(4+2) / 96·4 = 0.375 of the dense wire
    assert quarter / dense == pytest.approx(0.375)
    # k == D still pays the index overhead — the model must not pretend
    # compression is free at full width
    assert sparse_collective_bytes(plan, 96, 96) / dense \
        == pytest.approx(1.5)
    assert sparse_collective_bytes(plan, 96, 10 ** 6) \
        == sparse_collective_bytes(plan, 96, 96)      # k clamps to D
    assert sparse_collective_bytes(build_plan(g, 1, ps=8), 96, 24) == 0


def test_sparse_full_width_bitwise_matches_dense(small):
    g, x, _ = small
    plan = build_plan(g, 1, ps=8, dist=2)
    mesh = flat_ring_mesh(1)
    xp = jnp.asarray(pad_embeddings(plan, x))
    d = x.shape[1]
    dense = mgg_aggregate(xp, plan, mesh)
    sparse = mgg_aggregate_sparse(xp, plan, mesh, k=d)
    np.testing.assert_array_equal(_bits(dense), _bits(sparse))
    # fused ·W inside the step keeps the equality
    w = jnp.asarray(np.random.default_rng(2).normal(size=(d, 7))
                    .astype(np.float32))
    np.testing.assert_array_equal(
        _bits(mgg_aggregate(xp, plan, mesh, update_w=w)),
        _bits(mgg_aggregate_sparse(xp, plan, mesh, k=d, update_w=w)))


def test_sparse_below_width_deterministic_and_matches_oracle(small):
    """k < D drops information by design; the contract is that what remains
    is the exact dense aggregation OF the compressed activations — i.e.
    sparse(x) ≡ dense(decompress(compress(x))), bitwise — and that repeated
    calls reproduce the bits (fixed-order Σ, no nondeterministic scatter)."""
    g, x, _ = small
    plan = build_plan(g, 1, ps=8, dist=2)
    mesh = flat_ring_mesh(1)
    xp = jnp.asarray(pad_embeddings(plan, x))
    d, k = x.shape[1], 5
    a = mgg_aggregate_sparse(xp, plan, mesh, k=k)
    b = mgg_aggregate_sparse(xp, plan, mesh, k=k)
    np.testing.assert_array_equal(_bits(a), _bits(b))
    want = mgg_aggregate(topk_decompress(*topk_activation(xp, k), d),
                         plan, mesh)
    np.testing.assert_array_equal(_bits(a), _bits(want))


# The 8-device subprocess scripts (tests/multidev/) run through
# tests/test_system.py::test_multidevice_subprocess — one harness, one place.
