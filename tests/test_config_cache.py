"""ConfigCache concurrency + versioning hardening (PR-5 satellite):

* two processes hammering one cache file must not lose each other's
  entries (the read-modify-write in ``put`` is flock-serialized);
* a schema-version-mismatched (v1) file is discarded with exactly ONE
  RuntimeWarning per path — visible, not silent, not spammy.
"""
import json
import os
import subprocess
import sys
import warnings

import pytest

from repro.core.autotune import WorkloadShape
from repro.runtime.cache import ConfigCache

_SRC = os.path.join(os.path.dirname(__file__), "..", "src")

_HAMMER = r"""
import sys
from repro.core.autotune import WorkloadShape
from repro.runtime.cache import ConfigCache

path, start, n = sys.argv[1], int(sys.argv[2]), int(sys.argv[3])
cache = ConfigCache(path, hw="test:hw:1")
for i in range(start, start + n):
    shape = WorkloadShape(n_dev=1, d_feat=i, rows_per_dev=10,
                          local_edges_max=5, remote_edges_max=5)
    cache.put(shape, dict(ps=1, dist=1, pb=1), 1e-3)
"""


def test_two_processes_hammering_same_file_lose_nothing(tmp_path):
    """Each writer puts N entries under distinct keys; without the lock
    the read-modify-write interleaves and entries vanish."""
    path = str(tmp_path / "tuned.json")
    n = 25
    env = dict(os.environ,
               PYTHONPATH=_SRC + os.pathsep + os.environ.get("PYTHONPATH",
                                                             ""))
    procs = [subprocess.Popen(
        [sys.executable, "-c", _HAMMER, path, str(k * n), str(n)],
        env=env, stdout=subprocess.PIPE, stderr=subprocess.PIPE, text=True)
        for k in range(2)]
    for p in procs:
        _out, err = p.communicate(timeout=600)
        assert p.returncode == 0, err
    cache = ConfigCache(path, hw="test:hw:1")
    assert len(cache) == 2 * n
    for i in range(2 * n):
        shape = WorkloadShape(n_dev=1, d_feat=i, rows_per_dev=10,
                              local_edges_max=5, remote_edges_max=5)
        assert cache.get(shape) == dict(ps=1, dist=1, pb=1), i
    # the file on disk is a single valid current-schema document
    with open(path) as f:
        assert json.load(f)["version"] == 5


def test_version_mismatch_discard_warns_exactly_once(tmp_path):
    path = str(tmp_path / "old.json")
    with open(path, "w") as f:
        json.dump(dict(version=1, entries={"k": dict(
            config=dict(ps=2, dist=1, pb=1))}), f)
    cache = ConfigCache(path, hw="test:hw:1")
    shape = WorkloadShape(n_dev=1, d_feat=3, rows_per_dev=10,
                          local_edges_max=5, remote_edges_max=5)
    with pytest.warns(RuntimeWarning, match="schema version 1"):
        assert cache.get(shape) is None
    # second read of the same path: discarded again, but silently
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        assert cache.get(shape) is None
        assert ConfigCache(path, hw="other:hw:2").get(shape) is None
    assert not [w for w in rec if issubclass(w.category, RuntimeWarning)]
    # a put starts a fresh valid file; entries round-trip again
    cache.put(shape, dict(ps=4, dist=1, pb=1), 1e-3)
    assert cache.get(shape) == dict(ps=4, dist=1, pb=1)


def test_v2_files_discarded_with_one_warning_and_v5_roundtrips_knobs(
        tmp_path):
    """``cap``/``fuse`` (v3), ``k`` (v4) and ``fanout``/``batch`` (v5)
    persist alongside (ps, dist, pb); v2 files read as empty with the
    same single RuntimeWarning per path that v1 files get."""
    path = str(tmp_path / "v2.json")
    shape = WorkloadShape(n_dev=1, d_feat=7, rows_per_dev=10,
                          local_edges_max=5, remote_edges_max=5)
    probe = ConfigCache(path, hw="test:hw:1")
    with open(path, "w") as f:
        json.dump(dict(version=2, entries={
            probe.key(shape): dict(config=dict(ps=2, dist=1, pb=1),
                                   latency=1e-3)}), f)
    with pytest.warns(RuntimeWarning, match="schema version 2"):
        assert probe.get(shape) is None
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        assert probe.get(shape) is None           # warned once already
    assert not [w for w in rec if issubclass(w.category, RuntimeWarning)]
    # v5 round-trips the full knob set, global and per-layer
    probe.put(shape, dict(ps=4, dist=2, pb=1, cap=128, k=16,
                          fanout=8, batch=256), 1e-3)
    assert probe.get(shape) == dict(ps=4, dist=2, pb=1, cap=128, k=16,
                                    fanout=8, batch=256)
    cfgs = [dict(ps=8, dist=1, pb=1, cap=64, fuse=True),
            dict(ps=2, dist=1, pb=1, cap=64, k=32, fuse=False),
            dict(ps=2, dist=1, pb=1, fanout=4, batch=128)]
    shapes = [shape, shape.with_d_feat(3), shape.with_d_feat(5)]
    probe.put_layers(shapes, cfgs, 2e-3)
    assert probe.get_layers(shapes) == cfgs
    with open(path) as f:
        doc = json.load(f)
    assert doc["version"] == 5
    # plain (ps, dist, pb) entries stay exactly three knobs on disk
    probe.put(shape.with_d_feat(9), dict(ps=1, dist=1, pb=1), 1e-3)
    assert probe.get(shape.with_d_feat(9)) == dict(ps=1, dist=1, pb=1)


def test_lock_sidecar_does_not_break_atomic_replace(tmp_path):
    """Writes keep going through tmp-file + os.replace; the lock is a
    sidecar, never the data file itself."""
    path = str(tmp_path / "tuned.json")
    cache = ConfigCache(path, hw="test:hw:1")
    shape = WorkloadShape(n_dev=1, d_feat=1, rows_per_dev=10,
                          local_edges_max=5, remote_edges_max=5)
    cache.put(shape, dict(ps=1, dist=1, pb=1), 1e-3)
    names = set(os.listdir(tmp_path))
    assert "tuned.json" in names
    assert not any(n.endswith(".tmp") for n in names)
