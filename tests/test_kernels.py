"""Pallas kernel validation: shape/dtype sweeps + hypothesis cases against
the pure-jnp oracle (interpret mode on CPU), both kernel variants, and the
custom VJP."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from repro.testing.hypo import given, settings, strategies as st

from repro.kernels import ops, ref
from repro.kernels.neighbor_agg import (
    gather_sum_blocked_call, gather_sum_pipelined_call,
)

SHAPES = [
    # (T, D, P, ps)
    (16, 8, 4, 1),
    (64, 32, 20, 4),
    (128, 130, 33, 7),     # non-lane-aligned D, odd P/ps
    (256, 602, 100, 16),   # reddit embedding dim
    (32, 128, 5, 32),
    (512, 96, 257, 3),
]


def _case(t, d, p, ps, dtype, seed=0):
    rng = np.random.default_rng(seed)
    buf = rng.normal(size=(t, d)).astype(dtype)
    nbrs = rng.integers(0, t, size=(p, ps)).astype(np.int32)
    mask = rng.random((p, ps)) < 0.7
    return jnp.asarray(buf), jnp.asarray(nbrs), jnp.asarray(mask)


@pytest.mark.parametrize("t,d,p,ps", SHAPES)
@pytest.mark.parametrize("dtype", [np.float32, np.dtype(jnp.bfloat16)])
@pytest.mark.parametrize("pb", [None, 4])
def test_gather_sum_matches_oracle(t, d, p, ps, dtype, pb):
    buf, nbrs, mask = _case(t, d, p, ps, dtype)
    want = ref.neighbor_gather_sum_ref(buf, nbrs, mask)
    got = ops.neighbor_gather_sum(buf, nbrs, mask, pb=pb)
    # rtol admits fp32 reassociation between kernel and oracle (≤1 ulp of
    # the running sum at ps=32)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-6)


@given(st.integers(1, 64), st.integers(1, 200), st.integers(1, 40),
       st.integers(1, 12), st.integers(0, 1000))
@settings(max_examples=25, deadline=None)
def test_gather_sum_hypothesis(t, d, p, ps, seed):
    buf, nbrs, mask = _case(t, d, p, ps, np.float32, seed)
    want = ref.neighbor_gather_sum_ref(buf, nbrs, mask)
    got = ops.neighbor_gather_sum(buf, nbrs, mask)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_raw_kernel_variants_agree():
    buf, nbrs, mask = _case(64, 256, 24, 8, np.float32)
    maski = mask.astype(jnp.int32)
    a = gather_sum_pipelined_call(buf, nbrs, maski, db=128, interpret=True)
    b = gather_sum_blocked_call(buf, nbrs, maski, pb=4, db=128,
                                interpret=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)


def test_vjp_matches_oracle_grad():
    buf, nbrs, mask = _case(48, 20, 15, 5, np.float32)
    co = jnp.asarray(
        np.random.default_rng(1).normal(size=(15, 20)).astype(np.float32))
    g1 = jax.grad(lambda b: (ops.neighbor_gather_sum(b, nbrs, mask) * co)
                  .sum())(buf)
    g2 = jax.grad(lambda b: (ref.neighbor_gather_sum_ref(b, nbrs, mask) * co)
                  .sum())(buf)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), rtol=1e-5,
                               atol=1e-6)


def test_vmem_fallback_on_big_stripe():
    # huge row count forces the blocked variant to fall back to pipelined
    buf, nbrs, mask = _case(2 ** 15, 256, 8, 2, np.float32)
    got = ops.neighbor_gather_sum(buf, nbrs, mask, pb=8)
    want = ref.neighbor_gather_sum_ref(buf, nbrs, mask)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)


def test_all_masked_is_zero():
    buf, nbrs, _ = _case(16, 8, 4, 3, np.float32)
    mask = jnp.zeros((4, 3), bool)
    got = ops.neighbor_gather_sum(buf, nbrs, mask)
    assert np.allclose(np.asarray(got), 0.0)


# ---------------------------------------------------------------------------
# sparse (top-k payload) kernel + row-gather kernel
# ---------------------------------------------------------------------------

from repro.core import topk_activation, topk_decompress  # noqa: E402


def _sparse_case(t, d, p, ps, k, seed=0):
    buf, nbrs, mask = _case(t, d, p, ps, np.float32, seed)
    v, idx = topk_activation(buf, k)
    return v, idx, nbrs, mask, buf


@pytest.mark.parametrize("t,d,p,ps,k", [
    (16, 8, 4, 1, 8),       # k == D: kernel sees the full row
    (64, 32, 20, 4, 8),
    (128, 130, 33, 7, 13),  # non-lane-aligned D and k
    (256, 602, 100, 16, 150),
    (512, 96, 257, 3, 24),
])
def test_sparse_gather_sum_matches_oracle(t, d, p, ps, k):
    v, idx, nbrs, mask, _ = _sparse_case(t, d, p, ps, k)
    want = ref.neighbor_gather_sum_ref(topk_decompress(v, idx, d),
                                       nbrs, mask)
    got = ops.sparse_neighbor_gather_sum(v, idx, nbrs, mask, d_feat=d)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-6)


@given(st.integers(1, 64), st.integers(1, 200), st.integers(1, 40),
       st.integers(1, 12), st.integers(0, 1000))
@settings(max_examples=15, deadline=None)
def test_sparse_gather_sum_hypothesis(t, d, p, ps, seed):
    k = 1 + seed % d
    v, idx, nbrs, mask, _ = _sparse_case(t, d, p, ps, k, seed)
    want = ref.neighbor_gather_sum_ref(topk_decompress(v, idx, d),
                                       nbrs, mask)
    got = ops.sparse_neighbor_gather_sum(v, idx, nbrs, mask, d_feat=d)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_sparse_vjp_matches_decompressed_grad():
    """d/d values of the kernel path == the chain rule through
    decompress → dense oracle (the column ids are non-differentiable)."""
    v, idx, nbrs, mask, _ = _sparse_case(48, 20, 15, 5, 7, seed=3)
    co = jnp.asarray(
        np.random.default_rng(1).normal(size=(15, 20)).astype(np.float32))
    g1 = jax.grad(lambda a: (ops.sparse_neighbor_gather_sum(
        a, idx, nbrs, mask, d_feat=20) * co).sum())(v)
    g2 = jax.grad(lambda a: (ref.neighbor_gather_sum_ref(
        topk_decompress(a, idx, 20), nbrs, mask) * co).sum())(v)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2),
                               rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("t,d,n", [(16, 8, 5), (64, 130, 200), (7, 602, 7)])
def test_gather_rows_bitwise_matches_indexing(t, d, n):
    """The tiered-store assembly kernel is a pure copy: out[i] = src[idx[i]]
    bit for bit, repeats and all."""
    rng = np.random.default_rng(4)
    src = jnp.asarray(rng.normal(size=(t, d)).astype(np.float32))
    idx = jnp.asarray(rng.integers(0, t, size=n).astype(np.int32))
    got = ops.gather_rows(src, idx)
    np.testing.assert_array_equal(
        np.asarray(got).view(np.uint32),
        np.asarray(src)[np.asarray(idx)].view(np.uint32))
