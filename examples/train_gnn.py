"""End-to-end full-graph GCN training on the MGG engine (paper §5 setting:
2-layer GCN, 16 hidden) over an 8-way ring, with checkpoint/restart and the
paper's §4 intelligent runtime:

    PYTHONPATH=src python examples/train_gnn.py [--steps 100] [--model gin]
        [--dynamic-tune] [--per-layer-tune] [--fuse-update] [--tune-fuse]
        [--tune-cache /tmp/mgg_tuned.json]

``--dynamic-tune`` wraps the engine in repro.runtime.DynamicGNNEngine:
every training iteration's wall time feeds the online ps → dist → wpb
search, and whenever the tuner moves, the aggregation plan is rebuilt and
the step re-jitted — model parameters never change, so the loss curve is
the same one the static engine would produce config-for-config.
``--per-layer-tune`` lifts the search to one config per GNN layer
(PerLayerTuner over the model's aggregation widths, warm-started from the
global optimum); ``--fuse-update`` runs each layer's dense ·W update
inside the ring (fused with the tile transfers).  ``--tune-cache``
persists the converged config(s) keyed by workload shape + hardware, so
the next run warm-starts from it.

``--sample-fanout F`` switches to the sampled mini-batch path
(GraphSAGE only): fanout-bounded k-hop blocks (repro.sample) over a
tiered feature store — the per-step working set is bounded by
``batch * (F + 1) ** layers`` rows regardless of graph size.
``--sample-batch`` sets the seed mini-batch size; with
``--dynamic-tune``, fanout and batch become tuner knobs (climbed over
{F, 2F} × {B, 2B} on per-seed step latency) and the loop adopts the
tuned values live.
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import argparse
import tempfile
import time

import numpy as np
import jax
import jax.numpy as jnp

import repro.core as C
from repro.dist import flat_ring_mesh
from repro.obs import MetricsRegistry, Tracer
from repro.runtime import DynamicGNNEngine, ProfileConfig
from repro.train.data import graph_features
from repro.train.optimizer import AdamWConfig, adamw_init, adamw_update
from repro.train import checkpoint as ck


def run_sampled(args, g, x, y, train_mask, dim, ncls, mesh,
                tracer, registry):
    """Sampled mini-batch GraphSAGE: fanout-bounded blocks over the
    tiered store.  Fixed-shape blocks ⇒ one jit compile per (fanout,
    batch); the dynamic tuner (when on) climbs exactly those two knobs
    on per-seed step latency and the loop adopts its moves live."""
    from repro.sample import block_tree, sample_blocks, seed_batches
    from repro.store import FeatureStore, TieredFeatures

    init, _, kw = C.MODEL_ZOO["sage"]
    params = init(jax.random.key(0), dim, ncls, **kw)
    n_layers = len(params["layers"])
    fanout, batch = args.sample_fanout, args.sample_batch

    eng = None
    if args.dynamic_tune:
        # schedule knobs pinned (the ring plan is idle here — blocks
        # aggregate locally); the search space is the sampling geometry
        eng = DynamicGNNEngine.build(
            g, mesh, d_feat=dim,
            ps_space=(8,), dist_space=(1,), pb_space=(1,),
            fanout_space=(fanout, 2 * fanout),
            batch_space=(batch, 2 * batch),
            window=ProfileConfig(warmup=1, iters=2),
            cache_path=args.tune_cache or None, log_fn=print,
            tracer=tracer, metrics=registry)
        fanout = eng.sample_fanout or fanout
        batch = eng.sample_batch or batch

    store = FeatureStore(x)
    cap = args.feature_capacity if args.feature_capacity >= 0 \
        else g.num_nodes // 8
    tiers = TieredFeatures(store, None, capacity=cap)
    if cap:
        # degree order ≈ the Zipfian head: hubs land in most samples
        tiers.admit(np.argsort(-np.diff(g.indptr))[:cap])

    opt = adamw_init(params)
    ocfg = AdamWConfig(lr=5e-3, warmup_steps=5, total_steps=args.steps,
                       weight_decay=0.0)

    @jax.jit
    def step(params, opt, h0, btree, yb, mb):
        def loss_fn(p):
            logits = C.apply_blocks("sage", p, h0, btree)
            return C.masked_cross_entropy(logits, yb, mb)
        loss, grads = jax.value_and_grad(loss_fn)(params)
        params, opt, _m = adamw_update(grads, opt, params, ocfg)
        return params, opt, loss

    rng = np.random.default_rng(0)
    train_ids = np.nonzero(train_mask)[0]

    def minibatches():
        while True:   # resample EVERY epoch — new draw, new neighbors
            yield from seed_batches(train_ids, batch, rng=rng)

    batches = minibatches()
    for i in range(args.steps):
        seeds, valid = next(batches)
        t0 = time.perf_counter()
        blocks = sample_blocks(g, seeds, [fanout] * n_layers,
                               batch=batch, rng=rng)
        h0 = tiers.gather_rows(blocks[0].src_ids)
        yb = jnp.asarray(y[np.clip(seeds, 0, None)].astype(np.int32))
        params, opt, loss = step(params, opt, h0, block_tree(blocks),
                                 yb, jnp.asarray(valid))
        jax.block_until_ready(loss)
        dt = time.perf_counter() - t0
        if tracer is not None:
            tracer.complete("train.sampled_step", t0, t0 + dt, cat="train",
                            args={"step": i, "fanout": fanout,
                                  "batch": batch})
        registry.histogram("train.step_seconds").observe(dt)
        if eng is not None and eng.observe_step(dt / batch):
            # per-seed latency drives the climb; adopt the tuned geometry
            # (a batch move re-jits by shape, params are untouched)
            fanout = eng.sample_fanout or fanout
            batch = eng.sample_batch or batch
            batches = minibatches()
        if i % 10 == 0:
            print(f"step {i:4d} loss {float(loss):.4f} "
                  f"(fanout {fanout}, batch {batch})")

    # sampled inference over the held-out nodes, same block machinery
    test_ids = np.nonzero(~train_mask)[0]
    correct = total = 0
    for seeds, valid in seed_batches(test_ids, batch, rng=rng,
                                     shuffle=False):
        blocks = sample_blocks(g, seeds, [fanout] * n_layers,
                               batch=batch, rng=rng)
        logits = C.apply_blocks("sage", params,
                                tiers.gather_rows(blocks[0].src_ids),
                                block_tree(blocks))
        pred = np.asarray(logits).argmax(-1)
        live = valid > 0
        correct += int((pred[live] == y[seeds[live]]).sum())
        total += int(live.sum())
    rep = tiers.report()
    print(f"final loss {float(loss):.4f}; "
          f"sampled test acc {correct / max(1, total):.3f}")
    print(f"tiered store: cap {rep['capacity']} rows, hit rate "
          f"{rep['hit_rate']:.3f}, "
          f"{rep['host_rows_streamed']} host rows streamed")
    if eng is not None:
        print(f"tuned config: {eng.config} after "
              f"{eng.tuner.measured} measurements")
    if args.metrics_json:
        audit = eng.audit if eng is not None else []
        registry.dump_json(args.metrics_json, extra={"audit": audit})
        print(f"metrics snapshot: {args.metrics_json}")
    if tracer is not None:
        tracer.dump_chrome(args.trace)
        print(f"chrome trace: {args.trace} ({len(tracer)} events "
              f"— open in ui.perfetto.dev)")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--model", default="gcn",
                    choices=["gcn", "gin", "sage", "gat"])
    ap.add_argument("--dataset", default="products")
    ap.add_argument("--workdir", default="")
    ap.add_argument("--dynamic-tune", action="store_true",
                    help="online cross-iteration (ps, dist, pb) tuning")
    ap.add_argument("--per-layer-tune", action="store_true",
                    help="tune one (ps, dist, pb) per GNN layer "
                         "(implies --dynamic-tune)")
    ap.add_argument("--fuse-update", action="store_true",
                    help="run the dense ·W update inside the ring")
    ap.add_argument("--tune-fuse", action="store_true",
                    help="let the per-layer tuner probe flipping each "
                         "layer's fused-update dataflow (implies "
                         "--per-layer-tune)")
    ap.add_argument("--tune-cache", default="",
                    help="JSON path persisting tuned configs across runs")
    ap.add_argument("--sample-fanout", type=int, default=0,
                    help="train on fanout-bounded sampled mini-batch "
                         "blocks instead of the full graph (sage only; "
                         "0 = full-graph)")
    ap.add_argument("--sample-batch", type=int, default=128,
                    help="seed mini-batch size for --sample-fanout")
    ap.add_argument("--feature-capacity", type=int, default=-1,
                    help="device hot-cache rows for the sampled path's "
                         "tiered store (-1 = num_nodes // 8)")
    ap.add_argument("--trace", default="", metavar="PATH",
                    help="write a Chrome-trace JSON (per-step spans + "
                         "tuner audit events — open in ui.perfetto.dev)")
    ap.add_argument("--metrics-json", default="", metavar="PATH",
                    help="write the metrics snapshot + tuner audit trail")
    args = ap.parse_args()
    args.per_layer_tune = args.per_layer_tune or args.tune_fuse
    args.dynamic_tune = args.dynamic_tune or args.per_layer_tune

    tracer = Tracer() if args.trace else None
    registry = MetricsRegistry()

    g, meta = C.paper_dataset(args.dataset, scale=0.5)
    # demo-friendly label space (the full #Class makes a 100-step CPU demo
    # unconvincing; benchmarks/table5 runs the accuracy study properly)
    ncls = min(int(meta["classes"]), 10)
    dim = min(int(meta["dim"]), 64)
    x, y, train_mask = graph_features(g.num_nodes, dim, ncls, seed=0)

    mesh = flat_ring_mesh(len(jax.devices()))
    if args.sample_fanout:
        if args.model != "sage":
            ap.error("--sample-fanout requires --model sage "
                     "(block aggregation is GraphSAGE-only)")
        run_sampled(args, g, x, y, train_mask, dim, ncls, mesh,
                    tracer, registry)
        return
    init, apply, kw = C.MODEL_ZOO[args.model]
    params = init(jax.random.key(0), dim, ncls, **kw)

    if args.dynamic_tune:
        layer_dims = C.aggregation_widths(args.model, params,
                                          fused=args.fuse_update) \
            if args.per_layer_tune else None
        eng = DynamicGNNEngine.build(
            g, mesh, d_feat=dim,
            ps_space=(1, 2, 4, 8, 16, 32), dist_space=(1, 2, 4),
            pb_space=(1, 2, 4),
            window=ProfileConfig(warmup=1, iters=2),
            cache_path=args.tune_cache or None,
            fuse_update=args.fuse_update,
            tune_fuse=args.tune_fuse,
            layer_dims=layer_dims,
            log_fn=print,
            tracer=tracer, metrics=registry,
        )
    else:
        eng = C.GNNEngine.build(g, mesh, ps=16, dist=2,
                                fuse_update=args.fuse_update)
    opt = adamw_init(params)
    ocfg = AdamWConfig(lr=5e-3, warmup_steps=5, total_steps=args.steps,
                       weight_decay=0.0)

    def prepare():
        """Pad node tables for the CURRENT plan (layout changes with dist)."""
        pad1 = lambda a: C.pad_table(eng.plan.bounds, eng.plan.rows_per_dev,
                                     a[:, None])[:, 0]
        xp = eng.shard(eng.pad(x))
        yp = jnp.asarray(pad1(y.astype(np.int32)))
        mp = jnp.asarray(pad1(train_mask.astype(np.float32)))

        @jax.jit
        def step(params, opt):
            loss, grads = jax.value_and_grad(lambda p: C.masked_cross_entropy(
                apply(p, eng, xp), yp, mp))(params)
            params, opt, m = adamw_update(grads, opt, params, ocfg)
            return params, opt, loss

        return xp, step

    xp, step = prepare()
    workdir = args.workdir or tempfile.mkdtemp(prefix="gnn_ckpt_")
    for i in range(args.steps):
        t0 = time.perf_counter()
        params, opt, loss = step(params, opt)
        jax.block_until_ready(loss)
        dt = time.perf_counter() - t0
        if tracer is not None:
            # the timing exists regardless — tracing just records it, so
            # the loss curve is bitwise-identical with tracing on or off
            tracer.complete("train.step", t0, t0 + dt, cat="train",
                            args={"step": i})
        registry.histogram("train.step_seconds").observe(dt)
        if args.dynamic_tune and eng.observe_step(dt):
            # tuner moved: the plan (and possibly the padded layout)
            # changed — re-pad and re-jit; params are untouched
            xp, step = prepare()
        if i % 10 == 0:
            print(f"step {i:4d} loss {float(loss):.4f}")
        if (i + 1) % 50 == 0:
            ck.save(workdir, i + 1, dict(params=params))
    logits = C.unpad_embeddings(eng.plan, np.asarray(apply(params, eng, xp)))
    pred = logits.argmax(-1)
    test = ~train_mask
    print(f"final loss {float(loss):.4f}; "
          f"test acc {(pred[test] == y[test]).mean():.3f}; "
          f"checkpoints in {workdir}")
    if args.dynamic_tune:
        print(f"tuned config: {eng.config} after "
              f"{eng.tuner.measured} measurements "
              f"({len(eng.history) - 1} swaps)")
    if args.metrics_json:
        audit = eng.audit if args.dynamic_tune else []
        registry.dump_json(args.metrics_json, extra={"audit": audit})
        print(f"metrics snapshot: {args.metrics_json}")
    if tracer is not None:
        tracer.dump_chrome(args.trace)
        print(f"chrome trace: {args.trace} ({len(tracer)} events "
              f"— open in ui.perfetto.dev)")


if __name__ == "__main__":
    main()
