"""Batched serving demo: prefill + wave-scheduled decode over batch slots.

    PYTHONPATH=src python examples/serve_lm.py [--arch codeqwen1.5-7b]
"""
import argparse

import numpy as np
import jax

from repro import configs
from repro.models import transformer as T
from repro.serve import ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="codeqwen1.5-7b")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.8)
    args = ap.parse_args()

    cfg = configs.get_smoke_config(args.arch)  # CPU demo: reduced config
    params = T.init_params(jax.random.key(0), cfg, vocab_multiple=4)
    eng = ServeEngine(params, cfg, batch_slots=4, max_seq=128)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(1, cfg.vocab, size=rng.integers(2, 9))
               .astype(np.int32) for _ in range(args.requests)]
    import time
    t0 = time.perf_counter()
    results = eng.generate(prompts, max_new=args.max_new,
                           temperature=args.temperature)
    dt = time.perf_counter() - t0
    total = sum(r.steps for r in results)
    for i, r in enumerate(results):
        print(f"req {i}: prompt_len={r.prompt_len} -> {r.tokens}")
    print(f"{total} tokens in {dt:.2f}s "
          f"({total/dt:.1f} tok/s wave-batched on CPU)")


if __name__ == "__main__":
    main()
