"""Quickstart: MGG pipelined aggregation on an 8-way device ring.

    PYTHONPATH=src python examples/quickstart.py
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import numpy as np
import jax
import jax.numpy as jnp

import repro.core as C
from repro.dist import flat_ring_mesh


def main():
    # 1. a power-law graph (reddit-like structure, scaled down)
    g, meta = C.paper_dataset("reddit", scale=0.25)
    print(f"graph: {g.num_nodes} nodes, {g.num_edges} edges "
          f"(stand-in for reddit @ {meta['real_nodes']} nodes)")

    # 2. MGG preprocessing: edge-balanced node split → locality split →
    #    fixed-size neighbor partitions → ring-step bucketing
    n_dev = len(jax.devices())
    plan = C.build_plan(g, n_dev, ps=16, dist=2)
    print(f"plan: {n_dev} devices × {plan.rows_per_dev} rows, "
          f"ps={plan.ps}, dist={plan.dist}, stats={plan.stats()}")

    # 3. the PGAS embedding table, sharded over the ring
    x = np.random.default_rng(0).normal(
        size=(g.num_nodes, 64)).astype(np.float32)
    mesh = flat_ring_mesh(n_dev)
    xp = jnp.asarray(C.pad_embeddings(plan, x))

    # 4. pipelined aggregation (ppermute ring, double-buffered) vs oracle
    out = C.mgg_aggregate(xp, plan, mesh, interleave=True)
    got = C.unpad_embeddings(plan, np.asarray(out))
    want = C.reference_aggregate(g.indptr, g.indices, x)
    print("max |err| vs dense oracle:", np.abs(got - want).max())

    # 5. the autotuner (paper §4) on the analytical model
    w = C.WorkloadShape.from_graph(g, n_dev, 64)
    res = C.cross_iteration_optimize(
        lambda ps, dist, pb: C.estimate_latency(w, ps, dist, pb))
    print(f"autotuned knobs: {res.best} in {res.num_trials} trials "
          f"(modeled latency {res.best_latency*1e6:.1f} us)")


if __name__ == "__main__":
    main()
