"""End-to-end LM pretraining driver: any assigned --arch, fault-tolerant
Trainer (checkpoint/restart, straggler watchdog), synthetic shardable data.

Default: xlstm-125m (125M params — the "~100M model" e2e deliverable) for a
few hundred steps.  --smoke uses the reduced config for a fast run.

    PYTHONPATH=src python examples/train_lm.py --steps 300 --seq 128 --batch 4
    PYTHONPATH=src python examples/train_lm.py --arch granite-moe-1b-a400m --smoke

``--tune-accum`` turns gradient-accumulation depth into an online-tuned
knob: the same :class:`repro.runtime.OnlineTuner` that drives the GNN
aggregation search runs a 1-D search over ``accum_steps`` on measured
step times, swapping re-jitted step functions through the generic
``Trainer(tune_cb=...)`` hook — the ROADMAP's knob-agnostic proof point.
"""
import argparse
import dataclasses

import jax
import jax.numpy as jnp

from repro import configs
from repro.models import transformer as T
from repro.runtime import LatencyWindow, OnlineTuner, ProfileConfig
from repro.train import (AdamWConfig, LMDataConfig, Trainer, TrainState,
                         adamw_init, lm_batch, make_train_step)


def make_accum_tuner(build_step_fn, batch: int, *,
                     space=(1, 2, 4, 8), budget=None, log=print):
    """A ``Trainer(tune_cb=...)`` callback tuning ``accum_steps`` online.

    The OnlineTuner is knob-agnostic — here its first axis carries the
    accumulation depth (the other two are trivial), measurements are
    median step times from a LatencyWindow, and every tuner move returns
    a freshly jitted step function for the Trainer to swap in.
    """
    space = tuple(a for a in space if batch % a == 0 and a <= batch)
    tuner = OnlineTuner(ps_space=space, dist_space=(1,), pb_space=(1,),
                        budget=budget)
    window = LatencyWindow(ProfileConfig(warmup=1, iters=2))
    state = dict(accum=tuner.propose()["ps"])

    def tune_cb(dt, step):
        if tuner.converged:
            return None
        window.add(dt)
        if not window.ready:
            return None
        lat = window.value()
        window.reset()
        tuner.observe(lat)
        cfg = tuner.propose()
        accum = int(cfg["ps"]) if cfg is not None else state["accum"]
        if tuner.converged:
            log(f"[tune-accum] converged after {tuner.measured} "
                f"measurements: accum_steps={accum} "
                f"({tuner.best_latency * 1e3:.1f} ms)")
        if accum == state["accum"]:
            return None
        log(f"[tune-accum] step {step}: accum_steps "
            f"{state['accum']} → {accum}")
        state["accum"] = accum
        return build_step_fn(accum)

    return tuner, state, tune_cb


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="xlstm-125m")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--tune-accum", action="store_true",
                    help="online-tune accum_steps via Trainer(tune_cb=...)")
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--workdir", default="/tmp/lm_ckpt")
    args = ap.parse_args()

    cfg = (configs.get_smoke_config(args.arch) if args.smoke
           else configs.get_config(args.arch))
    if cfg.family == "encdec":
        raise SystemExit("use whisper via repro.models.encdec directly")
    cfg = dataclasses.replace(cfg, ssm_chunk=min(cfg.ssm_chunk, args.seq))
    print(f"arch={cfg.name} params≈{cfg.param_count()/1e6:.1f}M "
          f"seq={args.seq} batch={args.batch}")
    params = T.init_params(jax.random.key(0), cfg, vocab_multiple=16)
    opt = adamw_init(params)
    ocfg = AdamWConfig(lr=args.lr, warmup_steps=20, total_steps=args.steps)

    def build_step_fn(accum: int):
        return jax.jit(make_train_step(cfg, T.DistCtx(), ocfg,
                                       accum_steps=accum))

    tuner, tune_state, tune_cb = None, None, None
    if args.tune_accum:
        tuner, tune_state, tune_cb = make_accum_tuner(
            build_step_fn, args.batch)
        args.accum = tune_state["accum"]
    step_fn = build_step_fn(args.accum)
    dcfg = LMDataConfig(vocab=cfg.vocab, seq_len=args.seq,
                        global_batch=args.batch, doc_len=args.seq)

    def data_it():
        s = 0
        while True:
            b = lm_batch(dcfg, s,
                         n_vis=cfg.n_vis_tokens if cfg.family == "vlm" else 0,
                         d_model=cfg.d_model)
            yield {k: jnp.asarray(v) for k, v in b.items()}
            s += 1

    tr = Trainer(step_fn, data_it(), TrainState(params, opt),
                 workdir=args.workdir, ckpt_every=50, log_every=10,
                 tune_cb=tune_cb)
    tr.maybe_restore()
    losses = tr.run(args.steps)
    print(f"done: loss {losses[0]:.4f} -> {losses[-1]:.4f}; "
          f"stragglers={tr.stragglers} restarts={tr.restarts}")
    if args.tune_accum:
        print(f"tuned accum_steps={tune_state['accum']} "
              f"after {tuner.measured} measurements "
              f"({tr.retunes} step-fn swaps)")


if __name__ == "__main__":
    main()
