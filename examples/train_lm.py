"""End-to-end LM pretraining driver: any assigned --arch, fault-tolerant
Trainer (checkpoint/restart, straggler watchdog), synthetic shardable data.

Default: xlstm-125m (125M params — the "~100M model" e2e deliverable) for a
few hundred steps.  --smoke uses the reduced config for a fast run.

    PYTHONPATH=src python examples/train_lm.py --steps 300 --seq 128 --batch 4
    PYTHONPATH=src python examples/train_lm.py --arch granite-moe-1b-a400m --smoke
"""
import argparse
import dataclasses

import jax
import jax.numpy as jnp

from repro import configs
from repro.models import transformer as T
from repro.train import (AdamWConfig, LMDataConfig, Trainer, TrainState,
                         adamw_init, lm_batch, make_train_step)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="xlstm-125m")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--workdir", default="/tmp/lm_ckpt")
    args = ap.parse_args()

    cfg = (configs.get_smoke_config(args.arch) if args.smoke
           else configs.get_config(args.arch))
    if cfg.family == "encdec":
        raise SystemExit("use whisper via repro.models.encdec directly")
    cfg = dataclasses.replace(cfg, ssm_chunk=min(cfg.ssm_chunk, args.seq))
    print(f"arch={cfg.name} params≈{cfg.param_count()/1e6:.1f}M "
          f"seq={args.seq} batch={args.batch}")
    params = T.init_params(jax.random.key(0), cfg, vocab_multiple=16)
    opt = adamw_init(params)
    step_fn = jax.jit(make_train_step(
        cfg, T.DistCtx(),
        AdamWConfig(lr=args.lr, warmup_steps=20, total_steps=args.steps),
        accum_steps=args.accum))
    dcfg = LMDataConfig(vocab=cfg.vocab, seq_len=args.seq,
                        global_batch=args.batch, doc_len=args.seq)

    def data_it():
        s = 0
        while True:
            b = lm_batch(dcfg, s,
                         n_vis=cfg.n_vis_tokens if cfg.family == "vlm" else 0,
                         d_model=cfg.d_model)
            yield {k: jnp.asarray(v) for k, v in b.items()}
            s += 1

    tr = Trainer(step_fn, data_it(), TrainState(params, opt),
                 workdir=args.workdir, ckpt_every=50, log_every=10)
    tr.maybe_restore()
    losses = tr.run(args.steps)
    print(f"done: loss {losses[0]:.4f} -> {losses[-1]:.4f}; "
          f"stragglers={tr.stragglers} restarts={tr.restarts}")


if __name__ == "__main__":
    main()
