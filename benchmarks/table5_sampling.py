"""Paper Table 5: accuracy/latency tradeoff, full-graph vs sampled GraphSAGE.

Synthetic node classification (class-dependent feature means + homophilous
edges): train a 2-layer GraphSAGE full-graph on the ring engine, then train
the same model on the sampled mini-batch path — fanout-bounded k-hop blocks
(repro.sample) over a planless tiered feature store, with neighbors
REDRAWN every epoch (sampling as a training-time estimator, not a one-shot
static sparsification of the graph).  Compare test accuracy and per-epoch
latency.  Paper: small accuracy edge for full-graph at a latency premium;
here the sampled epoch touches only ``train_seeds * (fanout + 1) ** layers``
rows, so it wins on latency while the per-epoch redraw keeps the accuracy
gap small.

``--smoke`` (wired into ``benchmarks/run.py --smoke`` → CI) shrinks the
graph/epoch counts and *asserts* that the sampled epoch is faster than the
full-graph epoch — the headline claim of the sampled path — so the
benchmark cannot rot silently.
"""
from __future__ import annotations

import sys
import time

from benchmarks._common import (TimingSample, emit, force_devices_from_env,
                                sample_fields, timeit)

force_devices_from_env()

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

import repro.core as C  # noqa: E402
from repro.dist import flat_ring_mesh  # noqa: E402
from repro.sample import block_tree, sample_blocks, seed_batches  # noqa: E402
from repro.store import FeatureStore, TieredFeatures  # noqa: E402
from repro.train.optimizer import (AdamWConfig, adamw_init,  # noqa: E402
                                   adamw_update)


def _homophilous(n, ncls, deg, seed=0):
    """Random graph whose edges prefer same-class endpoints (70%).

    The same-class redraw is vectorized: nodes grouped by label via one
    stable argsort, then every edge draws a random member of its dst's
    class in one gather (the old per-edge Python loop was O(n*deg)
    interpreter time and dominated the benchmark's setup).
    """
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, ncls, n)
    dst = np.repeat(np.arange(n), deg)
    src = rng.integers(0, n, len(dst))
    same = rng.random(len(dst)) < 0.7  # homophily: mostly same-class edges
    order = np.argsort(labels, kind="stable")  # nodes grouped by class
    counts = np.bincount(labels, minlength=ncls)
    assert counts.min() > 0, "every class needs at least one member"
    starts = np.concatenate(([0], np.cumsum(counts)[:-1]))
    ld = labels[dst]
    src_same = order[starts[ld] + rng.integers(0, counts[ld])]
    src = np.where(same, src_same, src)
    from repro.core.graph import _from_edges
    return _from_edges(dst.astype(np.int64), src.astype(np.int64), n), labels


def _features(y, dim, seed=0):
    """Class-dependent feature means correlated with OUR labels."""
    n = len(y)
    ncls = int(y.max()) + 1
    centers = np.random.default_rng(seed).normal(
        size=(ncls, dim)).astype(np.float32)
    x = centers[y] * 0.4 + np.random.default_rng(seed + 1).normal(
        size=(n, dim)).astype(np.float32)
    return x


def _train_full(g, x, y, train_mask, mesh, epochs, ps=16):
    """Full-graph SAGE on the ring engine; epoch == one step over N nodes."""
    eng = C.GNNEngine.build(g, mesh, ps=ps)
    xp = eng.shard(eng.pad(x))
    pad1 = lambda a: C.pad_table(eng.plan.bounds, eng.plan.rows_per_dev,
                                 a[:, None])[:, 0]
    yp = jnp.asarray(pad1(y.astype(np.int32)))
    mp_train = jnp.asarray(pad1(train_mask.astype(np.float32)))
    init, apply, kw = C.MODEL_ZOO["sage"]
    params = init(jax.random.key(0), x.shape[1], int(y.max()) + 1, **kw)
    opt = adamw_init(params)
    ocfg = AdamWConfig(lr=1e-2, warmup_steps=2, total_steps=epochs,
                       weight_decay=0.0)

    @jax.jit
    def step(params, opt):
        loss, grads = jax.value_and_grad(
            lambda p: C.masked_cross_entropy(apply(p, eng, xp), yp, mp_train)
        )(params)
        params, opt, _ = adamw_update(grads, opt, params, ocfg)
        return params, opt, loss

    t = timeit(lambda: step(params, opt)[2], warmup=1, iters=3)
    for _ in range(epochs):
        params, opt, _ = step(params, opt)
    logits = np.asarray(apply(params, eng, xp))
    pred = C.unpad_embeddings(eng.plan, logits).argmax(-1)
    test = ~train_mask
    acc = float((pred[test] == y[test]).mean())
    return acc, t


def _train_sampled(g, x, y, train_mask, *, fanout, batch, epochs):
    """Mini-batch SAGE over fanout-bounded blocks, resampled every epoch.

    Features come through a planless TieredFeatures (device hot cache over
    the host store) — the same assembly path the memory-bound serving
    regime uses, so this row also exercises gather_rows end to end.
    """
    ncls = int(y.max()) + 1
    init, _, kw = C.MODEL_ZOO["sage"]
    params = init(jax.random.key(0), x.shape[1], ncls, **kw)
    n_layers = len(params["layers"])
    tiers = TieredFeatures(FeatureStore(x), None, capacity=g.num_nodes // 8)
    tiers.admit(np.argsort(-np.diff(g.indptr))[:g.num_nodes // 8])
    opt = adamw_init(params)
    ocfg = AdamWConfig(lr=1e-2, warmup_steps=2, total_steps=epochs,
                       weight_decay=0.0)

    @jax.jit
    def step(params, opt, h0, btree, yb, mb):
        def loss_fn(p):
            logits = C.apply_blocks("sage", p, h0, btree)
            return C.masked_cross_entropy(logits, yb, mb)
        loss, grads = jax.value_and_grad(loss_fn)(params)
        params, opt, _ = adamw_update(grads, opt, params, ocfg)
        return params, opt, loss

    rng = np.random.default_rng(0)
    train_ids = np.nonzero(train_mask)[0]

    def epoch(params, opt):
        loss = None
        for seeds, valid in seed_batches(train_ids, batch, rng=rng):
            blocks = sample_blocks(g, seeds, [fanout] * n_layers,
                                   batch=batch, rng=rng)
            h0 = tiers.gather_rows(blocks[0].src_ids)
            yb = jnp.asarray(y[np.clip(seeds, 0, None)].astype(np.int32))
            params, opt, loss = step(params, opt, h0, block_tree(blocks),
                                     yb, jnp.asarray(valid))
        jax.block_until_ready(loss)
        return params, opt

    times = []
    for e in range(epochs):  # fresh neighbor draw EVERY epoch
        t0 = time.perf_counter()
        params, opt = epoch(params, opt)
        if e > 0:  # epoch 0 pays jit compile; the rest are steady-state
            times.append(time.perf_counter() - t0)
    t = TimingSample(times)

    correct = total = 0
    test_ids = np.nonzero(~train_mask)[0]
    for seeds, valid in seed_batches(test_ids, batch, rng=rng, shuffle=False):
        blocks = sample_blocks(g, seeds, [fanout] * n_layers,
                               batch=batch, rng=rng)
        logits = C.apply_blocks("sage", params,
                                tiers.gather_rows(blocks[0].src_ids),
                                block_tree(blocks))
        pred = np.asarray(logits).argmax(-1)
        live = valid > 0
        correct += int((pred[live] == y[seeds[live]]).sum())
        total += int(live.sum())
    return correct / max(1, total), t


def run(as_json: bool, smoke: bool = False) -> list:
    n_dev = len(jax.devices())
    mesh = flat_ring_mesh(n_dev)
    n, deg, epochs = (1200, 16, 8) if smoke else (2400, 24, 30)
    fanout, batch = 4, 256
    g, y = _homophilous(n, ncls=6, deg=deg)
    x = _features(y, 32)
    # modest train fraction: the sampled epoch's win comes from touching
    # only the train seeds' fanout-bounded receptive field, not all N nodes
    train_mask = np.random.default_rng(3).random(n) < 0.15
    acc_full, t_full = _train_full(g, x, y, train_mask, mesh, epochs, ps=16)
    acc_samp, t_samp = _train_sampled(g, x, y, train_mask, fanout=fanout,
                                      batch=batch, epochs=epochs)
    if smoke:
        assert t_samp < t_full, (
            f"smoke: sampled epoch ({t_samp*1e3:.1f} ms) not faster than "
            f"full-graph epoch ({t_full*1e3:.1f} ms)")
    return [
        dict(name="table5_full_graph_epoch",
             us_per_call=round(t_full * 1e6, 1),
             **sample_fields(t_full),
             derived=f"acc={acc_full:.3f};epochs={epochs}"),
        dict(name="table5_sampled_epoch",
             us_per_call=round(t_samp * 1e6, 1),
             **sample_fields(t_samp),
             derived=(f"acc={acc_samp:.3f};"
                      f"acc_delta={(acc_full - acc_samp) * 100:+.1f}pp;"
                      f"speedup={t_full / t_samp:.2f}x;"
                      f"fanout={fanout};batch={batch}")),
    ]


if __name__ == "__main__":
    emit(run("--json" in sys.argv, smoke="--smoke" in sys.argv),
         "--json" in sys.argv)
