"""Paper Table 5: accuracy/latency tradeoff, full-graph vs sampled GNN.

Synthetic node classification (class-dependent feature means + homophilous
edges): train a 2-layer GCN (paper setting) full-graph and with
neighbor-sampled aggregation (cap each node at k sampled neighbors), then
compare test accuracy and epoch latency.  Paper: 2–5% accuracy advantage
for full-graph at ~1.07–1.25× latency.
"""
from __future__ import annotations

import sys

from benchmarks._common import (emit, force_devices_from_env, sample_fields,
                                timeit)

force_devices_from_env()

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

import repro.core as C  # noqa: E402
from repro.dist import flat_ring_mesh  # noqa: E402
from repro.train.data import graph_features  # noqa: E402
from repro.train.optimizer import (AdamWConfig, adamw_init,  # noqa: E402
                                   adamw_update)


def _homophilous(n, ncls, deg, seed=0):
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, ncls, n)
    dst = np.repeat(np.arange(n), deg)
    src = rng.integers(0, n, len(dst))
    same = rng.random(len(dst)) < 0.7  # homophily: mostly same-class edges
    pools = {c: np.where(labels == c)[0] for c in range(ncls)}
    src_same = np.array([pools[labels[d]][rng.integers(len(pools[labels[d]]))]
                         for d in dst])
    src = np.where(same, src_same, src)
    from repro.core.graph import _from_edges
    return _from_edges(dst.astype(np.int64), src.astype(np.int64), n), labels


def _sampled_graph(g, k, seed=0):
    rng = np.random.default_rng(seed)
    dst, src = [], []
    for v in range(g.num_nodes):
        nb = g.row(v)
        if len(nb) > k:
            nb = rng.choice(nb, size=k, replace=False)
        dst.extend([v] * len(nb))
        src.extend(nb.tolist())
    from repro.core.graph import _from_edges
    return _from_edges(np.asarray(dst, np.int64), np.asarray(src, np.int64),
                       g.num_nodes)


def _train(g, x, y, train_mask, mesh, epochs=40, ps=16):
    eng = C.GNNEngine.build(g, mesh, ps=ps)
    xp = eng.shard(eng.pad(x))
    pad1 = lambda a: C.pad_table(eng.plan.bounds, eng.plan.rows_per_dev,
                                 a[:, None])[:, 0]
    yp = jnp.asarray(pad1(y.astype(np.int32)))
    mp_train = jnp.asarray(pad1(train_mask.astype(np.float32)))
    init, apply, kw = C.MODEL_ZOO["gcn"]
    params = init(jax.random.key(0), x.shape[1], int(y.max()) + 1, **kw)
    opt = adamw_init(params)
    ocfg = AdamWConfig(lr=1e-2, warmup_steps=2, total_steps=epochs,
                       weight_decay=0.0)

    @jax.jit
    def step(params, opt):
        loss, grads = jax.value_and_grad(
            lambda p: C.masked_cross_entropy(apply(p, eng, xp), yp, mp_train)
        )(params)
        params, opt, _ = adamw_update(grads, opt, params, ocfg)
        return params, opt, loss

    t = timeit(lambda: step(params, opt)[2], warmup=1, iters=3)
    for _ in range(epochs):
        params, opt, _ = step(params, opt)
    logits = np.asarray(apply(params, eng, xp))
    pred = C.unpad_embeddings(eng.plan, logits).argmax(-1)
    test = ~train_mask
    acc = float((pred[test] == y[test]).mean())
    return acc, t


def run(as_json: bool) -> list:
    n_dev = len(jax.devices())
    mesh = flat_ring_mesh(n_dev)
    g, y = _homophilous(1600, ncls=6, deg=24)
    x, _, train_mask = graph_features(g.num_nodes, 32, 6, seed=2)
    # overwrite features to correlate with OUR labels
    centers = np.random.default_rng(0).normal(size=(6, 32)).astype(np.float32)
    x = centers[y] * 0.4 + np.random.default_rng(1).normal(
        size=(g.num_nodes, 32)).astype(np.float32)
    acc_full, t_full = _train(g, x, y, train_mask, mesh, ps=16)
    gs = _sampled_graph(g, k=4)
    # fair ps for the sampled graph (max degree 4): the autotuner's layout
    # knob — ps=16 would pad 75% of every partition
    acc_samp, t_samp = _train(gs, x, y, train_mask, mesh, ps=4)
    return [dict(
        name="table5_full_vs_sampled",
        us_per_call=round(t_full * 1e6, 1),
        **sample_fields(t_full),
        derived=(f"acc_full={acc_full:.3f};acc_sampled={acc_samp:.3f};"
                 f"acc_gain={(acc_full-acc_samp)*100:.1f}pp;"
                 f"latency_ratio={t_full/t_samp:.2f}"))]


if __name__ == "__main__":
    emit(run("--json" in sys.argv), "--json" in sys.argv)
