"""Roofline analysis from the dry-run artifacts (§Roofline of the brief).

Per (arch × shape) on the single-pod mesh (+ multi-pod shown for §Dry-run):

    compute term    = HLO_FLOPs_per_chip   / peak_FLOP/s      (197e12 bf16)
    memory term     = HLO_bytes_per_chip   / HBM_bw           (819e9 B/s)
    collective term = coll_bytes_per_chip  / link_bw          (50e9 B/s)

``compiled.cost_analysis()`` runs on the SPMD-partitioned per-chip module,
so flops/bytes are already per-chip (verified against 6·N·D/chips).  The
collective bytes come from summing operand sizes of every collective op in
the partitioned HLO (launch/dryrun.parse_collectives) — also per-chip, so
the brief's ``collective_bytes/(chips·link_bw)`` with *global* bytes equals
our ``per_chip_bytes/link_bw``.

MODEL_FLOPS uses the standard 6·N·D (train) / 2·N·D (prefill/decode) with
N = non-embedding (active, for MoE) parameters; the ratio to HLO FLOPs
exposes remat/dispatch overheads (ratio < 1 ⇒ the compiled program does
that much non-"useful" compute; > 1 ⇒ HLO under-counts, e.g. scan bodies).
"""
from __future__ import annotations

import glob
import json
import os
import sys
from typing import Dict, List, Optional

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro import configs  # noqa: E402
from repro.configs import SHAPES  # noqa: E402
from repro.core.autotune import TPU_V5E  # noqa: E402

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                       "dryrun")


def active_params(cfg) -> float:
    """Non-embedding, routing-active parameter count."""
    total = cfg.param_count()
    emb = cfg.vocab * cfg.d_model * (1 if cfg.tie_embeddings else 2)
    n = total - emb
    if cfg.family == "moe":
        per_e = (3 if cfg.mlp_type == "swiglu" else 2) * cfg.d_model * cfg.d_ff
        n = n - cfg.n_layers * cfg.n_experts * per_e \
            + cfg.n_layers * cfg.top_k * per_e
    return float(max(n, 1))


def model_flops(cfg, shape, n_chips: int) -> float:
    """Per-chip 'useful' FLOPs for the step this cell lowers."""
    n = active_params(cfg)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens / n_chips
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens / n_chips
    return 2.0 * n * shape.global_batch / n_chips  # decode: 1 token/seq


def load(mesh: str = "single_pod", tag: str = "") -> List[Dict]:
    rows = []
    suffix = f"_{tag}.json" if tag else ".json"
    for f in sorted(glob.glob(os.path.join(ART_DIR, f"*_{mesh}{suffix}"))):
        base = os.path.basename(f)
        if not tag and base.count("_") > 3 and not base.endswith(
                f"{mesh}.json"):
            continue  # skip tagged variants in the untagged view
        with open(f) as fh:
            rows.append(json.load(fh))
    return rows


def terms(r: Dict, hw=TPU_V5E) -> Dict:
    cfg = configs.get_config(r["arch"])
    shape = SHAPES[r["shape"]]
    t_comp = r["flops"] / hw.peak_flops
    t_mem = r["bytes_accessed"] / hw.hbm_bw
    t_coll = r["collectives"]["total_bytes"] / hw.link_bw
    dom = max(("compute", t_comp), ("memory", t_mem),
              ("collective", t_coll), key=lambda kv: kv[1])
    mf = model_flops(cfg, shape, r["n_chips"])
    bound = max(t_comp, t_mem, t_coll)
    return dict(
        arch=r["arch"], shape=r["shape"], mesh=r["mesh"],
        t_compute=t_comp, t_memory=t_mem, t_collective=t_coll,
        dominant=dom[0], bound_s=bound,
        model_flops=mf, hlo_flops=r["flops"],
        useful_ratio=mf / max(r["flops"], 1.0),
        # conservative: includes the fusion-boundary byte proxy (upper
        # bound on HBM traffic — real TPU fusion is coarser than CPU's)
        roofline_fraction=(mf / hw.peak_flops) / max(bound, 1e-30),
        # compute/collective-only: the MFU-style number if the memory
        # proxy is discounted entirely (the two bracket reality)
        roofline_fraction_cc=(mf / hw.peak_flops)
        / max(t_comp, t_coll, 1e-30),
        coll_per_op={k: v["bytes"] for k, v in
                     r["collectives"]["per_op"].items()},
        tag=r.get("tag", ""),
    )


_SUGGEST = {
    "compute": "compute-bound: raise MXU efficiency (larger per-chip tiles, "
               "bf16 everywhere, fuse elementwise into matmuls)",
    "memory": "HBM-bound: cut activation traffic (deeper fusion, selective "
              "remat policy, wider per-chip batch to amortize weight reads)",
    "collective": "ICI-bound: overlap or shrink collectives (MGG-style "
                  "chunked pipelining, gradient compression, shard the "
                  "dominant gather differently)",
}


def suggest(t: Dict) -> str:
    return _SUGGEST[t["dominant"]]


def run(as_json: bool = False) -> List[Dict]:
    rows = [terms(r) for r in load("single_pod")]
    out = []
    for t in rows:
        out.append(dict(
            name=f"roofline_{t['arch']}_{t['shape']}",
            us_per_call=round(t["bound_s"] * 1e6, 1),
            derived=(f"dom={t['dominant']};frac={t['roofline_fraction']:.3f};"
                     f"useful={t['useful_ratio']:.2f}"),
        ))
    if as_json:
        print(json.dumps(out))
    return out


def markdown_tables() -> str:
    """§Dry-run + §Roofline markdown for EXPERIMENTS.md."""
    single = load("single_pod")
    multi = load("multi_pod")
    lines = []
    lines.append("### Dry-run results (every arch × shape × mesh)\n")
    lines.append("| arch | shape | mesh | chips | HLO GFLOP/chip | HLO GB "
                 "touched/chip | collective MB/chip (ops) | async | "
                 "compile s |")
    lines.append("|---|---|---|---|---|---|---|---|---|")
    for r in single + multi:
        ops = ",".join(f"{k}:{int(v['count'])}" for k, v in
                       r["collectives"]["per_op"].items())
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['n_chips']} "
            f"| {r['flops']/1e9:.1f} | {r['bytes_accessed']/1e9:.1f} "
            f"| {r['collectives']['total_bytes']/1e6:.1f} ({ops}) "
            f"| {r['collectives']['n_async']} | {r['compile_s']} |")
    lines.append("")
    skipped = [("codeqwen1.5-7b"), ("mistral-nemo-12b"), ("qwen3-32b"),
               ("starcoder2-15b"), ("internvl2-76b"),
               ("granite-moe-1b-a400m"), ("whisper-base")]
    lines.append(f"Skipped cells (documented, DESIGN.md §Arch-applicability): "
                 f"`long_500k` for {', '.join(skipped)} — pure "
                 f"full-attention archs; it RUNS for zamba2-7b (hybrid), "
                 f"xlstm-125m (recurrent) and mixtral-8x7b (SWA-bounded "
                 f"cache).  {len(single)} + 7 = 40 cells accounted.\n")
    lines.append("### Roofline (single-pod, 256 × TPU v5e)\n")
    lines.append("`frac` = MODEL_FLOPS/peak over the binding term "
                 "(conservative: includes the byte proxy); `frac_cc` = the "
                 "same over max(compute, collective) only — the two bracket "
                 "the achievable MFU.\n")
    lines.append("| arch | shape | compute s | memory s | collective s | "
                 "dominant | MODEL_FLOPS/HLO | frac | frac_cc | "
                 "what would move the dominant term |")
    lines.append("|---|---|---|---|---|---|---|---|---|---|")
    for r in single:
        t = terms(r)
        lines.append(
            f"| {t['arch']} | {t['shape']} | {t['t_compute']:.2e} "
            f"| {t['t_memory']:.2e} | {t['t_collective']:.2e} "
            f"| **{t['dominant']}** | {t['useful_ratio']:.2f} "
            f"| {t['roofline_fraction']:.3f} "
            f"| {t['roofline_fraction_cc']:.3f} | {suggest(t)} |")
    return "\n".join(lines)


if __name__ == "__main__":
    if "--markdown" in sys.argv:
        print(markdown_tables())
    else:
        for r in run("--json" in sys.argv):
            if "--json" not in sys.argv:
                print(f"{r['name']},{r['us_per_call']},{r['derived']}")
