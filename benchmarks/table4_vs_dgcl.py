"""Paper Table 4: MGG vs DGCL — 1-layer GCN latency AND graph-preprocessing
time (DGCL's partitioner is 100×+ slower than MGG's).

DGCL analogue: communication-optimized partitioning via spectral bisection
(expensive, like DGCL's bespoke partitioner) + all-gather-then-local-
aggregate execution (communication fully ahead of compute).  MGG: Algorithm
1 edge-balanced split (cheap) + pipelined ring.
"""
from __future__ import annotations

import sys
import time

from benchmarks._common import (emit, force_devices_from_env, sample_fields,
                                timeit)

force_devices_from_env()

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

import repro.core as C  # noqa: E402
from repro.dist import flat_ring_mesh  # noqa: E402


def _spectral_partition_time(g, n_parts: int) -> float:
    """DGCL-like preprocessing: recursive spectral bisection (scipy)."""
    import scipy.sparse as sp
    import scipy.sparse.linalg as spl
    t0 = time.perf_counter()
    deg = np.maximum(g.degrees, 1)
    a = sp.csr_matrix(
        (np.ones(g.num_edges, np.float64),
         g.indices.astype(np.int64), g.indptr),
        shape=(g.num_nodes, g.num_nodes))
    a = (a + a.T) * 0.5
    lap = sp.diags(np.asarray(a.sum(1)).ravel()) - a
    parts = [np.arange(g.num_nodes)]
    while len(parts) < n_parts:
        nxt = []
        for idx in parts:
            if len(idx) < 4 or len(nxt) + (len(parts) - len(nxt)) >= n_parts:
                nxt.append(idx)
                continue
            sub = lap[idx][:, idx].asfptype()
            try:
                _, vecs = spl.eigsh(sub, k=2, which="SM", maxiter=3000,
                                    tol=1e-3)
                fiedler = vecs[:, 1]
                med = np.median(fiedler)
                nxt.append(idx[fiedler <= med])
                nxt.append(idx[fiedler > med])
            except Exception:
                half = len(idx) // 2
                nxt.extend([idx[:half], idx[half:]])
        parts = nxt
    return time.perf_counter() - t0


def run(as_json: bool) -> list:
    n_dev = len(jax.devices())
    mesh = flat_ring_mesh(n_dev)
    rows = []
    for name in ("reddit", "enwiki", "products", "proteins", "orkut"):
        g, meta = C.paper_dataset(name, scale=0.3)
        d = 16  # paper: 1-layer GCN, 16 hidden dims
        x = np.random.default_rng(0).normal(
            size=(g.num_nodes, d)).astype(np.float32)

        # --- preprocessing time -----------------------------------------
        t0 = time.perf_counter()
        plan = C.build_plan(g, n_dev, ps=16, dist=2)
        t_mgg_prep = time.perf_counter() - t0
        t_dgcl_prep = _spectral_partition_time(g, n_dev)

        # --- 1-layer GCN aggregation latency ------------------------------
        xb = jnp.asarray(C.pad_embeddings(plan, x))
        mgg = jax.jit(lambda z: C.mgg_aggregate(z, plan, mesh))
        t_mgg = timeit(mgg, xb)
        nbrs, mask, tgt, rows_pd = C.build_bulk_plan(g, n_dev, ps=16)
        bounds = C.edge_balanced_node_split(g.indptr, n_dev)
        xb2 = jnp.asarray(C.pad_table(bounds, rows_pd, x))
        dgcl = jax.jit(lambda z: C.bulk_aggregate(
            z, nbrs, mask, tgt, rows_pd, mesh))
        t_dgcl = timeit(dgcl, xb2)
        rows.append(dict(
            name=f"table4_{name}",
            us_per_call=round(t_mgg * 1e6, 1),
            **sample_fields(t_mgg),
            derived=(f"dgcl_us={t_dgcl*1e6:.1f};"
                     f"gcn_speedup={t_dgcl/t_mgg:.2f};"
                     f"prep_mgg_ms={t_mgg_prep*1e3:.1f};"
                     f"prep_dgcl_ms={t_dgcl_prep*1e3:.1f};"
                     f"prep_speedup={t_dgcl_prep/max(t_mgg_prep,1e-9):.1f}")))
    return rows


if __name__ == "__main__":
    emit(run("--json" in sys.argv), "--json" in sys.argv)
