"""Paper Fig. 9: (a) neighbor partitioning and (b) workload interleaving
ablations, reproduced with the paper's control variables — plus the two
per-layer-refactor ablations:

(a) ps=16 vs no partitioning (ps = max degree ⇒ one partition per node:
    per-work-unit cost becomes degree-skewed — the padded-slot waste and
    the latency both blow up; paper: 3.47× average).
(b) interleave=True vs False at ps=16 (paper: 1.32× average; fixed
    warp-per-block analogue pb).
(c) per-layer vs global config on a skewed-width GCN (wide input layer,
    narrow hidden): greedy per-layer descent over the *measured*
    full-forward latency, with the global config in every layer's
    candidate set — the reported per-layer latency is therefore never
    worse than the global one (the tuner's guarantee, GNNAdvisor-style
    dimension-aware adaptation).
(d) fused vs unfused update: the dense ·W matmul inside the ring vs after
    it, numerically equivalence-checked against each other.
(e) sparsity-aware aggregation (MaxK-GNN direction): top-k-compressed
    ring payloads at k ∈ {D, D/2, D/4} — per-k ring wire bytes
    (analytic, exact), measured aggregation step time, and the
    final-train-accuracy delta of a short GCN run with sparse hidden
    layers vs the dense baseline.  The accuracy-vs-speed trade the
    ``k_space`` tuner knob navigates, measured.

``--smoke`` (wired into ``benchmarks/run.py --smoke`` → CI) shrinks the
graphs and asserts (c)'s per-layer ≤ global, (d)'s equivalence, and
(e)'s wire-byte reduction (k = D/4 must ship < 0.5× the dense bytes).
"""
from __future__ import annotations

import sys

from benchmarks._common import (emit, force_devices_from_env, sample_fields,
                                timeit)

force_devices_from_env()

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

import repro.core as C  # noqa: E402
from repro.dist import flat_ring_mesh  # noqa: E402


def _lat(g, x, mesh, n_dev, ps, dist, interleave):
    plan = C.build_plan(g, n_dev, ps=ps, dist=dist)
    xb = jnp.asarray(C.pad_embeddings(plan, x))
    fn = jax.jit(lambda z: C.mgg_aggregate(z, plan, mesh,
                                           interleave=interleave))
    return timeit(fn, xb), plan


def _forward_lat(g, mesh, params, apply_fn, x, layer_configs, *,
                 fuse_update=False, partition=None):
    """Measured full-forward latency under one per-layer config stack."""
    eng = C.GNNEngine.build(g, mesh, layer_configs=layer_configs,
                            fuse_update=fuse_update, partition=partition)
    xp = eng.shard(eng.pad(x))
    fn = jax.jit(lambda p, t: apply_fn(p, eng, t))
    return timeit(lambda p: fn(p, xp), params), eng


def _per_layer_vs_global(g, mesh, d, *, candidates, global_cfg, name):
    """Greedy per-layer coordinate descent over measured forward times.

    The memo table guarantees the reported per-layer latency ≤ the global
    latency: the global config is measured first and stays in every
    layer's candidate set, so the running best can only improve on it.
    """
    init, apply_fn, kw = C.MODEL_ZOO["gcn"]
    params = init(jax.random.key(0), d, 4, **kw)  # wide-in → 16 → 4: skewed
    x = np.random.default_rng(0).normal(size=(g.num_nodes, d)) \
        .astype(np.float32)
    n_layers = len(params["layers"])
    n_dev = mesh.shape["ring"]
    gsl = g.with_self_loops()
    part = C.build_partition(gsl, n_dev)   # shared across every candidate

    memo = {}

    def measure(cfgs):
        key = tuple((c["ps"], c["dist"]) for c in cfgs)
        if key not in memo:
            memo[key], _ = _forward_lat(g, mesh, params, apply_fn, x,
                                        [dict(c) for c in cfgs],
                                        partition=part)
        return memo[key]

    best = [dict(global_cfg)] * n_layers
    t_global = measure(best)
    for i in range(n_layers):
        for cand in candidates:
            trial = [dict(c) for c in best]
            trial[i] = dict(cand)
            if measure(trial) < measure(best):
                best = trial
    t_per_layer = measure(best)
    distinct = len({(c["ps"], c["dist"]) for c in best})
    return dict(
        name=name, us_per_call=round(t_per_layer * 1e6, 1),
        **sample_fields(t_per_layer),
        derived=(f"global_us={t_global*1e6:.1f};"
                 f"speedup={t_global/t_per_layer:.2f};"
                 f"configs={[(c['ps'], c['dist']) for c in best]};"
                 f"distinct={distinct};trials={len(memo)}")), \
        t_per_layer, t_global


def _fused_vs_unfused(g, mesh, d, *, cfg, name, check=False):
    init, apply_fn, kw = C.MODEL_ZOO["gcn"]
    params = init(jax.random.key(1), d, 4, **kw)
    x = np.random.default_rng(1).normal(size=(g.num_nodes, d)) \
        .astype(np.float32)
    cfgs = [dict(cfg)] * len(params["layers"])
    t_unfused, eng_u = _forward_lat(g, mesh, params, apply_fn, x, cfgs)
    t_fused, eng_f = _forward_lat(g, mesh, params, apply_fn, x, cfgs,
                                  fuse_update=True)
    if check:  # fused == unfused up to summation order (documented: 2e-4)
        xu = eng_u.shard(eng_u.pad(x))
        xf = eng_f.shard(eng_f.pad(x))
        ou = C.unpad_embeddings(eng_u.plan,
                                np.asarray(apply_fn(params, eng_u, xu)))
        of = C.unpad_embeddings(eng_f.plan,
                                np.asarray(apply_fn(params, eng_f, xf)))
        np.testing.assert_allclose(of, ou, rtol=2e-4, atol=2e-4)
    return dict(
        name=name, us_per_call=round(t_fused * 1e6, 1),
        **sample_fields(t_fused),
        derived=(f"unfused_us={t_unfused*1e6:.1f};"
                 f"speedup={t_unfused/t_fused:.2f}"))


def _final_accuracy(g, mesh, d, ncls, *, cfg, topk, steps, lr=2e-2):
    """Final train accuracy of a short GCN run; ``topk`` sparsifies the
    hidden layers (layer 0 stays dense — see GNNEngine.stage_topk).

    3 layers with ``hidden=d``: GCN aggregates at each layer's OUTPUT
    width, so the default 16-dim hidden would clamp every probed k to
    dense — the middle layer must aggregate at ``d`` for k < D to bite.
    """
    from repro.train.data import graph_features
    from repro.train.optimizer import AdamWConfig, adamw_init, adamw_update

    x, y, train_mask = graph_features(g.num_nodes, d, ncls, seed=1)
    init, apply_fn, _kw = C.MODEL_ZOO["gcn"]
    params = init(jax.random.key(0), d, ncls, hidden=d, num_layers=3)
    eng = C.GNNEngine.build(g, mesh, ps=cfg["ps"], dist=cfg["dist"],
                            topk=topk)
    xp = eng.shard(eng.pad(x))
    pad1 = lambda a: C.pad_table(eng.plan.bounds, eng.plan.rows_per_dev,
                                 a[:, None])[:, 0]
    yp = jnp.asarray(pad1(y.astype(np.int32)))
    mp = jnp.asarray(pad1(train_mask.astype(np.float32)))
    opt = adamw_init(params)
    ocfg = AdamWConfig(lr=lr, warmup_steps=2, total_steps=2 * steps,
                       weight_decay=0.0)

    @jax.jit
    def step(params, opt):
        loss, grads = jax.value_and_grad(lambda p: C.masked_cross_entropy(
            apply_fn(p, eng, xp), yp, mp))(params)
        params, opt, _ = adamw_update(grads, opt, params, ocfg)
        return params, opt, loss

    for _ in range(steps):
        params, opt, _loss = step(params, opt)
    pred = np.asarray(jnp.argmax(apply_fn(params, eng, xp), axis=-1))
    m = np.asarray(mp) > 0
    return float((pred[m] == np.asarray(yp)[m]).mean())


def _sparsity_rows(g, mesh, d, ncls, *, cfg, name_prefix, train_steps,
                   check=False):
    """fig9e: one row per compression width k ∈ {D, D/2, D/4}."""
    n_dev = mesh.shape["ring"]
    x = np.random.default_rng(2).normal(size=(g.num_nodes, d)) \
        .astype(np.float32)
    plan = C.build_plan(g, n_dev, ps=cfg["ps"], dist=cfg["dist"])
    xb = jnp.asarray(C.pad_embeddings(plan, x))
    dense_fn = jax.jit(lambda z: C.mgg_aggregate(z, plan, mesh))
    t_dense = timeit(dense_fn, xb)
    dense_bytes = C.collective_bytes(plan, d)
    acc_dense = _final_accuracy(g, mesh, d, ncls, cfg=cfg, topk=None,
                                steps=train_steps)
    rows = []
    for k in (d, d // 2, d // 4):
        fn = jax.jit(lambda z, kk=k: C.mgg_aggregate_sparse(z, plan, mesh,
                                                            k=kk))
        t_k = timeit(fn, xb)
        wire = C.sparse_collective_bytes(plan, d, k)
        ratio = wire / max(1, dense_bytes)
        acc_k = acc_dense if k == d else _final_accuracy(
            g, mesh, d, ncls, cfg=cfg, topk=k, steps=train_steps)
        if check and k == d // 4:
            # the tentpole's wire-byte gate: a quarter-width payload must
            # ship under half the dense bytes (int16 idx ⇒ 0.375×)
            assert ratio < 0.5, (wire, dense_bytes, ratio)
        rows.append(dict(
            name=f"{name_prefix}_k{k}", us_per_call=round(t_k * 1e6, 1),
            **sample_fields(t_k),
            derived=(f"dense_us={t_dense*1e6:.1f};"
                     f"speedup={t_dense/t_k:.2f};"
                     f"wire_bytes={wire};dense_bytes={dense_bytes};"
                     f"wire_ratio={ratio:.3f};"
                     f"acc={acc_k:.3f};acc_dense={acc_dense:.3f};"
                     f"acc_delta={acc_k - acc_dense:+.3f}")))
    return rows


def run(as_json: bool, smoke: bool = False) -> list:
    n_dev = len(jax.devices())
    mesh = flat_ring_mesh(n_dev)
    rows = []
    if smoke:
        g = C.power_law(512, avg_degree=8.0, locality=0.4, seed=0)
        row_c, t_pl, t_gl = _per_layer_vs_global(
            g, mesh, 96,
            candidates=[dict(ps=2, dist=1), dict(ps=8, dist=1),
                        dict(ps=8, dist=2), dict(ps=32, dist=1)],
            global_cfg=dict(ps=8, dist=1),
            name="fig9c_per_layer_vs_global_smoke")
        rows.append(row_c)
        assert t_pl <= t_gl, (t_pl, t_gl)  # global is in the memo table
        rows.append(_fused_vs_unfused(
            g, mesh, 96, cfg=dict(ps=8, dist=2),
            name="fig9d_fused_update_smoke", check=True))
        rows.extend(_sparsity_rows(
            g, mesh, 96, 4, cfg=dict(ps=8, dist=2),
            name_prefix="fig9e_sparsity_smoke", train_steps=10, check=True))
        return rows
    for name in ("reddit", "products", "proteins"):
        g, meta = C.paper_dataset(name, scale=0.25)
        d = min(int(meta["dim"]), 128)
        x = np.random.default_rng(0).normal(
            size=(g.num_nodes, d)).astype(np.float32)
        # (a) neighbor partitioning
        t_ps, plan = _lat(g, x, mesh, n_dev, ps=16, dist=1, interleave=True)
        ps_off = int(min(4096, g.degrees.max()))
        t_nops, plan_off = _lat(g, x, mesh, n_dev, ps=ps_off, dist=1,
                                interleave=True)
        pad = plan_off.stats()["pad_remote"]
        rows.append(dict(
            name=f"fig9a_{name}", us_per_call=round(t_ps * 1e6, 1),
            derived=(f"no_partition_us={t_nops*1e6:.1f};"
                     f"speedup={t_nops/t_ps:.2f};"
                     f"pad_waste_off={pad:.2f}")))
        # (b) interleaving
        t_il, _ = _lat(g, x, mesh, n_dev, ps=16, dist=2, interleave=True)
        t_no, _ = _lat(g, x, mesh, n_dev, ps=16, dist=2, interleave=False)
        rows.append(dict(
            name=f"fig9b_{name}", us_per_call=round(t_il * 1e6, 1),
            derived=(f"no_interleave_us={t_no*1e6:.1f};"
                     f"speedup={t_no/t_il:.2f}")))
        # (c) per-layer vs global; (d) fused vs unfused (GCN forward)
        row_c, _t_pl, _t_gl = _per_layer_vs_global(
            g, mesh, d,
            candidates=[dict(ps=2, dist=1), dict(ps=8, dist=1),
                        dict(ps=16, dist=2), dict(ps=32, dist=1)],
            global_cfg=dict(ps=16, dist=2),
            name=f"fig9c_per_layer_{name}")
        rows.append(row_c)
        rows.append(_fused_vs_unfused(g, mesh, d, cfg=dict(ps=16, dist=2),
                                      name=f"fig9d_fused_{name}"))
        rows.extend(_sparsity_rows(
            g, mesh, d, 8, cfg=dict(ps=16, dist=2),
            name_prefix=f"fig9e_sparsity_{name}", train_steps=25))
    return rows


if __name__ == "__main__":
    emit(run("--json" in sys.argv, smoke="--smoke" in sys.argv),
         "--json" in sys.argv)
