"""Paper Fig. 9: (a) neighbor partitioning and (b) workload interleaving
ablations, reproduced with the paper's control variables.

(a) ps=16 vs no partitioning (ps = max degree ⇒ one partition per node:
    per-work-unit cost becomes degree-skewed — the padded-slot waste and
    the latency both blow up; paper: 3.47× average).
(b) interleave=True vs False at ps=16 (paper: 1.32× average; fixed
    warp-per-block analogue pb).
"""
from __future__ import annotations

import sys

from benchmarks._common import emit, force_devices_from_env, timeit

force_devices_from_env()

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

import repro.core as C  # noqa: E402
from repro.dist import flat_ring_mesh  # noqa: E402


def _lat(g, x, mesh, n_dev, ps, dist, interleave):
    plan = C.build_plan(g, n_dev, ps=ps, dist=dist)
    xb = jnp.asarray(C.pad_embeddings(plan, x))
    fn = jax.jit(lambda z: C.mgg_aggregate(z, plan, mesh,
                                           interleave=interleave))
    return timeit(fn, xb), plan


def run(as_json: bool) -> list:
    n_dev = len(jax.devices())
    mesh = flat_ring_mesh(n_dev)
    rows = []
    for name in ("reddit", "products", "proteins"):
        g, meta = C.paper_dataset(name, scale=0.25)
        d = min(int(meta["dim"]), 128)
        x = np.random.default_rng(0).normal(
            size=(g.num_nodes, d)).astype(np.float32)
        # (a) neighbor partitioning
        t_ps, plan = _lat(g, x, mesh, n_dev, ps=16, dist=1, interleave=True)
        ps_off = int(min(4096, g.degrees.max()))
        t_nops, plan_off = _lat(g, x, mesh, n_dev, ps=ps_off, dist=1,
                                interleave=True)
        pad = plan_off.stats()["pad_remote"]
        rows.append(dict(
            name=f"fig9a_{name}", us_per_call=round(t_ps * 1e6, 1),
            derived=(f"no_partition_us={t_nops*1e6:.1f};"
                     f"speedup={t_nops/t_ps:.2f};"
                     f"pad_waste_off={pad:.2f}")))
        # (b) interleaving
        t_il, _ = _lat(g, x, mesh, n_dev, ps=16, dist=2, interleave=True)
        t_no, _ = _lat(g, x, mesh, n_dev, ps=16, dist=2, interleave=False)
        rows.append(dict(
            name=f"fig9b_{name}", us_per_call=round(t_il * 1e6, 1),
            derived=(f"no_interleave_us={t_no*1e6:.1f};"
                     f"speedup={t_no/t_il:.2f}")))
    return rows


if __name__ == "__main__":
    emit(run("--json" in sys.argv), "--json" in sys.argv)
