"""Serving under traffic shifts: latency percentiles vs load, retune
on/off (the serving-side extension of the paper's §4 runtime — "fig11"
has no paper counterpart; it quantifies the ROADMAP's serving-retune
loop).

Three phases of Zipfian node-prediction traffic over the ring-partitioned
graph — steady, hot-set rotation, burst — served twice:

* ``fig11_serving_static`` — fixed (ps, dist) aggregation config;
* ``fig11_serving_retune`` — DynamicGNNEngine: the WorkloadStats drift
  signal re-opens the (ps, dist, pb) search mid-serve and the pipeline
  re-optimizes on live micro-batch times.

Reported per mode: p50/p99 request latency, layer-1 cache hit rate,
retunes fired, dropped requests (must be 0).  GIN and GAT serving rows
(``fig11_serving_gin`` / ``fig11_serving_gat``) run the same trace under
a static config alongside the GCN pair, so every MODEL_STAGES family is
exercised by the serving path.  ``--smoke`` (wired into
``benchmarks/run.py --smoke`` → CI) shrinks the graph/traffic and
*asserts* the acceptance criteria: ≥ 1 drift retune, hit rate > 0, no
drops, and served logits equal to the offline full-graph forward — for
GIN/GAT too.
"""
from __future__ import annotations

import sys

from benchmarks._common import emit, force_devices_from_env

force_devices_from_env()

import jax  # noqa: E402
import numpy as np  # noqa: E402

import repro.core as C  # noqa: E402
from repro.dist import flat_ring_mesh  # noqa: E402
from repro.runtime import DynamicGNNEngine, ProfileConfig  # noqa: E402
from repro.serve import (GNNServeEngine, TrafficPhase, WorkloadStats,  # noqa: E402
                         ZipfTraffic, run_trace)


def _phases(n_req: int) -> list:
    return [
        TrafficPhase(requests=n_req, alpha=1.3, rate=150.0, seeds_max=4),
        TrafficPhase(requests=n_req, alpha=1.3, rate=150.0, rotate=True,
                     seeds_max=4),
        TrafficPhase(requests=n_req, alpha=1.3, rate=600.0, seeds_max=4,
                     update_frac=0.05),
    ]


def _serve(g, x, params, apply_fn, engine, *, smoke: bool, model: str = "gcn"):
    srv = GNNServeEngine(
        engine, params, model, x, g, slots=8,
        stats=WorkloadStats(window=8 if smoke else 24, top_k=8),
        drift_threshold=0.5, check_every=2 if smoke else 4,
        min_records=4)
    traffic = ZipfTraffic(g.num_nodes, x.shape[1],
                          _phases(40 if smoke else 160), seed=9)
    results = run_trace(srv, traffic)
    lat = np.array([r.latency for r in results])
    rep = srv.report()
    # correctness: the trace tail was served under the final config
    xp = engine.shard(engine.pad(srv.x))
    offline = C.unpad_embeddings(
        engine.plan,
        np.asarray(jax.jit(lambda p, t: apply_fn(p, engine, t))(params, xp)))
    for r in results[-10:]:
        np.testing.assert_allclose(r.logits, offline[r.seeds],
                                   rtol=1e-5, atol=1e-5)
    return results, lat, rep


def run(as_json: bool, smoke: bool = False) -> list:
    n_dev = len(jax.devices())
    mesh = flat_ring_mesh(n_dev)
    if smoke:
        g = C.power_law(512, avg_degree=8.0, locality=0.4, seed=0)
        d = 16
        spaces = dict(ps_space=(2, 4, 8), dist_space=(1, 2), pb_space=(1,))
    else:
        g, meta = C.paper_dataset("reddit", scale=0.2)
        d = 64
        spaces = dict(ps_space=(1, 2, 4, 8, 16), dist_space=(1, 2, 4),
                      pb_space=(1,))
    x = np.random.default_rng(0).normal(size=(g.num_nodes, d)) \
        .astype(np.float32)
    init, apply_fn, kw = C.MODEL_ZOO["gcn"]
    params = init(jax.random.key(0), d, 8, **kw)

    rows = []
    static_eng = C.GNNEngine.build(g, mesh, ps=min(spaces["ps_space"]),
                                  dist=1)
    _res_s, lat_s, rep_s = _serve(g, x, params, apply_fn, static_eng,
                                  smoke=smoke)
    rows.append(dict(
        name="fig11_serving_static",
        us_per_call=round(float(np.percentile(lat_s, 50)) * 1e6, 1),
        derived=(f"p99_us={np.percentile(lat_s, 99) * 1e6:.0f};"
                 f"hit_rate={rep_s['cache_hit_rate']};"
                 f"dropped={rep_s['dropped']};"
                 f"config={rep_s['config']}")))

    dyn_eng = DynamicGNNEngine.build(
        g, mesh, d_feat=d, **spaces,
        window=ProfileConfig(warmup=1, iters=1 if smoke else 2))
    res_d, lat_d, rep_d = _serve(g, x, params, apply_fn, dyn_eng,
                                 smoke=smoke)
    rows.append(dict(
        name="fig11_serving_retune",
        us_per_call=round(float(np.percentile(lat_d, 50)) * 1e6, 1),
        derived=(f"p99_us={np.percentile(lat_d, 99) * 1e6:.0f};"
                 f"hit_rate={rep_d['cache_hit_rate']};"
                 f"dropped={rep_d['dropped']};"
                 f"retunes={rep_d['retunes']};"
                 f"rebuilds={rep_d['rebuilds']};"
                 f"config={rep_d['config']}")))

    # GIN / GAT serving alongside GCN (static config; every MODEL_STAGES
    # family flows through the serving path + offline-equality check)
    for model in ("gin", "gat"):
        init_m, apply_m, kw_m = C.MODEL_ZOO[model]
        params_m = init_m(jax.random.key(1), d, 8, **kw_m)
        eng_m = C.GNNEngine.build(g, mesh, ps=min(spaces["ps_space"]),
                                  dist=1)
        _res_m, lat_m, rep_m = _serve(g, x, params_m, apply_m, eng_m,
                                      smoke=smoke, model=model)
        rows.append(dict(
            name=f"fig11_serving_{model}",
            us_per_call=round(float(np.percentile(lat_m, 50)) * 1e6, 1),
            derived=(f"p99_us={np.percentile(lat_m, 99) * 1e6:.0f};"
                     f"hit_rate={rep_m['cache_hit_rate']};"
                     f"dropped={rep_m['dropped']};"
                     f"config={rep_m['config']}")))
        if smoke:
            assert rep_m["dropped"] == 0, (model, rep_m)
            assert rep_m["cache_hit_rate"] > 0, (model, rep_m)

    if smoke:
        assert rep_d["retunes"] >= 1, \
            f"smoke: no traffic-drift retune fired: {rep_d}"
        assert rep_d["dropped"] == 0 and rep_s["dropped"] == 0
        assert rep_d["cache_hit_rate"] > 0 and rep_s["cache_hit_rate"] > 0
        assert any(r.cached for r in res_d)
    return rows


if __name__ == "__main__":
    emit(run("--json" in sys.argv, smoke="--smoke" in sys.argv),
         "--json" in sys.argv)
