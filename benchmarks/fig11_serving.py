"""Serving under traffic shifts: latency percentiles vs load, retune
on/off (the serving-side extension of the paper's §4 runtime — "fig11"
has no paper counterpart; it quantifies the ROADMAP's serving-retune
loop).

Three phases of Zipfian node-prediction traffic over the ring-partitioned
graph — steady, hot-set rotation, burst — served twice:

* ``fig11_serving_static`` — fixed (ps, dist) aggregation config;
* ``fig11_serving_retune`` — DynamicGNNEngine: the WorkloadStats drift
  signal re-opens the (ps, dist, pb) search mid-serve and the pipeline
  re-optimizes on live micro-batch times.

Reported per mode: p50/p99 request latency, layer-1 cache hit rate,
retunes fired, dropped requests (must be 0).  GIN and GAT serving rows
(``fig11_serving_gin`` / ``fig11_serving_gat``) run the same trace under
a static config alongside the GCN pair, so every MODEL_STAGES family is
exercised by the serving path.

**Cluster rows** (``fig11_cluster_*``) scale the retune mode out through
:class:`repro.serve.cluster.ServeCluster`: 1 vs 2 vs 4 replicas, locality
vs least-load routing, all replicas sharing one ConfigCache and
staggering their drift retunes (drain → shadow-retune → rejoin).  Both
sides of the cluster comparison are *pre-converged* on a steady warm-up
trace so p99 reflects how each mode absorbs the drift — the single
engine re-searches inline (re-jits land on live requests), the cluster
routes around the draining replica.

``--smoke`` (wired into ``benchmarks/run.py --smoke`` → CI) shrinks the
graph/traffic and *asserts* the acceptance criteria: ≥ 1 drift retune,
hit rate > 0, no drops, served logits equal to the offline full-graph
forward (GIN/GAT too) — and for the cluster: ≥ 1 staggered retune, zero
drops cluster-wide, and cluster p99 ≤ single-replica p99 under the
rotation + burst phases.
"""
from __future__ import annotations

import os
import sys
import tempfile

from benchmarks._common import (emit, force_devices_from_env,
                                sample_stats)

force_devices_from_env()

import jax  # noqa: E402
import numpy as np  # noqa: E402

import repro.core as C  # noqa: E402
from repro.dist import flat_ring_mesh  # noqa: E402
from repro.runtime import DynamicGNNEngine, ProfileConfig  # noqa: E402
from repro.serve import (GNNServeEngine, ServeCluster, TrafficPhase,  # noqa: E402
                         WorkloadStats, ZipfTraffic, make_router, run_trace)


def _phases(n_req: int) -> list:
    return [
        TrafficPhase(requests=n_req, alpha=1.3, rate=150.0, seeds_max=4),
        TrafficPhase(requests=n_req, alpha=1.3, rate=150.0, rotate=True,
                     seeds_max=4),
        TrafficPhase(requests=n_req, alpha=1.3, rate=600.0, seeds_max=4,
                     update_frac=0.05),
    ]


def _serve(g, x, params, apply_fn, engine, *, smoke: bool, model: str = "gcn"):
    srv = GNNServeEngine(
        engine, params, model, x, g, slots=8,
        stats=WorkloadStats(window=8 if smoke else 24, top_k=8),
        drift_threshold=0.5, check_every=2 if smoke else 4,
        min_records=4)
    traffic = ZipfTraffic(g.num_nodes, x.shape[1],
                          _phases(40 if smoke else 160), seed=9)
    results = run_trace(srv, traffic)
    lat = np.array([r.latency for r in results])
    rep = srv.report()
    # correctness: the trace tail was served under the final config
    xp = engine.shard(engine.pad(srv.x))
    offline = C.unpad_embeddings(
        engine.plan,
        np.asarray(jax.jit(lambda p, t: apply_fn(p, engine, t))(params, xp)))
    for r in results[-10:]:
        np.testing.assert_allclose(r.logits, offline[r.seeds],
                                   rtol=1e-5, atol=1e-5)
    return results, lat, rep


def _mk_dyn(g, d, mesh, spaces, smoke, cache_path=None):
    return DynamicGNNEngine.build(
        g, mesh, d_feat=d, **spaces,
        window=ProfileConfig(warmup=1, iters=1 if smoke else 2),
        cache_path=cache_path)


def _mk_replica(g, x, params, engine, smoke):
    return GNNServeEngine(
        engine, params, "gcn", x, g, slots=8,
        stats=WorkloadStats(window=8 if smoke else 24, top_k=8),
        drift_threshold=0.5, check_every=2 if smoke else 4,
        min_records=4)


def _preconverge(run_fn, converged, num_nodes, d, smoke):
    """Steady warm-up traffic until the initial searches commit, so the
    measured trace isolates how each mode absorbs the *drift* retune."""
    for rnd in range(4):
        if converged():
            break
        run_fn(ZipfTraffic(num_nodes, d, [
            TrafficPhase(requests=30 if smoke else 80, alpha=1.3,
                         rate=150.0, seeds_max=4)], seed=123 + rnd))


def _offline_for(srv, apply_fn, params):
    eng = srv.eng
    xp = eng.shard(eng.pad(srv.x))
    return C.unpad_embeddings(eng.plan, np.asarray(
        jax.jit(lambda p, t: apply_fn(p, eng, t))(params, xp)))


def _serve_single_preconverged(g, x, params, apply_fn, spaces, mesh, *,
                               smoke):
    d = x.shape[1]
    srv = _mk_replica(g, x, params, _mk_dyn(g, d, mesh, spaces, smoke),
                      smoke)
    _preconverge(lambda tr: run_trace(srv, tr),
                 lambda: not srv._tuning, g.num_nodes, d, smoke)
    results = run_trace(srv, ZipfTraffic(
        g.num_nodes, d, _phases(30 if smoke else 120), seed=9))
    offline = _offline_for(srv, apply_fn, params)
    for r in results[-10:]:
        np.testing.assert_allclose(r.logits, offline[r.seeds],
                                   rtol=1e-5, atol=1e-5)
    return results, np.array([r.latency for r in results]), srv.report()


def _serve_cluster(g, x, params, apply_fn, n_rep, router_name, spaces,
                   mesh, *, smoke, cache_path):
    d = x.shape[1]
    replicas = [
        _mk_replica(g, x, params,
                    _mk_dyn(g, d, mesh, spaces, smoke, cache_path), smoke)
        for _ in range(n_rep)]
    cluster = ServeCluster(replicas, router=make_router(router_name))
    _preconverge(cluster.run_trace,
                 lambda: all(not r._tuning for r in replicas),
                 g.num_nodes, d, smoke)
    results = cluster.run_trace(ZipfTraffic(
        g.num_nodes, d, _phases(30 if smoke else 120), seed=9))
    lat = np.array([r.latency for r in results])
    rep = cluster.report()
    # tail correctness per replica (final committed configs may differ)
    offline = {}
    for r in results[-10:]:
        i = cluster.replica_of(r.request_id)
        if i not in offline:
            offline[i] = _offline_for(replicas[i], apply_fn, params)
        np.testing.assert_allclose(r.logits, offline[i][r.seeds],
                                   rtol=1e-5, atol=1e-5)
    return results, lat, rep


def run(as_json: bool, smoke: bool = False) -> list:
    n_dev = len(jax.devices())
    mesh = flat_ring_mesh(n_dev)
    if smoke:
        g = C.power_law(512, avg_degree=8.0, locality=0.4, seed=0)
        d = 16
        spaces = dict(ps_space=(2, 4, 8), dist_space=(1, 2), pb_space=(1,))
    else:
        g, meta = C.paper_dataset("reddit", scale=0.2)
        d = 64
        spaces = dict(ps_space=(1, 2, 4, 8, 16), dist_space=(1, 2, 4),
                      pb_space=(1,))
    x = np.random.default_rng(0).normal(size=(g.num_nodes, d)) \
        .astype(np.float32)
    init, apply_fn, kw = C.MODEL_ZOO["gcn"]
    params = init(jax.random.key(0), d, 8, **kw)

    rows = []
    static_eng = C.GNNEngine.build(g, mesh, ps=min(spaces["ps_space"]),
                                  dist=1)
    _res_s, lat_s, rep_s = _serve(g, x, params, apply_fn, static_eng,
                                  smoke=smoke)
    rows.append(dict(
        name="fig11_serving_static",
        us_per_call=round(float(np.percentile(lat_s, 50)) * 1e6, 1),
        **sample_stats(lat_s),
        derived=(f"p99_us={np.percentile(lat_s, 99) * 1e6:.0f};"
                 f"hit_rate={rep_s['cache_hit_rate']};"
                 f"dropped={rep_s['dropped']};"
                 f"config={rep_s['config']}")))

    dyn_eng = DynamicGNNEngine.build(
        g, mesh, d_feat=d, **spaces,
        window=ProfileConfig(warmup=1, iters=1 if smoke else 2))
    res_d, lat_d, rep_d = _serve(g, x, params, apply_fn, dyn_eng,
                                 smoke=smoke)
    rows.append(dict(
        name="fig11_serving_retune",
        us_per_call=round(float(np.percentile(lat_d, 50)) * 1e6, 1),
        **sample_stats(lat_d),
        derived=(f"p99_us={np.percentile(lat_d, 99) * 1e6:.0f};"
                 f"hit_rate={rep_d['cache_hit_rate']};"
                 f"dropped={rep_d['dropped']};"
                 f"retunes={rep_d['retunes']};"
                 f"rebuilds={rep_d['rebuilds']};"
                 f"config={rep_d['config']}")))

    # GIN / GAT serving alongside GCN (static config; every MODEL_STAGES
    # family flows through the serving path + offline-equality check)
    for model in ("gin", "gat"):
        init_m, apply_m, kw_m = C.MODEL_ZOO[model]
        params_m = init_m(jax.random.key(1), d, 8, **kw_m)
        eng_m = C.GNNEngine.build(g, mesh, ps=min(spaces["ps_space"]),
                                  dist=1)
        _res_m, lat_m, rep_m = _serve(g, x, params_m, apply_m, eng_m,
                                      smoke=smoke, model=model)
        rows.append(dict(
            name=f"fig11_serving_{model}",
            us_per_call=round(float(np.percentile(lat_m, 50)) * 1e6, 1),
        **sample_stats(lat_m),
            derived=(f"p99_us={np.percentile(lat_m, 99) * 1e6:.0f};"
                     f"hit_rate={rep_m['cache_hit_rate']};"
                     f"dropped={rep_m['dropped']};"
                     f"config={rep_m['config']}")))
        if smoke:
            assert rep_m["dropped"] == 0, (model, rep_m)
            assert rep_m["cache_hit_rate"] > 0, (model, rep_m)

    if smoke:
        assert rep_d["retunes"] >= 1, \
            f"smoke: no traffic-drift retune fired: {rep_d}"
        assert rep_d["dropped"] == 0 and rep_s["dropped"] == 0
        assert rep_d["cache_hit_rate"] > 0 and rep_s["cache_hit_rate"] > 0
        assert any(r.cached for r in res_d)

    # ---- cluster scale-out: replicated engines, shared ConfigCache ----
    with tempfile.TemporaryDirectory(prefix="fig11-cluster-") as tmpdir:
        rows += _cluster_rows(g, x, params, apply_fn, spaces, mesh,
                              smoke=smoke, tmpdir=tmpdir)
    return rows


def _cluster_rows(g, x, params, apply_fn, spaces, mesh, *, smoke, tmpdir):
    rows = []
    _res_1, lat_1, rep_1 = _serve_single_preconverged(
        g, x, params, apply_fn, spaces, mesh, smoke=smoke)
    rows.append(dict(
        name="fig11_cluster_single",
        us_per_call=round(float(np.percentile(lat_1, 50)) * 1e6, 1),
        **sample_stats(lat_1),
        derived=(f"p99_us={np.percentile(lat_1, 99) * 1e6:.0f};"
                 f"retunes={rep_1['retunes']};"
                 f"dropped={rep_1['dropped']}")))
    combos = [(2, "locality")] if smoke else [
        (1, "locality"), (2, "load"), (2, "locality"),
        (4, "load"), (4, "locality")]
    for n_rep, router_name in combos:
        cache_path = os.path.join(tmpdir,
                                  f"tuned-{n_rep}-{router_name}.json")
        _res_c, lat_c, rep_c = _serve_cluster(
            g, x, params, apply_fn, n_rep, router_name, spaces, mesh,
            smoke=smoke, cache_path=cache_path)
        hits = [p["cache_hit_rate"] for p in rep_c["per_replica"]]
        rows.append(dict(
            name=f"fig11_cluster_{n_rep}_{router_name}",
            us_per_call=round(float(np.percentile(lat_c, 50)) * 1e6, 1),
        **sample_stats(lat_c),
            derived=(f"p99_us={np.percentile(lat_c, 99) * 1e6:.0f};"
                     f"staggered={rep_c['staggered_retunes']};"
                     f"deferred={rep_c['deferred_retunes']};"
                     f"dropped={rep_c['dropped']};"
                     f"hit_rates={hits}")))
        if smoke:
            assert rep_c["dropped"] == 0, rep_c
            assert rep_c["staggered_retunes"] >= 1, \
                f"smoke: no staggered cluster retune fired: {rep_c}"
            p99_c = float(np.percentile(lat_c, 99))
            p99_s = float(np.percentile(lat_1, 99))
            assert rep_1["retunes"] >= 1, rep_1
            assert p99_c <= p99_s, (
                f"smoke: cluster p99 {p99_c * 1e3:.1f} ms above "
                f"single-replica p99 {p99_s * 1e3:.1f} ms")
    return rows


if __name__ == "__main__":
    emit(run("--json" in sys.argv, smoke="--smoke" in sys.argv),
         "--json" in sys.argv)
