"""Paper Table 1: Direct NVSHMEM vs UVM — naive fine-grained remote fetch
is NOT automatically faster than page-batched migration.

Analogue: fetch-exact-rows (page_rows=1, many tiny gathers — the Direct
pattern) vs page-batched fetch (page_rows=16, fewer/larger transfers with
waste).  The paper's point (Direct loses on 3/5 graphs, 0.77× gmean) is
about transfer-granularity overheads; we report measured ratios plus the
modeled per-transfer-overhead ratio for the paper's real sizes.
"""
from __future__ import annotations

import sys

from benchmarks._common import (emit, force_devices_from_env, sample_fields,
                                timeit)

force_devices_from_env()

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

import repro.core as C  # noqa: E402


def run(as_json: bool) -> list:
    n_dev = max(2, len(jax.devices()))
    rows = []
    for name in ("reddit", "enwiki", "products", "proteins", "orkut"):
        g, meta = C.paper_dataset(name, scale=0.35)
        d = min(int(meta["dim"]), 128)
        x = np.random.default_rng(0).normal(
            size=(g.num_nodes, d)).astype(np.float32)
        bounds = C.edge_balanced_node_split(g.indptr, n_dev)
        times = {}
        for label, page in (("direct", 1), ("batched", 16)):
            fp = C.build_fetch_plan(g, n_dev, ps=16, page_rows=page)
            xb = jnp.asarray(C.pad_table(bounds, fp["rows_per_dev"], x))
            fn = jax.jit(lambda z, fp=fp: C.fetch_rows_aggregate(
                z, fp["fetch_rows"], fp["nbrs"], fp["mask"], fp["targets"],
                fp["rows_per_dev"]))
            times[label] = timeit(fn, xb)
        rows.append(dict(
            name=f"table1_{name}",
            us_per_call=round(times["direct"] * 1e6, 1),
            **sample_fields(times["direct"]),
            derived=(f"batched_us={times['batched']*1e6:.1f};"
                     f"direct_over_batched="
                     f"{times['batched']/times['direct']:.2f}")))
    return rows


if __name__ == "__main__":
    emit(run("--json" in sys.argv), "--json" in sys.argv)
