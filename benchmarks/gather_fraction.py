"""DESIGN.md §2 evidence: what fraction of each remote shard is actually
referenced by some neighbor partition?  Decides dense ring rotation vs
sparse row all-to-all (the NVSHMEM-GET → collective-granularity adaptation).

Pure host-side analysis: no devices needed.
"""
from __future__ import annotations

import sys

import numpy as np

from benchmarks._common import emit

import repro.core as C  # noqa: E402


def run(as_json: bool) -> list:
    rows = []
    for name in ("reddit", "enwiki", "products", "proteins", "orkut"):
        for n_dev in (8, 64, 256):
            g, meta = C.paper_dataset(name, scale=1.0)
            bounds = C.edge_balanced_node_split(g.indptr, n_dev)
            fracs = []
            for d in range(min(n_dev, 8)):  # sample devices
                vg = C.locality_edge_split(g, bounds, d)
                cols = vg.remote.indices
                owner = np.searchsorted(bounds, cols, side="right") - 1
                for o in np.unique(owner)[:8]:
                    rows_o = np.unique(cols[owner == o]).size
                    shard = max(1, int(bounds[o + 1] - bounds[o]))
                    fracs.append(rows_o / shard)
            f = float(np.mean(fracs)) if fracs else 0.0
            # analytic fraction at the REAL dataset size: balls-in-bins —
            # r = E/n² refs land in a shard of S = V/n rows ⇒
            # referenced ≈ 1 − exp(−r/S)
            v, e = meta["real_nodes"], meta["real_edges"]
            s_real = v / n_dev
            r_real = e / n_dev ** 2
            f_real = 1.0 - float(np.exp(-r_real / s_real))
            rows.append(dict(
                name=f"gatherfrac_{name}_{n_dev}dev", us_per_call="",
                derived=(f"scaled_measured={f:.3f};"
                         f"real_size_analytic={f_real:.3f};"
                         f"dense_ring_optimal={f_real > 0.5}")))
    return rows


if __name__ == "__main__":
    emit(run("--json" in sys.argv), "--json" in sys.argv)
