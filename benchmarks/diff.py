"""Perf-regression diff over BENCH_*.json snapshots (schema v1/v2).

Library (``load_snapshot`` / ``compare`` / ``render``) plus a CLI:

    PYTHONPATH=src python -m benchmarks.diff BENCH_BASELINE.json \
        bench/BENCH_ci.json [--mad-mult 8] [--min-rel 0.5] [--force]

Rows are matched by ``(module, name)``.  A row regresses when the new
median exceeds the baseline median by more than the *noise band* — a
threshold expressed in MAD multiples of the measured jitter, not a raw
percentage, so tight-variance rows are held to tight tolerances while
noisy rows are not flagged for wobbling inside their own spread:

    band = max(mad_mult * max(MAD_base, MAD_new), min_rel * median_base)

``min_rel`` is the relative floor for rows without samples (schema-v1
snapshots, search-result rows) and for near-zero-MAD rows where a MAD
band alone would flag scheduler noise.  Improvements are reported but
never fail the diff; rows present only in the candidate snapshot are
reported as ``"new"`` findings (latency included, never failing) so a
PR that adds a benchmark row sees it in the gate report.

Snapshots from different machines (backend / device kind / device count
mismatch) are refused unless ``--force`` — cross-machine latency deltas
are hardware deltas, not regressions.  Exit codes: 0 clean, 1 regression
found, 2 refused/unusable input.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import sys
from typing import Dict, List, Optional, Tuple

# fingerprint fields that must match for a latency diff to be meaningful
COMPAT_FIELDS = ("backend", "device_kind", "device_count")
DEFAULT_MAD_MULT = 5.0
DEFAULT_MIN_REL = 0.10


class SnapshotError(ValueError):
    """The file is not a usable benchmark snapshot."""


def load_snapshot(path: str) -> dict:
    """Parse + structurally validate a snapshot (v1 or v2)."""
    try:
        with open(path) as f:
            snap = json.load(f)
    except OSError as e:
        raise SnapshotError(f"{path}: {e}") from e
    except ValueError as e:
        raise SnapshotError(f"{path}: not JSON ({e})") from e
    if not isinstance(snap, dict) or "modules" not in snap:
        raise SnapshotError(f"{path}: no 'modules' section — not a "
                            f"BENCH_*.json snapshot")
    if not isinstance(snap["modules"], dict):
        raise SnapshotError(f"{path}: 'modules' is not a mapping")
    snap.setdefault("schema", 1)
    snap.setdefault("machine", {})
    return snap


def fingerprint_mismatches(a: dict, b: dict) -> List[str]:
    """Human-readable reasons the two machines are not comparable."""
    out = []
    for field in COMPAT_FIELDS:
        va, vb = a.get(field), b.get(field)
        if va is not None and vb is not None and va != vb:
            out.append(f"{field}: {va!r} vs {vb!r}")
    return out


def _row_stats(row: dict) -> Optional[Tuple[float, float]]:
    """(median_us, mad_us) for a row; None when it carries no latency.

    v2 rows have exact ``us_median``/``us_mad``; v1 rows fall back to the
    single ``us_per_call`` with an unknown (0) MAD — the relative floor
    carries the whole noise band for those.
    """
    if "us_median" in row:
        return float(row["us_median"]), float(row.get("us_mad", 0.0))
    us = row.get("us_per_call")
    if us in (None, ""):
        return None
    try:
        return float(us), 0.0
    except (TypeError, ValueError):
        return None


@dataclasses.dataclass
class Finding:
    module: str
    name: str
    kind: str          # "regression" | "improvement" | "new"
    base_us: float     # 0.0 for "new" rows (no baseline to diff against)
    new_us: float
    band_us: float     # the noise band the delta had to clear

    @property
    def rel(self) -> float:
        if self.kind == "new":
            return 0.0
        return (self.new_us - self.base_us) / max(1e-12, self.base_us)


@dataclasses.dataclass
class CompareResult:
    findings: List[Finding]
    compared: int
    skipped: List[str]          # rows without usable latency
    missing_in_new: List[str]   # (module, name) present only in base
    new_rows: List[str]         # present only in new

    @property
    def regressions(self) -> List[Finding]:
        return [f for f in self.findings if f.kind == "regression"]

    @property
    def improvements(self) -> List[Finding]:
        return [f for f in self.findings if f.kind == "improvement"]


def _rows_by_key(snap: dict) -> Dict[Tuple[str, str], dict]:
    out: Dict[Tuple[str, str], dict] = {}
    for module, rows in snap["modules"].items():
        for row in rows or []:
            name = row.get("name")
            if name:
                out[(module, str(name))] = row
    return out


def compare(base: dict, new: dict, *, mad_mult: float = DEFAULT_MAD_MULT,
            min_rel: float = DEFAULT_MIN_REL,
            force: bool = False) -> CompareResult:
    """Row-by-row diff of two loaded snapshots.

    Raises :class:`SnapshotError` on a machine-fingerprint mismatch
    unless ``force`` — see the module docstring for the noise band.
    """
    mismatches = fingerprint_mismatches(base.get("machine", {}),
                                        new.get("machine", {}))
    if mismatches and not force:
        raise SnapshotError(
            "snapshots are from different machines ("
            + "; ".join(mismatches)
            + ") — latency deltas would be hardware deltas, not "
              "regressions; pass --force to compare anyway")
    rows_a, rows_b = _rows_by_key(base), _rows_by_key(new)
    findings: List[Finding] = []
    skipped: List[str] = []
    compared = 0
    for key in sorted(set(rows_a) & set(rows_b)):
        sa, sb = _row_stats(rows_a[key]), _row_stats(rows_b[key])
        if sa is None or sb is None:
            skipped.append("/".join(key))
            continue
        (base_us, base_mad), (new_us, new_mad) = sa, sb
        band = max(mad_mult * max(base_mad, new_mad),
                   min_rel * abs(base_us))
        compared += 1
        delta = new_us - base_us
        if delta > band:
            findings.append(Finding(*key, "regression", base_us, new_us,
                                    band))
        elif -delta > band:
            findings.append(Finding(*key, "improvement", base_us, new_us,
                                    band))
    # rows only the candidate snapshot carries: report them as "new"
    # findings (latency included) rather than a silent footnote, so a PR
    # that ADDS a benchmark row sees it land in the gate report
    for key in sorted(set(rows_b) - set(rows_a)):
        sb = _row_stats(rows_b[key])
        if sb is not None:
            findings.append(Finding(*key, "new", 0.0, sb[0], 0.0))
    findings.sort(key=lambda f: -abs(f.rel))
    return CompareResult(
        findings=findings, compared=compared, skipped=skipped,
        missing_in_new=sorted("/".join(k) for k in set(rows_a) - set(rows_b)),
        new_rows=sorted("/".join(k) for k in set(rows_b) - set(rows_a)),
    )


def render(result: CompareResult, base_stamp: str = "",
           new_stamp: str = "") -> str:
    """Human-readable diff report."""
    lines = [f"bench diff: {result.compared} rows compared"
             + (f" ({base_stamp} -> {new_stamp})"
                if base_stamp or new_stamp else "")]
    covered = set()
    for f in result.findings:
        if f.kind == "new":
            covered.add(f"{f.module}/{f.name}")
            lines.append(f"  {'new':>11}  {f.module}/{f.name}: "
                         f"{f.new_us:.1f}us (not in baseline)")
            continue
        arrow = "REGRESSION" if f.kind == "regression" else "improvement"
        lines.append(
            f"  {arrow:>11}  {f.module}/{f.name}: "
            f"{f.base_us:.1f}us -> {f.new_us:.1f}us "
            f"({f.rel:+.1%}, band ±{f.band_us:.1f}us)")
    if not result.findings:
        lines.append("  all rows inside the noise band")
    if result.missing_in_new:
        lines.append("  rows only in baseline: "
                     + ", ".join(result.missing_in_new))
    latencyless = [r for r in result.new_rows if r not in covered]
    if latencyless:
        lines.append("  new rows (not in baseline): "
                     + ", ".join(latencyless))
    if result.skipped:
        lines.append(f"  skipped (no latency): {', '.join(result.skipped)}")
    return "\n".join(lines)


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        description="diff two BENCH_*.json perf snapshots")
    ap.add_argument("base", help="baseline snapshot (e.g. "
                                 "BENCH_BASELINE.json)")
    ap.add_argument("new", help="candidate snapshot")
    ap.add_argument("--mad-mult", type=float, default=DEFAULT_MAD_MULT,
                    help="noise band in MAD multiples "
                         f"(default {DEFAULT_MAD_MULT})")
    ap.add_argument("--min-rel", type=float, default=DEFAULT_MIN_REL,
                    help="relative noise-band floor "
                         f"(default {DEFAULT_MIN_REL})")
    ap.add_argument("--force", action="store_true",
                    help="compare across machine fingerprints")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write the findings machine-readably")
    args = ap.parse_args(argv)
    try:
        base, new = load_snapshot(args.base), load_snapshot(args.new)
        result = compare(base, new, mad_mult=args.mad_mult,
                         min_rel=args.min_rel, force=args.force)
    except SnapshotError as e:
        print(f"[bench.diff] REFUSED: {e}", file=sys.stderr)
        return 2
    print(render(result, base.get("stamp", ""), new.get("stamp", "")))
    if args.json:
        with open(args.json, "w") as f:
            json.dump({
                "base": args.base, "new": args.new,
                "compared": result.compared,
                "findings": [dataclasses.asdict(x) for x in result.findings],
                "missing_in_new": result.missing_in_new,
                "new_rows": result.new_rows, "skipped": result.skipped,
            }, f, indent=2)
    if result.regressions:
        print(f"[bench.diff] FAIL: {len(result.regressions)} row(s) "
              f"regressed beyond the noise band", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
