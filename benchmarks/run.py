"""Benchmark harness entry point — one section per paper table/figure plus
the roofline report.  Prints ``name,us_per_call,derived`` CSV.

The main process sees ONE CPU device; modules needing a multi-device ring
run as subprocesses with 8 forced host devices (benchmarks/_common.py).

    PYTHONPATH=src python -m benchmarks.run [--quick] [--smoke] [--only fig8,...]

``--smoke`` is the CI mode: a tiny-graph fig10 run (exercising the
measured-search path, the online runtime tuner, and the benchmark
subprocess harness) so benchmark code cannot rot silently.  It fails the
process on any error, like the full run.

Full (non-smoke) runs also write a ``BENCH_<stamp>.json`` perf snapshot
next to the CSV stream: a machine fingerprint (host, platform, JAX
backend/devices) plus every per-figure row, so runs on different
machines/dates can be diffed.  ``--no-snapshot`` disables it,
``--snapshot-dir`` relocates it.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time
import traceback

from benchmarks._common import run_subprocess

MULTI_DEVICE_MODULES = [
    "fig2_comm_compute",
    "table1_direct_vs_batched",
    "fig8_mgg_vs_uvm",
    "table4_vs_dgcl",
    "fig9_ablations",
    "fig10_autotune",
    "fig11_serving",
    "table5_sampling",
]
LOCAL_MODULES = ["gather_fraction", "roofline"]
QUICK_SKIP = {"fig10_autotune", "fig11_serving", "table5_sampling"}
# tiny graphs, --smoke arg, 2 devices (CI runs these on every PR)
SMOKE_MODULES = ["fig8_mgg_vs_uvm", "fig9_ablations", "fig10_autotune",
                 "fig11_serving"]


def machine_fingerprint() -> dict:
    """Identify the machine a snapshot was measured on (enough to tell
    two snapshots apart, not to uniquely identify hardware)."""
    import multiprocessing
    import platform

    fp = {
        "hostname": platform.node(),
        "platform": platform.platform(),
        "machine": platform.machine(),
        "python": platform.python_version(),
        "cpu_count": multiprocessing.cpu_count(),
    }
    try:
        import jax
        fp["jax"] = jax.__version__
        fp["backend"] = jax.default_backend()
        fp["device_kind"] = jax.devices()[0].device_kind
    except Exception:
        pass
    return fp


def write_snapshot(path: str, rows_by_module: dict, args_ns) -> None:
    snap = {
        "stamp": time.strftime("%Y-%m-%dT%H:%M:%S"),
        "machine": machine_fingerprint(),
        "args": {"quick": args_ns.quick, "only": args_ns.only,
                 "devices": args_ns.devices},
        "modules": rows_by_module,
    }
    with open(path, "w") as f:
        json.dump(snap, f, indent=2, sort_keys=True, default=str)
    print(f"# perf snapshot: {path}", file=sys.stderr)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--only", default="")
    ap.add_argument("--devices", type=int, default=8)
    ap.add_argument("--no-snapshot", action="store_true",
                    help="skip the BENCH_<stamp>.json perf snapshot")
    ap.add_argument("--snapshot-dir", default=".",
                    help="directory for the perf snapshot (default: cwd)")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None

    print("name,us_per_call,derived")
    failures = []
    rows_by_module: dict = {}
    if args.smoke:
        for mod in SMOKE_MODULES:
            if only and mod not in only:
                continue
            try:
                for row in run_subprocess(mod, devices=2, args=["--smoke"],
                                          timeout=600):
                    print(f"{row['name']},{row.get('us_per_call', '')},"
                          f"\"{row.get('derived', '')}\"")
                sys.stdout.flush()
            except Exception as e:
                failures.append((mod, e))
                print(f"{mod},ERROR,\"{e}\"", file=sys.stderr)
        if failures:
            print(f"# {len(failures)} smoke module(s) failed",
                  file=sys.stderr)
            sys.exit(1)
        return
    for mod in MULTI_DEVICE_MODULES:
        if only and mod not in only:
            continue
        if args.quick and mod in QUICK_SKIP:
            continue
        try:
            for row in run_subprocess(mod, devices=args.devices):
                print(f"{row['name']},{row.get('us_per_call', '')},"
                      f"\"{row.get('derived', '')}\"")
                rows_by_module.setdefault(mod, []).append(dict(row))
            sys.stdout.flush()
        except Exception as e:
            failures.append((mod, e))
            print(f"{mod},ERROR,\"{e}\"", file=sys.stderr)
    for mod in LOCAL_MODULES:
        if only and mod not in only:
            continue
        try:
            module = __import__(f"benchmarks.{mod}", fromlist=["run"])
            for row in module.run(False):
                print(f"{row['name']},{row.get('us_per_call', '')},"
                      f"\"{row.get('derived', '')}\"")
                rows_by_module.setdefault(mod, []).append(dict(row))
        except Exception as e:
            traceback.print_exc()
            failures.append((mod, e))
    if not args.no_snapshot and rows_by_module:
        stamp = time.strftime("%Y%m%d_%H%M%S")
        write_snapshot(os.path.join(args.snapshot_dir,
                                    f"BENCH_{stamp}.json"),
                       rows_by_module, args)
    if failures:
        print(f"# {len(failures)} benchmark module(s) failed", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
