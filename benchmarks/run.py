"""Benchmark harness entry point — one section per paper table/figure plus
the roofline report.  Prints ``name,us_per_call,derived`` CSV.

The main process sees ONE CPU device; modules needing a multi-device ring
run as subprocesses with 8 forced host devices (benchmarks/_common.py).

    PYTHONPATH=src python -m benchmarks.run [--quick] [--smoke] [--only fig8,...]

``--smoke`` is the CI mode: a tiny-graph fig10 run (exercising the
measured-search path, the online runtime tuner, and the benchmark
subprocess harness) so benchmark code cannot rot silently.  It fails the
process on any error, like the full run.

Every run — ``--smoke`` included, so CI always has data — also writes a
``BENCH_<stamp>.json`` perf snapshot (schema v2): a device-count-complete
machine fingerprint, a UTC ISO-8601 stamp, and every per-figure row with
its raw repeated measurements (``us_median`` / ``us_mad`` /
``samples_us``) alongside the headline ``us_per_call``.  Snapshots land
in ``bench/`` (gitignored; the committed smoke-scale ``BENCH_BASELINE.json``
at the repo root is the one tracked exception) and are compared with
``benchmarks/diff.py`` — the CI ``bench-regression`` job gates PRs on the
smoke snapshot staying inside the baseline's noise band.
``--no-snapshot`` disables it, ``--snapshot-dir``/``--snapshot-name``
relocate it.
"""
from __future__ import annotations

import argparse
import os
import sys
import time
import traceback

from benchmarks._common import (machine_fingerprint, run_subprocess,
                                write_snapshot)

MULTI_DEVICE_MODULES = [
    "fig2_comm_compute",
    "table1_direct_vs_batched",
    "fig8_mgg_vs_uvm",
    "table4_vs_dgcl",
    "fig9_ablations",
    "fig10_autotune",
    "fig11_serving",
    "table5_sampling",
]
LOCAL_MODULES = ["gather_fraction", "roofline"]
QUICK_SKIP = {"fig10_autotune", "fig11_serving", "table5_sampling"}
# tiny graphs, --smoke arg, 2 devices (CI runs these on every PR)
SMOKE_MODULES = ["fig8_mgg_vs_uvm", "fig9_ablations", "fig10_autotune",
                 "fig11_serving", "table5_sampling"]


def _maybe_snapshot(args, rows_by_module: dict) -> None:
    if args.no_snapshot or not rows_by_module:
        return
    name = args.snapshot_name or \
        f"BENCH_{time.strftime('%Y%m%d_%H%M%S', time.gmtime())}.json"
    write_snapshot(
        os.path.join(args.snapshot_dir, name), rows_by_module,
        {"quick": args.quick, "smoke": args.smoke, "only": args.only,
         "devices": 2 if args.smoke else args.devices})


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--only", default="")
    ap.add_argument("--devices", type=int, default=8)
    ap.add_argument("--no-snapshot", action="store_true",
                    help="skip the BENCH_<stamp>.json perf snapshot")
    ap.add_argument("--snapshot-dir", default="bench",
                    help="directory for the perf snapshot "
                         "(default: bench/, gitignored)")
    ap.add_argument("--snapshot-name", default=None,
                    help="snapshot file name (default: BENCH_<utcstamp>.json;"
                         " a fixed name lets CI diff it deterministically)")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None

    print("name,us_per_call,derived")
    failures = []
    rows_by_module: dict = {}
    if args.smoke:
        for mod in SMOKE_MODULES:
            if only and mod not in only:
                continue
            try:
                for row in run_subprocess(mod, devices=2, args=["--smoke"],
                                          timeout=600):
                    print(f"{row['name']},{row.get('us_per_call', '')},"
                          f"\"{row.get('derived', '')}\"")
                    rows_by_module.setdefault(mod, []).append(dict(row))
                sys.stdout.flush()
            except Exception as e:
                failures.append((mod, e))
                print(f"{mod},ERROR,\"{e}\"", file=sys.stderr)
        _maybe_snapshot(args, rows_by_module)
        if failures:
            print(f"# {len(failures)} smoke module(s) failed",
                  file=sys.stderr)
            sys.exit(1)
        return
    for mod in MULTI_DEVICE_MODULES:
        if only and mod not in only:
            continue
        if args.quick and mod in QUICK_SKIP:
            continue
        try:
            for row in run_subprocess(mod, devices=args.devices):
                print(f"{row['name']},{row.get('us_per_call', '')},"
                      f"\"{row.get('derived', '')}\"")
                rows_by_module.setdefault(mod, []).append(dict(row))
            sys.stdout.flush()
        except Exception as e:
            failures.append((mod, e))
            print(f"{mod},ERROR,\"{e}\"", file=sys.stderr)
    for mod in LOCAL_MODULES:
        if only and mod not in only:
            continue
        try:
            module = __import__(f"benchmarks.{mod}", fromlist=["run"])
            for row in module.run(False):
                print(f"{row['name']},{row.get('us_per_call', '')},"
                      f"\"{row.get('derived', '')}\"")
                rows_by_module.setdefault(mod, []).append(dict(row))
        except Exception as e:
            traceback.print_exc()
            failures.append((mod, e))
    _maybe_snapshot(args, rows_by_module)
    if failures:
        print(f"# {len(failures)} benchmark module(s) failed", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
