"""Paper Fig. 8: MGG vs UVM-based design, GCN + GIN end-to-end, all five
datasets (scaled stand-ins), 8-device ring.

UVM analogue (per DESIGN.md): page-granular fetch-then-aggregate with no
overlap — each device pulls whole "pages" of remote rows before computing
(the §2.2 access pattern), vs MGG's pipelined ring.  We report wall-clock
per aggregation epoch on the CPU backend plus the modeled TPU-term
speedup; the paper measures 3.16× (GCN) / 4.15× (GIN) on A100s.
"""
from __future__ import annotations

import sys

from benchmarks._common import emit, force_devices_from_env, timeit

force_devices_from_env()

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

import repro.core as C  # noqa: E402
from repro.dist import flat_ring_mesh  # noqa: E402

PAGE_ROWS = 16  # ≈64 KB pages / (dim · 4 B), the paper's migration granularity


def _uvm_epoch(g, x, n_dev, layers):
    fp = C.build_fetch_plan(g, n_dev, ps=16, page_rows=PAGE_ROWS)
    bounds = C.edge_balanced_node_split(g.indptr, n_dev)
    rows = fp["rows_per_dev"]
    xb = jnp.asarray(C.pad_table(bounds, rows, x))

    @jax.jit
    def epoch(z):
        for _ in range(layers):
            out = C.fetch_rows_aggregate(
                z, fp["fetch_rows"], fp["nbrs"], fp["mask"], fp["targets"],
                rows)
            z = out.reshape(z.shape)
        return z

    return timeit(epoch, xb), fp


def _mgg_epoch(g, x, n_dev, mesh, layers, ps=16, dist=2):
    plan = C.build_plan(g, n_dev, ps=ps, dist=dist)
    xb = jnp.asarray(C.pad_embeddings(plan, x))

    @jax.jit
    def epoch(z):
        for _ in range(layers):
            z = C.mgg_aggregate(z, plan, mesh, interleave=True)
        return z

    return timeit(epoch, xb), plan


def run(as_json: bool) -> list:
    n_dev = len(jax.devices())
    mesh = flat_ring_mesh(n_dev)
    rows = []
    for model, layers in (("gcn", 2), ("gin", 5)):
        for name in ("reddit", "enwiki", "products", "proteins", "orkut"):
            g, meta = C.paper_dataset(name, scale=0.35)
            d = min(int(meta["dim"]), 128)
            x = np.random.default_rng(0).normal(
                size=(g.num_nodes, d)).astype(np.float32)
            t_uvm, fp = _uvm_epoch(g, x, n_dev, layers)
            t_mgg, plan = _mgg_epoch(g, x, n_dev, mesh, layers)
            speed = t_uvm / t_mgg
            # modeled fetch-volume ratio (the paper's mechanism: page waste)
            exact = C.build_fetch_plan(g, n_dev, ps=16, page_rows=1)
            waste = (np.mean(fp["fetched_rows_per_dev"])
                     / max(1.0, np.mean(exact["fetched_rows_per_dev"])))
            # modeled TPU-term speedup at the REAL dataset size: UVM has no
            # overlap (comm + comp, with page-waste bytes); MGG overlaps
            # (max(comm, comp) + fill).  The CPU wall-clock above CANNOT
            # show overlap (one core serializes compute and "comm"), so the
            # hardware terms carry the paper's actual claim.
            from repro.core.autotune import TPU_V5E as HW
            e, v = meta["real_edges"], meta["real_nodes"]
            dim = int(meta["dim"])
            comp = 2 * e * dim * 4 / n_dev / HW.hbm_bw
            comm_mgg = v * dim * 4 / n_dev / HW.link_bw  # ring, exact rows
            comm_uvm = waste * v * dim * 4 / n_dev / HW.link_bw
            # UVM's dominant cost is page-FAULT handling, not bandwidth
            # (paper Fig. 3: fault count/duration grow with GPU count);
            # ~30 µs per 64 KB page migration, demand-paged.
            pages = waste * v * dim * 4 / n_dev / 65536
            t_fault = pages * 30e-6
            t_mgg_hw = max(comm_mgg, comp) + comm_mgg / n_dev
            t_uvm_hw = comm_uvm + comp + t_fault
            rows.append(dict(
                name=f"fig8_{model}_{name}",
                us_per_call=round(t_mgg * 1e6, 1),
                derived=(f"uvm_us={t_uvm*1e6:.1f};cpu_ratio={speed:.2f};"
                         f"page_waste={waste:.2f}x;"
                         f"modeled_tpu_speedup={t_uvm_hw/t_mgg_hw:.2f}")))
    return rows


if __name__ == "__main__":
    emit(run("--json" in sys.argv), "--json" in sys.argv)
