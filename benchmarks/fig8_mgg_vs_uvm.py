"""Paper Fig. 8: MGG vs UVM-based design, GCN + GIN end-to-end, all five
datasets (scaled stand-ins), 8-device ring — now a THREE-way comparison:

* **resident** — every feature row device-resident, pipelined ring
  (:func:`repro.core.pipeline.mgg_aggregate`): the paper's MGG under the
  infinite-HBM assumption.
* **tiered**  — the memory-bound regime made real: features live in a
  host :class:`repro.store.FeatureStore`, the device holds a bounded
  :class:`~repro.store.HotFeatureCache` (hottest = highest-degree rows),
  and :func:`~repro.core.pipeline.mgg_aggregate_streamed` overlaps the
  host→device row gather for chunk *i+1* with the in-flight ring
  ppermute for chunk *i* (double-buffered prefetch).
* **uvm**     — page-granular fetch-then-aggregate with no overlap (the
  §2.2 access pattern): each device pulls whole 64 KB "pages" of remote
  rows before computing.

We report wall-clock per aggregation epoch on the CPU backend plus the
modeled TPU-term speedups at the REAL dataset size; the paper measures
3.16× (GCN) / 4.15× (GIN) on A100s.  The CPU wall-clock CANNOT show
overlap (one core serializes compute, "comm", and the host gather), so
the hardware terms carry the claim: UVM pays fault handling + page-waste
bytes serially, tiered pays only the *exposed* part of the host gather
(fill + whatever the ring cannot hide), resident pays nothing.

``--smoke`` (wired into ``benchmarks/run.py --smoke`` → CI) shrinks to a
tiny graph on 2 devices and asserts the tentpole's acceptance criteria:
the tiered forward is bitwise-identical to the all-resident streamed
forward when capacity covers the working set, prefetch actually issues
(dist−1 per call), and the modeled tiered latency strictly beats the
modeled UVM baseline.
"""
from __future__ import annotations

import sys

from benchmarks._common import (emit, force_devices_from_env, sample_fields,
                                timeit)

force_devices_from_env()

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

import repro.core as C  # noqa: E402
from repro.core.autotune import TPU_V5E as HW  # noqa: E402
from repro.core.pipeline import mgg_aggregate_streamed  # noqa: E402
from repro.dist import flat_ring_mesh  # noqa: E402
from repro.store import FeatureStore, TieredFeatures  # noqa: E402

PAGE_ROWS = 16  # ≈64 KB pages / (dim · 4 B), the paper's migration granularity


def _uvm_epoch(g, x, n_dev, layers):
    fp = C.build_fetch_plan(g, n_dev, ps=16, page_rows=PAGE_ROWS)
    bounds = C.edge_balanced_node_split(g.indptr, n_dev)
    rows = fp["rows_per_dev"]
    xb = jnp.asarray(C.pad_table(bounds, rows, x))

    @jax.jit
    def epoch(z):
        for _ in range(layers):
            out = C.fetch_rows_aggregate(
                z, fp["fetch_rows"], fp["nbrs"], fp["mask"], fp["targets"],
                rows)
            z = out.reshape(z.shape)
        return z

    return timeit(epoch, xb), fp


def _mgg_epoch(g, x, n_dev, mesh, layers, ps=16, dist=2):
    plan = C.build_plan(g, n_dev, ps=ps, dist=dist)
    xb = jnp.asarray(C.pad_embeddings(plan, x))

    @jax.jit
    def epoch(z):
        for _ in range(layers):
            z = C.mgg_aggregate(z, plan, mesh, interleave=True)
        return z

    return timeit(epoch, xb), plan


def _tiered_setup(g, x, mesh, plan, capacity, axis="ring"):
    """Host store + device hot cache over ``plan``; hottest-by-degree
    rows admitted (aggregation touches every row, so degree IS the touch
    count — the serving path uses the live request histogram instead)."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    shard = lambda a: jax.device_put(a, NamedSharding(mesh, P(axis, None)))
    tiers = TieredFeatures(FeatureStore(x), plan, capacity, shard=shard)
    if capacity:
        hot = np.argsort(-g.degrees)[:capacity]
        tiers.admit(hot.tolist())
    return tiers


def _tiered_epoch(g, x, n_dev, mesh, layers, capacity, ps=16, dist=2):
    plan = C.build_plan(g, n_dev, ps=ps, dist=dist)
    tiers = _tiered_setup(g, x, mesh, plan, capacity)
    stats = dict(prefetch_issued=0, prefetch_inflight=0)

    def epoch():
        # layer 1 streams from the tiers; deeper layers consume the
        # previous layer's device-resident output (standard ring) — the
        # raw-feature table is the memory-bound tier, activations are not
        z = mgg_aggregate_streamed(tiers.chunk_fetcher(), plan, mesh,
                                   stats=stats)
        for _ in range(layers - 1):
            z = C.mgg_aggregate(z, plan, mesh, interleave=True)
        return z

    return timeit(epoch), tiers, stats, epoch


def _modeled_terms(meta, n_dev, waste, resident_frac, dist=2):
    """TPU-term latencies at the real dataset size (per layer-1 pass)."""
    e, v, dim = meta["real_edges"], meta["real_nodes"], int(meta["dim"])
    comp = 2 * e * dim * 4 / n_dev / HW.hbm_bw
    comm = v * dim * 4 / n_dev / HW.link_bw      # ring, exact rows
    t_resident = max(comm, comp) + comm / n_dev  # overlap + fill
    # tiered: cold rows stream from host, overlapped chunk-by-chunk with
    # the ring; only the pipeline fill + un-hidden tail is exposed
    t_gather = (1.0 - resident_frac) * v * dim * 4 / n_dev / HW.host_bw
    fill = t_gather / max(1, dist)
    t_tiered = t_resident + fill + max(0.0, (t_gather - fill) - t_resident)
    # UVM's dominant cost is page-FAULT handling, not bandwidth (paper
    # Fig. 3: fault count/duration grow with GPU count); ~30 µs per
    # 64 KB page migration, demand-paged, zero overlap
    comm_uvm = waste * v * dim * 4 / n_dev / HW.link_bw
    pages = waste * v * dim * 4 / n_dev / 65536
    t_uvm = comm_uvm + comp + pages * 30e-6
    return t_resident, t_tiered, t_uvm


def _smoke() -> list:
    """CI: tiny graph, 2 devices — assert the tentpole's guarantees."""
    n_dev = len(jax.devices())
    mesh = flat_ring_mesh(n_dev)
    g, meta = C.paper_dataset("products", scale=0.02)
    rng = np.random.default_rng(0)
    x = rng.normal(size=(g.num_nodes, 8)).astype(np.float32)
    dist = 2
    plan = C.build_plan(g, n_dev, ps=8, dist=dist)

    def streamed(capacity):
        tiers = _tiered_setup(g, x, mesh, plan, capacity)
        stats = dict(prefetch_issued=0, prefetch_inflight=0)
        out = mgg_aggregate_streamed(tiers.chunk_fetcher(), plan, mesh,
                                     stats=stats)
        return np.asarray(out), stats, tiers

    full, s_full, _ = streamed(g.num_nodes)      # capacity ⊇ working set
    part, s_part, tiers = streamed(g.num_nodes // 3)
    none, _, _ = streamed(0)                     # stream everything
    assert np.array_equal(full, part) and np.array_equal(full, none), \
        "tiered forward not bitwise-identical across capacities"
    assert s_full["prefetch_issued"] == dist - 1, s_full
    assert s_part["prefetch_issued"] == dist - 1, s_part
    # vs the all-resident ring: same sum, streamed chunk order (tolerance)
    xb = jnp.asarray(C.pad_embeddings(plan, x))
    res = np.asarray(C.mgg_aggregate(xb, plan, mesh, interleave=True))
    np.testing.assert_allclose(full, res, rtol=2e-5, atol=2e-5)
    # modeled regime: tiered strictly beats the UVM baseline
    fp = C.build_fetch_plan(g, n_dev, ps=16, page_rows=PAGE_ROWS)
    exact = C.build_fetch_plan(g, n_dev, ps=16, page_rows=1)
    waste = (np.mean(fp["fetched_rows_per_dev"])
             / max(1.0, np.mean(exact["fetched_rows_per_dev"])))
    frac = tiers.resident_fraction
    t_res, t_tier, t_uvm = _modeled_terms(meta, n_dev, waste, frac,
                                          dist=dist)
    assert t_tier < t_uvm, f"tiered {t_tier} not faster than UVM {t_uvm}"
    assert t_res <= t_tier, "resident must lower-bound tiered"
    return [dict(name="fig8_smoke", us_per_call=0.0,
                 derived=(f"bitwise=ok;prefetch_issued={dist - 1};"
                          f"resident_frac={frac:.2f};"
                          f"modeled_tiered_vs_uvm={t_uvm / t_tier:.2f}x"))]


def run(as_json: bool, smoke: bool = False) -> list:
    if smoke:
        return _smoke()
    n_dev = len(jax.devices())
    mesh = flat_ring_mesh(n_dev)
    rows = []
    for model, layers in (("gcn", 2), ("gin", 5)):
        for name in ("reddit", "enwiki", "products", "proteins", "orkut"):
            g, meta = C.paper_dataset(name, scale=0.35)
            d = min(int(meta["dim"]), 128)
            x = np.random.default_rng(0).normal(
                size=(g.num_nodes, d)).astype(np.float32)
            t_uvm, fp = _uvm_epoch(g, x, n_dev, layers)
            t_mgg, plan = _mgg_epoch(g, x, n_dev, mesh, layers)
            cap = g.num_nodes // 4
            t_tier, tiers, pstats, _ = _tiered_epoch(
                g, x, n_dev, mesh, layers, cap)
            # modeled fetch-volume ratio (the paper's mechanism: page waste)
            exact = C.build_fetch_plan(g, n_dev, ps=16, page_rows=1)
            waste = (np.mean(fp["fetched_rows_per_dev"])
                     / max(1.0, np.mean(exact["fetched_rows_per_dev"])))
            t_res_hw, t_tier_hw, t_uvm_hw = _modeled_terms(
                meta, n_dev, waste, tiers.resident_fraction)
            rows.append(dict(
                name=f"fig8_{model}_{name}",
                us_per_call=round(t_mgg * 1e6, 1),
                **sample_fields(t_mgg),
                derived=(f"uvm_us={t_uvm*1e6:.1f};"
                         f"tiered_us={t_tier*1e6:.1f};"
                         f"cpu_ratio={t_uvm/t_mgg:.2f};"
                         f"page_waste={waste:.2f}x;"
                         f"feat_hit_rate={tiers.cache.hit_rate:.2f};"
                         f"prefetch_issued={pstats['prefetch_issued']};"
                         f"modeled_tpu_speedup={t_uvm_hw/t_res_hw:.2f};"
                         f"modeled_tiered_speedup={t_uvm_hw/t_tier_hw:.2f}")))
    return rows


if __name__ == "__main__":
    emit(run("--json" in sys.argv, smoke="--smoke" in sys.argv),
         "--json" in sys.argv)
