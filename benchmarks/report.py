"""EXPERIMENTS.md generator: §Dry-run + §Roofline from dry-run artifacts,
§Perf from the hillclimb log (experiments/perf/*.json), §Paper-claims from
bench_output.txt when present.

    PYTHONPATH=src python -m benchmarks.report        # rewrites EXPERIMENTS.md
"""
from __future__ import annotations

import glob
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from benchmarks import roofline  # noqa: E402

ROOT = os.path.join(os.path.dirname(__file__), "..")
PERF_DIR = os.path.join(ROOT, "experiments", "perf")

PREAMBLE = """\
# EXPERIMENTS

System: MGG (fine-grained communication–computation pipelining) on TPU —
see DESIGN.md for the paper→TPU mapping.  Hardware model: TPU v5e
(197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI).  This container is
CPU-only: dry-runs lower+compile the production meshes with 512 forced
host devices; wall-clock numbers below come from 8-device CPU rings and
are structural (relative) evidence, while the roofline terms are derived
from the compiled artifacts.

Measurement conventions:
* `cost_analysis` runs on the SPMD-partitioned per-chip module ⇒ FLOPs /
  bytes are **per chip**; the brief's `collective_bytes/(chips·link_bw)`
  with global bytes equals our `per_chip_bytes / link_bw`.
* XLA counts while-loop bodies ONCE; all numbers below are re-derived with
  loop-trip multiplication by `repro.launch.hlo_cost` (oracle-tested in
  tests/test_hlo_cost.py).  FLOPs = dot FLOPs (MXU term); bytes = operand+
  result bytes at fusion boundaries (an HBM-traffic proxy: real TPU fusion
  is coarser than CPU fusion, so the memory term is an upper bound).
* `memory_analysis` on the CPU backend reports per-host-module sizes;
  shown for completeness, not used for the roofline.
* MODEL_FLOPS/HLO ratios > 1 (zamba2) mean the 6·N·D proxy under-counts
  real compute there (Mamba2's intra-chunk quadratic SSD term is not
  parameter-tied); < 1 means remat/dispatch/padding overhead.

## GNN engine on the production mesh

The paper's own workload also passes the production-scale gate
(`repro.launch.dryrun_gnn`): the pipelined ring aggregation for a
reddit-stand-in GCN layer lowers + compiles on the flattened 256-chip ring
(255 collective-permutes) and the 512-chip multi-pod ring (511), with the
HLO-parsed collective bytes matching the analytical model EXACTLY
(35,614,320 B at 256; 39,375,616 B at 512 — `collective_bytes(plan, D)`).
Terms at 256 chips, D=602: memory 0.92 ms vs collective 0.71 ms per layer
— the near-balanced regime where MGG's overlap converts comm+comp
(1.63 ms) into max(comm, comp) (0.92 ms), a 1.77× layer-time win; this is
the paper's Fig. 7(b) claim expressed in roofline terms at pod scale.

## §Paper-claims (reproduction vs the paper's own numbers)

The GNN engine reproduces the paper's experiments on scaled structural
stand-ins of its five datasets (Table 3) on an 8-device ring; see
bench_output.txt for the full CSV.  Paper-claim correspondence:

| paper claim | our measurement (bench_output.txt) |
|---|---|
| Fig. 2: bulk comm ≫ aggregation compute | `fig2_*`: measured CPU-ring ratio + modeled TPU-term ratio |
| Table 1: direct fine-grained fetch is NOT automatically faster than batched (0.77× gmean) | `table1_*`: direct vs page-batched fetch ratios |
| Fig. 8: MGG 3.16×/4.15× vs UVM (GCN/GIN) | `fig8_*`: pipelined ring vs page-fetch baseline + page-waste factor |
| Table 4: 7.38× vs DGCL, >100× faster preprocessing | `table4_*`: vs allgather-then-aggregate + Alg.1 vs spectral partitioning time |
| Fig. 9a: 3.47× from neighbor partitioning | `fig9a_*` |
| Fig. 9b: 1.32× from interleaving | `fig9b_*` |
| Fig. 10: ~10-trial autotune, up to 68% | `fig10_*` trials/improvement/gap-to-grid |
| Table 5: 2–5% accuracy gain w/o sampling | `table5_*` |

"""


PERF_SUMMARY = """\
### §Perf summary — paper-faithful baseline vs beyond-paper optimized

Three hillclimbed cells (worst roofline fraction / most collective-bound /
most technique-representative), binding-term seconds per step on the
single-pod mesh, plus the paper-side GNN engine:

| cell | paper-faithful baseline | optimized | gain | what changed |
|---|---|---|---|---|
| granite-moe-1b × train_4k (technique) | dot 2.09e14 FLOP/chip (useful 0.04) | dot 2.02e13, a2a pipelined ×4, capacity 1.0 | 10.3× less compute, −9% ICI | EP token sharding + MGG-chunked a2a + capacity |
| mixtral-8x7b × prefill_32k (worst frac) | dot 1.93e15, coll 2.76e12 B | dot 1.98e14, coll 3.00e11 B | 9.7× / 9.2× | dispatch-buffer sharding anchors |
| xlstm-125m × train_4k (pathological mem) | 6.52e14 B/chip, 24.6k per-step all-reduces | 4.97e13 B (+ modeled 21× on the sLSTM share via the Pallas fused scan) | 13.1× bytes | family-aware act sharding + VMEM-resident recurrence kernel |
| zamba2-7b × train_4k (same fix) | 3.19e14 B/chip | 5.02e13 B | 6.4× | family-aware act sharding |
| GNN reddit-GCN 8-dev ring (paper side) | 415 ms naive | 3.2 ms (+partitioning, +interleave, +autotune) | 128× vs naive; ablation ratios match paper Fig. 9/10 | the paper's own §3 recipe |

Further beyond-paper kernels validated in interpret mode and available to
all cells: Pallas flash attention (GQA + sliding window; O(S·d) HBM per
head instead of O(S²) score blocks — `cfg.use_flash_attention`) and the
fused sLSTM scan; the scalar-prefetch neighbor-gather kernel IS the
paper's async-GET pipeline expressed as a Pallas BlockSpec index_map.

Stopping criterion: the last iterations on each cell (capacity step,
bf16-gather attempt [refuted], SP-off negative control [refuted]) each
moved the dominant term <5%; three consecutive <5% changes ⇒ stop per the
§Perf protocol.

### End-to-end runnability evidence

* `examples/train_lm.py` — xlstm-125m (~124M real params) trained **300
  steps** on CPU with the fault-tolerant Trainer; loss 11.29 → ~4.5
  (experiments/train_lm_125m.log).  The fault-tolerance machinery fired in
  anger, not in a drill: the run was interrupted twice and resumed from
  the atomic checkpoints ("[trainer] restored step 50/100"), an accidental
  second trainer instance raced on the same checkpoint directory without
  corruption (atomic tmp→rename commits), and the straggler watchdog
  flagged 2 slow steps ("stragglers=2").
* `examples/train_gnn.py` — full-graph GCN on the 8-device ring engine.
* `examples/serve_lm.py` — wave-batched prefill+decode serving.
* multi-device correctness: tests/multidev/* (8-device shard_map
  equivalence vs oracle, collectives, e2e GCN training).
"""


def perf_section() -> str:
    lines = [PERF_SUMMARY, "## §Perf — hillclimbing log\n"]
    files = sorted(glob.glob(os.path.join(PERF_DIR, "*.json")))
    if not files:
        return "\n".join(lines + ["(no perf iterations recorded yet)", ""])
    by_cell = {}
    for f in files:
        e = json.load(open(f))
        by_cell.setdefault(e["cell"], []).append(e)
    for cell, entries in by_cell.items():
        lines.append(f"### {cell}\n")
        for e in sorted(entries, key=lambda x: x["iteration"]):
            lines.append(f"**Iteration {e['iteration']} — {e['title']}**")
            lines.append(f"- hypothesis: {e['hypothesis']}")
            lines.append(f"- change: {e['change']}")
            lines.append(f"- before: {e['before']}")
            lines.append(f"- after: {e['after']}")
            lines.append(f"- verdict: **{e['verdict']}** — {e['lesson']}")
            lines.append("")
    return "\n".join(lines)


def main() -> None:
    md = [PREAMBLE]
    md.append("## §Dry-run and §Roofline\n")
    md.append(roofline.markdown_tables())
    md.append("")
    md.append(perf_section())
    out = os.path.join(ROOT, "EXPERIMENTS.md")
    with open(out, "w") as f:
        f.write("\n".join(md))
    print(f"wrote {out}")


if __name__ == "__main__":
    main()
