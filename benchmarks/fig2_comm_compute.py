"""Paper Fig. 2: bulk communication dominates aggregation compute in a
1-layer ring-forwarding GNN (the NCCL baseline pattern).

We rebuild the paper's microbenchmark: every device holds a node-embedding
shard; a "NCCL-style" layer all-gathers the full table, then aggregates.
Reported: comm time, compute time, and their ratio (paper: >5× on reddit /
enwiki with real NVLink; the CPU-backend ratio differs numerically but the
structural comparison — and the roofline-term version computed from the
plan — reproduce the paper's conclusion).
"""
from __future__ import annotations

import sys

from benchmarks._common import emit, force_devices_from_env, timeit

force_devices_from_env()

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

import repro.core as C  # noqa: E402
from repro.core.autotune import TPU_V5E  # noqa: E402
from repro.dist import flat_ring_mesh  # noqa: E402


def run(as_json: bool) -> list:
    n_dev = len(jax.devices())
    mesh = flat_ring_mesh(n_dev)
    rows = []
    for name in ("reddit", "enwiki"):
        g, meta = C.paper_dataset(name, scale=0.5)
        d = int(meta["dim"])
        x = np.random.default_rng(0).normal(
            size=(g.num_nodes, d)).astype(np.float32)
        nbrs, mask, tgt, rpd = C.build_bulk_plan(g, n_dev, ps=16)
        bounds = C.edge_balanced_node_split(g.indptr, n_dev)
        xb = jnp.asarray(C.pad_table(bounds, rpd, x))

        # comm only: all-gather the full table
        gather = jax.jit(jax.shard_map(
            lambda z: jax.lax.all_gather(z, "ring", axis=0, tiled=True),
            mesh=mesh, in_specs=P("ring"), out_specs=P(None),
            check_vma=False))
        t_comm = timeit(gather, xb)

        # compute only: aggregation against a local (already gathered) table
        full = jnp.asarray(np.asarray(gather(xb)))
        agg = jax.jit(lambda f: C.fetch_rows_aggregate(
            f, np.arange(n_dev * rpd, dtype=np.int32)[None, :].repeat(
                n_dev, 0), nbrs, mask, tgt, rpd))
        t_comp = timeit(agg, full)

        ratio = t_comm / t_comp
        rows.append(dict(
            name=f"fig2_{name}_comm", us_per_call=round(t_comm * 1e6, 1),
            derived=f"ratio_comm_over_comp={ratio:.2f}"))
        rows.append(dict(
            name=f"fig2_{name}_comp", us_per_call=round(t_comp * 1e6, 1),
            derived=""))
        # roofline-term version on the paper's REAL sizes + target hardware
        e = meta["real_edges"]
        v = meta["real_nodes"]
        bytes_comm = v * d * 4  # full table over the interconnect
        bytes_comp = 2 * e * d * 4
        t_comm_hw = bytes_comm / TPU_V5E.link_bw
        t_comp_hw = bytes_comp / (n_dev * TPU_V5E.hbm_bw)
        rows.append(dict(
            name=f"fig2_{name}_modeled", us_per_call="",
            derived=f"hw_ratio={t_comm_hw / t_comp_hw:.2f}"))
    return rows


if __name__ == "__main__":
    emit(run("--json" in sys.argv), "--json" in sys.argv)
