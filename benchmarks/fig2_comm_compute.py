"""Paper Fig. 2: bulk communication dominates aggregation compute in a
1-layer ring-forwarding GNN (the NCCL baseline pattern).

We rebuild the paper's microbenchmark: every device holds a node-embedding
shard; a "NCCL-style" layer all-gathers the full table, then aggregates.
Reported: comm time, compute time, and their ratio (paper: >5× on reddit /
enwiki with real NVLink; the CPU-backend ratio differs numerically but the
structural comparison — and the roofline-term version computed from the
plan — reproduce the paper's conclusion).
"""
from __future__ import annotations

import sys

from benchmarks._common import (emit, force_devices_from_env, sample_fields,
                                timeit)

force_devices_from_env()

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

import repro.core as C  # noqa: E402
from repro.core.autotune import TPU_V5E  # noqa: E402
from repro.dist import (ef_allreduce_mean, ef_state_init,  # noqa: E402
                        flat_ring_mesh)


def _ef_gradient_rows(mesh, n_dev: int) -> list:
    """Wire-byte reduction of the error-feedback int8 gradient allreduce
    (the train/trainer.py ``ef_bits`` path) vs the fp32 reduce it replaces.

    The payload is a GIN-sized gradient tree (paper setting: 5 layers, 64
    hidden on reddit's 602-dim features).  Wire bytes are the ring
    allreduce's 2·(n−1)/n·payload per device; the int8 format also ships
    one fp32 scale per tensor.  Measured wall times on the fake-CPU ring
    show the same step executing; the byte accounting is the paper-scale
    comparison.
    """
    from jax.sharding import PartitionSpec as P

    rng = np.random.default_rng(0)
    dims = [(602, 64)] + [(64, 64)] * 9 + [(64, 41)]
    grads = {f"w{i}": jnp.asarray(rng.normal(size=s), jnp.float32)
             for i, s in enumerate(dims)}
    n_elems = sum(int(np.prod(s)) for s in dims)
    ring_factor = 2 * (n_dev - 1) / max(1, n_dev)  # 0 on 1 device: no wire
    bytes_fp32 = int(n_elems * 4 * ring_factor)
    bytes_int8 = int((n_elems * 1 + len(dims) * 4) * ring_factor)
    # payload ratio (ring-factor cancels; well-defined even on 1 device)
    reduction = n_elems * 4 / (n_elems + len(dims) * 4)

    specs = jax.tree.map(lambda _: P(), grads)
    err = ef_state_init(grads)
    t_ef = timeit(jax.jit(lambda g, e: ef_allreduce_mean(
        g, e, mesh, ("ring",), specs)), grads, err)
    plain = jax.jit(jax.shard_map(
        lambda g: jax.tree.map(lambda v: jax.lax.pmean(v, "ring"), g),
        mesh=mesh, in_specs=(specs,), out_specs=specs, check_vma=False))
    t_plain = timeit(plain, grads)
    return [dict(
        name="fig2_ef_gradient_wire", us_per_call=round(t_ef * 1e6, 1),
        **sample_fields(t_ef),
        derived=(f"fp32_wire_bytes={bytes_fp32};int8_wire_bytes={bytes_int8};"
                 f"reduction={reduction:.2f}x;"
                 f"plain_us={t_plain*1e6:.1f};"
                 f"hw_us_fp32={bytes_fp32 / TPU_V5E.link_bw * 1e6:.1f};"
                 f"hw_us_int8={bytes_int8 / TPU_V5E.link_bw * 1e6:.1f}"))]


def run(as_json: bool) -> list:
    n_dev = len(jax.devices())
    mesh = flat_ring_mesh(n_dev)
    rows = []
    for name in ("reddit", "enwiki"):
        g, meta = C.paper_dataset(name, scale=0.5)
        d = int(meta["dim"])
        x = np.random.default_rng(0).normal(
            size=(g.num_nodes, d)).astype(np.float32)
        nbrs, mask, tgt, rpd = C.build_bulk_plan(g, n_dev, ps=16)
        bounds = C.edge_balanced_node_split(g.indptr, n_dev)
        xb = jnp.asarray(C.pad_table(bounds, rpd, x))

        # comm only: all-gather the full table
        gather = jax.jit(jax.shard_map(
            lambda z: jax.lax.all_gather(z, "ring", axis=0, tiled=True),
            mesh=mesh, in_specs=P("ring"), out_specs=P(None),
            check_vma=False))
        t_comm = timeit(gather, xb)

        # compute only: aggregation against a local (already gathered) table
        full = jnp.asarray(np.asarray(gather(xb)))
        agg = jax.jit(lambda f: C.fetch_rows_aggregate(
            f, np.arange(n_dev * rpd, dtype=np.int32)[None, :].repeat(
                n_dev, 0), nbrs, mask, tgt, rpd))
        t_comp = timeit(agg, full)

        ratio = t_comm / t_comp
        rows.append(dict(
            name=f"fig2_{name}_comm", us_per_call=round(t_comm * 1e6, 1),
            **sample_fields(t_comm),
            derived=f"ratio_comm_over_comp={ratio:.2f}"))
        rows.append(dict(
            name=f"fig2_{name}_comp", us_per_call=round(t_comp * 1e6, 1),
            **sample_fields(t_comp), derived=""))
        # roofline-term version on the paper's REAL sizes + target hardware
        e = meta["real_edges"]
        v = meta["real_nodes"]
        bytes_comm = v * d * 4  # full table over the interconnect
        bytes_comp = 2 * e * d * 4
        t_comm_hw = bytes_comm / TPU_V5E.link_bw
        t_comp_hw = bytes_comp / (n_dev * TPU_V5E.hbm_bw)
        rows.append(dict(
            name=f"fig2_{name}_modeled", us_per_call="",
            derived=f"hw_ratio={t_comm_hw / t_comp_hw:.2f}"))
    rows.extend(_ef_gradient_rows(mesh, n_dev))
    return rows


if __name__ == "__main__":
    emit(run("--json" in sys.argv), "--json" in sys.argv)
