"""Benchmark helpers.

The main benchmark process sees exactly ONE CPU device (per the brief).
Multi-device measurements therefore run in subprocesses that set
``--xla_force_host_platform_device_count`` before importing jax; each
benchmark module doubles as that subprocess entry point (``--json`` mode).

Timing helpers return :class:`TimingSample` — a float (the median, so
every ``round(t * 1e6, 1)`` call site is unchanged) that also carries the
raw per-iteration samples.  Rows splat ``**sample_fields(t)`` to persist
``us_median`` / ``us_mad`` / ``samples_us`` into the snapshot (schema v2),
which is what lets ``benchmarks/diff.py`` express its regression threshold
in MAD multiples instead of raw percentages.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time
from typing import Callable, Dict, List, Optional, Sequence

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

SNAPSHOT_SCHEMA = 2
# raw samples persisted per row are capped (fig11 rows reduce hundreds of
# request latencies; median/MAD stay exact over the full set)
MAX_STORED_SAMPLES = 32


def run_subprocess(module: str, devices: int = 8,
                   args: Optional[List[str]] = None,
                   timeout: int = 1200) -> List[Dict]:
    """Run ``python -m benchmarks.<module> --json`` with N fake devices."""
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + os.path.dirname(SRC)
    env["BENCH_DEVICES"] = str(devices)
    r = subprocess.run(
        [sys.executable, "-m", f"benchmarks.{module}", "--json"]
        + (args or []),
        capture_output=True, text=True, env=env, timeout=timeout,
        cwd=os.path.join(os.path.dirname(__file__), ".."),
    )
    if r.returncode != 0:
        raise RuntimeError(
            f"benchmarks.{module} failed:\n{r.stdout}\n{r.stderr}")
    # last JSON line of stdout
    for line in reversed(r.stdout.strip().splitlines()):
        line = line.strip()
        if line.startswith("[") or line.startswith("{"):
            return json.loads(line)
    raise RuntimeError(f"no JSON in output of {module}:\n{r.stdout}")


def force_devices_from_env() -> None:
    """Subprocess entry: honor BENCH_DEVICES before jax import."""
    n = os.environ.get("BENCH_DEVICES")
    if n and "jax" not in sys.modules:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={n}")


class TimingSample(float):
    """A median latency (seconds) that remembers its raw samples.

    Subclassing float keeps every existing ``round(t * 1e6, 1)`` /
    arithmetic call site working; ``sample_fields(t)`` extracts the
    snapshot-v2 robustness fields.
    """

    samples: List[float]

    def __new__(cls, samples: Sequence[float]):
        ss = sorted(float(s) for s in samples)
        if not ss:
            raise ValueError("TimingSample needs at least one sample")
        self = super().__new__(cls, ss[len(ss) // 2])
        self.samples = ss
        return self


def median_mad_us(samples_s: Sequence[float]) -> Dict[str, float]:
    """Median and median-absolute-deviation of samples, in microseconds."""
    ss = sorted(float(s) for s in samples_s)
    med = ss[len(ss) // 2]
    dev = sorted(abs(s - med) for s in ss)
    mad = dev[len(dev) // 2]
    return {"us_median": round(med * 1e6, 3), "us_mad": round(mad * 1e6, 3)}


def sample_stats(samples_s: Sequence[float]) -> Dict:
    """Snapshot-v2 row fields from raw per-iteration seconds."""
    ss = [float(s) for s in samples_s]
    out = median_mad_us(ss)
    out["iters"] = len(ss)
    out["samples_us"] = [round(s * 1e6, 3)
                         for s in sorted(ss)[:MAX_STORED_SAMPLES]]
    return out


def sample_fields(t) -> Dict:
    """Row fields for a :func:`timeit` result; `{}` for a bare float."""
    if isinstance(t, TimingSample):
        return sample_stats(t.samples)
    return {}


def timeit(fn: Callable, *args, warmup: int = 2, iters: int = 5) -> TimingSample:
    """Median wall-clock seconds per call (after warmup, block_until_ready).

    Returns a :class:`TimingSample` so callers can persist the raw
    per-iteration samples alongside the median.
    """
    import jax
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    return TimingSample(times)


def machine_fingerprint() -> dict:
    """Identify the machine a snapshot was measured on.

    Enough to tell two snapshots apart — and, for ``benchmarks/diff.py``,
    to decide whether a row-by-row latency comparison is meaningful at
    all: ``backend`` / ``device_kind`` / ``device_count`` must match
    (host memory and accelerator memory are recorded for the report, not
    the compatibility check).
    """
    import multiprocessing
    import platform

    fp = {
        "hostname": platform.node(),
        "platform": platform.platform(),
        "machine": platform.machine(),
        "python": platform.python_version(),
        "cpu_count": multiprocessing.cpu_count(),
    }
    try:
        fp["host_memory_bytes"] = (os.sysconf("SC_PAGE_SIZE")
                                   * os.sysconf("SC_PHYS_PAGES"))
    except (ValueError, OSError, AttributeError):
        pass
    try:
        import jax
        devs = jax.devices()
        fp["jax"] = jax.__version__
        fp["backend"] = jax.default_backend()
        fp["device_kind"] = devs[0].device_kind
        fp["device_count"] = len(devs)
        try:  # accelerator memory: absent on CPU backends
            stats = devs[0].memory_stats() or {}
            if "bytes_limit" in stats:
                fp["device_memory_bytes"] = int(stats["bytes_limit"])
        except Exception:
            pass
    except Exception:
        pass
    return fp


def write_snapshot(path: str, rows_by_module: dict, args: dict) -> None:
    """Write a schema-v2 perf snapshot (UTC ISO-8601 stamp)."""
    snap = {
        "schema": SNAPSHOT_SCHEMA,
        "stamp": time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime()),
        "machine": machine_fingerprint(),
        "args": dict(args),
        "modules": rows_by_module,
    }
    d = os.path.dirname(path)
    if d:
        os.makedirs(d, exist_ok=True)
    with open(path, "w") as f:
        json.dump(snap, f, indent=2, sort_keys=True, default=str)
    print(f"# perf snapshot: {path}", file=sys.stderr)


def emit(rows: List[Dict], as_json: bool) -> None:
    if as_json:
        print(json.dumps(rows))
    else:
        for r in rows:
            print(f"{r['name']},{r.get('us_per_call', '')},"
                  f"{r.get('derived', '')}")
