"""Benchmark helpers.

The main benchmark process sees exactly ONE CPU device (per the brief).
Multi-device measurements therefore run in subprocesses that set
``--xla_force_host_platform_device_count`` before importing jax; each
benchmark module doubles as that subprocess entry point (``--json`` mode).
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time
from typing import Callable, Dict, List, Optional

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_subprocess(module: str, devices: int = 8,
                   args: Optional[List[str]] = None,
                   timeout: int = 1200) -> List[Dict]:
    """Run ``python -m benchmarks.<module> --json`` with N fake devices."""
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + os.path.dirname(SRC)
    env["BENCH_DEVICES"] = str(devices)
    r = subprocess.run(
        [sys.executable, "-m", f"benchmarks.{module}", "--json"]
        + (args or []),
        capture_output=True, text=True, env=env, timeout=timeout,
        cwd=os.path.join(os.path.dirname(__file__), ".."),
    )
    if r.returncode != 0:
        raise RuntimeError(
            f"benchmarks.{module} failed:\n{r.stdout}\n{r.stderr}")
    # last JSON line of stdout
    for line in reversed(r.stdout.strip().splitlines()):
        line = line.strip()
        if line.startswith("[") or line.startswith("{"):
            return json.loads(line)
    raise RuntimeError(f"no JSON in output of {module}:\n{r.stdout}")


def force_devices_from_env() -> None:
    """Subprocess entry: honor BENCH_DEVICES before jax import."""
    n = os.environ.get("BENCH_DEVICES")
    if n and "jax" not in sys.modules:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={n}")


def timeit(fn: Callable, *args, warmup: int = 2, iters: int = 5) -> float:
    """Median wall-clock seconds per call (after warmup, block_until_ready)."""
    import jax
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def emit(rows: List[Dict], as_json: bool) -> None:
    if as_json:
        print(json.dumps(rows))
    else:
        for r in rows:
            print(f"{r['name']},{r.get('us_per_call', '')},"
                  f"{r.get('derived', '')}")
