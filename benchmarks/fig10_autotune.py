"""Paper Fig. 10 / §5.3: the cross-iteration optimizer finds a near-optimal
(ps, dist, pb) in ~10 measured trials, vs an exhaustive grid.

Setting I analogue: reddit-GCN on the 8-device ring with *measured*
latencies as the objective.  Reported: trials used, latency of the found
config, best-in-grid latency, and the improvement over the (1,1,1) start
(paper: up to 68%).
"""
from __future__ import annotations

import sys

from benchmarks._common import emit, force_devices_from_env, timeit

force_devices_from_env()

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

import repro.core as C  # noqa: E402
from repro.dist import flat_ring_mesh  # noqa: E402

PS_SPACE = (1, 2, 4, 8, 16, 32)
DIST_SPACE = (1, 2, 4)
PB_SPACE = (1, 2, 4)


def run(as_json: bool) -> list:
    n_dev = len(jax.devices())
    mesh = flat_ring_mesh(n_dev)
    g, meta = C.paper_dataset("reddit", scale=0.2)
    d = 64
    x = np.random.default_rng(0).normal(
        size=(g.num_nodes, d)).astype(np.float32)
    cache = {}

    def measure(ps, dist, pb):
        key = (ps, dist, pb)
        if key not in cache:
            plan = C.build_plan(g, n_dev, ps=ps, dist=dist)
            xb = jnp.asarray(C.pad_embeddings(plan, x))
            fn = jax.jit(lambda z: C.mgg_aggregate(z, plan, mesh))
            cache[key] = timeit(fn, xb, warmup=1, iters=3)
        return cache[key]

    res = C.cross_iteration_optimize(
        measure, ps_space=PS_SPACE, dist_space=DIST_SPACE,
        pb_space=PB_SPACE)
    t_init = measure(1, 1, 1)
    # exhaustive grid over (ps, dist) at pb of the found config
    grid = {(ps, dist): measure(ps, dist, res.best["pb"])
            for ps in PS_SPACE for dist in DIST_SPACE}
    t_grid_best = min(grid.values())
    rows = [dict(
        name="fig10_reddit_setting1",
        us_per_call=round(res.best_latency * 1e6, 1),
        derived=(f"trials={res.num_trials};best={res.best};"
                 f"init_us={t_init*1e6:.1f};"
                 f"improvement={(1 - res.best_latency / t_init) * 100:.0f}%;"
                 f"grid_best_us={t_grid_best*1e6:.1f};"
                 f"gap_to_grid={res.best_latency / t_grid_best:.2f}"))]
    # the analytical-model-only search (zero measurements) for comparison
    w = C.WorkloadShape.from_graph(g, n_dev, d)
    res_m = C.cross_iteration_optimize(
        lambda ps, dist, pb: C.estimate_latency(w, ps, dist, pb),
        ps_space=PS_SPACE, dist_space=DIST_SPACE, pb_space=PB_SPACE)
    t_model_pick = measure(res_m.best["ps"], res_m.best["dist"],
                           res_m.best["pb"])
    rows.append(dict(
        name="fig10_model_only_pick",
        us_per_call=round(t_model_pick * 1e6, 1),
        derived=f"model_best={res_m.best};"
                f"gap_to_grid={t_model_pick / t_grid_best:.2f}"))
    return rows


if __name__ == "__main__":
    emit(run("--json" in sys.argv), "--json" in sys.argv)
