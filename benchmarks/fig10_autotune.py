"""Paper Fig. 10 / §5.3: the cross-iteration optimizer finds a near-optimal
(ps, dist, pb) in ~10 measured trials, vs an exhaustive grid.

Setting I analogue: reddit-GCN on the 8-device ring with *measured*
latencies as the objective.  Three searches are compared on the same
measured surface:

* ``fig10_reddit_setting1`` — the offline coordinate-descent helper
  (core.autotune.cross_iteration_optimize) driven by measurements;
* ``fig10_online_measured`` — the §4 *runtime* path: the incremental
  OnlineTuner fed one measurement at a time through
  repro.runtime.AggregateProfiler, exactly as a training loop would feed
  it (plus its stop-at-top-3 refinement);
* ``fig10_model_only_pick`` — the zero-measurement analytical-model
  search, evaluated on the measured surface (what you get for free).

Reported: trials used, found-config latency, best-in-grid latency, and the
improvement over the (1,1,1) start (paper: up to 68%).

``--smoke`` (used by ``benchmarks/run.py --smoke`` in CI) swaps in a tiny
synthetic graph and small search spaces so the whole module exercises in
seconds.
"""
from __future__ import annotations

import sys

from benchmarks._common import emit, force_devices_from_env, timeit

force_devices_from_env()

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

import repro.core as C  # noqa: E402
from repro.dist import flat_ring_mesh  # noqa: E402
from repro.runtime import AggregateProfiler, OnlineTuner, ProfileConfig  # noqa: E402

PS_SPACE = (1, 2, 4, 8, 16, 32)
DIST_SPACE = (1, 2, 4)
PB_SPACE = (1, 2, 4)

SMOKE_PS = (1, 2, 4)
SMOKE_DIST = (1, 2)
SMOKE_PB = (1, 2)


def run(as_json: bool, smoke: bool = False) -> list:
    n_dev = len(jax.devices())
    mesh = flat_ring_mesh(n_dev)
    if smoke:
        g = C.power_law(512, avg_degree=8.0, locality=0.4, seed=0)
        d = 16
        ps_space, dist_space, pb_space = SMOKE_PS, SMOKE_DIST, SMOKE_PB
        prof_cfg = ProfileConfig(warmup=1, iters=2)
    else:
        g, meta = C.paper_dataset("reddit", scale=0.2)
        d = 64
        ps_space, dist_space, pb_space = PS_SPACE, DIST_SPACE, PB_SPACE
        prof_cfg = ProfileConfig(warmup=1, iters=3)

    # one shared measurement table so all three searches see the same
    # surface (AggregateProfiler memoizes per config)
    profiler = AggregateProfiler(g, mesh, d, profile=prof_cfg, mode="measure")
    measure = profiler

    res = C.cross_iteration_optimize(
        measure, ps_space=ps_space, dist_space=dist_space,
        pb_space=pb_space)
    t_init = measure(1, 1, 1)
    # exhaustive grid over (ps, dist) at pb of the found config
    grid = {(ps, dist): measure(ps, dist, res.best["pb"])
            for ps in ps_space for dist in dist_space}
    t_grid_best = min(grid.values())
    rows = [dict(
        name="fig10_reddit_setting1",
        us_per_call=round(res.best_latency * 1e6, 1),
        derived=(f"trials={res.num_trials};best={res.best};"
                 f"init_us={t_init*1e6:.1f};"
                 f"improvement={(1 - res.best_latency / t_init) * 100:.0f}%;"
                 f"grid_best_us={t_grid_best*1e6:.1f};"
                 f"gap_to_grid={res.best_latency / t_grid_best:.2f}"))]

    # --- the online runtime path: same search, fed incrementally ----------
    tuner = OnlineTuner(ps_space, dist_space, pb_space)
    while not tuner.converged:
        cfg = tuner.propose()
        tuner.observe(measure(cfg["ps"], cfg["dist"], cfg["pb"]))
    traj = ";".join(f"{lat*1e6:.0f}" for _c, lat in tuner.trajectory)
    rows.append(dict(
        name="fig10_online_measured",
        us_per_call=round(tuner.best_latency * 1e6, 1),
        derived=(f"trials={tuner.measured};best={tuner.best};"
                 f"improvement={(1 - tuner.best_latency / t_init) * 100:.0f}%;"
                 f"gap_to_grid={tuner.best_latency / t_grid_best:.2f};"
                 f"traj_us={traj}")))

    # the analytical-model-only search (zero measurements) for comparison
    w = profiler.workload_shape()
    res_m = C.cross_iteration_optimize(
        lambda ps, dist, pb: C.estimate_latency(w, ps, dist, pb),
        ps_space=ps_space, dist_space=dist_space, pb_space=pb_space)
    t_model_pick = measure(res_m.best["ps"], res_m.best["dist"],
                           res_m.best["pb"])
    rows.append(dict(
        name="fig10_model_only_pick",
        us_per_call=round(t_model_pick * 1e6, 1),
        derived=f"model_best={res_m.best};"
                f"gap_to_grid={t_model_pick / t_grid_best:.2f}"))

    # --- calibration: fit the analytical model to the measured surface ----
    # every config the searches measured (the profiler's memo table +
    # the online tuner's audit trail) becomes a fit observation
    from repro.obs.calibrate import fit_spec
    obs = profiler.observations() + tuner.observations()
    cal = fit_spec(w, obs)
    scales = {k: round(v, 4) for k, v in cal.scales.items() if v != 1.0}
    rows.append(dict(
        name="fig10_calibration",
        us_per_call=0.0,
        derived=(f"n_obs={cal.n_observations};"
                 f"stock_err={cal.base_error:.3f};"
                 f"calibrated_err={cal.error:.3f};"
                 f"scales={scales}")))
    if smoke:
        # the fit grid contains the identity scale: never worse than stock
        assert cal.error <= cal.base_error, (cal.error, cal.base_error)
    return rows


if __name__ == "__main__":
    emit(run("--json" in sys.argv, smoke="--smoke" in sys.argv),
         "--json" in sys.argv)
