"""Online cross-iteration tuning controller (paper §4, Fig. 10).

:class:`OnlineTuner` runs the paper's heuristic search — greedy coordinate
descent in the order ``ps → dist → wpb`` with the *retreat* rule and the
*stop-at-top-3* criterion — over **measured** step times delivered one at a
time by the training loop.  The offline helper
:func:`repro.core.autotune.cross_iteration_optimize` pulls measurements
synchronously; training cannot block like that, so here the identical
control flow is expressed as a generator that *yields* the next config to
try and is *sent* the measured latency once the trainer has timed a few
iterations with it:

    tuner = OnlineTuner()
    while not tuner.converged:
        cfg = tuner.propose()          # (ps, dist, pb) to run next
        tuner.observe(measure(cfg))    # median step time under cfg

Extras over the offline search, per the paper's runtime:

* **stop-at-top-3** — after descent + retreat, single-knob neighbors of
  the incumbent are probed until one fails to land in the top-3 recorded
  latencies ("decrease ps... until the updated setting could not make it
  to the top-3 lowest latency performance").
* **warm start** — a cached config (see :mod:`repro.runtime.cache`) is
  measured first so a previously tuned workload starts from its optimum.
* **drift detection** — :meth:`observe_shape` compares the live
  :class:`~repro.core.autotune.WorkloadShape` against the one the search
  converged on; past ``drift_threshold`` relative change the search
  re-opens (warm-started from the old best), because the measured surface
  is stale.
* **budget** — a hard cap on measurements; the search reports the best
  config seen when the budget runs out.
* **audit trail** — every probe / reopen / retreat / adopt / convergence
  lands in ``tuner.audit`` as a structured event (and streams through an
  optional ``audit_sink`` callable), so *why* the runtime picked a config
  is machine-readable instead of buried in launcher prints.  See
  docs/observability.md for the event schema.
"""
from __future__ import annotations

import math
from typing import Callable, Dict, Iterator, List, Optional, Sequence, Tuple

from repro.core.autotune import (HardwareSpec, TPU_V5E, SearchResult,
                                 WorkloadShape, vmem_bytes)

__all__ = ["OnlineTuner", "PerLayerTuner", "make_vmem_check", "shape_drift"]

_AUDIT_CAP = 10_000   # bounded like the tracer ring buffers


class _AuditMixin:
    """Shared audit-event plumbing for both tuners.

    Events are plain dicts with an ``event`` key (``probe`` / ``reopen``
    / ``retreat`` / ``adopt`` / ``converged`` / ``budget_exhausted``),
    appended to the bounded ``self.audit`` list and streamed through
    ``self.audit_sink`` when one is set (the engine forwards them to its
    tracer/metrics).  A sink that raises never breaks the search.
    """

    def _init_audit(self, audit_sink=None) -> None:
        self.audit: List[dict] = []
        self.audit_sink = audit_sink

    def _emit(self, event: str, **fields) -> None:
        ev = {"event": event, "measured": self.measured, **fields}
        if len(self.audit) >= _AUDIT_CAP:
            del self.audit[:_AUDIT_CAP // 2]
        self.audit.append(ev)
        if self.audit_sink is not None:
            try:
                self.audit_sink(ev)
            except Exception:
                pass

    def observations(self) -> List[Tuple[dict, float]]:
        """Measured ``(config, latency)`` pairs from the audit trail —
        the calibration fit's input (:mod:`repro.obs.calibrate`).
        OnlineTuner probes yield config dicts, PerLayerTuner probes
        per-layer config lists; finite positive latencies only.
        """
        out: List[Tuple[dict, float]] = []
        for ev in self.audit:
            if ev.get("event") != "probe":
                continue
            cfg = ev.get("config") or ev.get("configs")
            lat = ev.get("latency")
            if cfg is None or lat is None:
                continue
            lat = float(lat)
            if math.isfinite(lat) and lat > 0.0:
                out.append((cfg, lat))
        return out

# (ps, dist, pb) — extended with cap / k / fanout / batch when the
# corresponding spaces are configured:
# (ps, dist, pb[, cap][, k][, fanout][, batch])
Key = Tuple[int, ...]

DEFAULT_PS = (1, 2, 4, 8, 16, 32)
DEFAULT_DIST = (1, 2, 4, 8, 16)
DEFAULT_PB = (1, 2, 4, 8, 16)


def make_vmem_check(w: WorkloadShape, hw: HardwareSpec = TPU_V5E,
                    dim_block: int = 128) -> Callable[[int, int, int], bool]:
    """The §4 hardware constraint as a config predicate (VMEM budget)."""

    def check(ps: int, dist: int, pb: int) -> bool:
        tile_rows = -(-w.rows_per_dev // dist)
        return vmem_bytes(ps, pb, dim_block, tile_rows, w.d_feat,
                          w.itemsize) <= hw.vmem_bytes

    return check


def shape_drift(a: WorkloadShape, b: WorkloadShape) -> float:
    """Relative workload change; ``inf`` when shapes are incomparable."""
    if (a.n_dev, a.itemsize) != (b.n_dev, b.itemsize):
        return math.inf
    rel = 0.0
    for fa, fb in ((a.d_feat, b.d_feat), (a.rows_per_dev, b.rows_per_dev),
                   (a.local_edges_max, b.local_edges_max),
                   (a.remote_edges_max, b.remote_edges_max)):
        rel = max(rel, abs(fa - fb) / max(1.0, float(fa)))
    return rel


class OnlineTuner(_AuditMixin):
    """Incremental ps → dist → wpb search over externally-measured latencies.

    ``cap_space`` (optional, the tiered feature path's device-cache
    capacity in rows) adds a FOURTH climbed coordinate after ``pb``:
    larger caches stream fewer cold rows from the host store, so latency
    falls until the hit rate saturates — exactly the
    increase-until-no-improvement shape the paper's climb expects.  With
    a cap_space, config dicts carry a ``cap`` key and table keys are
    4-tuples; without one (the default) behavior is unchanged.

    ``k_space`` (optional, top-k activation-compression widths for the
    sparse ring payload — pipeline.mgg_aggregate_sparse) adds a further
    climbed coordinate after ``cap``.  NOTE the tuner minimizes latency
    alone, and smaller k is (almost) always faster — so the climb lands on
    the smallest candidate.  ``k_space`` is therefore the caller's
    *accuracy-approved* candidate set, not a free search dimension: every
    value in it must already be acceptable accuracy-wise (the fig9 sparsity
    row is the accuracy/speed evidence).  Config dicts carry a ``k`` key.

    ``fanout_space`` / ``batch_space`` (optional, the sampled mini-batch
    path's per-hop neighbor bound and seed-batch size — ``repro.sample``)
    climb after ``k``, in that order.  Both carry the same caveat as
    ``k``: fanout trades accuracy for work (the space must be
    accuracy-approved), and batch trades steps-per-epoch for step time —
    feed the tuner a *per-seed* latency (``dt / batch``) if you want the
    batch climb to optimize throughput rather than raw step time (the
    sampled training loop does).  Config dicts carry ``fanout`` /
    ``batch`` keys.
    """

    def __init__(
        self,
        ps_space: Tuple[int, ...] = DEFAULT_PS,
        dist_space: Tuple[int, ...] = DEFAULT_DIST,
        pb_space: Tuple[int, ...] = DEFAULT_PB,
        *,
        cap_space: Tuple[int, ...] = (),
        k_space: Tuple[int, ...] = (),
        fanout_space: Tuple[int, ...] = (),
        batch_space: Tuple[int, ...] = (),
        vmem_check: Optional[Callable[[int, int, int], bool]] = None,
        top_k: int = 3,
        budget: Optional[int] = None,
        drift_threshold: float = 0.25,
        warm_start: Optional[Dict[str, int]] = None,
        audit_sink: Optional[Callable[[dict], None]] = None,
    ):
        self.ps_space = tuple(sorted(ps_space))
        self.dist_space = tuple(sorted(dist_space))
        self.pb_space = tuple(sorted(pb_space))
        self.cap_space = tuple(sorted(cap_space))
        self.k_space = tuple(sorted(k_space))
        self.fanout_space = tuple(sorted(fanout_space))
        self.batch_space = tuple(sorted(batch_space))
        self.vmem_check = vmem_check
        self.top_k = int(top_k)
        self.budget = budget
        self.drift_threshold = float(drift_threshold)
        self.measured = 0          # total across re-opens (budget applies here)
        self.reopens = 0
        self._shape: Optional[WorkloadShape] = None
        self.table: Dict[Key, float] = {}
        self.trajectory: List[Tuple[Dict[str, int], float]] = []
        self._gen: Optional[Iterator[Key]] = None
        self._pending: Optional[Key] = None
        self._init_audit(audit_sink)
        self.reset(warm_start=warm_start)

    # -- knob/key mapping (3 knobs, + cap/k/fanout/batch when configured) ----

    @property
    def knobs(self) -> Tuple[str, ...]:
        return ("ps", "dist", "pb") \
            + (("cap",) if self.cap_space else ()) \
            + (("k",) if self.k_space else ()) \
            + (("fanout",) if self.fanout_space else ()) \
            + (("batch",) if self.batch_space else ())

    def _key(self, cfg: Dict[str, int]) -> Key:
        key = (int(cfg["ps"]), int(cfg["dist"]), int(cfg["pb"]))
        if self.cap_space:
            key += (int(cfg.get("cap", self.cap_space[0])),)
        if self.k_space:
            key += (int(cfg.get("k", self.k_space[0])),)
        if self.fanout_space:
            key += (int(cfg.get("fanout", self.fanout_space[0])),)
        if self.batch_space:
            key += (int(cfg.get("batch", self.batch_space[0])),)
        return key

    def _cfg(self, key: Key) -> Dict[str, int]:
        return dict(zip(self.knobs, key))

    # -- public protocol -----------------------------------------------------

    def reset(self, warm_start: Optional[Dict[str, int]] = None) -> None:
        """(Re-)open the search; stale measurements are discarded."""
        self.table = {}
        self.trajectory = []
        self._gen = self._search(warm_start)
        self._advance(None)

    @property
    def converged(self) -> bool:
        return self._pending is None

    def propose(self) -> Optional[Dict[str, int]]:
        """Config awaiting a measurement; the best config once converged."""
        if self._pending is None:
            return self.best
        return self._cfg(self._pending)

    def observe(self, latency: float) -> None:
        """Deliver the measured latency for the proposed config."""
        if self._pending is None:
            raise RuntimeError("observe() on a converged tuner — call "
                               "reset() or observe_shape() to re-open")
        self.measured += 1
        self._emit("probe", config=self._cfg(self._pending),
                   latency=float(latency))
        if self.budget is not None and self.measured >= self.budget:
            # budget exhausted: record this sample and stop the search
            key = self._pending
            self.table[key] = float(latency)
            self.trajectory.append((self._cfg(key), float(latency)))
            self._gen.close()
            self._pending = None
            self._emit("budget_exhausted", best=self.best,
                       best_latency=self.best_latency)
            return
        self._advance(float(latency))

    @property
    def best(self) -> Optional[Dict[str, int]]:
        finite = {k: v for k, v in self.table.items() if v < math.inf}
        if not finite:
            return None
        return self._cfg(min(finite, key=finite.get))

    @property
    def best_latency(self) -> float:
        best = self.best
        if best is None:
            return math.inf
        return self.table[self._key(best)]

    def result(self) -> SearchResult:
        """The search outcome in the offline optimizer's result type."""
        best = self.best
        if best is None:
            raise RuntimeError("result() before any finite measurement")
        return SearchResult(best=best, best_latency=self.best_latency,
                            trajectory=list(self.trajectory),
                            table=dict(self.table))

    def reopen(self, warm_start: Optional[Dict[str, int]] = None,
               mode: str = "search", cause: str = "drift") -> None:
        """Re-open the search, warm-started from ``warm_start`` (the best
        config seen so far by default).  ``cause`` tags the audit event
        (``shape_drift`` / ``traffic_drift`` / ``cache_adopt`` / ...).

        Owns the reopen bookkeeping for every drift path — shape drift
        (:meth:`observe_shape`) and caller-forced traffic drift
        (``DynamicGNNEngine.retune(force=True)``) alike.

        ``mode="adopt"`` trusts the warm config instead of re-searching:
        it is measured once (seeding the latency table) and the search
        converges immediately after.  This is the serving cluster's
        shared-cache path — a sibling replica on identical hardware just
        paid for the full re-search under the same traffic shift, so this
        replica validates the committed optimum with a single measurement
        rather than re-exploring.  Falls back to a full search when there
        is no warm config or it fails the VMEM check.
        """
        self.reopens += 1
        warm = warm_start if warm_start is not None else self.best
        self._emit("reopen", cause=cause, mode=mode, warm=warm,
                   reopens=self.reopens)
        if (mode == "adopt" and warm is not None
                and (self.vmem_check is None
                     or self.vmem_check(warm["ps"], warm["dist"],
                                        warm["pb"]))):
            self.table = {}
            self.trajectory = []
            self._gen = self._adopt(warm)
            self._advance(None)
            self._emit("adopt", config=dict(warm))
        else:
            self.reset(warm_start=warm)

    def _adopt(self, warm: Dict[str, int]):
        key = self._key(warm)
        lat = yield key
        self.table[key] = float(lat)
        self.trajectory.append((self._cfg(key), self.table[key]))

    def observe_shape(self, shape: WorkloadShape) -> bool:
        """Report the live workload shape; True ⇔ drift re-opened the search."""
        if self._shape is None:
            self._shape = shape
            return False
        if shape_drift(self._shape, shape) <= self.drift_threshold:
            return False
        self._shape = shape
        self.reopen(cause="shape_drift")
        return True

    # -- the search as a generator (identical control flow to the offline
    #    cross_iteration_optimize, plus warm start and top-3 refinement) -----

    def _advance(self, latency: Optional[float]) -> None:
        try:
            self._pending = self._gen.send(latency)
        except StopIteration:
            self._pending = None
            self._emit("converged", best=self.best,
                       best_latency=self.best_latency)

    def _search(self, warm: Optional[Dict[str, int]]):
        table, traj = self.table, self.trajectory
        caps = self.cap_space
        c0 = caps[0] if caps else None
        ks = self.k_space
        k0 = ks[0] if ks else None
        fos = self.fanout_space
        f0 = fos[0] if fos else None
        bts = self.batch_space
        bt0 = bts[0] if bts else None

        def mget(ps: int, dist: int, pb: int, cap: Optional[int] = c0,
                 k: Optional[int] = k0, fanout: Optional[int] = f0,
                 batch: Optional[int] = bt0):
            key = (int(ps), int(dist), int(pb)) \
                + ((int(cap),) if caps else ()) \
                + ((int(k),) if ks else ()) \
                + ((int(fanout),) if fos else ()) \
                + ((int(batch),) if bts else ())
            if key not in table:
                # cap (feature cache in HBM), k (ring payload width) and
                # fanout/batch (host-side sampling geometry) never touch
                # VMEM, so feasibility is checked on (ps, dist, pb) only
                if self.vmem_check is not None \
                        and not self.vmem_check(*key[:3]):
                    table[key] = math.inf
                    traj.append((self._cfg(key), math.inf))
                else:
                    lat = yield key
                    table[key] = float(lat)
                    traj.append((self._cfg(key), table[key]))
            return table[key]

        def mget_key(key: Key):
            # keys lay out as self.knobs (ps, dist, pb, then only the
            # CONFIGURED extras) — positional unpacking into mget's full
            # parameter list would misassign extras when some spaces are
            # absent (e.g. a fanout landing in the cap slot), probing a
            # cached key forever instead of the intended neighbor
            cfg = self._cfg(key)
            return (yield from mget(cfg["ps"], cfg["dist"], cfg["pb"],
                                    cfg.get("cap", c0), cfg.get("k", k0),
                                    cfg.get("fanout", f0),
                                    cfg.get("batch", bt0)))

        def climb(values, cur, f):
            best, best_lat = cur, (yield from f(cur))
            for v in values:
                if v <= cur:
                    continue
                lat = yield from f(v)
                if lat < best_lat:
                    best, best_lat = v, lat
                else:
                    break  # paper: stop the climb once latency increases
            return best

        p0, d0, b0 = self.ps_space[0], self.dist_space[0], self.pb_space[0]
        if warm is not None:
            # warm start: the cached optimum is measured first, so it seeds
            # the table (and is the committed answer if nothing beats it).
            yield from mget(warm["ps"], warm["dist"], warm["pb"],
                            warm.get("cap", c0), warm.get("k", k0),
                            warm.get("fanout", f0), warm.get("batch", bt0))

        ps = yield from climb(self.ps_space, p0,
                              lambda v: mget(v, d0, b0))
        dist = yield from climb(self.dist_space, d0,
                                lambda v: mget(ps, v, b0))
        pb = yield from climb(self.pb_space, b0,
                              lambda v: mget(ps, dist, v))
        cap = c0
        if caps:
            # capacity climbs LAST: it buys bandwidth with memory, so it
            # only moves once the schedule knobs have settled
            cap = yield from climb(caps, c0, lambda v: mget(ps, dist, pb, v))
        kk = k0
        if ks:
            # k climbs after the schedule knobs: it trades accuracy for
            # wire bytes, so it only moves on the settled schedule (and a
            # pure latency objective keeps it at the space's floor — see
            # the class docstring on k_space being accuracy-approved).
            kk = yield from climb(ks, k0,
                                  lambda v: mget(ps, dist, pb, cap, v))
        fo = f0
        if fos:
            # sampling geometry climbs last of all: fanout bounds per-hop
            # work (accuracy-approved space, like k) ...
            fo = yield from climb(fos, f0,
                                  lambda v: mget(ps, dist, pb, cap, kk, v))
        bt = bt0
        if bts:
            # ... and batch amortizes fixed per-step cost over more seeds —
            # it only climbs when the caller feeds per-seed latencies
            # (dt / batch), under which larger batches win until the
            # device saturates.
            bt = yield from climb(bts, bt0,
                                  lambda v: mget(ps, dist, pb, cap, kk, fo,
                                                 v))

        # Retreat rule: if pb never improved, drop ps one notch and retry pb
        # (on the climbed cap/k/fanout/batch, so the probes stay on the
        # incumbent's slice).
        if pb == b0 and ps != p0:
            ps_retreat = self.ps_space[max(0, self.ps_space.index(ps) - 1)]
            pb2 = yield from climb(self.pb_space, b0,
                                   lambda v: mget(ps_retreat, dist, v, cap,
                                                  kk, fo, bt))
            a = yield from mget(ps_retreat, dist, pb2, cap, kk, fo, bt)
            b = yield from mget(ps, dist, pb, cap, kk, fo, bt)
            if a < b:
                self._emit("retreat", ps_from=ps, ps_to=ps_retreat,
                           pb_from=pb, pb_to=pb2, latency=a)
                ps, pb = ps_retreat, pb2

        # Stop-at-top-3: probe unmeasured single-knob neighbors of the
        # incumbent until one cannot make it into the top-k latencies.
        while True:
            finite = {k: v for k, v in table.items() if v < math.inf}
            if not finite:
                return
            incumbent = min(finite, key=finite.get)
            cands = [k for k in self._neighbors(incumbent) if k not in table]
            if not cands:
                return
            cut = sorted(finite.values())[:self.top_k][-1]
            lat = yield from mget_key(cands[0])
            if lat > cut:
                return

    def _neighbors(self, key: Key) -> List[Key]:
        """Single-knob ±1-notch moves around ``key`` (deterministic order)."""
        out: List[Key] = []
        spaces = (self.ps_space, self.dist_space, self.pb_space) \
            + ((self.cap_space,) if self.cap_space else ()) \
            + ((self.k_space,) if self.k_space else ()) \
            + ((self.fanout_space,) if self.fanout_space else ()) \
            + ((self.batch_space,) if self.batch_space else ())
        for dim, space in enumerate(spaces):
            i = space.index(key[dim]) if key[dim] in space else None
            if i is None:
                continue
            for j in (i - 1, i + 1):
                if 0 <= j < len(space):
                    nk = list(key)
                    nk[dim] = space[j]
                    out.append(tuple(nk))
        return out


class PerLayerTuner(_AuditMixin):
    """Layer-wise (ps, dist, wpb) search over full-forward step times.

    GNN layers have radically different shapes (GCN: wide input layer vs a
    16-dim hidden layer), so one global config leaves latency on the table.
    This tuner lifts the paper's coordinate descent one level: the *layer*
    becomes the outer coordinate.

    Phases (one :class:`OnlineTuner` each, identical inner control flow):

    1. **global** — every layer shares the candidate config; warm-started
       from the cached config if one exists.  This is the pre-refactor
       search, kept as the cheap first approximation.
    2. **per-layer ℓ = 0..L-1** — layer ℓ's knobs move, every other layer
       is pinned (layers < ℓ at their committed optimum, layers > ℓ at the
       global optimum); each phase warm-starts from the global best, so
       its first measurement re-validates the incumbent under the current
       pinning.
    3. **fuse ℓ** (only with ``fuse_space=(False, True)``) — after layer
       ℓ's schedule commits, its ``fuse_update`` flag is probed with ONE
       measurement of the committed configs with layer ℓ's fuse flipped;
       the flip is kept iff it beats the phase's committed latency.  A
       boolean knob needs no climb — a single flip probe per layer is the
       entire dimension, so the fourth per-layer knob costs at most L
       extra measurements.

    ``cap_space`` makes the tiered feature-cache capacity a tuned knob.
    Capacity is a *global* resource (one device cache feeds every layer),
    so only the global phase's sub-tuner climbs it; the committed ``cap``
    is then pinned into every layer config for the per-layer phases.

    ``k_space`` does the same for the top-k sparse-ring payload width:
    the global phase climbs ``k`` (over the caller's accuracy-approved
    candidates — see :class:`OnlineTuner`) and the committed value is
    pinned into every layer config.  Model stages apply it to hidden
    layers only (layer 0 always rides the dense ring).

    ``fanout_space`` / ``batch_space`` make the sampled mini-batch
    geometry (``repro.sample``) tuned knobs.  One block pipeline feeds
    every layer — sampling geometry is global like capacity — so only
    the global phase's sub-tuner climbs them; the committed values are
    pinned into every layer config (see :class:`OnlineTuner` for the
    per-seed-latency caveat on ``batch``).

    Every ``observe`` is the latency of the FULL forward under the proposed
    per-layer configs, so each phase's table is a valid surface for its
    free layer.  The measurement ``budget`` is shared across all phases —
    when it runs out the search commits the best configs seen so far.
    The public protocol mirrors :class:`OnlineTuner` with per-layer lists
    in place of single config dicts.
    """

    def __init__(
        self,
        num_layers: int,
        ps_space: Tuple[int, ...] = DEFAULT_PS,
        dist_space: Tuple[int, ...] = DEFAULT_DIST,
        pb_space: Tuple[int, ...] = DEFAULT_PB,
        *,
        cap_space: Tuple[int, ...] = (),
        k_space: Tuple[int, ...] = (),
        fanout_space: Tuple[int, ...] = (),
        batch_space: Tuple[int, ...] = (),
        fuse_space: Tuple[bool, ...] = (False,),
        vmem_checks=None,   # None | callable | per-layer sequence of callables
        top_k: int = 3,
        budget: Optional[int] = None,
        drift_threshold: float = 0.25,
        warm_start=None,    # None | global dict | per-layer list of dicts
        tune_global_first: bool = True,
        audit_sink: Optional[Callable[[dict], None]] = None,
    ):
        if num_layers < 1:
            raise ValueError("num_layers must be >= 1")
        self.num_layers = int(num_layers)
        self.ps_space = tuple(sorted(ps_space))
        self.dist_space = tuple(sorted(dist_space))
        self.pb_space = tuple(sorted(pb_space))
        self.cap_space = tuple(sorted(cap_space))
        self.k_space = tuple(sorted(k_space))
        self.fanout_space = tuple(sorted(fanout_space))
        self.batch_space = tuple(sorted(batch_space))
        self.fuse_space = tuple(dict.fromkeys(bool(f) for f in fuse_space))
        if not self.fuse_space:
            self.fuse_space = (False,)
        if vmem_checks is None or callable(vmem_checks):
            vmem_checks = [vmem_checks] * self.num_layers
        if len(vmem_checks) != self.num_layers:
            raise ValueError("one vmem check per layer required")
        self.vmem_checks = list(vmem_checks)
        self.top_k = int(top_k)
        self.budget = budget
        self.drift_threshold = float(drift_threshold)
        self.tune_global_first = bool(tune_global_first)
        self.measured = 0
        self.reopens = 0
        self._shapes: Optional[List[WorkloadShape]] = None
        self.trajectory: List[Tuple[List[Dict[str, int]], float]] = []
        self._init_audit(audit_sink)
        self.reset(warm_start=warm_start)

    # -- public protocol -----------------------------------------------------

    @property
    def _tune_fuse(self) -> bool:
        return len(self.fuse_space) > 1

    def reset(self, warm_start=None) -> None:
        """(Re-)open the search; stale measurements are discarded."""
        self.trajectory = []
        self._best_lat = math.inf
        self._best_cfgs: Optional[List[Dict[str, int]]] = None
        default = dict(ps=self.ps_space[0], dist=self.dist_space[0],
                       pb=self.pb_space[0])
        if isinstance(warm_start, dict):
            global_warm, layer_warms = dict(warm_start), None
        elif warm_start is not None:          # per-layer warm start
            layer_warms = [dict(c) for c in warm_start]
            if len(layer_warms) != self.num_layers:
                raise ValueError("one warm config per layer required")
            global_warm = None
        else:
            global_warm, layer_warms = None, None
        self._configs = (list(layer_warms) if layer_warms is not None
                         else [dict(global_warm or default)
                               for _ in range(self.num_layers)])
        if self._tune_fuse:
            for c in self._configs:
                c.setdefault("fuse", bool(self.fuse_space[0]))
        self._phases: List[Tuple] = []
        if self.tune_global_first and layer_warms is None:
            self._phases.append(("global", global_warm))
        for i in range(self.num_layers):
            self._phases.append(("layer", i))
            if self._tune_fuse:
                self._phases.append(("fuse", i))
        self._sub: Optional[OnlineTuner] = None
        self._sub_layer: Optional[int] = None
        self._adopt_pending = False
        self._fuse_pending: Optional[int] = None
        self._phase_lat = math.inf
        self._done = False
        self._start_next_phase()

    @property
    def converged(self) -> bool:
        return self._done

    def propose(self) -> Optional[List[Dict[str, int]]]:
        """Per-layer configs awaiting a measurement (the best once done)."""
        if self._done:
            return self.best
        if self._adopt_pending:
            return [dict(c) for c in self._configs]
        if self._fuse_pending is not None:
            out = [dict(c) for c in self._configs]
            lf = self._fuse_pending
            out[lf]["fuse"] = not out[lf].get("fuse", False)
            return out
        cand = self._sub.propose()
        if self._sub_layer is None:           # global phase
            # merge keeps per-layer extras (fuse) while the shared
            # candidate moves every layer's (ps, dist, pb[, cap])
            return [{**dict(c), **dict(cand)} for c in self._configs]
        out = [dict(c) for c in self._configs]
        out[self._sub_layer] = {**out[self._sub_layer], **dict(cand)}
        return out

    def observe(self, latency: float) -> None:
        """Deliver the full-forward latency for the proposed configs."""
        if self._done:
            raise RuntimeError("observe() on a converged tuner — call "
                               "reset() or reopen() to re-open")
        latency = float(latency)
        cfgs = self.propose()
        self.measured += 1
        self.trajectory.append((cfgs, latency))
        self._emit("probe", phase=self._phase_name(), configs=cfgs,
                   latency=latency)
        if latency < self._best_lat:
            self._best_lat, self._best_cfgs = latency, cfgs
        if self._adopt_pending:
            # shared-cache adoption: the single validation measurement
            # closes the search (see OnlineTuner.reopen(mode="adopt"))
            self._adopt_pending = False
            self._done = True
            self._emit("adopt", configs=cfgs, latency=latency)
            return
        if self._fuse_pending is not None:
            # single flip probe: keep the flip iff it beats the latency the
            # layer phase committed at
            lf = self._fuse_pending
            self._fuse_pending = None
            if latency < self._phase_lat:
                self._configs[lf]["fuse"] = \
                    not self._configs[lf].get("fuse", False)
                self._phase_lat = latency
            self._start_next_phase()
        else:
            self._sub.observe(latency)
            while (not self._done and self._sub is not None
                   and self._sub.converged):
                self._commit_phase()
        if (self.budget is not None and self.measured >= self.budget
                and not self._done):
            self._commit_phase(exhausted=True)
            self._emit("budget_exhausted", best=self.best,
                       best_latency=self._best_lat)
        if self._done:
            self._emit("converged", best=self.best,
                       best_latency=self._best_lat)

    def _phase_name(self) -> str:
        if self._adopt_pending:
            return "adopt"
        if self._fuse_pending is not None:
            return f"fuse:{self._fuse_pending}"
        if self._sub_layer is None:
            return "global"
        return f"layer:{self._sub_layer}"

    @property
    def best(self) -> Optional[List[Dict[str, int]]]:
        """Best *measured* joint configs (never worse than any phase pick)."""
        if self._best_cfgs is None:
            return None
        return [dict(c) for c in self._best_cfgs]

    @property
    def best_latency(self) -> float:
        return self._best_lat

    def reopen(self, warm_start=None, mode: str = "search",
               cause: str = "drift") -> None:
        """Re-open per-layer phases, warm-started from ``warm_start`` (the
        best configs so far by default — traffic/shape drift made the
        measured surface stale).  ``cause`` tags the audit event.

        ``mode="adopt"`` with a per-layer warm list trusts it outright:
        the joint configs are measured once and the search converges (the
        serving cluster's shared-cache path; see
        :meth:`OnlineTuner.reopen`).  Falls back to the phase search when
        the warm list is missing, wrongly sized, or VMEM-infeasible.
        """
        self.reopens += 1
        warm = warm_start if warm_start is not None \
            else (self.best or self._configs)
        self._emit("reopen", cause=cause, mode=mode, warm=warm,
                   reopens=self.reopens)
        if mode == "adopt" and self._adoptable(warm):
            self.trajectory = []
            self._best_lat = math.inf
            self._best_cfgs = None
            self._configs = [dict(c) for c in warm]
            self._phases = []
            self._sub = None
            self._sub_layer = None
            self._adopt_pending = True
            self._done = False
        else:
            if isinstance(warm, list) and warm \
                    and len(warm) != self.num_layers:
                # unusably-sized warm list (layer count moved since it was
                # recorded): resize rather than raise
                warm = self._resize_warm(warm)
            self.reset(warm_start=warm)

    def _resize_warm(self, warm: List[Dict[str, int]]) \
            -> List[Dict[str, int]]:
        """Fit a per-layer warm list to the current layer count — extra
        layers seed from the last known config."""
        return ([dict(c) for c in warm]
                + [dict(warm[-1])] * self.num_layers)[:self.num_layers]

    def _adoptable(self, warm) -> bool:
        if not isinstance(warm, list) or len(warm) != self.num_layers:
            return False
        return all(
            check is None or check(c["ps"], c["dist"], c["pb"])
            for c, check in zip(warm, self.vmem_checks))

    def reconfigure(
        self,
        num_layers: Optional[int] = None,
        vmem_checks=None,
        warm_start=None,
    ) -> None:
        """Re-shape an already-reopened search: the layer count and/or the
        feasibility predicates changed (drift moved the per-layer widths or
        the model gained/lost layers).  The warm start — the previous best
        by default — is resized to the new layer count (extra layers seed
        from the last known config).  Does NOT count as another reopen;
        callers invoke it right after the reopen that detected the change.
        """
        if num_layers is not None:
            if num_layers < 1:
                raise ValueError("num_layers must be >= 1")
            self.num_layers = int(num_layers)
        if vmem_checks is not None:
            if callable(vmem_checks):
                vmem_checks = [vmem_checks] * self.num_layers
            if len(vmem_checks) != self.num_layers:
                raise ValueError("one vmem check per layer required")
            self.vmem_checks = list(vmem_checks)
        elif len(self.vmem_checks) != self.num_layers:
            self.vmem_checks = (self.vmem_checks
                                + [self.vmem_checks[-1]] * self.num_layers
                                )[:self.num_layers]
        if warm_start is None:
            warm_start = self.best or self._configs
        if isinstance(warm_start, list) and warm_start:
            warm_start = self._resize_warm(warm_start)
        self.reset(warm_start=warm_start)

    def observe_shape(self, shapes) -> bool:
        """Report live per-layer shapes; True ⇔ drift re-opened the search."""
        if isinstance(shapes, WorkloadShape):
            shapes = [shapes]
        shapes = list(shapes)
        if self._shapes is None:
            self._shapes = shapes
            return False
        drift = max(shape_drift(a, b)
                    for a, b in zip(self._shapes, shapes)) \
            if len(shapes) == len(self._shapes) else math.inf
        if drift <= self.drift_threshold:
            return False
        self._shapes = shapes
        self.reopen(cause="shape_drift")
        return True

    # -- internals -----------------------------------------------------------

    def _layer_check(self, layer: Optional[int]):
        if layer is not None:
            return self.vmem_checks[layer]
        checks = [c for c in self.vmem_checks if c is not None]
        if not checks:
            return None
        return lambda ps, dist, pb: all(c(ps, dist, pb) for c in checks)

    def _start_next_phase(self) -> None:
        while self._phases:
            phase = self._phases.pop(0)
            if phase[0] == "fuse":
                # one flip probe of the just-committed layer (no sub-tuner)
                self._fuse_pending = phase[1]
                self._sub = None
                self._sub_layer = None
                return
            if phase[0] == "global":
                self._sub_layer = None
                warm = phase[1]
            else:
                self._sub_layer = phase[1]
                warm = dict(self._configs[self._sub_layer])
            self._sub = OnlineTuner(
                self.ps_space, self.dist_space, self.pb_space,
                # capacity is a global resource: only the global phase's
                # sub-tuner climbs it (pinned for per-layer phases)
                cap_space=self.cap_space if self._sub_layer is None else (),
                # k is likewise climbed globally: the paper's accuracy
                # budget is end-to-end, so per-layer phases keep it pinned
                k_space=self.k_space if self._sub_layer is None else (),
                # sampling geometry (one block pipeline feeds all layers)
                # is global too
                fanout_space=(self.fanout_space
                              if self._sub_layer is None else ()),
                batch_space=(self.batch_space
                             if self._sub_layer is None else ()),
                vmem_check=self._layer_check(self._sub_layer),
                top_k=self.top_k, warm_start=warm,
            )
            if not self._sub.converged:
                return
            self._apply_sub_best()  # degenerate space: nothing to measure
        self._done = True
        self._sub = None

    def _apply_sub_best(self) -> None:
        if self._sub is None:
            return
        best = self._sub.best
        if best is None:
            return
        if self._sub_layer is None:
            # merge: the global winner (incl. any committed cap) lands in
            # every layer while per-layer extras (fuse) persist
            self._configs = [{**dict(c), **dict(best)}
                             for c in self._configs]
        else:
            self._configs[self._sub_layer] = \
                {**self._configs[self._sub_layer], **dict(best)}

    def _commit_phase(self, exhausted: bool = False) -> None:
        if self._sub is not None:
            self._phase_lat = self._sub.best_latency
        self._apply_sub_best()
        if exhausted:
            self._phases = []
            self._done = True
            self._sub = None
            self._fuse_pending = None
            return
        self._start_next_phase()
