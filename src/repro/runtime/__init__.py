"""repro.runtime — the paper's intelligent runtime (§4): online
cross-iteration re-optimization of the aggregation pipeline during
training.

Four pieces (see docs/runtime.md):

* :mod:`repro.runtime.profiler` — measurement harness: per-iteration
  latency windows with warmup/percentile handling, a jitted-step timer,
  and the analytical-model fallback when no devices are available
  (``ProfileConfig``, ``LatencyWindow``, ``time_jitted``,
  ``AggregateProfiler``);
* :mod:`repro.runtime.tuner` — the online ps → dist → wpb coordinate
  descent with retreat, stop-at-top-3, warm start, budget, and
  workload-drift re-exploration (``OnlineTuner``), plus the layer-wise
  lift (``PerLayerTuner``: per-layer searches over full-forward times,
  warm-started from the global optimum, shared budget);
* :mod:`repro.runtime.cache` — persistent JSON config cache keyed by
  workload-shape + hardware fingerprint, global or per-layer entries
  (``ConfigCache``);
* :mod:`repro.runtime.engine` — ``DynamicGNNEngine``: a
  :class:`repro.core.gnn.GNNEngine` wrapper that rebuilds plans/kernels
  when the tuner commits a new config — one global ``(ps, dist, pb)`` or
  one per layer — without touching model parameters.
"""
from repro.runtime.cache import (ConfigCache, hardware_fingerprint,
                                 layers_fingerprint, shape_fingerprint)
from repro.runtime.engine import DynamicGNNEngine
from repro.runtime.profiler import (AggregateProfiler, LatencyWindow,
                                    ProfileConfig, time_jitted)
from repro.runtime.tuner import (OnlineTuner, PerLayerTuner, make_vmem_check,
                                 shape_drift)

__all__ = [
    "ProfileConfig", "LatencyWindow", "time_jitted", "AggregateProfiler",
    "OnlineTuner", "PerLayerTuner", "make_vmem_check", "shape_drift",
    "ConfigCache", "hardware_fingerprint", "shape_fingerprint",
    "layers_fingerprint", "DynamicGNNEngine",
]
