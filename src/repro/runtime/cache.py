"""Persistent tuned-config cache: workload-shape × hardware → (ps, dist, pb).

The paper's runtime converges in ~10 measured iterations; a *later run* of
the same workload on the same hardware should not pay those iterations
again.  :class:`ConfigCache` stores each converged config in a JSON file
keyed by the :class:`~repro.core.autotune.WorkloadShape` fingerprint plus a
hardware fingerprint (platform, device kind, device count), so
:class:`~repro.runtime.engine.DynamicGNNEngine` warm-starts the search from
the cached optimum.

Robustness rules (this file lives across jobs — and, with serving
replicas (:mod:`repro.serve.cluster`), across concurrent *processes*):

* writes are atomic (tmp file + ``os.replace``) — a preempted writer never
  corrupts the cache;
* the read-modify-write in :meth:`put` / :meth:`put_layers` is serialized
  across processes by an exclusive ``flock`` on a sidecar ``<path>.lock``
  file, so two replicas committing different entries never lose each
  other's update (on platforms without ``fcntl`` the RMW falls back to
  last-writer-wins, which is still corruption-free);
* reads retry briefly on malformed JSON (an external non-atomic copy can
  race a reader even though our own writes cannot) before reading as
  empty;
* a corrupt or version-mismatched file reads as empty (tuning simply
  starts cold) rather than raising — pre-per-layer (v1) cache files are
  discarded with a single :class:`RuntimeWarning` per path per process,
  never a crash and never silent;
* entries keep the latency and shape they were tuned at, for debugging
  and for future staleness policies.

Schema v2 adds **per-layer** entries: a tuned config may be either one
global ``{ps, dist, pb}`` or ``{"layers": [{ps, dist, pb}, ...]}`` keyed
by the joint fingerprint of every layer's WorkloadShape (the per-layer
tuner's warm start).

Schema v3 rounds out the knob set: the tiered feature-cache capacity
(``cap``, an int) and the per-layer fused-update dataflow (``fuse``, a
bool) persist alongside ``(ps, dist, pb)`` when the committed config
carries them — previously only the three schedule knobs round-tripped,
so a re-opened search re-probed capacity and fuse from scratch.  v2
files are discarded with the same one-time-per-path RuntimeWarning as
v1 (tuning starts cold, never a crash).

Schema v4 persists the sparsity knob: the top-k compression width
(``k``, an int — see :func:`repro.core.pipeline.mgg_aggregate_sparse`)
rides alongside the other knobs when the committed config carries it.
v3 (and older) files are discarded the same way.

Schema v5 persists the sampled mini-batch geometry: the per-hop
neighbor bound (``fanout``) and seed-batch size (``batch``) of the
sampled path (:mod:`repro.sample`) round-trip when the committed config
carries them, so a warm-started search re-validates the tuned sampling
geometry instead of re-climbing it.  v4 (and older) files are discarded
the same way.
"""
from __future__ import annotations

import contextlib
import dataclasses
import json
import os
import tempfile
import time
import warnings
from typing import Any, Dict, List, Optional, Sequence, Set

try:
    import fcntl
except ImportError:  # pragma: no cover - non-POSIX
    fcntl = None

from repro.core.autotune import WorkloadShape

__all__ = ["ConfigCache", "hardware_fingerprint", "shape_fingerprint",
           "layers_fingerprint"]

_VERSION = 5

_KNOBS = ("ps", "dist", "pb")

# optional integer knobs persisted when the committed config carries them
_OPT_INT_KNOBS = ("cap", "k", "fanout", "batch")

# paths whose version-mismatch discard has already been reported (once per
# process, not once per read — replicas poll the cache constantly)
_VERSION_WARNED: Set[str] = set()


def _valid_cfg(cfg: Any) -> bool:
    if not isinstance(cfg, dict) \
            or not all(isinstance(cfg.get(k), int) for k in _KNOBS):
        return False
    for k in _OPT_INT_KNOBS:
        if k in cfg and not isinstance(cfg[k], int):
            return False
    if "fuse" in cfg and not isinstance(cfg["fuse"], bool):
        return False
    return True


def _pack_cfg(cfg: Dict[str, Any]) -> Dict[str, Any]:
    """The persisted knob set: (ps, dist, pb) plus the optional knobs the
    committed config carries (cap/k v3-v4, fanout/batch v5, fuse bool)."""
    out: Dict[str, Any] = {k: int(cfg[k]) for k in _KNOBS}
    for k in _OPT_INT_KNOBS:
        if k in cfg:
            out[k] = int(cfg[k])
    if "fuse" in cfg:
        out["fuse"] = bool(cfg["fuse"])
    return out


def hardware_fingerprint() -> str:
    """platform:device_kind:count — stable across runs on the same host."""
    try:
        import jax

        devs = jax.devices()
    except Exception:
        return "nodev"
    if not devs:
        return "nodev"
    d0 = devs[0]
    kind = str(getattr(d0, "device_kind", d0.platform))
    return f"{d0.platform}:{kind}:{len(devs)}".replace(" ", "_")


def shape_fingerprint(w: WorkloadShape) -> str:
    return (f"ndev{w.n_dev}_d{w.d_feat}_rows{w.rows_per_dev}"
            f"_le{w.local_edges_max}_re{w.remote_edges_max}_it{w.itemsize}")


def layers_fingerprint(shapes: Sequence[WorkloadShape]) -> str:
    """Joint fingerprint of a per-layer shape stack (the topology part is
    shared, so only the widths vary between segments)."""
    dims = "-".join(str(w.d_feat) for w in shapes)
    return f"L{len(shapes)}_d{dims}|{shape_fingerprint(shapes[0])}"


class ConfigCache:
    """JSON-file-backed map: (shape, hardware) → tuned config."""

    def __init__(self, path: str, hw: Optional[str] = None):
        self.path = str(path)
        self.hw = hw if hw is not None else hardware_fingerprint()

    # -- key / io ------------------------------------------------------------

    def key(self, shape: WorkloadShape, hw: Optional[str] = None) -> str:
        return f"{shape_fingerprint(shape)}|{hw or self.hw}"

    @contextlib.contextmanager
    def _locked(self):
        """Exclusive cross-process lock for read-modify-write sections.

        A sidecar ``<path>.lock`` file is flocked so the cache file itself
        can keep being atomically replaced (flocking the data file would
        pin the lock to a replaced inode).  No-op where fcntl is missing.
        """
        if fcntl is None:
            yield
            return
        d = os.path.dirname(os.path.abspath(self.path))
        os.makedirs(d, exist_ok=True)
        with open(self.path + ".lock", "a") as lf:
            fcntl.flock(lf.fileno(), fcntl.LOCK_EX)
            try:
                yield
            finally:
                fcntl.flock(lf.fileno(), fcntl.LOCK_UN)

    def _load(self) -> Dict[str, Any]:
        data = None
        for attempt in range(3):
            try:
                with open(self.path) as f:
                    data = json.load(f)
                break
            except OSError:
                return {}
            except ValueError:
                # our writes are atomic (os.replace), but an external
                # non-atomic copy can expose a truncated file to a reader;
                # retry briefly before treating it as genuinely corrupt
                if attempt == 2:
                    return {}
                time.sleep(0.01 * (attempt + 1))
        if not isinstance(data, dict) or data.get("version") != _VERSION:
            key = os.path.abspath(self.path)
            if key not in _VERSION_WARNED:
                _VERSION_WARNED.add(key)
                found = data.get("version") if isinstance(data, dict) \
                    else None
                warnings.warn(
                    f"config cache {self.path}: discarding entries with "
                    f"schema version {found!r} (expected {_VERSION}); "
                    f"tuning starts cold", RuntimeWarning, stacklevel=3)
            return {}
        entries = data.get("entries")
        return entries if isinstance(entries, dict) else {}

    def _store(self, entries: Dict[str, Any]) -> None:
        payload = dict(version=_VERSION, entries=entries)
        d = os.path.dirname(os.path.abspath(self.path))
        os.makedirs(d, exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=d, prefix=".cfgcache-", suffix=".tmp")
        try:
            with os.fdopen(fd, "w") as f:
                json.dump(payload, f, indent=1, sort_keys=True)
            os.replace(tmp, self.path)
        except BaseException:
            try:
                os.unlink(tmp)
            except OSError:
                pass
            raise

    # -- public api ----------------------------------------------------------

    def get(self, shape: WorkloadShape,
            hw: Optional[str] = None) -> Optional[Dict[str, int]]:
        """The cached (ps, dist, pb) for this workload/hardware, or None."""
        entry = self._load().get(self.key(shape, hw))
        if not isinstance(entry, dict):
            return None
        cfg = entry.get("config")
        if _valid_cfg(cfg):
            return _pack_cfg(cfg)
        return None

    def put(self, shape: WorkloadShape, config: Dict[str, int],
            latency: float, hw: Optional[str] = None) -> None:
        with self._locked():
            entries = self._load()
            entries[self.key(shape, hw)] = dict(
                config=_pack_cfg(config),
                latency=float(latency),
                shape=dataclasses.asdict(shape),
                hw=hw or self.hw,
            )
            self._store(entries)

    # -- per-layer entries (schema v2+) ---------------------------------------

    def layers_key(self, shapes: Sequence[WorkloadShape],
                   hw: Optional[str] = None) -> str:
        return f"{layers_fingerprint(shapes)}|{hw or self.hw}"

    def get_layers(self, shapes: Sequence[WorkloadShape],
                   hw: Optional[str] = None) -> Optional[List[Dict[str, int]]]:
        """The cached per-layer configs for this shape stack, or None."""
        entry = self._load().get(self.layers_key(shapes, hw))
        if not isinstance(entry, dict):
            return None
        cfg = entry.get("config")
        layers = cfg.get("layers") if isinstance(cfg, dict) else None
        if (isinstance(layers, list) and len(layers) == len(shapes)
                and all(_valid_cfg(c) for c in layers)):
            return [_pack_cfg(c) for c in layers]
        return None

    def put_layers(self, shapes: Sequence[WorkloadShape],
                   configs: Sequence[Dict[str, int]], latency: float,
                   hw: Optional[str] = None) -> None:
        with self._locked():
            entries = self._load()
            entries[self.layers_key(shapes, hw)] = dict(
                config=dict(layers=[_pack_cfg(c) for c in configs]),
                latency=float(latency),
                shape=[dataclasses.asdict(s) for s in shapes],
                hw=hw or self.hw,
            )
            self._store(entries)

    def __len__(self) -> int:
        return len(self._load())
