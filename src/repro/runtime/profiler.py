"""Measurement harness for the online tuning runtime (paper §4).

The paper's runtime exploits the iterative nature of GNN training: every
epoch executes the same aggregation, so each iteration is a *free*
measurement of the current ``(ps, dist, wpb)`` configuration.  This module
supplies the two measurement paths the runtime needs:

* :class:`LatencyWindow` — an *online* accumulator fed with per-iteration
  wall times by the training loop.  It discards the first ``warmup``
  samples after every config swap (they carry jit recompilation) and
  reduces the rest to a percentile, which is what the tuner consumes.
* :class:`AggregateProfiler` — an *offline/benchmark* ``measure(ps, dist,
  pb) -> seconds`` callable that builds the plan, jits the pipelined
  aggregation, and times it (``time_jitted``).  When no usable devices are
  present — or ``mode="model"`` is forced — it falls back to the
  analytical :func:`repro.core.autotune.estimate_latency`, so the same
  tuner code runs in pure host-side tests and CI.

Both paths accept an injectable ``clock`` so tests drive them with a fake
clock deterministically.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.core.autotune import (HardwareSpec, TPU_V5E, WorkloadShape,
                                 estimate_latency)

__all__ = ["ProfileConfig", "LatencyWindow", "time_jitted",
           "AggregateProfiler"]


@dataclasses.dataclass(frozen=True)
class ProfileConfig:
    """How many samples make one measurement, and how they reduce.

    ``warmup`` samples are dropped (compile + cache-cold effects); the
    remaining ``iters`` reduce to the ``percentile``-th percentile (50 ⇒
    median — robust to straggler iterations, which the paper's measured
    search needs since one preempted step must not steer the descent).
    """

    warmup: int = 1
    iters: int = 3
    percentile: float = 50.0

    @property
    def samples_needed(self) -> int:
        return self.warmup + self.iters


class LatencyWindow:
    """Accumulates per-iteration step times for ONE candidate config."""

    def __init__(self, cfg: ProfileConfig = ProfileConfig()):
        self.cfg = cfg
        self.samples: List[float] = []

    def add(self, dt: float) -> bool:
        """Record one step time; True once the window is full."""
        self.samples.append(float(dt))
        return self.ready

    @property
    def ready(self) -> bool:
        return len(self.samples) >= self.cfg.samples_needed

    def value(self) -> float:
        """The reduced measurement (percentile over post-warmup samples)."""
        kept = self.samples[self.cfg.warmup:]
        if not kept:
            raise ValueError("LatencyWindow.value() before any sample")
        return float(np.percentile(np.asarray(kept), self.cfg.percentile))

    def reset(self) -> None:
        self.samples.clear()


def time_jitted(fn: Callable, *args, cfg: ProfileConfig = ProfileConfig(),
                clock: Callable[[], float] = time.perf_counter) -> float:
    """Time a jitted callable: warmup calls, then percentile-of-iters.

    Every call is synchronized with ``jax.block_until_ready`` so the
    device queue cannot hide work past the clock read.
    """
    import jax

    for _ in range(cfg.warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(cfg.iters):
        t0 = clock()
        jax.block_until_ready(fn(*args))
        times.append(clock() - t0)
    return float(np.percentile(np.asarray(times), cfg.percentile))


class AggregateProfiler:
    """``measure(ps, dist, pb)`` over real jitted aggregation steps.

    ``mode``:
      * ``"measure"`` — always build + time the real pipelined aggregation
        on ``mesh`` (raises if no devices are available);
      * ``"model"`` — always use the analytical latency model;
      * ``"auto"`` — measure when a mesh and at least one device exist,
        model otherwise (the documented fallback).

    Measurements are memoized per ``(ps, dist, pb)`` — re-probing a config
    the search already visited is free, mirroring the paper's lookup table.
    """

    def __init__(
        self,
        graph,
        mesh,
        d_feat: int,
        *,
        axis_name: str = "ring",
        interleave: bool = True,
        use_kernel: bool = False,
        profile: ProfileConfig = ProfileConfig(warmup=1, iters=3),
        hw: HardwareSpec = TPU_V5E,
        mode: str = "auto",
        seed: int = 0,
        clock: Callable[[], float] = time.perf_counter,
    ):
        if mode not in ("auto", "measure", "model"):
            raise ValueError(f"unknown profiler mode {mode!r}")
        self.graph = graph
        self.mesh = mesh
        self.d_feat = int(d_feat)
        self.axis_name = axis_name
        self.interleave = interleave
        self.use_kernel = use_kernel
        self.profile = profile
        self.hw = hw
        self.mode = mode
        self.clock = clock
        self._x = np.random.default_rng(seed).normal(
            size=(graph.num_nodes, self.d_feat)).astype(np.float32)
        self._table: Dict[Tuple[int, int, int], float] = {}
        self._shape: Optional[WorkloadShape] = None

    # -- capability probing --------------------------------------------------

    def can_measure(self) -> bool:
        if self.mesh is None:
            return False
        try:
            import jax

            return len(jax.devices()) > 0
        except Exception:
            return False

    @property
    def measuring(self) -> bool:
        if self.mode == "measure":
            if not self.can_measure():
                raise RuntimeError(
                    "AggregateProfiler(mode='measure') but no devices/mesh "
                    "available — use mode='auto' for the analytical fallback")
            return True
        return self.mode == "auto" and self.can_measure()

    def workload_shape(self) -> WorkloadShape:
        if self._shape is None:
            n_dev = (self.mesh.shape[self.axis_name] if self.mesh is not None
                     else 1)
            self._shape = WorkloadShape.from_graph(
                self.graph, n_dev, self.d_feat)
        return self._shape

    # -- the measure callable ------------------------------------------------

    def __call__(self, ps: int, dist: int, pb: int) -> float:
        key = (int(ps), int(dist), int(pb))
        if key not in self._table:
            if self.measuring:
                self._table[key] = self._measure(*key)
            else:
                self._table[key] = float(estimate_latency(
                    self.workload_shape(), *key, hw=self.hw,
                    interleave=self.interleave))
        return self._table[key]

    def observations(self) -> List[Tuple[dict, float]]:
        """The memo table as ``(config, latency)`` pairs — calibration
        fodder for :func:`repro.obs.calibrate.fit_spec` (only meaningful
        when measuring; in model mode the pairs would just refit the
        model to itself)."""
        return [(dict(ps=ps, dist=dist, pb=pb), lat)
                for (ps, dist, pb), lat in self._table.items()
                if np.isfinite(lat) and lat > 0.0]

    def _measure(self, ps: int, dist: int, pb: int) -> float:
        import jax
        import jax.numpy as jnp

        from repro.core.gnn import GNNEngine

        eng = GNNEngine.build(
            self.graph, self.mesh, axis_name=self.axis_name, ps=ps,
            dist=dist, pb=pb if self.use_kernel else None,
            interleave=self.interleave, use_kernel=self.use_kernel,
            self_loops=False,
        )
        xb = eng.shard(eng.pad(self._x))
        fn = jax.jit(eng.aggregate)
        return time_jitted(fn, xb, cfg=self.profile, clock=self.clock)
