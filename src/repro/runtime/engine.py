"""DynamicGNNEngine — the paper's intelligent runtime around the GNN engine.

Wraps :class:`repro.core.gnn.GNNEngine` so the aggregation configuration
``(ps, dist, pb)`` can change *during* training without touching model
parameters: the training loop feeds each iteration's wall time into
:meth:`observe_step`; once a :class:`~repro.runtime.profiler.LatencyWindow`
fills, the reduced measurement goes to the
:class:`~repro.runtime.tuner.OnlineTuner`, and whenever the tuner moves to
a new candidate (or commits its final answer) the engine rebuilds the
aggregation plan — and, on the kernel path, the partition-blocked kernel —
for the new knobs.

Only the *engine* state is rebuilt.  Model parameters never move; what DOES
change with ``dist`` is the padded PGAS layout (``rows_per_dev`` is padded
to a multiple of ``dist``), so ``observe_step`` returns ``True`` when a
rebuild happened and the caller must re-pad node tables and re-jit its step
function (see examples/train_gnn.py's ``--dynamic-tune`` path).  Because
padded rows are masked out of both the loss and the aggregation, the loss
trajectory under any fixed config is bitwise identical to a static
:class:`GNNEngine` run with that config — the runtime machinery adds
measurement and plan swaps, never different math.

A :class:`~repro.runtime.cache.ConfigCache` (optional) warm-starts the
search from the config a previous run converged to for the same
workload-shape + hardware fingerprint, and receives the committed config
when this run's search closes.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.autotune import (HardwareSpec, TPU_V5E, WorkloadShape,
                                 layer_workload_shapes)
from repro.core.gnn import GNNEngine
from repro.core.graph import CSRGraph
from repro.obs import NULL_TRACER
from repro.runtime.cache import ConfigCache
from repro.runtime.profiler import LatencyWindow, ProfileConfig
from repro.runtime.tuner import (DEFAULT_DIST, DEFAULT_PB, DEFAULT_PS,
                                 OnlineTuner, PerLayerTuner, make_vmem_check)

__all__ = ["DynamicGNNEngine"]


def _finite(obj):
    """JSON-safe copy: non-finite floats become None (Perfetto rejects
    the ``Infinity`` literal Python's json module would otherwise emit)."""
    if isinstance(obj, dict):
        return {k: _finite(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_finite(v) for v in obj]
    if isinstance(obj, float) and not np.isfinite(obj):
        return None
    return obj


def _as_config_dict(cfg) -> Dict:
    """Normalize a tuner proposal: a per-layer list becomes
    ``{"layers": [...]}`` so every config in histories/caches/logs is a
    plain dict."""
    if isinstance(cfg, list):
        return dict(layers=[dict(c) for c in cfg])
    return dict(cfg)


class DynamicGNNEngine:
    """A GNNEngine whose (ps, dist, pb) re-optimizes across iterations.

    Two tuning modes share one protocol:

    * **global** (an :class:`OnlineTuner`) — one (ps, dist, pb) for every
      layer; configs are ``{ps, dist, pb}`` dicts.
    * **per-layer** (a :class:`PerLayerTuner`, selected by passing
      ``layer_dims`` to :meth:`build`) — each layer runs its own plan over
      the shared partition; configs are ``{"layers": [{ps, dist, pb}, …]}``.
    """

    def __init__(
        self,
        graph: CSRGraph,
        mesh,
        *,
        tuner,
        shape: WorkloadShape,
        window: ProfileConfig = ProfileConfig(warmup=1, iters=3),
        cache: Optional[ConfigCache] = None,
        axis_name: str = "ring",
        interleave: bool = True,
        use_kernel: bool = False,
        self_loops: bool = True,
        fuse_update: bool = False,
        layer_dims: Optional[Sequence[int]] = None,
        hw: HardwareSpec = TPU_V5E,
        log_fn: Callable[[str], None] = lambda _s: None,
        tracer=None,
        metrics=None,
    ):
        self.graph = graph
        self.mesh = mesh
        self.tuner = tuner
        # observability: tuner audit events flow through _on_audit into the
        # tracer (as tuner.* instants) and metrics registry.  NULL_TRACER's
        # recording calls are no-ops, so the default costs one branch.
        self.tracer = tracer if tracer is not None else NULL_TRACER
        self.metrics = metrics
        tuner.audit_sink = self._on_audit
        self.shape = shape
        self.cache = cache
        self.hw = hw
        self.axis_name = axis_name
        self.interleave = interleave
        self.use_kernel = use_kernel
        self.self_loops = self_loops
        self.fuse_update = fuse_update
        self.layer_dims = list(layer_dims) if layer_dims is not None else None
        self.log = log_fn
        self._window = LatencyWindow(window)
        self.step_count = 0
        self.committed = False
        self._layer_shapes: Optional[List[WorkloadShape]] = None
        # the MODEL's feature width as reported by the caller — in per-layer
        # mode self.shape holds the max aggregation width instead, so the
        # retune() unchanged-d_feat check needs this separately (build()
        # overwrites it with the true model width)
        self._model_d_feat = shape.d_feat
        self._partition = None   # SharedPartition, reused across tuner moves
        self.history: List[Tuple[int, Dict[str, int]]] = []
        cfg0 = tuner.propose()
        if cfg0 is None:  # empty search space ⇒ static engine at defaults
            cfg0 = dict(ps=DEFAULT_PS[0], dist=DEFAULT_DIST[0],
                        pb=DEFAULT_PB[0])
            if self.per_layer:
                cfg0 = [cfg0] * len(self.layer_dims)
            self.committed = True
        self._config = _as_config_dict(cfg0)
        self.engine = self._build_engine(self._config)
        self.history.append((0, dict(self._config)))

    @property
    def per_layer(self) -> bool:
        return self.layer_dims is not None

    # -- construction --------------------------------------------------------

    @classmethod
    def build(
        cls,
        graph: CSRGraph,
        mesh,
        *,
        d_feat: int,
        ps_space: Tuple[int, ...] = DEFAULT_PS,
        dist_space: Tuple[int, ...] = DEFAULT_DIST,
        pb_space: Tuple[int, ...] = DEFAULT_PB,
        cap_space: Tuple[int, ...] = (),
        k_space: Tuple[int, ...] = (),
        fanout_space: Tuple[int, ...] = (),
        batch_space: Tuple[int, ...] = (),
        tune_fuse: bool = False,
        window: ProfileConfig = ProfileConfig(warmup=1, iters=3),
        cache_path: Optional[str] = None,
        budget: Optional[int] = None,
        drift_threshold: float = 0.25,
        hw: HardwareSpec = TPU_V5E,
        axis_name: str = "ring",
        interleave: bool = True,
        use_kernel: bool = False,
        self_loops: bool = True,
        fuse_update: bool = False,
        layer_dims: Optional[Sequence[int]] = None,
        log_fn: Callable[[str], None] = lambda _s: None,
        tracer=None,
        metrics=None,
    ) -> "DynamicGNNEngine":
        """``layer_dims`` (one aggregation feature width per layer, e.g.
        ``aggregation_widths(model, params)``) selects per-layer tuning:
        a :class:`PerLayerTuner` searches each layer's (ps, dist, pb) over
        one shared partition, warm-started from the global search.

        ``cap_space`` makes the tiered feature-cache capacity (rows held
        device-resident by :class:`repro.store.TieredFeatures`) a tuned
        knob — configs then carry a ``cap`` key, surfaced via
        :attr:`feature_capacity` for the storage layer to adopt.
        ``k_space`` makes the top-k compression width of the sparse ring
        payload (:func:`repro.core.pipeline.mgg_aggregate_sparse`) a tuned
        knob — configs then carry a ``k`` key, applied to hidden layers
        only (layer 0 stays dense; see :meth:`GNNEngine.stage_topk`).
        Offer only widths whose accuracy the caller has validated: the
        tuner's objective is latency, so it will take the narrowest
        candidate that measures fastest.
        ``fanout_space`` / ``batch_space`` make the sampled mini-batch
        geometry (:mod:`repro.sample`) tuned knobs — configs then carry
        ``fanout``/``batch`` keys, surfaced via :attr:`sample_fanout` /
        :attr:`sample_batch` for the sampling loop to adopt (they never
        reach the ring plans).  Same accuracy caveat as ``k_space``, and
        feed per-seed latencies (``dt / batch``) to ``observe_step`` if
        batch should optimize throughput (see :class:`OnlineTuner`).
        ``tune_fuse`` (per-layer mode only) probes flipping each layer's
        fused-update dataflow after its (ps, dist, pb) search settles;
        ``fuse_update`` remains the starting point for every layer."""
        if tune_fuse and layer_dims is None:
            raise ValueError(
                "tune_fuse probes a per-layer dataflow knob — pass "
                "layer_dims to select per-layer tuning")
        n_dev = mesh.shape[axis_name]
        g = graph.with_self_loops() if self_loops else graph
        if not use_kernel:
            # pb only reaches the partition-blocked Pallas kernel; on the
            # jnp path every pb builds the identical computation, so probing
            # it would spend real training iterations measuring recompile
            # noise.  Collapse the dimension instead of searching it.
            pb_space = (min(pb_space),)
        cache = ConfigCache(cache_path) if cache_path else None
        if layer_dims is not None:
            shapes = layer_workload_shapes(g, n_dev, list(layer_dims))
            shape = max(shapes, key=lambda s: s.d_feat)
            warm = cache.get_layers(shapes) if cache is not None else None
            if warm is None and cache is not None:
                # a previous GLOBAL run's entry still seeds phase 1 — look
                # it up under the key global mode writes (d_feat, not the
                # aggregation width, which differs e.g. for unfused GCN)
                warm = cache.get(shapes[0].with_d_feat(int(d_feat)))
            warm = cls._clamp_pb(warm, pb_space)
            tuner = PerLayerTuner(
                len(shapes), ps_space, dist_space, pb_space,
                cap_space=cap_space, k_space=k_space,
                fanout_space=fanout_space, batch_space=batch_space,
                fuse_space=((fuse_update, not fuse_update) if tune_fuse
                            else (fuse_update,)),
                vmem_checks=[make_vmem_check(s, hw) for s in shapes],
                budget=budget, drift_threshold=drift_threshold,
                warm_start=warm,
            )
            tuner.observe_shape(shapes)
        else:
            shape = WorkloadShape.from_graph(g, n_dev, int(d_feat))
            warm = cache.get(shape) if cache is not None else None
            warm = cls._clamp_pb(warm, pb_space)
            tuner = OnlineTuner(
                ps_space, dist_space, pb_space, cap_space=cap_space,
                k_space=k_space,
                fanout_space=fanout_space, batch_space=batch_space,
                vmem_check=make_vmem_check(shape, hw),
                budget=budget, drift_threshold=drift_threshold,
                warm_start=warm,
            )
            tuner.observe_shape(shape)
        if warm is not None:
            log_fn(f"[runtime] warm start from cache: {warm}")
        eng = cls(graph, mesh, tuner=tuner, shape=shape, window=window,
                  cache=cache, axis_name=axis_name, interleave=interleave,
                  use_kernel=use_kernel, self_loops=self_loops,
                  fuse_update=fuse_update, layer_dims=layer_dims, hw=hw,
                  log_fn=log_fn, tracer=tracer, metrics=metrics)
        if layer_dims is not None:
            eng._layer_shapes = shapes
        eng._model_d_feat = int(d_feat)
        return eng

    @staticmethod
    def _clamp_pb(warm, pb_space):
        """Cached pb values outside the live space fall back to its floor."""
        if warm is None:
            return None
        if isinstance(warm, list):
            return [dict(c, pb=c["pb"] if c["pb"] in pb_space else pb_space[0])
                    for c in warm]
        if warm["pb"] not in pb_space:
            warm = dict(warm, pb=pb_space[0])
        return warm

    def _build_engine(self, cfg: Dict) -> GNNEngine:
        def _lc(c):
            # "cap" (storage layer — see feature_capacity) and
            # "fanout"/"batch" (sampling loop — see sample_fanout /
            # sample_batch) never reach the plan; "fuse" selects the
            # layer's dataflow; "k" is the sparse-payload width
            # (0/absent ⇒ dense ring).
            lc = dict(ps=int(c["ps"]), dist=int(c["dist"]),
                      pb=int(c["pb"]) if self.use_kernel else None)
            if "fuse" in c:
                lc["fuse_update"] = bool(c["fuse"])
            if c.get("k"):
                lc["topk"] = int(c["k"])
            return lc

        # The node split + locality split depend only on (graph, n_dev):
        # build them once and re-derive only the schedules on tuner moves
        # (invalidated in retune() when the topology changes).
        if "layers" in cfg:
            eng = GNNEngine.build(
                self.graph, self.mesh, axis_name=self.axis_name,
                layer_configs=[_lc(c) for c in cfg["layers"]],
                interleave=self.interleave, use_kernel=self.use_kernel,
                self_loops=self.self_loops, fuse_update=self.fuse_update,
                partition=self._partition,
            )
        else:
            eng = GNNEngine.build(
                self.graph, self.mesh, axis_name=self.axis_name,
                ps=int(cfg["ps"]), dist=int(cfg["dist"]),
                pb=int(cfg["pb"]) if self.use_kernel else None,
                topk=int(cfg["k"]) if cfg.get("k") else None,
                interleave=self.interleave, use_kernel=self.use_kernel,
                self_loops=self.self_loops, fuse_update=self.fuse_update,
                partition=self._partition,
            )
        self._partition = eng.partition
        return eng

    # -- GNNEngine surface (delegation: models take either engine) -----------

    @property
    def plan(self):
        return self.engine.plan

    @property
    def layer_plans(self):
        return self.engine.layer_plans

    def layer_plan(self, layer: int):
        return self.engine.layer_plan(layer)

    @property
    def layer_configs(self) -> List[Dict[str, int]]:
        return self.engine.layer_configs

    @property
    def deg(self):
        return self.engine.deg

    @property
    def config(self) -> Dict:
        return dict(self._config)

    def _global_knob(self, key: str) -> Optional[int]:
        """A globally-pinned optional knob's live value (per-layer configs
        pin one value across layers, so the first carrier is THE value)."""
        cfg = self._config
        if "layers" in cfg:
            for c in cfg["layers"]:
                if key in c:
                    return int(c[key])
            return None
        return int(cfg[key]) if key in cfg else None

    @property
    def feature_capacity(self) -> Optional[int]:
        """The live config's tiered-cache capacity (``cap`` knob), or
        None when capacity is not being tuned.  Per-layer configs pin one
        cap across layers (the feature table is shared), so the first
        layer's value is THE value."""
        return self._global_knob("cap")

    @property
    def sample_fanout(self) -> Optional[int]:
        """The live config's sampled-path per-hop neighbor bound
        (``fanout`` knob), or None when sampling is not being tuned.
        Global like ``cap`` — one block pipeline feeds every layer."""
        return self._global_knob("fanout")

    @property
    def sample_batch(self) -> Optional[int]:
        """The live config's sampled-path seed-batch size (``batch``
        knob), or None when sampling is not being tuned."""
        return self._global_knob("batch")

    def pad(self, x: np.ndarray) -> np.ndarray:
        return self.engine.pad(x)

    def shard(self, x):
        return self.engine.shard(x)

    def aggregate(self, x, layer: int = 0, update_w=None, topk=None):
        return self.engine.aggregate(x, layer=layer, update_w=update_w,
                                     topk=topk)

    def aggregate_update(self, x, w, layer: int = 0, topk=None):
        return self.engine.aggregate_update(x, w, layer=layer, topk=topk)

    def aggregate_streamed(self, tiered, layer: int = 0, update_w=None,
                           topk=None, stats=None, tracer=None):
        return self.engine.aggregate_streamed(
            tiered, layer=layer, update_w=update_w, topk=topk, stats=stats,
            tracer=tracer if tracer is not None else self.tracer)

    def stage_topk(self, layer: int):
        return self.engine.stage_topk(layer)

    def gcn_norm_aggregate(self, x, layer: int = 0, topk=None):
        return self.engine.gcn_norm_aggregate(x, layer=layer, topk=topk)

    def gcn_norm_aggregate_update(self, x, w, layer: int = 0, topk=None):
        return self.engine.gcn_norm_aggregate_update(x, w, layer=layer,
                                                     topk=topk)

    def mean_aggregate(self, x, layer: int = 0, topk=None):
        return self.engine.mean_aggregate(x, layer=layer, topk=topk)

    def mean_aggregate_update(self, x, w, layer: int = 0, topk=None):
        return self.engine.mean_aggregate_update(x, w, layer=layer, topk=topk)

    # -- observability -------------------------------------------------------

    @property
    def audit(self) -> List[dict]:
        """The tuner's audit trail (probe/reopen/retreat/adopt/commit
        events) — the machine-readable answer to "why this config"."""
        return self.tuner.audit

    def _on_audit(self, ev: dict) -> None:
        safe = _finite(ev)
        self.tracer.instant("tuner." + ev["event"], cat="tuner", **safe)
        if self.metrics is not None:
            self.metrics.counter("tuner.events", event=ev["event"]).inc()
            if ev["event"] == "probe":
                # model-vs-measured relative error for every probed config:
                # the continuous check that the §4 analytical model still
                # ranks configs the way this machine measures them (numpy
                # only — no device work on the audit path)
                err = self._model_error(ev)
                if err is not None:
                    self.metrics.histogram("tuner.model_error").observe(err)

    def _model_error(self, ev: dict) -> Optional[float]:
        cfg = ev.get("config") or ev.get("configs")
        lat = ev.get("latency")
        if cfg is None or lat is None or not np.isfinite(lat) or lat <= 0:
            return None
        from repro.obs.calibrate import model_latency
        shapes = self._layer_shapes if isinstance(cfg, list) else self.shape
        if shapes is None:
            return None
        try:
            model = model_latency(shapes, cfg, self.hw,
                                  interleave=self.interleave)
        except Exception:
            return None
        return abs(model - float(lat)) / float(lat)

    def calibrate(self, *, params=None, adopt: bool = True):
        """Fit ``self.hw`` to the latencies the search actually measured.

        Runs :func:`repro.obs.calibrate.fit_spec` over the audit trail's
        probe observations; with ``adopt=True`` (default) the calibrated
        spec replaces ``self.hw``, so subsequent re-tunes build their VMEM
        feasibility checks and model-error baselines against measured
        hardware constants instead of the shipped ones.  Returns the
        :class:`~repro.obs.calibrate.CalibrationResult` (None when the
        trail holds no usable measurements yet).
        """
        from repro.obs import calibrate as cal

        obs = self.tuner.observations()
        shapes = self._layer_shapes if self.per_layer else self.shape
        if shapes is None:
            return None
        kw = {} if params is None else {"params": params}
        result = cal.fit_spec(shapes, obs, self.hw,
                              interleave=self.interleave, **kw)
        if result is None:
            return None
        if self.metrics is not None:
            self.metrics.gauge("tuner.calibration_error").set(result.error)
            self.metrics.gauge("tuner.calibration_error_base") \
                .set(result.base_error)
        self.tracer.instant("tuner.calibrate", cat="tuner",
                            error=result.error, base_error=result.base_error,
                            n=result.n_observations)
        self.log(f"[runtime] {result.summary()}")
        if adopt:
            self.hw = result.spec
        return result

    # -- the online tuning protocol ------------------------------------------

    def observe_step(self, dt: float) -> bool:
        """Feed one training iteration's wall time.

        Returns True when the engine was rebuilt for a new config — the
        caller must then re-pad its node tables (layout may have changed
        with ``dist``) and re-jit anything that closed over the engine.
        """
        self.step_count += 1
        if self.metrics is not None:
            self.metrics.histogram("runtime.step_seconds").observe(dt)
        if self.tuner.converged:
            return False
        self._window.add(dt)
        if not self._window.ready:
            return False
        latency = self._window.value()
        self._window.reset()
        self.tuner.observe(latency)
        nxt = self.tuner.propose()
        if self.tuner.converged:
            return self._commit()
        return self._set_config(_as_config_dict(nxt))

    def retune(self, graph: Optional[CSRGraph] = None,
               d_feat: Optional[int] = None, *,
               layer_dims: Optional[Sequence[int]] = None,
               force: bool = False, from_cache: bool = False) -> bool:
        """Drift entry point: the workload changed (graph grew, features
        resized).  Recomputes the WorkloadShape; if it drifted past the
        tuner's threshold the search re-opens (warm-started from the old
        best) and the engine rebuilds against the new graph.

        Per-layer engines report width changes via ``layer_dims`` (one
        aggregation width per layer — a single ``d_feat`` cannot describe
        them); passing a changed ``d_feat`` alone there is an error rather
        than a silently dropped drift signal.

        ``force=True`` re-opens the search even when the WorkloadShape is
        unchanged.  This is the *traffic*-drift path: a serving frontend
        (see repro.serve.gnn) observes request statistics the shape cannot
        see — hot-set rotations, burst load — and the measured latency
        surface under the new traffic is stale evidence either way, so the
        caller's drift signal overrides the shape comparison.

        ``from_cache=True`` (only meaningful with ``force``) warm-starts
        the re-opened search from the shared :class:`ConfigCache` entry in
        *adopt* mode: a sibling serving replica already re-searched under
        the same shift and committed its optimum, so this engine validates
        that config with a single measurement instead of re-exploring
        (falls back to the normal warm re-search on a cache miss).
        """
        if graph is not None:
            self.graph = graph
            self._partition = None   # topology changed: re-partition
        if self.per_layer and d_feat is not None \
                and int(d_feat) != self._model_d_feat and layer_dims is None:
            raise ValueError(
                "per-layer engine: report feature-width changes via "
                "retune(layer_dims=[...]) — a lone d_feat cannot describe "
                "per-layer aggregation widths")
        if d_feat is None:
            d_feat = self._model_d_feat if self.per_layer \
                else self.shape.d_feat
        self._model_d_feat = int(d_feat)
        if layer_dims is not None:
            if not self.per_layer:
                raise ValueError("layer_dims on a global-mode engine")
            self.layer_dims = list(layer_dims)
        g = (self.graph.with_self_loops() if self.self_loops else self.graph)
        n_dev = self.mesh.shape[self.axis_name]
        if self.per_layer:
            shapes = layer_workload_shapes(g, n_dev, self.layer_dims)
            shape = max(shapes, key=lambda s: s.d_feat)
            reopened = self.tuner.observe_shape(shapes)
        else:
            shapes = None
            shape = WorkloadShape.from_graph(g, n_dev, int(d_feat))
            reopened = self.tuner.observe_shape(shape)
        adopted = False
        if force and not reopened:
            warm = None
            if from_cache and self.cache is not None:
                warm = (self.cache.get_layers(shapes) if self.per_layer
                        else self.cache.get(shape))
                warm = self._clamp_pb(warm, self.tuner.pb_space)
            if warm is not None:
                self.tuner.reopen(warm_start=warm, mode="adopt",
                                  cause="cache_adopt")
                adopted = True
                self.log(f"[runtime] adopting shared-cache config: {warm}")
            else:
                self.tuner.reopen(cause="traffic_drift")
            reopened = True
        if reopened and self.per_layer and not adopted:
            # the layer count / per-layer widths may have moved: resize the
            # search and rebuild the VMEM feasibility predicates against the
            # LIVE shapes (stale checks would admit configs that spill)
            self.tuner.reconfigure(
                num_layers=len(shapes),
                vmem_checks=[make_vmem_check(s, self.hw) for s in shapes])
        if reopened:
            self.shape = shape
            self._layer_shapes = shapes
            self.committed = False
            self._window.reset()
            self.log(f"[runtime] workload drift → search re-opened "
                     f"(reopen #{self.tuner.reopens})")
            nxt = self.tuner.propose()
            if nxt is not None:
                self._set_config(_as_config_dict(nxt),
                                 force_rebuild=graph is not None)
        elif graph is not None:
            # same shape class, new topology: rebuild the plan in place
            self.engine = self._build_engine(self._config)
        return reopened

    # -- internals -----------------------------------------------------------

    def _commit(self) -> bool:
        best = self.tuner.best
        self.committed = True
        if best is None:  # nothing measurable (all configs vmem-rejected)
            return False
        if self.cache is not None:
            if self.per_layer and self._layer_shapes is not None:
                self.cache.put_layers(self._layer_shapes, best,
                                      self.tuner.best_latency)
            elif not self.per_layer:
                self.cache.put(self.shape, best, self.tuner.best_latency)
        self.log(f"[runtime] tuning converged after "
                 f"{self.tuner.measured} measurements: {best} "
                 f"({self.tuner.best_latency * 1e3:.2f} ms)")
        self.tuner._emit("commit", config=_as_config_dict(best),
                         latency=self.tuner.best_latency,
                         step=self.step_count)
        return self._set_config(_as_config_dict(best))

    def _set_config(self, cfg: Dict,
                    force_rebuild: bool = False) -> bool:
        if cfg == self._config and not force_rebuild:
            return False
        self._config = dict(cfg)
        self.engine = self._build_engine(self._config)
        self.history.append((self.step_count, dict(self._config)))
        self.log(f"[runtime] step {self.step_count}: config → {self._config}")
        return True
