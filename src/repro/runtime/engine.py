"""DynamicGNNEngine — the paper's intelligent runtime around the GNN engine.

Wraps :class:`repro.core.gnn.GNNEngine` so the aggregation configuration
``(ps, dist, pb)`` can change *during* training without touching model
parameters: the training loop feeds each iteration's wall time into
:meth:`observe_step`; once a :class:`~repro.runtime.profiler.LatencyWindow`
fills, the reduced measurement goes to the
:class:`~repro.runtime.tuner.OnlineTuner`, and whenever the tuner moves to
a new candidate (or commits its final answer) the engine rebuilds the
aggregation plan — and, on the kernel path, the partition-blocked kernel —
for the new knobs.

Only the *engine* state is rebuilt.  Model parameters never move; what DOES
change with ``dist`` is the padded PGAS layout (``rows_per_dev`` is padded
to a multiple of ``dist``), so ``observe_step`` returns ``True`` when a
rebuild happened and the caller must re-pad node tables and re-jit its step
function (see examples/train_gnn.py's ``--dynamic-tune`` path).  Because
padded rows are masked out of both the loss and the aggregation, the loss
trajectory under any fixed config is bitwise identical to a static
:class:`GNNEngine` run with that config — the runtime machinery adds
measurement and plan swaps, never different math.

A :class:`~repro.runtime.cache.ConfigCache` (optional) warm-starts the
search from the config a previous run converged to for the same
workload-shape + hardware fingerprint, and receives the committed config
when this run's search closes.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.core.autotune import HardwareSpec, TPU_V5E, WorkloadShape
from repro.core.gnn import GNNEngine
from repro.core.graph import CSRGraph
from repro.runtime.cache import ConfigCache
from repro.runtime.profiler import LatencyWindow, ProfileConfig
from repro.runtime.tuner import (DEFAULT_DIST, DEFAULT_PB, DEFAULT_PS,
                                 OnlineTuner, make_vmem_check)

__all__ = ["DynamicGNNEngine"]


class DynamicGNNEngine:
    """A GNNEngine whose (ps, dist, pb) re-optimizes across iterations."""

    def __init__(
        self,
        graph: CSRGraph,
        mesh,
        *,
        tuner: OnlineTuner,
        shape: WorkloadShape,
        window: ProfileConfig = ProfileConfig(warmup=1, iters=3),
        cache: Optional[ConfigCache] = None,
        axis_name: str = "ring",
        interleave: bool = True,
        use_kernel: bool = False,
        self_loops: bool = True,
        log_fn: Callable[[str], None] = lambda _s: None,
    ):
        self.graph = graph
        self.mesh = mesh
        self.tuner = tuner
        self.shape = shape
        self.cache = cache
        self.axis_name = axis_name
        self.interleave = interleave
        self.use_kernel = use_kernel
        self.self_loops = self_loops
        self.log = log_fn
        self._window = LatencyWindow(window)
        self.step_count = 0
        self.committed = False
        self.history: List[Tuple[int, Dict[str, int]]] = []
        cfg0 = tuner.propose()
        if cfg0 is None:  # empty search space ⇒ static engine at defaults
            cfg0 = dict(ps=DEFAULT_PS[0], dist=DEFAULT_DIST[0],
                        pb=DEFAULT_PB[0])
            self.committed = True
        self._config = dict(cfg0)
        self.engine = self._build_engine(self._config)
        self.history.append((0, dict(self._config)))

    # -- construction --------------------------------------------------------

    @classmethod
    def build(
        cls,
        graph: CSRGraph,
        mesh,
        *,
        d_feat: int,
        ps_space: Tuple[int, ...] = DEFAULT_PS,
        dist_space: Tuple[int, ...] = DEFAULT_DIST,
        pb_space: Tuple[int, ...] = DEFAULT_PB,
        window: ProfileConfig = ProfileConfig(warmup=1, iters=3),
        cache_path: Optional[str] = None,
        budget: Optional[int] = None,
        drift_threshold: float = 0.25,
        hw: HardwareSpec = TPU_V5E,
        axis_name: str = "ring",
        interleave: bool = True,
        use_kernel: bool = False,
        self_loops: bool = True,
        log_fn: Callable[[str], None] = lambda _s: None,
    ) -> "DynamicGNNEngine":
        n_dev = mesh.shape[axis_name]
        g = graph.with_self_loops() if self_loops else graph
        shape = WorkloadShape.from_graph(g, n_dev, int(d_feat))
        if not use_kernel:
            # pb only reaches the partition-blocked Pallas kernel; on the
            # jnp path every pb builds the identical computation, so probing
            # it would spend real training iterations measuring recompile
            # noise.  Collapse the dimension instead of searching it.
            pb_space = (min(pb_space),)
        cache = ConfigCache(cache_path) if cache_path else None
        warm = cache.get(shape) if cache is not None else None
        if warm is not None and warm["pb"] not in pb_space:
            warm = dict(warm, pb=pb_space[0])
        tuner = OnlineTuner(
            ps_space, dist_space, pb_space,
            vmem_check=make_vmem_check(shape, hw),
            budget=budget, drift_threshold=drift_threshold,
            warm_start=warm,
        )
        tuner.observe_shape(shape)
        if warm is not None:
            log_fn(f"[runtime] warm start from cache: {warm}")
        return cls(graph, mesh, tuner=tuner, shape=shape, window=window,
                   cache=cache, axis_name=axis_name, interleave=interleave,
                   use_kernel=use_kernel, self_loops=self_loops,
                   log_fn=log_fn)

    def _build_engine(self, cfg: Dict[str, int]) -> GNNEngine:
        return GNNEngine.build(
            self.graph, self.mesh, axis_name=self.axis_name,
            ps=int(cfg["ps"]), dist=int(cfg["dist"]),
            pb=int(cfg["pb"]) if self.use_kernel else None,
            interleave=self.interleave, use_kernel=self.use_kernel,
            self_loops=self.self_loops,
        )

    # -- GNNEngine surface (delegation: models take either engine) -----------

    @property
    def plan(self):
        return self.engine.plan

    @property
    def deg(self):
        return self.engine.deg

    @property
    def config(self) -> Dict[str, int]:
        return dict(self._config)

    def pad(self, x: np.ndarray) -> np.ndarray:
        return self.engine.pad(x)

    def shard(self, x):
        return self.engine.shard(x)

    def aggregate(self, x):
        return self.engine.aggregate(x)

    def gcn_norm_aggregate(self, x):
        return self.engine.gcn_norm_aggregate(x)

    def mean_aggregate(self, x):
        return self.engine.mean_aggregate(x)

    # -- the online tuning protocol ------------------------------------------

    def observe_step(self, dt: float) -> bool:
        """Feed one training iteration's wall time.

        Returns True when the engine was rebuilt for a new config — the
        caller must then re-pad its node tables (layout may have changed
        with ``dist``) and re-jit anything that closed over the engine.
        """
        self.step_count += 1
        if self.tuner.converged:
            return False
        self._window.add(dt)
        if not self._window.ready:
            return False
        latency = self._window.value()
        self._window.reset()
        self.tuner.observe(latency)
        nxt = self.tuner.propose()
        if self.tuner.converged:
            return self._commit()
        return self._set_config(nxt)

    def retune(self, graph: Optional[CSRGraph] = None,
               d_feat: Optional[int] = None, *,
               force: bool = False) -> bool:
        """Drift entry point: the workload changed (graph grew, features
        resized).  Recomputes the WorkloadShape; if it drifted past the
        tuner's threshold the search re-opens (warm-started from the old
        best) and the engine rebuilds against the new graph.

        ``force=True`` re-opens the search even when the WorkloadShape is
        unchanged.  This is the *traffic*-drift path: a serving frontend
        (see repro.serve.gnn) observes request statistics the shape cannot
        see — hot-set rotations, burst load — and the measured latency
        surface under the new traffic is stale evidence either way, so the
        caller's drift signal overrides the shape comparison.
        """
        if graph is not None:
            self.graph = graph
        if d_feat is None:
            d_feat = self.shape.d_feat
        g = (self.graph.with_self_loops() if self.self_loops else self.graph)
        shape = WorkloadShape.from_graph(
            g, self.mesh.shape[self.axis_name], int(d_feat))
        reopened = self.tuner.observe_shape(shape)
        if force and not reopened:
            self.tuner.reopen()
            reopened = True
        if reopened:
            self.shape = shape
            self.committed = False
            self._window.reset()
            self.log(f"[runtime] workload drift → search re-opened "
                     f"(reopen #{self.tuner.reopens})")
            nxt = self.tuner.propose()
            if nxt is not None:
                self._set_config(nxt, force_rebuild=graph is not None)
        elif graph is not None:
            # same shape class, new topology: rebuild the plan in place
            self.engine = self._build_engine(self._config)
        return reopened

    # -- internals -----------------------------------------------------------

    def _commit(self) -> bool:
        best = self.tuner.best
        self.committed = True
        if best is None:  # nothing measurable (all configs vmem-rejected)
            return False
        if self.cache is not None:
            self.cache.put(self.shape, best, self.tuner.best_latency)
        self.log(f"[runtime] tuning converged after "
                 f"{self.tuner.measured} measurements: {best} "
                 f"({self.tuner.best_latency * 1e3:.2f} ms)")
        return self._set_config(best)

    def _set_config(self, cfg: Dict[str, int],
                    force_rebuild: bool = False) -> bool:
        if cfg == self._config and not force_rebuild:
            return False
        self._config = dict(cfg)
        self.engine = self._build_engine(self._config)
        self.history.append((self.step_count, dict(self._config)))
        self.log(f"[runtime] step {self.step_count}: config → {self._config}")
        return True
