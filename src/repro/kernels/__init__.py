"""Pallas TPU kernels for the compute hot-spots (validated in interpret
mode on CPU against the ref.py oracles):

* neighbor_agg — the paper's warp-level gather+reduce (scalar-prefetch
  pipelined + partition-blocked variants)
* slstm_scan — fused sLSTM recurrence with VMEM-resident weights (§Perf)
"""
from . import neighbor_agg, ops, ref, slstm_scan
from .ops import neighbor_gather_sum
