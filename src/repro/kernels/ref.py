"""Pure-jnp oracles for every Pallas kernel in this package."""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["neighbor_gather_sum_ref"]


def neighbor_gather_sum_ref(buf: jax.Array, nbrs: jax.Array, mask: jax.Array,
                            acc_dtype=jnp.float32) -> jax.Array:
    """``out[p] = Σ_j mask[p, j] · buf[nbrs[p, j]]`` → (P, D).

    The paper's warp-level gather + reduce over one neighbor partition
    (partial_results in Listing 2), as a dense jnp program.
    """
    g = jnp.take(buf, nbrs, axis=0)  # (P, ps, D)
    return jnp.sum(g.astype(acc_dtype) * mask[..., None].astype(acc_dtype),
                   axis=1)
