"""Pallas TPU row-gather kernel for tiered-feature chunk assembly.

`TieredFeatures` (store/tiered.py) assembles each ring chunk's device
buffer from two sources — the device-resident hot cache and a host-gathered
cold batch.  The seed implementation placed rows with two host-side
scatter (`.at[pos].set`) passes; this kernel inverts the formulation into
a *gather*: for every output row, the scalar-prefetched selector table
names the source row, and the grid streams the rows through the same
double-buffered DMA pipeline the neighbor-aggregation kernels use — the
Pallas analogue of the paper's zero-copy row fetch, and the same gather
the sampled mini-batch path will want (ROADMAP).

The kernel body is a copy; all the work is in the BlockSpec index map,
which is exactly what makes the DMA engine do the gather.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["gather_rows_call"]


def _gather_rows_kernel(idx_ref, src_blk, out_blk):
    del idx_ref  # consumed by the index maps
    out_blk[...] = src_blk[...]


def gather_rows_call(
    src: jax.Array,   # (T, D) source table (D multiple of db)
    idx: jax.Array,   # (B,)   int32 row ids into src
    *,
    db: int,
    interpret: bool = False,
) -> jax.Array:
    t, d = src.shape
    (b,) = idx.shape
    assert d % db == 0, (d, db)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b, d // db),
        in_specs=[
            # The gather: source block row chosen by the prefetched selector.
            pl.BlockSpec((1, db), lambda i, kk, idx: (idx[i], kk)),
        ],
        out_specs=pl.BlockSpec((1, db), lambda i, kk, idx: (i, kk)),
    )
    fn = pl.pallas_call(
        _gather_rows_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, d), src.dtype),
        interpret=interpret,
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("arbitrary", "arbitrary"),
        ),
    )
    return fn(idx, src)
