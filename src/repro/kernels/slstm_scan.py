"""Pallas TPU kernel: fused sLSTM sequence scan with VMEM-resident state.

Motivation (§Perf cell 3, xlstm-125m × train_4k): the XLA lowering of the
sLSTM recurrence is a 4096-iteration while loop whose every step re-reads
the recurrent weights ``wr (H, hd, 4·hd)`` (~2.4 MB) and round-trips the
four state tensors through HBM — the memory roofline term blows up by the
trip count.  ``wr`` + states fit comfortably in VMEM (~16 MB), so the MGG
philosophy (explicit memory staging, §3.4) says: fuse the whole scan into
one kernel, pin ``wr``/states in VMEM, and stream only ``x_proj`` in and
``h`` out.

Layout:
  grid = (B/bt, S/st) with the sequence dimension iterated sequentially
  (last grid dim) so the VMEM scratch states persist across sequence tiles
  (standard Pallas revisiting pattern).
  xp block   (bt, st, 4·D)  — streamed in (double-buffered by Pallas)
  out block  (bt, st, D)    — streamed out
  wr         (H, hd, 4·hd)  — full-array block, stays resident
  states     4 × (bt, H·hd) — VMEM scratch (fp32)

Validated against ``xlstm.slstm_apply`` in interpret mode
(tests/test_kernels_slstm.py); the HBM-traffic win is quantified in
EXPERIMENTS.md §Perf (modeled: this container cannot execute TPU VMEM).
"""
from __future__ import annotations

import functools
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["slstm_scan_call"]


def _kernel(xp_ref, wr_ref, h0_ref, c0_ref, n0_ref, m0_ref,
            out_ref, hN_ref, cN_ref, nN_ref, mN_ref,
            h_s, c_s, n_s, m_s, *, heads, hd, st):
    sj = pl.program_id(1)

    @pl.when(sj == 0)
    def _load_initial_state():
        h_s[...] = h0_ref[...].astype(jnp.float32)
        c_s[...] = c0_ref[...].astype(jnp.float32)
        n_s[...] = n0_ref[...].astype(jnp.float32)
        m_s[...] = m0_ref[...].astype(jnp.float32)

    wr = wr_ref[...].astype(jnp.float32)        # (H·hd, 4·H·hd) blockdiag-
    bt = out_ref.shape[0]                        # expanded outside

    def step(t, _):
        h = h_s[...]                             # (bt, H·hd)
        rec = jnp.dot(h, wr, preferred_element_type=jnp.float32)
        gates = xp_ref[:, t, :].astype(jnp.float32) + rec  # (bt, 4·H·hd)
        d = heads * hd
        z = jnp.tanh(gates[:, 0 * d : 1 * d])
        log_i = gates[:, 1 * d : 2 * d]
        log_f = -jnp.logaddexp(0.0, -gates[:, 2 * d : 3 * d])  # log σ(x)
        o = jax.nn.sigmoid(gates[:, 3 * d : 4 * d])
        m_new = jnp.maximum(log_f + m_s[...], log_i)
        i_p = jnp.exp(log_i - m_new)
        f_p = jnp.exp(log_f + m_s[...] - m_new)
        c = f_p * c_s[...] + i_p * z
        n = f_p * n_s[...] + i_p
        h = o * c / jnp.maximum(jnp.abs(n), 1.0)
        h_s[...] = h
        c_s[...] = c
        n_s[...] = n
        m_s[...] = m_new
        out_ref[:, t, :] = h.astype(out_ref.dtype)
        return 0

    lax.fori_loop(0, st, step, 0)
    hN_ref[...] = h_s[...]
    cN_ref[...] = c_s[...]
    nN_ref[...] = n_s[...]
    mN_ref[...] = m_s[...]


def slstm_scan_call(
    xp: jax.Array,      # (B, S, 4·D) precomputed Wx·x + b, gate-major
    wr: jax.Array,      # (D, 4·D) block-diagonal-expanded recurrent weights
    state: Dict[str, jax.Array],  # h/c/n/m: (B, D) fp32
    *,
    heads: int,
    hd: int,
    bt: int = 8,
    st: int = 256,
    interpret: bool = False,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    b, s, d4 = xp.shape
    d = heads * hd
    assert d4 == 4 * d
    bt = min(bt, b)
    st = min(st, s)
    if b % bt or s % st:
        bt, st = 1, s  # smoke shapes
    grid = (b // bt, s // st)
    kernel = functools.partial(_kernel, heads=heads, hd=hd, st=st)
    out, hN, cN, nN, mN = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bt, st, 4 * d), lambda i, j: (i, j, 0)),
            pl.BlockSpec((d, 4 * d), lambda i, j: (0, 0)),  # resident
            pl.BlockSpec((bt, d), lambda i, j: (i, 0)),
            pl.BlockSpec((bt, d), lambda i, j: (i, 0)),
            pl.BlockSpec((bt, d), lambda i, j: (i, 0)),
            pl.BlockSpec((bt, d), lambda i, j: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bt, st, d), lambda i, j: (i, j, 0)),
            pl.BlockSpec((bt, d), lambda i, j: (i, 0)),
            pl.BlockSpec((bt, d), lambda i, j: (i, 0)),
            pl.BlockSpec((bt, d), lambda i, j: (i, 0)),
            pl.BlockSpec((bt, d), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, s, d), xp.dtype),
            jax.ShapeDtypeStruct((b, d), jnp.float32),
            jax.ShapeDtypeStruct((b, d), jnp.float32),
            jax.ShapeDtypeStruct((b, d), jnp.float32),
            jax.ShapeDtypeStruct((b, d), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((bt, d), jnp.float32),
            pltpu.VMEM((bt, d), jnp.float32),
            pltpu.VMEM((bt, d), jnp.float32),
            pltpu.VMEM((bt, d), jnp.float32),
        ],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(xp, wr, state["h"], state["c"], state["n"], state["m"])
    return out, dict(h=hN, c=cN, n=nN, m=mN)


def expand_blockdiag(wr_heads: jax.Array) -> jax.Array:
    """(H, hd, 4·hd) per-head recurrent weights → (H·hd, 4·H·hd) gate-major
    block-diagonal matrix matching the kernel's fused dot.

    Gate-major means output columns are ordered [z | i | f | o] with each
    gate's block spanning all heads — the same layout the model's ``wx``
    projection produces.
    """
    h, hd, hd4 = wr_heads.shape
    assert hd4 == 4 * hd
    d = h * hd
    out = jnp.zeros((d, 4 * d), wr_heads.dtype)
    for g in range(4):
        blk = wr_heads[:, :, g * hd : (g + 1) * hd]  # (H, hd, hd)
        # scatter into block-diagonal positions of gate g
        for i in range(h):
            out = out.at[i * hd : (i + 1) * hd,
                         g * d + i * hd : g * d + (i + 1) * hd].set(blk[i])
    return out
