"""Pallas TPU flash attention (forward) with GQA + sliding-window support.

The transformer archs' memory-critical hot spot: standard XLA attention
materializes (S, S) score blocks in HBM; this kernel streams KV blocks
through VMEM with running-max/denominator carries (the same dataflow as
``layers._chunked_softmax_attention``, which is the jnp oracle), so HBM
traffic is O(S·d) per head.

Grid ``(B, H, Sq/bq, Skv/bk)``: the KV-block dimension is innermost and
sequential; the softmax statistics (m, l) and the output accumulator live
in VMEM scratch, persisting across KV steps (Pallas revisiting pattern —
the same trick the MGG aggregation kernel uses for its partial results).
GQA maps query head ``h`` to KV head ``h // (H // KV)`` inside the
BlockSpec index_map — no KV repetition in HBM.

Causal + sliding-window masking is computed from block-relative iotas.
Fully-masked KV blocks still stream (no early exit) — on real TPU one
would clamp the grid per q-block; noted as a further optimization.

Validated in interpret mode against the jnp oracle over shape sweeps
(tests/test_kernels_flash.py).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["flash_attention_call", "flash_attention"]

_NEG = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_s, l_s, acc_s,
            *, bq, bk, n_kv_blocks, causal, window, scale):
    i = pl.program_id(2)          # q block
    j = pl.program_id(3)          # kv block (sequential)

    @pl.when(j == 0)
    def _init():
        m_s[...] = jnp.full_like(m_s, _NEG)
        l_s[...] = jnp.zeros_like(l_s)
        acc_s[...] = jnp.zeros_like(acc_s)

    q = q_ref[0, 0].astype(jnp.float32) * scale        # (bq, hd)
    k = k_ref[0, 0].astype(jnp.float32)                # (bk, hd)
    v = v_ref[0, 0].astype(jnp.float32)
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)  # (bq, bk)

    q_pos = i * bq + lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = j * bk + lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    ok = jnp.ones((bq, bk), jnp.bool_)
    if causal:
        ok &= k_pos <= q_pos
    if window > 0:
        ok &= (q_pos - k_pos) < window
    s = jnp.where(ok, s, _NEG)

    m_prev = m_s[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_s[...] = l_s[...] * corr + p.sum(axis=1, keepdims=True)
    acc_s[...] = acc_s[...] * corr + jnp.dot(
        p, v, preferred_element_type=jnp.float32)
    m_s[...] = m_new

    @pl.when(j == n_kv_blocks - 1)
    def _finalize():
        o_ref[0, 0] = (acc_s[...] / jnp.maximum(l_s[...], 1e-30)
                       ).astype(o_ref.dtype)


def flash_attention_call(
    q: jax.Array,   # (B, H, Sq, hd)
    k: jax.Array,   # (B, KV, Skv, hd)
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    bq: int = 128,
    bk: int = 128,
    interpret: bool = False,
) -> jax.Array:
    b, h, sq, hd = q.shape
    _, kv, skv, _ = k.shape
    assert h % kv == 0, (h, kv)
    group = h // kv
    bq = min(bq, sq)
    bk = min(bk, skv)
    if sq % bq:
        bq = sq
    if skv % bk:
        bk = skv
    n_kv_blocks = skv // bk
    grid = (b, h, sq // bq, n_kv_blocks)
    kernel = functools.partial(
        _kernel, bq=bq, bk=bk, n_kv_blocks=n_kv_blocks,
        causal=causal, window=window, scale=hd ** -0.5)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda b_, h_, i, j: (b_, h_, i, 0)),
            pl.BlockSpec((1, 1, bk, hd),
                         lambda b_, h_, i, j, group=group:
                         (b_, h_ // group, j, 0)),
            pl.BlockSpec((1, 1, bk, hd),
                         lambda b_, h_, i, j, group=group:
                         (b_, h_ // group, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, hd),
                               lambda b_, h_, i, j: (b_, h_, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, hd), jnp.float32),
        ],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary"),
        ),
        interpret=interpret,
    )(q, k, v)


def flash_attention(
    q: jax.Array,   # (B, S, H, hd) — layers.py layout
    k: jax.Array,   # (B, S, KV, hd)
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Layout adapter around :func:`flash_attention_call`."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    out = flash_attention_call(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3), causal=causal, window=window,
        interpret=interpret)
    return out.transpose(0, 2, 1, 3)
