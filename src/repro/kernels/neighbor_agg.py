"""Pallas TPU kernels for the MGG neighbor gather+reduce hot spot.

This is the compute core of the paper's pipeline-centric kernel (§3.3–3.4):
for each neighbor partition ``p`` (≤ ``ps`` neighbors of one destination
node), fetch the neighbor embedding rows and reduce them into a partial
result — Listing 2's ``partial_results`` staged in SM shared memory.

Two TPU-native designs are provided:

1. :func:`gather_sum_pipelined_call` — **scalar-prefetch index-map gather**.
   The neighbor-id table is a scalar-prefetch operand; the input BlockSpec's
   ``index_map`` reads ``nbrs[p, j]`` to pick which embedding **row block**
   the next grid step consumes.  Pallas double-buffers input blocks, so the
   DMA for neighbor ``j+1`` overlaps the multiply-accumulate of neighbor
   ``j`` — the same async-GET double-buffering the paper builds by hand with
   NVSHMEM (Fig. 7b), here provided by the Pallas pipeline engine.  This is
   the primary kernel.

2. :func:`gather_sum_blocked_call` — **partition-blocked loop gather**: one
   grid cell owns ``pb`` partitions (the paper's warps-per-block knob) and
   loops over slots with dynamic row slices from a VMEM-resident column
   stripe of the embedding buffer.  Exposes the ``pb`` knob the autotuner
   searches (§4); preferable when the buffer tile is small enough to pin in
   VMEM.

Both compute ``out[p] = Σ_j mask[p, j] · buf[nbrs[p, j]]`` in fp32 and are
validated against ``ref.neighbor_gather_sum_ref`` in interpret mode (CPU)
across shape/dtype sweeps (tests/test_kernels.py).

A third, sparse design serves the top-k compressed pipeline
(core/pipeline.py `mgg_aggregate_sparse`): :func:`sparse_gather_sum_call`
streams each neighbor row's ``(values, col_idx)`` pair — k lanes instead of
D — through the same scalar-prefetch double buffer and expands it into the
output column block with a one-hot contraction, so the DMA volume scales
with k (the MaxK-GNN kernel/sparsity co-design).

VMEM accounting (the SMEM ≤ 164 KB analogue, checked by ops.py):
  pipelined: 2 · (1 · db) · 4  (double-buffered row blocks) + (1 · db) · 4
  blocked:   tile_rows · db · 4 (buffer stripe) + pb · db · 4 + ids in SMEM
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["gather_sum_pipelined_call", "gather_sum_blocked_call",
           "sparse_gather_sum_call"]


# ---------------------------------------------------------------------------
# Variant 1: scalar-prefetch index-map gather (primary)
# ---------------------------------------------------------------------------

def _pipelined_kernel(nbrs_ref, mask_ref, buf_blk, out_blk):
    """Grid (P, K, ps): accumulate one neighbor row block per step.

    ``out`` block index is constant across the innermost (slot) dimension, so
    the block stays resident in VMEM while ``ps`` neighbor rows stream
    through the double buffer.
    """
    p = pl.program_id(0)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _zero():
        out_blk[...] = jnp.zeros_like(out_blk)

    m = mask_ref[p, j].astype(out_blk.dtype)
    out_blk[...] += m * buf_blk[...].astype(out_blk.dtype)


def gather_sum_pipelined_call(
    buf: jax.Array,    # (T, D)  embedding rows (D multiple of db)
    nbrs: jax.Array,   # (P, ps) int32 row ids into buf
    mask: jax.Array,   # (P, ps) int32 validity (0/1)
    *,
    db: int,
    acc_dtype=jnp.float32,
    interpret: bool = False,
) -> jax.Array:
    t, d = buf.shape
    p, ps = nbrs.shape
    assert d % db == 0, (d, db)
    k = d // db

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(p, k, ps),
        in_specs=[
            # The gather: block row chosen by the prefetched neighbor table.
            pl.BlockSpec((1, db), lambda pi, ki, ji, nbrs, mask: (nbrs[pi, ji], ki)),
        ],
        out_specs=pl.BlockSpec((1, db), lambda pi, ki, ji, nbrs, mask: (pi, ki)),
    )
    fn = pl.pallas_call(
        _pipelined_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((p, d), acc_dtype),
        interpret=interpret,
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("arbitrary", "arbitrary", "arbitrary"),
        ),
    )
    return fn(nbrs, mask, buf)


# ---------------------------------------------------------------------------
# Variant 3: sparse (top-k compressed) scalar-prefetch gather
# ---------------------------------------------------------------------------

def _sparse_pipelined_kernel(nbrs_ref, mask_ref, val_blk, idx_blk, out_blk,
                             *, db):
    """Grid (P, KD, ps): scatter one neighbor's k live columns per step.

    Each step streams one neighbor row's *compressed* ``(values, col_idx)``
    pair — ``k`` lanes instead of ``D`` — through the double buffer, and
    expands the pairs landing in this ``db``-wide output column block with a
    compare-against-iota one-hot contraction (the decompress runs on the MXU,
    the DMA only ever moves the k live pairs: the MaxK-GNN co-design).
    """
    p = pl.program_id(0)
    ki = pl.program_id(1)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _zero():
        out_blk[...] = jnp.zeros_like(out_blk)

    m = mask_ref[p, j].astype(out_blk.dtype)
    lane = lax.broadcasted_iota(jnp.int32, (1, db), 1) + ki * db
    idx = idx_blk[...].astype(jnp.int32)               # (1, k)
    vals = val_blk[...].astype(out_blk.dtype)          # (1, k)
    onehot = (idx[0, :, None] == lane[0, None, :]).astype(out_blk.dtype)
    out_blk[...] += m * jnp.dot(vals, onehot)          # (1, k) @ (k, db)


def sparse_gather_sum_call(
    values: jax.Array,  # (T, k)  compressed rows (k lane-padded)
    idx: jax.Array,     # (T, k)  int32 column ids (pad slots carry value 0)
    nbrs: jax.Array,    # (P, ps) int32 row ids into values/idx
    mask: jax.Array,    # (P, ps) int32 validity (0/1)
    *,
    d: int,             # dense output width (multiple of db)
    db: int,
    acc_dtype=jnp.float32,
    interpret: bool = False,
) -> jax.Array:
    t, kc = values.shape
    p, ps = nbrs.shape
    assert d % db == 0, (d, db)
    kd = d // db

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(p, kd, ps),
        in_specs=[
            # Both halves of the compressed pair gather the same row block,
            # chosen by the prefetched neighbor table — one full compressed
            # row per step, reused across the kd output column blocks.
            pl.BlockSpec((1, kc), lambda pi, ki, ji, nbrs, mask: (nbrs[pi, ji], 0)),
            pl.BlockSpec((1, kc), lambda pi, ki, ji, nbrs, mask: (nbrs[pi, ji], 0)),
        ],
        out_specs=pl.BlockSpec((1, db), lambda pi, ki, ji, nbrs, mask: (pi, ki)),
    )
    fn = pl.pallas_call(
        functools.partial(_sparse_pipelined_kernel, db=db),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((p, d), acc_dtype),
        interpret=interpret,
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("arbitrary", "arbitrary", "arbitrary"),
        ),
    )
    return fn(nbrs, mask, values, idx)


# ---------------------------------------------------------------------------
# Variant 2: partition-blocked loop gather (exposes the pb knob)
# ---------------------------------------------------------------------------

def _blocked_kernel(nbrs_ref, mask_ref, buf_ref, out_ref, *, pb, ps):
    """Grid (P/pb, K): each cell reduces pb partitions against a VMEM stripe."""
    i = pl.program_id(0)

    def part_body(q, _):
        gp = i * pb + q  # global partition id (for the SMEM id table)

        def slot_body(j, acc):
            idx = nbrs_ref[gp, j]
            m = mask_ref[gp, j].astype(acc.dtype)
            row = buf_ref[pl.dslice(idx, 1), :].astype(acc.dtype)
            return acc + m * row

        acc = lax.fori_loop(
            0, ps, slot_body,
            jnp.zeros((1, out_ref.shape[1]), out_ref.dtype),
        )
        out_ref[pl.dslice(q, 1), :] = acc
        return 0

    lax.fori_loop(0, pb, part_body, 0)


def gather_sum_blocked_call(
    buf: jax.Array,    # (T, D)
    nbrs: jax.Array,   # (P, ps) int32 (P multiple of pb)
    mask: jax.Array,   # (P, ps) int32
    *,
    pb: int,
    db: int,
    acc_dtype=jnp.float32,
    interpret: bool = False,
) -> jax.Array:
    t, d = buf.shape
    p, ps = nbrs.shape
    assert p % pb == 0 and d % db == 0, (p, pb, d, db)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(p // pb, d // db),
        in_specs=[
            # Full row range of one column stripe pinned in VMEM.
            pl.BlockSpec((t, db), lambda i, k, nbrs, mask: (0, k)),
        ],
        out_specs=pl.BlockSpec((pb, db), lambda i, k, nbrs, mask: (i, k)),
    )
    fn = pl.pallas_call(
        functools.partial(_blocked_kernel, pb=pb, ps=ps),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((p, d), acc_dtype),
        interpret=interpret,
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel"),
        ),
    )
    return fn(nbrs, mask, buf)
