"""Public jit'd wrappers around the Pallas kernels.

Handles the messy edges so callers (core/pipeline.py) stay clean:
  * feature-dim padding to lane multiples (128) and block-size selection,
  * partition padding to ``pb`` multiples for the blocked variant,
  * VMEM-budget-driven variant selection (the §4 model's hardware constraint),
  * interpret-mode fallback on non-TPU backends (kernel body runs in Python
    on CPU — the validation mode mandated for this repo),
  * custom VJP: the backward of a masked gather-sum is a masked scatter-add,
    expressed with the same jnp oracle so training works on every backend.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from . import neighbor_agg, ref, rows

__all__ = ["neighbor_gather_sum", "sparse_neighbor_gather_sum",
           "gather_rows"]

_LANE = 128
_VMEM_BUDGET = 12 * 2**20  # leave headroom below the ~16 MB/core ceiling


def _pick_db(d_pad: int) -> int:
    """Largest lane-aligned column block ≤ 1024 dividing the padded dim."""
    db = _LANE
    while db * 2 <= min(d_pad, 1024) and d_pad % (db * 2) == 0:
        db *= 2
    return db


def _pad_cols(x: jax.Array, d_pad: int) -> jax.Array:
    d = x.shape[-1]
    if d == d_pad:
        return x
    return jnp.pad(x, ((0, 0), (0, d_pad - d)))


@functools.partial(
    jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7, 8)
)
def _gather_sum(buf, nbrs, maski, acc_dtype, pb, db, interpret,
                buf_rows, buf_dtype):
    t, d = buf.shape
    d_pad = -(-d // _LANE) * _LANE
    bufp = _pad_cols(buf, d_pad)
    if pb is None:
        out = neighbor_agg.gather_sum_pipelined_call(
            bufp, nbrs, maski, db=db, acc_dtype=acc_dtype, interpret=interpret
        )
    else:
        p = nbrs.shape[0]
        p_pad = -(-p // pb) * pb
        nb = jnp.pad(nbrs, ((0, p_pad - p), (0, 0)))
        mk = jnp.pad(maski, ((0, p_pad - p), (0, 0)))
        out = neighbor_agg.gather_sum_blocked_call(
            bufp, nb, mk, pb=pb, db=db, acc_dtype=acc_dtype,
            interpret=interpret,
        )[:p]
    return out[:, :d]


def _gather_sum_fwd(buf, nbrs, maski, acc_dtype, pb, db, interpret,
                    buf_rows, buf_dtype):
    out = _gather_sum(buf, nbrs, maski, acc_dtype, pb, db, interpret,
                      buf_rows, buf_dtype)
    return out, (nbrs, maski)


def _gather_sum_bwd(acc_dtype, pb, db, interpret, buf_rows, buf_dtype,
                    res, g):
    (nbrs, maski) = res
    # d buf = scatter-add of masked cotangents back to the gathered rows.
    gm = g.astype(acc_dtype)[:, None, :] * maski[..., None].astype(acc_dtype)
    dbuf = jnp.zeros((buf_rows, g.shape[-1]), acc_dtype).at[nbrs].add(gm)
    return (dbuf.astype(jnp.dtype(buf_dtype)), None, None)


_gather_sum.defvjp(_gather_sum_fwd, _gather_sum_bwd)


@functools.partial(
    jax.custom_vjp, nondiff_argnums=(4, 5, 6, 7, 8)
)
def _sparse_gather_sum(values, idx, nbrs, maski, acc_dtype, d, db,
                       interpret, val_dtype):
    t, k = values.shape
    d_pad = -(-d // _LANE) * _LANE
    k_pad = -(-k // _LANE) * _LANE
    # Column-pad the compressed pair: pad slots carry value 0 at column 0,
    # which contributes nothing to the one-hot accumulation.
    out = neighbor_agg.sparse_gather_sum_call(
        _pad_cols(values, k_pad), _pad_cols(idx, k_pad), nbrs, maski,
        d=d_pad, db=db, acc_dtype=acc_dtype, interpret=interpret,
    )
    return out[:, :d]


def _sparse_gather_sum_fwd(values, idx, nbrs, maski, acc_dtype, d, db,
                           interpret, val_dtype):
    out = _sparse_gather_sum(values, idx, nbrs, maski, acc_dtype, d, db,
                             interpret, val_dtype)
    return out, (idx, nbrs, maski)


def _sparse_gather_sum_bwd(acc_dtype, d, db, interpret, val_dtype, res, g):
    (idx, nbrs, maski) = res
    # d values = the dense scatter-add cotangent (as in _gather_sum_bwd)
    # re-gathered at each row's k live columns; the column ids are non-diff.
    gm = g.astype(acc_dtype)[:, None, :] * maski[..., None].astype(acc_dtype)
    dbuf = jnp.zeros((idx.shape[0], g.shape[-1]), acc_dtype).at[nbrs].add(gm)
    dval = jnp.take_along_axis(dbuf, idx.astype(jnp.int32), axis=1)
    return (dval.astype(jnp.dtype(val_dtype)), None, None, None)


_sparse_gather_sum.defvjp(_sparse_gather_sum_fwd, _sparse_gather_sum_bwd)


def sparse_neighbor_gather_sum(
    values: jax.Array,   # (T, k) compressed rows (topk_activation)
    idx: jax.Array,      # (T, k) column ids (any int dtype)
    nbrs: jax.Array,
    mask: jax.Array,
    *,
    d_feat: int,
    acc_dtype=jnp.float32,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """``out[p] = Σ_j mask[p, j] · decompress(values, idx)[nbrs[p, j]]``.

    Sparse counterpart of :func:`neighbor_gather_sum`: the kernel's DMA
    traffic is the k live ``(value, col)`` pairs per neighbor row, not the
    D-wide dense row.  There is no blocked (``pb``) variant — the
    compressed row is already narrow enough for the pipelined design.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    d_pad = -(-d_feat // _LANE) * _LANE
    db = _pick_db(d_pad)
    maski = mask.astype(jnp.int32)
    return _sparse_gather_sum(values, idx.astype(jnp.int32), nbrs, maski,
                              jnp.dtype(acc_dtype).name, d_feat, db,
                              interpret, jnp.dtype(values.dtype).name)


def gather_rows(src: jax.Array, idx: jax.Array, *,
                interpret: Optional[bool] = None) -> jax.Array:
    """``out[i] = src[idx[i]]`` via the Pallas row-gather kernel.

    The tiered-feature chunk assembly's hot spot (store/tiered.py): a pure
    row gather with no reduction, so the kernel is the scalar-prefetch
    pipeline with a copy body — every row lands via the double-buffered DMA
    engine instead of a host-side per-row scatter.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    t, d = src.shape
    d_pad = -(-d // _LANE) * _LANE
    db = _pick_db(d_pad)
    out = rows.gather_rows_call(_pad_cols(src, d_pad), idx.astype(jnp.int32),
                                db=db, interpret=interpret)
    return out[:, :d]


def neighbor_gather_sum(
    buf: jax.Array,
    nbrs: jax.Array,
    mask: jax.Array,
    *,
    acc_dtype=jnp.float32,
    pb: Optional[int] = None,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """``out[p] = Σ_j mask[p, j] · buf[nbrs[p, j]]`` via Pallas.

    ``pb=None`` selects the scalar-prefetch pipelined kernel; an integer
    selects the partition-blocked kernel with that warps-per-block analogue.
    The blocked variant is refused (falls back to pipelined) when its VMEM
    stripe would exceed the budget — the §4 hardware constraint.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    t, d = buf.shape
    d_pad = -(-d // _LANE) * _LANE
    db = _pick_db(d_pad)
    if pb is not None and (t * db + pb * db) * 4 > _VMEM_BUDGET:
        pb = None  # VMEM constraint: stripe does not fit — use pipelined
    maski = mask.astype(jnp.int32)
    return _gather_sum(buf, nbrs, maski, jnp.dtype(acc_dtype).name, pb, db,
                       interpret, t, jnp.dtype(buf.dtype).name)
