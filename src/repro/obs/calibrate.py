"""Measured-vs-model calibration for the §4 analytical latency model.

``estimate_latency``'s :class:`~repro.core.autotune.HardwareSpec`
constants (``TPU_V5E``, ``A100_NVSWITCH``) are hand-set; nothing checks
them against the machine actually running.  This module closes that
loop:

* **Micro-probes** (:func:`probe_hardware`) measure what a spec claims —
  matmul FLOP/s, host→device bandwidth, ring-link bandwidth — directly
  on the live backend, each probe best-effort (``None`` when the backend
  can't express it, e.g. link bandwidth on a single device).
* **Audit-trail fitting** (:func:`fit_spec`) takes the tuner's measured
  ``(config, latency)`` probes — the audit trail PR 7 already records —
  and fits per-parameter scale factors on a base spec by coordinate
  descent over a log-spaced grid, minimizing mean relative model error.
  The identity scale is always in the grid, so the calibrated error is
  never worse than the base spec's.
* **Model-error reporting** (:func:`model_errors`): per-config
  |model − measured| / measured, which the runtime engine feeds into the
  ``tuner.model_error`` histogram of its :class:`MetricsRegistry`.

The fit's objective is whatever latency the tuner measured (a full
forward / training step, not aggregation alone), so the fitted scales
absorb both hardware-constant error and the constant work the analytical
model does not express — exactly what a *ranking* model needs: after
calibration the model's ordering of configs provably matches this
machine's measurements better than the stock spec's
(``tests/test_calibrate.py``).

Unlike the rest of ``repro.obs`` this submodule depends on
``repro.core.autotune`` (it calibrates that model), so it is not
imported by the package ``__init__`` eagerly — ``import
repro.obs.calibrate`` explicitly, or via the package's lazy attribute.

CLI::

    PYTHONPATH=src python -m repro.obs.calibrate [--probe] [--devices N]
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence, Tuple, Union

from repro.core.autotune import (HardwareSpec, TPU_V5E, WorkloadShape,
                                 estimate_latency, estimate_pipeline_latency)

__all__ = [
    "CalibrationResult",
    "fit_spec",
    "model_latency",
    "model_errors",
    "observations_from_audit",
    "probe_hardware",
    "spec_from_probes",
    "FIT_PARAMS",
]

# the HardwareSpec fields the fit may scale (vmem_bytes is a feasibility
# constraint, not a latency term — never fitted)
FIT_PARAMS = ("peak_flops", "hbm_bw", "link_bw", "host_bw")

# log2-spaced scale grid: half-notch resolution over 256× in each
# direction, with the identity scale included so the fit can only improve
_DEFAULT_GRID = tuple(2.0 ** (0.5 * k) for k in range(-16, 17))

Config = Union[Dict, List[Dict]]
Shapes = Union[WorkloadShape, Sequence[WorkloadShape]]


def model_latency(shapes: Shapes, config: Config,
                  hw: HardwareSpec, interleave: bool = True) -> float:
    """The analytical estimate for one tuner proposal.

    ``config`` is whatever the tuner probed: a global ``{ps, dist, pb}``
    dict (optionally with ``fuse``), a ``{"layers": [...]}`` wrapper, or
    a bare per-layer list — per-layer forms need ``shapes`` to be the
    matching per-layer list (see
    :func:`repro.core.autotune.layer_workload_shapes`).
    """
    if isinstance(config, dict) and "layers" in config:
        config = config["layers"]
    if isinstance(config, list):
        shapes = list(shapes) if not isinstance(shapes, WorkloadShape) \
            else [shapes] * len(config)
        if len(shapes) != len(config):
            raise ValueError("one shape per layer config required")
        return estimate_pipeline_latency(shapes, config, hw=hw,
                                         interleave=interleave)
    shape = shapes[0] if not isinstance(shapes, WorkloadShape) else shapes
    return estimate_latency(shape, int(config["ps"]), int(config["dist"]),
                            int(config["pb"]), hw=hw, interleave=interleave,
                            fuse=bool(config.get("fuse", False)))


def observations_from_audit(audit: Sequence[dict]) \
        -> List[Tuple[Config, float]]:
    """Extract the fit's ``(config, measured latency)`` pairs from a
    tuner audit trail (``probe`` events with finite positive latency)."""
    out: List[Tuple[Config, float]] = []
    for ev in audit:
        if ev.get("event") != "probe":
            continue
        lat = ev.get("latency")
        cfg = ev.get("config") or ev.get("configs")
        if cfg is None or lat is None:
            continue
        lat = float(lat)
        if math.isfinite(lat) and lat > 0.0:
            out.append((cfg, lat))
    return out


def model_errors(shapes: Shapes, observations: Sequence[Tuple[Config, float]],
                 hw: HardwareSpec, interleave: bool = True) -> List[float]:
    """Per-observation relative model error |model − measured|/measured."""
    errs = []
    for cfg, measured in observations:
        model = model_latency(shapes, cfg, hw, interleave=interleave)
        errs.append(abs(model - measured) / measured)
    return errs


def _mean_error(shapes, observations, hw, interleave) -> float:
    errs = model_errors(shapes, observations, hw, interleave=interleave)
    return sum(errs) / len(errs) if errs else math.inf


@dataclasses.dataclass(frozen=True)
class CalibrationResult:
    """Outcome of :func:`fit_spec`."""

    spec: HardwareSpec            # the calibrated spec
    base: HardwareSpec            # what it was fitted from
    scales: Dict[str, float]      # per-parameter multipliers applied
    base_error: float             # mean relative error of `base`
    error: float                  # mean relative error of `spec` (≤ base)
    n_observations: int

    @property
    def improved(self) -> bool:
        return self.error < self.base_error

    def summary(self) -> str:
        sc = ", ".join(f"{k}×{v:.3g}" for k, v in self.scales.items()
                       if v != 1.0) or "identity"
        return (f"calibrated {self.base.name}: model error "
                f"{self.base_error:.1%} → {self.error:.1%} over "
                f"{self.n_observations} measured configs ({sc})")


def fit_spec(
    shapes: Shapes,
    observations: Sequence[Tuple[Config, float]],
    base: HardwareSpec = TPU_V5E,
    *,
    params: Sequence[str] = FIT_PARAMS,
    grid: Sequence[float] = _DEFAULT_GRID,
    rounds: int = 2,
    interleave: bool = True,
) -> Optional[CalibrationResult]:
    """Fit per-parameter scale factors on ``base`` to the measurements.

    Coordinate descent: for each parameter in turn, sweep the scale grid
    holding the others fixed, keep the best; repeat ``rounds`` times.
    Deterministic, derivative-free, and monotone — the identity scale is
    in the grid, so the result's error is ≤ the base spec's.  Returns
    ``None`` when there are no usable observations.
    """
    obs = [(c, l) for c, l in observations
           if math.isfinite(l) and l > 0.0]
    if not obs:
        return None

    def spec_for(scales: Dict[str, float]) -> HardwareSpec:
        return base.scaled(**scales)

    scales = {p: 1.0 for p in params}
    base_err = _mean_error(shapes, obs, base, interleave)
    best_err = base_err
    for _ in range(max(1, rounds)):
        moved = False
        for p in params:
            for s in grid:
                if s == scales[p]:
                    continue
                trial = dict(scales, **{p: s})
                err = _mean_error(shapes, obs, spec_for(trial), interleave)
                if err < best_err:
                    best_err, scales, moved = err, trial, True
        if not moved:
            break
    return CalibrationResult(spec=spec_for(scales), base=base, scales=scales,
                             base_error=base_err, error=best_err,
                             n_observations=len(obs))


# ---------------------------------------------------------------------------
# micro-probes: measure what a HardwareSpec claims, on the live backend
# ---------------------------------------------------------------------------

def _time_best(fn, warmup: int = 2, iters: int = 5) -> float:
    """Best-of-N wall time of a blocking callable (probes want the
    contention-free floor, not the median — bandwidth is a capacity)."""
    import time

    for _ in range(warmup):
        fn()
    best = math.inf
    for _ in range(iters):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def probe_matmul_flops(n: int = 512) -> float:
    """Measured dense-matmul FLOP/s on one device (fp32)."""
    import jax
    import jax.numpy as jnp

    a = jnp.ones((n, n), jnp.float32)
    f = jax.jit(lambda x: x @ x)
    t = _time_best(lambda: jax.block_until_ready(f(a)))
    return 2.0 * n ** 3 / max(t, 1e-12)


def probe_host_bw(nbytes: int = 32 << 20) -> float:
    """Measured host→device transfer bandwidth (bytes/s) — the tiered
    feature path's cold-row gather link."""
    import jax
    import numpy as np

    rows = max(1, nbytes // 1024)
    arr = np.zeros((rows, 256), np.float32)
    t = _time_best(
        lambda: jax.block_until_ready(jax.device_put(arr)), warmup=1)
    return arr.nbytes / max(t, 1e-12)


def probe_link_bw(mesh=None, axis_name: str = "ring",
                  rows: int = 2048, d: int = 256) -> Optional[float]:
    """Measured per-step ring (ppermute) bandwidth in bytes/s, or None
    when no multi-device mesh is available to probe."""
    import jax

    if mesh is None or axis_name not in getattr(mesh, "shape", {}) \
            or mesh.shape[axis_name] < 2:
        return None
    import jax.numpy as jnp
    from jax import lax
    from jax.sharding import PartitionSpec as P

    n = mesh.shape[axis_name]
    perm = [(i, (i + 1) % n) for i in range(n)]
    fn = jax.jit(jax.shard_map(
        lambda z: lax.ppermute(z, axis_name, perm),
        mesh=mesh, in_specs=P(axis_name), out_specs=P(axis_name),
        check_vma=False))
    x = jnp.ones((n * rows, d), jnp.float32)
    t = _time_best(lambda: jax.block_until_ready(fn(x)))
    return rows * d * 4 / max(t, 1e-12)  # per-device tile over the link


def probe_hardware(mesh=None, axis_name: str = "ring") -> Dict[str, Optional[float]]:
    """All micro-probes, each best-effort (None on failure)."""
    out: Dict[str, Optional[float]] = {}
    for key, probe in (("peak_flops", probe_matmul_flops),
                       ("host_bw", probe_host_bw)):
        try:
            out[key] = float(probe())
        except Exception:
            out[key] = None
    try:
        out["link_bw"] = probe_link_bw(mesh, axis_name)
    except Exception:
        out["link_bw"] = None
    return out


def spec_from_probes(base: HardwareSpec = TPU_V5E,
                     probes: Optional[Dict[str, Optional[float]]] = None,
                     mesh=None) -> HardwareSpec:
    """A copy of ``base`` with every successfully probed field measured."""
    if probes is None:
        probes = probe_hardware(mesh)
    changed = {k: v for k, v in probes.items()
               if v is not None and hasattr(base, k)}
    if not changed:
        return base
    return dataclasses.replace(base, name=base.name + "+probed", **changed)


def main(argv: Optional[List[str]] = None) -> int:
    import argparse
    import json as _json

    ap = argparse.ArgumentParser(
        description="micro-probe this machine and print a measured "
                    "HardwareSpec")
    ap.add_argument("--base", default="tpu_v5e",
                    choices=["tpu_v5e", "a100_nvswitch"])
    ap.add_argument("--json", action="store_true")
    args = ap.parse_args(argv)
    from repro.core.autotune import A100_NVSWITCH
    base = TPU_V5E if args.base == "tpu_v5e" else A100_NVSWITCH
    probes = probe_hardware()
    spec = spec_from_probes(base, probes)
    if args.json:
        print(_json.dumps({"probes": probes,
                           "spec": dataclasses.asdict(spec)}, indent=2))
    else:
        for k, v in probes.items():
            print(f"probe {k}: "
                  + (f"{v:.3e}" if v is not None else "unavailable"))
        print(f"spec: {spec}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
