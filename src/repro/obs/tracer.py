"""Span tracer with a Chrome-trace (Perfetto) exporter.

Design constraints (the acceptance criteria of the observability PR):

- **No-op when disabled.** The common case is a tracer that is off —
  every hot loop in the repo takes a ``tracer`` argument and must pay
  (almost) nothing when observability wasn't requested.  A disabled
  tracer's ``span()`` returns one preallocated context manager whose
  ``__enter__``/``__exit__`` do nothing; ``instant``/``complete``/
  ``counter`` return immediately on a single attribute check.
- **Injectable clock.** Everything times through ``self._clock`` (default
  ``time.perf_counter``) so tests drive spans deterministically — the
  same pattern as ``runtime.profiler``.
- **Bounded.** Events land in a ring buffer (``collections.deque`` with
  ``maxlen``); a week-long serve run cannot OOM the host through its
  own telemetry.
- **Thread-safe.** Serving replicas and background pumps record from
  wherever they run; one lock guards the buffer, and span begin/end
  pairs are folded into single complete events so interleaved threads
  can't corrupt nesting.

Events use the Chrome trace "X" (complete) and "i" (instant) phases;
``dump_chrome`` writes the ``{"traceEvents": [...]}`` wrapper that
ui.perfetto.dev and chrome://tracing both load.
"""
from __future__ import annotations

import json
import threading
import time
from collections import deque
from typing import Any, Callable, Optional


class _NullSpan:
    """Shared do-nothing context manager handed out by disabled tracers."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False

    def set(self, **args) -> None:
        pass


_NULL_SPAN = _NullSpan()


class _Span:
    """A live span: records begin time at __enter__, emits at __exit__."""

    __slots__ = ("_tracer", "name", "cat", "args", "_t0")

    def __init__(self, tracer: "Tracer", name: str, cat: str,
                 args: Optional[dict]):
        self._tracer = tracer
        self.name = name
        self.cat = cat
        self.args = args
        self._t0 = 0.0

    def __enter__(self):
        self._t0 = self._tracer._clock()
        return self

    def __exit__(self, *exc):
        self._tracer.complete(self.name, self._t0, self._tracer._clock(),
                              cat=self.cat, args=self.args)
        return False

    def set(self, **args) -> None:
        """Attach/override args on the span before it closes."""
        if self.args is None:
            self.args = {}
        self.args.update(args)


class Tracer:
    """Bounded, thread-safe span recorder with Perfetto export.

    ``Tracer(enabled=False)`` (or the module-level :data:`NULL_TRACER`)
    is safe to thread everywhere: every recording call bails on one
    ``enabled`` check and ``span()`` allocates nothing.
    """

    def __init__(self, enabled: bool = True, *,
                 clock: Callable[[], float] = time.perf_counter,
                 capacity: int = 200_000, pid: int = 0):
        self.enabled = bool(enabled)
        self._clock = clock
        self._events: deque = deque(maxlen=int(capacity))
        self._lock = threading.Lock()
        self._pid = int(pid)
        self._epoch = clock() if self.enabled else 0.0
        self.dropped = 0  # events pushed out of the ring buffer

    # -- recording ---------------------------------------------------------

    def span(self, name: str, cat: str = "", **args):
        """Context manager timing a region; no-op when disabled."""
        if not self.enabled:
            return _NULL_SPAN
        return _Span(self, name, cat, args or None)

    def complete(self, name: str, t0: float, t1: float, *, cat: str = "",
                 tid: int = 0, args: Optional[dict] = None) -> None:
        """Record a span retroactively from clock readings t0..t1.

        Used for lifecycle spans whose start was observed earlier (e.g.
        a request's admission time) without holding a span object open.
        """
        if not self.enabled:
            return
        ev = {"ph": "X", "name": name, "cat": cat or "span",
              "ts": (t0 - self._epoch) * 1e6,
              "dur": max(0.0, (t1 - t0) * 1e6),
              "pid": self._pid, "tid": int(tid)}
        if args:
            ev["args"] = args
        self._push(ev)

    def instant(self, name: str, *, cat: str = "", tid: int = 0,
                **args) -> None:
        """Record a point event (shown as a marker in Perfetto)."""
        if not self.enabled:
            return
        ev = {"ph": "i", "name": name, "cat": cat or "event",
              "ts": (self._clock() - self._epoch) * 1e6,
              "pid": self._pid, "tid": int(tid), "s": "t"}
        if args:
            ev["args"] = args
        self._push(ev)

    def counter(self, name: str, *, tid: int = 0, **values) -> None:
        """Record a counter sample (Perfetto renders a stacked track)."""
        if not self.enabled:
            return
        self._push({"ph": "C", "name": name, "cat": "counter",
                    "ts": (self._clock() - self._epoch) * 1e6,
                    "pid": self._pid, "tid": int(tid),
                    "args": {k: float(v) for k, v in values.items()}})

    def now(self) -> float:
        """Clock reading, for callers building retroactive spans."""
        return self._clock()

    def _push(self, ev: dict) -> None:
        with self._lock:
            if len(self._events) == self._events.maxlen:
                self.dropped += 1
            self._events.append(ev)

    # -- export ------------------------------------------------------------

    def events(self) -> list:
        with self._lock:
            return list(self._events)

    def __len__(self) -> int:
        return len(self._events)

    def clear(self) -> None:
        with self._lock:
            self._events.clear()
            self.dropped = 0

    def to_chrome(self) -> dict:
        """The ``{"traceEvents": [...]}`` object Perfetto loads."""
        return {"traceEvents": self.events(),
                "displayTimeUnit": "ms",
                "otherData": {"dropped_events": self.dropped}}

    def dump_chrome(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_chrome(), f)

    def dump_jsonl(self, path: str) -> None:
        with open(path, "w") as f:
            for ev in self.events():
                f.write(json.dumps(ev) + "\n")


NULL_TRACER = Tracer(enabled=False, capacity=1)


def resolve(tracer: Optional[Tracer]) -> Tracer:
    """Normalize an optional tracer argument to a Tracer instance."""
    return tracer if tracer is not None else NULL_TRACER


# ---------------------------------------------------------------------------
# multi-replica trace merging
# ---------------------------------------------------------------------------

def _load_events(path: str) -> list:
    """Events from either export format (JSONL or Chrome ``traceEvents``).

    Both start with ``{``, so sniffing the first byte can't tell them
    apart: parse as one JSON document first, fall back to line-per-event.
    """
    with open(path) as f:
        text = f.read()
    try:
        doc = json.loads(text)
    except ValueError:
        return [json.loads(line) for line in text.splitlines()
                if line.strip()]
    if isinstance(doc, dict):
        if "traceEvents" in doc:
            return list(doc["traceEvents"])
        return [doc]        # single-line JSONL: one bare event
    return list(doc)        # bare event array


def merge_traces(paths, labels=None, out: Optional[str] = None) -> dict:
    """Fold N per-replica trace dumps into one Perfetto timeline.

    Each input (JSONL from :meth:`Tracer.dump_jsonl` or Chrome JSON from
    :meth:`Tracer.dump_chrome`) becomes its own process row: every event
    is reassigned ``pid=i`` (the input's position), and a Chrome ``M``
    (``process_name``) metadata event names the row — by ``labels[i]``
    when given, else ``replica<i>``.  Timestamps are left alone: each
    tracer's clock already starts at its own epoch, so replica timelines
    align at zero, which is what you want for comparing per-replica
    phase timing side by side.

    Returns the merged ``{"traceEvents": [...]}`` object; also writes it
    to ``out`` when given.  Used by ``launch/serve_gnn.py`` for
    ``--replicas N --trace``.
    """
    merged = []
    for i, path in enumerate(paths):
        label = labels[i] if labels and i < len(labels) else f"replica{i}"
        merged.append({"ph": "M", "name": "process_name", "pid": i,
                       "tid": 0, "args": {"name": label}})
        for ev in _load_events(path):
            ev = dict(ev)
            ev["pid"] = i
            merged.append(ev)
    doc = {"traceEvents": merged, "displayTimeUnit": "ms",
           "otherData": {"merged_from": len(list(paths))}}
    if out:
        with open(out, "w") as f:
            json.dump(doc, f)
    return doc
