"""repro.obs — low-overhead tracing + metrics for the MGG stack.

Leaf package: imports nothing from the rest of ``repro`` so core /
runtime / serve / store can all depend on it without cycles.

Two primitives:

- :class:`Tracer` (tracer.py): nestable wall-clock spans with an
  injectable monotonic clock, bounded ring buffer, thread safety, and a
  strict no-op fast path when disabled.  Exports Chrome-trace JSON
  (opens directly in ui.perfetto.dev) and JSONL.
- :class:`MetricsRegistry` (metrics.py): labeled counters / gauges /
  histograms with percentile summaries and a JSON snapshot.

One exception to leaf-ness, deliberately quarantined: ``calibrate.py``
fits the §4 analytical latency model to measured latencies and so must
import ``repro.core.autotune``.  It is never imported here eagerly —
``import repro.obs.calibrate`` explicitly (or touch the lazy
``repro.obs.calibrate`` attribute) — so ``from repro.obs import Tracer``
stays dependency-free.

See docs/observability.md for the span taxonomy and metric names.
"""
from repro.obs.tracer import NULL_TRACER, Tracer, merge_traces
from repro.obs.metrics import MetricsRegistry

__all__ = ["Tracer", "NULL_TRACER", "MetricsRegistry", "merge_traces",
           "calibrate"]


def __getattr__(name):
    if name == "calibrate":  # lazy: pulls in repro.core.autotune
        import repro.obs.calibrate as _cal
        return _cal
    raise AttributeError(f"module 'repro.obs' has no attribute {name!r}")
