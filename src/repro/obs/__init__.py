"""repro.obs — low-overhead tracing + metrics for the MGG stack.

Leaf package: imports nothing from the rest of ``repro`` so core /
runtime / serve / store can all depend on it without cycles.

Two primitives:

- :class:`Tracer` (tracer.py): nestable wall-clock spans with an
  injectable monotonic clock, bounded ring buffer, thread safety, and a
  strict no-op fast path when disabled.  Exports Chrome-trace JSON
  (opens directly in ui.perfetto.dev) and JSONL.
- :class:`MetricsRegistry` (metrics.py): labeled counters / gauges /
  histograms with percentile summaries and a JSON snapshot.

See docs/observability.md for the span taxonomy and metric names.
"""
from repro.obs.tracer import NULL_TRACER, Tracer
from repro.obs.metrics import MetricsRegistry

__all__ = ["Tracer", "NULL_TRACER", "MetricsRegistry"]
