"""Validate an emitted Chrome-trace file (CI smoke gate).

    PYTHONPATH=src python -m repro.obs.validate trace.json

Asserts the structural properties the observability PR promises:

1. the file parses as Chrome-trace JSON (``traceEvents`` list);
2. it contains at least one ring-step pipeline span
   (``mgg.stream.*``) and the stream-level span reports a nonzero
   ``overlap_efficiency``;
3. it contains at least one tuner audit event (``tuner.*`` instant).

Exit code 0 on success; 1 with a reason on stderr otherwise.
"""
from __future__ import annotations

import json
import sys


def validate(path: str) -> list:
    """Return a list of problems (empty = valid)."""
    problems = []
    try:
        with open(path) as f:
            doc = json.load(f)
    except (OSError, ValueError) as e:
        return [f"not parseable as JSON: {e}"]
    events = doc.get("traceEvents")
    if not isinstance(events, list) or not events:
        return ["no traceEvents list"]
    for ev in events:
        if not isinstance(ev, dict) or "ph" not in ev or "name" not in ev:
            problems.append(f"malformed event: {ev!r}")
            return problems

    ring_steps = [e for e in events
                  if e["name"].startswith("mgg.stream.")
                  and e["ph"] == "X"]
    if not ring_steps:
        problems.append("no ring-step spans (mgg.stream.*)")
    overlaps = [e["args"]["overlap_efficiency"] for e in events
                if e.get("args") and "overlap_efficiency" in e["args"]]
    if not overlaps:
        problems.append("no span reports overlap_efficiency")
    elif max(overlaps) <= 0.0:
        problems.append(f"overlap_efficiency never positive: {overlaps}")

    tuner_events = [e for e in events if e["name"].startswith("tuner.")]
    if not tuner_events:
        problems.append("no tuner audit events (tuner.*)")
    return problems


def main(argv=None) -> int:
    argv = sys.argv[1:] if argv is None else argv
    if len(argv) != 1:
        print("usage: python -m repro.obs.validate TRACE.json",
              file=sys.stderr)
        return 2
    problems = validate(argv[0])
    if problems:
        for p in problems:
            print(f"[obs.validate] FAIL: {p}", file=sys.stderr)
        return 1
    with open(argv[0]) as f:
        n = len(json.load(f)["traceEvents"])
    print(f"[obs.validate] OK: {argv[0]} ({n} events, ring-step spans "
          f"with overlap_efficiency and tuner audit events present)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
