"""Counter / gauge / histogram registry with labeled series.

One :class:`MetricsRegistry` per engine (or shared across a cluster's
replicas with distinguishing labels).  Series are keyed by
``(name, sorted(labels))`` so ``reg.counter("serve.requests",
replica=0)`` and ``replica=1`` are independent; ``snapshot()`` folds
everything into a plain JSON-ready dict.

Counters/gauges are exact.  Histograms keep exact count/sum/min/max and
a bounded reservoir for percentile summaries — a serve run recording
millions of latencies stays O(reservoir) in memory.  All mutation is
lock-guarded; reads take the same lock and copy.
"""
from __future__ import annotations

import json
import threading
from typing import Dict, Optional, Tuple


def _series_key(name: str, labels: dict) -> Tuple[str, Tuple]:
    return name, tuple(sorted(labels.items()))


class Counter:
    __slots__ = ("_lock", "value")

    def __init__(self, lock: threading.Lock):
        self._lock = lock
        self.value = 0

    def inc(self, n: int = 1) -> None:
        with self._lock:
            self.value += n

    def set(self, n) -> None:
        """Absolute set — for adapters mirroring an externally-kept total."""
        with self._lock:
            self.value = n


class Gauge:
    __slots__ = ("_lock", "value")

    def __init__(self, lock: threading.Lock):
        self._lock = lock
        self.value = 0.0

    def set(self, v: float) -> None:
        with self._lock:
            self.value = float(v)

    def inc(self, d: float = 1.0) -> None:
        with self._lock:
            self.value += d

    def dec(self, d: float = 1.0) -> None:
        with self._lock:
            self.value -= d


class Histogram:
    """Exact count/sum/min/max + bounded reservoir for percentiles.

    The reservoir keeps the first ``reservoir`` observations then
    overwrites cyclically — recent-biased, deterministic (no RNG so
    replays are reproducible), and bounded.
    """

    __slots__ = ("_lock", "count", "sum", "min", "max", "_buf", "_cap",
                 "_i")

    def __init__(self, lock: threading.Lock, reservoir: int = 1024):
        self._lock = lock
        self.count = 0
        self.sum = 0.0
        self.min = float("inf")
        self.max = float("-inf")
        self._buf: list = []
        self._cap = int(reservoir)
        self._i = 0

    def observe(self, v: float) -> None:
        v = float(v)
        with self._lock:
            self.count += 1
            self.sum += v
            if v < self.min:
                self.min = v
            if v > self.max:
                self.max = v
            if len(self._buf) < self._cap:
                self._buf.append(v)
            else:
                self._buf[self._i] = v
                self._i = (self._i + 1) % self._cap

    def percentile(self, q: float) -> float:
        with self._lock:
            buf = sorted(self._buf)
        if not buf:
            return 0.0
        idx = min(len(buf) - 1, max(0, int(round(q / 100.0 * (len(buf) - 1)))))
        return buf[idx]

    def summary(self) -> dict:
        with self._lock:
            buf = sorted(self._buf)
            out = {"count": self.count, "sum": self.sum,
                   "min": self.min if self.count else 0.0,
                   "max": self.max if self.count else 0.0,
                   "mean": (self.sum / self.count) if self.count else 0.0}
        for q in (50, 90, 99):
            if buf:
                idx = min(len(buf) - 1,
                          max(0, int(round(q / 100.0 * (len(buf) - 1)))))
                out[f"p{q}"] = buf[idx]
            else:
                out[f"p{q}"] = 0.0
        return out


class MetricsRegistry:
    """Labeled counter/gauge/histogram factory with a JSON snapshot."""

    def __init__(self):
        self._lock = threading.Lock()
        self._counters: Dict[Tuple, Counter] = {}
        self._gauges: Dict[Tuple, Gauge] = {}
        self._hists: Dict[Tuple, Histogram] = {}

    def counter(self, name: str, **labels) -> Counter:
        key = _series_key(name, labels)
        with self._lock:
            c = self._counters.get(key)
            if c is None:
                c = self._counters[key] = Counter(self._lock)
        return c

    def gauge(self, name: str, **labels) -> Gauge:
        key = _series_key(name, labels)
        with self._lock:
            g = self._gauges.get(key)
            if g is None:
                g = self._gauges[key] = Gauge(self._lock)
        return g

    def histogram(self, name: str, reservoir: int = 1024,
                  **labels) -> Histogram:
        key = _series_key(name, labels)
        with self._lock:
            h = self._hists.get(key)
            if h is None:
                h = self._hists[key] = Histogram(self._lock, reservoir)
        return h

    # -- aggregation -------------------------------------------------------

    def counter_total(self, name: str) -> int:
        """Sum a counter across every label combination (cluster rollup)."""
        with self._lock:
            return sum(c.value for (n, _), c in self._counters.items()
                       if n == name)

    def snapshot(self) -> dict:
        """JSON-ready view: {counters: {...}, gauges: {...}, histograms}."""
        def fmt(key):
            name, labels = key
            if not labels:
                return name
            return name + "{" + ",".join(f"{k}={v}" for k, v in labels) + "}"

        with self._lock:
            counters = {fmt(k): c.value for k, c in self._counters.items()}
            gauges = {fmt(k): g.value for k, g in self._gauges.items()}
            hists = list(self._hists.items())
        return {"counters": counters, "gauges": gauges,
                "histograms": {fmt(k): h.summary() for k, h in hists}}

    def dump_json(self, path: str, extra: Optional[dict] = None) -> None:
        snap = self.snapshot()
        if extra:
            snap.update(extra)
        with open(path, "w") as f:
            json.dump(snap, f, indent=2, default=str)
