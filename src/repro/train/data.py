"""Deterministic, shardable synthetic data pipelines.

The brief requires the data substrate to be real: batches are a pure
function of ``(seed, step, arch)``, so every DP shard regenerates its slice
after a restart or an elastic re-mesh with no data-order drift — the same
property a production loader gets from a checkpointed dataset iterator.

LM batches follow a Zipfian unigram draw with short-range Markov structure
(so losses move during the e2e examples), plus packed-document loss masks.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["LMDataConfig", "lm_batch", "lm_stream", "graph_features"]


@dataclasses.dataclass(frozen=True)
class LMDataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    doc_len: int = 1024          # documents packed per row
    markov: float = 0.7          # P(next token near current)


def lm_batch(cfg: LMDataConfig, step: int,
             n_vis: int = 0, d_model: int = 0) -> Dict[str, np.ndarray]:
    """Batch for ``step`` (whole global batch; shard by slicing dim 0)."""
    rng = np.random.default_rng(
        np.random.SeedSequence([cfg.seed, step, 0xD1CE]))
    b, s = cfg.global_batch, cfg.seq_len
    base = rng.zipf(1.3, size=(b, s)).astype(np.int64) % cfg.vocab
    # short-range structure: with prob markov, copy-shift the previous token
    keep = rng.random((b, s)) < cfg.markov
    shifted = np.roll(base, 1, axis=1)
    tokens = np.where(keep, (shifted + 1) % cfg.vocab, base)
    # packed documents: mask loss across document boundaries
    boundaries = (np.arange(s)[None, :] % cfg.doc_len) == 0
    loss_mask = np.broadcast_to(~boundaries, (b, s)).astype(np.float32).copy()
    out = dict(tokens=tokens.astype(np.int32), loss_mask=loss_mask)
    if n_vis:
        out["vis"] = rng.normal(size=(b, n_vis, d_model)).astype(np.float32)
    return out


def lm_stream(cfg: LMDataConfig, start_step: int = 0, **kw
              ) -> Iterator[Dict[str, np.ndarray]]:
    step = start_step
    while True:
        yield lm_batch(cfg, step, **kw)
        step += 1


def graph_features(num_nodes: int, dim: int, num_classes: int,
                   seed: int = 0):
    """Node features + labels with class-dependent means (learnable)."""
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, num_classes, num_nodes)
    centers = rng.normal(size=(num_classes, dim)).astype(np.float32)
    x = centers[labels] + 0.5 * rng.normal(size=(num_nodes, dim)).astype(
        np.float32)
    train_mask = rng.random(num_nodes) < 0.6
    return x, labels.astype(np.int32), train_mask
