"""Training substrate: optimizer, step factory, fault-tolerant driver,
checkpointing, data pipelines."""
from .optimizer import AdamWConfig, adamw_init, adamw_update, global_norm
from .trainer import Trainer, TrainState, make_train_step, make_loss_fn
from .data import LMDataConfig, lm_batch, lm_stream, graph_features
from . import checkpoint
