"""Atomic, shard-agnostic checkpointing (fault-tolerance substrate).

Design (orbax-free, single-controller):

* every pytree leaf is saved as one ``.npy`` file named by its tree path;
  a ``manifest.json`` records the treedef, shapes, dtypes, and step;
* writes go to ``<dir>/tmp-<step>`` and are atomically ``rename``d to
  ``<dir>/step-<step>`` after fsync — a crash mid-write never corrupts the
  latest checkpoint;
* ``restore`` takes the *abstract* target tree + shardings and
  ``device_put``s each leaf with the **current** mesh's sharding — the
  checkpoint stores plain host arrays, so restarts may change the mesh
  shape or chip count (elastic scaling / DESIGN.md §5.5);
* optional async save on a background thread (double-buffered host copy);
* retention: keep the newest ``keep`` checkpoints.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

__all__ = ["save", "restore", "latest_step", "CheckpointManager"]


def _flat_with_names(tree: Any) -> List[Tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        name = "/".join(
            str(getattr(k, "key", getattr(k, "idx", getattr(k, "name", k))))
            for k in path
        )
        out.append((name, leaf))
    return out


def save(ckpt_dir: str, step: int, tree: Any, *, keep: int = 3,
         extra: Optional[Dict] = None) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    tmp = os.path.join(ckpt_dir, f"tmp-{step}")
    final = os.path.join(ckpt_dir, f"step-{step:09d}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    manifest = dict(step=step, leaves=[], extra=extra or {})
    for name, leaf in _flat_with_names(tree):
        arr = np.asarray(jax.device_get(leaf))
        logical = str(arr.dtype)
        if arr.dtype.kind == "V":  # ml_dtypes (bf16, fp8): store raw bits
            logical = str(jax.numpy.dtype(leaf.dtype))
            arr = arr.view(np.uint8 if arr.dtype.itemsize == 1 else np.uint16)
        fname = name.replace("/", "__") + ".npy"
        np.save(os.path.join(tmp, fname), arr)
        manifest["leaves"].append(
            dict(name=name, file=fname, shape=list(arr.shape),
                 dtype=logical))
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)  # atomic commit
    _retain(ckpt_dir, keep)
    return final


def _retain(ckpt_dir: str, keep: int) -> None:
    steps = sorted(
        d for d in os.listdir(ckpt_dir) if d.startswith("step-")
    )
    for d in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, d), ignore_errors=True)


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = sorted(
        int(d.split("-")[1]) for d in os.listdir(ckpt_dir)
        if d.startswith("step-")
    )
    return steps[-1] if steps else None


def restore(ckpt_dir: str, step: int, target: Any,
            shardings: Optional[Any] = None) -> Any:
    """Load into the structure of ``target`` (abstract or concrete pytree).

    ``shardings``: optional matching pytree of NamedShardings built from the
    *current* mesh — leaves are placed directly into their (possibly new)
    layout, which is what makes restarts elastic.
    """
    d = os.path.join(ckpt_dir, f"step-{step:09d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    by_name = {l["name"]: l for l in manifest["leaves"]}
    names = [n for n, _ in _flat_with_names(target)]
    shard_leaves = (jax.tree_util.tree_leaves(
        shardings, is_leaf=lambda x: hasattr(x, "spec"))
        if shardings is not None else [None] * len(names))
    leaves = []
    for name, sh in zip(names, shard_leaves):
        entry = by_name[name]
        arr = np.load(os.path.join(d, entry["file"]))
        want = jax.numpy.dtype(entry["dtype"])
        if arr.dtype != want and arr.dtype.kind == "u":
            arr = arr.view(want)  # bf16/fp8 stored as raw bits
        leaves.append(jax.device_put(arr, sh) if sh is not None
                      else jax.numpy.asarray(arr))
    treedef = jax.tree_util.tree_structure(target)
    return jax.tree_util.tree_unflatten(treedef, leaves)


class CheckpointManager:
    """Periodic (optionally async) checkpointing with restart support."""

    def __init__(self, ckpt_dir: str, *, every: int = 100, keep: int = 3,
                 async_save: bool = True):
        self.dir = ckpt_dir
        self.every = every
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None

    def maybe_save(self, step: int, tree: Any, extra=None) -> bool:
        if step % self.every:
            return False
        self.wait()
        # host copy now (cheap, double buffer) — device free to continue
        host = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)
        if self.async_save:
            self._thread = threading.Thread(
                target=save, args=(self.dir, step, host),
                kwargs=dict(keep=self.keep, extra=extra), daemon=True)
            self._thread.start()
        else:
            save(self.dir, step, host, keep=self.keep, extra=extra)
        return True

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def restore_latest(self, target: Any, shardings=None):
        step = latest_step(self.dir)
        if step is None:
            return None, None
        return step, restore(self.dir, step, target, shardings)
