"""Training step factory + fault-tolerant driver loop.

``make_train_step`` builds the jitted step for any assigned architecture:
value_and_grad over the family's loss, optional microbatch gradient
accumulation (lax.scan), AdamW, and (for pure-DP meshes, ``ef_bits > 0``)
the int8 error-feedback gradient all-reduce from dist/compress.py.

``Trainer`` is the production driver: checkpoint/restart (atomic, async),
straggler detection (wall-time watchdog vs. a running median — on a real
multi-host deployment the same hook aborts and re-queues the step),
bounded retry on transient failures, elastic restore (the checkpoint is
mesh-agnostic; restarting on a different mesh re-shards on load), and the
``--dynamic-tune`` hook: ``tune_cb(dt, step)`` receives every measured
step time and may return a *replacement step function* — the
repro.runtime online tuner uses this to swap in a re-optimized
aggregation pipeline mid-training.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Iterator, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import encdec, transformer
from repro.obs import NULL_TRACER
from repro.train import checkpoint as ckpt_lib
from repro.train.optimizer import AdamWConfig, adamw_init, adamw_update

__all__ = ["make_loss_fn", "make_train_step", "Trainer", "TrainState"]


def make_loss_fn(cfg, ctx: transformer.DistCtx) -> Callable:
    if cfg.family == "encdec":
        return lambda p, batch: encdec.loss_fn(p, cfg, batch, ctx=ctx)
    return lambda p, batch: transformer.loss_fn(p, cfg, batch, ctx=ctx)


def make_train_step(
    cfg,
    ctx: transformer.DistCtx,
    opt_cfg: AdamWConfig,
    *,
    accum_steps: int = 1,
    ef_bits: int = 0,
) -> Callable:
    """Returns ``step(params, opt_state, batch) -> (params, opt, metrics)``.

    With ``accum_steps > 1`` the batch's leading dim is split into
    microbatches accumulated with a lax.scan — the standard way to hold
    the global batch when per-chip memory is tight.

    With ``ef_bits > 0`` the gradients pass through the error-feedback
    compressed allreduce (``dist.compress.ef_allreduce_mean``) before the
    optimizer: the int-``ef_bits`` wire format cuts the gradient payload
    ``32 / ef_bits``× and the quantization residual carries into the next
    step.  This path requires a mesh whose model axis is trivial (pure
    data parallelism — the paper-scale setting where the gradient reduce
    competes with the aggregation ring for the interconnect) and changes
    the state convention: ``opt_state`` becomes the pair
    ``(adamw_state, ef_err)`` with ``ef_err = ef_state_init(params)``.
    """
    loss_fn = make_loss_fn(cfg, ctx)
    ef_on = int(ef_bits) > 0
    if ef_on:
        if ctx.mesh is None:
            raise ValueError("ef_bits > 0 needs a mesh (ctx.mesh is None)")
        if int(ctx.mesh.shape.get(ctx.model_axis, 1)) > 1:
            raise ValueError(
                "ef_bits > 0 is a pure-DP path; model axis "
                f"{ctx.model_axis!r} has size "
                f"{ctx.mesh.shape[ctx.model_axis]} > 1")
        from jax.sharding import PartitionSpec as _P

        from repro.dist.compress import ef_allreduce_mean

    def grads_of(params, batch):
        (loss, aux), grads = jax.value_and_grad(
            lambda p: loss_fn(p, batch), has_aux=True)(params)
        return loss, aux, grads

    def step(params, opt_state, batch):
        if ef_on:
            opt_state, ef_err = opt_state
        if accum_steps == 1:
            loss, aux, grads = grads_of(params, batch)
        else:
            def split(x):
                b = x.shape[0]
                return x.reshape((accum_steps, b // accum_steps) + x.shape[1:])
            mb = jax.tree.map(split, batch)

            def body(carry, mbatch):
                gsum, lsum = carry
                loss, _, g = grads_of(params, mbatch)
                gsum = jax.tree.map(jnp.add, gsum, g)
                return (gsum, lsum + loss), None

            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (gsum, lsum), _ = jax.lax.scan(body, (g0, 0.0), mb)
            grads = jax.tree.map(lambda g: g / accum_steps, gsum)
            loss = lsum / accum_steps
            aux = dict(loss=loss)
        if ef_on:
            # int-bits wire format + error feedback; the pmean over the
            # data axes is the (compressed) gradient allreduce of the
            # paper-scale DP setting.
            specs = jax.tree.map(lambda _: _P(), grads)
            grads, ef_err = ef_allreduce_mean(
                grads, ef_err, ctx.mesh, ctx.data_axes, specs, bits=ef_bits)
        params, opt_state, om = adamw_update(grads, opt_state, params, opt_cfg)
        metrics = dict(loss=loss, **om)
        if ef_on:
            opt_state = (opt_state, ef_err)
        return params, opt_state, metrics

    return step


@dataclasses.dataclass
class TrainState:
    params: Any
    opt_state: Any
    step: int = 0


class Trainer:
    """Fault-tolerant driver: run → watchdog → checkpoint → restart."""

    def __init__(
        self,
        step_fn: Callable,
        data_it: Iterator[Dict[str, np.ndarray]],
        state: TrainState,
        *,
        workdir: Optional[str] = None,
        ckpt_every: int = 50,
        straggler_factor: float = 4.0,
        max_retries: int = 2,
        shardings: Optional[Any] = None,
        log_every: int = 10,
        log_fn: Callable[[str], None] = print,
        tune_cb: Optional[Callable[[float, int], Optional[Callable]]] = None,
        tracer=None,
        metrics=None,
    ):
        self.step_fn = step_fn
        self.data_it = data_it
        self.state = state
        self.workdir = workdir
        self.mgr = (ckpt_lib.CheckpointManager(workdir, every=ckpt_every)
                    if workdir else None)
        self.straggler_factor = straggler_factor
        self.max_retries = max_retries
        self.shardings = shardings
        self.log_every = log_every
        self.log = log_fn
        self.tune_cb = tune_cb
        # observability: span per step + step-time histogram.  The step
        # timing (t0 / block_until_ready / dt) exists regardless, so
        # tracing adds no synchronization — losses are bitwise-identical
        # either way (asserted in tests/test_obs.py).
        self.tracer = tracer if tracer is not None else NULL_TRACER
        self.metrics = metrics
        self.step_times: list = []
        self.stragglers = 0
        self.restarts = 0
        self.retunes = 0

    def maybe_restore(self) -> bool:
        if self.mgr is None:
            return False
        target = dict(params=self.state.params,
                      opt_state=self.state.opt_state)
        out = self.mgr.restore_latest(target, self.shardings)
        if out[0] is None:
            return False
        step, tree = out
        self.state = TrainState(tree["params"], tree["opt_state"], step)
        self.log(f"[trainer] restored step {step} from {self.workdir}")
        return True

    def _watchdog(self, dt: float, step: int) -> None:
        if len(self.step_times) >= 5:
            med = float(np.median(self.step_times[-50:]))
            if dt > self.straggler_factor * med:
                # Real deployment: mark the host, requeue the step, page the
                # scheduler.  Single-controller: record + keep going.
                self.stragglers += 1
                self.log(f"[trainer] straggler at step {step}: "
                         f"{dt:.3f}s vs median {med:.3f}s")
                self.tracer.instant("train.straggler", cat="train",
                                    step=step, dt=dt, median=med)
                if self.metrics is not None:
                    self.metrics.counter("train.stragglers").inc()
        self.step_times.append(dt)

    def run(self, num_steps: int, metrics_cb: Optional[Callable] = None):
        losses = []
        retries = 0
        step = self.state.step
        while step < num_steps:
            batch = next(self.data_it)
            t0 = time.perf_counter()
            try:
                params, opt, metrics = self.step_fn(
                    self.state.params, self.state.opt_state, batch)
                jax.block_until_ready(metrics["loss"])
            except Exception as e:  # transient failure → restore & retry
                retries += 1
                self.restarts += 1
                self.log(f"[trainer] step {step} failed ({e!r}); "
                         f"retry {retries}/{self.max_retries}")
                self.tracer.instant("train.restart", cat="train", step=step)
                if self.metrics is not None:
                    self.metrics.counter("train.restarts").inc()
                if retries > self.max_retries or not self.maybe_restore():
                    raise
                step = self.state.step
                continue
            retries = 0
            dt = time.perf_counter() - t0
            if self.tracer.enabled:
                self.tracer.complete("train.step", t0, t0 + dt, cat="train",
                                     args={"step": step})
            if self.metrics is not None:
                self.metrics.histogram("train.step_seconds").observe(dt)
            self._watchdog(dt, step)
            if self.tune_cb is not None:
                # Online tuning (repro.runtime): the callback digests the
                # measured step time; a non-None return is a re-optimized
                # replacement step function to run from the next iteration.
                new_fn = self.tune_cb(dt, step)
                if new_fn is not None:
                    self.step_fn = new_fn
                    self.retunes += 1
                    # old medians describe the old pipeline (and the next
                    # step pays a recompile) — reset the watchdog window
                    self.step_times.clear()
                    self.log(f"[trainer] dynamic-tune: step fn swapped "
                             f"at step {step} (retune #{self.retunes})")
                    self.tracer.instant("train.retune", cat="train",
                                        step=step, retune=self.retunes)
                    if self.metrics is not None:
                        self.metrics.counter("train.retunes").inc()
            self.state = TrainState(params, opt, step + 1)
            losses.append(float(metrics["loss"]))
            if self.mgr is not None:
                self.mgr.maybe_save(step + 1, dict(
                    params=params, opt_state=opt))
            if metrics_cb:
                metrics_cb(step, metrics)
            if step % self.log_every == 0:
                self.log(f"[trainer] step {step} "
                         f"loss {float(metrics['loss']):.4f} "
                         f"({dt*1e3:.1f} ms)")
            step += 1
        if self.mgr is not None:
            self.mgr.wait()
        return losses
