"""AdamW + schedules, from scratch (no optax in this environment).

Optimizer state mirrors the parameter pytree (m, v per leaf in fp32), so
the parameter sharding specs apply verbatim to the state — ZeRO-1 falls out
of the FSDP parameter sharding with zero extra code (dist/sharding.py).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = ["AdamWConfig", "adamw_init", "adamw_update", "global_norm",
           "cosine_schedule", "linear_warmup"]


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


def adamw_init(params: Any) -> Any:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return dict(
        m=jax.tree.map(zeros, params),
        v=jax.tree.map(zeros, params),
        count=jnp.zeros((), jnp.int32),
    )


def global_norm(tree: Any) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(sum(leaves))


def cosine_schedule(cfg: AdamWConfig) -> Callable[[jax.Array], jax.Array]:
    def lr(step):
        step = step.astype(jnp.float32)
        warm = jnp.minimum(1.0, step / jnp.maximum(1, cfg.warmup_steps))
        frac = jnp.clip((step - cfg.warmup_steps)
                        / jnp.maximum(1, cfg.total_steps - cfg.warmup_steps),
                        0.0, 1.0)
        cos = 0.5 * (1 + jnp.cos(jnp.pi * frac))
        scale = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos
        return cfg.lr * warm * scale
    return lr


def linear_warmup(cfg: AdamWConfig) -> Callable[[jax.Array], jax.Array]:
    def lr(step):
        return cfg.lr * jnp.minimum(
            1.0, (step.astype(jnp.float32) + 1) / jnp.maximum(1, cfg.warmup_steps))
    return lr


def adamw_update(
    grads: Any, state: Any, params: Any, cfg: AdamWConfig,
    schedule: Optional[Callable] = None,
) -> Tuple[Any, Any, dict]:
    """One AdamW step with global-norm clipping. Returns (params', state',
    metrics).  fp32 math; params cast back to their stored dtype."""
    count = state["count"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-12)) \
        if cfg.clip_norm > 0 else 1.0
    lr = (schedule or cosine_schedule(cfg))(count)
    b1c = 1 - cfg.b1 ** count.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** count.astype(jnp.float32)

    def upd(g, m, v, p):
        gf = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * gf
        v = cfg.b2 * v + (1 - cfg.b2) * gf * gf
        mh = m / b1c
        vh = v / b2c
        step = mh / (jnp.sqrt(vh) + cfg.eps)
        if p.ndim >= 2:  # decay matrices only (standard practice)
            step = step + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * step).astype(p.dtype), m, v

    out = jax.tree.map(upd, grads, state["m"], state["v"], params)
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda t: t[2], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    return new_params, dict(m=new_m, v=new_v, count=count), dict(
        grad_norm=gnorm, lr=lr)
