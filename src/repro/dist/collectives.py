"""Ring-pipelined collectives: the paper's intra-kernel pipeline in shard_map.

MGG's core observation (§3.3–3.4) is that a bulk collective serializes
communication before computation, while chunking the transfer into ring
steps lets every step's DMA overlap the previous step's compute.  These
helpers express that schedule with ``lax.ppermute`` / ``lax.all_to_all``
per chunk: each loop iteration *issues the next transfer before consuming
the current chunk*, so the two have no data dependence and XLA's
latency-hiding scheduler runs them concurrently — the same dataflow
``core/pipeline.py`` uses for neighbor aggregation, here for the dense
matmul/dispatch collectives of the LM stack.

All functions are *per-shard* bodies: call them inside ``jax.shard_map``
over a mesh from :mod:`repro.dist.mesh`.  A 1-sized axis degenerates to the
purely local computation (no permutes), so the same model code runs on a
single device.
"""
from __future__ import annotations

from typing import Callable, Sequence

import jax
import jax.numpy as jnp
from jax import lax

__all__ = [
    "ring_allgather_matmul",
    "matmul_reducescatter",
    "pipelined_all_to_all",
]


def _ring_perm(n: int):
    return [(i, (i + 1) % n) for i in range(n)]


def ring_allgather_matmul(lhs: jax.Array, rhs: jax.Array,
                          axis_name: str) -> jax.Array:
    """``concat_gather(lhs) @ rhs`` without ever materializing the gather.

    ``lhs``: this shard's ``(m, k)`` row block; ``rhs``: ``(k, n)``
    (replicated).  Returns the full ``(axis_size * m, n)`` product on every
    shard.  Row block ``j`` is multiplied the moment it arrives over the
    ring, while the following block is already in flight — an all-gather
    whose transfer cost hides behind the matmuls (cf. MGG Fig. 7(b)).
    """
    n_dev = lax.psum(1, axis_name)
    if n_dev == 1:
        return lhs @ rhs
    idx = lax.axis_index(axis_name)
    m = lhs.shape[0]
    perm = _ring_perm(n_dev)
    out = jnp.zeros((n_dev * m, rhs.shape[-1]),
                    jnp.promote_types(lhs.dtype, rhs.dtype))
    cur = lhs
    for step in range(n_dev):
        # issue rotation step+1 BEFORE the matmul on `cur` — no data
        # dependence between them, so the DMA overlaps the compute
        nxt = lax.ppermute(cur, axis_name, perm) if step < n_dev - 1 else None
        src = (idx - step) % n_dev  # ring rank that produced `cur`
        out = lax.dynamic_update_slice_in_dim(
            out, (cur @ rhs).astype(out.dtype), src * m, axis=0)
        cur = nxt
    return out


def matmul_reducescatter(lhs: jax.Array, rhs: jax.Array,
                         axis_name: str) -> jax.Array:
    """``reduce_scatter(lhs @ rhs)`` fused into a pipelined ring.

    ``lhs``: ``(m, k_local)`` — the full row range with this shard's slice
    of the contraction dim; ``rhs``: ``(k_local, n)``.  Shard ``i`` returns
    rows ``[i*c, (i+1)*c)`` of the summed product, ``c = ceil(m/axis_size)``
    (rows are zero-padded up to ``axis_size * c`` when ``m`` is not
    divisible).  Each ring step computes one partial row block while the
    running accumulator travels to its neighbor — transfer and partial
    matmul overlap exactly as in the paper's pipelined aggregation.
    """
    n_dev = lax.psum(1, axis_name)
    if n_dev == 1:
        return lhs @ rhs
    idx = lax.axis_index(axis_name)
    m = lhs.shape[0]
    chunk = -(-m // n_dev)
    if chunk * n_dev != m:
        lhs = jnp.pad(lhs, ((0, chunk * n_dev - m), (0, 0)))
    perm = _ring_perm(n_dev)

    def partial_block(c):
        rows = lax.dynamic_slice_in_dim(lhs, c * chunk, chunk, axis=0)
        return rows @ rhs

    # The accumulator for output block b starts at shard b+1, visits every
    # shard once, and lands home after n-1 hops.  At hop `step`, shard `idx`
    # holds the accumulator for block (idx - 1 - step) and adds its own
    # partial for it *computed before the permute is consumed*.
    acc = partial_block((idx + n_dev - 1) % n_dev)
    for step in range(1, n_dev):
        nxt_partial = partial_block((idx + n_dev - 1 - step) % n_dev)
        acc = lax.ppermute(acc, axis_name, perm)
        acc = acc + nxt_partial
    return acc


def pipelined_all_to_all(
    x: jax.Array,
    axis_name: str,
    fn: Callable[[jax.Array], jax.Array],
    *,
    split_axis: int,
    concat_axis: int,
    chunk_axis: int,
    chunks: int,
) -> jax.Array:
    """all_to_all → ``fn`` → inverse all_to_all, pipelined chunkwise.

    The expert-parallel dispatch pattern: route tokens to their shard, apply
    ``fn`` (the expert compute), route results back.  ``x`` is cut into
    ``chunks`` pieces along ``chunk_axis``; while ``fn`` runs on chunk *i*,
    chunk *i+1*'s dispatch is already on the wire — MGG's pipelining knob
    (``dist``) applied to the MoE a2a.  Uneven chunking is fine (the last
    piece is smaller); ``chunks`` is clamped to the chunk-axis extent.

    Inherited ``lax.all_to_all`` contract: the per-shard ``split_axis``
    extent must be divisible by the axis size (``concat_axis`` chunking
    never changes it).
    """
    n_dev = lax.psum(1, axis_name)
    size = x.shape[chunk_axis]
    if size == 0:  # empty block: un-pipelined path (zero pieces to overlap)
        if n_dev == 1:
            return fn(x)
        return lax.all_to_all(
            fn(lax.all_to_all(x, axis_name, split_axis, concat_axis,
                              tiled=True)),
            axis_name, concat_axis, split_axis, tiled=True)
    chunks = max(1, min(int(chunks), size))
    bounds = [(i * size) // chunks for i in range(chunks + 1)]
    pieces = [
        lax.slice_in_dim(x, lo, hi, axis=chunk_axis)
        for lo, hi in zip(bounds[:-1], bounds[1:]) if hi > lo
    ]
    if n_dev > 1:
        bad = [p.shape[split_axis] for p in pieces
               if p.shape[split_axis] % n_dev != 0]
        if bad:
            raise ValueError(
                f"pipelined_all_to_all: split_axis={split_axis} extents "
                f"{bad} not divisible by axis {axis_name!r} size {n_dev} "
                f"(chunk_axis={chunk_axis}, chunks={chunks} cut into the "
                f"split dim?)")
    if n_dev == 1:
        outs = [fn(p) for p in pieces]
        return outs[0] if len(outs) == 1 else jnp.concatenate(outs, chunk_axis)

    def dispatch(p):
        return lax.all_to_all(p, axis_name, split_axis, concat_axis,
                              tiled=True)

    def combine(p):
        return lax.all_to_all(p, axis_name, concat_axis, split_axis,
                              tiled=True)

    outs = []
    in_flight = dispatch(pieces[0])
    for i in range(len(pieces)):
        cur = in_flight
        if i + 1 < len(pieces):
            # next chunk's dispatch is independent of fn(cur) → overlaps it
            in_flight = dispatch(pieces[i + 1])
        outs.append(combine(fn(cur)))
    return outs[0] if len(outs) == 1 else jnp.concatenate(outs, chunk_axis)
