"""Sharding-rule derivation: pytree → PartitionSpec pytree.

One rule set covers every architecture in ``repro.configs.ARCH_IDS`` on the
production meshes (``launch/mesh.py``): tensor parallelism over ``"model"``,
FSDP-style parameter sharding over the data axes *in training only*, batch
sharding for inputs, and batch + KV-head sharding for decode caches.

Specs are derived from the *names* in the parameter tree (``wq``/``down``/
``embed``/…) plus leaf shapes, with a hard divisibility guard: an axis is
only ever assigned to a dim the mesh divides evenly, so the same rules are
valid on a 2×2 CPU dry-run mesh and the 512-chip pod.  Stacked-layer
leading dims (``lax.scan`` layout) are never sharded.

Works on abstract inputs (``jax.eval_shape`` trees) and on stand-in meshes
exposing only ``.shape``/``.axis_names`` — deriving 512-device specs never
touches device state.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Optional, Sequence, Tuple

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

__all__ = ["ShardingRules", "param_specs", "batch_specs", "cache_specs",
           "to_shardings"]


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    """How logical roles map onto a mesh.

    ``mesh`` only needs ``.shape`` (axis → size mapping); ``data_axes`` may
    span several mesh axes (``("pod", "data")`` on multi-pod meshes) and is
    always applied as the combined product.  ``train=True`` enables FSDP
    parameter sharding over the data axes; serving replicates parameters
    across them.
    """

    mesh: Any
    data_axes: Tuple[str, ...] = ("data",)
    model_axis: str = "model"
    train: bool = True

    @property
    def data_size(self) -> int:
        return math.prod(
            int(self.mesh.shape.get(a, 1)) for a in self.data_axes)

    @property
    def model_size(self) -> int:
        return int(self.mesh.shape.get(self.model_axis, 1))

    def data_entry(self):
        return self.data_axes[0] if len(self.data_axes) == 1 \
            else tuple(self.data_axes)


# --- name classification ----------------------------------------------------

# fan-out (column-parallel): shard the LAST dim on the model axis
_COL_PARALLEL = frozenset({
    "wq", "wk", "wv", "up", "gate", "in_proj", "wx", "wif", "wr",
    "vis_proj", "conv_w", "lm_head",
})
# fan-in (row-parallel): shard dim −2 on the model axis (the contraction
# dim of the preceding column-parallel matmul — output needs one reduce)
_ROW_PARALLEL = frozenset({"down", "wo", "out_proj", "out"})
# MoE expert tables (leading expert dim after the layer stack)
_EXPERT_TABLES = frozenset({"w_up", "w_gate", "w_down"})
_ROUTERS = frozenset({"w_router", "router"})


def _path_names(path) -> list:
    names = []
    for entry in path:
        key = getattr(entry, "key", None)
        if key is None:
            key = getattr(entry, "name", None)
        if isinstance(key, str):
            names.append(key)
    return names


def _leaf_name(names: list) -> str:
    # weights live as {"w": array} under their role name; biases/norm
    # scales keep their own name
    for n in reversed(names):
        if n not in ("w", "b"):
            return n
    return names[-1] if names else ""


def _spec_from_entries(entries: list) -> P:
    return P(*entries)


def _param_rule(path, leaf, rules: ShardingRules, expert_mode: str) -> P:
    shape = tuple(leaf.shape)
    ndim = len(shape)
    if ndim < 2:
        return P()
    names = _path_names(path)
    name = _leaf_name(names)
    model, msize = rules.model_axis, rules.model_size
    entries: list = [None] * ndim

    # --- tensor-parallel dim ------------------------------------------------
    tp: Optional[int] = None
    if "embed" in names:
        tp = ndim - 2            # (vocab_padded, d_model): vocab-parallel
    elif name in _EXPERT_TABLES:
        if expert_mode == "ep" and ndim >= 3 and shape[ndim - 3] % msize == 0:
            tp = ndim - 3        # expert-parallel: shard the expert dim
        else:                    # tp fallback: shard d_ff inside each expert
            tp = ndim - 1 if name != "w_down" else ndim - 2
    elif name in _ROUTERS:
        tp = ndim - 1
    elif name in _ROW_PARALLEL:
        tp = ndim - 2
    elif name in _COL_PARALLEL:
        tp = ndim - 1
    if tp is not None and (msize <= 1 or shape[tp] % msize != 0):
        tp = None
    if tp is not None:
        entries[tp] = model

    # --- FSDP dim (train only) ---------------------------------------------
    dsize = rules.data_size
    if rules.train and dsize > 1:
        cands = [d for d in (ndim - 2, ndim - 1) if d != tp]
        cands.sort(key=lambda d: -shape[d])
        for d in cands:
            if shape[d] % dsize == 0:
                entries[d] = rules.data_entry()
                break
    return _spec_from_entries(entries)


def param_specs(params: Any, rules: ShardingRules,
                expert_mode: str = "ep") -> Any:
    """PartitionSpecs for a parameter tree (``transformer``/``encdec``
    layout).  ``expert_mode``: ``cfg.expert_mode`` — ``"ep"`` shards the
    expert dim of MoE tables, ``"tp"`` shards ``d_ff`` inside each expert.
    """
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: _param_rule(path, leaf, rules, expert_mode),
        params)


def batch_specs(batch: Any, rules: ShardingRules) -> Any:
    """Inputs: dim 0 (global batch) over the data axes when divisible."""
    dsize = rules.data_size

    def rule(leaf) -> P:
        shape = tuple(leaf.shape)
        entries: list = [None] * len(shape)
        if shape and dsize > 1 and shape[0] % dsize == 0:
            entries[0] = rules.data_entry()
        return _spec_from_entries(entries)

    return jax.tree.map(rule, batch)


def cache_specs(cache: Any, rules: ShardingRules, batch: int) -> Any:
    """Decode caches: ``(layers, batch, ...)`` leaves — batch over the data
    axes, KV heads (dim −2 of 4D+ leaves) over the model axis, both guarded
    by divisibility.  The layer-stack dim stays replicated (it is scanned)."""
    dsize, msize = rules.data_size, rules.model_size

    def rule(leaf) -> P:
        shape = tuple(leaf.shape)
        ndim = len(shape)
        entries: list = [None] * ndim
        if ndim >= 2 and dsize > 1 and shape[1] == batch and batch % dsize == 0:
            entries[1] = rules.data_entry()
        if ndim >= 4 and msize > 1 and shape[ndim - 2] % msize == 0:
            entries[ndim - 2] = rules.model_axis
        return _spec_from_entries(entries)

    return jax.tree.map(rule, cache)


def to_shardings(specs: Any, mesh) -> Any:
    """PartitionSpec pytree → NamedSharding pytree on a *concrete* mesh."""
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), specs,
        is_leaf=lambda x: isinstance(x, P))
