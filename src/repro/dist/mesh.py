"""Device meshes with ring-ordered placement.

The MGG pipeline moves embedding tiles neighbor-to-neighbor with
``lax.ppermute`` (paper §3.3: fine-grained tiles over NVLink; here ICI).
That only hides latency if rank ``i+1`` in the mesh is a *physical*
neighbor of rank ``i``, so mesh construction orders devices along a ring:

* TPU: snake through the torus coordinates (consecutive ranks share an ICI
  link; the wrap-around hop is the only long edge, and on a torus it is a
  single link too).
* CPU/GPU fakes: device id order (the host-platform devices are
  interchangeable).

Unlike ``jax.make_mesh`` this accepts meshes *smaller* than the process
device count — elastic restarts and the multi-size property tests build
2/4-way meshes inside an 8-device process.
"""
from __future__ import annotations

import math
from typing import Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh

__all__ = ["make_mesh", "flat_ring_mesh", "ring_order"]


def ring_order(devices: Sequence) -> list:
    """Order ``devices`` so consecutive entries are physical neighbors."""
    devs = list(devices)
    if not devs:
        return devs
    coords = getattr(devs[0], "coords", None)
    if coords is None:
        return sorted(devs, key=lambda d: d.id)

    # snake through the torus: even rows left→right, odd rows right→left,
    # recursively per leading coordinate (plus the core-on-chip index).
    def key(d):
        c = tuple(d.coords) + (getattr(d, "core_on_chip", 0),)
        snaked = []
        flip = 0
        for x in c:
            snaked.append(-x if flip % 2 else x)
            flip += x
        return tuple(snaked)

    return sorted(devs, key=key)


def make_mesh(shape: Sequence[int], axis_names: Sequence[str],
              *, devices: Optional[Sequence] = None) -> Mesh:
    """A :class:`jax.sharding.Mesh` of ``prod(shape)`` ring-ordered devices.

    ``devices`` defaults to ``jax.devices()``; only the first ``prod(shape)``
    (in ring order) are used, so sub-meshes of a larger process are fine.
    """
    shape = tuple(int(s) for s in shape)
    if len(shape) != len(tuple(axis_names)):
        raise ValueError(f"shape {shape} vs axis_names {tuple(axis_names)}")
    need = math.prod(shape)
    devs = ring_order(jax.devices() if devices is None else devices)
    if len(devs) < need:
        raise ValueError(
            f"mesh {dict(zip(axis_names, shape))} needs {need} devices, "
            f"process has {len(devs)}")
    arr = np.empty((need,), dtype=object)
    for i, d in enumerate(devs[:need]):
        arr[i] = d
    return Mesh(arr.reshape(shape), tuple(axis_names))


def flat_ring_mesh(n: int) -> Mesh:
    """The MGG aggregation mesh: ``n`` devices on a single ``"ring"`` axis."""
    return make_mesh((n,), ("ring",))
