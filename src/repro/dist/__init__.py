"""repro.dist — the distributed substrate between the Pallas kernels and
the multi-GPU/TPU system the paper describes.

Four pieces (see docs/dist.md):

* :mod:`repro.dist.mesh` — ring-ordered device meshes
  (``make_mesh``, ``flat_ring_mesh``);
* :mod:`repro.dist.collectives` — ring-pipelined collectives that overlap
  each chunk's transfer with the previous chunk's compute
  (``ring_allgather_matmul``, ``matmul_reducescatter``,
  ``pipelined_all_to_all``);
* :mod:`repro.dist.compress` — error-feedback compressed gradient
  allreduce (``ef_state_init``, ``ef_allreduce_mean``);
* :mod:`repro.dist.sharding` — divisibility-respecting PartitionSpec
  derivation for every config in ``repro.configs.ARCH_IDS``
  (``ShardingRules``, ``param_specs``, ``batch_specs``, ``cache_specs``,
  ``to_shardings``).
"""
from repro.dist import sharding
from repro.dist.collectives import (matmul_reducescatter, pipelined_all_to_all,
                                    ring_allgather_matmul)
from repro.dist.compress import (ef_allreduce_mean, ef_state_init,
                                 quantize_dequantize)
from repro.dist.mesh import flat_ring_mesh, make_mesh, ring_order
from repro.dist.sharding import (ShardingRules, batch_specs, cache_specs,
                                 param_specs, to_shardings)

__all__ = [
    "make_mesh", "flat_ring_mesh", "ring_order",
    "ring_allgather_matmul", "matmul_reducescatter", "pipelined_all_to_all",
    "ef_state_init", "ef_allreduce_mean", "quantize_dequantize",
    "sharding", "ShardingRules", "param_specs", "batch_specs",
    "cache_specs", "to_shardings",
]
