"""Error-feedback compressed gradient allreduce.

Data-parallel training reduces gradients every step; at MGG's scale the
reduce competes for the same interconnect as the pipelined aggregation
ring, so the gradient payload is quantized to int8 on the wire (4× fewer
bytes than fp32).  Plain quantization biases the update; *error feedback*
(Seide et al.; Karimireddy et al.) carries each step's quantization
residual into the next step's gradient, so the error telescopes:

    sum_t C(g_t + e_{t-1}) = sum_t g_t + e_0 - e_T

— the accumulated compressed means converge to the accumulated true mean
with only the final O(quantization-step) residual, which is what
``tests/multidev/collectives.py`` asserts.

State is one fp32 residual per parameter leaf (``ef_state_init``), held
alongside the optimizer state and sharded the same way.
"""
from __future__ import annotations

from typing import Any, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax import lax

__all__ = ["ef_state_init", "ef_allreduce_mean", "quantize_dequantize"]


def ef_state_init(grads: Any) -> Any:
    """Zero residual carry, one fp32 leaf per gradient leaf."""
    return jax.tree.map(
        lambda g: jnp.zeros(jnp.shape(g), jnp.float32), grads)


def quantize_dequantize(v: jax.Array, bits: int = 8) -> jax.Array:
    """Symmetric per-tensor fake-quantization (the wire format simulated)."""
    levels = float(2 ** (bits - 1) - 1)
    scale = jnp.max(jnp.abs(v)) / levels
    scale = jnp.where(scale > 0, scale, 1.0)
    return jnp.round(v / scale) * scale


def ef_allreduce_mean(
    grads: Any,
    err: Any,
    mesh,
    axes: Sequence[str],
    specs: Any,
    *,
    bits: int = 8,
) -> Tuple[Any, Any]:
    """Mean-allreduce ``grads`` over mesh ``axes`` with int-``bits``
    compression and error feedback.

    ``specs``: pytree of ``PartitionSpec`` matching ``grads`` (how each leaf
    lives on ``mesh``).  Returns ``(mean, new_err)``; feed ``new_err`` back
    in on the next step.
    """
    axes = tuple(axes)
    compensated = jax.tree.map(
        lambda g, e: g.astype(jnp.float32) + e, grads, err)
    quantized = jax.tree.map(
        lambda c: quantize_dequantize(c, bits=bits), compensated)
    new_err = jax.tree.map(lambda c, q: c - q, compensated, quantized)

    def mean_body(tree):
        return jax.tree.map(lambda v: lax.pmean(v, axes), tree)

    mean = jax.shard_map(
        mean_body, mesh=mesh, in_specs=(specs,), out_specs=specs,
        check_vma=False,
    )(quantized)
    return mean, new_err
