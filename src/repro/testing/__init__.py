"""Test-support utilities shipped with the library (no pytest dependency)."""
