"""``hypothesis`` front-end: the real library when installed, else a
deterministic fallback for the API subset the suite uses.

Property tests import from here unconditionally —

    from repro.testing.hypo import given, settings, strategies as st

— and get real hypothesis whenever it is importable (CI installs it; see
the re-export at the bottom of this module).  Hermetic images without it
get the shim.

Shim semantics: ``@given`` runs the test body ``max_examples`` times with values
drawn from a per-example seeded ``numpy`` RNG — deterministic across runs
and machines (no shrinking, no database, no deadline handling; ``settings``
accepts and ignores the extra knobs).  Strategies cover exactly what the
suite draws: ``integers``, ``floats``, ``booleans``, ``sampled_from``,
``just``, ``lists``, ``tuples``, and ``composite``.
"""
from __future__ import annotations

import functools
import inspect
from typing import Any, Callable, Sequence

import numpy as np

__all__ = ["given", "settings", "strategies"]

_DEFAULT_MAX_EXAMPLES = 100


class Strategy:
    """A value generator: ``example(rng) -> value``."""

    def __init__(self, draw_fn: Callable[[np.random.Generator], Any],
                 label: str = "strategy"):
        self._draw = draw_fn
        self._label = label

    def example(self, rng: np.random.Generator) -> Any:
        return self._draw(rng)

    def map(self, f: Callable[[Any], Any]) -> "Strategy":
        return Strategy(lambda rng: f(self._draw(rng)),
                        f"{self._label}.map")

    def filter(self, pred: Callable[[Any], bool],
               max_tries: int = 100) -> "Strategy":
        def draw(rng):
            for _ in range(max_tries):
                v = self._draw(rng)
                if pred(v):
                    return v
            raise ValueError(f"{self._label}.filter found no example "
                             f"in {max_tries} tries")
        return Strategy(draw, f"{self._label}.filter")

    def __repr__(self):
        return f"<{self._label}>"


class _Strategies:
    @staticmethod
    def integers(min_value: int, max_value: int) -> Strategy:
        return Strategy(
            lambda rng: int(rng.integers(min_value, max_value + 1)),
            f"integers({min_value},{max_value})")

    @staticmethod
    def floats(min_value: float, max_value: float) -> Strategy:
        return Strategy(
            lambda rng: float(rng.uniform(min_value, max_value)),
            f"floats({min_value},{max_value})")

    @staticmethod
    def booleans() -> Strategy:
        return Strategy(lambda rng: bool(rng.integers(0, 2)), "booleans")

    @staticmethod
    def sampled_from(options: Sequence) -> Strategy:
        opts = list(options)
        return Strategy(lambda rng: opts[int(rng.integers(len(opts)))],
                        "sampled_from")

    @staticmethod
    def just(value) -> Strategy:
        return Strategy(lambda rng: value, "just")

    @staticmethod
    def lists(elements: Strategy, *, min_size: int = 0,
              max_size: int = 10) -> Strategy:
        def draw(rng):
            n = int(rng.integers(min_size, max_size + 1))
            return [elements.example(rng) for _ in range(n)]
        return Strategy(draw, "lists")

    @staticmethod
    def tuples(*strats: Strategy) -> Strategy:
        return Strategy(lambda rng: tuple(s.example(rng) for s in strats),
                        "tuples")

    @staticmethod
    def composite(f: Callable) -> Callable[..., Strategy]:
        """``@st.composite``: ``f(draw, *args) -> value`` becomes a strategy
        factory, mirroring hypothesis' signature contract."""
        @functools.wraps(f)
        def factory(*args, **kwargs) -> Strategy:
            def draw_value(rng):
                draw = lambda strat: strat.example(rng)
                return f(draw, *args, **kwargs)
            return Strategy(draw_value, f"composite:{f.__name__}")
        return factory


strategies = _Strategies()


def settings(*, max_examples: int = _DEFAULT_MAX_EXAMPLES, **_ignored):
    """Decorator recording ``max_examples``; other knobs (deadline, …) are
    accepted for signature compatibility and ignored."""
    def deco(fn):
        fn._hypo_max_examples = max_examples
        return fn
    return deco


def given(*strats: Strategy, **kw_strats: Strategy):
    """Run the wrapped test for each deterministic example draw."""
    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_hypo_max_examples",
                        getattr(fn, "_hypo_max_examples",
                                _DEFAULT_MAX_EXAMPLES))
            for i in range(n):
                rng = np.random.default_rng(
                    np.random.SeedSequence([0xC0FFEE, i]))
                vals = [s.example(rng) for s in strats]
                kwvals = {k: s.example(rng) for k, s in kw_strats.items()}
                try:
                    fn(*args, *vals, **{**kwargs, **kwvals})
                except Exception as e:
                    raise AssertionError(
                        f"property falsified on example {i}: "
                        f"args={vals} kwargs={kwvals}") from e
        # keep pytest from trying to collect strategy params as fixtures
        sig = inspect.signature(fn)
        keep = list(sig.parameters.values())[: max(
            0, len(sig.parameters) - len(strats) - len(kw_strats))]
        wrapper.__signature__ = sig.replace(parameters=keep)
        return wrapper
    return deco


try:  # prefer the real library whenever it is installed (e.g. in CI)
    from hypothesis import given, settings, strategies  # noqa: F811,F401
except ModuleNotFoundError:
    pass
