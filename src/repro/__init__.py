"""repro: MGG (fine-grained communication-computation pipelining) on TPU —
core GNN engine + assigned LM-architecture framework."""
from repro import compat as _compat

_compat.install()

__version__ = "1.0.0"
