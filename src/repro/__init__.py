"""repro: MGG (fine-grained communication-computation pipelining) on TPU —
core GNN engine + assigned LM-architecture framework."""
__version__ = "1.0.0"
