"""Analytical modeling and cross-iteration design optimization (paper §4).

The paper models two resources —

    WPW  = 2 · ps · D · dist                  (work per warp)
    SMEM = ps · wpb · IntS + 2 · wpb · D · FloatS   (shared mem per block)

— and runs a greedy coordinate-descent search (``ps → dist → wpb``, with a
"retreat" rule on ``ps`` and a stop-at-top-3 criterion), converging in ~10
measurements (paper Fig. 10, up to 68% latency reduction vs. the initial
configuration).

TPU re-targeting (DESIGN.md §2):

* ``ps``   — unchanged: neighbor-partition size (layout-time knob).
* ``dist`` — ring tiles per shard: pipeline granularity (init-time knob).
* ``wpb``  — Pallas partition-block height ``pb``: how many neighbor
  partitions one kernel grid cell processes (runtime mapping knob).
* ``SMEM ≤ 164 KB/SM`` becomes ``VMEM ≤ ~16 MB/core``: the ring double
  buffer (2 tiles) plus the kernel block working set must fit VMEM.

The latency model combines the three roofline terms of the ring schedule so
the same machinery drives both the autotuner and the §Roofline analysis in
EXPERIMENTS.md.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from .graph import CSRGraph
from .partition import edge_balanced_node_split, locality_edge_split

__all__ = [
    "HardwareSpec",
    "TPU_V5E",
    "A100_NVSWITCH",
    "FUSE_RING_EFF",
    "estimate_latency",
    "estimate_pipeline_latency",
    "layer_workload_shapes",
    "vmem_bytes",
    "cross_iteration_optimize",
    "SearchResult",
]


@dataclasses.dataclass(frozen=True)
class HardwareSpec:
    """Per-chip hardware constants for the analytical model.

    The shipped constants (:data:`TPU_V5E`, :data:`A100_NVSWITCH`) are
    datasheet numbers; :mod:`repro.obs.calibrate` can replace them with
    values *measured* on the live machine — either micro-probed directly
    (``spec_from_probes``) or fitted to the tuner's audit-trail latencies
    (``fit_spec``), via :meth:`scaled`.
    """

    name: str
    peak_flops: float        # FLOP/s (bf16 for TPU)
    hbm_bw: float            # bytes/s
    link_bw: float           # bytes/s per ICI link / NVLink direction
    vmem_bytes: int          # VMEM (TPU) or SMEM-per-SM * SMs (GPU)
    cores: int = 1
    host_bw: float = 32e9    # host→device bytes/s (PCIe gen4 ×16 class);
    #                          the tiered feature path's cold-row gathers
    #                          stream over this link

    def scaled(self, suffix: str = "+calibrated",
               **scales: float) -> "HardwareSpec":
        """A copy with named float fields multiplied by the given scales
        (identity scales elide the copy), e.g. ``hw.scaled(link_bw=0.5)``
        for a machine whose ring moves half the datasheet bytes/s."""
        changed = {k: getattr(self, k) * float(v)
                   for k, v in scales.items() if float(v) != 1.0}
        if not changed:
            return self
        return dataclasses.replace(self, name=self.name + suffix, **changed)


# Target hardware for the roofline (per the brief): TPU v5e.
TPU_V5E = HardwareSpec(
    name="tpu_v5e",
    peak_flops=197e12,
    hbm_bw=819e9,
    link_bw=50e9,
    vmem_bytes=16 * 2**20,
    host_bw=32e9,
)

# The paper's platform, used to sanity-check the model against paper numbers.
A100_NVSWITCH = HardwareSpec(
    name="a100_nvswitch",
    peak_flops=312e12,
    hbm_bw=1555e9,
    link_bw=300e9,  # NVSwitch per-GPU uni-directional
    vmem_bytes=164 * 1024 * 108,
    host_bw=32e9,
)


# Fused-update MXU efficiency relative to the drained post-ring GEMM: the
# fused path runs one (P, D)·(D, D_out) partial matmul per ring step, whose
# smaller M dimension underutilizes the MXU relative to one full-shard GEMM.
# Calibrated against the measured fig9d rows (benchmarks/fig9_ablations.py
# emits model-vs-measured fused speedups; 0.85 keeps the modeled fused win
# within the measured envelope across the fig9d widths).
FUSE_RING_EFF = 0.85


def vmem_bytes(ps: int, pb: int, dim_block: int, tile_rows: int,
               d_feat: int, itemsize: int = 4) -> int:
    """VMEM working set: ring double buffer + one kernel block.

    Paper SMEM analogue: ids (ps·pb·4) + partial results (pb·D) + staged
    remote rows; the ring adds two tiles (current + in-flight).
    """
    kernel = ps * pb * 4 + pb * dim_block * itemsize + ps * dim_block * itemsize
    ring = 2 * tile_rows * min(dim_block * 8, d_feat) * itemsize
    return kernel + ring


@dataclasses.dataclass(frozen=True)
class WorkloadShape:
    """Aggregate statistics the latency model consumes (host-side, cheap)."""

    n_dev: int
    d_feat: int
    rows_per_dev: int
    local_edges_max: int    # max over devices
    remote_edges_max: int
    itemsize: int = 4

    @staticmethod
    def from_graph(graph: CSRGraph, n_dev: int, d_feat: int,
                   itemsize: int = 4) -> "WorkloadShape":
        bounds = edge_balanced_node_split(graph.indptr, n_dev)
        le, re = 0, 0
        for d in range(n_dev):
            vg = locality_edge_split(graph, bounds, d)
            le = max(le, vg.local.num_edges)
            re = max(re, vg.remote.num_edges)
        rows = int((bounds[1:] - bounds[:-1]).max())
        return WorkloadShape(n_dev, d_feat, rows, le, re, itemsize)

    def with_d_feat(self, d_feat: int) -> "WorkloadShape":
        """Same graph/partition statistics at another feature width."""
        return dataclasses.replace(self, d_feat=int(d_feat))


def layer_workload_shapes(
    graph: CSRGraph, n_dev: int, dims: "List[int]", itemsize: int = 4,
) -> "List[WorkloadShape]":
    """Per-layer workload shapes sharing ONE partition-statistics pass.

    GNN layers differ only in feature width ``D`` (the topology — and hence
    the edge/row statistics — is shared), so the per-layer latency model is
    the same :func:`estimate_latency` evaluated at each layer's ``D``.
    """
    if not dims:
        raise ValueError("need at least one layer width")
    base = WorkloadShape.from_graph(graph, n_dev, int(dims[0]), itemsize)
    return [base.with_d_feat(d) for d in dims]


def estimate_latency(
    w: WorkloadShape,
    ps: int,
    dist: int,
    pb: int,
    hw: HardwareSpec = TPU_V5E,
    interleave: bool = True,
    d_out: Optional[int] = None,
    fuse: bool = False,
    host_rows: Optional[int] = None,
    topk: Optional[int] = None,
) -> float:
    """Modeled per-aggregation latency (seconds) for one device.

    Ring schedule: S = (n-1)·dist steps.  Per step,
      comm  = tile_bytes / link_bw
      comp  = (remote gather+add bytes + interleaved local share) / hbm_bw
    With overlap (interleave=True) a step costs max(comm, comp); without, the
    local pass runs first and every step costs comm + remote-comp (paper
    Fig. 7a vs 7b).  Padding inefficiency from partition granularity is
    modeled by rounding edges up to multiples of ps per node — the same
    waste the mask slots represent at runtime.

    ``d_out`` adds the layer's dense ``·W`` update phase
    (``2 · rows · D · D_out`` FLOPs per device): serial after the ring when
    ``fuse=False`` (the cuBLAS-after-aggregation dataflow), or folded into
    each ring step's compute when ``fuse=True`` — which is exactly when
    fusion wins: the MXU term hides under ``max(comm, comp)`` whenever the
    step is transfer-bound.  ``d_out=None`` models aggregation only
    (backward-compatible).

    ``host_rows`` adds the tiered feature path's host→device gather term:
    that many cold rows stream from the host :class:`repro.store`
    FeatureStore over ``hw.host_bw`` per aggregation.  The streamed
    pipeline (pipeline.mgg_aggregate_streamed) double-buffers: the fill
    chunk (``1/dist`` of the gather) is exposed, the rest hides under the
    ring — only the spill past the ring's own time is paid.  Larger
    cache capacity ⇒ fewer ``host_rows`` ⇒ lower latency, which is what
    makes capacity a climbable tuner knob; ``host_rows=None`` (or 0)
    models all-resident features (backward-compatible).

    ``topk`` models the sparse ring payload
    (:func:`repro.core.pipeline.mgg_aggregate_sparse`): each tile ships
    ``k`` top-k values plus their column indices (int16 below the int16
    id range, else int32) instead of ``D`` dense floats, scaling the
    per-step wire bytes by ``k·(itemsize+idx)/(D·itemsize)``.  The
    gather side reads the narrow compressed rows but still accumulates a
    dense ``D``-wide output, so compute bytes scale by
    ``(k·(itemsize+idx) + D·itemsize)/(2·D·itemsize)``.  ``topk=None``
    (or ≥ D) models the dense pipeline.
    """
    k = None if topk is None else int(min(int(topk), w.d_feat))
    idx_b = 2 if w.d_feat <= 32767 else 4
    wire_mult = 1.0 if k is None \
        else k * (w.itemsize + idx_b) / (w.d_feat * w.itemsize)
    comp_mult = 1.0 if k is None \
        else (k * (w.itemsize + idx_b) + w.d_feat * w.itemsize) \
        / (2.0 * w.d_feat * w.itemsize)
    t_update = 0.0
    if d_out is not None:
        t_update = 2.0 * w.rows_per_dev * w.d_feat * d_out / hw.peak_flops
    t_gather = 0.0
    if host_rows:
        t_gather = host_rows * w.d_feat * w.itemsize / hw.host_bw
    if w.n_dev == 1:
        bytes_local = 2 * w.local_edges_max * w.d_feat * w.itemsize
        return bytes_local * comp_mult / hw.hbm_bw + t_update + t_gather
    tile_rows = -(-w.rows_per_dev // dist)
    steps = (w.n_dev - 1) * dist
    tile_bytes = tile_rows * w.d_feat * w.itemsize * wire_mult
    # partition-padding waste: ~ps/2 wasted slots per node on average; fold
    # into an effective edge multiplier (calibrated vs. plan.stats()).
    pad_mult = 1.0 + 0.5 * ps * w.n_dev / max(1, w.remote_edges_max)
    re_bytes = 2 * w.remote_edges_max * pad_mult * w.d_feat * w.itemsize \
        * comp_mult
    lc_bytes = 2 * w.local_edges_max * w.d_feat * w.itemsize * comp_mult
    t_comm = tile_bytes / hw.link_bw
    t_remote = re_bytes / steps / hw.hbm_bw
    t_local = lc_bytes / steps / hw.hbm_bw
    # pb: block mapping efficiency — too small starves the VPU lanes, too big
    # spills VMEM.  Modeled as a mild efficiency curve peaking at pb where the
    # block fits VMEM (hard constraint checked by the caller).
    eff = min(1.0, 0.55 + 0.15 * np.log2(max(1, pb)))
    # fused partial GEMMs run at FUSE_RING_EFF of the drained GEMM's MXU
    # utilization (calibrated vs fig9d)
    t_step_update = t_update / steps / FUSE_RING_EFF if fuse else 0.0
    if interleave:
        per_step = max(t_comm, (t_remote + t_local) / eff + t_step_update)
        t = steps * per_step + t_comm  # + pipeline fill
    else:
        t = lc_bytes / hw.hbm_bw / eff \
            + steps * (t_comm + t_remote / eff + t_step_update)
    if t_gather:
        # double-buffered prefetch: the fill chunk is exposed, the rest
        # overlaps the ring — pay only what spills past the ring's time
        fill = t_gather / max(1, dist)
        t += fill + max(0.0, (t_gather - fill) - t)
    return t if fuse else t + t_update


def estimate_pipeline_latency(
    shapes: "List[WorkloadShape]",
    configs: "List[Dict[str, int]]",
    hw: HardwareSpec = TPU_V5E,
    interleave: bool = True,
    d_outs: Optional["List[Optional[int]]"] = None,
    fuse: bool = False,
    fuses: Optional["List[bool]"] = None,
    topk: Optional[int] = None,
) -> float:
    """Whole-forward model: Σ over layers of the per-layer estimate.

    ``shapes[i]`` carries layer ``i``'s feature width (see
    :func:`layer_workload_shapes`); ``configs[i]`` its ``(ps, dist, pb)``
    and optionally a per-layer ``fuse`` flag (``fuses`` overrides, then
    ``configs[i]['fuse']``, then the call-level ``fuse`` default — the
    same precedence the per-layer tuner's fuse dimension produces).  A
    per-config ``k`` (the v4 cache knob) likewise overrides the
    call-level ``topk`` default; layer 0 is always modeled dense,
    matching :meth:`GNNEngine.stage_topk`.  The
    analytical counterpart of the per-layer tuner's objective — the tuner
    itself descends MEASURED full-forward latencies (it never calls
    this); use it for offline what-if modeling and roofline reports.  The
    ``fuse`` term is calibrated against the measured fig9d rows via
    :data:`FUSE_RING_EFF`.
    """
    if len(shapes) != len(configs):
        raise ValueError("one config per layer required")
    if d_outs is None:
        d_outs = [None] * len(shapes)
    def _k(i, c):
        if i == 0:
            return None
        k = c.get("k", topk)
        return int(k) if k else None

    return sum(
        estimate_latency(s, int(c["ps"]), int(c["dist"]), int(c["pb"]),
                         hw=hw, interleave=interleave, d_out=d_outs[i],
                         fuse=bool(fuses[i] if fuses is not None
                                   else c.get("fuse", fuse)),
                         topk=_k(i, c))
        for i, (s, c) in enumerate(zip(shapes, configs))
    )


@dataclasses.dataclass
class SearchResult:
    best: Dict[str, int]
    best_latency: float
    trajectory: List[Tuple[Dict[str, int], float]]
    table: Dict[Tuple[int, int, int], float]

    @property
    def num_trials(self) -> int:
        return len(self.trajectory)


def cross_iteration_optimize(
    measure: Callable[[int, int, int], float],
    ps_space: Tuple[int, ...] = (1, 2, 4, 8, 16, 32),
    dist_space: Tuple[int, ...] = (1, 2, 4, 8, 16),
    pb_space: Tuple[int, ...] = (1, 2, 4, 8, 16),
    vmem_check: Optional[Callable[[int, int, int], bool]] = None,
) -> SearchResult:
    """The paper's cross-iteration optimization (§4), verbatim logic.

    ``measure(ps, dist, pb) -> latency``.  Parameters start at the smallest
    value; each phase greedily increases one knob while latency improves:

    1. increase ``ps`` until latency rises (layout),
    2. increase ``dist`` likewise (pipeline),
    3. increase ``pb``; if no pb improves, *retreat* ``ps`` one notch and
       retry (the paper's "decrease ps to its second-highest value"),
    stopping when further moves cannot beat the top-3 recorded latencies.
    A lookup table memoizes every measured configuration.
    """
    table: Dict[Tuple[int, int, int], float] = {}
    traj: List[Tuple[Dict[str, int], float]] = []

    def mget(ps: int, dist: int, pb: int) -> float:
        key = (ps, dist, pb)
        if key not in table:
            if vmem_check is not None and not vmem_check(ps, dist, pb):
                table[key] = float("inf")
            else:
                table[key] = float(measure(ps, dist, pb))
            traj.append((dict(ps=ps, dist=dist, pb=pb), table[key]))
        return table[key]

    def climb(values: Tuple[int, ...], cur: int, f: Callable[[int], float]) -> int:
        best, best_lat = cur, f(cur)
        for v in values:
            if v <= cur:
                continue
            lat = f(v)
            if lat < best_lat:
                best, best_lat = v, lat
            else:
                break  # paper: stop the search once latency increases
        return best

    ps = climb(ps_space, ps_space[0], lambda v: mget(v, dist_space[0], pb_space[0]))
    dist = climb(dist_space, dist_space[0], lambda v: mget(ps, v, pb_space[0]))
    pb = climb(pb_space, pb_space[0], lambda v: mget(ps, dist, v))

    # Retreat rule: if pb never improved, drop ps one notch and retry pb.
    if pb == pb_space[0] and ps != ps_space[0]:
        ps_retreat = ps_space[max(0, ps_space.index(ps) - 1)]
        pb2 = climb(pb_space, pb_space[0], lambda v: mget(ps_retreat, dist, v))
        if mget(ps_retreat, dist, pb2) < mget(ps, dist, pb):
            ps, pb = ps_retreat, pb2

    best_key = min(table, key=lambda k: table[k])
    return SearchResult(
        best=dict(ps=best_key[0], dist=best_key[1], pb=best_key[2]),
        best_latency=table[best_key],
        trajectory=traj,
        table=table,
    )
