"""Graph containers and synthetic generators for the MGG engine.

The paper evaluates full-graph GNNs on five large graphs (Table 3: reddit,
enwiki-2013, ogbn-products, ogbn-proteins, com-orkut).  Those datasets are not
shippable inside this repo, so we provide deterministic synthetic generators
that reproduce the *structural properties that matter to MGG*: heavy-tailed
degree distributions (power-law), high average degree, and community locality
(which controls the local/remote edge ratio after an edge-balanced node
split).  Scaled-down stand-ins for each paper dataset are exposed through
:func:`paper_dataset` so every benchmark names the graph it models.

All preprocessing here is host-side NumPy — mirroring the paper, where graph
partitioning and workload management run on the CPU before kernels launch.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import numpy as np

__all__ = [
    "CSRGraph",
    "erdos_renyi",
    "power_law",
    "paper_dataset",
    "PAPER_DATASETS",
    "neighbors_of",
    "khop_in_frontier",
]


@dataclasses.dataclass(frozen=True)
class CSRGraph:
    """A directed graph in CSR form (row = destination, cols = in-neighbors).

    GNN aggregation consumes *in*-edges: row ``v`` of the CSR lists the
    neighbors ``u`` whose embeddings are accumulated into ``v``.  ``indptr``
    has length ``num_nodes + 1``; ``indices`` holds column ids.
    """

    indptr: np.ndarray  # (N+1,) int64
    indices: np.ndarray  # (nnz,) int32
    num_nodes: int

    def __post_init__(self) -> None:
        assert self.indptr.ndim == 1 and self.indices.ndim == 1
        assert self.indptr.shape[0] == self.num_nodes + 1
        assert self.indptr[0] == 0 and self.indptr[-1] == self.indices.shape[0]

    @property
    def num_edges(self) -> int:
        return int(self.indices.shape[0])

    @property
    def degrees(self) -> np.ndarray:
        return np.diff(self.indptr)

    def row(self, v: int) -> np.ndarray:
        return self.indices[self.indptr[v] : self.indptr[v + 1]]

    def with_self_loops(self) -> "CSRGraph":
        """Return a copy with a self edge added to every row (GCN's A + I)."""
        deg = self.degrees
        new_ptr = np.zeros(self.num_nodes + 1, dtype=np.int64)
        np.cumsum(deg + 1, out=new_ptr[1:])
        new_idx = np.empty(self.num_edges + self.num_nodes, dtype=np.int32)
        # Vectorized construction: positions of original edges shift by row id.
        row_ids = np.repeat(np.arange(self.num_nodes), deg)
        new_pos = self.indptr[:-1][row_ids] + row_ids + (
            np.arange(self.num_edges) - self.indptr[:-1][row_ids]
        )
        new_idx[new_pos] = self.indices
        new_idx[new_ptr[1:] - 1] = np.arange(self.num_nodes, dtype=np.int32)
        return CSRGraph(new_ptr, new_idx, self.num_nodes)

    def transpose(self) -> "CSRGraph":
        """The reverse graph: row ``u`` lists the nodes ``v`` with an edge
        ``u → v`` in this graph (i.e. out-neighbors under the in-edge CSR).

        Serving uses this for cache invalidation: a feature change at ``u``
        dirties the layer-1 aggregates of exactly ``transpose().row(u)``.
        """
        dst = np.repeat(np.arange(self.num_nodes, dtype=np.int32),
                        self.degrees)
        order = np.argsort(self.indices, kind="stable")
        counts = np.bincount(self.indices, minlength=self.num_nodes)
        indptr = np.zeros(self.num_nodes + 1, dtype=np.int64)
        np.cumsum(counts, out=indptr[1:])
        return CSRGraph(indptr, dst[order], self.num_nodes)

    def to_dense(self) -> np.ndarray:
        """Dense adjacency (tests only — O(N^2)); multi-edges accumulate."""
        a = np.zeros((self.num_nodes, self.num_nodes), dtype=np.float32)
        row_ids = np.repeat(np.arange(self.num_nodes), self.degrees)
        np.add.at(a, (row_ids, self.indices), 1.0)
        return a


def neighbors_of(graph: CSRGraph, nodes: np.ndarray) -> np.ndarray:
    """Concatenated in-neighbor lists of ``nodes`` (duplicates kept).

    Vectorized CSR range gather — the serving frontier extractor calls this
    per hop, so no per-node Python loop.
    """
    nodes = np.asarray(nodes, dtype=np.int64)
    starts = graph.indptr[nodes]
    lens = graph.indptr[nodes + 1] - starts
    total = int(lens.sum())
    if total == 0:
        return np.empty(0, dtype=np.int32)
    # flat positions: for each node, starts[i] + (0..lens[i])
    offs = np.arange(total, dtype=np.int64) - np.repeat(
        np.cumsum(lens) - lens, lens)
    return graph.indices[np.repeat(starts, lens) + offs]


def khop_in_frontier(graph: CSRGraph, seeds: np.ndarray,
                     k: int) -> np.ndarray:
    """Sorted node set reachable from ``seeds`` over ≤ ``k`` reverse hops.

    These are exactly the nodes whose embeddings a ``k``-layer GNN reads to
    predict ``seeds`` (the receptive field): hop 0 is the seeds themselves,
    hop ``i`` adds the in-neighbors of hop ``i-1``.
    """
    frontier = np.unique(np.asarray(seeds, dtype=np.int64))
    seen = frontier
    for _ in range(int(k)):
        nxt = np.unique(neighbors_of(graph, frontier).astype(np.int64))
        frontier = nxt[~np.isin(nxt, seen)]
        if frontier.size == 0:
            break
        seen = np.union1d(seen, frontier)
    return seen.astype(np.int64)


def _from_edges(dst: np.ndarray, src: np.ndarray, num_nodes: int) -> CSRGraph:
    """Build a CSR from (dst, src) edge arrays, sorting and deduplicating."""
    order = np.lexsort((src, dst))
    dst, src = dst[order], src[order]
    keep = np.ones(dst.shape[0], dtype=bool)
    keep[1:] = (dst[1:] != dst[:-1]) | (src[1:] != src[:-1])
    dst, src = dst[keep], src[keep]
    counts = np.bincount(dst, minlength=num_nodes)
    indptr = np.zeros(num_nodes + 1, dtype=np.int64)
    np.cumsum(counts, out=indptr[1:])
    return CSRGraph(indptr, src.astype(np.int32), num_nodes)


def erdos_renyi(num_nodes: int, avg_degree: float, seed: int = 0) -> CSRGraph:
    """Uniform random directed graph with the given expected in-degree."""
    rng = np.random.default_rng(seed)
    num_edges = int(num_nodes * avg_degree)
    dst = rng.integers(0, num_nodes, size=num_edges, dtype=np.int64)
    src = rng.integers(0, num_nodes, size=num_edges, dtype=np.int64)
    return _from_edges(dst, src, num_nodes)


def power_law(
    num_nodes: int,
    avg_degree: float,
    alpha: float = 2.1,
    locality: float = 0.0,
    seed: int = 0,
) -> CSRGraph:
    """Heavy-tailed graph: in-degrees ~ Zipf(alpha), sources Zipf-popular.

    ``locality`` in [0, 1) biases a fraction of edges to nearby node ids,
    modeling community structure: after a contiguous node split, higher
    locality ⇒ larger local/remote edge ratio (the knob MGG's locality-aware
    edge split responds to).
    """
    rng = np.random.default_rng(seed)
    # Target in-degree per node: truncated Zipf scaled to the requested mean.
    raw = rng.zipf(alpha, size=num_nodes).astype(np.float64)
    raw = np.minimum(raw, num_nodes / 4)
    deg = np.maximum(1, (raw * (avg_degree / raw.mean())).astype(np.int64))
    dst = np.repeat(np.arange(num_nodes, dtype=np.int64), deg)
    num_edges = dst.shape[0]
    # Sources: popularity-weighted (hubs), with a locality mixture.
    pop = rng.permutation(num_nodes)  # hub ids are random, not id-ordered
    zipf_src = rng.zipf(alpha, size=num_edges) % num_nodes
    src = pop[zipf_src]
    if locality > 0.0:
        local_mask = rng.random(num_edges) < locality
        width = max(2, num_nodes // 64)
        offs = rng.integers(-width, width + 1, size=num_edges)
        src = np.where(local_mask, (dst + offs) % num_nodes, src)
    return _from_edges(dst, src.astype(np.int64), num_nodes)


# Scaled-down structural stand-ins for the paper's Table 3 datasets.
# (name → (num_nodes, avg_degree, feature dim D, #classes, locality)).
# Full-size graphs do not fit a CPU CI loop; the generators keep the degree
# skew and local/remote edge mix that drive MGG's behaviour.  The real sizes
# are kept alongside for the analytical model / roofline extrapolations.
PAPER_DATASETS: Dict[str, Dict[str, float]] = {
    "reddit": dict(nodes=8192, avg_degree=48.0, dim=602, classes=41,
                   locality=0.30, real_nodes=232_965, real_edges=114_615_892),
    "enwiki": dict(nodes=16384, avg_degree=12.0, dim=96, classes=128,
                   locality=0.15, real_nodes=4_203_323, real_edges=202_623_226),
    "products": dict(nodes=12288, avg_degree=10.0, dim=100, classes=64,
                     locality=0.45, real_nodes=2_449_029, real_edges=61_859_140),
    "proteins": dict(nodes=6144, avg_degree=64.0, dim=128, classes=112,
                     locality=0.25, real_nodes=132_534, real_edges=39_561_252),
    "orkut": dict(nodes=16384, avg_degree=16.0, dim=128, classes=32,
                  locality=0.20, real_nodes=3_072_441, real_edges=117_185_083),
}


def paper_dataset(
    name: str, scale: float = 1.0, seed: int = 0
) -> Tuple[CSRGraph, Dict[str, float]]:
    """Return (graph, meta) for a scaled stand-in of a paper dataset."""
    meta = dict(PAPER_DATASETS[name])
    n = max(64, int(meta["nodes"] * scale))
    g = power_law(
        n,
        avg_degree=float(meta["avg_degree"]),
        locality=float(meta["locality"]),
        seed=seed,
    )
    meta["nodes"] = n
    return g, meta
