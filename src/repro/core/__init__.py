"""MGG core: the paper's contribution — pipeline-aware workload management,
hybrid data placement, pipelined ring aggregation, analytical autotuning,
and the full-graph GNN models built on top."""
from .graph import (CSRGraph, erdos_renyi, power_law, paper_dataset,
                    PAPER_DATASETS, neighbors_of, khop_in_frontier)
from .partition import (
    edge_balanced_node_split,
    locality_edge_split,
    neighbor_partitions,
    NeighborPartitions,
    VirtualGraphs,
)
from .placement import (
    AggregationPlan,
    SharedPartition,
    LayerPlan,
    build_partition,
    plan_from_partition,
    build_plan,
    build_layer_plans,
    build_bulk_plan,
    build_fetch_plan,
    pad_table,
    unpad_table,
    pad_embeddings,
    unpad_embeddings,
    pgas_rows,
)
from .pipeline import (
    mgg_aggregate,
    mgg_aggregate_sparse,
    topk_activation,
    topk_decompress,
    wire_index_dtype,
    block_neighbor_sum,
    bulk_aggregate,
    fetch_rows_aggregate,
    reference_aggregate,
    collective_bytes,
    sparse_collective_bytes,
)
from .autotune import (
    HardwareSpec,
    TPU_V5E,
    A100_NVSWITCH,
    estimate_latency,
    cross_iteration_optimize,
    WorkloadShape,
    layer_workload_shapes,
)
from .gnn import (GNNEngine, MODEL_ZOO, MODEL_STAGES, BLOCK_MODELS,
                  masked_cross_entropy, num_stages, apply_stage,
                  apply_from_stage, apply_blocks, aggregation_widths)
