"""MGG pipelined aggregation: shard_map + ppermute ring, double-buffered.

This is the paper's pipeline-centric kernel (§3.3–§3.4) re-expressed for TPU
(DESIGN.md §2).  Per chip, neighbor aggregation is split into

* a **local** pass over the chip's own embedding shard (paper: local virtual
  graph, full-HBM-bandwidth), and
* ``(n-1) · dist`` **ring steps**: each step aggregates one remote tile that
  arrived over ICI while the *previous* step's compute was running.  The loop
  body issues ``ppermute`` (tile *k+1*) and the gather+reduce for tile *k*
  with no data dependence between them — exactly the independence XLA's
  latency-hiding scheduler needs to overlap the DMA with compute.  This is
  the paper's Fig. 7(b) (async GET double-buffering) at ring-tile granularity.

The *interleave* flag reproduces §3.3's workload interleaving: local neighbor
partitions are spread across ring steps so every step carries both
latency-bound (remote) and compute-bound (local) work; ``interleave=False``
is the paper's Fig. 9(b) baseline.

The *fused update* path (``update_w``) additionally folds the dense ``·W``
update phase into the ring: each step's partial aggregate performs its own
``(P, D) @ (D, D_out)`` matmul before the scatter-add, so the update GEMM's
FLOPs — which otherwise run as a separate kernel after the ring drains —
overlap the in-flight ppermute of the next tile (the MaxK-GNN-style fused
aggregation+update kernel shape, expressed at ring-tile granularity).

Three baselines used throughout benchmarks:

* :func:`bulk_aggregate` — all-gather the full embedding table, then a purely
  local aggregation (the DGCL/NCCL pattern; paper §2.1, Table 4).
* :func:`fetch_rows_aggregate` — gather an explicit row set first, aggregate
  second, with a ``page_rows`` granularity knob.  ``page_rows=1`` models the
  Direct-NVSHMEM baseline (exact rows, no overlap; Table 1); larger values
  model UVM's page-granular migration with its wasted bandwidth (§2.2).
"""
from __future__ import annotations

import functools
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .placement import AggregationPlan

__all__ = [
    "mgg_aggregate",
    "mgg_aggregate_sparse",
    "mgg_aggregate_streamed",
    "mgg_aggregate_sparse_streamed",
    "topk_activation",
    "topk_decompress",
    "wire_index_dtype",
    "block_neighbor_sum",
    "bulk_aggregate",
    "fetch_rows_aggregate",
    "plan_device_arrays",
    "reference_aggregate",
    "collective_bytes",
    "sparse_collective_bytes",
]


# ---------------------------------------------------------------------------
# inner gather + reduce (the hot spot; Pallas kernel or jnp)
# ---------------------------------------------------------------------------

def _gather_sum(buf: jax.Array, nbrs: jax.Array, mask: jax.Array,
                use_kernel: bool, acc_dtype,
                pb: Optional[int] = None) -> jax.Array:
    """``out[p] = sum_j mask[p, j] * buf[nbrs[p, j]]`` → (P, D).

    The paper's warp-level gather+reduce.  ``use_kernel`` routes to the
    Pallas TPU kernel (kernels/neighbor_agg.py); the jnp path is the oracle
    and the CPU execution path.  ``pb`` (the paper's wpb knob) selects the
    partition-blocked kernel variant; the jnp path ignores it.
    """
    if use_kernel:
        from repro.kernels import ops as kops

        return kops.neighbor_gather_sum(buf, nbrs, mask, acc_dtype=acc_dtype,
                                        pb=pb)
    g = jnp.take(buf, nbrs, axis=0)  # (P, ps, D)
    return jnp.sum(
        g.astype(acc_dtype) * mask[..., None].astype(acc_dtype), axis=1
    )


def block_neighbor_sum(h_src: jax.Array, nbr: jax.Array, mask: jax.Array, *,
                       use_kernel: bool = False,
                       acc_dtype=jnp.float32) -> jax.Array:
    """Masked neighbor sum over one sampled block → ``(num_dst, D)``.

    ``h_src`` is the block's source embedding table; ``nbr``/``mask``
    are the fixed-shape ``(num_dst, fanout)`` tables from
    ``repro.sample`` whose padding slots point at local row
    ``num_src`` — a zero sentinel row appended here — so the sampled
    path rides the exact same masked gather-sum primitive (Pallas
    ``neighbor_gather_sum`` or the jnp oracle) as the full-graph ring.
    """
    sentinel = jnp.zeros((1, h_src.shape[1]), h_src.dtype)
    buf = jnp.concatenate([h_src, sentinel], axis=0)
    return _gather_sum(buf, nbr, mask, use_kernel, acc_dtype).astype(
        h_src.dtype)


# ---------------------------------------------------------------------------
# top-k activation compression (MaxK-GNN direction)
# ---------------------------------------------------------------------------

def topk_activation(x: jax.Array, k: int):
    """Keep the ``k`` largest entries per row: ``x → (values, col_idx)``.

    The compressed form is CSR-style with a *fixed* shape ``(N, k)`` —
    ``values[n, s] = x[n, col_idx[n, s]]`` — so jit caches stay warm across
    steps regardless of which columns survive.  ``lax.top_k`` guarantees the
    ``k`` column ids of a row are distinct, which is what makes
    :func:`topk_decompress` an exact (bitwise) inverse at ``k == D`` and
    order-independent for any ``k``.
    """
    values, idx = lax.top_k(x, k)
    return values, idx.astype(jnp.int32)


def wire_index_dtype(d_feat: int):
    """Narrowest integer dtype that can address a column of width ``d_feat``.

    The column-id half of the compressed payload travels the ring in this
    dtype: int16 covers every realistic feature width and keeps the wire
    cost of a ``(value, idx)`` pair at 6 bytes instead of 8.
    """
    return jnp.int16 if d_feat <= np.iinfo(np.int16).max else jnp.int32


def topk_decompress(values: jax.Array, idx: jax.Array, d_feat: int) -> jax.Array:
    """Inverse of :func:`topk_activation`: ``(N, k) → (N, d_feat)`` dense.

    Each row's column ids are distinct (a top-k guarantee), so every output
    slot is written at most once: the scatter is deterministic, bitwise
    invariant to any permutation of the compressed columns, and — at
    ``k == d_feat`` — an exact identity.
    """
    rows = values.shape[0]
    out = jnp.zeros((rows, d_feat), values.dtype)
    rr = jnp.arange(rows, dtype=jnp.int32)[:, None]
    return out.at[rr, idx.astype(jnp.int32)].set(values)


def _sparse_gather_sum(values: jax.Array, idx: jax.Array, nbrs: jax.Array,
                       mask: jax.Array, d_feat: int, use_kernel: bool,
                       acc_dtype, pb: Optional[int] = None) -> jax.Array:
    """Sparse analogue of :func:`_gather_sum` over compressed rows.

    ``use_kernel`` routes to the sparse Pallas kernel, which reads only the
    ``k`` live ``(value, col)`` pairs per neighbor row (the MaxK-GNN
    co-design); the jnp path decompresses the buffer once and reuses the
    dense oracle, so at ``k == d_feat`` it is bitwise-equal to the dense
    pipeline.
    """
    if use_kernel:
        from repro.kernels import ops as kops

        return kops.sparse_neighbor_gather_sum(
            values, idx, nbrs, mask, d_feat=d_feat, acc_dtype=acc_dtype)
    return _gather_sum(topk_decompress(values, idx, d_feat), nbrs, mask,
                       False, acc_dtype, pb)


def plan_device_arrays(plan: AggregationPlan) -> Dict[str, np.ndarray]:
    """The device-resident pytree of an :class:`AggregationPlan`."""
    return dict(
        local_nbrs=plan.local_nbrs,
        local_mask=plan.local_mask,
        local_targets=plan.local_targets,
        remote_nbrs=plan.remote_nbrs,
        remote_mask=plan.remote_mask,
        remote_targets=plan.remote_targets,
    )


def _plan_specs(axis_name: str) -> Dict[str, P]:
    return {k: P(axis_name) for k in (
        "local_nbrs", "local_mask", "local_targets",
        "remote_nbrs", "remote_mask", "remote_targets")}


# ---------------------------------------------------------------------------
# MGG pipelined ring aggregation
# ---------------------------------------------------------------------------

def mgg_aggregate(
    x: jax.Array,
    plan: AggregationPlan,
    mesh: Mesh,
    *,
    axis_name: str = "ring",
    interleave: bool = True,
    use_kernel: bool = False,
    acc_dtype=jnp.float32,
    pb: Optional[int] = None,
    update_w: Optional[jax.Array] = None,
) -> jax.Array:
    """Pipelined sum-aggregation: ``out[v] = Σ_{u ∈ N(v)} x[u]``.

    ``x`` is the padded PGAS embedding table ``(n_dev · rows_per_dev, D)``
    sharded by rows over ``axis_name`` (see placement.pad_embeddings); the
    output has the same layout/sharding.  ``pb`` is the paper's wpb knob:
    the partition-block height of the kernel variant (kernel path only).

    ``update_w`` (``(D, D_out)``, replicated) selects the **fused update**
    path: the output becomes ``(A x) @ W`` and each ring step performs its
    tile's partial ``·W`` matmul right after the gather+reduce, inside the
    same step that already issued the next tile's ppermute — so the update
    phase's MXU FLOPs overlap the next tile's ICI transfer instead of
    running as a separate post-ring matmul.  Because matmul distributes
    over the partial sums, ``Σ_s (partial_s @ W) == (Σ_s partial_s) @ W``
    exactly in reals; in floats the two paths differ only by summation
    order (tolerance-tested in tests/test_layer_plans.py).
    """
    n_dev, dist, tile_rows = plan.n_dev, plan.dist, plan.tile_rows
    arrays = jax.tree.map(jnp.asarray, plan_device_arrays(plan))

    body = functools.partial(
        _mgg_shard_body,
        axis_name=axis_name,
        n_dev=n_dev,
        dist=dist,
        tile_rows=tile_rows,
        interleave=interleave,
        use_kernel=use_kernel,
        acc_dtype=acc_dtype,
        pb=pb,
        fused=update_w is not None,
    )
    in_specs = [P(axis_name), _plan_specs(axis_name)]
    args = [x, arrays]
    if update_w is not None:
        in_specs.append(P(None, None))  # replicated update weight
        args.append(update_w)
    fn = jax.shard_map(
        body,
        mesh=mesh,
        in_specs=tuple(in_specs),
        out_specs=P(axis_name),
        # Pallas calls inside the body produce vma-less ShapeDtypeStructs;
        # skip the varying-manual-axes check (correctness is oracle-tested).
        check_vma=False,
    )
    return fn(*args)


def _mgg_shard_body(
    x, arrays, w=None, *, axis_name, n_dev, dist, tile_rows, interleave,
    use_kernel, acc_dtype, pb=None, fused=False,
):
    # Per-device blocks: squeeze the device-major axis.
    l_nbrs = arrays["local_nbrs"][0]        # (PL, ps)
    l_mask = arrays["local_mask"][0]
    l_tgt = arrays["local_targets"][0]      # (PL,)
    r_nbrs = arrays["remote_nbrs"][0]       # (S, PR, ps)
    r_mask = arrays["remote_mask"][0]
    r_tgt = arrays["remote_targets"][0]     # (S, PR)

    rows, d_feat = x.shape
    if fused:
        wacc = w.astype(acc_dtype)
        d_out = wacc.shape[1]
        # Fused update: every partial aggregate does its ·W matmul before
        # the scatter-add, so the MXU work lands inside the ring step whose
        # next-tile ppermute is already in flight.
        update = lambda partial: partial @ wacc
    else:
        d_out = d_feat
        update = lambda partial: partial
    # Mark the accumulator as device-varying so it can be carried through the
    # ring fori_loop (shard_map vma typing).
    out = jnp.zeros((rows, d_out), acc_dtype)
    if hasattr(lax, "pcast"):
        out = lax.pcast(out, (axis_name,), to="varying")
    else:  # older jax
        out = lax.pvary(out, (axis_name,))
    n_steps = r_nbrs.shape[0] if n_dev > 1 else 0

    # ---- local work scheduling (paper §3.3 interleaving) -------------------
    if interleave and n_steps > 0:
        pl_total = l_nbrs.shape[0]
        ls = -(-pl_total // n_steps)  # ceil: local partitions per ring step
        pad = ls * n_steps - pl_total
        l_nbrs_s = jnp.pad(l_nbrs, ((0, pad), (0, 0))).reshape(n_steps, ls, -1)
        l_mask_s = jnp.pad(l_mask, ((0, pad), (0, 0))).reshape(n_steps, ls, -1)
        l_tgt_s = jnp.pad(l_tgt, ((0, pad),)).reshape(n_steps, ls)
    else:
        # Paper Fig. 9(b) baseline: all local partitions up front, then the
        # (non-overlapped-with-local) remote rounds.
        out = out.at[l_tgt].add(
            update(_gather_sum(x, l_nbrs, l_mask, use_kernel, acc_dtype, pb))
        )

    if n_dev == 1:
        return out.astype(x.dtype)

    perm = [(i, (i + 1) % n_dev) for i in range(n_dev)]
    tiles = x.reshape(dist, tile_rows, d_feat)

    def step_work(out, cur, idx):
        """Aggregate remote tile `cur` for ring step `idx` (+ its local slice)."""
        nbrs = lax.dynamic_index_in_dim(r_nbrs, idx, 0, keepdims=False)
        mask = lax.dynamic_index_in_dim(r_mask, idx, 0, keepdims=False)
        tgt = lax.dynamic_index_in_dim(r_tgt, idx, 0, keepdims=False)
        out = out.at[tgt].add(
            update(_gather_sum(cur, nbrs, mask, use_kernel, acc_dtype, pb)))
        if interleave:
            ln = lax.dynamic_index_in_dim(l_nbrs_s, idx, 0, keepdims=False)
            lm = lax.dynamic_index_in_dim(l_mask_s, idx, 0, keepdims=False)
            lt = lax.dynamic_index_in_dim(l_tgt_s, idx, 0, keepdims=False)
            out = out.at[lt].add(
                update(_gather_sum(x, ln, lm, use_kernel, acc_dtype, pb)))
        return out

    # One double-buffered ring per tile chunk (chunk-major, so every chunk
    # performs exactly n_dev - 1 permutes — no wasted trailing rotation).
    for c in range(dist):
        cur = lax.ppermute(tiles[c], axis_name, perm)  # rotation 1 (prologue)

        def body(k, carry, c=c):
            cur, out = carry
            nxt = lax.ppermute(cur, axis_name, perm)  # rotation k+2 — no dep
            out = step_work(out, cur, k * dist + c)   # on the aggregation ⇒
            return (nxt, out)                          # XLA overlaps DMA+compute

        cur, out = lax.fori_loop(0, n_dev - 2, body, (cur, out))
        out = step_work(out, cur, (n_dev - 2) * dist + c)  # epilogue (drain)

    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MGG sparse ring aggregation: top-k compressed payload on the wire
# ---------------------------------------------------------------------------

def mgg_aggregate_sparse(
    x: jax.Array,
    plan: AggregationPlan,
    mesh: Mesh,
    *,
    k: int,
    axis_name: str = "ring",
    interleave: bool = True,
    use_kernel: bool = False,
    acc_dtype=jnp.float32,
    pb: Optional[int] = None,
    update_w: Optional[jax.Array] = None,
) -> jax.Array:
    """Sparse-payload variant of :func:`mgg_aggregate`.

    ``x`` is first compressed row-wise with :func:`topk_activation`; the
    ring then ppermutes the ``(values, col_idx)`` pair — ``k · (4 + 2)``
    bytes per row instead of ``D · 4`` — and every step decompresses its
    arriving tile *inside* the step before the same fixed-order masked
    gather+reduce the dense path runs.  The schedule (chunk-major rings,
    interleaved local slices, fused ``·W``) is byte-for-byte the dense
    one's, so:

    * at ``k == D`` the output is **bitwise-equal** to dense
      :func:`mgg_aggregate` (decompression is an exact inverse);
    * at ``k < D`` the output is the deterministic aggregation of the
      top-k-sparsified features — an accuracy/speed trade the caller (the
      tuner's ``k_space``) opts into explicitly.
    """
    n_dev, dist, tile_rows = plan.n_dev, plan.dist, plan.tile_rows
    d_feat = x.shape[1]
    k = int(min(k, d_feat))
    values, idx = topk_activation(x, k)
    idx = idx.astype(wire_index_dtype(d_feat))
    arrays = jax.tree.map(jnp.asarray, plan_device_arrays(plan))

    body = functools.partial(
        _mgg_sparse_shard_body,
        axis_name=axis_name,
        n_dev=n_dev,
        dist=dist,
        tile_rows=tile_rows,
        d_feat=d_feat,
        interleave=interleave,
        use_kernel=use_kernel,
        acc_dtype=acc_dtype,
        pb=pb,
        fused=update_w is not None,
    )
    in_specs = [P(axis_name), P(axis_name), _plan_specs(axis_name)]
    args = [values, idx, arrays]
    if update_w is not None:
        in_specs.append(P(None, None))  # replicated update weight
        args.append(update_w)
    fn = jax.shard_map(
        body,
        mesh=mesh,
        in_specs=tuple(in_specs),
        out_specs=P(axis_name),
        check_vma=False,
    )
    return fn(*args)


def _mgg_sparse_shard_body(
    values, idx, arrays, w=None, *, axis_name, n_dev, dist, tile_rows,
    d_feat, interleave, use_kernel, acc_dtype, pb=None, fused=False,
):
    """Mirror of :func:`_mgg_shard_body` over the compressed payload."""
    l_nbrs = arrays["local_nbrs"][0]        # (PL, ps)
    l_mask = arrays["local_mask"][0]
    l_tgt = arrays["local_targets"][0]      # (PL,)
    r_nbrs = arrays["remote_nbrs"][0]       # (S, PR, ps)
    r_mask = arrays["remote_mask"][0]
    r_tgt = arrays["remote_targets"][0]     # (S, PR)

    rows, k = values.shape
    if fused:
        wacc = w.astype(acc_dtype)
        d_out = wacc.shape[1]
        update = lambda partial: partial @ wacc
    else:
        d_out = d_feat
        update = lambda partial: partial
    gather = lambda v, i, nb, mk: _sparse_gather_sum(
        v, i, nb, mk, d_feat, use_kernel, acc_dtype, pb)
    out = jnp.zeros((rows, d_out), acc_dtype)
    if hasattr(lax, "pcast"):
        out = lax.pcast(out, (axis_name,), to="varying")
    else:  # older jax
        out = lax.pvary(out, (axis_name,))
    n_steps = r_nbrs.shape[0] if n_dev > 1 else 0

    if interleave and n_steps > 0:
        pl_total = l_nbrs.shape[0]
        ls = -(-pl_total // n_steps)  # ceil: local partitions per ring step
        pad = ls * n_steps - pl_total
        l_nbrs_s = jnp.pad(l_nbrs, ((0, pad), (0, 0))).reshape(n_steps, ls, -1)
        l_mask_s = jnp.pad(l_mask, ((0, pad), (0, 0))).reshape(n_steps, ls, -1)
        l_tgt_s = jnp.pad(l_tgt, ((0, pad),)).reshape(n_steps, ls)
    else:
        out = out.at[l_tgt].add(update(gather(values, idx, l_nbrs, l_mask)))

    if n_dev == 1:
        return out.astype(values.dtype)

    perm = [(i, (i + 1) % n_dev) for i in range(n_dev)]
    v_tiles = values.reshape(dist, tile_rows, k)
    i_tiles = idx.reshape(dist, tile_rows, k)

    def step_work(out, cur_v, cur_i, step):
        nbrs = lax.dynamic_index_in_dim(r_nbrs, step, 0, keepdims=False)
        mask = lax.dynamic_index_in_dim(r_mask, step, 0, keepdims=False)
        tgt = lax.dynamic_index_in_dim(r_tgt, step, 0, keepdims=False)
        out = out.at[tgt].add(update(gather(cur_v, cur_i, nbrs, mask)))
        if interleave:
            ln = lax.dynamic_index_in_dim(l_nbrs_s, step, 0, keepdims=False)
            lm = lax.dynamic_index_in_dim(l_mask_s, step, 0, keepdims=False)
            lt = lax.dynamic_index_in_dim(l_tgt_s, step, 0, keepdims=False)
            out = out.at[lt].add(update(gather(values, idx, ln, lm)))
        return out

    # Same chunk-major double-buffered rings as the dense body — only the
    # payload narrows: both halves of the compressed pair ride each rotation.
    for c in range(dist):
        cur_v = lax.ppermute(v_tiles[c], axis_name, perm)
        cur_i = lax.ppermute(i_tiles[c], axis_name, perm)

        def body(s, carry, c=c):
            cur_v, cur_i, out = carry
            nxt_v = lax.ppermute(cur_v, axis_name, perm)  # rotation s+2
            nxt_i = lax.ppermute(cur_i, axis_name, perm)  # — no dep on the
            out = step_work(out, cur_v, cur_i, s * dist + c)  # aggregation
            return (nxt_v, nxt_i, out)

        cur_v, cur_i, out = lax.fori_loop(
            0, n_dev - 2, body, (cur_v, cur_i, out))
        out = step_work(out, cur_v, cur_i, (n_dev - 2) * dist + c)

    return out.astype(values.dtype)


# ---------------------------------------------------------------------------
# MGG streamed aggregation: tiered features, host→device prefetch in the ring
# ---------------------------------------------------------------------------

def mgg_aggregate_streamed(
    fetch_chunk,
    plan: AggregationPlan,
    mesh: Mesh,
    *,
    axis_name: str = "ring",
    use_kernel: bool = False,
    acc_dtype=jnp.float32,
    pb: Optional[int] = None,
    update_w: Optional[jax.Array] = None,
    stats: Optional[dict] = None,
    tracer=None,
) -> jax.Array:
    """Pipelined aggregation over *partial-resident* features.

    ``fetch_chunk(c)`` supplies ring chunk ``c`` on demand — the
    ``(n_dev · tile_rows, D)`` array holding every device's chunk-``c``
    tile (see :meth:`repro.store.TieredFeatures.device_chunk`, which
    sources rows from the device hot cache or a host-side gather).  The
    schedule is the double-buffered prefetch of the tentpole:

    1. fetch chunk 0 (pipeline fill — the one gather nothing can hide);
    2. for each chunk ``c``: dispatch chunk ``c``'s remote ring
       asynchronously, then immediately call ``fetch_chunk(c + 1)`` —
       the host row gather and ``device_put`` upload for tile *i+1* run
       while tile *i*'s ppermute ring is in flight (same independence
       the in-ring double buffer gives XLA, lifted to the host side);
    3. once every chunk is resident, run the local pass over the
       assembled shard and sum: ``out = local + Σ_c ring_c``.

    The sum order is fixed (local first, then chunks in order), so the
    result is **deterministic and independent of row sourcing**: any
    capacity — including all-resident — produces bitwise-identical
    output through this path.  Against :func:`mgg_aggregate` the result
    differs only by scatter-add accumulation order (tolerance-tested);
    there is no ``interleave`` knob here because the local pass cannot
    start before the last chunk lands.

    ``stats`` (optional dict) is updated in place with prefetch
    accounting: ``prefetch_issued`` counts fetches issued while the
    previous chunk's ring was already dispatched (structural overlap,
    ``dist - 1`` per call), ``prefetch_inflight`` counts those where the
    ring result was verifiably still unrealized when the fetch returned.

    ``tracer`` (optional :class:`repro.obs.Tracer`) records the ring-step
    timeline: per-chunk ``mgg.stream.fetch`` / ``mgg.stream.ring`` spans,
    the assembled local pass, and an explicit drain wait, rolled up into
    an ``mgg.stream.aggregate`` span whose ``overlap_efficiency`` arg is
    ``1 − exposed_comm / total`` (exposed = pipeline-fill fetch + drain —
    the transfer time nothing overlaps).  Only span bookkeeping differs
    with tracing on: the output value is identical, and the enabled path
    adds one extra ``block_until_ready`` that the caller's own drain would
    otherwise pay.
    """
    n_dev, dist, tile_rows = plan.n_dev, plan.dist, plan.tile_rows
    arrays = jax.tree.map(jnp.asarray, plan_device_arrays(plan))
    if stats is not None:
        stats.setdefault("prefetch_issued", 0)
        stats.setdefault("prefetch_inflight", 0)
    tracing = tracer is not None and tracer.enabled

    fused = update_w is not None
    extra = (update_w,) if fused else ()
    chunks = []
    partials = []
    if tracing:
        t_start = tracer.now()
        t0 = tracer.now()
        cur = fetch_chunk(0)
        t_fill = tracer.now() - t0             # pipeline fill (not hidden)
        tracer.complete("mgg.stream.fetch", t0, t0 + t_fill,
                        cat="mgg", args={"chunk": 0, "fill": True})
    else:
        cur = fetch_chunk(0)                   # pipeline fill (not hidden)
    for c in range(dist):
        chunks.append(cur)
        if n_dev > 1:
            # dispatched asynchronously: returns before the ring executes
            ring = _streamed_ring_fn(mesh, axis_name, n_dev, dist, c,
                                     use_kernel, acc_dtype, pb, fused)
            if tracing:
                with tracer.span("mgg.stream.ring", cat="mgg", chunk=c,
                                 dist=dist, n_dev=n_dev):
                    partials.append(ring(cur, arrays, *extra))
            else:
                partials.append(ring(cur, arrays, *extra))
        if c + 1 < dist:
            # host gather + upload for tile c+1 overlaps ring c in flight
            if tracing:
                with tracer.span("mgg.stream.fetch", cat="mgg",
                                 chunk=c + 1, fill=False):
                    cur = fetch_chunk(c + 1)
            else:
                cur = fetch_chunk(c + 1)
            if stats is not None:
                stats["prefetch_issued"] += 1
                last = partials[-1] if partials else None
                if last is not None and hasattr(last, "is_ready") \
                        and not last.is_ready():
                    stats["prefetch_inflight"] += 1

    x_full = _streamed_assemble_fn(mesh, axis_name, n_dev, dist)(*chunks)
    local = _streamed_local_fn(mesh, axis_name, use_kernel, acc_dtype, pb,
                               fused)
    if tracing:
        with tracer.span("mgg.stream.local", cat="mgg", dist=dist):
            out = local(x_full, arrays, *extra)
    else:
        out = local(x_full, arrays, *extra)
    for p in partials:                         # fixed order ⇒ deterministic
        out = out + p
    out = out.astype(chunks[0].dtype)
    if tracing:
        # drain: the wait nothing overlaps.  block_until_ready changes
        # only when the host observes completion, never the values.
        t0 = tracer.now()
        jax.block_until_ready(out)
        t_drain = tracer.now() - t0
        tracer.complete("mgg.stream.drain", t0, t0 + t_drain, cat="mgg")
        total = tracer.now() - t_start
        exposed = t_fill + t_drain
        overlap = max(0.0, 1.0 - exposed / total) if total > 0 else 0.0
        tracer.complete("mgg.stream.aggregate", t_start, t_start + total,
                        cat="mgg",
                        args={"dist": dist, "n_dev": n_dev,
                              "overlap_efficiency": overlap,
                              "exposed_s": exposed, "total_s": total})
        if stats is not None:
            stats["overlap_efficiency"] = overlap
    return out


# The streamed entry point is called once per chunk per aggregation, so —
# unlike mgg_aggregate, whose single shard_map is traced per call — its
# compiled pieces are memoized on their static configuration (the arrays
# pytree and tiles are traced arguments, so one cache entry serves every
# plan with the same shapes).

@functools.lru_cache(maxsize=None)
def _streamed_ring_fn(mesh, axis_name, n_dev, dist, chunk, use_kernel,
                      acc_dtype, pb, fused):
    body = functools.partial(
        _streamed_chunk_body, axis_name=axis_name, n_dev=n_dev, dist=dist,
        chunk=chunk, use_kernel=use_kernel, acc_dtype=acc_dtype, pb=pb,
        fused=fused,
    )
    in_specs = [P(axis_name), _plan_specs(axis_name)]
    if fused:
        in_specs.append(P(None, None))
    # jit the shard_map: a bare shard_map re-traces on every call, and
    # the streamed path issues dist of these per aggregation
    return jax.jit(jax.shard_map(body, mesh=mesh, in_specs=tuple(in_specs),
                                 out_specs=P(axis_name), check_vma=False))


@functools.lru_cache(maxsize=None)
def _streamed_local_fn(mesh, axis_name, use_kernel, acc_dtype, pb, fused):
    body = functools.partial(
        _streamed_local_body, axis_name=axis_name, use_kernel=use_kernel,
        acc_dtype=acc_dtype, pb=pb, fused=fused,
    )
    in_specs = [P(axis_name), _plan_specs(axis_name)]
    if fused:
        in_specs.append(P(None, None))
    return jax.jit(jax.shard_map(body, mesh=mesh, in_specs=tuple(in_specs),
                                 out_specs=P(axis_name), check_vma=False))


@functools.lru_cache(maxsize=None)
def _streamed_assemble_fn(mesh, axis_name, n_dev, dist):
    """Chunk arrays → the full shard (chunk-minor → row-major per device)."""
    def assemble(*chs):
        tile_rows = chs[0].shape[0] // n_dev
        st = jnp.stack(chs, axis=0)            # (dist, n_dev·tile, D)
        st = st.reshape(dist, n_dev, tile_rows, -1).transpose(1, 0, 2, 3)
        return st.reshape(n_dev * dist * tile_rows, -1)

    return jax.jit(assemble,
                   out_shardings=NamedSharding(mesh, P(axis_name)))


def _streamed_step(out, cur, idx, r_nbrs, r_mask, r_tgt, update,
                   use_kernel, acc_dtype, pb):
    nbrs = lax.dynamic_index_in_dim(r_nbrs, idx, 0, keepdims=False)
    mask = lax.dynamic_index_in_dim(r_mask, idx, 0, keepdims=False)
    tgt = lax.dynamic_index_in_dim(r_tgt, idx, 0, keepdims=False)
    return out.at[tgt].add(
        update(_gather_sum(cur, nbrs, mask, use_kernel, acc_dtype, pb)))


def _streamed_init(w, d_feat, acc_dtype, fused):
    """(update fn, output width) — fused folds the ·W matmul into every
    partial aggregate, exactly as in :func:`mgg_aggregate`."""
    if fused:
        wacc = w.astype(acc_dtype)
        return (lambda partial: partial @ wacc), int(wacc.shape[1])
    return (lambda partial: partial), d_feat


def _streamed_chunk_body(tile, arrays, w=None, *, axis_name, n_dev, dist,
                         chunk, use_kernel, acc_dtype, pb=None, fused=False):
    """One chunk's remote ring: only the steps ``s`` with
    ``s % dist == chunk`` — i.e. the rotations of this chunk's tile."""
    r_nbrs = arrays["remote_nbrs"][0]       # (S, PR, ps)
    r_mask = arrays["remote_mask"][0]
    r_tgt = arrays["remote_targets"][0]
    rows = dist * tile.shape[0]             # shard height = dist · tile_rows
    update, d_out = _streamed_init(w, tile.shape[1], acc_dtype, fused)
    out = jnp.zeros((rows, d_out), acc_dtype)
    if hasattr(lax, "pcast"):
        out = lax.pcast(out, (axis_name,), to="varying")
    else:  # older jax
        out = lax.pvary(out, (axis_name,))

    perm = [(i, (i + 1) % n_dev) for i in range(n_dev)]
    cur = lax.ppermute(tile, axis_name, perm)  # rotation 1 (prologue)

    def body(k, carry):
        cur, out = carry
        nxt = lax.ppermute(cur, axis_name, perm)   # rotation k+2 — no dep
        out = _streamed_step(out, cur, k * dist + chunk, r_nbrs, r_mask,
                             r_tgt, update, use_kernel, acc_dtype, pb)
        return (nxt, out)

    cur, out = lax.fori_loop(0, n_dev - 2, body, (cur, out))
    out = _streamed_step(out, cur, (n_dev - 2) * dist + chunk, r_nbrs,
                         r_mask, r_tgt, update, use_kernel, acc_dtype, pb)
    return out


def _streamed_local_body(x, arrays, w=None, *, axis_name, use_kernel,
                         acc_dtype, pb=None, fused=False):
    """The local pass over the fully assembled shard (runs last)."""
    l_nbrs = arrays["local_nbrs"][0]
    l_mask = arrays["local_mask"][0]
    l_tgt = arrays["local_targets"][0]
    update, d_out = _streamed_init(w, x.shape[1], acc_dtype, fused)
    out = jnp.zeros((x.shape[0], d_out), acc_dtype)
    if hasattr(lax, "pcast"):
        out = lax.pcast(out, (axis_name,), to="varying")
    else:  # older jax
        out = lax.pvary(out, (axis_name,))
    return out.at[l_tgt].add(
        update(_gather_sum(x, l_nbrs, l_mask, use_kernel, acc_dtype, pb)))


# ---------------------------------------------------------------------------
# MGG sparse streamed aggregation: compressed wire + tiered host features
# ---------------------------------------------------------------------------

def mgg_aggregate_sparse_streamed(
    fetch_chunk,
    plan: AggregationPlan,
    mesh: Mesh,
    *,
    k: int,
    axis_name: str = "ring",
    use_kernel: bool = False,
    acc_dtype=jnp.float32,
    pb: Optional[int] = None,
    update_w: Optional[jax.Array] = None,
    stats: Optional[dict] = None,
    tracer=None,
) -> jax.Array:
    """Sparse-payload variant of :func:`mgg_aggregate_streamed`.

    ``fetch_chunk`` keeps the dense contract (it sources rows from the
    tiered store); each chunk is compressed on device with
    :func:`topk_activation` right after it lands, so the ring rotations —
    the part the host prefetch overlaps — carry the narrow
    ``(values, col_idx)`` pair.  The local pass runs over the assembled
    compressed shard.  Sum order matches the dense streamed path exactly,
    so at ``k == D`` the output is bitwise-equal to
    :func:`mgg_aggregate_streamed` at any cache capacity.
    """
    n_dev, dist, tile_rows = plan.n_dev, plan.dist, plan.tile_rows
    arrays = jax.tree.map(jnp.asarray, plan_device_arrays(plan))
    if stats is not None:
        stats.setdefault("prefetch_issued", 0)
        stats.setdefault("prefetch_inflight", 0)
    tracing = tracer is not None and tracer.enabled

    fused = update_w is not None
    extra = (update_w,) if fused else ()
    v_chunks = []
    i_chunks = []
    partials = []
    compress = None
    d_feat = None

    def _land(chunk):
        """Compress a freshly fetched dense chunk on device."""
        nonlocal compress, d_feat
        if compress is None:
            d_feat = int(chunk.shape[1])
            wire = jnp.dtype(wire_index_dtype(d_feat)).name
            compress = _sparse_compress_fn(mesh, axis_name,
                                           int(min(k, d_feat)), wire)
        return compress(chunk)

    if tracing:
        t_start = tracer.now()
        t0 = tracer.now()
        cur = _land(fetch_chunk(0))
        t_fill = tracer.now() - t0             # pipeline fill (not hidden)
        tracer.complete("mgg.stream.fetch", t0, t0 + t_fill,
                        cat="mgg", args={"chunk": 0, "fill": True})
    else:
        cur = _land(fetch_chunk(0))            # pipeline fill (not hidden)
    for c in range(dist):
        v_chunks.append(cur[0])
        i_chunks.append(cur[1])
        if n_dev > 1:
            ring = _sparse_streamed_ring_fn(
                mesh, axis_name, n_dev, dist, c, d_feat,
                use_kernel, acc_dtype, pb, fused)
            if tracing:
                with tracer.span("mgg.stream.ring", cat="mgg", chunk=c,
                                 dist=dist, n_dev=n_dev, sparse_k=k):
                    partials.append(ring(cur[0], cur[1], arrays, *extra))
            else:
                partials.append(ring(cur[0], cur[1], arrays, *extra))
        if c + 1 < dist:
            if tracing:
                with tracer.span("mgg.stream.fetch", cat="mgg",
                                 chunk=c + 1, fill=False):
                    cur = _land(fetch_chunk(c + 1))
            else:
                cur = _land(fetch_chunk(c + 1))
            if stats is not None:
                stats["prefetch_issued"] += 1
                last = partials[-1] if partials else None
                if last is not None and hasattr(last, "is_ready") \
                        and not last.is_ready():
                    stats["prefetch_inflight"] += 1

    assemble = _streamed_assemble_fn(mesh, axis_name, n_dev, dist)
    v_full = assemble(*v_chunks)
    i_full = assemble(*i_chunks)
    local = _sparse_streamed_local_fn(mesh, axis_name, d_feat, use_kernel,
                                      acc_dtype, pb, fused)
    if tracing:
        with tracer.span("mgg.stream.local", cat="mgg", dist=dist):
            out = local(v_full, i_full, arrays, *extra)
    else:
        out = local(v_full, i_full, arrays, *extra)
    for p in partials:                         # fixed order ⇒ deterministic
        out = out + p
    out = out.astype(v_chunks[0].dtype)
    if tracing:
        t0 = tracer.now()
        jax.block_until_ready(out)
        t_drain = tracer.now() - t0
        tracer.complete("mgg.stream.drain", t0, t0 + t_drain, cat="mgg")
        total = tracer.now() - t_start
        exposed = t_fill + t_drain
        overlap = max(0.0, 1.0 - exposed / total) if total > 0 else 0.0
        tracer.complete("mgg.stream.aggregate", t_start, t_start + total,
                        cat="mgg",
                        args={"dist": dist, "n_dev": n_dev, "sparse_k": k,
                              "overlap_efficiency": overlap,
                              "exposed_s": exposed, "total_s": total})
        if stats is not None:
            stats["overlap_efficiency"] = overlap
    return out


@functools.lru_cache(maxsize=None)
def _sparse_compress_fn(mesh, axis_name, k, wire_dtype_name):
    """jitted per-chunk row-wise top-k compression, sharding-preserving."""
    def compress(chunk):
        values, idx = lax.top_k(chunk, k)
        return values, idx.astype(jnp.dtype(wire_dtype_name))

    sharding = NamedSharding(mesh, P(axis_name))
    return jax.jit(compress, out_shardings=(sharding, sharding))


@functools.lru_cache(maxsize=None)
def _sparse_streamed_ring_fn(mesh, axis_name, n_dev, dist, chunk, d_feat,
                             use_kernel, acc_dtype, pb, fused):
    body = functools.partial(
        _sparse_streamed_chunk_body, axis_name=axis_name, n_dev=n_dev,
        dist=dist, chunk=chunk, d_feat=d_feat, use_kernel=use_kernel,
        acc_dtype=acc_dtype, pb=pb, fused=fused,
    )
    in_specs = [P(axis_name), P(axis_name), _plan_specs(axis_name)]
    if fused:
        in_specs.append(P(None, None))
    return jax.jit(jax.shard_map(body, mesh=mesh, in_specs=tuple(in_specs),
                                 out_specs=P(axis_name), check_vma=False))


@functools.lru_cache(maxsize=None)
def _sparse_streamed_local_fn(mesh, axis_name, d_feat, use_kernel, acc_dtype,
                              pb, fused):
    body = functools.partial(
        _sparse_streamed_local_body, axis_name=axis_name, d_feat=d_feat,
        use_kernel=use_kernel, acc_dtype=acc_dtype, pb=pb, fused=fused,
    )
    in_specs = [P(axis_name), P(axis_name), _plan_specs(axis_name)]
    if fused:
        in_specs.append(P(None, None))
    return jax.jit(jax.shard_map(body, mesh=mesh, in_specs=tuple(in_specs),
                                 out_specs=P(axis_name), check_vma=False))


def _sparse_streamed_chunk_body(v_tile, i_tile, arrays, w=None, *, axis_name,
                                n_dev, dist, chunk, d_feat, use_kernel,
                                acc_dtype, pb=None, fused=False):
    """One chunk's remote ring over the compressed payload."""
    r_nbrs = arrays["remote_nbrs"][0]       # (S, PR, ps)
    r_mask = arrays["remote_mask"][0]
    r_tgt = arrays["remote_targets"][0]
    rows = dist * v_tile.shape[0]           # shard height = dist · tile_rows
    update, d_out = _streamed_init(w, d_feat, acc_dtype, fused)
    out = jnp.zeros((rows, d_out), acc_dtype)
    if hasattr(lax, "pcast"):
        out = lax.pcast(out, (axis_name,), to="varying")
    else:  # older jax
        out = lax.pvary(out, (axis_name,))

    def step(out, cur_v, cur_i, idx):
        nbrs = lax.dynamic_index_in_dim(r_nbrs, idx, 0, keepdims=False)
        mask = lax.dynamic_index_in_dim(r_mask, idx, 0, keepdims=False)
        tgt = lax.dynamic_index_in_dim(r_tgt, idx, 0, keepdims=False)
        return out.at[tgt].add(update(_sparse_gather_sum(
            cur_v, cur_i, nbrs, mask, d_feat, use_kernel, acc_dtype, pb)))

    perm = [(i, (i + 1) % n_dev) for i in range(n_dev)]
    cur_v = lax.ppermute(v_tile, axis_name, perm)  # rotation 1 (prologue)
    cur_i = lax.ppermute(i_tile, axis_name, perm)

    def body(s, carry):
        cur_v, cur_i, out = carry
        nxt_v = lax.ppermute(cur_v, axis_name, perm)   # rotation s+2 — no dep
        nxt_i = lax.ppermute(cur_i, axis_name, perm)
        out = step(out, cur_v, cur_i, s * dist + chunk)
        return (nxt_v, nxt_i, out)

    cur_v, cur_i, out = lax.fori_loop(0, n_dev - 2, body,
                                      (cur_v, cur_i, out))
    out = step(out, cur_v, cur_i, (n_dev - 2) * dist + chunk)
    return out


def _sparse_streamed_local_body(values, idx, arrays, w=None, *, axis_name,
                                d_feat, use_kernel, acc_dtype, pb=None,
                                fused=False):
    """The local pass over the assembled compressed shard (runs last)."""
    l_nbrs = arrays["local_nbrs"][0]
    l_mask = arrays["local_mask"][0]
    l_tgt = arrays["local_targets"][0]
    update, d_out = _streamed_init(w, d_feat, acc_dtype, fused)
    out = jnp.zeros((values.shape[0], d_out), acc_dtype)
    if hasattr(lax, "pcast"):
        out = lax.pcast(out, (axis_name,), to="varying")
    else:  # older jax
        out = lax.pvary(out, (axis_name,))
    return out.at[l_tgt].add(update(_sparse_gather_sum(
        values, idx, l_nbrs, l_mask, d_feat, use_kernel, acc_dtype, pb)))


# ---------------------------------------------------------------------------
# Baseline 1: bulk all-gather + local aggregation (DGCL / NCCL pattern)
# ---------------------------------------------------------------------------

def bulk_aggregate(
    x: jax.Array,
    bulk_nbrs: np.ndarray,   # (n_dev, P, ps) offsets into the padded table
    bulk_mask: np.ndarray,
    bulk_targets: np.ndarray,  # (n_dev, P)
    rows_per_dev: int,
    mesh: Mesh,
    *,
    axis_name: str = "ring",
    use_kernel: bool = False,
    acc_dtype=jnp.float32,
) -> jax.Array:
    """All-gather the entire table first, aggregate second (no overlap)."""

    def body(x, nbrs, mask, tgt):
        full = lax.all_gather(x, axis_name, axis=0, tiled=True)
        out = jnp.zeros((x.shape[0], x.shape[1]), acc_dtype)
        out = out.at[tgt[0]].add(
            _gather_sum(full, nbrs[0], mask[0], use_kernel, acc_dtype)
        )
        return out.astype(x.dtype)

    fn = jax.shard_map(
        body,
        mesh=mesh,
        in_specs=(P(axis_name), P(axis_name), P(axis_name), P(axis_name)),
        out_specs=P(axis_name),
        check_vma=False,
    )
    return fn(x, jnp.asarray(bulk_nbrs), jnp.asarray(bulk_mask),
              jnp.asarray(bulk_targets))


# ---------------------------------------------------------------------------
# Baseline 2: fetch-then-aggregate with a granularity knob (UVM / Direct)
# ---------------------------------------------------------------------------

def fetch_rows_aggregate(
    x: jax.Array,
    fetch_rows: np.ndarray,   # (n_dev, F) padded-global row ids to fetch
    nbrs: np.ndarray,         # (n_dev, P, ps) offsets into the fetched buffer
    mask: np.ndarray,
    targets: np.ndarray,      # (n_dev, P)
    out_rows: int,
    *,
    use_kernel: bool = False,
    acc_dtype=jnp.float32,
) -> jax.Array:
    """Gather ``fetch_rows`` from the global table, then aggregate locally.

    Cost-model baseline (single-program execution): with exact rows this is
    the Direct-NVSHMEM pattern of Table 1; with page-expanded rows it is the
    UVM pattern of §2.2 — the gather volume, not the aggregation math,
    changes.  No communication/computation overlap by construction.
    """

    def per_dev(rows_ids, nb, mk, tg):
        buf = jnp.take(x, rows_ids, axis=0)
        partial = _gather_sum(buf, nb, mk, use_kernel, acc_dtype)
        out = jnp.zeros((out_rows, x.shape[1]), acc_dtype)
        return out.at[tg].add(partial).astype(x.dtype)

    return jax.vmap(per_dev)(
        jnp.asarray(fetch_rows), jnp.asarray(nbrs), jnp.asarray(mask),
        jnp.asarray(targets),
    )


# ---------------------------------------------------------------------------
# Oracle + analytical terms
# ---------------------------------------------------------------------------

def reference_aggregate(indptr: np.ndarray, indices: np.ndarray,
                        x: np.ndarray) -> np.ndarray:
    """Dense oracle: ``out[v] = Σ_{u ∈ N(v)} x[u]`` (float64 accumulation)."""
    out = np.zeros_like(x, dtype=np.float64)
    deg = np.diff(indptr)
    row_ids = np.repeat(np.arange(x.shape[0]), deg)
    np.add.at(out, row_ids, x[indices].astype(np.float64))
    return out.astype(x.dtype)


def collective_bytes(plan: AggregationPlan, d_feat: int, itemsize: int = 4) -> int:
    """ICI bytes per device per aggregation: (n-1) full shard rotations."""
    if plan.n_dev <= 1:
        return 0
    return (plan.n_dev - 1) * plan.rows_per_dev * d_feat * itemsize


def sparse_collective_bytes(plan: AggregationPlan, d_feat: int, k: int,
                            itemsize: int = 4) -> int:
    """Ring bytes of the compressed payload: (n-1) rotations of the
    ``(values, col_idx)`` pair — ``k`` values plus ``k`` column ids in the
    wire index dtype (int16 when ``D`` fits) per row."""
    if plan.n_dev <= 1:
        return 0
    idx_itemsize = jnp.dtype(wire_index_dtype(d_feat)).itemsize
    k = min(int(k), int(d_feat))
    return (plan.n_dev - 1) * plan.rows_per_dev * k * (itemsize + idx_itemsize)
