"""Full-graph GNN models on the MGG engine (paper §5 benchmarks).

Two models, with the paper's exact settings:

* **GCN** (Kipf & Welling) — 2 layers, 16 hidden dims:
  ``Z = softmax(Â · relu(Â X W¹) W²)`` with ``Â = D^{-1/2}(A+I)D^{-1/2}``.
* **GIN** (Xu et al.) — 5 layers, 64 hidden dims:
  ``h' = MLP((1+ε)h + Σ_{u∈N(v)} h_u)``.

plus GraphSAGE-mean as a third example model.  The sparse Â·X / Σ-neighbor
products run through :func:`repro.core.pipeline.mgg_aggregate`; the dense
``·W`` updates are plain (replicated-weight) matmuls, mirroring the paper's
use of cuBLAS for the update phase.  Symmetric normalization is folded into
per-node scalings so the aggregation kernel stays a pure masked gather-sum.

Everything operates in the padded PGAS layout (placement.pad_embeddings);
``deg`` vectors are padded alongside.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .graph import CSRGraph
from .placement import AggregationPlan, build_plan, pad_embeddings, pad_table
from .pipeline import mgg_aggregate

__all__ = ["GNNEngine", "gcn_init", "gcn_apply", "gin_init", "gin_apply",
           "sage_init", "sage_apply", "gat_init", "gat_apply",
           "masked_cross_entropy", "MODEL_ZOO",
           "MODEL_STAGES", "num_stages", "apply_stage", "apply_from_stage"]


@dataclasses.dataclass
class GNNEngine:
    """Bundles graph partitioning state + the pipelined aggregation op.

    One engine per (graph, mesh, knob set).  ``aggregate`` is the Â-free
    neighbor sum; ``gcn_norm_aggregate`` applies the symmetric normalization.
    """

    plan: AggregationPlan
    mesh: Mesh
    axis_name: str = "ring"
    interleave: bool = True
    use_kernel: bool = False
    pb: Optional[int] = None  # paper wpb: kernel partition-block height
    deg: Optional[jax.Array] = None  # padded (N_pad,) float32, degree of A+I

    @staticmethod
    def build(
        graph: CSRGraph,
        mesh: Mesh,
        *,
        axis_name: str = "ring",
        ps: int = 16,
        dist: int = 1,
        pb: Optional[int] = None,
        interleave: bool = True,
        use_kernel: bool = False,
        self_loops: bool = True,
    ) -> "GNNEngine":
        g = graph.with_self_loops() if self_loops else graph
        n_dev = int(np.prod([mesh.shape[a] for a in mesh.axis_names])) \
            if axis_name == "__all__" else mesh.shape[axis_name]
        plan = build_plan(g, n_dev, ps=ps, dist=dist)
        deg = pad_table(plan.bounds, plan.rows_per_dev,
                        g.degrees.astype(np.float32)[:, None])[:, 0]
        return GNNEngine(
            plan=plan, mesh=mesh, axis_name=axis_name,
            interleave=interleave, use_kernel=use_kernel, pb=pb,
            deg=jnp.asarray(np.maximum(deg, 1.0)),
        )

    def pad(self, x: np.ndarray) -> np.ndarray:
        return pad_embeddings(self.plan, x)

    def shard(self, x) -> jax.Array:
        spec = P(self.axis_name) if x.ndim == 1 else P(self.axis_name, None)
        return jax.device_put(x, NamedSharding(self.mesh, spec))

    def aggregate(self, x: jax.Array) -> jax.Array:
        return mgg_aggregate(
            x, self.plan, self.mesh,
            axis_name=self.axis_name,
            interleave=self.interleave,
            use_kernel=self.use_kernel,
            pb=self.pb,
        )

    @property
    def config(self) -> Dict[str, int]:
        """The live (ps, dist, pb) knob set — the tuner's search point."""
        return dict(ps=self.plan.ps, dist=self.plan.dist,
                    pb=self.pb if self.pb is not None else 1)

    def gcn_norm_aggregate(self, x: jax.Array) -> jax.Array:
        """Â x with Â = D^{-1/2}(A+I)D^{-1/2} (self-loops already in plan)."""
        dinv = jax.lax.rsqrt(self.deg)[:, None].astype(x.dtype)
        return self.aggregate(x * dinv) * dinv

    def mean_aggregate(self, x: jax.Array) -> jax.Array:
        return self.aggregate(x) / self.deg[:, None].astype(x.dtype)


# ---------------------------------------------------------------------------
# parameter init / apply (no flax — plain pytrees, framework substrate)
# ---------------------------------------------------------------------------

def _dense_init(key, fan_in: int, fan_out: int, dtype=jnp.float32):
    w = jax.random.normal(key, (fan_in, fan_out), dtype) * jnp.sqrt(
        2.0 / (fan_in + fan_out)
    ).astype(dtype)
    return dict(w=w, b=jnp.zeros((fan_out,), dtype))


def _dense(p, x):
    return x @ p["w"] + p["b"]


def gcn_init(key, in_dim: int, num_classes: int, hidden: int = 16,
             num_layers: int = 2, dtype=jnp.float32) -> Dict:
    """Paper setting: 2 layers, 16 hidden dims."""
    dims = [in_dim] + [hidden] * (num_layers - 1) + [num_classes]
    keys = jax.random.split(key, num_layers)
    return dict(
        layers=[_dense_init(k, dims[i], dims[i + 1], dtype)
                for i, k in enumerate(keys)]
    )


def gcn_stage(params: Dict, engine: GNNEngine, h: jax.Array,
              i: int) -> jax.Array:
    """Layer ``i`` of the GCN: one aggregation + dense update (+ relu).

    Update-before-aggregate when it shrinks the feature dim (D_in > D_out),
    else aggregate-first — the standard dataflow optimization; MGG's kernel
    is agnostic to the order.
    """
    n = len(params["layers"])
    layer = params["layers"][i]
    d_in, d_out = layer["w"].shape
    if d_in >= d_out:
        h = engine.gcn_norm_aggregate(_dense(layer, h))
    else:
        h = _dense(layer, engine.gcn_norm_aggregate(h))
    if i < n - 1:
        h = jax.nn.relu(h)
    return h


def gcn_apply(params: Dict, engine: GNNEngine, x: jax.Array) -> jax.Array:
    """Z = Â relu(... Â relu(Â X W¹) ...) Wᴸ (logits; softmax in the loss)."""
    h = x
    for i in range(len(params["layers"])):
        h = gcn_stage(params, engine, h, i)
    return h


def gin_init(key, in_dim: int, num_classes: int, hidden: int = 64,
             num_layers: int = 5, dtype=jnp.float32) -> Dict:
    """Paper setting: 5 layers, 64 hidden dims; 2-layer MLP per GIN layer."""
    keys = jax.random.split(key, 2 * num_layers + 1)
    layers = []
    dims = [in_dim] + [hidden] * num_layers
    for i in range(num_layers):
        layers.append(dict(
            eps=jnp.zeros((), dtype),
            mlp1=_dense_init(keys[2 * i], dims[i], hidden, dtype),
            mlp2=_dense_init(keys[2 * i + 1], hidden, hidden, dtype),
        ))
    return dict(layers=layers,
                head=_dense_init(keys[-1], hidden, num_classes, dtype))


def gin_stage(params: Dict, engine: GNNEngine, h: jax.Array,
              i: int) -> jax.Array:
    """GIN stage ``i``: layers 0..L-1 are GIN layers, stage L is the head."""
    if i == len(params["layers"]):
        return _dense(params["head"], h)
    layer = params["layers"][i]
    agg = engine.aggregate(h)  # Σ neighbors (+ self, via self-loop plan)
    z = agg + layer["eps"] * h  # (1+ε)h + Σ_{u∈N(v)}: self-loop gives 1·h
    z = jax.nn.relu(_dense(layer["mlp1"], z))
    return jax.nn.relu(_dense(layer["mlp2"], z))


def gin_apply(params: Dict, engine: GNNEngine, x: jax.Array) -> jax.Array:
    h = x
    for i in range(len(params["layers"]) + 1):
        h = gin_stage(params, engine, h, i)
    return h


def sage_init(key, in_dim: int, num_classes: int, hidden: int = 32,
              num_layers: int = 2, dtype=jnp.float32) -> Dict:
    dims = [in_dim] + [hidden] * (num_layers - 1) + [num_classes]
    keys = jax.random.split(key, 2 * num_layers)
    return dict(layers=[
        dict(self=_dense_init(keys[2 * i], dims[i], dims[i + 1], dtype),
             nbr=_dense_init(keys[2 * i + 1], dims[i], dims[i + 1], dtype))
        for i in range(num_layers)
    ])


def sage_stage(params: Dict, engine: GNNEngine, h: jax.Array,
               i: int) -> jax.Array:
    layer = params["layers"][i]
    agg = engine.mean_aggregate(h)
    h = _dense(layer["self"], h) + _dense(layer["nbr"], agg)
    if i < len(params["layers"]) - 1:
        h = jax.nn.relu(h)
    return h


def sage_apply(params: Dict, engine: GNNEngine, x: jax.Array) -> jax.Array:
    h = x
    for i in range(len(params["layers"])):
        h = sage_stage(params, engine, h, i)
    return h


def masked_cross_entropy(logits: jax.Array, labels: jax.Array,
                         mask: jax.Array) -> jax.Array:
    """Mean CE over real (non-padding) nodes; padded rows carry mask 0."""
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    ll = jnp.take_along_axis(logp, labels[:, None], axis=-1)[:, 0]
    m = mask.astype(jnp.float32)
    return -(ll * m).sum() / jnp.maximum(m.sum(), 1.0)


def gat_init(key, in_dim: int, num_classes: int, hidden: int = 32,
             num_layers: int = 2, heads: int = 4, dtype=jnp.float32) -> Dict:
    """GATv1 (Veličković et al.) — the paper cites it as GIN's successor.

    GATv1's softmax over (a_l·Wh_u + a_r·Wh_v) is source-decomposable (the
    destination term is constant per softmax and cancels), so each head is
    two engine sum-aggregations: Σ e^{s_u}·Wh_u and Σ e^{s_u}.
    """
    dims = [in_dim] + [hidden * heads] * (num_layers - 1) + [num_classes]
    keys = jax.random.split(key, 2 * num_layers)
    layers = []
    for i in range(num_layers):
        out_total = dims[i + 1]
        h = heads if i < num_layers - 1 else 1
        hd = out_total // h
        layers.append(dict(
            w=_dense_init(keys[2 * i], dims[i], out_total, dtype),
            a_l=(jax.random.normal(keys[2 * i + 1], (h, hd), dtype) * 0.1),
        ))
    return dict(layers=layers)


def gat_stage(params: Dict, engine: GNNEngine, h: jax.Array,
              i: int) -> jax.Array:
    layer = params["layers"][i]
    nh = layer["a_l"].shape[0]                 # heads (static)
    z = _dense(layer["w"], h)                  # (N, H·hd)
    npad, total = z.shape
    hd = total // nh
    zh = z.reshape(npad, nh, hd)
    s = jnp.einsum("nhd,hd->nh", zh, layer["a_l"])
    e = jnp.exp(jax.nn.leaky_relu(s, 0.2))     # source weights (N, H)
    num = engine.aggregate((zh * e[..., None]).reshape(npad, total))
    den = engine.aggregate(jnp.repeat(e, hd, axis=1))
    out = (num / jnp.maximum(den, 1e-9)).astype(h.dtype)
    if i < len(params["layers"]) - 1:
        out = jax.nn.elu(out)
    return out


def gat_apply(params: Dict, engine: GNNEngine, x: jax.Array) -> jax.Array:
    h = x
    for i in range(len(params["layers"])):
        h = gat_stage(params, engine, h, i)
    return h


MODEL_ZOO = {
    "gcn": (gcn_init, gcn_apply, dict(hidden=16, num_layers=2)),
    "gin": (gin_init, gin_apply, dict(hidden=64, num_layers=5)),
    "sage": (sage_init, sage_apply, dict(hidden=32, num_layers=2)),
    "gat": (gat_init, gat_apply, dict(hidden=16, num_layers=2, heads=4)),
}

# ---------------------------------------------------------------------------
# stage-wise access (the serving subsystem resumes inference from a cached
# layer-1 table; folding the SAME stage functions guarantees bitwise equality
# between the served logits and the offline *_apply full pass)
# ---------------------------------------------------------------------------

MODEL_STAGES = {
    "gcn": gcn_stage,
    "gin": gin_stage,
    "sage": sage_stage,
    "gat": gat_stage,
}


def num_stages(model: str, params: Dict) -> int:
    """Stages in ``model``'s forward pass (GIN's head dense is a stage)."""
    n = len(params["layers"])
    return n + 1 if model == "gin" else n


def apply_stage(model: str, params: Dict, engine: GNNEngine, h: jax.Array,
                i: int) -> jax.Array:
    return MODEL_STAGES[model](params, engine, h, i)


def apply_from_stage(model: str, params: Dict, engine: GNNEngine,
                     h: jax.Array, start: int) -> jax.Array:
    """Fold stages ``start..`` — ``apply_from_stage(m, p, e, x, 0)`` is the
    full forward, identical to ``MODEL_ZOO[m][1](p, e, x)``."""
    for i in range(start, num_stages(model, params)):
        h = apply_stage(model, params, engine, h, i)
    return h
