"""Full-graph GNN models on the MGG engine (paper §5 benchmarks).

Two models, with the paper's exact settings:

* **GCN** (Kipf & Welling) — 2 layers, 16 hidden dims:
  ``Z = softmax(Â · relu(Â X W¹) W²)`` with ``Â = D^{-1/2}(A+I)D^{-1/2}``.
* **GIN** (Xu et al.) — 5 layers, 64 hidden dims:
  ``h' = MLP((1+ε)h + Σ_{u∈N(v)} h_u)``.

plus GraphSAGE-mean as a third example model.  The sparse Â·X / Σ-neighbor
products run through :func:`repro.core.pipeline.mgg_aggregate`; the dense
``·W`` updates are plain (replicated-weight) matmuls (mirroring the paper's
use of cuBLAS for the update phase) — unless a layer's
:class:`~repro.core.placement.LayerPlan` sets ``fuse_update``, in which case
the update matmul runs *inside* the ring so its FLOPs overlap the next
tile's transfer.  Symmetric normalization is folded into per-node scalings
so the aggregation kernel stays a pure masked gather-sum.

Every model stage consumes its own LayerPlan (``engine.layer_plan(i)``):
layers can run different ``(ps, dist, pb, interleave)`` schedules over one
shared graph partition and PGAS layout.

Everything operates in the padded PGAS layout (placement.pad_embeddings);
``deg`` vectors are padded alongside.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .graph import CSRGraph
from .placement import (AggregationPlan, LayerPlan, SharedPartition,
                        build_layer_plans, build_partition, pad_embeddings,
                        pad_table)
from .pipeline import (block_neighbor_sum, mgg_aggregate,
                       mgg_aggregate_sparse, mgg_aggregate_sparse_streamed,
                       mgg_aggregate_streamed)

__all__ = ["GNNEngine", "gcn_init", "gcn_apply", "gin_init", "gin_apply",
           "sage_init", "sage_apply", "gat_init", "gat_apply",
           "sage_apply_blocks", "apply_blocks", "BLOCK_MODELS",
           "masked_cross_entropy", "MODEL_ZOO", "aggregation_widths",
           "MODEL_STAGES", "num_stages", "apply_stage", "apply_from_stage"]


@dataclasses.dataclass
class GNNEngine:
    """Bundles graph partitioning state + the pipelined aggregation op.

    One engine per (graph, mesh, knob sets).  The engine holds one
    :class:`~repro.core.placement.LayerPlan` per GNN layer, all derived
    from a single shared graph partition: layers may run radically
    different ``(ps, dist, pb, interleave)`` schedules (GCN's wide input
    layer vs its 16-dim hidden layer want different knobs) while sharing
    one PGAS embedding layout, so activations flow between layers without
    re-padding.  A single-config engine is the degenerate case of one
    LayerPlan shared by every layer.

    ``aggregate`` is the Â-free neighbor sum; ``gcn_norm_aggregate``
    applies the symmetric normalization; the ``*_update`` variants run the
    layer's dense ``·W`` update fused into the ring (see
    pipeline.mgg_aggregate ``update_w``).
    """

    layer_plans: List[LayerPlan]
    mesh: Mesh
    axis_name: str = "ring"
    use_kernel: bool = False
    deg: Optional[jax.Array] = None  # padded (N_pad,) float32, degree of A+I
    partition: Optional[SharedPartition] = None

    @staticmethod
    def build(
        graph: CSRGraph,
        mesh: Mesh,
        *,
        axis_name: str = "ring",
        ps: int = 16,
        dist: int = 1,
        pb: Optional[int] = None,
        interleave: bool = True,
        use_kernel: bool = False,
        self_loops: bool = True,
        fuse_update: bool = False,
        topk: Optional[int] = None,
        layer_configs: Optional[Sequence[Dict]] = None,
        partition: Optional[SharedPartition] = None,
    ) -> "GNNEngine":
        """Build an engine; ``layer_configs`` (one ``{ps, dist, pb, ...}``
        dict per layer) selects per-layer plans, otherwise the single
        ``(ps, dist, pb)`` config is shared by every layer.  ``partition``
        reuses a previously built :class:`SharedPartition` (it must match
        this graph *after* self-loop handling and this mesh's device
        count) — the dynamic runtime passes it so tuner moves re-derive
        schedules without re-partitioning the graph."""
        g = graph.with_self_loops() if self_loops else graph
        n_dev = int(np.prod([mesh.shape[a] for a in mesh.axis_names])) \
            if axis_name == "__all__" else mesh.shape[axis_name]
        if layer_configs is None:
            layer_configs = [dict(ps=ps, dist=dist, pb=pb)]
        part = partition if partition is not None \
            else build_partition(g, n_dev)
        plans = build_layer_plans(g, n_dev, layer_configs, partition=part,
                                  interleave=interleave,
                                  fuse_update=fuse_update, topk=topk)
        plan0 = plans[0].plan
        deg = pad_table(plan0.bounds, plan0.rows_per_dev,
                        g.degrees.astype(np.float32)[:, None])[:, 0]
        return GNNEngine(
            layer_plans=plans, mesh=mesh, axis_name=axis_name,
            use_kernel=use_kernel,
            deg=jnp.asarray(np.maximum(deg, 1.0)),
            partition=part,
        )

    # -- layer plan access ---------------------------------------------------

    def layer_plan(self, layer: int) -> LayerPlan:
        """The plan driving aggregation stage ``layer`` (clamped: stages
        beyond the configured depth reuse the last layer's plan — e.g.
        GIN's head dense, which never aggregates)."""
        return self.layer_plans[min(layer, len(self.layer_plans) - 1)]

    @property
    def num_layer_plans(self) -> int:
        return len(self.layer_plans)

    @property
    def per_layer(self) -> bool:
        return len(self.layer_plans) > 1

    @property
    def plan(self) -> AggregationPlan:
        """Layer 0's schedule; every layer shares its PGAS layout
        (``bounds`` / ``rows_per_dev``), so layout consumers (padding,
        pgas_rows, serving) can keep using this single handle."""
        return self.layer_plans[0].plan

    @property
    def interleave(self) -> bool:
        return self.layer_plans[0].interleave

    @property
    def pb(self) -> Optional[int]:
        return self.layer_plans[0].pb

    @property
    def config(self) -> Dict[str, int]:
        """Layer 0's (ps, dist, pb) — THE knob set for single-config
        engines; per-layer engines expose ``layer_configs``."""
        return self.layer_plans[0].config

    @property
    def layer_configs(self) -> List[Dict[str, int]]:
        return [lp.config for lp in self.layer_plans]

    # -- layout --------------------------------------------------------------

    def pad(self, x: np.ndarray) -> np.ndarray:
        return pad_embeddings(self.plan, x)

    def shard(self, x) -> jax.Array:
        spec = P(self.axis_name) if x.ndim == 1 else P(self.axis_name, None)
        return jax.device_put(x, NamedSharding(self.mesh, spec))

    def stage_topk(self, layer: int) -> Optional[int]:
        """Effective top-k compression for aggregation stage ``layer``:
        hidden layers only — layer 0's inputs aren't ours to sparsify, so
        the input layer always rides the dense ring."""
        return self.layer_plan(layer).topk if layer >= 1 else None

    # -- aggregation ---------------------------------------------------------

    def aggregate(self, x: jax.Array, layer: int = 0,
                  update_w: Optional[jax.Array] = None,
                  topk: Optional[int] = None) -> jax.Array:
        lp = self.layer_plan(layer)
        if topk:
            return mgg_aggregate_sparse(
                x, lp.plan, self.mesh,
                k=int(topk),
                axis_name=self.axis_name,
                interleave=lp.interleave,
                use_kernel=self.use_kernel,
                pb=lp.pb,
                update_w=update_w,
            )
        return mgg_aggregate(
            x, lp.plan, self.mesh,
            axis_name=self.axis_name,
            interleave=lp.interleave,
            use_kernel=self.use_kernel,
            pb=lp.pb,
            update_w=update_w,
        )

    def aggregate_update(self, x: jax.Array, w: jax.Array, layer: int = 0,
                         topk: Optional[int] = None) -> jax.Array:
        """Fused ``(A x) @ W``: the update matmul runs inside the ring."""
        return self.aggregate(x, layer=layer, update_w=w, topk=topk)

    def aggregate_sparse(self, x: jax.Array, k: int, layer: int = 0,
                         update_w: Optional[jax.Array] = None) -> jax.Array:
        """Explicit-k sparse aggregation (``aggregate`` with ``topk=k``)."""
        return self.aggregate(x, layer=layer, update_w=update_w, topk=k)

    def aggregate_streamed(self, tiered, layer: int = 0,
                           update_w: Optional[jax.Array] = None,
                           topk: Optional[int] = None,
                           stats: Optional[Dict] = None,
                           tracer=None) -> jax.Array:
        """Partial-resident aggregation: chunks are pulled on demand from
        a :class:`repro.store.TieredFeatures` (host store + device hot
        cache), with each tile's host→device gather prefetched while the
        previous tile's ring is in flight — see
        :func:`repro.core.pipeline.mgg_aggregate_streamed`.  ``topk``
        additionally compresses each landed chunk so the in-flight rings
        carry the sparse payload (mgg_aggregate_sparse_streamed)."""
        lp = self.layer_plan(layer)
        if tiered.plan is not lp.plan:
            tiered.set_plan(lp.plan)
        if topk:
            return mgg_aggregate_sparse_streamed(
                tiered.chunk_fetcher(), lp.plan, self.mesh,
                k=int(topk),
                axis_name=self.axis_name,
                use_kernel=self.use_kernel,
                pb=lp.pb,
                update_w=update_w,
                stats=stats,
                tracer=tracer,
            )
        return mgg_aggregate_streamed(
            tiered.chunk_fetcher(), lp.plan, self.mesh,
            axis_name=self.axis_name,
            use_kernel=self.use_kernel,
            pb=lp.pb,
            update_w=update_w,
            stats=stats,
            tracer=tracer,
        )

    def gcn_norm_aggregate(self, x: jax.Array, layer: int = 0,
                           topk: Optional[int] = None) -> jax.Array:
        """Â x with Â = D^{-1/2}(A+I)D^{-1/2} (self-loops already in plan)."""
        dinv = jax.lax.rsqrt(self.deg)[:, None].astype(x.dtype)
        return self.aggregate(x * dinv, layer=layer, topk=topk) * dinv

    def gcn_norm_aggregate_update(self, x: jax.Array, w: jax.Array,
                                  layer: int = 0,
                                  topk: Optional[int] = None) -> jax.Array:
        """Fused ``(Â x) @ W``: the left diagonal scaling commutes with the
        right matmul, so ``D^{-1/2}((A (D^{-1/2} x)) W)`` is exact."""
        dinv = jax.lax.rsqrt(self.deg)[:, None].astype(x.dtype)
        return self.aggregate_update(x * dinv, w, layer=layer, topk=topk) \
            * dinv

    def mean_aggregate(self, x: jax.Array, layer: int = 0,
                       topk: Optional[int] = None) -> jax.Array:
        return self.aggregate(x, layer=layer, topk=topk) \
            / self.deg[:, None].astype(x.dtype)

    def mean_aggregate_update(self, x: jax.Array, w: jax.Array,
                              layer: int = 0,
                              topk: Optional[int] = None) -> jax.Array:
        """Fused ``(D^{-1} A x) @ W`` (same commutation as gcn_norm)."""
        return self.aggregate_update(x, w, layer=layer, topk=topk) \
            / self.deg[:, None].astype(x.dtype)


# ---------------------------------------------------------------------------
# parameter init / apply (no flax — plain pytrees, framework substrate)
# ---------------------------------------------------------------------------

def _dense_init(key, fan_in: int, fan_out: int, dtype=jnp.float32):
    w = jax.random.normal(key, (fan_in, fan_out), dtype) * jnp.sqrt(
        2.0 / (fan_in + fan_out)
    ).astype(dtype)
    return dict(w=w, b=jnp.zeros((fan_out,), dtype))


def _dense(p, x):
    return x @ p["w"] + p["b"]


def gcn_init(key, in_dim: int, num_classes: int, hidden: int = 16,
             num_layers: int = 2, dtype=jnp.float32) -> Dict:
    """Paper setting: 2 layers, 16 hidden dims."""
    dims = [in_dim] + [hidden] * (num_layers - 1) + [num_classes]
    keys = jax.random.split(key, num_layers)
    return dict(
        layers=[_dense_init(k, dims[i], dims[i + 1], dtype)
                for i, k in enumerate(keys)]
    )


def gcn_stage(params: Dict, engine: GNNEngine, h: jax.Array,
              i: int) -> jax.Array:
    """Layer ``i`` of the GCN: one aggregation + dense update (+ relu).

    Three dataflows, selected by the layer's plan: fused (update inside the
    ring — ``(Â h) W`` with per-tile partial matmuls), else
    update-before-aggregate when it shrinks the feature dim (D_in > D_out),
    else aggregate-first.  All three compute the same math (matmul
    associativity); MGG's kernel is agnostic to the order.
    """
    n = len(params["layers"])
    layer = params["layers"][i]
    d_in, d_out = layer["w"].shape
    tk = engine.stage_topk(i)  # hidden layers may ride the sparse ring
    if engine.layer_plan(i).fuse_update:
        h = engine.gcn_norm_aggregate_update(h, layer["w"], layer=i,
                                             topk=tk) + layer["b"]
    elif d_in >= d_out:
        # transform-first; bias after aggregation (PyG convention) so all
        # three dataflows compute identical math up to summation order
        h = engine.gcn_norm_aggregate(h @ layer["w"], layer=i, topk=tk) \
            + layer["b"]
    else:
        h = _dense(layer, engine.gcn_norm_aggregate(h, layer=i, topk=tk))
    if i < n - 1:
        h = jax.nn.relu(h)
    return h


def gcn_apply(params: Dict, engine: GNNEngine, x: jax.Array) -> jax.Array:
    """Z = Â relu(... Â relu(Â X W¹) ...) Wᴸ (logits; softmax in the loss)."""
    h = x
    for i in range(len(params["layers"])):
        h = gcn_stage(params, engine, h, i)
    return h


def gin_init(key, in_dim: int, num_classes: int, hidden: int = 64,
             num_layers: int = 5, dtype=jnp.float32) -> Dict:
    """Paper setting: 5 layers, 64 hidden dims; 2-layer MLP per GIN layer."""
    keys = jax.random.split(key, 2 * num_layers + 1)
    layers = []
    dims = [in_dim] + [hidden] * num_layers
    for i in range(num_layers):
        layers.append(dict(
            eps=jnp.zeros((), dtype),
            mlp1=_dense_init(keys[2 * i], dims[i], hidden, dtype),
            mlp2=_dense_init(keys[2 * i + 1], hidden, hidden, dtype),
        ))
    return dict(layers=layers,
                head=_dense_init(keys[-1], hidden, num_classes, dtype))


def gin_stage(params: Dict, engine: GNNEngine, h: jax.Array,
              i: int) -> jax.Array:
    """GIN stage ``i``: layers 0..L-1 are GIN layers, stage L is the head.

    Fused dataflow: ``((A h) + ε h) W₁ = (A h) W₁ + ε (h W₁)`` — the
    aggregate's ·W₁ runs inside the ring, the ε-scaled self term is a
    plain local matmul.
    """
    if i == len(params["layers"]):
        return _dense(params["head"], h)
    layer = params["layers"][i]
    tk = engine.stage_topk(i)  # sparse ring for hidden layers; self term dense
    if engine.layer_plan(i).fuse_update:
        z = engine.aggregate_update(h, layer["mlp1"]["w"], layer=i, topk=tk) \
            + layer["eps"] * (h @ layer["mlp1"]["w"]) + layer["mlp1"]["b"]
        z = jax.nn.relu(z)
    else:
        agg = engine.aggregate(h, layer=i, topk=tk)  # Σ nbrs (+ self-loop)
        z = agg + layer["eps"] * h  # (1+ε)h + Σ_{u∈N(v)}: self-loop gives 1·h
        z = jax.nn.relu(_dense(layer["mlp1"], z))
    return jax.nn.relu(_dense(layer["mlp2"], z))


def gin_apply(params: Dict, engine: GNNEngine, x: jax.Array) -> jax.Array:
    h = x
    for i in range(len(params["layers"]) + 1):
        h = gin_stage(params, engine, h, i)
    return h


def sage_init(key, in_dim: int, num_classes: int, hidden: int = 32,
              num_layers: int = 2, dtype=jnp.float32) -> Dict:
    dims = [in_dim] + [hidden] * (num_layers - 1) + [num_classes]
    keys = jax.random.split(key, 2 * num_layers)
    return dict(layers=[
        dict(self=_dense_init(keys[2 * i], dims[i], dims[i + 1], dtype),
             nbr=_dense_init(keys[2 * i + 1], dims[i], dims[i + 1], dtype))
        for i in range(num_layers)
    ])


def sage_stage(params: Dict, engine: GNNEngine, h: jax.Array,
               i: int) -> jax.Array:
    layer = params["layers"][i]
    tk = engine.stage_topk(i)  # sparse ring for hidden layers; self path dense
    if engine.layer_plan(i).fuse_update:
        nbr = engine.mean_aggregate_update(h, layer["nbr"]["w"], layer=i,
                                           topk=tk) + layer["nbr"]["b"]
    else:
        nbr = _dense(layer["nbr"], engine.mean_aggregate(h, layer=i, topk=tk))
    h = _dense(layer["self"], h) + nbr
    if i < len(params["layers"]) - 1:
        h = jax.nn.relu(h)
    return h


def sage_apply(params: Dict, engine: GNNEngine, x: jax.Array) -> jax.Array:
    h = x
    for i in range(len(params["layers"])):
        h = sage_stage(params, engine, h, i)
    return h


def sage_apply_blocks(params: Dict, h: jax.Array, blocks,
                      *, use_kernel: bool = False) -> jax.Array:
    """GraphSAGE-mean forward over sampled mini-batch blocks.

    ``h`` is the outermost block's source feature table — ``(num_src, D)``
    rows aligned with ``blocks[0]['nbr']``'s local indices, zeros in the
    ``-1``-padded slots (see ``TieredFeatures.gather_rows``).  ``blocks``
    is the jit-traced pytree from ``repro.sample.block_tree``, one entry
    per layer, outermost hop first.  Destination rows are the leading
    rows of each source table (dst-first ordering), so the self term is
    ``h[:num_dst]`` — no second gather.  Returns the ``(batch,
    num_classes)`` seed logits; rows of padded seeds are garbage and
    must stay masked in the loss (``masked_cross_entropy``).
    """
    layers = params["layers"]
    if len(blocks) != len(layers):
        raise ValueError(
            f"{len(blocks)} blocks for {len(layers)} layers — sample with "
            f"one fanout per layer")
    for i, (layer, blk) in enumerate(zip(layers, blocks)):
        nbr, mask = blk["nbr"], blk["mask"]
        s = block_neighbor_sum(h, nbr, mask, use_kernel=use_kernel)
        deg = jnp.maximum(mask.sum(axis=-1), 1.0).astype(h.dtype)[:, None]
        h = _dense(layer["self"], h[:nbr.shape[0]]) + _dense(
            layer["nbr"], s / deg)
        if i < len(layers) - 1:
            h = jax.nn.relu(h)
    return h


# Block-capable models: the sampled mini-batch path is GraphSAGE-style
# by construction (per-hop fanout bound == per-layer neighbor sample).
BLOCK_MODELS = {"sage": sage_apply_blocks}


def apply_blocks(model: str, params: Dict, h: jax.Array, blocks,
                 *, use_kernel: bool = False) -> jax.Array:
    """Dispatch the sampled-block forward for ``model`` (see
    ``BLOCK_MODELS``; currently GraphSAGE only)."""
    if model not in BLOCK_MODELS:
        raise ValueError(
            f"model {model!r} has no sampled-block path (have: "
            f"{sorted(BLOCK_MODELS)})")
    return BLOCK_MODELS[model](params, h, blocks, use_kernel=use_kernel)


def masked_cross_entropy(logits: jax.Array, labels: jax.Array,
                         mask: jax.Array) -> jax.Array:
    """Mean CE over real (non-padding) nodes; padded rows carry mask 0."""
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    ll = jnp.take_along_axis(logp, labels[:, None], axis=-1)[:, 0]
    m = mask.astype(jnp.float32)
    return -(ll * m).sum() / jnp.maximum(m.sum(), 1.0)


def gat_init(key, in_dim: int, num_classes: int, hidden: int = 32,
             num_layers: int = 2, heads: int = 4, dtype=jnp.float32) -> Dict:
    """GATv1 (Veličković et al.) — the paper cites it as GIN's successor.

    GATv1's softmax over (a_l·Wh_u + a_r·Wh_v) is source-decomposable (the
    destination term is constant per softmax and cancels), so each head is
    two engine sum-aggregations: Σ e^{s_u}·Wh_u and Σ e^{s_u}.
    """
    dims = [in_dim] + [hidden * heads] * (num_layers - 1) + [num_classes]
    keys = jax.random.split(key, 2 * num_layers)
    layers = []
    for i in range(num_layers):
        out_total = dims[i + 1]
        h = heads if i < num_layers - 1 else 1
        hd = out_total // h
        layers.append(dict(
            w=_dense_init(keys[2 * i], dims[i], out_total, dtype),
            a_l=(jax.random.normal(keys[2 * i + 1], (h, hd), dtype) * 0.1),
        ))
    return dict(layers=layers)


def gat_stage(params: Dict, engine: GNNEngine, h: jax.Array,
              i: int) -> jax.Array:
    # GAT's dense W is applied BEFORE aggregation (attention needs Wh per
    # source), so there is no post-aggregation update to fuse: the layer's
    # fuse_update flag is a no-op and fused == unfused bitwise.  topk is
    # likewise not honoured: zeroing entries of the e^s attention numerator
    # and denominator aggregations would bias the softmax, not sparsify it.
    layer = params["layers"][i]
    nh = layer["a_l"].shape[0]                 # heads (static)
    z = _dense(layer["w"], h)                  # (N, H·hd)
    npad, total = z.shape
    hd = total // nh
    zh = z.reshape(npad, nh, hd)
    s = jnp.einsum("nhd,hd->nh", zh, layer["a_l"])
    e = jnp.exp(jax.nn.leaky_relu(s, 0.2))     # source weights (N, H)
    num = engine.aggregate((zh * e[..., None]).reshape(npad, total), layer=i)
    den = engine.aggregate(jnp.repeat(e, hd, axis=1), layer=i)
    out = (num / jnp.maximum(den, 1e-9)).astype(h.dtype)
    if i < len(params["layers"]) - 1:
        out = jax.nn.elu(out)
    return out


def gat_apply(params: Dict, engine: GNNEngine, x: jax.Array) -> jax.Array:
    h = x
    for i in range(len(params["layers"])):
        h = gat_stage(params, engine, h, i)
    return h


MODEL_ZOO = {
    "gcn": (gcn_init, gcn_apply, dict(hidden=16, num_layers=2)),
    "gin": (gin_init, gin_apply, dict(hidden=64, num_layers=5)),
    "sage": (sage_init, sage_apply, dict(hidden=32, num_layers=2)),
    "gat": (gat_init, gat_apply, dict(hidden=16, num_layers=2, heads=4)),
}


def aggregation_widths(model: str, params: Dict,
                       fused: bool = False) -> List[int]:
    """Feature width crossing the ring at each aggregation layer.

    This is the per-layer ``D`` the autotuner's latency model needs: GCN's
    input layer aggregates at a very different width than its 16-dim hidden
    layer, which is exactly why one global ``(ps, dist, pb)`` is the wrong
    shape.  ``fused`` widths reflect the fused dataflow (the ring carries
    the pre-update features).
    """
    widths: List[int] = []
    for layer in params["layers"]:
        if model == "gcn":
            d_in, d_out = layer["w"].shape
            widths.append(d_in if fused else min(d_in, d_out))
        elif model == "gin":
            widths.append(layer["mlp1"]["w"].shape[0])
        elif model == "sage":
            widths.append(layer["nbr"]["w"].shape[0])
        elif model == "gat":
            widths.append(layer["w"]["w"].shape[1])
        else:
            raise ValueError(f"unknown model {model!r}")
    return widths

# ---------------------------------------------------------------------------
# stage-wise access (the serving subsystem resumes inference from a cached
# layer-1 table; folding the SAME stage functions guarantees bitwise equality
# between the served logits and the offline *_apply full pass)
# ---------------------------------------------------------------------------

MODEL_STAGES = {
    "gcn": gcn_stage,
    "gin": gin_stage,
    "sage": sage_stage,
    "gat": gat_stage,
}


def num_stages(model: str, params: Dict) -> int:
    """Stages in ``model``'s forward pass (GIN's head dense is a stage)."""
    n = len(params["layers"])
    return n + 1 if model == "gin" else n


def apply_stage(model: str, params: Dict, engine: GNNEngine, h: jax.Array,
                i: int) -> jax.Array:
    return MODEL_STAGES[model](params, engine, h, i)


def apply_from_stage(model: str, params: Dict, engine: GNNEngine,
                     h: jax.Array, start: int) -> jax.Array:
    """Fold stages ``start..`` — ``apply_from_stage(m, p, e, x, 0)`` is the
    full forward, identical to ``MODEL_ZOO[m][1](p, e, x)``."""
    for i in range(start, num_stages(model, params)):
        h = apply_stage(model, params, engine, h, i)
    return h
