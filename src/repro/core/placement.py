"""Hybrid GNN data placement (paper §3.2), adapted to a TPU mesh.

The paper stores node embeddings (NE) in NVSHMEM *shared* global memory — a
PGAS heap spanning all GPUs — and the partitioned topology (GP) in each GPU's
*private* memory, with global node ids remapped to (owner, local offset).

TPU analogue:

* **NE** → a single embedding array of shape ``(n_dev * rows_per_dev, D)``
  with a ``NamedSharding`` over the ring axis: chip ``d`` physically owns the
  row range ``[d * rows, (d+1) * rows)``.  This is the PGAS layout — one
  logical array, physically distributed, remotely reachable (via the ring
  collective rather than one-sided GET; see DESIGN.md §2).
* **GP** → the per-device neighbor-partition tensors built here.  They are
  *also* stacked into device-major arrays (leading axis ``n_dev``) and
  sharded on that axis, so inside ``shard_map`` every chip sees only its own
  topology block — the "private memory" of the paper, including the
  global→local offset remap of Fig. 5.

The :class:`AggregationPlan` is a pytree of plain arrays; building it is
host-side NumPy (cheap preprocessing — paper Table 4 contrasts this with
DGCL's minutes-long partitioner).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .graph import CSRGraph
from .partition import (
    NeighborPartitions,
    VirtualGraphs,
    edge_balanced_node_split,
    locality_edge_split,
    neighbor_partitions,
)

__all__ = [
    "AggregationPlan",
    "SharedPartition",
    "LayerPlan",
    "build_partition",
    "plan_from_partition",
    "build_plan",
    "build_layer_plans",
    "build_bulk_plan",
    "build_fetch_plan",
    "pad_table",
    "unpad_table",
    "pad_embeddings",
    "unpad_embeddings",
    "pgas_rows",
]


@dataclasses.dataclass(frozen=True)
class AggregationPlan:
    """Device-major MGG aggregation plan.

    Shapes (``n`` devices, ``S = (n-1) * dist`` ring steps, ``ps`` slots):

    ================  =============================  ==========================
    field             shape                          meaning
    ================  =============================  ==========================
    local_nbrs        (n, PL, ps) int32              local neighbor offsets
    local_mask        (n, PL, ps) bool               valid slots
    local_targets     (n, PL) int32                  destination local row
    remote_nbrs       (n, S, PR, ps) int32           tile-local nbr offsets
    remote_mask       (n, S, PR, ps) bool
    remote_targets    (n, S, PR) int32
    node_counts       (n,) int32                     real rows per device
    ================  =============================  ==========================

    ``rows_per_dev`` is the padded shard height; ``tile_rows`` =
    ``rows_per_dev / dist`` is the ring-tile height.  Step ``s`` of the ring
    aggregates the tile of chunk ``s % dist`` from owner
    ``(d - (s // dist) - 1) mod n``.
    """

    local_nbrs: np.ndarray
    local_mask: np.ndarray
    local_targets: np.ndarray
    remote_nbrs: np.ndarray
    remote_mask: np.ndarray
    remote_targets: np.ndarray
    node_counts: np.ndarray
    bounds: np.ndarray  # (n+1,) global node-range bounds
    n_dev: int
    rows_per_dev: int
    tile_rows: int
    ps: int
    dist: int

    @property
    def num_steps(self) -> int:
        return int(self.remote_nbrs.shape[1])

    @property
    def padded_nodes(self) -> int:
        return self.n_dev * self.rows_per_dev

    def stats(self) -> dict:
        """Workload-balance diagnostics used by benchmarks and the autotuner."""
        local_parts = self.local_mask.any(-1).sum(-1)  # per device
        remote_parts = self.remote_mask.any(-1).sum(-1).sum(-1)
        return dict(
            local_partitions=local_parts.tolist(),
            remote_partitions=remote_parts.tolist(),
            pad_local=float(self.local_mask.shape[1] * self.n_dev
                            - local_parts.sum()) / max(1, self.local_mask.shape[1] * self.n_dev),
            pad_remote=float(self.remote_mask.shape[1] * self.remote_mask.shape[2] * self.n_dev
                             - remote_parts.sum())
            / max(1, self.remote_mask.shape[1] * self.remote_mask.shape[2] * self.n_dev),
        )


def _pad_parts(parts: NeighborPartitions, p_max: int) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    p = parts.num_partitions
    nbrs = np.zeros((p_max, parts.ps), dtype=np.int32)
    mask = np.zeros((p_max, parts.ps), dtype=bool)
    tgt = np.zeros((p_max,), dtype=np.int32)
    nbrs[:p] = parts.nbrs
    mask[:p] = parts.mask
    tgt[:p] = parts.targets
    return nbrs, mask, tgt


@dataclasses.dataclass(frozen=True)
class SharedPartition:
    """The layer-independent half of plan construction (paper §3.1–3.2).

    Node split + per-device locality edge split are functions of the *graph*
    only; the neighbor-partition schedules (``ps``) and ring-tile bucketing
    (``dist``) are per-layer knobs.  Building one :class:`SharedPartition`
    and deriving every layer's :class:`AggregationPlan` from it keeps a
    single neighbor table source — per-layer plans differ only in schedule,
    never in topology — and makes per-layer plan construction O(schedules)
    instead of O(layers × locality splits).
    """

    bounds: np.ndarray                 # (n_dev + 1,) global node ranges
    n_dev: int
    vgs: Tuple[VirtualGraphs, ...]     # per-device local/remote virtual CSRs
    base_rows: int                     # unpadded max shard height

    @property
    def node_counts(self) -> np.ndarray:
        return (self.bounds[1:] - self.bounds[:-1]).astype(np.int32)


def build_partition(
    graph: CSRGraph, n_dev: int, bounds: Optional[np.ndarray] = None
) -> SharedPartition:
    """Node split + locality split, shared by every layer's plan."""
    if bounds is None:
        bounds = edge_balanced_node_split(graph.indptr, n_dev)
    bounds = np.asarray(bounds, dtype=np.int64)
    vgs = tuple(locality_edge_split(graph, bounds, d) for d in range(n_dev))
    return SharedPartition(
        bounds=bounds, n_dev=n_dev, vgs=vgs,
        base_rows=int((bounds[1:] - bounds[:-1]).max()),
    )


def plan_from_partition(
    part: SharedPartition,
    ps: int,
    dist: int = 1,
    rows_multiple: int = 1,
) -> AggregationPlan:
    """Derive one (ps, dist) aggregation schedule from a shared partition.

    ``rows_multiple`` forces the padded shard height to a common multiple so
    plans with *different* ``dist`` can share one PGAS embedding layout
    (build_layer_plans passes the lcm of every layer's dist).
    """
    n_dev, bounds = part.n_dev, part.bounds
    # Pad shard height to a multiple of dist (uniform ring tiles) and of
    # rows_multiple (cross-layer shared layout).
    m = dist * rows_multiple // math.gcd(dist, rows_multiple)
    rows = ((part.base_rows + m - 1) // m) * m
    tile_rows = rows // dist
    n_steps = (n_dev - 1) * dist if n_dev > 1 else 0

    per_dev_local = []
    per_dev_remote = []  # list of lists: [dev][step] -> NeighborPartitions
    for d in range(n_dev):
        vg = part.vgs[d]
        # --- local virtual graph: global ids -> my local offsets (Fig. 5) ---
        local_csr = CSRGraph(
            vg.local.indptr,
            (vg.local.indices - vg.lb).astype(np.int32),
            vg.local.num_nodes,
        )
        per_dev_local.append(neighbor_partitions(local_csr, ps))
        # --- remote virtual graph: bucket edges by (owner, ring tile) -------
        cols = vg.remote.indices
        deg = vg.remote.degrees
        rows_ids = np.repeat(np.arange(vg.remote.num_nodes, dtype=np.int64), deg)
        owner = np.searchsorted(bounds, cols, side="right") - 1
        local_off = cols - bounds[owner]
        chunk = local_off // tile_rows  # which ring tile inside the owner shard
        tile_off = (local_off - chunk * tile_rows).astype(np.int32)
        steps = []
        for s in range(n_steps):
            r = s // dist + 1  # rotation count
            c = s % dist  # chunk id
            o = (d - r) % n_dev  # owner whose tile arrives at this step
            m_sel = (owner == o) & (chunk == c)
            sel_rows, sel_off = rows_ids[m_sel], tile_off[m_sel]
            counts = np.bincount(sel_rows, minlength=vg.remote.num_nodes)
            indptr = np.zeros(vg.remote.num_nodes + 1, dtype=np.int64)
            np.cumsum(counts, out=indptr[1:])
            order = np.argsort(sel_rows, kind="stable")
            sub = CSRGraph(indptr, sel_off[order], vg.remote.num_nodes)
            steps.append(neighbor_partitions(sub, ps))
        per_dev_remote.append(steps)

    pl_max = max(1, max(p.num_partitions for p in per_dev_local))
    pr_max = 1
    for steps in per_dev_remote:
        for p in steps:
            pr_max = max(pr_max, p.num_partitions)

    local_nbrs = np.zeros((n_dev, pl_max, ps), dtype=np.int32)
    local_mask = np.zeros((n_dev, pl_max, ps), dtype=bool)
    local_targets = np.zeros((n_dev, pl_max), dtype=np.int32)
    remote_nbrs = np.zeros((n_dev, max(1, n_steps), pr_max, ps), dtype=np.int32)
    remote_mask = np.zeros((n_dev, max(1, n_steps), pr_max, ps), dtype=bool)
    remote_targets = np.zeros((n_dev, max(1, n_steps), pr_max), dtype=np.int32)
    for d in range(n_dev):
        local_nbrs[d], local_mask[d], local_targets[d] = _pad_parts(
            per_dev_local[d], pl_max
        )
        for s in range(n_steps):
            (remote_nbrs[d, s], remote_mask[d, s],
             remote_targets[d, s]) = _pad_parts(per_dev_remote[d][s], pr_max)

    return AggregationPlan(
        local_nbrs=local_nbrs,
        local_mask=local_mask,
        local_targets=local_targets,
        remote_nbrs=remote_nbrs,
        remote_mask=remote_mask,
        remote_targets=remote_targets,
        node_counts=part.node_counts,
        bounds=bounds,
        n_dev=n_dev,
        rows_per_dev=rows,
        tile_rows=tile_rows,
        ps=ps,
        dist=dist,
    )


def build_plan(
    graph: CSRGraph,
    n_dev: int,
    ps: int,
    dist: int = 1,
    bounds: Optional[np.ndarray] = None,
) -> AggregationPlan:
    """Build the full MGG plan: node split → locality split → neighbor split
    → ring-step bucketing, with the PGAS offset remap of paper Fig. 5."""
    return plan_from_partition(build_partition(graph, n_dev, bounds),
                               ps=ps, dist=dist)


# ---------------------------------------------------------------------------
# per-layer pipeline plans (shared partition, per-layer schedules)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class LayerPlan:
    """One GNN layer's pipeline configuration: an aggregation schedule plus
    the runtime knobs that select how it executes.

    ``plan`` may be SHARED between layers whose ``(ps, dist)`` coincide (see
    :func:`build_layer_plans`) — a LayerPlan never owns topology, only the
    schedule + mapping knobs:

    * ``interleave`` — §3.3 local/remote workload interleaving;
    * ``pb``         — the paper's wpb: kernel partition-block height;
    * ``fuse_update`` — run this layer's dense ``·W`` update *inside* the
      ring (one partial matmul per tile), so update FLOPs overlap the next
      tile's transfer (pipeline.mgg_aggregate ``update_w``);
    * ``topk``       — top-k activation compression: the ring ppermutes the
      compressed ``(values, col_idx)`` payload instead of dense tiles
      (pipeline.mgg_aggregate_sparse).  ``None``/0 = dense.  Model stages
      honour it for hidden layers only — layer 0's inputs aren't ours to
      sparsify.
    """

    plan: AggregationPlan
    interleave: bool = True
    pb: Optional[int] = None
    fuse_update: bool = False
    topk: Optional[int] = None

    @property
    def config(self) -> Dict[str, int]:
        return dict(ps=self.plan.ps, dist=self.plan.dist,
                    pb=self.pb if self.pb is not None else 1)


def build_layer_plans(
    graph: CSRGraph,
    n_dev: int,
    configs: Sequence[Dict],
    *,
    partition: Optional[SharedPartition] = None,
    interleave: bool = True,
    fuse_update: bool = False,
    topk: Optional[int] = None,
) -> List[LayerPlan]:
    """Per-layer plans from ONE shared partition.

    ``configs`` is one dict per layer with keys ``ps`` and ``dist`` (and
    optionally ``pb``, ``interleave``, ``fuse_update``, ``topk`` overriding
    the call-level defaults).  All plans share the partition's neighbor tables
    and — because shard heights are padded to the lcm of every layer's
    ``dist`` — one PGAS embedding layout, so activations flow between
    layers without re-padding.  Layers with identical ``(ps, dist)`` share
    the SAME AggregationPlan object (no duplicated schedule arrays).
    """
    if not configs:
        raise ValueError("need at least one layer config")
    part = partition if partition is not None \
        else build_partition(graph, n_dev)
    lcm = 1
    for cfg in configs:
        d = int(cfg["dist"])
        lcm = lcm * d // math.gcd(lcm, d)
    memo: Dict[Tuple[int, int], AggregationPlan] = {}
    out: List[LayerPlan] = []
    for cfg in configs:
        key = (int(cfg["ps"]), int(cfg["dist"]))
        if key not in memo:
            memo[key] = plan_from_partition(part, ps=key[0], dist=key[1],
                                            rows_multiple=lcm)
        pb = cfg.get("pb")
        tk = cfg.get("topk", topk)
        out.append(LayerPlan(
            plan=memo[key],
            interleave=bool(cfg.get("interleave", interleave)),
            pb=int(pb) if pb is not None else None,
            fuse_update=bool(cfg.get("fuse_update", fuse_update)),
            topk=int(tk) if tk else None,
        ))
    return out


def _padded_offset(bounds: np.ndarray, rows: int, ids: np.ndarray) -> np.ndarray:
    """Global node id → row offset in the padded PGAS table."""
    owner = np.searchsorted(bounds, ids, side="right") - 1
    return (owner * rows + (ids - bounds[owner])).astype(np.int32)


def build_bulk_plan(
    graph: CSRGraph, n_dev: int, ps: int, bounds: Optional[np.ndarray] = None
) -> Tuple[np.ndarray, np.ndarray, np.ndarray, int]:
    """Plan for the bulk (all-gather-then-aggregate, DGCL-style) baseline.

    Returns device-major ``(nbrs, mask, targets, rows_per_dev)`` where
    ``nbrs`` index into the *full padded* table (valid after an all-gather).
    """
    if bounds is None:
        bounds = edge_balanced_node_split(graph.indptr, n_dev)
    rows = int((bounds[1:] - bounds[:-1]).max())
    per_dev = []
    for d in range(n_dev):
        lb, ub = int(bounds[d]), int(bounds[d + 1])
        sub = CSRGraph(
            (graph.indptr[lb : ub + 1] - graph.indptr[lb]),
            graph.indices[graph.indptr[lb] : graph.indptr[ub]],
            ub - lb,
        )
        parts = neighbor_partitions(sub, ps)
        remapped = _padded_offset(bounds, rows, parts.nbrs.ravel()).reshape(
            parts.nbrs.shape
        )
        per_dev.append(
            NeighborPartitions(remapped, parts.mask, parts.targets, ps)
        )
    p_max = max(1, max(p.num_partitions for p in per_dev))
    nbrs = np.zeros((n_dev, p_max, ps), dtype=np.int32)
    mask = np.zeros((n_dev, p_max, ps), dtype=bool)
    tgt = np.zeros((n_dev, p_max), dtype=np.int32)
    for d in range(n_dev):
        nbrs[d], mask[d], tgt[d] = _pad_parts(per_dev[d], p_max)
    return nbrs, mask, tgt, rows


def build_fetch_plan(
    graph: CSRGraph,
    n_dev: int,
    ps: int,
    page_rows: int = 1,
    bounds: Optional[np.ndarray] = None,
) -> dict:
    """Plan for the fetch-then-aggregate baselines (Direct-NVSHMEM / UVM).

    Each device fetches the union of rows it references, expanded to
    ``page_rows`` granularity (``page_rows=1`` → exact rows, the Direct
    baseline; ``page_rows≈4KB/row_bytes`` → the UVM page-migration model).
    Neighbor offsets are remapped into the fetched buffer.
    """
    if bounds is None:
        bounds = edge_balanced_node_split(graph.indptr, n_dev)
    rows = int((bounds[1:] - bounds[:-1]).max())
    fetch_lists, parts_list = [], []
    for d in range(n_dev):
        lb, ub = int(bounds[d]), int(bounds[d + 1])
        sub = CSRGraph(
            (graph.indptr[lb : ub + 1] - graph.indptr[lb]),
            graph.indices[graph.indptr[lb] : graph.indptr[ub]],
            ub - lb,
        )
        parts = neighbor_partitions(sub, ps)
        padded = _padded_offset(bounds, rows, parts.nbrs.ravel())
        pages = np.unique(padded[parts.mask.ravel()] // page_rows)
        fetched = (pages[:, None] * page_rows
                   + np.arange(page_rows)[None, :]).ravel()
        # remap padded offsets → position inside the fetched buffer
        pos = np.searchsorted(fetched, padded).astype(np.int32)
        pos = np.where(parts.mask.ravel(), pos, 0).reshape(parts.nbrs.shape)
        fetch_lists.append(fetched.astype(np.int32))
        parts_list.append(
            NeighborPartitions(pos, parts.mask, parts.targets, ps)
        )
    f_max = max(1, max(len(f) for f in fetch_lists))
    p_max = max(1, max(p.num_partitions for p in parts_list))
    fetch = np.zeros((n_dev, f_max), dtype=np.int32)
    nbrs = np.zeros((n_dev, p_max, ps), dtype=np.int32)
    mask = np.zeros((n_dev, p_max, ps), dtype=bool)
    tgt = np.zeros((n_dev, p_max), dtype=np.int32)
    for d in range(n_dev):
        fetch[d, : len(fetch_lists[d])] = fetch_lists[d]
        nbrs[d], mask[d], tgt[d] = _pad_parts(parts_list[d], p_max)
    return dict(
        fetch_rows=fetch, nbrs=nbrs, mask=mask, targets=tgt,
        rows_per_dev=rows,
        fetched_rows_per_dev=[len(f) for f in fetch_lists],
    )


def pad_table(bounds: np.ndarray, rows: int, x: np.ndarray) -> np.ndarray:
    """Scatter a (num_nodes, D) table into the padded PGAS layout
    (n_dev * rows, D): shard d holds global rows [bounds[d], bounds[d+1])."""
    n_dev = bounds.shape[0] - 1
    out = np.zeros((n_dev * rows,) + x.shape[1:], dtype=x.dtype)
    for dev in range(n_dev):
        lb, ub = int(bounds[dev]), int(bounds[dev + 1])
        out[dev * rows : dev * rows + (ub - lb)] = x[lb:ub]
    return out


def unpad_table(bounds: np.ndarray, rows: int, x: np.ndarray) -> np.ndarray:
    """Inverse of :func:`pad_table`."""
    num_nodes = int(bounds[-1])
    out = np.zeros((num_nodes,) + x.shape[1:], dtype=x.dtype)
    for dev in range(bounds.shape[0] - 1):
        lb, ub = int(bounds[dev]), int(bounds[dev + 1])
        out[lb:ub] = x[dev * rows : dev * rows + (ub - lb)]
    return out


def pgas_rows(plan: AggregationPlan, ids: np.ndarray) -> np.ndarray:
    """Global node ids → row offsets in the plan's padded PGAS table.

    The serving engine uses this to turn request seed ids into gather rows
    of the (sharded) logits/embedding tables.
    """
    return _padded_offset(plan.bounds, plan.rows_per_dev,
                          np.asarray(ids, dtype=np.int64))


def pad_embeddings(plan: AggregationPlan, x: np.ndarray) -> np.ndarray:
    """:func:`pad_table` using an :class:`AggregationPlan`'s layout."""
    return pad_table(plan.bounds, plan.rows_per_dev, x)


def unpad_embeddings(plan: AggregationPlan, x: np.ndarray) -> np.ndarray:
    """Inverse of :func:`pad_embeddings`."""
    return unpad_table(plan.bounds, plan.rows_per_dev, x)
