"""Pipeline-aware workload management (paper §3.1).

Three stages, faithful to MGG:

1. **Edge-balanced node split** — partition nodes into ``num_parts``
   contiguous ranges holding an approximately equal number of *edges*
   (Algorithm 1's range-constrained binary search over the CSR row pointer).
2. **Locality-aware edge split** — per partition, split incident edges into a
   *local* virtual graph (neighbor owned by the same partition) and a
   *remote* virtual graph (neighbor owned elsewhere), two separate CSRs whose
   partial aggregates are summed (paper Fig. 4a-1).
3. **Workload-aware neighbor split** — chop each virtual-graph row into
   fixed-size neighbor partitions of ``ps`` neighbors (paper Fig. 4a-2) so
   every work unit (GPU warp there, Pallas grid cell / ring-round slice here)
   carries uniform work.

Everything is host-side NumPy: this is the cheap preprocessing the paper
contrasts with DGCL's expensive partitioner (Table 4).
"""
from __future__ import annotations

import dataclasses
from typing import List, Tuple

import numpy as np

from .graph import CSRGraph

__all__ = [
    "edge_balanced_node_split",
    "locality_edge_split",
    "neighbor_partitions",
    "NeighborPartitions",
    "VirtualGraphs",
]


def edge_balanced_node_split(indptr: np.ndarray, num_parts: int) -> np.ndarray:
    """Algorithm 1: choose ``num_parts - 1`` node split points so that each
    contiguous node range covers ~``nnz / num_parts`` edges.

    Returns ``bounds`` of length ``num_parts + 1`` with ``bounds[0] == 0`` and
    ``bounds[-1] == num_nodes``; partition ``p`` owns nodes
    ``[bounds[p], bounds[p+1])``.

    The paper's range-constrained binary search looks, per split point, for
    the node id whose cumulative edge count first reaches
    ``lastSplitEdges + ePerGPU``.  ``indptr`` is exactly that cumulative edge
    count, so each search is a ``searchsorted`` over ``indptr`` restricted to
    ``[lastPos, num_nodes]`` — identical result, branch-free.
    """
    num_nodes = indptr.shape[0] - 1
    nnz = int(indptr[-1])
    if num_parts <= 0:
        raise ValueError("num_parts must be positive")
    e_per_part = (nnz + num_parts - 1) // num_parts  # paper line 2 (ceil)
    bounds = np.zeros(num_parts + 1, dtype=np.int64)
    bounds[-1] = num_nodes
    last = 0
    for p in range(1, num_parts):
        target = min(int(indptr[last]) + e_per_part, nnz)
        # first node id in (last, num_nodes] whose indptr >= target
        nid = int(np.searchsorted(indptr, target, side="left"))
        nid = max(nid, last + 1) if last + 1 <= num_nodes else num_nodes
        nid = min(nid, num_nodes)
        bounds[p] = nid
        last = nid
    # Monotonic repair for degenerate cases (many empty rows / tiny graphs).
    for p in range(1, num_parts + 1):
        bounds[p] = max(bounds[p], bounds[p - 1])
    return bounds


@dataclasses.dataclass(frozen=True)
class VirtualGraphs:
    """Local + remote virtual CSRs for one node partition (paper Fig. 4a-1).

    Rows are partition-local (``0 .. n_local``); ``local.indices`` hold
    *global* neighbor ids within this partition's own range, while
    ``remote.indices`` hold global neighbor ids owned by other partitions.
    """

    part_id: int
    lb: int  # global node-id lower bound (inclusive)
    ub: int  # global node-id upper bound (exclusive)
    local: CSRGraph
    remote: CSRGraph

    @property
    def n_local_nodes(self) -> int:
        return self.ub - self.lb


def locality_edge_split(
    graph: CSRGraph, bounds: np.ndarray, part_id: int
) -> VirtualGraphs:
    """Split partition ``part_id``'s rows into local/remote virtual CSRs."""
    lb, ub = int(bounds[part_id]), int(bounds[part_id + 1])
    n_rows = ub - lb
    row_start = graph.indptr[lb:ub]
    row_end = graph.indptr[lb + 1 : ub + 1]
    deg = (row_end - row_start).astype(np.int64)
    cols = graph.indices[graph.indptr[lb] : graph.indptr[ub]]
    rows = np.repeat(np.arange(n_rows, dtype=np.int64), deg)
    is_local = (cols >= lb) & (cols < ub)

    def _build(mask: np.ndarray) -> CSRGraph:
        sel_rows, sel_cols = rows[mask], cols[mask]
        counts = np.bincount(sel_rows, minlength=n_rows)
        indptr = np.zeros(n_rows + 1, dtype=np.int64)
        np.cumsum(counts, out=indptr[1:])
        # rows are already sorted (CSR order preserved under boolean mask)
        return CSRGraph(indptr, sel_cols.astype(np.int32), n_rows)

    return VirtualGraphs(
        part_id=part_id,
        lb=lb,
        ub=ub,
        local=_build(is_local),
        remote=_build(~is_local),
    )


@dataclasses.dataclass(frozen=True)
class NeighborPartitions:
    """Fixed-size neighbor partitions of one virtual CSR (paper Fig. 4a-2).

    ``nbrs[p, j]`` is the j-th neighbor id of partition ``p`` (padded),
    ``mask[p, j]`` marks valid slots, ``targets[p]`` is the partition-local
    destination row.  Every partition carries at most ``ps`` neighbors of a
    single destination node, so per-work-unit cost is uniform — the paper's
    answer to inter-node workload imbalance.
    """

    nbrs: np.ndarray  # (P, ps) int32, padded with 0
    mask: np.ndarray  # (P, ps) bool
    targets: np.ndarray  # (P,) int32
    ps: int

    @property
    def num_partitions(self) -> int:
        return int(self.targets.shape[0])


def neighbor_partitions(csr: CSRGraph, ps: int) -> NeighborPartitions:
    """Chop each CSR row into ceil(deg/ps) partitions of ``ps`` slots."""
    if ps <= 0:
        raise ValueError("ps must be positive")
    deg = csr.degrees.astype(np.int64)
    parts_per_row = (deg + ps - 1) // ps
    total = int(parts_per_row.sum())
    nbrs = np.zeros((total, ps), dtype=np.int32)
    mask = np.zeros((total, ps), dtype=bool)
    targets = np.repeat(
        np.arange(csr.num_nodes, dtype=np.int32), parts_per_row
    )
    if total == 0:
        return NeighborPartitions(nbrs, mask, targets, ps)
    # Vectorized fill: edge e of row v goes to partition base[v] + off // ps,
    # slot off % ps, where off is e's offset within its row.
    part_base = np.zeros(csr.num_nodes, dtype=np.int64)
    np.cumsum(parts_per_row[:-1], out=part_base[1:])
    row_ids = np.repeat(np.arange(csr.num_nodes, dtype=np.int64), deg)
    offs = np.arange(csr.num_edges, dtype=np.int64) - csr.indptr[:-1][row_ids]
    p_idx = part_base[row_ids] + offs // ps
    s_idx = offs % ps
    nbrs[p_idx, s_idx] = csr.indices
    mask[p_idx, s_idx] = True
    return NeighborPartitions(nbrs, mask, targets, ps)


def split_summary(graph: CSRGraph, bounds: np.ndarray) -> List[Tuple[int, int, int]]:
    """(edges, local_edges, remote_edges) per partition — for benchmarks."""
    out = []
    for p in range(bounds.shape[0] - 1):
        vg = locality_edge_split(graph, bounds, p)
        out.append(
            (vg.local.num_edges + vg.remote.num_edges,
             vg.local.num_edges, vg.remote.num_edges)
        )
    return out
