"""Decoder-only LM assembly for the assigned architecture pool.

One parameter/pytree layout + three entry points per architecture family:

* ``loss_fn``  — training forward + next-token CE (the ``train_step`` body)
* ``prefill``  — run the prompt, fill decode caches, return last-pos logits
* ``decode_step`` — one token with O(1)/ring-buffer caches

Families (cfg.family):
  dense   — GQA transformer (codeqwen / nemo / qwen3 / starcoder2)
  moe     — dense attention + MoE FFN (mixtral, granite)
  vlm     — dense backbone with stub visual-token prefix (internvl2)
  hybrid  — Mamba2 stack with a *shared* attention block every
            ``attn_every`` layers (zamba2)
  xlstm   — alternating mLSTM/sLSTM groups (xlstm-125m)
(whisper's encoder-decoder lives in encdec.py.)

Layers are stacked and driven by ``lax.scan`` (one traced block per family
⇒ O(1) HLO size for 80-layer models) with per-layer ``jax.checkpoint``
(remat) in training.  Activation sharding is anchored by
``with_sharding_constraint`` using the DistCtx's logical rules: residual
stream is (batch=data, seq=model, d) — Megatron-style sequence parallelism.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from . import moe as moe_lib
from . import ssm as ssm_lib
from . import xlstm as xlstm_lib
from .layers import (
    KVCache, attention_apply, attention_init, embed_init, embed_lookup,
    kv_cache_init, layer_norm, mlp_apply, mlp_init, rms_norm, unembed_logits,
)

__all__ = ["DistCtx", "init_params", "loss_fn", "forward", "prefill",
           "decode_step", "init_cache", "cache_length"]


@dataclasses.dataclass(frozen=True)
class DistCtx:
    """Distribution context threaded through the model (None ⇒ single chip)."""

    mesh: Optional[Mesh] = None
    data_axes: Tuple[str, ...] = ("data",)
    model_axis: str = "model"
    moe_pipeline_chunks: int = 1   # MGG pipelining depth for EP dispatch
    shard_activations: bool = True
    # Route the TP matmuls through the ring-pipelined collectives
    # (dist.collectives.ring_allgather_matmul / matmul_reducescatter)
    # instead of XLA's default SPMD all-gather/reduce-scatter.  Off by
    # default; layers fall back to the plain matmul whenever shapes don't
    # divide the model axis (decode S=1, odd head counts, ...).
    use_ring_tp: bool = False
    # Megatron-style sequence-parallel residual stream.  WRONG for
    # recurrent families (xlstm/hybrid): their per-timestep/per-chunk scans
    # would reshard the sequence dim every iteration (measured: 24,604
    # all-reduces for xlstm-125m × train_4k) — launch/cells.py turns it off
    # for those families.
    seq_shard_acts: bool = True

    def constrain(self, h: jax.Array, spec: P) -> jax.Array:
        if self.mesh is None or not self.shard_activations:
            return h
        return lax.with_sharding_constraint(h, NamedSharding(self.mesh, spec))

    def act_spec(self, seq_sharded: bool = True) -> P:
        seq = seq_sharded and self.seq_shard_acts
        return P(self.data_axes, self.model_axis if seq else None, None)


def _norm(h, w, cfg):
    if cfg.norm == "ln":
        return layer_norm(h, w["scale"], w["bias"], cfg.norm_eps)
    return rms_norm(h, w["scale"], cfg.norm_eps)


def _norm_init(cfg):
    w = dict(scale=jnp.ones((cfg.d_model,), cfg.param_dtype))
    if cfg.norm == "ln":
        w["bias"] = jnp.zeros((cfg.d_model,), cfg.param_dtype)
    return w


# ---------------------------------------------------------------------------
# per-family block init / apply
# ---------------------------------------------------------------------------

def _dense_block_init(key, cfg):
    k1, k2 = jax.random.split(key)
    return dict(ln1=_norm_init(cfg), attn=attention_init(k1, cfg),
                ln2=_norm_init(cfg), mlp=mlp_init(k2, cfg))


def _moe_block_init(key, cfg):
    k1, k2 = jax.random.split(key)
    return dict(ln1=_norm_init(cfg), attn=attention_init(k1, cfg),
                ln2=_norm_init(cfg), moe=moe_lib.moe_init(k2, cfg))


def _attn_sub(bp, h, cfg, positions, cache, ctx):
    a, new_cache = attention_apply(
        bp["attn"], _norm(h, bp["ln1"], cfg), cfg, positions, cache, ctx=ctx
    )
    return h + a, new_cache


def _dense_block(bp, h, cfg, positions, cache, ctx):
    h, new_cache = _attn_sub(bp, h, cfg, positions, cache, ctx)
    h = h + mlp_apply(bp["mlp"], _norm(h, bp["ln2"], cfg), cfg, ctx=ctx)
    return ctx.constrain(h, ctx.act_spec()), new_cache


def _moe_block(bp, h, cfg, positions, cache, ctx):
    h, new_cache = _attn_sub(bp, h, cfg, positions, cache, ctx)
    z = _norm(h, bp["ln2"], cfg)
    if (cfg.expert_mode == "ep" and ctx.mesh is not None
            and cfg.n_experts % ctx.mesh.shape[ctx.model_axis] == 0):
        y = moe_lib.moe_apply_ep_shard(
            bp["moe"], z, cfg, ctx.mesh,
            data_axes=ctx.data_axes, model_axis=ctx.model_axis,
            pipeline_chunks=ctx.moe_pipeline_chunks,
        )
    else:
        y = moe_lib.moe_apply(bp["moe"], z, cfg, ctx=ctx)
    return ctx.constrain(h + y, ctx.act_spec()), new_cache


def _mamba_block_init(key, cfg):
    return dict(ln=_norm_init(cfg), ssm=ssm_lib.ssm_init(key, cfg))


def _mamba_block(bp, h, cfg, positions, state, ctx, *, step: bool):
    z = _norm(h, bp["ln"], cfg)
    if step:
        y, new_state = ssm_lib.ssm_step(bp["ssm"], z, cfg, state)
    else:
        y, new_state = ssm_lib.ssm_apply(bp["ssm"], z, cfg, state)
    return ctx.constrain(h + y, ctx.act_spec()), new_state


def _xlstm_block_init(key, cfg, kind: str):
    if kind == "m":
        return dict(ln=_norm_init(cfg), mix=xlstm_lib.mlstm_init(key, cfg))
    return dict(ln=_norm_init(cfg), mix=xlstm_lib.slstm_init(key, cfg))


def _xlstm_block(bp, h, cfg, kind, state, ctx):
    z = _norm(h, bp["ln"], cfg)
    fn = xlstm_lib.mlstm_apply if kind == "m" else xlstm_lib.slstm_apply
    y, new_state = fn(bp["mix"], z, cfg, state=state)
    return ctx.constrain(h + y, ctx.act_spec()), new_state


def _stack(key, n: int, init_fn):
    ps = [init_fn(k) for k in jax.random.split(key, n)]
    return jax.tree.map(lambda *xs: jnp.stack(xs), *ps)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _hybrid_layout(cfg) -> Tuple[int, int, int]:
    """(n_groups, group_size, tail) for the hybrid family."""
    gs = max(1, cfg.attn_every)
    n_groups = cfg.n_layers // gs
    tail = cfg.n_layers - n_groups * gs
    return n_groups, gs, tail


def init_params(key, cfg, vocab_multiple: int = 16) -> Dict[str, Any]:
    keys = jax.random.split(key, 8)
    params: Dict[str, Any] = dict(
        embed=embed_init(keys[0], cfg.vocab, cfg.d_model, cfg.param_dtype,
                         vocab_multiple),
        final_norm=_norm_init(cfg),
    )
    if not cfg.tie_embeddings:
        from .layers import dense_init
        params["lm_head"] = dense_init(
            keys[6], cfg.d_model,
            -(-cfg.vocab // vocab_multiple) * vocab_multiple, cfg.param_dtype)
    fam = cfg.family
    if fam in ("dense", "vlm"):
        params["blocks"] = _stack(
            keys[1], cfg.n_layers, lambda k: _dense_block_init(k, cfg))
        if fam == "vlm":
            from .layers import dense_init
            params["vis_proj"] = dense_init(
                keys[2], cfg.d_model, cfg.d_model, cfg.param_dtype)
    elif fam == "moe":
        params["blocks"] = _stack(
            keys[1], cfg.n_layers, lambda k: _moe_block_init(k, cfg))
    elif fam == "hybrid":
        n_groups, gs, tail = _hybrid_layout(cfg)
        params["mamba_main"] = _stack(
            keys[1], n_groups * gs, lambda k: _mamba_block_init(k, cfg))
        if tail:
            params["mamba_tail"] = _stack(
                keys[2], tail, lambda k: _mamba_block_init(k, cfg))
        # zamba2's shared transformer block = attention + MLP (d_ff),
        # ONE param set reused at every application (the arch's trick)
        params["shared_attn"] = _dense_block_init(keys[3], cfg)
    elif fam == "xlstm":
        pat = cfg.xlstm_pattern or ("m", "s")
        n_groups = cfg.n_layers // len(pat)
        for i, kind in enumerate(pat):
            params[f"xl_{i}_{kind}"] = _stack(
                keys[1 + i], n_groups,
                lambda k, kind=kind: _xlstm_block_init(k, cfg, kind))
    else:
        raise ValueError(fam)
    return params


# ---------------------------------------------------------------------------
# caches
# ---------------------------------------------------------------------------

def cache_length(cfg, seq_len: int) -> int:
    """Ring-buffer size: the sliding window bounds it when set."""
    return min(seq_len, cfg.sliding_window) if cfg.sliding_window else seq_len


def init_cache(cfg, batch: int, seq_len: int, dtype=jnp.bfloat16):
    """Decode caches for a maximum context of ``seq_len`` tokens."""
    size = cache_length(cfg, seq_len)
    fam = cfg.family

    def kv(n):
        c = kv_cache_init(cfg, batch, size, dtype)
        return KVCache(
            k=jnp.broadcast_to(c.k, (n,) + c.k.shape),
            v=jnp.broadcast_to(c.v, (n,) + c.v.shape),
            key_pos=jnp.broadcast_to(c.key_pos, (n,) + c.key_pos.shape),
        )

    if fam in ("dense", "vlm", "moe"):
        return dict(kv=kv(cfg.n_layers))
    if fam == "hybrid":
        n_groups, gs, tail = _hybrid_layout(cfg)
        st = ssm_lib.ssm_state_init(cfg, batch)
        stack = lambda t, n: jax.tree.map(
            lambda x: jnp.broadcast_to(x, (n,) + x.shape), t)
        out = dict(ssm_main=stack(st, n_groups * gs), attn=kv(n_groups))
        if tail:
            out["ssm_tail"] = stack(st, tail)
        return out
    if fam == "xlstm":
        pat = cfg.xlstm_pattern or ("m", "s")
        n_groups = cfg.n_layers // len(pat)
        out = {}
        for i, kind in enumerate(pat):
            st = (xlstm_lib.mlstm_state_init(cfg, batch) if kind == "m"
                  else xlstm_lib.slstm_state_init(cfg, batch))
            out[f"xl_{i}_{kind}"] = jax.tree.map(
                lambda x: jnp.broadcast_to(x, (n_groups,) + x.shape), st)
        return out
    raise ValueError(fam)


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------

def _scan_blocks(blocks, h, fn, cache=None, remat: bool = False):
    """Scan ``fn(bp, h, cache_slice) -> (h, new_cache_slice)`` over layers."""

    def body(h, xs):
        bp, c = xs
        h, new_c = fn(bp, h, c)
        return h, new_c

    if remat:
        body = jax.checkpoint(body)
    if cache is None:
        n = jax.tree.leaves(blocks)[0].shape[0]
        h, _ = lax.scan(body, h, (blocks, None), length=n)
        return h, None
    return lax.scan(body, h, (blocks, cache))


def forward(
    params: Dict[str, Any],
    cfg,
    tokens: jax.Array,                 # (B, S)
    *,
    ctx: DistCtx = DistCtx(),
    positions: Optional[jax.Array] = None,
    cache=None,
    vis: Optional[jax.Array] = None,   # vlm: (B, n_vis, d_model)
    remat: Optional[bool] = None,
    step: bool = False,                # decode single-step mode
):
    """Returns (logits, new_cache)."""
    b, s = tokens.shape
    remat = cfg.remat if remat is None else remat
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    h = embed_lookup(params["embed"], tokens, cfg.cdtype)
    n_vis = 0
    if cfg.family == "vlm" and vis is not None:
        hv = vis.astype(cfg.cdtype) @ params["vis_proj"]["w"].astype(cfg.cdtype)
        h = jnp.concatenate([hv, h], axis=1)
        n_vis = vis.shape[1]
        positions = jnp.broadcast_to(
            jnp.arange(s + n_vis, dtype=jnp.int32), (b, s + n_vis))
    h = ctx.constrain(h, ctx.act_spec(seq_sharded=not step))

    fam = cfg.family
    new_cache = None
    if fam in ("dense", "vlm", "moe"):
        block = _dense_block if fam in ("dense", "vlm") else _moe_block

        def fn(bp, h, c):
            return block(bp, h, cfg, positions,
                         None if c is None else c, ctx)

        h, kv_new = _scan_blocks(params["blocks"], h, fn,
                                 None if cache is None else cache["kv"],
                                 remat)
        if cache is not None:
            new_cache = dict(kv=kv_new)
    elif fam == "hybrid":
        n_groups, gs, tail = _hybrid_layout(cfg)
        mm = params["mamba_main"]
        # reshape the stacked mamba params into (n_groups, gs, ...)
        mm_g = jax.tree.map(
            lambda x: x.reshape((n_groups, gs) + x.shape[1:]), mm)
        c_main = None if cache is None else jax.tree.map(
            lambda x: x.reshape((n_groups, gs) + x.shape[1:]),
            cache["ssm_main"])
        c_attn = None if cache is None else cache["attn"]

        def group_fn(h, xs):
            gp, c_ssm, c_kv = xs

            def inner(h, ys):
                bp, c = ys
                return _mamba_block(bp, h, cfg, positions, c, ctx, step=step)

            h, new_ssm = lax.scan(inner, h, (gp, c_ssm))
            h, new_kv = _dense_block(params["shared_attn"], h, cfg,
                                     positions, c_kv, ctx)
            return h, (new_ssm, new_kv)

        if remat:
            group_fn = jax.checkpoint(group_fn)
        if cache is None:
            def group_fn_nc(h, gp):
                def inner(h, bp):
                    h, _ = _mamba_block(bp, h, cfg, positions, None, ctx,
                                        step=False)
                    return h, None
                h, _ = lax.scan(inner, h, gp)
                h, _ = _dense_block(params["shared_attn"], h, cfg,
                                    positions, None, ctx)
                return h, None
            if remat:
                group_fn_nc = jax.checkpoint(group_fn_nc)
            h, _ = lax.scan(group_fn_nc, h, mm_g)
        else:
            h, (new_ssm, new_kv) = lax.scan(
                group_fn, h, (mm_g, c_main, c_attn))
            new_cache = dict(
                ssm_main=jax.tree.map(
                    lambda x: x.reshape((n_groups * gs,) + x.shape[2:]),
                    new_ssm),
                attn=new_kv,
            )
        if tail:
            def tail_fn(h, xs):
                bp, c = xs
                return _mamba_block(bp, h, cfg, positions, c, ctx, step=step)
            if cache is None:
                def tail_fn_nc(h, bp):
                    h, _ = _mamba_block(bp, h, cfg, positions, None, ctx,
                                        step=False)
                    return h, None
                h, _ = lax.scan(tail_fn_nc, h, params["mamba_tail"])
            else:
                h, new_tail = lax.scan(
                    tail_fn, h, (params["mamba_tail"], cache["ssm_tail"]))
                new_cache["ssm_tail"] = new_tail
    elif fam == "xlstm":
        pat = cfg.xlstm_pattern or ("m", "s")
        n_groups = cfg.n_layers // len(pat)
        stacks = [(f"xl_{i}_{kind}", kind) for i, kind in enumerate(pat)]
        new_cache = {} if cache is not None else None

        def group_fn(h, xs):
            # xs: tuple of (bp, c) per pattern element
            new_cs = []
            for (name, kind), (bp, c) in zip(stacks, xs):
                h, nc = _xlstm_block(bp, h, cfg, kind, c, ctx)
                new_cs.append(nc)
            return h, tuple(new_cs)

        if remat:
            group_fn = jax.checkpoint(group_fn)
        xs = tuple(
            (params[name], None if cache is None else cache[name])
            for name, _ in stacks
        )
        if cache is None:
            def group_fn_nc(h, xs):
                for (name, kind), bp in zip(stacks, xs):
                    h, _ = _xlstm_block(bp, h, cfg, kind, None, ctx)
                return h, None
            if remat:
                group_fn_nc = jax.checkpoint(group_fn_nc)
            h, _ = lax.scan(group_fn_nc, h,
                            tuple(params[name] for name, _ in stacks))
        else:
            h, new_cs = lax.scan(group_fn, h, xs)
            for (name, _), nc in zip(stacks, new_cs):
                new_cache[name] = nc
    else:
        raise ValueError(fam)

    h = _norm(h, params["final_norm"], cfg)
    if n_vis:
        h = h[:, n_vis:]
    if cfg.tie_embeddings:
        logits = unembed_logits(params["embed"], h, cfg.vocab)
    else:
        logits = h.astype(jnp.float32) @ params["lm_head"]["w"].astype(jnp.float32)
        logits = logits.at[..., cfg.vocab:].set(-1e30) \
            if logits.shape[-1] != cfg.vocab else logits
    return logits, new_cache


def loss_fn(params, cfg, batch: Dict[str, jax.Array], *,
            ctx: DistCtx = DistCtx()) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Next-token CE (mean over non-masked positions)."""
    tokens = batch["tokens"]
    vis = batch.get("vis")
    logits, _ = forward(params, cfg, tokens, ctx=ctx, vis=vis)
    tgt = tokens[:, 1:]
    lg = logits[:, :-1]
    logp = jax.nn.log_softmax(lg, axis=-1)
    ll = jnp.take_along_axis(logp, tgt[..., None].astype(jnp.int32),
                             axis=-1)[..., 0]
    mask = batch.get("loss_mask")
    mask = jnp.ones_like(ll) if mask is None else mask[:, 1:].astype(ll.dtype)
    loss = -(ll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    return loss, dict(loss=loss, ntokens=mask.sum())


def prefill(params, cfg, tokens, cache, *, ctx: DistCtx = DistCtx(),
            vis=None):
    """Run the prompt; fills caches; returns (last-position logits, cache)."""
    logits, new_cache = forward(
        params, cfg, tokens, ctx=ctx, cache=cache, vis=vis, remat=False)
    return logits[:, -1], new_cache


def decode_step(params, cfg, token, pos, cache, *, ctx: DistCtx = DistCtx()):
    """One decode step. token: (B,) int32; pos: (B,) absolute position."""
    logits, new_cache = forward(
        params, cfg, token[:, None], ctx=ctx,
        positions=pos[:, None], cache=cache, remat=False, step=True)
    return logits[:, 0], new_cache
