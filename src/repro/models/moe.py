"""Mixture-of-Experts with MGG-style pipelined expert dispatch.

Expert dispatch is the LM-side incarnation of the paper's problem: tokens
(= graph nodes) need embeddings processed by experts living on *other* chips
(= remote neighbors).  The paper's §6 generalization (DLRM embedding gather
overlapped with associative interaction) maps 1:1 onto expert-parallel MoE:

* **sort-based dispatch** (this module): tokens are bucketed per expert into
  an ``(E, C, d)`` capacity buffer — the analogue of MGG's fixed-size
  neighbor partitions (uniform work units, imbalance amortized by capacity).
* **EP mode**: the buffer is exchanged with ``all_to_all`` over the model
  axis so each chip holds *its* experts' tokens from all chips.
  ``pipeline_chunks > 1`` splits the capacity axis and double-buffers the
  exchange: the FFN of chunk *k* overlaps the all-to-all of chunk *k+1* —
  the same fori/double-buffer schedule as ``core/pipeline.py``.
* **TP mode** (mixtral: 8 experts < 16-way model axis): experts are
  replicated, ``d_ff`` is sharded over the model axis; no dispatch comm.

Token overflow beyond capacity is dropped (standard capacity-factor
routing); the residual connection preserves those tokens' values.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import Mesh, PartitionSpec as P

from .layers import dense_init

__all__ = ["moe_init", "moe_apply", "moe_apply_ep_shard"]


def moe_init(key, cfg) -> Dict[str, Any]:
    e, d, f = cfg.n_experts, cfg.d_model, cfg.d_ff
    k1, k2, k3, k4 = jax.random.split(key, 4)
    scale = (2.0 / (d + f)) ** 0.5
    p = dict(
        router=dense_init(k1, d, e, cfg.param_dtype),
        w_up=(jax.random.normal(k2, (e, d, f), jnp.float32) * scale
              ).astype(cfg.param_dtype),
        w_down=(jax.random.normal(k3, (e, f, d), jnp.float32) * scale
                ).astype(cfg.param_dtype),
    )
    if cfg.mlp_type == "swiglu":
        p["w_gate"] = (jax.random.normal(k4, (e, d, f), jnp.float32) * scale
                       ).astype(cfg.param_dtype)
    return p


def _route(p, x2d, cfg):
    """Top-k routing. Returns (gates (T,k), experts (T,k))."""
    logits = (x2d @ p["router"]["w"].astype(x2d.dtype)).astype(jnp.float32)
    topv, tope = lax.top_k(logits, cfg.top_k)
    gates = jax.nn.softmax(topv, axis=-1)  # renormalized over selected
    return gates, tope


def _dispatch_indices(tope, n_experts: int, capacity: int):
    """Sort-based capacity dispatch (no (T,E,C) one-hot tensor).

    Returns per-slot token ids (E·C,), per-slot validity, and for each
    (token, k) pair its (expert, slot) position + keep flag.
    """
    t, k = tope.shape
    flat_e = tope.reshape(-1)                      # (T·k,)
    order = jnp.argsort(flat_e, stable=True)       # pairs grouped by expert
    inv = jnp.argsort(order, stable=True)          # pair → rank in sorted
    start = jnp.searchsorted(flat_e[order], jnp.arange(n_experts))  # (E,)
    # slot s of expert e ← pair order[start[e] + s]
    slot_pair = start[:, None] + jnp.arange(capacity)[None, :]      # (E, C)
    slot_valid = slot_pair < jnp.searchsorted(
        flat_e[order], jnp.arange(n_experts) + 1
    )[:, None]
    slot_pair = jnp.clip(slot_pair, 0, t * k - 1)
    pair_id = jnp.take(order, slot_pair)           # (E, C) index into T·k
    slot_token = pair_id // k
    # reverse map: pair (t,k) → its capacity slot
    pair_rank = inv - jnp.take(start, flat_e)      # rank within expert
    pair_kept = pair_rank < capacity
    return slot_token, slot_valid, pair_rank.reshape(t, k), pair_kept.reshape(t, k)


def _expert_ffn(p, xe, cfg):
    """xe: (E, C, d) → (E, C, d); per-expert SwiGLU/GELU FFN."""
    w_up = p["w_up"].astype(xe.dtype)
    w_down = p["w_down"].astype(xe.dtype)
    if "w_gate" in p:
        g = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, p["w_gate"].astype(xe.dtype)))
        u = jnp.einsum("ecd,edf->ecf", xe, w_up)
        return jnp.einsum("ecf,efd->ecd", g * u, w_down)
    h = jax.nn.gelu(jnp.einsum("ecd,edf->ecf", xe, w_up))
    return jnp.einsum("ecf,efd->ecd", h, w_down)


def moe_apply(
    p: Dict[str, Any],
    x: jax.Array,  # (B, S, D)
    cfg,
    *,
    capacity_factor: Optional[float] = None,
    expert_fn=None,
    ctx=None,
) -> jax.Array:
    """Single-program MoE (TP mode / smoke tests): full dispatch→FFN→combine.

    Under GSPMD, ``w_up/w_gate/w_down`` carry a model-axis sharding on the
    ``f`` dimension (TP inside each expert), so this path needs no explicit
    collectives.  ``expert_fn`` lets the EP path reuse dispatch/combine.

    ``ctx`` (transformer.DistCtx): when given, the (E, C, d) dispatch and
    output buffers are sharding-constrained with capacity over the data
    axes.  Without the anchor GSPMD tends to REPLICATE the gathered buffer
    across the model axis and run every expert FFN redundantly on all
    model ranks (caught by the §Roofline useful-FLOPs ratio on
    mixtral × prefill_32k: 18× redundant dot FLOPs).
    """
    b, s, d = x.shape
    t = b * s
    x2d = x.reshape(t, d)
    gates, tope = _route(p, x2d, cfg)
    if capacity_factor is None:
        capacity_factor = cfg.moe_capacity_factor
    capacity = max(1, int(t * cfg.top_k / cfg.n_experts * capacity_factor))
    slot_token, slot_valid, pair_slot, pair_kept = _dispatch_indices(
        tope, cfg.n_experts, capacity
    )
    xe = jnp.take(x2d, slot_token, axis=0) * slot_valid[..., None].astype(x.dtype)
    if ctx is not None and ctx.mesh is not None:
        spec = P(None, ctx.data_axes, None)
        xe = lax.with_sharding_constraint(
            xe, jax.sharding.NamedSharding(ctx.mesh, spec))
    ye = (expert_fn or _expert_ffn)(p, xe, cfg)      # (E, C, d)
    if ctx is not None and ctx.mesh is not None:
        ye = lax.with_sharding_constraint(
            ye, jax.sharding.NamedSharding(ctx.mesh, P(None, ctx.data_axes, None)))
    # combine: token t's k-th pair reads (expert, slot) if kept
    flat = ye.reshape(cfg.n_experts * capacity, d)
    pair_idx = tope * capacity + jnp.clip(pair_slot, 0, capacity - 1)
    y_pairs = jnp.take(flat, pair_idx.reshape(-1), axis=0).reshape(t, cfg.top_k, d)
    w = (gates * pair_kept).astype(x.dtype)
    return jnp.einsum("tkd,tk->td", y_pairs, w).reshape(b, s, d)


# ---------------------------------------------------------------------------
# Expert-parallel path: all_to_all over the model axis, optionally pipelined
# ---------------------------------------------------------------------------

def moe_apply_ep_shard(
    p: Dict[str, Any],
    x: jax.Array,
    cfg,
    mesh: Mesh,
    *,
    data_axes=("data",),
    model_axis: str = "model",
    capacity_factor: Optional[float] = None,
    pipeline_chunks: int = 1,
) -> jax.Array:
    """EP MoE under shard_map: experts sharded over ``model_axis``.

    The dispatch buffer (E, C, d) is exchanged with all_to_all; with
    ``pipeline_chunks > 1`` the capacity axis is chunked and the exchange of
    chunk *k+1* is issued before the expert FFN of chunk *k* consumes its
    buffer — MGG's communication-computation overlap (paper Fig. 7b).
    """
    ep = mesh.shape[model_axis]
    assert cfg.n_experts % ep == 0, (cfg.n_experts, ep)

    def body(p, x):
        # x block: (B_local, S, D); expert weights block: (E/ep, d, f)
        def expert_fn(p_blk, xe, cfg):
            # xe: (E, C, d) local dispatch buffer → exchange → local experts
            e, c, d = xe.shape
            chunks = min(pipeline_chunks, c)
            if c % chunks:
                chunks = 1
            xc = xe.reshape(e, chunks, c // chunks, d)

            def exchange(z):  # (E, c', d) → (E/ep, c'·ep, d)
                return lax.all_to_all(
                    z, model_axis, split_axis=0, concat_axis=1, tiled=True
                )

            def exchange_back(z):
                return lax.all_to_all(
                    z, model_axis, split_axis=1, concat_axis=0, tiled=True
                )

            outs = []
            cur = exchange(xc[:, 0])
            for i in range(chunks):
                nxt = exchange(xc[:, i + 1]) if i + 1 < chunks else None
                y = _expert_ffn(p_blk, cur, cfg)      # overlaps nxt's A2A
                outs.append(exchange_back(y))
                if nxt is not None:
                    cur = nxt
            return jnp.concatenate(outs, axis=1)

        p_local = dict(p)  # router replicated; experts sharded on E
        return moe_apply(
            p_local, x, cfg, capacity_factor=capacity_factor,
            expert_fn=expert_fn,
        )

    pspec = dict(
        router=dict(w=P()),
        w_up=P(model_axis, None, None),
        w_down=P(model_axis, None, None),
    )
    if "w_gate" in p:
        pspec["w_gate"] = P(model_axis, None, None)
    # Tokens are sharded over the model axis too (sequence split): every
    # chip routes a DISTINCT token slice.  Replicating tokens over the
    # model axis would make each chip compute identical dispatch buffers —
    # an ep-fold redundancy (caught by the §Roofline useful-FLOPs ratio).
    seq_shardable = x.shape[1] % ep == 0 and x.shape[1] >= ep
    x_spec = P(data_axes, model_axis if seq_shardable else None, None)
    fn = jax.shard_map(
        body, mesh=mesh, in_specs=(pspec, x_spec), out_specs=x_spec,
        check_vma=False,
    )
    return fn(p, x)
