"""xLSTM blocks (Beck et al. 2024): mLSTM (matrix memory) + sLSTM (scalar
memory with recurrent gating), for the xlstm-125m architecture.

* **mLSTM** is linear-attention-like and admits a chunkwise-parallel form:
  within a chunk, token-token terms are a masked matmul (MXU-friendly);
  across chunks the matrix memory ``C (B,H,dk,dv)`` and normalizer
  ``n (B,H,dk)`` are carried by ``lax.scan``.  Gate stabilization follows
  the paper's max-state trick ``m_t`` (carried across chunks).
* **sLSTM** has a true recurrent connection (hidden state feeds the gates),
  so it is inherently sequential: a ``lax.scan`` over time with per-head
  block-diagonal recurrent weights.

Both are O(1)-state at decode time — the property that makes the
``long_500k`` cell runnable for this family.

Simplifications vs. the reference (noted per the brief): single projection
block per layer (the reference wraps mLSTM in an up/down projection of
factor 2 — kept), conv4 front omitted, forget gate is ``exp``-parameterized
with sigmoid-bounded alternative folded into the bias init.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from .layers import dense_init, rms_norm

__all__ = [
    "mlstm_init", "mlstm_apply", "mlstm_step", "mlstm_state_init",
    "slstm_init", "slstm_apply", "slstm_step", "slstm_state_init",
]


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

def _mdims(cfg):
    d_in = cfg.d_model * 2          # up-projection factor 2
    heads = cfg.n_heads
    dk = d_in // heads
    return d_in, heads, dk


def mlstm_init(key, cfg) -> Dict[str, Any]:
    d, (d_in, heads, dk) = cfg.d_model, _mdims(cfg)
    ks = jax.random.split(key, 7)
    return dict(
        up=dense_init(ks[0], d, 2 * d_in, cfg.param_dtype),   # x, z-gate
        wq=dense_init(ks[1], d_in, d_in, cfg.param_dtype),
        wk=dense_init(ks[2], d_in, d_in, cfg.param_dtype),
        wv=dense_init(ks[3], d_in, d_in, cfg.param_dtype),
        wif=dense_init(ks[4], d_in, 2 * heads, cfg.param_dtype),  # i, f gates
        fgate_bias=jnp.full((heads,), 3.0, jnp.float32),
        norm_w=jnp.ones((d_in,), cfg.param_dtype),
        down=dense_init(ks[5], d_in, d, cfg.param_dtype),
    )


def mlstm_state_init(cfg, batch: int, dtype=jnp.float32):
    d_in, heads, dk = _mdims(cfg)
    return dict(
        c=jnp.zeros((batch, heads, dk, dk), dtype),
        n=jnp.zeros((batch, heads, dk), dtype),
        m=jnp.full((batch, heads), -1e30, dtype),
    )


def _mlstm_qkvif(p, x, cfg):
    d_in, heads, dk = _mdims(cfg)
    b, s, _ = x.shape
    up = x @ p["up"]["w"].astype(x.dtype)
    xi, z = up[..., :d_in], up[..., d_in:]
    q = (xi @ p["wq"]["w"].astype(x.dtype)).reshape(b, s, heads, dk)
    k = (xi @ p["wk"]["w"].astype(x.dtype)).reshape(b, s, heads, dk) * dk**-0.5
    v = (xi @ p["wv"]["w"].astype(x.dtype)).reshape(b, s, heads, dk)
    gif = (xi @ p["wif"]["w"].astype(x.dtype)).astype(jnp.float32)
    log_i = gif[..., :heads]                                   # (B,S,H)
    log_f = jax.nn.log_sigmoid(gif[..., heads:] + p["fgate_bias"])
    return xi, z, q, k, v, log_i, log_f


def mlstm_apply(
    p: Dict[str, Any], x: jax.Array, cfg,
    state: Optional[Dict[str, jax.Array]] = None,
) -> Tuple[jax.Array, Optional[Dict[str, jax.Array]]]:
    """Chunkwise-parallel mLSTM over a sequence. x: (B, S, D)."""
    b, s, d = x.shape
    d_in, heads, dk = _mdims(cfg)
    xi, z, q, k, v, log_i, log_f = _mlstm_qkvif(p, x, cfg)

    chunk = min(cfg.ssm_chunk, s)
    if s % chunk:
        chunk = s
    n_ch = s // chunk

    def r(t):  # (B, S, ...) → chunk-major (n_ch, B, chunk, ...)
        return jnp.moveaxis(
            t.reshape((b, n_ch, chunk) + t.shape[2:]), 1, 0
        )

    st = state or mlstm_state_init(cfg, b)
    carry0 = (st["c"].astype(jnp.float32), st["n"].astype(jnp.float32),
              st["m"].astype(jnp.float32))

    def chunk_body(carry, inp):
        c, n, m = carry                     # (B,H,dk,dk), (B,H,dk), (B,H)
        qk_, kk_, vk_, li, lf = inp
        qf = qk_.astype(jnp.float32)
        kf = kk_.astype(jnp.float32)
        vf = vk_.astype(jnp.float32)
        cum_f = jnp.cumsum(lf, axis=1)                         # (B,c,H)
        # stabilizer: running max of (m_prev + cum_f_i) vs intra (cum_f_i −
        # cum_f_j + log_i_j); use per-position bound  m_i = max(...)
        inter_log = m[:, None, :] + cum_f                      # (B,c,H)
        intra_log = cum_f[:, :, None, :] - cum_f[:, None, :, :] \
            + li[:, None, :, :]                                # (B,c,c,H)
        mask = jnp.tril(jnp.ones((chunk, chunk), bool))
        intra_log = jnp.where(mask[None, :, :, None], intra_log, -1e30)
        m_new = jnp.maximum(inter_log, intra_log.max(axis=2))  # (B,c,H)
        w_intra = jnp.exp(intra_log - m_new[:, :, None, :])    # (B,c,c,H)
        w_inter = jnp.exp(inter_log - m_new)                   # (B,c,H)
        scores = jnp.einsum("bihd,bjhd->bijh", qf, kf) * w_intra
        num = jnp.einsum("bijh,bjhd->bihd", scores, vf)
        num += jnp.einsum("bihd,bhde,bih->bihe", qf, c, w_inter)
        den = scores.sum(axis=2)                               # (B,c,H)
        den += jnp.einsum("bihd,bhd,bih->bih", qf, n, w_inter)
        h = num / jnp.maximum(jnp.abs(den), 1.0)[..., None]
        # carry update (stabilized at the chunk-final max)
        m_last = m_new[:, -1]                                  # (B,H)
        wk_c = jnp.exp(cum_f[:, -1:, :] - cum_f + li - m_last[:, None, :])
        c = c * jnp.exp(m[:, :, None, None] + cum_f[:, -1][:, :, None, None]
                        - m_last[:, :, None, None]) \
            + jnp.einsum("bjh,bjhd,bjhe->bhde", wk_c, kf, vf)
        n = n * jnp.exp(m + cum_f[:, -1] - m_last)[..., None] \
            + jnp.einsum("bjh,bjhd->bhd", wk_c, kf)
        return (c, n, m_last), h

    (c, n, m), hs = lax.scan(
        chunk_body, carry0, (r(q), r(k), r(v), r(log_i), r(log_f))
    )
    h = jnp.moveaxis(hs, 0, 1).reshape(b, s, d_in).astype(x.dtype)
    h = rms_norm(h, p["norm_w"], cfg.norm_eps) * jax.nn.silu(z)
    out = h @ p["down"]["w"].astype(x.dtype)
    new_state = None
    if state is not None:
        new_state = dict(c=c.astype(state["c"].dtype),
                         n=n.astype(state["n"].dtype),
                         m=m.astype(state["m"].dtype))
    return out, new_state


def mlstm_step(p, x, cfg, state):
    """Single-token decode. x: (B, 1, D)."""
    out, st = mlstm_apply(p, x, cfg, state=state)
    return out, st


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def slstm_init(key, cfg) -> Dict[str, Any]:
    d = cfg.d_model
    heads = cfg.n_heads
    hd = d // heads
    ks = jax.random.split(key, 3)
    return dict(
        # input weights for (z, i, f, o) gates
        wx=dense_init(ks[0], d, 4 * d, cfg.param_dtype),
        # block-diagonal recurrent weights, per head: (H, hd, 4*hd)
        wr=(jax.random.normal(ks[1], (heads, hd, 4 * hd), jnp.float32)
            * hd ** -0.5).astype(cfg.param_dtype),
        bias=jnp.zeros((4 * d,), jnp.float32),
        norm_w=jnp.ones((d,), cfg.param_dtype),
        out=dense_init(ks[2], d, d, cfg.param_dtype),
    )


def slstm_state_init(cfg, batch: int, dtype=jnp.float32):
    d, heads = cfg.d_model, cfg.n_heads
    hd = d // heads
    z = jnp.zeros((batch, heads, hd), dtype)
    return dict(h=z, c=z, n=jnp.ones_like(z), m=jnp.zeros((batch, heads, hd), dtype))


def _slstm_cell(p, xt_proj, st, cfg):
    """One sLSTM step. xt_proj: (B, 4D) precomputed Wx·x_t + b."""
    d, heads = cfg.d_model, cfg.n_heads
    hd = d // heads
    b = xt_proj.shape[0]
    h, c, n, m = st["h"], st["c"], st["n"], st["m"]   # (B, H, hd)
    rec = jnp.einsum("bhd,hdg->bhg", h.astype(jnp.float32),
                     p["wr"].astype(jnp.float32))     # (B, H, 4·hd)
    gates = xt_proj.reshape(b, heads, 4 * hd).astype(jnp.float32) + rec
    zt = jnp.tanh(gates[..., 0 * hd : 1 * hd])
    log_i = gates[..., 1 * hd : 2 * hd]
    log_f = jax.nn.log_sigmoid(gates[..., 2 * hd : 3 * hd])
    ot = jax.nn.sigmoid(gates[..., 3 * hd : 4 * hd])
    m_new = jnp.maximum(log_f + m, log_i)
    i_p = jnp.exp(log_i - m_new)
    f_p = jnp.exp(log_f + m - m_new)
    c = f_p * c + i_p * zt
    n = f_p * n + i_p
    h = ot * c / jnp.maximum(jnp.abs(n), 1.0)
    return dict(h=h, c=c, n=n, m=m_new)


def slstm_apply(
    p: Dict[str, Any], x: jax.Array, cfg,
    state: Optional[Dict[str, jax.Array]] = None,
) -> Tuple[jax.Array, Optional[Dict[str, jax.Array]]]:
    """Sequential sLSTM over the sequence (true recurrence). x: (B, S, D)."""
    b, s, d = x.shape
    heads = cfg.n_heads
    hd = d // heads
    xp = (x @ p["wx"]["w"].astype(x.dtype)).astype(jnp.float32) \
        + p["bias"][None, None]
    st = state or slstm_state_init(cfg, b)
    st = {k: v.astype(jnp.float32) for k, v in st.items()}

    def step(carry, xt):
        new = _slstm_cell(p, xt, carry, cfg)
        return new, new["h"]

    st_out, hs = lax.scan(step, st, jnp.moveaxis(xp, 0, 1))
    h = jnp.moveaxis(hs, 0, 1).reshape(b, s, d).astype(x.dtype)
    h = rms_norm(h, p["norm_w"], cfg.norm_eps)
    out = h @ p["out"]["w"].astype(x.dtype)
    new_state = None
    if state is not None:
        new_state = {k: v.astype(state[k].dtype) for k, v in st_out.items()}
    return out, new_state


def slstm_step(p, x, cfg, state):
    return slstm_apply(p, x, cfg, state=state)
