"""Shared transformer layer library (no flax — plain pytrees + functions).

Covers every mixer the assigned architecture pool needs:
  * RMSNorm / LayerNorm
  * rotary embeddings (configurable theta; per-head qk_norm for qwen3)
  * GQA attention with: causal masking, sliding windows (mixtral, zamba2
    long-context), chunked "flash-style" softmax (O(S·chunk) memory — a 32k
    prefill never materializes the S×S score matrix), ring-buffer KV caches
    for decode (window-bounded for SWA archs)
  * SwiGLU and GELU MLPs
  * padded vocab embedding / logits (vocab rows padded to the model-axis
    multiple; pad logits are masked to −inf)

Dtype policy: parameters are stored in ``cfg.param_dtype`` and compute runs
in ``cfg.compute_dtype`` (bf16 on TPU); softmax/normalization accumulate in
fp32.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import PartitionSpec as P

__all__ = [
    "rms_norm", "layer_norm", "rope_frequencies", "apply_rope",
    "attention_init", "attention_apply", "mlp_init", "mlp_apply",
    "embed_init", "embed_lookup", "unembed_logits", "dense_init",
    "KVCache", "kv_cache_init", "padded_vocab",
    "ring_tp_colwise", "ring_tp_rowwise",
]

Array = jax.Array


# ---------------------------------------------------------------------------
# ring-pipelined tensor-parallel matmuls (DistCtx.use_ring_tp)
#
# With Megatron-style sequence parallelism the residual stream is sharded on
# the sequence dim over the model axis; a column-parallel matmul needs the
# *full* sequence gathered first, and its row-parallel partner needs a
# reduce(-scatter) after.  XLA's SPMD partitioner inserts a bulk all-gather /
# reduce-scatter around the einsum; these helpers replace that pair with the
# ring-pipelined collectives from repro.dist.collectives, whose per-chunk
# transfer overlaps the previous chunk's matmul (MGG Fig. 7(b) applied to the
# dense LM stack — the ROADMAP "wire collectives into TP matmuls" item).
# ---------------------------------------------------------------------------

def _ring_tp_active(ctx, *dims_divisible) -> bool:
    """True when ctx opted in, the model axis is real, and shapes divide."""
    if ctx is None or not getattr(ctx, "use_ring_tp", False) \
            or getattr(ctx, "mesh", None) is None:
        return False
    m = int(ctx.mesh.shape.get(ctx.model_axis, 1))
    if m <= 1:
        return False
    return all(d % m == 0 for d in dims_divisible)


def _data_size(ctx) -> int:
    import math as _math
    return _math.prod(
        int(ctx.mesh.shape.get(a, 1)) for a in ctx.data_axes)


def ring_tp_colwise(x: Array, w: Array, ctx) -> Array:
    """``x @ w`` with x (B, S, D) sequence-sharded and w (D, F) column-
    parallel over the model axis → (B, S, F) feature-sharded.

    The sequence all-gather rides the ring fused into the matmul
    (``ring_allgather_matmul``): row block j is multiplied the moment it
    arrives while block j+1 is in flight.  Falls back to a plain matmul
    (XLA SPMD collectives) when the flag is off or shapes don't divide.
    """
    b, s, d = x.shape
    f = w.shape[-1]
    if not _ring_tp_active(ctx, s, f) or b % _data_size(ctx) != 0:
        return x @ w
    from repro.dist.collectives import ring_allgather_matmul

    mesh, axis = ctx.mesh, ctx.model_axis
    m = int(mesh.shape[axis])

    def body(xs, ws):
        bl, sl, _ = xs.shape       # (B_l, S/m, D), ws: (D, F/m)
        lhs = xs.reshape(bl * sl, d)
        out = ring_allgather_matmul(lhs, ws, axis)   # (m·B_l·S_l, F/m)
        out = out.reshape(m, bl, sl, ws.shape[-1])
        return jnp.moveaxis(out, 0, 1).reshape(bl, m * sl, ws.shape[-1])

    fn = jax.shard_map(
        body, mesh=mesh,
        in_specs=(P(ctx.data_axes, axis, None), P(None, axis)),
        out_specs=P(ctx.data_axes, None, axis),
        check_vma=False,
    )
    return fn(x, w)


def ring_tp_rowwise(x: Array, w: Array, ctx) -> Array:
    """``x @ w`` with x (B, S, F) feature-sharded and w (F, D) row-parallel
    over the model axis → (B, S, D) sequence-sharded.

    The partial-sum reduce-scatter is fused into a pipelined ring
    (``matmul_reducescatter``): each step computes one output row block
    while the travelling accumulator is on the wire.
    """
    b, s, f = x.shape
    d = w.shape[-1]
    if not _ring_tp_active(ctx, s, f) or b % _data_size(ctx) != 0:
        return x @ w
    from repro.dist.collectives import matmul_reducescatter

    mesh, axis = ctx.mesh, ctx.model_axis
    m = int(mesh.shape[axis])

    def body(xs, ws):
        bl, _, fl = xs.shape       # (B_l, S, F/m), ws: (F/m, D)
        sl = s // m
        # shard-major row order so shard i's reduce-scatter chunk is its
        # own sequence block (matching the colwise gather order)
        lhs = xs.reshape(bl, m, sl, fl)
        lhs = jnp.moveaxis(lhs, 1, 0).reshape(m * bl * sl, fl)
        out = matmul_reducescatter(lhs, ws, axis)    # (B_l·S_l, D)
        return out.reshape(bl, sl, d)

    fn = jax.shard_map(
        body, mesh=mesh,
        in_specs=(P(ctx.data_axes, None, axis), P(axis, None)),
        out_specs=P(ctx.data_axes, axis, None),
        check_vma=False,
    )
    return fn(x, w)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def rms_norm(x: Array, w: Array, eps: float = 1e-6) -> Array:
    xf = x.astype(jnp.float32)
    scale = lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return (xf * scale).astype(x.dtype) * w.astype(x.dtype)


def layer_norm(x: Array, w: Array, b: Array, eps: float = 1e-5) -> Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean((xf - mu) ** 2, axis=-1, keepdims=True)
    y = (xf - mu) * lax.rsqrt(var + eps)
    return y.astype(x.dtype) * w.astype(x.dtype) + b.astype(x.dtype)


# ---------------------------------------------------------------------------
# rotary embeddings
# ---------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float) -> np.ndarray:
    return 1.0 / (theta ** (np.arange(0, head_dim, 2) / head_dim))


def apply_rope(x: Array, positions: Array, theta: float) -> Array:
    """x: (B, S, H, hd); positions: (B, S) int32."""
    hd = x.shape[-1]
    freqs = jnp.asarray(rope_frequencies(hd, theta), jnp.float32)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (B, S, hd/2)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# dense / embedding primitives
# ---------------------------------------------------------------------------

def dense_init(key, fan_in: int, fan_out: int, dtype) -> Dict[str, Array]:
    scale = (2.0 / (fan_in + fan_out)) ** 0.5
    return dict(w=(jax.random.normal(key, (fan_in, fan_out), jnp.float32)
                   * scale).astype(dtype))


def padded_vocab(vocab: int, multiple: int) -> int:
    return -(-vocab // multiple) * multiple


def embed_init(key, vocab: int, d_model: int, dtype, multiple: int = 16):
    vp = padded_vocab(vocab, multiple)
    w = jax.random.normal(key, (vp, d_model), jnp.float32) * (d_model ** -0.5)
    return dict(w=w.astype(dtype))


def embed_lookup(emb: Dict[str, Array], tokens: Array, compute_dtype) -> Array:
    return jnp.take(emb["w"], tokens, axis=0).astype(compute_dtype)


def unembed_logits(emb: Dict[str, Array], h: Array, vocab: int) -> Array:
    """Tied unembedding; pad logits masked to −inf (fp32)."""
    logits = jnp.einsum(
        "bsd,vd->bsv", h.astype(jnp.float32), emb["w"].astype(jnp.float32)
    )
    vp = emb["w"].shape[0]
    if vp != vocab:
        neg = jnp.full((vp - vocab,), -1e30, jnp.float32)
        logits = logits.at[..., vocab:].set(neg)
    return logits


# ---------------------------------------------------------------------------
# attention (GQA + RoPE + qk_norm + sliding window + chunked softmax)
# ---------------------------------------------------------------------------

def attention_init(key, cfg) -> Dict[str, Any]:
    hd = cfg.head_dim
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    p = dict(
        wq=dense_init(k1, cfg.d_model, cfg.n_heads * hd, cfg.param_dtype),
        wk=dense_init(k2, cfg.d_model, cfg.n_kv_heads * hd, cfg.param_dtype),
        wv=dense_init(k3, cfg.d_model, cfg.n_kv_heads * hd, cfg.param_dtype),
        wo=dense_init(k4, cfg.n_heads * hd, cfg.d_model, cfg.param_dtype),
    )
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), cfg.param_dtype)
        p["k_norm"] = jnp.ones((hd,), cfg.param_dtype)
    return p


@dataclasses.dataclass
class KVCache:
    """Ring-buffer KV cache: ``size`` slots (= sliding window when set).

    ``k``/``v``: (B, size, KV, hd).  ``key_pos``: (B, size) absolute position
    held in each slot (−1 ⇒ empty).  Slot for position p is ``p % size``.
    """

    k: Array
    v: Array
    key_pos: Array

    def tree_flatten(self):
        return (self.k, self.v, self.key_pos), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


jax.tree_util.register_pytree_node(
    KVCache, KVCache.tree_flatten, KVCache.tree_unflatten
)


def kv_cache_init(cfg, batch: int, size: int, dtype) -> KVCache:
    hd = cfg.head_dim
    return KVCache(
        k=jnp.zeros((batch, size, cfg.n_kv_heads, hd), dtype),
        v=jnp.zeros((batch, size, cfg.n_kv_heads, hd), dtype),
        key_pos=jnp.full((batch, size), -1, jnp.int32),
    )


def _chunked_softmax_attention(
    q: Array,        # (B, S, H, hd)
    k: Array,        # (B, T, KV, hd)
    v: Array,        # (B, T, KV, hd)
    q_pos: Array,    # (B, S)
    k_pos: Array,    # (B, T)  (−1 ⇒ masked slot)
    window: int,     # 0 ⇒ full causal
    chunk: int,
) -> Array:
    """Streaming-softmax attention over key chunks (flash-attention dataflow).

    Never materializes the (S, T) score matrix: ``T`` is consumed in chunks
    with running max/denominator carries, so a 32k-prefill activation
    footprint is O(S · chunk) per head.
    """
    b, s, h, hd = q.shape
    t = k.shape[1]
    kv = k.shape[2]
    rep = h // kv
    scale = hd ** -0.5
    qf = q.astype(jnp.float32) * scale
    n_chunks = -(-t // chunk)
    t_pad = n_chunks * chunk
    if t_pad != t:
        pad = ((0, 0), (0, t_pad - t), (0, 0), (0, 0))
        k = jnp.pad(k, pad)
        v = jnp.pad(v, pad)
        k_pos = jnp.pad(k_pos, ((0, 0), (0, t_pad - t)), constant_values=-1)
    kc = k.reshape(b, n_chunks, chunk, kv, hd)
    vc = v.reshape(b, n_chunks, chunk, kv, hd)
    pc = k_pos.reshape(b, n_chunks, chunk)

    def body(carry, inp):
        acc, m, l = carry                  # (B,S,H,hd), (B,S,H), (B,S,H)
        kb, vb, pb = inp                   # (B,c,KV,hd), (B,c,KV,hd), (B,c)
        kb = jnp.repeat(kb, rep, axis=2).astype(jnp.float32)  # (B,c,H,hd)
        vb = jnp.repeat(vb, rep, axis=2).astype(jnp.float32)
        logits = jnp.einsum("bshd,bchd->bshc", qf, kb)         # (B,S,H,c)
        causal = pb[:, None, :] <= q_pos[:, :, None]           # (B,S,c)
        valid = pb[:, None, :] >= 0
        ok = causal & valid
        if window > 0:
            ok &= (q_pos[:, :, None] - pb[:, None, :]) < window
        logits = jnp.where(ok[:, :, None, :], logits, -1e30)
        m_new = jnp.maximum(m, logits.max(axis=-1))
        p = jnp.exp(logits - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l = l * corr + p.sum(axis=-1)
        acc = acc * corr[..., None] + jnp.einsum("bshc,bchd->bshd", p, vb)
        return (acc, m_new, l), None

    acc0 = jnp.zeros((b, s, h, hd), jnp.float32)
    m0 = jnp.full((b, s, h), -1e30, jnp.float32)
    l0 = jnp.zeros((b, s, h), jnp.float32)
    (acc, m, l), _ = lax.scan(
        body, (acc0, m0, l0),
        (jnp.moveaxis(kc, 1, 0), jnp.moveaxis(vc, 1, 0), jnp.moveaxis(pc, 1, 0)),
    )
    return (acc / jnp.maximum(l, 1e-30)[..., None]).astype(q.dtype)


def attention_apply(
    p: Dict[str, Any],
    x: Array,                       # (B, S, D)
    cfg,
    positions: Array,               # (B, S)
    cache: Optional[KVCache] = None,
    *,
    causal: bool = True,
    kv_override: Optional[Tuple[Array, Array, Array]] = None,
    chunk: int = 1024,
    ctx=None,
) -> Tuple[Array, Optional[KVCache]]:
    """GQA attention.  Three modes:

    * train / prefill: ``cache=None`` (or a fresh cache to fill) — attends
      over the sequence itself.
    * decode: ``cache`` holds past KV; S is typically 1; the new KV are
      written at ``positions % cache.size`` (ring buffer).
    * cross-attention (whisper decoder): ``kv_override=(k, v, k_pos)``;
      ``causal=False`` and the cache machinery is bypassed.
    """
    b, s, d = x.shape
    hd = cfg.head_dim
    q = ring_tp_colwise(x, p["wq"]["w"].astype(x.dtype), ctx) \
        .reshape(b, s, cfg.n_heads, hd)
    if kv_override is None:
        k = ring_tp_colwise(x, p["wk"]["w"].astype(x.dtype), ctx) \
            .reshape(b, s, cfg.n_kv_heads, hd)
        v = ring_tp_colwise(x, p["wv"]["w"].astype(x.dtype), ctx) \
            .reshape(b, s, cfg.n_kv_heads, hd)
    else:
        k, v, kv_pos = kv_override
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        if kv_override is None:
            k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    if cfg.rope_theta > 0 and kv_override is None:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)

    window = cfg.sliding_window or 0
    new_cache = None
    if kv_override is not None:
        out = _chunked_softmax_attention(
            q, k, v, positions, kv_pos, 0 if not causal else window, chunk
        ) if causal else _chunked_softmax_attention(
            q, k, v, jnp.full_like(positions, 2**30), kv_pos, 0, chunk
        )
    elif cache is None:
        if getattr(cfg, "use_flash_attention", False):
            from repro.kernels.flash_attention import flash_attention
            out = flash_attention(q, k, v, causal=causal, window=window)
        else:
            out = _chunked_softmax_attention(
                q, k, v, positions, positions, window, chunk
            )
    elif s == 1:
        # decode: attend over the ring buffer after inserting the new KV
        size = cache.k.shape[1]
        slots = positions % size  # (B, 1)
        bidx = jnp.arange(b)[:, None]
        ck = cache.k.at[bidx, slots].set(k)
        cv = cache.v.at[bidx, slots].set(v)
        cp = cache.key_pos.at[bidx, slots].set(positions)
        new_cache = KVCache(k=ck, v=cv, key_pos=cp)
        out = _chunked_softmax_attention(
            q, ck, cv, positions, cp, window, chunk
        )
    else:
        # prefill: full (windowed) self-attention; then write the *tail*
        # min(S, size) KVs into the ring (consecutive positions ⇒ unique
        # slots; a ring cache never needs more than its own size).
        out = _chunked_softmax_attention(
            q, k, v, positions, positions, window, chunk
        )
        size = cache.k.shape[1]
        tail = min(s, size)
        kt, vt, pt = k[:, -tail:], v[:, -tail:], positions[:, -tail:]
        slots = pt % size
        bidx = jnp.arange(b)[:, None]
        new_cache = KVCache(
            k=cache.k.at[bidx, slots].set(kt),
            v=cache.v.at[bidx, slots].set(vt),
            key_pos=cache.key_pos.at[bidx, slots].set(pt),
        )
    out = out.reshape(b, s, cfg.n_heads * hd)
    return ring_tp_rowwise(out, p["wo"]["w"].astype(x.dtype), ctx), new_cache


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def mlp_init(key, cfg, d_ff: Optional[int] = None) -> Dict[str, Any]:
    d_ff = d_ff or cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    if cfg.mlp_type == "swiglu":
        return dict(
            gate=dense_init(k1, cfg.d_model, d_ff, cfg.param_dtype),
            up=dense_init(k2, cfg.d_model, d_ff, cfg.param_dtype),
            down=dense_init(k3, d_ff, cfg.d_model, cfg.param_dtype),
        )
    return dict(
        up=dense_init(k1, cfg.d_model, d_ff, cfg.param_dtype),
        down=dense_init(k2, d_ff, cfg.d_model, cfg.param_dtype),
    )


def mlp_apply(p: Dict[str, Any], x: Array, cfg, ctx=None) -> Array:
    if "gate" in p:
        g = jax.nn.silu(ring_tp_colwise(x, p["gate"]["w"].astype(x.dtype), ctx))
        u = ring_tp_colwise(x, p["up"]["w"].astype(x.dtype), ctx)
        return ring_tp_rowwise(g * u, p["down"]["w"].astype(x.dtype), ctx)
    h = jax.nn.gelu(ring_tp_colwise(x, p["up"]["w"].astype(x.dtype), ctx))
    return ring_tp_rowwise(h, p["down"]["w"].astype(x.dtype), ctx)
