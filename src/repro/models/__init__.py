"""LM-architecture substrate: layer library + family assemblies."""
from . import encdec, layers, moe, ssm, transformer, xlstm
from .transformer import (
    DistCtx, decode_step, forward, init_cache, init_params, loss_fn, prefill,
)
