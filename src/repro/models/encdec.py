"""Whisper-style encoder-decoder backbone (whisper-base).

Per the brief, the conv/mel frontend is a **stub**: ``input_specs()``
supplies precomputed frame embeddings ``(B, n_frames, d_model)`` (the output
the two conv layers would produce).  The transformer backbone is complete:

* encoder: bidirectional attention + GELU MLP, pre-LN, sinusoidal positions
* decoder: causal self-attention (ring KV cache for decode) + cross
  attention over encoder output + GELU MLP

Deviation noted per the brief: decoder positions are sinusoidal rather than
Whisper's learned embedding table, so the same parameter set serves the
mechanical 32k-token decode cell (a learned table would pin max context at
init time).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from .layers import (
    KVCache, attention_apply, attention_init, embed_init, embed_lookup,
    kv_cache_init, layer_norm, mlp_apply, mlp_init, unembed_logits,
)
from .transformer import DistCtx

__all__ = ["init_params", "loss_fn", "encode", "prefill", "decode_step",
           "init_cache"]


def _ln_init(cfg):
    return dict(scale=jnp.ones((cfg.d_model,), cfg.param_dtype),
                bias=jnp.zeros((cfg.d_model,), cfg.param_dtype))


def _ln(h, w, cfg):
    return layer_norm(h, w["scale"], w["bias"], cfg.norm_eps)


def sinusoid(seq: int, d: int) -> np.ndarray:
    pos = np.arange(seq)[:, None]
    i = np.arange(d // 2)[None, :]
    ang = pos / (10000 ** (2 * i / d))
    return np.concatenate([np.sin(ang), np.cos(ang)], axis=-1).astype(np.float32)


def _enc_block_init(key, cfg):
    k1, k2 = jax.random.split(key)
    return dict(ln1=_ln_init(cfg), attn=attention_init(k1, cfg),
                ln2=_ln_init(cfg), mlp=mlp_init(k2, cfg))


def _dec_block_init(key, cfg):
    k1, k2, k3 = jax.random.split(key, 3)
    return dict(ln1=_ln_init(cfg), self_attn=attention_init(k1, cfg),
                ln2=_ln_init(cfg), cross_attn=attention_init(k2, cfg),
                ln3=_ln_init(cfg), mlp=mlp_init(k3, cfg))


def init_params(key, cfg, vocab_multiple: int = 16) -> Dict[str, Any]:
    ks = jax.random.split(key, 4)
    stack = lambda key, n, f: jax.tree.map(
        lambda *xs: jnp.stack(xs), *[f(k) for k in jax.random.split(key, n)])
    return dict(
        embed=embed_init(ks[0], cfg.vocab, cfg.d_model, cfg.param_dtype,
                         vocab_multiple),
        enc_blocks=stack(ks[1], cfg.n_enc_layers,
                         lambda k: _enc_block_init(k, cfg)),
        dec_blocks=stack(ks[2], cfg.n_layers,
                         lambda k: _dec_block_init(k, cfg)),
        enc_ln=_ln_init(cfg),
        dec_ln=_ln_init(cfg),
    )


def _cross_kv(bp, enc_out, cfg):
    b, t, _ = enc_out.shape
    hd = cfg.head_dim
    k = (enc_out @ bp["wk"]["w"].astype(enc_out.dtype)
         ).reshape(b, t, cfg.n_kv_heads, hd)
    v = (enc_out @ bp["wv"]["w"].astype(enc_out.dtype)
         ).reshape(b, t, cfg.n_kv_heads, hd)
    return k, v


def encode(params, cfg, frames: jax.Array, *, ctx: DistCtx = DistCtx(),
           remat: Optional[bool] = None) -> jax.Array:
    """frames: (B, T, d_model) stub conv output → encoder states."""
    b, t, d = frames.shape
    h = frames.astype(cfg.cdtype) + jnp.asarray(
        sinusoid(t, d), cfg.cdtype)[None]
    h = ctx.constrain(h, ctx.act_spec())
    positions = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32), (b, t))
    remat = cfg.remat if remat is None else remat

    def body(h, bp):
        a, _ = attention_apply(bp["attn"], _ln(h, bp["ln1"], cfg), cfg,
                               positions, causal=False)
        h = h + a
        h = h + mlp_apply(bp["mlp"], _ln(h, bp["ln2"], cfg), cfg)
        return ctx.constrain(h, ctx.act_spec()), None

    if remat:
        body = jax.checkpoint(body)
    h, _ = lax.scan(body, h, params["enc_blocks"])
    return _ln(h, params["enc_ln"], cfg)


def _decoder(params, cfg, tokens, enc_out, enc_pos, *, ctx, positions,
             cache=None, remat=False):
    b, s = tokens.shape
    # sinusoidal positions computed per (possibly decode-time) position
    d = cfg.d_model
    freqs = 10000 ** (-2 * np.arange(d // 2) / d)
    ang = positions[..., None].astype(jnp.float32) * freqs[None, None]
    pos_emb = jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)
    h = embed_lookup(params["embed"], tokens, cfg.cdtype) \
        + pos_emb.astype(cfg.cdtype)
    h = ctx.constrain(h, ctx.act_spec(seq_sharded=s > 1))

    def body(h, xs):
        bp, c = xs
        a, new_c = attention_apply(
            bp["self_attn"], _ln(h, bp["ln1"], cfg), cfg, positions, c)
        h = h + a
        ck, cv = _cross_kv(bp["cross_attn"], enc_out, cfg)
        x2, _ = attention_apply(
            bp["cross_attn"], _ln(h, bp["ln2"], cfg), cfg, positions,
            kv_override=(ck, cv, enc_pos), causal=False)
        h = h + x2
        h = h + mlp_apply(bp["mlp"], _ln(h, bp["ln3"], cfg), cfg)
        return ctx.constrain(h, ctx.act_spec(seq_sharded=s > 1)), new_c

    if remat:
        body = jax.checkpoint(body)
    if cache is None:
        h, _ = lax.scan(body, h, (params["dec_blocks"], None))
        new_cache = None
    else:
        h, new_cache = lax.scan(body, h, (params["dec_blocks"], cache))
    h = _ln(h, params["dec_ln"], cfg)
    return unembed_logits(params["embed"], h, cfg.vocab), new_cache


def loss_fn(params, cfg, batch, *, ctx: DistCtx = DistCtx()):
    """batch: frames (B,T,d), tokens (B,S)."""
    frames, tokens = batch["frames"], batch["tokens"]
    b, s = tokens.shape
    enc_out = encode(params, cfg, frames, ctx=ctx)
    enc_pos = jnp.broadcast_to(
        jnp.arange(enc_out.shape[1], dtype=jnp.int32), enc_out.shape[:2])
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    logits, _ = _decoder(params, cfg, tokens, enc_out, enc_pos, ctx=ctx,
                         positions=positions, remat=cfg.remat)
    tgt = tokens[:, 1:]
    logp = jax.nn.log_softmax(logits[:, :-1], axis=-1)
    ll = jnp.take_along_axis(logp, tgt[..., None].astype(jnp.int32),
                             axis=-1)[..., 0]
    loss = -ll.mean()
    return loss, dict(loss=loss, ntokens=jnp.asarray(ll.size, jnp.float32))


def init_cache(cfg, batch: int, seq_len: int, n_frames: int,
               dtype=jnp.bfloat16):
    c = kv_cache_init(cfg, batch, min(seq_len, 2**20), dtype)
    n = cfg.n_layers
    stack = lambda x: jnp.broadcast_to(x, (n,) + x.shape)
    return dict(
        kv=KVCache(k=stack(c.k), v=stack(c.v), key_pos=stack(c.key_pos)),
        cross_k=jnp.zeros((n, batch, n_frames, cfg.n_kv_heads, cfg.head_dim),
                          dtype),
        cross_v=jnp.zeros((n, batch, n_frames, cfg.n_kv_heads, cfg.head_dim),
                          dtype),
        enc_pos=jnp.zeros((batch, n_frames), jnp.int32),
    )


def prefill(params, cfg, frames, tokens, cache, *, ctx: DistCtx = DistCtx()):
    """Encode audio + run the prompt; returns (last logits, cache)."""
    b, s = tokens.shape
    enc_out = encode(params, cfg, frames, ctx=ctx, remat=False)
    enc_pos = jnp.broadcast_to(
        jnp.arange(enc_out.shape[1], dtype=jnp.int32), enc_out.shape[:2])
    # precompute cross K/V per decoder layer (map over stacked params)
    ck, cv = _stacked_cross(params, enc_out, cfg)
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    logits, kv_new = _decoder(params, cfg, tokens, enc_out, enc_pos,
                              ctx=ctx, positions=positions,
                              cache=cache["kv"], remat=False)
    new_cache = dict(kv=kv_new, cross_k=ck.astype(cache["cross_k"].dtype),
                     cross_v=cv.astype(cache["cross_v"].dtype),
                     enc_pos=enc_pos)
    return logits[:, -1], new_cache


def _stacked_cross(params, enc_out, cfg):
    def one(bp):
        return _cross_kv(bp["cross_attn"], enc_out, cfg)
    ks, vs = lax.map(one, params["dec_blocks"])
    return ks, vs


def decode_step(params, cfg, token, pos, cache, *, ctx: DistCtx = DistCtx()):
    """One decoder token using cached self KV + cross KV."""
    b = token.shape[0]
    positions = pos[:, None]
    d = cfg.d_model
    freqs = 10000 ** (-2 * np.arange(d // 2) / d)
    ang = positions[..., None].astype(jnp.float32) * freqs[None, None]
    pos_emb = jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)
    h = embed_lookup(params["embed"], token[:, None], cfg.cdtype) \
        + pos_emb.astype(cfg.cdtype)

    def body(h, xs):
        bp, c, ck, cv = xs
        a, new_c = attention_apply(
            bp["self_attn"], _ln(h, bp["ln1"], cfg), cfg, positions, c)
        h = h + a
        x2, _ = attention_apply(
            bp["cross_attn"], _ln(h, bp["ln2"], cfg), cfg, positions,
            kv_override=(ck, cv, cache["enc_pos"]), causal=False)
        h = h + x2
        h = h + mlp_apply(bp["mlp"], _ln(h, bp["ln3"], cfg), cfg)
        return h, new_c

    h, kv_new = lax.scan(
        body, h,
        (params["dec_blocks"], cache["kv"], cache["cross_k"],
         cache["cross_v"]))
    h = _ln(h, params["dec_ln"], cfg)
    logits = unembed_logits(params["embed"], h, cfg.vocab)
    new_cache = dict(cache, kv=kv_new)
    return logits[:, 0], new_cache
