"""Mamba2 (SSD) mixer for the zamba2 hybrid architecture.

Implements the chunked state-space-dual algorithm: the sequence is processed
in chunks of ``cfg.ssm_chunk``; within a chunk the token-token interactions
are computed in parallel (an MXU-friendly masked matmul — this is what makes
SSD a TPU-native formulation), while the O(1) recurrent state ``h`` of shape
``(B, H, hd, N)`` is carried across chunks with ``lax.scan``.

Recurrence (per head, discretized):
    h_t = exp(a·dt_t) · h_{t-1} + dt_t · x_t ⊗ B_t
    y_t = C_t · h_t + D · x_t

Decode is the single-step form (``ssm_step``) — O(1) state, which is what
makes the ``long_500k`` cell feasible for this family (DESIGN.md).

Simplifications vs. the reference CUDA implementation (noted per the brief):
single B/C group (n_groups=1), no dt bias clamping schedule; depthwise
causal conv of width 4 kept.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from .layers import dense_init, rms_norm

__all__ = ["ssm_init", "ssm_apply", "ssm_step", "ssm_state_init"]

_CONV_K = 4


def _dims(cfg):
    d_in = cfg.d_model * cfg.ssm_expand
    heads = d_in // cfg.ssm_headdim
    return d_in, heads, cfg.ssm_state


def ssm_init(key, cfg) -> Dict[str, Any]:
    d, (d_in, heads, n) = cfg.d_model, _dims(cfg)
    ks = jax.random.split(key, 4)
    # in_proj → [z (d_in), x (d_in), B (n), C (n), dt (heads)]
    zxbcdt = 2 * d_in + 2 * n + heads
    return dict(
        in_proj=dense_init(ks[0], d, zxbcdt, cfg.param_dtype),
        conv_w=(jax.random.normal(ks[1], (_CONV_K, d_in + 2 * n), jnp.float32)
                * 0.1).astype(cfg.param_dtype),
        a_log=jnp.zeros((heads,), jnp.float32),            # a = -exp(a_log)
        d_skip=jnp.ones((heads,), jnp.float32),
        dt_bias=jnp.zeros((heads,), jnp.float32),
        norm_w=jnp.ones((d_in,), cfg.param_dtype),
        out_proj=dense_init(ks[2], d_in, d, cfg.param_dtype),
    )


def ssm_state_init(cfg, batch: int, dtype=jnp.float32):
    d_in, heads, n = _dims(cfg)
    return dict(
        h=jnp.zeros((batch, heads, cfg.ssm_headdim, n), dtype),
        conv=jnp.zeros((batch, _CONV_K - 1, d_in + 2 * n), dtype),
    )


def _split_proj(p, x, cfg):
    d_in, heads, n = _dims(cfg)
    zxbcdt = x @ p["in_proj"]["w"].astype(x.dtype)
    z = zxbcdt[..., :d_in]
    xbc = zxbcdt[..., d_in : 2 * d_in + 2 * n]
    dt = zxbcdt[..., 2 * d_in + 2 * n :]
    return z, xbc, dt


def _causal_conv(xbc: jax.Array, w: jax.Array,
                 state: Optional[jax.Array]) -> Tuple[jax.Array, jax.Array]:
    """Depthwise causal conv width-4; returns (out, new_conv_state)."""
    b, s, c = xbc.shape
    hist = state if state is not None else jnp.zeros(
        (b, _CONV_K - 1, c), xbc.dtype
    )
    full = jnp.concatenate([hist, xbc], axis=1)  # (B, S+3, C)
    out = sum(
        full[:, i : i + s] * w[i][None, None].astype(xbc.dtype)
        for i in range(_CONV_K)
    )
    return jax.nn.silu(out), full[:, -(_CONV_K - 1) :]


def ssm_apply(
    p: Dict[str, Any], x: jax.Array, cfg,
    state: Optional[Dict[str, jax.Array]] = None,
) -> Tuple[jax.Array, Optional[Dict[str, jax.Array]]]:
    """Chunked SSD over a full sequence. x: (B, S, D)."""
    b, s, d = x.shape
    d_in, heads, n = _dims(cfg)
    hd = cfg.ssm_headdim
    z, xbc, dt_raw = _split_proj(p, x, cfg)
    conv_in_state = state["conv"] if state is not None else None
    xbc, conv_state = _causal_conv(xbc, p["conv_w"], conv_in_state)
    xs = xbc[..., :d_in].reshape(b, s, heads, hd)
    bmat = xbc[..., d_in : d_in + n]            # (B, S, N)
    cmat = xbc[..., d_in + n :]                 # (B, S, N)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + p["dt_bias"][None, None])        # (B, S, H)
    a = -jnp.exp(p["a_log"])                                 # (H,)

    chunk = min(cfg.ssm_chunk, s)
    if s % chunk:
        chunk = s  # smoke shapes; production shapes divide evenly
    n_ch = s // chunk
    xs_c = xs.reshape(b, n_ch, chunk, heads, hd)
    b_c = bmat.reshape(b, n_ch, chunk, n)
    c_c = cmat.reshape(b, n_ch, chunk, n)
    dt_c = dt.reshape(b, n_ch, chunk, heads)

    h0 = (state["h"].astype(jnp.float32) if state is not None
          else jnp.zeros((b, heads, hd, n), jnp.float32))

    def chunk_body(h, inp):
        xk, bk, ck, dtk = inp       # (B,c,H,hd), (B,c,N), (B,c,N), (B,c,H)
        la = dtk * a[None, None]                      # log decay (B,c,H) ≤ 0
        cum = jnp.cumsum(la, axis=1)                  # (B,c,H)
        # intra-chunk: scores[i,j] = (C_i·B_j) exp(cum_i − cum_j) dt_j, j ≤ i
        cb = jnp.einsum("bin,bjn->bij", ck.astype(jnp.float32),
                        bk.astype(jnp.float32))       # (B,c,c)
        decay = cum[:, :, None, :] - cum[:, None, :, :]      # (B,c,c,H)
        mask = jnp.tril(jnp.ones((chunk, chunk), bool))
        w = jnp.where(mask[None, :, :, None], jnp.exp(decay), 0.0)
        w = w * (cb[..., None] * dtk[:, None, :, :])
        y = jnp.einsum("bijh,bjhp->bihp", w, xk.astype(jnp.float32))
        # inter-chunk: contribution of the carried state
        y += jnp.einsum("bin,bhpn,bih->bihp", ck.astype(jnp.float32), h,
                        jnp.exp(cum))
        # next state: h' = h·exp(cum_last) + Σ_j exp(cum_last−cum_j) dt_j x_j⊗B_j
        wlast = jnp.exp(cum[:, -1:, :] - cum) * dtk          # (B,c,H)
        dh = jnp.einsum("bjh,bjhp,bjn->bhpn", wlast,
                        xk.astype(jnp.float32), bk.astype(jnp.float32))
        h = h * jnp.exp(cum[:, -1])[:, :, None, None] + dh
        return h, y

    h, ys = lax.scan(
        chunk_body, h0,
        (jnp.moveaxis(xs_c, 1, 0), jnp.moveaxis(b_c, 1, 0),
         jnp.moveaxis(c_c, 1, 0), jnp.moveaxis(dt_c, 1, 0)),
    )
    y = jnp.moveaxis(ys, 0, 1).reshape(b, s, heads, hd)
    y = y + xs.astype(jnp.float32) * p["d_skip"][None, None, :, None]
    y = y.reshape(b, s, d_in).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["norm_w"], cfg.norm_eps)
    out = y @ p["out_proj"]["w"].astype(x.dtype)
    new_state = None
    if state is not None:
        new_state = dict(h=h.astype(state["h"].dtype), conv=conv_state)
    return out, new_state


def ssm_step(
    p: Dict[str, Any], x: jax.Array, cfg, state: Dict[str, jax.Array],
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Single-token decode step. x: (B, 1, D); O(1) state update."""
    b = x.shape[0]
    d_in, heads, n = _dims(cfg)
    hd = cfg.ssm_headdim
    z, xbc, dt_raw = _split_proj(p, x, cfg)
    xbc, conv_state = _causal_conv(xbc, p["conv_w"], state["conv"])
    xs = xbc[:, 0, :d_in].reshape(b, heads, hd)
    bmat = xbc[:, 0, d_in : d_in + n]  # (B, N)
    cmat = xbc[:, 0, d_in + n :]
    dt = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32) + p["dt_bias"])
    a = -jnp.exp(p["a_log"])
    h = state["h"].astype(jnp.float32)
    decay = jnp.exp(dt * a[None])                 # (B, H)
    h = h * decay[:, :, None, None] + jnp.einsum(
        "bh,bhp,bn->bhpn", dt, xs.astype(jnp.float32), bmat.astype(jnp.float32)
    )
    y = jnp.einsum("bn,bhpn->bhp", cmat.astype(jnp.float32), h)
    y = y + xs.astype(jnp.float32) * p["d_skip"][None, :, None]
    y = y.reshape(b, 1, d_in).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["norm_w"], cfg.norm_eps)
    out = y @ p["out_proj"]["w"].astype(x.dtype)
    return out, dict(h=h.astype(state["h"].dtype), conv=conv_state)
