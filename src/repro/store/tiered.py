"""TieredFeatures: bind the host store + device hot cache to a PGAS layout.

This is the coordination layer of the tiered feature path: given a
:class:`~repro.core.placement.AggregationPlan` (which fixes the padded
PGAS layout and the ring-tile chunking), it assembles device-resident
feature *chunks* — one ring tile per device — sourcing each row from the
device hot cache when resident and from the host
:class:`~repro.store.FeatureStore` otherwise.

Three consumers:

* :func:`repro.core.pipeline.mgg_aggregate_streamed` pulls chunks one at
  a time through :meth:`chunk_fetcher`; the pipeline dispatches chunk
  *i*'s ring ppermute asynchronously and then calls back here for chunk
  *i+1*, so the host row gather (synchronous NumPy) and the
  ``device_put`` upload overlap the in-flight ring — the double-buffered
  prefetch of the tentpole.
* The serving engine's full pass calls :meth:`padded_table` to
  materialize the whole padded table transiently; assembly is one
  combined row *gather* (selector tables built host-side, rows moved by
  the device — the Pallas DMA kernel in :mod:`repro.kernels.rows` on
  real TPUs), and the buffer is dropped after the pass — steady-state
  device residency is the hot cache alone.
* The sampled mini-batch path (``repro.sample``) calls
  :meth:`gather_rows` with each block's ``src_ids`` — arbitrary row
  sets, no plan required (``plan=None`` builds a planless store view):
  Zipfian-head seeds hit the hot cache, tail rows ride one host gather.

**Bitwise guarantee**: every assembled row is the float32 bits of the
store's current row — whether it traveled via the cache (filled by
``store.gather`` at admission) or via the cold-path gather — and padding
rows are zeros, exactly like :func:`~repro.core.placement.pad_embeddings`.
Assembly is therefore bitwise-identical to the all-resident padded table
at ANY capacity, which is what makes the tiered forward bitwise-equal to
the all-resident forward (property-tested).

Feature rows are keyed by global node id, so tuner moves that change the
plan (``set_plan``) keep every cached row valid — only the chunk/layout
maps are recomputed.
"""
from __future__ import annotations

from typing import Callable, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.placement import AggregationPlan
from repro.obs import MetricsRegistry

from .feature_store import FeatureStore
from .hotfeatures import HotFeatureCache

__all__ = ["TieredFeatures"]


class TieredFeatures:
    """Tiered (host store + device hot cache) view of one PGAS layout."""

    def __init__(self, store: FeatureStore, plan: Optional[AggregationPlan],
                 capacity: int,
                 shard: Optional[Callable] = None,
                 metrics: Optional[MetricsRegistry] = None,
                 labels: Optional[dict] = None):
        self.store = store
        self.shard = shard            # e.g. GNNEngine.shard; None = default
        self.cache = HotFeatureCache(store.num_nodes, capacity, store.d_feat)
        # tiered-level accounting survives cache resizes / plan moves.
        # Counters live in a MetricsRegistry (a shared one when the caller
        # passes it — the serving engine labels by replica); the legacy
        # int attributes (host_rows_streamed, ...) are read-through
        # properties over the same series, so report() and every external
        # consumer see identical numbers.
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self.labels = dict(labels or {})
        self._c_host_rows = self.metrics.counter(
            "store.host_rows_streamed", **self.labels)
        self._c_host_bytes = self.metrics.counter(
            "store.host_bytes_streamed", **self.labels)
        self._c_cache_rows = self.metrics.counter(
            "store.cache_rows_served", **self.labels)
        self._c_assemblies = self.metrics.counter(
            "store.assemblies", **self.labels)
        # plan=None: planless mode for the sampled mini-batch path — only
        # gather_rows() is usable (no ring chunk maps to build).
        self.plan = None
        self._chunks = []
        if plan is not None:
            self.set_plan(plan)

    @property
    def host_rows_streamed(self) -> int:
        """Cold rows uploaded during assembly (host → device misses)."""
        return self._c_host_rows.value

    @property
    def cache_rows_served(self) -> int:
        """Rows sourced from the device tier (hits)."""
        return self._c_cache_rows.value

    @property
    def assemblies(self) -> int:
        """Chunks assembled."""
        return self._c_assemblies.value

    @property
    def capacity(self) -> int:
        return self.cache.capacity

    @property
    def resident_fraction(self) -> float:
        return self.cache.resident_rows / max(1, self.store.num_nodes)

    # -- layout --------------------------------------------------------------

    def set_plan(self, plan: AggregationPlan) -> None:
        """(Re)bind to a PGAS layout.  Cached rows stay valid — the cache
        key is the global node id, not a padded offset — so a tuner move
        only recomputes the chunk maps."""
        if plan.bounds[-1] != self.store.num_nodes:
            raise ValueError(
                f"plan covers {int(plan.bounds[-1])} nodes, store holds "
                f"{self.store.num_nodes}")
        self.plan = plan
        counts = plan.node_counts
        tile, rows = plan.tile_rows, plan.rows_per_dev
        # per chunk c: (global node ids, offsets into the (n_dev·tile) chunk
        # buffer, offsets into the (n_dev·rows) full padded table)
        self._chunks: List[Tuple[np.ndarray, np.ndarray, np.ndarray]] = []
        for c in range(plan.dist):
            ids, pos, fpos = [], [], []
            for d in range(plan.n_dev):
                lo, hi = c * tile, min((c + 1) * tile, int(counts[d]))
                if hi > lo:
                    o = np.arange(lo, hi, dtype=np.int64)
                    ids.append(int(plan.bounds[d]) + o)
                    pos.append(d * tile + (o - lo))
                    fpos.append(d * rows + o)
            cat = lambda a: (np.concatenate(a) if a
                             else np.zeros(0, dtype=np.int64))
            self._chunks.append((cat(ids), cat(pos).astype(np.int32),
                                 cat(fpos).astype(np.int32)))

    # -- admission / updates -------------------------------------------------

    def admit(self, hot_nodes: Sequence[int]) -> int:
        """Refresh the device tier from a hottest-first node list (the
        serving engine passes the WorkloadStats hot-seed histogram)."""
        return self.cache.admit(hot_nodes, self.store)

    def resize(self, capacity: int) -> None:
        """Adopt a new capacity (tuner knob move).  The cache restarts
        cold; the next admission refills it from the current hot list."""
        if capacity == self.cache.capacity:
            return
        self.cache = HotFeatureCache(self.store.num_nodes, capacity,
                                     self.store.d_feat)

    def update(self, node: int, value: np.ndarray) -> None:
        """Live feature update: the store is the source of truth, and the
        derived device row (if resident) is invalidated so no later
        assembly — prefetched or not — can serve the stale bits."""
        self.store.update_row(node, value)
        self.cache.invalidate(np.array([node], dtype=np.int64))

    # -- assembly ------------------------------------------------------------

    def _source(self, ids: np.ndarray):
        """Split one row set into (cold ids+positions idx, hot slot ids)."""
        if self.cache.capacity:
            slots = self.cache.slots(ids)
        else:
            slots = np.full(ids.shape, -1, dtype=np.int32)
        hot = slots >= 0
        cold = int((~hot).sum())
        self._c_host_rows.inc(cold)
        self._c_host_bytes.inc(cold * self.store.d_feat
                               * self.store.itemsize)
        self._c_cache_rows.inc(int(hot.sum()))
        return hot, slots

    @staticmethod
    def _gather(table, sel):
        """Backend-dispatched device row gather: the Pallas DMA kernel
        (:func:`repro.kernels.ops.gather_rows`) on real TPUs, ``jnp.take``
        elsewhere (interpret-mode Pallas would serialize the grid)."""
        import jax
        import jax.numpy as jnp

        if jax.default_backend() == "tpu":
            from repro.kernels import ops as kops
            return kops.gather_rows(table, sel)
        return jnp.take(table, sel, axis=0)

    def _assemble(self, rows: int, ids, pos):
        """Build the ``(rows, d_feat)`` device buffer holding ``ids``'s
        feature rows at ``pos`` and zeros elsewhere — as a row *gather*,
        not the seed's per-row scatter: host-side selector tables name,
        for every output row, its source row in either the uploaded cold
        batch (whose trailing zero rows double as the padding source) or
        the hot-cache table, and the device runs two row gathers plus a
        per-row select.  Each output row is one source row verbatim, so
        assembly stays bitwise-identical to the scatter formulation.

        The cold upload is padded to the next power-of-two row count:
        the cold miss count varies call to call (sampling draws, cache
        churn), and every distinct shape would otherwise compile a fresh
        un-jitted gather executable — with the bucket there are at most
        log2(rows) shapes, so steady state always hits the op cache."""
        import jax
        import jax.numpy as jnp

        hot, slots = self._source(ids)
        cold = ~hot
        n_cold = int(cold.sum())
        bucket = 1 << max(n_cold - 1, 0).bit_length()  # ≥ n_cold, pow2
        cold_rows = self.store.gather(ids[cold])
        cold_up = jax.device_put(np.concatenate(
            [cold_rows, np.zeros((bucket + 1 - n_cold, self.store.d_feat),
                                 cold_rows.dtype)]))
        cold_sel = np.full(rows, bucket, np.int32)     # default: a pad row
        cold_sel[pos[cold]] = np.arange(n_cold, dtype=np.int32)
        out = self._gather(cold_up, jnp.asarray(cold_sel))
        if hot.any():
            hot_sel = np.zeros(rows, np.int32)
            hot_sel[pos[hot]] = slots[hot]
            hot_mask = np.zeros(rows, bool)
            hot_mask[pos[hot]] = True
            out = jnp.where(jnp.asarray(hot_mask)[:, None],
                            self._gather(self.cache.table,
                                         jnp.asarray(hot_sel)),
                            out)
        self._c_assemblies.inc()
        return out

    def device_chunk(self, c: int):
        """Assemble ring chunk ``c``: the ``(n_dev · tile_rows, d_feat)``
        device array holding every device's chunk-``c`` tile."""
        if self.plan is None:
            raise ValueError("TieredFeatures built without a plan — only "
                             "gather_rows() is available")
        ids, pos, _ = self._chunks[c]
        buf = self._assemble(self.plan.n_dev * self.plan.tile_rows, ids, pos)
        return self.shard(buf) if self.shard is not None else buf

    def chunk_fetcher(self) -> Callable[[int], object]:
        """The ``fetch_chunk`` callable for
        :func:`~repro.core.pipeline.mgg_aggregate_streamed`."""
        return self.device_chunk

    def padded_table(self):
        """Materialize the full padded PGAS table as ONE combined gather
        over every chunk's row set (the chunk maps are disjoint and cover
        all real rows; everything else is padding, served by the zero pad
        row).  Transient: callers drop it after the pass."""
        if self.plan is None:
            raise ValueError("TieredFeatures built without a plan — only "
                             "gather_rows() is available")
        ids = np.concatenate([c[0] for c in self._chunks])
        fpos = np.concatenate([c[2] for c in self._chunks])
        buf = self._assemble(self.plan.padded_nodes, ids, fpos)
        return self.shard(buf) if self.shard is not None else buf

    def gather_rows(self, ids, rows: Optional[int] = None):
        """Assemble an arbitrary row set — the sampled mini-batch path's
        source feature tables (``Block.src_ids``).

        ``ids`` is a 1-D global-id array; ``ids[i] < 0`` is the sentinel
        -padding contract of ``repro.sample`` and yields a zero row, as
        do rows beyond ``len(ids)`` when ``rows`` over-allocates.  Hot
        rows come off the device cache, cold rows ride one host gather —
        the same :meth:`_assemble` as the ring chunks, so the result is
        bitwise-identical to an all-resident ``x[ids]`` at ANY capacity
        (including 0).  Buffers are replicated (mini-batch working sets
        are mesh-small), so ``shard`` is not applied."""
        ids = np.asarray(ids, dtype=np.int64).ravel()
        n = ids.shape[0] if rows is None else int(rows)
        if n < ids.shape[0]:
            raise ValueError(f"rows={n} cannot hold {ids.shape[0]} ids")
        pos = np.nonzero(ids >= 0)[0]
        live = ids[pos]
        if live.size and int(live.max()) >= self.store.num_nodes:
            raise ValueError(
                f"node id {int(live.max())} out of range for store of "
                f"{self.store.num_nodes} rows")
        return self._assemble(n, live, pos.astype(np.int32))

    # -- accounting ----------------------------------------------------------

    def report(self) -> dict:
        return dict(
            capacity=self.capacity,
            resident_rows=self.cache.resident_rows,
            resident_fraction=self.resident_fraction,
            hit_rate=self.cache.hit_rate,
            host_rows_streamed=self._c_host_rows.value,
            host_bytes_streamed=self._c_host_bytes.value,
            cache_rows_served=self._c_cache_rows.value,
            admissions=self.cache.admissions,
            evictions=self.cache.evictions,
            store_updates=self.store.updates,
        )
