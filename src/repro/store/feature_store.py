"""Host-side tier of the tiered feature path: the full node-feature table.

MGG keeps node embeddings in a PGAS heap spanning all GPUs; the UVM
baseline it beats (§2.2) instead leaves them in host memory and migrates
4 KB pages on demand.  The tiered path here takes a third position —
features live on the host in a *row-gather* store (this class) and only
a bounded hot set is device-resident (:class:`~repro.store.HotFeatureCache`)
— so the repro can serve graphs whose feature table does not fit on
device while still streaming at row granularity, not page granularity.

On CUDA platforms the host tier would be *pinned* (page-locked) memory so
the gather DMA bypasses a staging copy.  JAX's CPU/TPU backends expose no
page-locking API, so the closest faithful analogue is what this class
guarantees: one contiguous, aligned, dtype-stable buffer that
``jax.device_put`` can transfer from without conversion or re-staging.
The accounting (rows/bytes gathered) is what the cost model and fig8
consume, and it is exact either way.
"""
from __future__ import annotations

from typing import Sequence, Union

import numpy as np

__all__ = ["FeatureStore"]


class FeatureStore:
    """Full ``(num_nodes, d_feat)`` feature table in host memory.

    The store is the single source of truth for feature values: the
    device hot cache and every assembled tile are derived from it, and
    :meth:`update_row` bumps a monotone version counter that
    :class:`~repro.store.TieredFeatures` uses to invalidate derived rows.
    """

    def __init__(self, features: np.ndarray, copy: bool = True):
        x = np.array(features, dtype=np.float32, order="C", copy=copy)
        if x.ndim != 2:
            raise ValueError(f"features must be (num_nodes, d_feat), "
                             f"got shape {x.shape}")
        self.x = x
        self.version = 0          # bumped on every row update
        # gather accounting: the host→device traffic model reads these
        self.gathers = 0          # gather() calls
        self.rows_gathered = 0    # total rows returned across calls
        self.updates = 0

    @property
    def num_nodes(self) -> int:
        return int(self.x.shape[0])

    @property
    def d_feat(self) -> int:
        return int(self.x.shape[1])

    @property
    def itemsize(self) -> int:
        return int(self.x.itemsize)

    @property
    def bytes_gathered(self) -> int:
        return self.rows_gathered * self.d_feat * self.itemsize

    def gather(self, node_ids: Union[np.ndarray, Sequence[int]]) -> np.ndarray:
        """Row-gather ``x[node_ids]`` as a fresh contiguous buffer.

        The copy is deliberate: the caller hands the result straight to
        ``jax.device_put``, and a contiguous buffer is the transfer-ready
        shape (a strided view would be re-staged by the backend anyway).
        Counts toward the gather accounting even when ``node_ids`` is
        empty — an issued transfer of zero rows is still an issue slot in
        the prefetch pipeline.
        """
        ids = np.asarray(node_ids, dtype=np.int64).ravel()
        self.gathers += 1
        self.rows_gathered += int(ids.size)
        return np.ascontiguousarray(self.x[ids])

    def row(self, node: int) -> np.ndarray:
        """One row, copied (callers must not alias the store)."""
        return self.x[int(node)].copy()

    def update_row(self, node: int, value: np.ndarray) -> None:
        """In-place feature update at ``node`` (live feature refresh)."""
        v = np.asarray(value, dtype=np.float32)
        if v.shape != (self.d_feat,):
            raise ValueError(f"expected shape ({self.d_feat},), got {v.shape}")
        self.x[int(node)] = v
        self.version += 1
        self.updates += 1
