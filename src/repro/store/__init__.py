"""Tiered feature storage: host feature store + device hot-row cache.

The paper's headline baseline gap is against UVM-style page-granular
feature access (§2.2, fig 8): real GNN feature tables outgrow device
memory, and the question is *how* the cold rows travel.  This package
makes that memory-bound regime real in the reproduction:

* :class:`FeatureStore` — the host tier: the full ``(num_nodes, D)``
  feature table in page-aligned host memory with a row-gather API (the
  DMA-source analogue of pinned memory on GPU platforms).
* :class:`HotFeatureCache` — the device tier: a bounded ``(capacity, D)``
  row cache holding the hottest nodes, admission driven by the serving
  workload's hot-seed histogram, validity/eviction following the
  :class:`repro.serve.hotcache.HotNodeCache` semantics.
* :class:`TieredFeatures` — binds the two tiers to a padded PGAS layout
  (:class:`repro.core.placement.AggregationPlan`) and assembles
  ring-tile chunks / full padded tables on demand, feeding
  :func:`repro.core.pipeline.mgg_aggregate_streamed`'s double-buffered
  host→device prefetch.

See docs/storage.md for the end-to-end story.
"""
from .feature_store import FeatureStore
from .hotfeatures import HotFeatureCache
from .tiered import TieredFeatures

__all__ = ["FeatureStore", "HotFeatureCache", "TieredFeatures"]
