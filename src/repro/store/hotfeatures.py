"""Device-side tier of the tiered feature path: a bounded hot-row cache.

MG-GCN attributes roughly half its multi-GPU speedup to caching
frequently-accessed vertex data; GNNAdvisor's adaptive runtime shows the
*workload* should decide what is cached.  :class:`HotFeatureCache` is
that component for raw input features: a ``(capacity, d_feat)`` device
array holding the currently-hottest nodes, with admission driven by a
hottest-first node list (the serving engine passes the
:class:`~repro.serve.stats.WorkloadStats` hot-seed histogram) and
explicit per-node invalidation.

Policy follows the (fixed) :class:`~repro.serve.hotcache.HotNodeCache`
semantics:

* admission is hottest-first and capacity-bounded — an empty hot list
  admits *nothing* (an empty histogram means nothing has earned
  admission yet; falling back to all-valid would silently disable the
  memory bound);
* invalidation is explicit and deduplicated — the returned count is
  actual rows dirtied, never inflated by duplicate ids;
* hit/miss accounting is per looked-up row, so the reported hit rate is
  meaningful under any capacity.

Unlike ``HotNodeCache`` (which caches the *layer-1 aggregate* in the
padded PGAS layout and must be flushed when the tuner moves ``dist``),
this cache stores **raw feature rows keyed by global node id** — a
layout-independent key — so tuner-driven plan rebuilds keep every cached
row valid.
"""
from __future__ import annotations

from typing import Optional, Sequence

import numpy as np

__all__ = ["HotFeatureCache"]


class HotFeatureCache:
    """Bounded device-resident cache of hot feature rows."""

    def __init__(self, num_nodes: int, capacity: int, d_feat: int):
        import jax.numpy as jnp

        self.num_nodes = int(num_nodes)
        self.capacity = max(0, min(int(capacity), self.num_nodes))
        self.d_feat = int(d_feat)
        # device tier: the only feature storage that lives in device memory
        self.table = jnp.zeros((self.capacity, self.d_feat), jnp.float32) \
            if self.capacity else None
        # host-side metadata, off the device queue (HotNodeCache precedent)
        self._slot_of = np.full(self.num_nodes, -1, dtype=np.int32)
        self._node_at = np.full(self.capacity, -1, dtype=np.int64)
        self._valid = np.zeros(self.capacity, dtype=bool)
        self.hits = 0
        self.misses = 0
        self.admissions = 0
        self.evictions = 0
        self.invalidations = 0

    # -- admission -----------------------------------------------------------

    def admit(self, hot_nodes: Sequence[int], store) -> int:
        """Admit the hottest ``capacity`` of ``hot_nodes`` (hottest first).

        Rows already resident and valid stay put (no refetch); the
        remaining slots are filled from ``store`` (a
        :class:`~repro.store.FeatureStore`) — that gather is the
        admission's host→device traffic and is counted by the store.
        Nodes beyond ``capacity`` are ignored; resident nodes that fell
        out of the hot set are evicted only when their slot is needed.
        Returns the number of rows fetched.

        Hottest-first prefix admission is what makes the hit rate
        monotone in capacity: the admitted set under capacity ``c`` is a
        subset of the admitted set under any ``c' ≥ c`` for the same hot
        list (property-tested in tests/test_store_properties.py).
        """
        if self.capacity == 0:
            return 0
        want = list(dict.fromkeys(int(n) for n in hot_nodes))[: self.capacity]
        want_set = set(want)
        new = [n for n in want
               if self._slot_of[n] < 0 or not self._valid[self._slot_of[n]]]
        if not new:
            return 0
        # A re-admitted node may still map to its old (invalidated) slot;
        # clear that stale mapping first — otherwise handing the old slot
        # to a different node below would wipe the fresh assignment via
        # ``_slot_of[old] = -1`` and strand the row in an unreachable slot.
        for n in new:
            s_old = int(self._slot_of[n])
            if s_old >= 0:
                self._node_at[s_old] = -1
                self._valid[s_old] = False
                self._slot_of[n] = -1
        # free slots: never used, invalid, or holding a node now cold
        free = [s for s in range(self.capacity)
                if self._node_at[s] < 0 or not self._valid[s]
                or self._node_at[s] not in want_set]
        assert len(free) >= len(new)  # |want| ≤ capacity ⇒ always enough
        slots = free[: len(new)]
        for s, n in zip(slots, new):
            old = self._node_at[s]
            if old >= 0:
                if self._valid[s]:
                    self.evictions += 1
                self._slot_of[old] = -1
            self._slot_of[n] = s
            self._node_at[s] = n
            self._valid[s] = True
        rows = store.gather(np.asarray(new, dtype=np.int64))
        self.table = self.table.at[np.asarray(slots, np.int32)].set(rows)
        self.admissions += len(new)
        return len(new)

    # -- lookup --------------------------------------------------------------

    def slots(self, nodes: np.ndarray) -> np.ndarray:
        """Resident slot per node (−1 = miss), with hit/miss accounting."""
        nodes = np.asarray(nodes, dtype=np.int64)
        if self.capacity == 0:     # no _valid to index: everything misses
            self.misses += int(nodes.size)
            return np.full(nodes.shape, -1, dtype=np.int32)
        s = self._slot_of[nodes].astype(np.int32)
        ok = (s >= 0) & self._valid[np.maximum(s, 0)]
        s = np.where(ok, s, -1).astype(np.int32)
        n_hit = int(ok.sum())
        self.hits += n_hit
        self.misses += int(nodes.size) - n_hit
        return s

    def resident(self, nodes: np.ndarray) -> np.ndarray:
        """Boolean residency mask (no accounting — introspection only)."""
        nodes = np.asarray(nodes, dtype=np.int64)
        if self.capacity == 0:
            return np.zeros(nodes.shape, dtype=bool)
        s = self._slot_of[nodes]
        return (s >= 0) & self._valid[np.maximum(s, 0)]

    # -- invalidation --------------------------------------------------------

    def invalidate(self, nodes: Optional[np.ndarray] = None) -> int:
        """Mark ``nodes`` (or everything) dirty; returns rows actually
        dirtied (duplicates deduplicated, HotNodeCache semantics)."""
        self.invalidations += 1
        if nodes is None:
            n = int(self._valid.sum())
            self._valid[:] = False
            return n
        nodes = np.unique(np.asarray(nodes, dtype=np.int64))
        s = self._slot_of[nodes]
        s = s[s >= 0]
        n = int(self._valid[s].sum())
        self._valid[s] = False
        return n

    # -- accounting ----------------------------------------------------------

    def resident_ids(self) -> np.ndarray:
        """Global node ids of the currently valid rows, ascending.

        This is the cache's *admitted set* — what a serving engine
        persists across restarts so the next process can re-admit the
        same rows instead of starting cold (the row BITS are refetched
        from the store at admission; only the ids survive)."""
        if self.capacity == 0:
            return np.zeros(0, dtype=np.int64)
        return np.sort(self._node_at[self._valid])

    @property
    def resident_rows(self) -> int:
        return int(self._valid.sum())

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0
