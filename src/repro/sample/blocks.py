"""Fanout-bounded neighbor sampling: fixed-shape GraphSAGE blocks.

Host-side CSC sampling in the DGL-GraphBolt mold: for a mini-batch of
seed nodes, draw at most ``fanout`` in-neighbors per node per hop
(without replacement; every neighbor when the degree fits) and emit one
:class:`Block` per layer.  The full-graph path caps graph size at
aggregate device memory — sampling bounds every step's working set at
``batch * (fanout + 1) ** num_layers`` rows regardless of graph size,
which is the door to billion-edge workloads (ROADMAP: "Sampled
mini-batch path").

Everything is **fixed-shape**: capacities depend only on ``(batch,
fanouts)``, never on which seeds arrived or how many neighbors they
had, so one jitted step function serves every mini-batch with zero
retraces.  The padding contract:

* ``src_ids`` is padded with ``-1`` — the feature gather
  (:meth:`repro.store.TieredFeatures.gather_rows`) materializes those
  rows as zeros.
* ``nbr`` holds **local** row indices into the block's source feature
  table; empty slots point at row ``num_src``, a zero sentinel row the
  aggregation appends (see :func:`repro.core.block_neighbor_sum`), and
  carry ``mask == 0`` so they are doubly inert.

Blocks are returned **outermost hop first** — ``blocks[0]`` consumes
raw features, ``blocks[-1]`` produces the seed embeddings — matching
the layer order of ``repro.core.apply_blocks``.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, List, Optional, Sequence, Tuple

import numpy as np

from ..core.graph import CSRGraph

__all__ = [
    "Block",
    "sample_blocks",
    "block_tree",
    "seed_batches",
    "sampled_khop_frontier",
]


@dataclasses.dataclass(frozen=True)
class Block:
    """One hop of a sampled mini-batch (fixed-shape, sentinel-padded).

    ``src_ids``: ``(num_src,)`` int64 global node ids, ``-1`` in unused
    slots.  The first ``num_dst`` entries are the destination ids
    themselves (dst-first ordering), so ``h[:num_dst]`` of the source
    embedding table is exactly the destination embedding table — the
    self-term of GraphSAGE needs no second gather.

    ``nbr``: ``(num_dst, fanout)`` int32 local rows into the source
    table; padding points at row ``num_src`` (the appended zero row).

    ``mask``: ``(num_dst, fanout)`` float32, 1.0 on sampled edges.
    """

    src_ids: np.ndarray
    nbr: np.ndarray
    mask: np.ndarray

    @property
    def num_dst(self) -> int:
        return int(self.nbr.shape[0])

    @property
    def num_src(self) -> int:
        return int(self.src_ids.shape[0])

    @property
    def fanout(self) -> int:
        return int(self.nbr.shape[1])

    @property
    def sentinel(self) -> int:
        """The local index padding slots of ``nbr`` point at."""
        return self.num_src


def _sample_in_neighbors(graph: CSRGraph, dst_ids: np.ndarray, fanout: int,
                         rng: np.random.Generator) -> np.ndarray:
    """Per valid dst (id >= 0), draw ≤ ``fanout`` in-neighbors without
    replacement — all of them when the degree fits.  Returns a
    ``(len(dst_ids), fanout)`` int64 table of global ids, ``-1``-padded.

    Vectorized end to end: one flat gather of every candidate edge (the
    ``neighbors_of`` idiom), a random key per edge, then a segment-wise
    lexsort keeping the ``fanout`` smallest keys per destination.
    """
    nd = int(dst_ids.shape[0])
    out = np.full((nd, fanout), -1, dtype=np.int64)
    if fanout <= 0:
        return out
    valid = np.nonzero(dst_ids >= 0)[0]
    if valid.size == 0:
        return out
    nodes = dst_ids[valid]
    starts = graph.indptr[nodes]
    lens = (graph.indptr[nodes + 1] - starts).astype(np.int64)
    total = int(lens.sum())
    if total == 0:
        return out
    seg_starts = np.cumsum(lens) - lens
    seg = np.repeat(np.arange(valid.size, dtype=np.int64), lens)
    offs = np.arange(total, dtype=np.int64) - np.repeat(seg_starts, lens)
    cand = graph.indices[np.repeat(starts, lens) + offs].astype(np.int64)
    # Uniform keys + stable per-segment sort == a without-replacement
    # draw of min(deg, fanout) neighbors per destination.
    order = np.lexsort((rng.random(total), seg))
    seg_sorted = seg[order]
    pos = np.arange(total, dtype=np.int64) - np.repeat(seg_starts, lens)
    keep = pos < fanout
    out[valid[seg_sorted[keep]], pos[keep]] = cand[order][keep]
    return out


def sample_blocks(graph: CSRGraph, seeds: np.ndarray,
                  fanouts: Sequence[int], *,
                  batch: Optional[int] = None,
                  rng: Optional[np.random.Generator] = None) -> List[Block]:
    """Sample a ``len(fanouts)``-hop block pipeline for ``seeds``.

    ``fanouts`` is listed outermost hop first (layer order), matching
    ``apply_blocks``; ``fanouts[-1]`` bounds the seeds' direct
    in-neighborhood.  ``batch`` fixes the innermost destination
    capacity (defaults to ``len(seeds)``); short seed batches are
    ``-1``-padded up to it so shapes never vary.  Seed order is
    preserved — row ``i`` of the final embedding belongs to
    ``seeds[i]`` — and valid seeds must be unique (labels line up
    positionally and the local index map needs one row per node).
    """
    rng = np.random.default_rng() if rng is None else rng
    seeds = np.asarray(seeds, dtype=np.int64).ravel()
    cap = seeds.size if batch is None else int(batch)
    if seeds.size > cap:
        raise ValueError(f"{seeds.size} seeds exceed batch capacity {cap}")
    live = seeds[seeds >= 0]
    if np.unique(live).size != live.size:
        raise ValueError("seeds must be unique")
    dst = np.full(cap, -1, dtype=np.int64)
    dst[:seeds.size] = seeds

    blocks: List[Block] = []
    for fanout in reversed([int(f) for f in fanouts]):
        nd = int(dst.shape[0])
        ns = nd * (fanout + 1)
        sampled = _sample_in_neighbors(graph, dst, fanout, rng)
        # dst-first source ordering; new sources deduped after the dsts.
        extra = np.setdiff1d(sampled[sampled >= 0], dst[dst >= 0])
        src_ids = np.full(ns, -1, dtype=np.int64)
        src_ids[:nd] = dst
        src_ids[nd:nd + extra.size] = extra
        # Global → local over the valid src rows (ids are unique).
        vpos = np.nonzero(src_ids >= 0)[0]
        vids = src_ids[vpos]
        order = np.argsort(vids, kind="stable")
        sorted_ids, sorted_pos = vids[order], vpos[order]
        nbr = np.full((nd, fanout), ns, dtype=np.int32)
        mask = np.zeros((nd, fanout), dtype=np.float32)
        hit = sampled >= 0
        if hit.any():
            loc = sorted_pos[np.searchsorted(sorted_ids, sampled[hit])]
            nbr[hit] = loc.astype(np.int32)
            mask[hit] = 1.0
        blocks.append(Block(src_ids=src_ids, nbr=nbr, mask=mask))
        dst = src_ids
    blocks.reverse()
    return blocks


def block_tree(blocks: Sequence[Block]):
    """Device-ready pytree of the jit-traced block fields.

    Only ``nbr``/``mask`` enter the jitted step (``src_ids`` drives the
    host-side feature gather); shapes depend only on (batch, fanouts),
    so the same compiled step serves every mini-batch.
    """
    import jax.numpy as jnp

    return [{"nbr": jnp.asarray(b.nbr), "mask": jnp.asarray(b.mask)}
            for b in blocks]


def seed_batches(ids: np.ndarray, batch: int, *,
                 rng: Optional[np.random.Generator] = None,
                 shuffle: bool = True
                 ) -> Iterator[Tuple[np.ndarray, np.ndarray]]:
    """Yield ``(seeds, valid)`` mini-batches of fixed size ``batch``.

    ``seeds`` is ``-1``-padded int64; ``valid`` is float32 (1.0 on real
    seeds) for masking the loss over padded rows.
    """
    ids = np.asarray(ids, dtype=np.int64).ravel()
    if shuffle:
        ids = (np.random.default_rng() if rng is None else rng).permutation(ids)
    for lo in range(0, ids.size, batch):
        part = ids[lo:lo + batch]
        seeds = np.full(batch, -1, dtype=np.int64)
        seeds[:part.size] = part
        valid = (seeds >= 0).astype(np.float32)
        yield seeds, valid


def sampled_khop_frontier(graph: CSRGraph, seeds: np.ndarray,
                          fanouts: Sequence[int], *,
                          rng: Optional[np.random.Generator] = None
                          ) -> np.ndarray:
    """Fanout-bounded receptive field of ``seeds`` — the sampled
    counterpart of :func:`repro.core.khop_in_frontier`.

    Returns sorted unique global ids (seeds included); always a subset
    of the exact k-hop frontier, with size bounded by
    ``len(seeds) * prod(fanout + 1)`` independent of graph degree.
    """
    blocks = sample_blocks(graph, seeds, fanouts, rng=rng)
    ids = blocks[0].src_ids if blocks else np.asarray(seeds, dtype=np.int64)
    return np.unique(ids[ids >= 0])
