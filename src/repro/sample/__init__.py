"""Host-side fanout-bounded neighbor sampling (fixed-shape blocks).

See :mod:`repro.sample.blocks` for the block format and padding
contract, and ``docs/sampling.md`` for the end-to-end picture.
"""
from .blocks import (Block, block_tree, sample_blocks, sampled_khop_frontier,
                     seed_batches)

__all__ = [
    "Block",
    "sample_blocks",
    "block_tree",
    "seed_batches",
    "sampled_khop_frontier",
]
