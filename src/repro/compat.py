"""Forward/backward JAX API compatibility shims.

The codebase is written against the current public JAX surface
(``jax.shard_map`` with ``check_vma``, ``lax.pvary``, ``jax.sharding.AxisType``,
``pltpu.CompilerParams``).  Older jaxlibs (0.4.x) expose the same machinery
under previous names (``jax.experimental.shard_map`` with ``check_rep``,
``pltpu.TPUCompilerParams``, no axis types).  :func:`install` bridges the gap
*only where an attribute is missing*, so on a current JAX every shim is a
no-op and nothing is monkeypatched.

Called once from ``repro/__init__.py`` — every entry point (tests, examples,
benchmarks, launchers) imports ``repro`` first, so call sites can use the
modern spelling unconditionally.
"""
from __future__ import annotations

import enum
import functools
import inspect

import jax
from jax import lax

__all__ = ["install"]


def _install_shard_map() -> None:
    if getattr(jax, "shard_map", None) is not None:
        return
    try:
        from jax.experimental.shard_map import shard_map as _legacy
    except ImportError:  # pragma: no cover - very old jax
        return

    @functools.wraps(_legacy)
    def shard_map(f, mesh=None, in_specs=None, out_specs=None, *,
                  check_vma: bool = True, **kwargs):
        # modern kwarg -> legacy kwarg; everything else passes through
        kwargs.setdefault("check_rep", check_vma)
        return _legacy(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                       **kwargs)

    jax.shard_map = shard_map


def _install_lax_names() -> None:
    # pvary / pcast: varying-manual-axes typing markers.  With the legacy
    # shard_map (check_rep) they have no typing effect — identity is correct.
    if not hasattr(lax, "pvary"):
        lax.pvary = lambda x, axes: x
    if not hasattr(lax, "axis_size"):
        # psum of a python literal folds to the (static) axis size
        lax.axis_size = lambda axis_name: lax.psum(1, axis_name)


def _install_axis_type() -> None:
    if hasattr(jax.sharding, "AxisType"):
        return

    class AxisType(enum.Enum):
        Auto = "auto"
        Explicit = "explicit"
        Manual = "manual"

    jax.sharding.AxisType = AxisType


def _install_make_mesh() -> None:
    """Let ``jax.make_mesh(..., axis_types=...)`` work on jaxlibs whose
    ``make_mesh`` predates the ``axis_types`` parameter (it is dropped)."""
    orig = getattr(jax, "make_mesh", None)
    if orig is None:
        return
    try:
        params = inspect.signature(orig).parameters
    except (TypeError, ValueError):  # pragma: no cover
        return
    if "axis_types" in params:
        return

    @functools.wraps(orig)
    def make_mesh(axis_shapes, axis_names, *, axis_types=None, **kwargs):
        return orig(axis_shapes, axis_names, **kwargs)

    jax.make_mesh = make_mesh


def _install_pallas_tpu_params() -> None:
    try:
        from jax.experimental.pallas import tpu as pltpu
    except ImportError:  # pragma: no cover - pallas-less build
        return
    if not hasattr(pltpu, "CompilerParams") and hasattr(pltpu,
                                                        "TPUCompilerParams"):
        pltpu.CompilerParams = pltpu.TPUCompilerParams


def install() -> None:
    _install_shard_map()
    _install_lax_names()
    _install_axis_type()
    _install_make_mesh()
    _install_pallas_tpu_params()
