"""Batched serving engine: prefill + decode over fixed batch slots.

Wave-scheduled continuous batching: requests are admitted into a fixed
number of batch slots; one jitted ``decode_step`` advances every active
slot; finished slots (EOS / budget) are frozen via the active mask and
refilled from the queue at the next wave boundary.  Greedy or temperature
sampling.  This is the serving loop the ``decode_*`` dry-run cells lower.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import encdec, transformer

__all__ = ["ServeEngine", "GenerationResult"]


@dataclasses.dataclass
class GenerationResult:
    tokens: List[int]
    prompt_len: int
    steps: int


class ServeEngine:
    def __init__(self, params, cfg, *, batch_slots: int = 8,
                 max_seq: int = 512, ctx=None, eos_id: Optional[int] = None):
        self.params = params
        self.cfg = cfg
        self.B = batch_slots
        self.max_seq = max_seq
        self.ctx = ctx or transformer.DistCtx()
        self.eos_id = eos_id
        if cfg.family == "encdec":
            raise NotImplementedError(
                "use encdec.prefill/decode_step directly for whisper")
        self._prefill = jax.jit(
            lambda p, t, c: transformer.prefill(p, cfg, t, c, ctx=self.ctx))
        self._decode = jax.jit(
            lambda p, t, pos, c: transformer.decode_step(
                p, cfg, t, pos, c, ctx=self.ctx))

    def _sample(self, logits: np.ndarray, temperature: float,
                rng: np.random.Generator) -> np.ndarray:
        if temperature <= 0:
            return logits.argmax(-1).astype(np.int32)
        z = logits / temperature
        z = z - z.max(-1, keepdims=True)
        p = np.exp(z)
        p /= p.sum(-1, keepdims=True)
        return np.array([rng.choice(p.shape[-1], p=p[i])
                         for i in range(p.shape[0])], np.int32)

    def generate(self, prompts: List[np.ndarray], *, max_new: int = 32,
                 temperature: float = 0.0, seed: int = 0
                 ) -> List[GenerationResult]:
        """Wave-batched generation over all prompts."""
        rng = np.random.default_rng(seed)
        results: List[Optional[GenerationResult]] = [None] * len(prompts)
        queue = list(range(len(prompts)))
        while queue:
            wave, queue = queue[: self.B], queue[self.B :]
            plen = max(len(prompts[i]) for i in wave)
            b = len(wave)
            toks = np.zeros((b, plen), np.int32)
            for j, i in enumerate(wave):
                toks[j, -len(prompts[i]):] = prompts[i]  # left-pad
            cache = transformer.init_cache(
                self.cfg, b, min(self.max_seq, plen + max_new),
                dtype=jnp.float32)
            logits, cache = self._prefill(
                self.params, jnp.asarray(toks), cache)
            out_tokens = [[] for _ in wave]
            active = np.ones(b, bool)
            cur = self._sample(np.asarray(logits), temperature, rng)
            pos = np.full((b,), plen, np.int32)
            for step in range(max_new):
                for j in range(b):
                    if active[j]:
                        out_tokens[j].append(int(cur[j]))
                        if self.eos_id is not None and cur[j] == self.eos_id:
                            active[j] = False
                if not active.any():
                    break
                logits, cache = self._decode(
                    self.params, jnp.asarray(cur), jnp.asarray(pos), cache)
                cur = self._sample(np.asarray(logits), temperature, rng)
                pos = pos + 1
            for j, i in enumerate(wave):
                results[i] = GenerationResult(
                    tokens=out_tokens[j], prompt_len=len(prompts[i]),
                    steps=len(out_tokens[j]))
        return results  # type: ignore[return-value]
