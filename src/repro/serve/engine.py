"""Batched serving engine: prefill + decode over fixed batch slots.

Continuous batching: requests are admitted into a fixed number of batch
slots; one jitted ``decode_step`` advances every active slot; a slot that
finishes (EOS / budget) is refilled from the queue **at the next step**,
not at a wave boundary — the decode cache stays live and a long request
never blocks admission of short ones behind it (no head-of-line barrier).

Each admission prefills alone (batch 1, exact prompt length — no left-pad
tokens polluting attention) and its cache is scattered into the shared
decode cache at the slot index, so per-slot results are identical to
running that prompt solo.  Greedy or temperature sampling; temperature
sampling is vectorized over slots via the Gumbel-max trick (one argmax,
no per-row ``rng.choice`` loop).
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import encdec, transformer

__all__ = ["ServeEngine", "GenerationResult"]


@dataclasses.dataclass
class GenerationResult:
    tokens: List[int]
    prompt_len: int
    steps: int


class ServeEngine:
    def __init__(self, params, cfg, *, batch_slots: int = 8,
                 max_seq: int = 512, ctx=None, eos_id: Optional[int] = None):
        self.params = params
        self.cfg = cfg
        self.B = batch_slots
        self.max_seq = max_seq
        self.ctx = ctx or transformer.DistCtx()
        self.eos_id = eos_id
        if cfg.family == "encdec":
            raise NotImplementedError(
                "use encdec.prefill/decode_step directly for whisper")
        self._prefill = jax.jit(
            lambda p, t, c: transformer.prefill(p, cfg, t, c, ctx=self.ctx))
        self._decode = jax.jit(
            lambda p, t, pos, c: transformer.decode_step(
                p, cfg, t, pos, c, ctx=self.ctx))
        # scatter one prefilled batch-1 cache into slot j of the shared
        # decode cache (every cache leaf carries batch at axis 1, under the
        # layer/group stack axis)
        self._scatter = jax.jit(
            lambda cache, c1, j: jax.tree.map(
                lambda dst, src: jax.lax.dynamic_update_slice_in_dim(
                    dst, src.astype(dst.dtype), j, axis=1), cache, c1))

    def _sample(self, logits: np.ndarray, temperature: float,
                rng: np.random.Generator) -> np.ndarray:
        """Vectorized over rows: argmax (greedy) or Gumbel-max (categorical
        at ``temperature``) — no per-row rng.choice loop."""
        if temperature <= 0:
            return logits.argmax(-1).astype(np.int32)
        z = logits.astype(np.float64) / temperature
        g = rng.gumbel(size=z.shape)
        return (z + g).argmax(-1).astype(np.int32)

    def generate(self, prompts: List[np.ndarray], *, max_new: int = 32,
                 temperature: float = 0.0, seed: int = 0
                 ) -> List[GenerationResult]:
        """Continuously batched generation over all prompts."""
        rng = np.random.default_rng(seed)
        results: List[Optional[GenerationResult]] = [None] * len(prompts)
        if not prompts:
            return []
        queue = deque(range(len(prompts)))
        L = min(self.max_seq, max(len(p) for p in prompts) + max_new)
        cache = transformer.init_cache(self.cfg, self.B, L,
                                       dtype=jnp.float32)
        slot_req = [-1] * self.B                 # request index per slot
        out_tokens: List[List[int]] = [[] for _ in range(self.B)]
        cur = np.zeros(self.B, np.int32)          # next token to emit/feed
        pos = np.zeros(self.B, np.int32)
        active = np.zeros(self.B, bool)

        def finalize(j: int) -> None:
            i = slot_req[j]
            results[i] = GenerationResult(
                tokens=out_tokens[j], prompt_len=len(prompts[i]),
                steps=len(out_tokens[j]))
            slot_req[j] = -1
            active[j] = False
            cur[j] = 0
            pos[j] = 0

        while queue or active.any():
            # -- refill every free slot from the queue (per step, not per
            #    wave: finished slots re-admit immediately) ----------------
            for j in range(self.B):
                if slot_req[j] >= 0 or not queue:
                    continue
                i = queue.popleft()
                toks = np.asarray(prompts[i], np.int32)[None, :]
                c1 = transformer.init_cache(self.cfg, 1, L,
                                            dtype=jnp.float32)
                logits1, c1 = self._prefill(self.params, jnp.asarray(toks),
                                            c1)
                cache = self._scatter(cache, c1, j)
                cur[j] = self._sample(np.asarray(logits1), temperature,
                                      rng)[0]
                pos[j] = toks.shape[1]
                slot_req[j] = i
                out_tokens[j] = []
                active[j] = True

            # -- emit the sampled token for every active slot; finished
            #    slots free up for the refill at the top of the next step --
            for j in range(self.B):
                if not active[j]:
                    continue
                out_tokens[j].append(int(cur[j]))
                if ((self.eos_id is not None and cur[j] == self.eos_id)
                        or len(out_tokens[j]) >= max_new):
                    finalize(j)
            if not active.any():
                continue  # refill (or exit) without a wasted decode

            # -- one decode step advances every active slot ----------------
            logits, cache = self._decode(
                self.params, jnp.asarray(cur),
                jnp.asarray(np.minimum(pos, L - 1)), cache)
            nxt = self._sample(np.asarray(logits), temperature, rng)
            cur = np.where(active, nxt, cur).astype(np.int32)
            pos = pos + active.astype(np.int32)
        return results  # type: ignore[return-value]
