"""Sliding-window request statistics for the GNN serving engine.

:class:`WorkloadStats` watches the live request stream — arrival rate,
seed counts, receptive-field (frontier) sizes, and a per-node touch
histogram — over a bounded window of recent micro-batches.  Its
:meth:`drift` score compares two :class:`TrafficSnapshot`\\ s and is the
signal that drives :meth:`repro.runtime.engine.DynamicGNNEngine.retune`
under live traffic shifts: a hot-set rotation collapses the hot-node
overlap, a burst moves the arrival rate, a workload-mix change moves the
frontier-size distribution.  Any of the three past the serving engine's
threshold re-opens the (ps, dist, pb) search.

Timestamps are supplied by the caller (the serving engine passes request
arrival times), so replayed traces and fake clocks drive the collector
deterministically in tests.
"""
from __future__ import annotations

import dataclasses
from collections import Counter, deque
from typing import Deque, Optional, Tuple

import numpy as np

__all__ = ["TrafficSnapshot", "WorkloadStats"]


@dataclasses.dataclass(frozen=True)
class TrafficSnapshot:
    """Aggregate view of the stats window at one instant."""

    requests: int           # REQUESTS recorded in the window (not batches)
    rate: float             # requests / second over the window span
    mean_seeds: float       # seeds per micro-batch
    mean_frontier: float
    hot_nodes: Tuple[int, ...]  # top-k node ids by touch count, hottest first


def _rel(a: float, b: float) -> float:
    """Symmetric relative change in [0, 1] (0 ⇔ equal, → 1 as one side
    dwarfs the other) — normalizing by ``max`` keeps :meth:`drift`
    bounded so one threshold means the same thing for a 4× burst and a
    hot-set rotation."""
    return abs(a - b) / max(1e-12, abs(a), abs(b))


class WorkloadStats:
    """Bounded window over served micro-batches.

    ``record`` takes the arrival timestamp of the batch's newest request,
    the requested seed ids (these feed the hot-node histogram), and the
    size of the batch's k-hop receptive field.
    """

    def __init__(self, window: int = 128, top_k: int = 16):
        self.window = int(window)
        self.top_k = int(top_k)
        # (t, n_seeds, frontier_size, seed ids, n_requests) per micro-batch
        self._events: Deque[Tuple[float, int, int, np.ndarray, int]] = \
            deque()
        self._counts: Counter = Counter()
        self.total_batches = 0
        # last rate computed over a non-degenerate span: carried forward
        # when every window batch shares one timestamp (replayed shadow
        # traffic under a frozen clock), so a degenerate window cannot
        # collapse the rate to 0 and fake a full-drift rate change
        self._last_rate = 0.0

    def record(self, t: float, seeds: np.ndarray, frontier_size: int,
               n_requests: int = 1) -> None:
        """One micro-batch: newest arrival time, the REQUESTED node ids,
        its k-hop receptive-field size, and how many requests it packed.

        The hot-node histogram counts *seeds*, not the frontier: a k-hop
        frontier is dominated by high-degree hubs that appear in every
        receptive field regardless of what was asked for, so it cannot see
        a hot-set rotation — the request distribution can.
        """
        nodes = np.asarray(seeds, dtype=np.int64)
        self._events.append((float(t), int(nodes.size), int(frontier_size),
                             nodes, int(n_requests)))
        self._counts.update(nodes.tolist())
        self.total_batches += 1
        while len(self._events) > self.window:
            _, _, _, old, _ = self._events.popleft()
            self._counts.subtract(old.tolist())
        # Counter.subtract keeps zero/negative entries; prune so top-k and
        # memory stay honest.
        if len(self._counts) > 8 * self.window:
            self._counts = Counter(
                {k: v for k, v in self._counts.items() if v > 0})

    def __len__(self) -> int:
        return len(self._events)

    def top_nodes(self, k: int) -> Tuple[int, ...]:
        """Hottest-first node ids by window touch count, up to ``k``.

        ``snapshot().hot_nodes`` caps at ``top_k`` — sized for drift
        comparison, not for cache fills; the tiered feature path admits a
        *capacity*-sized hot list through here instead."""
        return tuple(n for n, v in self._counts.most_common(int(k))
                     if v > 0)

    def recent_seed_batches(self, limit: Optional[int] = None) -> list:
        """Seed-id arrays of the newest ``limit`` window batches (oldest
        first).  The serving cluster replays these as *shadow traffic*
        through a drained replica so its re-opened search measures the
        exact workload that triggered the drift — without holding any live
        request hostage to the re-jits."""
        events = list(self._events)
        if limit is not None:
            events = events[-int(limit):]
        return [e[3].copy() for e in events]

    def snapshot(self) -> TrafficSnapshot:
        n = len(self._events)
        if n == 0:
            return TrafficSnapshot(0, 0.0, 0.0, 0.0, ())
        t0 = self._events[0][0]
        t1 = self._events[-1][0]
        n_req = sum(e[4] for e in self._events)
        # requests/second: arrivals AFTER the window-opening batch over the
        # window span (the first batch anchors t0, its requests predate it).
        # A degenerate span (n == 1, or all timestamps equal — a frozen
        # clock) carries the last measured rate instead of reporting 0.0:
        # the request stream did not stop, the clock did.
        if n > 1 and t1 > t0:
            arrivals = n_req - self._events[0][4]
            rate = arrivals / (t1 - t0)
            self._last_rate = rate
        else:
            rate = self._last_rate
        seeds = float(np.mean([e[1] for e in self._events]))
        frontier = float(np.mean([e[2] for e in self._events]))
        hot = tuple(k for k, v in self._counts.most_common(self.top_k)
                    if v > 0)
        return TrafficSnapshot(n_req, rate, seeds, frontier, hot)

    @staticmethod
    def drift(baseline: TrafficSnapshot, current: TrafficSnapshot) -> float:
        """Relative traffic change in [0, 1]: max over rate change,
        frontier-size change (both symmetric-relative, so bounded) and
        hot-set turnover (1 − overlap with the baseline hot set).  0 for
        identical windows; monotone in hot-set turnover."""
        if baseline.requests == 0 or current.requests == 0:
            return 0.0
        score = max(_rel(baseline.rate, current.rate)
                    if baseline.rate > 0 else 0.0,
                    _rel(baseline.mean_frontier, current.mean_frontier))
        if baseline.hot_nodes:
            overlap = len(set(baseline.hot_nodes) & set(current.hot_nodes)) \
                / len(baseline.hot_nodes)
            score = max(score, 1.0 - overlap)
        return score
