"""Serving substrate: LM token decoding + online GNN inference.

* :mod:`repro.serve.engine` — continuous-batching LM generation
  (``ServeEngine``);
* :mod:`repro.serve.gnn` — online GNN node-prediction serving with
  traffic-driven re-tuning (``GNNServeEngine``, see docs/serving.md);
* :mod:`repro.serve.cluster` — multi-replica scale-out behind a router
  with staggered drain→retune→rejoin and a shared ConfigCache
  (``ServeCluster``, see docs/cluster.md);
* :mod:`repro.serve.router` — routing policies (``LeastLoadRouter``,
  ``LocalityRouter``);
* :mod:`repro.serve.stats` — sliding-window request statistics + drift
  signal (``WorkloadStats``);
* :mod:`repro.serve.hotcache` — MG-GCN-style layer-1 aggregate cache
  (``HotNodeCache``);
* :mod:`repro.serve.traffic` — Zipfian phase-shifted traffic generator
  (``ZipfTraffic``).
"""
from .cluster import ServeCluster
from .engine import ServeEngine, GenerationResult
from .gnn import GNNServeEngine, ServeResult, run_trace
from .hotcache import HotNodeCache
from .router import LeastLoadRouter, LocalityRouter, Router, make_router
from .stats import TrafficSnapshot, WorkloadStats
from .traffic import TrafficEvent, TrafficPhase, ZipfTraffic

__all__ = [
    "ServeEngine", "GenerationResult",
    "GNNServeEngine", "ServeResult", "run_trace",
    "ServeCluster", "Router", "LeastLoadRouter", "LocalityRouter",
    "make_router",
    "HotNodeCache", "TrafficSnapshot", "WorkloadStats",
    "TrafficEvent", "TrafficPhase", "ZipfTraffic",
]
