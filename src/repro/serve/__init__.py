"""Serving substrate."""
from .engine import ServeEngine, GenerationResult
