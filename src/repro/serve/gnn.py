"""Online GNN inference serving on the MGG engine.

:class:`GNNServeEngine` serves node-prediction requests against the
partitioned full graph, closing the loop the ROADMAP asked for: request
statistics drive :meth:`repro.runtime.engine.DynamicGNNEngine.retune`, so
the aggregation pipeline re-optimizes ``(ps, dist, pb)`` under live
traffic shifts using the same OnlineTuner/ConfigCache machinery training
uses.

Request path (one micro-batch)::

    submit(seeds) ─► admission queue ─► fixed slots (≤ ``slots`` seeds)
        ─► k-hop frontier extraction (host, CSR)      → WorkloadStats
        ─► layer-1 cache lookup over the (k-1)-hop frontier
        ─► jitted step through GNNEngine/mgg_aggregate:
              · cache miss → FULL pass (all stages; refreshes the cache)
              · all hits   → CACHED pass (stages 1.. from the h₁ table)
        ─► gather seed rows from the padded PGAS logits → responses

Because both passes fold the *same* stage functions
(:func:`repro.core.gnn.apply_stage`) over the *same* tables, served logits
are bitwise-identical to the offline ``*_apply`` full-graph forward under
the active config.  This holds for per-layer engines too: each stage
consumes its own :class:`~repro.core.placement.LayerPlan` (including
fused-update layers), and because every layer plan shares one PGAS layout
*within a build*, ``engine.plan`` remains the single layout handle for
seed-row gathers and padding.  A per-layer re-tune goes through the same
rebuild path as the global one: ``_on_rebuild`` re-pads the feature
table, re-jits both serve steps against the rebuilt plans, and
invalidates the h₁ cache (a ``dist`` move changes the lcm-padded layout,
so cached rows would no longer line up).

Traffic-driven re-tuning: every ``check_every`` micro-batches the engine
snapshots :class:`~repro.serve.stats.WorkloadStats` and compares it to the
snapshot taken at the last tune.  Past ``drift_threshold`` (hot-set
rotation, burst, frontier shift) it calls ``retune(force=True)``; the
re-opened search is then fed per-micro-batch wall times via
``observe_step`` until it converges again — serving never stops, requests
are never dropped, and every tuner move re-jits the serve steps against
the rebuilt plan.  While a search is open the engine forces FULL passes so
the tuner measures the complete aggregation pipeline (and the cache is
refreshed for free once per batch).
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Callable, Deque, Dict, List, Optional

import jax
import numpy as np

from repro.core.gnn import apply_from_stage, apply_stage, num_stages
from repro.core.graph import CSRGraph, khop_in_frontier, neighbors_of
from repro.core.placement import pgas_rows
from repro.obs import MetricsRegistry, NULL_TRACER
from repro.runtime.engine import DynamicGNNEngine
from repro.sample import sampled_khop_frontier
from repro.serve.hotcache import HotNodeCache
from repro.serve.stats import TrafficSnapshot, WorkloadStats
from repro.serve.traffic import TrafficEvent
from repro.store import FeatureStore, TieredFeatures

__all__ = ["GNNServeEngine", "ServeResult", "run_trace"]


@dataclasses.dataclass(frozen=True)
class ServeResult:
    """Response for one request: logits per seed + latency accounting."""

    request_id: int
    seeds: np.ndarray
    logits: np.ndarray        # (len(seeds), num_classes)
    latency: float            # submit → response wall seconds (incl. queue)
    cached: bool              # served from the layer-1 cache


@dataclasses.dataclass
class _Pending:
    request_id: int
    seeds: np.ndarray
    t_arrival: float          # traffic timestamp (stats / rate drift)
    t_submit: float           # wall clock (latency accounting)
    t_trace: float = 0.0      # tracer clock at admission (span timelines)


class GNNServeEngine:
    """Admission queue + fixed micro-batch slots over a (Dynamic)GNNEngine."""

    def __init__(
        self,
        engine,                      # GNNEngine or DynamicGNNEngine
        params: Dict,
        model: str,
        x: np.ndarray,               # (num_nodes, d_feat) features
        graph: CSRGraph,             # the raw topology the engine was built on
        *,
        slots: int = 8,
        self_loops: bool = True,     # must match the engine's build
        stats: Optional[WorkloadStats] = None,
        drift_threshold: float = 0.5,
        check_every: int = 8,
        min_records: int = 8,
        use_cache: bool = True,
        cache_capacity: Optional[int] = None,
        feature_store: Optional[FeatureStore] = None,
        feature_capacity: Optional[int] = None,
        hotset_path: Optional[str] = None,
        frontier_fanout: Optional[int] = None,
        frontier_seed: int = 0,
        log_fn: Callable[[str], None] = lambda _s: None,
        clock: Callable[[], float] = time.perf_counter,
        retune_gate: Optional[
            Callable[["GNNServeEngine", float], bool]] = None,
        tracer=None,
        metrics: Optional[MetricsRegistry] = None,
        obs_labels: Optional[dict] = None,
    ):
        self.eng = engine
        self.params = params
        self.model = model
        self.x = np.array(x, dtype=np.float32)
        self.graph = graph
        self.g_full = graph.with_self_loops() if self_loops else graph
        self.rev = self.g_full.transpose()   # invalidation fan-out
        self.slots = int(slots)
        self.k_hops = len(params["layers"])
        self.n_stages = num_stages(model, params)
        # default window: short enough that a phase shift dominates the
        # histogram within a few check periods (old hot nodes must age out)
        self.stats = stats or WorkloadStats(window=32)
        self.drift_threshold = float(drift_threshold)
        self.check_every = int(check_every)
        self.min_records = int(min_records)
        self.use_cache = bool(use_cache)
        self.cache = HotNodeCache(graph.num_nodes, capacity=cache_capacity)
        # fanout-bounded frontier accounting (repro.sample): when set, the
        # per-batch receptive-field size fed to WorkloadStats (and hence
        # hot-admission pressure) comes from a sampled k-hop frontier —
        # bounded by slots·(fanout+1)^k instead of the full BFS fan-out,
        # which on power-law graphs is the whole graph within 2 hops.
        # Cache GATING stays exact: a fanout-bounded frontier may miss a
        # dirty row, and correctness gates on the exact (k-1)-hop set.
        self.frontier_fanout = (None if frontier_fanout is None
                                else int(frontier_fanout))
        self._frontier_rng = np.random.default_rng(frontier_seed)
        self.log = log_fn
        self.clock = clock
        # coordinator hook: called with (self, drift_score) when traffic
        # drift crosses the threshold; returning False defers the retune
        # (a ServeCluster uses this to stagger replica re-searches — it
        # later drives force_retune() itself once the replica is drained)
        self.retune_gate = retune_gate
        # False while a coordinator replays *shadow* traffic through this
        # engine (re-tune measurement): replayed batches must not be
        # double-counted into the drift window
        self.record_stats = True

        self.dynamic = isinstance(engine, DynamicGNNEngine)
        self._tuning = self.dynamic and not engine.tuner.converged
        self._baseline: Optional[TrafficSnapshot] = None
        self._queue: Deque[_Pending] = deque()
        self._next_id = 0
        # observability: counters live in a MetricsRegistry (shared with
        # sibling replicas when the caller passes one, labeled per
        # replica); served/batches/... read-through properties keep the
        # pre-registry surface intact.  The tracer records request
        # lifecycle spans; NULL_TRACER makes every recording call a no-op.
        self.tracer = tracer if tracer is not None else NULL_TRACER
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self.obs_labels = dict(obs_labels or {})
        _c = lambda name: self.metrics.counter(name, **self.obs_labels)
        self._c_served = _c("serve.served")
        self._c_shadow = _c("serve.shadow_served")   # record_stats off
        self._c_batches = _c("serve.batches")  # ALL batches (check_every)
        self._c_retunes = _c("serve.retunes")  # traffic-drift re-opens
        self._c_rebuilds = _c("serve.rebuilds")  # plan/jit rebuilds
        self._g_queue = self.metrics.gauge("serve.queue_depth",
                                           **self.obs_labels)
        self._h_latency = self.metrics.histogram("serve.request_seconds",
                                                 **self.obs_labels)
        self._h_batch = self.metrics.histogram("serve.batch_seconds",
                                               **self.obs_labels)
        if self.dynamic:
            # thread the same sinks into the runtime so tuner audit events
            # land in this trace/registry (engine construction predates us)
            if tracer is not None:
                engine.tracer = self.tracer
            if engine.metrics is None:
                engine.metrics = self.metrics
        # measurements (≈ configs visited) per closed search, in order;
        # the cluster asserts shared-cache adoption makes these shrink
        self.search_sizes: List[int] = []
        self._search_opened_at: Optional[int] = \
            engine.tuner.measured if self._tuning else None

        # tiered feature storage (the memory-bound regime): features live
        # in the host FeatureStore, the device holds a bounded hot cache,
        # and full passes assemble a transient padded table — no resident
        # O(N·D) device copy.  Selected by passing either knob.
        self.tiers: Optional[TieredFeatures] = None
        if feature_store is not None or feature_capacity is not None:
            store = feature_store if feature_store is not None \
                else FeatureStore(x)
            cap = feature_capacity
            if cap is None:   # adopt the tuner's cap knob when it has one
                cap = (engine.feature_capacity or 0) if self.dynamic else 0
            self.tiers = TieredFeatures(store, self.eng.plan, int(cap),
                                        shard=self.eng.shard,
                                        metrics=self.metrics,
                                        labels=self.obs_labels)
            self.x = store.x   # the store owns the bits; keep a shared view

        # hot-set persistence: the admitted global-id set survives serve
        # restarts via a JSON sidecar next to the ConfigCache (explicit
        # ``hotset_path`` overrides; no cache and no override ⇒ off).
        # Only the IDS persist — the row bits are refetched from the
        # store at warm admission, so a restart can never serve stale
        # features.  The derived path is per-REPLICA: cluster replicas
        # share one ConfigCache (that is the point — search once, adopt
        # cheaply) but each replica's hot set reflects ITS routed
        # traffic slice, so a shared sidecar would be last-writer-wins
        # across replicas and every restart would warm-load whichever
        # replica dumped last.  The ``replica`` obs label (set by
        # launch/serve_gnn.py and the cluster) suffixes the path.
        self._hotset_path = hotset_path
        if self._hotset_path is None and self.dynamic \
                and engine.cache is not None:
            rep = self.obs_labels.get("replica")
            suffix = ".hotset.json" if rep is None \
                else f".hotset.r{rep}.json"
            self._hotset_path = engine.cache.path + suffix
        if self.tiers is not None:
            self._hotset_load()

        self.xp = None
        self._refresh_tables()
        self._build_steps()

    # -- registry-backed counters (legacy read surface) ----------------------

    @property
    def served(self) -> int:
        return self._c_served.value

    @property
    def shadow_served(self) -> int:
        return self._c_shadow.value

    @property
    def batches(self) -> int:
        return self._c_batches.value

    @property
    def retunes(self) -> int:
        return self._c_retunes.value

    @property
    def rebuilds(self) -> int:
        return self._c_rebuilds.value

    # -- hot-set persistence --------------------------------------------------

    def _hotset_load(self) -> None:
        """Warm-admit the hot-id set a previous serve process persisted.

        The sidecar is a hint: a missing/corrupt file, or one recorded
        against a different store shape, is ignored (serving starts with
        a cold tier, exactly as before this feature)."""
        if self._hotset_path is None or not self.tiers.capacity:
            return
        import json

        try:
            with open(self._hotset_path) as f:
                doc = json.load(f)
        except (OSError, ValueError):
            return
        if not isinstance(doc, dict) \
                or doc.get("num_nodes") != self.tiers.store.num_nodes \
                or doc.get("d_feat") != self.tiers.store.d_feat:
            return
        ids = doc.get("ids")
        if not isinstance(ids, list):
            return
        ids = [int(i) for i in ids
               if 0 <= int(i) < self.tiers.store.num_nodes]
        if ids:
            n = self.tiers.admit(ids)
            self.log(f"[serve.gnn] warm hot set from {self._hotset_path}: "
                     f"{n} rows admitted")

    def _hotset_dump(self) -> None:
        """Atomically persist the current admitted-id set (tmp+replace,
        the ConfigCache discipline — a preempted writer never corrupts
        the sidecar)."""
        if self._hotset_path is None or self.tiers is None:
            return
        import json
        import os
        import tempfile

        doc = dict(num_nodes=self.tiers.store.num_nodes,
                   d_feat=self.tiers.store.d_feat,
                   ids=[int(i) for i in self.tiers.cache.resident_ids()])
        d = os.path.dirname(os.path.abspath(self._hotset_path)) or "."
        os.makedirs(d, exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=d, prefix=".hotset-", suffix=".tmp")
        try:
            with os.fdopen(fd, "w") as f:
                json.dump(doc, f)
            os.replace(tmp, self._hotset_path)
        except BaseException:
            try:
                os.unlink(tmp)
            except OSError:
                pass
            raise

    # -- jit / layout management ---------------------------------------------

    def _refresh_tables(self) -> None:
        """(Re-)pad + shard the feature table for the CURRENT plan layout.

        Tiered mode keeps NO resident padded table: the plan is re-bound
        (cached rows stay valid — they key on global node id) and each
        full pass assembles a transient table via
        :meth:`TieredFeatures.padded_table`."""
        if self.tiers is not None:
            self.tiers.set_plan(self.eng.plan)
            self.xp = None
            return
        self.xp = self.eng.shard(self.eng.pad(self.x))

    def _build_steps(self) -> None:
        """Jit the serve steps against the current engine state.

        Fresh ``jax.jit`` objects on every plan rebuild: the engine is
        baked into the trace, so a stale jit would silently serve the old
        pipeline.
        """
        eng = self.eng.engine if self.dynamic else self.eng
        model = self.model

        def full(params, xp, rows):
            h1 = apply_stage(model, params, eng, xp, 0)
            return apply_from_stage(model, params, eng, h1, 1)[rows], h1

        def cached(params, h1, rows):
            return apply_from_stage(model, params, eng, h1, 1)[rows]

        self._step_full = jax.jit(full)
        self._step_cached = jax.jit(cached)

    def _on_rebuild(self) -> None:
        self._c_rebuilds.inc()
        self.tracer.instant("serve.rebuild", cat="serve",
                            config=self.eng.config)
        if self.tiers is not None and self.dynamic:
            # the tuner may have moved the cap knob; adopt it (cold
            # restart — the next admission refills from the live hot set)
            cap = self.eng.feature_capacity
            if cap is not None and cap != self.tiers.capacity:
                self.tiers.resize(int(cap))
        self._refresh_tables()
        self._build_steps()
        # the padded layout may have moved with dist — the cached table's
        # rows no longer line up; recompute on next batch
        self.cache.invalidate()

    # -- admission -----------------------------------------------------------

    def submit(self, seeds: np.ndarray, t: Optional[float] = None) -> int:
        """Enqueue a prediction request; returns its id.  Never drops."""
        seeds = np.asarray(seeds, dtype=np.int64).ravel()
        if seeds.size == 0 or seeds.size > self.slots:
            raise ValueError(
                f"request must carry 1..{self.slots} seeds, got {seeds.size}")
        if seeds.min() < 0 or seeds.max() >= self.graph.num_nodes:
            raise ValueError("seed id out of range")
        rid = self._next_id
        self._next_id += 1
        now = self.clock()
        self._queue.append(_Pending(
            rid, seeds, now if t is None else float(t), now,
            t_trace=self.tracer.now() if self.tracer.enabled else 0.0))
        self._g_queue.set(len(self._queue))
        return rid

    @property
    def pending_requests(self) -> int:
        return len(self._queue)

    @property
    def pending_seeds(self) -> int:
        return sum(p.seeds.size for p in self._queue)

    def update_features(self, node: int, value: np.ndarray) -> int:
        """Feature write at ``node``: scatters the one changed row into the
        device table (no O(N·D) re-pad) and explicitly invalidates the
        layer-1 rows that aggregate it (reverse edges, self-loop
        included).  Returns the number of rows invalidated."""
        value = np.asarray(value, dtype=np.float32)
        if self.tiers is not None:
            # store write + hot-feature-row invalidation: no assembly —
            # prefetched or not — can serve the stale bits afterwards
            self.tiers.update(int(node), value)
        else:
            self.x[int(node)] = value
            row = int(pgas_rows(self.eng.plan, np.array([node]))[0])
            self.xp = self.eng.shard(self.xp.at[row].set(value))
        dirty = self.rev.row(int(node))
        return self.cache.invalidate(dirty)

    def sampled_frontier(self, seeds: np.ndarray) -> np.ndarray:
        """Fanout-bounded k-hop receptive field of ``seeds`` (sorted
        unique global ids) — the sampled counterpart of the exact BFS
        frontier, composing :mod:`repro.sample` with the serving path.
        Always a subset of the exact frontier; size bounded by
        ``len(seeds) * (frontier_fanout + 1) ** k_hops``.  Duplicate
        seeds (two requests for one node in a batch) are deduped."""
        if self.frontier_fanout is None:
            raise ValueError("serve engine built without frontier_fanout")
        return sampled_khop_frontier(
            self.g_full, np.unique(np.asarray(seeds, dtype=np.int64)),
            [self.frontier_fanout] * self.k_hops, rng=self._frontier_rng)

    # -- the serving loop ----------------------------------------------------

    def step(self) -> List[ServeResult]:
        """Serve ONE micro-batch: pack whole requests into the slots, run
        the jitted step, respond.  No-op (empty list) on an empty queue."""
        batch: List[_Pending] = []
        n_seeds = 0
        while self._queue and \
                n_seeds + self._queue[0].seeds.size <= self.slots:
            p = self._queue.popleft()
            batch.append(p)
            n_seeds += p.seeds.size
        if not batch:
            return []
        tracing = self.tracer.enabled
        if tracing:
            t_batch0 = self.tracer.now()
            for p in batch:
                # queue wait: admission → slot assignment
                self.tracer.complete("serve.queue_wait", p.t_trace,
                                     t_batch0, cat="serve",
                                     args={"request_id": p.request_id})
        self._g_queue.set(len(self._queue))

        seeds = np.concatenate([p.seeds for p in batch])
        padded = np.zeros(self.slots, dtype=np.int64)   # masked tail slots
        padded[:n_seeds] = seeds
        rows = np.asarray(pgas_rows(self.eng.plan, padded), dtype=np.int32)

        # Rows the CACHED pass reads: the cached step folds stages 1..,
        # so seed logits depend on h₁ rows up to (k-1) hops out — gating
        # on a shallower frontier would serve stale logits after a deep
        # feature update.  One more hop on top of the same BFS gives the
        # full receptive-field size for the stats.
        with self.tracer.span("serve.frontier", cat="serve",
                              n_seeds=int(n_seeds)):
            f_need = khop_in_frontier(self.g_full, seeds,
                                      max(0, self.k_hops - 1))
            if self.frontier_fanout is not None and self.k_hops > 0:
                # stats-side receptive field via the sampled frontier:
                # bounded work per batch, and the Zipfian head still
                # dominates the histogram (hub nodes appear in most
                # samples), so hot admission sees the same head.
                fk_size = self.sampled_frontier(seeds).size
            elif self.k_hops > 0:
                fk_size = np.unique(np.concatenate(
                    [f_need,
                     neighbors_of(self.g_full, f_need).astype(np.int64)])
                ).size
            else:
                fk_size = f_need.size
            misses = self.cache.lookup(f_need)
        if self.record_stats:
            self.stats.record(batch[-1].t_arrival, seeds, fk_size,
                              n_requests=len(batch))
        if self.tiers is not None and self.tiers.capacity \
                and self.record_stats:
            # refresh the device feature tier from the live hot set BEFORE
            # this batch's assembly — a capacity-sized list, not the
            # drift-sized snapshot().hot_nodes.  admit() fetches only
            # newly-hot rows, so a stable hot set costs nothing here.
            if self.tiers.admit(self.stats.top_nodes(self.tiers.capacity)):
                # admitted set moved: persist it for the next serve
                # process (no-op write when the hot set is stable)
                self._hotset_dump()

        # lookup() already scanned validity over exactly f_need (with the
        # table-None guard), so zero misses ⇔ the cached pass is safe
        use_cached = (self.use_cache and not self._tuning and misses == 0)
        t0 = self.clock()
        with self.tracer.span("serve.aggregate", cat="serve",
                              cached=bool(use_cached),
                              frontier=int(fk_size)):
            if use_cached:
                out = self._step_cached(self.params, self.cache.table, rows)
                jax.block_until_ready(out)
            else:
                # tiered mode assembles the padded table transiently — later
                # chunks' host gathers overlap earlier chunks' device work
                xp = self.xp if self.tiers is None \
                    else self.tiers.padded_table()
                out, h1 = self._step_full(self.params, xp, rows)
                jax.block_until_ready((out, h1))
                if self.use_cache:
                    hot = self.stats.snapshot().hot_nodes \
                        if self.cache.capacity is not None else None
                    self.cache.store(h1, hot_nodes=hot)
        dt = self.clock() - t0
        self._h_batch.observe(dt)

        self._c_batches.inc()
        if self.dynamic and self._tuning:
            if self.eng.observe_step(dt):
                self._on_rebuild()
            self._tuning = not self.eng.tuner.converged
            if not self._tuning:
                if self._search_opened_at is not None:
                    self.search_sizes.append(
                        self.eng.tuner.measured - self._search_opened_at)
                    self._search_opened_at = None
                if len(self.stats) >= self.min_records:
                    # search just closed: the current window is the traffic
                    # the committed config was tuned under — that's the
                    # drift baseline
                    self._baseline = self.stats.snapshot()
        self._maybe_retune()

        logits = np.asarray(out)
        results, off = [], 0
        now = self.clock()
        t_emit = self.tracer.now() if tracing else 0.0
        for p in batch:
            k = p.seeds.size
            res = ServeResult(
                request_id=p.request_id, seeds=p.seeds,
                logits=logits[off:off + k], latency=now - p.t_submit,
                cached=use_cached)
            results.append(res)
            self._h_latency.observe(res.latency)
            if tracing:
                # admission → emit lifecycle span (queue wait + batch)
                self.tracer.complete(
                    "serve.request", p.t_trace, t_emit, cat="serve",
                    args={"request_id": p.request_id, "n_seeds": int(k),
                          "cached": bool(use_cached),
                          "shadow": not self.record_stats})
            off += k
        if self.record_stats:
            # shadow-replay batches (record_stats off) answer no user:
            # `served` stays reconcilable with the cluster-level count
            self._c_served.inc(len(results))
        else:
            self._c_shadow.inc(len(results))
        return results

    def drain(self) -> List[ServeResult]:
        """Serve until the queue is empty."""
        out: List[ServeResult] = []
        while self._queue:
            out.extend(self.step())
        return out

    # -- traffic-driven re-tuning --------------------------------------------

    def _maybe_retune(self) -> None:
        if not self.dynamic or self._tuning:
            return
        if self.batches % self.check_every != 0:
            return
        if len(self.stats) < self.min_records:
            return
        snap = self.stats.snapshot()
        if self._baseline is None:
            self._baseline = snap
            return
        score = WorkloadStats.drift(self._baseline, snap)
        if score <= self.drift_threshold:
            return
        hot_overlap = (len(set(self._baseline.hot_nodes)
                           & set(snap.hot_nodes))
                       / max(1, len(self._baseline.hot_nodes)))
        self.log(f"[serve.gnn] traffic drift {score:.2f} > "
                 f"{self.drift_threshold:.2f} → retune "
                 f"(rate {self._baseline.rate:.0f}→{snap.rate:.0f}/s, "
                 f"hot-set overlap {hot_overlap:.2f})")
        if self.retune_gate is not None and not self.retune_gate(self, score):
            # deferred: the coordinator drains this replica and drives
            # force_retune() itself (the un-reset baseline keeps the drift
            # signal alive, so a busy coordinator is re-asked next check)
            return
        self.force_retune()

    def force_retune(self, from_cache: bool = False) -> None:
        """Re-open the tuning search under live traffic, immediately.

        The drift path above lands here; a :class:`ServeCluster` calls it
        directly on a drained replica.  ``from_cache=True`` adopts the
        shared-ConfigCache entry a sibling replica committed (single
        validation measurement instead of a re-search; see
        ``DynamicGNNEngine.retune``).
        """
        if not self.dynamic or self._tuning:
            return
        self._c_retunes.inc()
        self.tracer.instant("serve.retune", cat="serve",
                            from_cache=bool(from_cache))
        self._baseline = self.stats.snapshot() if len(self.stats) else None
        cfg_before = dict(self.eng.config)
        measured_before = self.eng.tuner.measured
        self.eng.retune(force=True, from_cache=from_cache)
        self._tuning = not self.eng.tuner.converged
        self._search_opened_at = measured_before if self._tuning else None
        if self.eng.config != cfg_before:
            # the forced re-open moved the config immediately — later moves
            # arrive through observe_step; an unchanged config keeps the
            # live jits and the warm cache
            self._on_rebuild()

    # -- reporting -----------------------------------------------------------

    @property
    def config(self) -> Dict[str, int]:
        return self.eng.config

    def report(self) -> Dict[str, object]:
        """Thin view over the metrics registry (schema unchanged)."""
        return dict(
            served=self._c_served.value, shadow_served=self._c_shadow.value,
            batches=self._c_batches.value,
            pending=self.pending_requests, dropped=0,
            retunes=self._c_retunes.value, rebuilds=self._c_rebuilds.value,
            search_sizes=list(self.search_sizes),
            cache_hit_rate=round(self.cache.hit_rate, 4),
            cache_stores=self.cache.stores,
            cache_invalidations=self.cache.invalidations,
            config=self.config,
            tiers=self.tiers.report() if self.tiers is not None else None,
        )


def run_trace(engine: GNNServeEngine, events) -> List[ServeResult]:
    """Feed a :class:`~repro.serve.traffic.ZipfTraffic`-style event stream
    through the engine: updates apply immediately, requests queue, and a
    micro-batch is served whenever the slots can be filled.  Drains at the
    end — every request is answered."""
    results: List[ServeResult] = []
    for ev in events:
        if isinstance(ev, TrafficEvent) and ev.is_update:
            engine.update_features(ev.update_node, ev.update_value)
            continue
        seeds = ev.seeds if isinstance(ev, TrafficEvent) else ev
        engine.submit(seeds, t=ev.t if isinstance(ev, TrafficEvent) else None)
        while engine.pending_seeds >= engine.slots:
            results.extend(engine.step())
    results.extend(engine.drain())
    return results
