"""Synthetic request traffic for the GNN serving engine.

Zipfian node popularity with **phase shifts** — the traffic patterns the
paper's runtime must survive:

* **hot-set rotation** — each phase may re-permute the popularity ranking,
  so the nodes that were hot go cold and a disjoint set heats up (the
  drift signal :class:`repro.serve.stats.WorkloadStats` watches);
* **burst load** — per-phase arrival rate, so a phase can multiply the
  request rate without touching the node distribution;
* **feature updates** — a per-phase fraction of events are node-feature
  writes, which exercise the hot-node cache's explicit invalidation.

Arrival timestamps are *simulated* (exponential inter-arrivals at the
phase rate) and carried on each event, so stats and rate-drift detection
are deterministic given the seed — no wall-clock sleeping.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Optional, Sequence

import numpy as np

__all__ = ["TrafficPhase", "TrafficEvent", "ZipfTraffic"]


@dataclasses.dataclass(frozen=True)
class TrafficPhase:
    """One homogeneous stretch of traffic."""

    requests: int                 # events generated in this phase
    alpha: float = 1.1            # Zipf exponent over the popularity ranking
    rate: float = 200.0           # mean arrivals per second (simulated)
    rotate: bool = False          # re-permute node popularity at phase entry
    seeds_min: int = 1
    seeds_max: int = 4
    update_frac: float = 0.0      # fraction of events that are feature writes


@dataclasses.dataclass(frozen=True)
class TrafficEvent:
    """Either a prediction request (``seeds``) or a feature update."""

    t: float                      # simulated arrival time (seconds)
    seeds: Optional[np.ndarray] = None       # request: node ids
    update_node: Optional[int] = None        # feature write: node id
    update_value: Optional[np.ndarray] = None  # new feature row (d_feat,)

    @property
    def is_update(self) -> bool:
        return self.update_node is not None


class ZipfTraffic:
    """Deterministic event stream over ``phases``."""

    def __init__(self, num_nodes: int, d_feat: int,
                 phases: Sequence[TrafficPhase], seed: int = 0):
        self.num_nodes = int(num_nodes)
        self.d_feat = int(d_feat)
        self.phases = list(phases)
        self.seed = int(seed)

    def _sample_nodes(self, rng, perm: np.ndarray, alpha: float,
                      n: int) -> np.ndarray:
        # Zipf over ranks: rank r is drawn with p ∝ r^-alpha; the permutation
        # maps ranks to node ids, so rotating the permutation rotates the
        # hot set without touching the distribution.
        ranks = (rng.zipf(alpha, size=n) - 1) % self.num_nodes
        return perm[ranks].astype(np.int64)

    def events(self) -> Iterator[TrafficEvent]:
        rng = np.random.default_rng(self.seed)
        perm = np.arange(self.num_nodes, dtype=np.int64)
        t = 0.0
        for phase in self.phases:
            if phase.rotate:
                perm = rng.permutation(self.num_nodes).astype(np.int64)
            for _ in range(phase.requests):
                t += float(rng.exponential(1.0 / max(phase.rate, 1e-9)))
                if phase.update_frac > 0 and rng.random() < phase.update_frac:
                    node = int(self._sample_nodes(rng, perm, phase.alpha, 1)[0])
                    value = rng.normal(size=self.d_feat).astype(np.float32)
                    yield TrafficEvent(t=t, update_node=node,
                                       update_value=value)
                    continue
                k = int(rng.integers(phase.seeds_min, phase.seeds_max + 1))
                # unique seeds within a request keep slot packing simple
                seeds = np.unique(
                    self._sample_nodes(rng, perm, phase.alpha, k))
                yield TrafficEvent(t=t, seeds=seeds)

    def __iter__(self) -> Iterator[TrafficEvent]:
        return self.events()

    @property
    def total_events(self) -> int:
        return sum(p.requests for p in self.phases)
