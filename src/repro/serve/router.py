"""Request routing policies for the multi-replica GNN serving cluster.

A :class:`Router` picks which :class:`~repro.serve.gnn.GNNServeEngine`
replica answers a prediction request.  Two policies, per the GNNAdvisor
lesson that runtime decisions should follow observed workload properties:

* :class:`LeastLoadRouter` — the replica with the fewest pending seeds.
  Optimal for queue balance, blind to caches.
* :class:`LocalityRouter` — seed-locality hashing: the request's *anchor*
  seed (the min-hash seed of the set, so requests sharing a hot seed
  usually share the anchor) maps to a home replica, which therefore keeps
  seeing the same neighborhoods and keeps its layer-1 hot cache valid for
  them.  When the home replica is out of rotation (draining for a retune)
  or overloaded past ``load_slack`` micro-batches of backlog, the policy
  falls back to the least-loaded replica whose cache is ready for the
  seeds, then to plain least-load — locality is a preference, load is the
  guarantee.

Routers are deterministic (no RNG, no wall clock): the same request
stream over the same replica states routes identically, which is what
makes the cluster's single-replica mode bitwise-reproducible.
"""
from __future__ import annotations

from typing import Sequence

import numpy as np

__all__ = ["Router", "LeastLoadRouter", "LocalityRouter", "make_router"]

_M64 = (1 << 64) - 1


def _mix(x: int) -> int:
    """splitmix64 finalizer — a stable integer hash (``hash()`` would do,
    but its value is implementation-defined and we want routing to be
    reproducible across runs and machines)."""
    x = (x + 0x9E3779B97F4A7C15) & _M64
    x = ((x ^ (x >> 30)) * 0xBF58476D1CE4E5B9) & _M64
    x = ((x ^ (x >> 27)) * 0x94D049BB133111EB) & _M64
    return (x ^ (x >> 31)) & _M64


class Router:
    """Policy interface: pick a replica index for a request."""

    name = "base"

    def __init__(self):
        self._rr = 0    # round-robin tie-break cursor (see _least_load)

    def pick(self, seeds: np.ndarray, replicas: Sequence,
             available: Sequence[int]) -> int:
        """Return the index (into ``replicas``) that should serve
        ``seeds``.  ``available`` lists the replicas currently in rotation
        (a draining/retuning replica is excluded by the cluster); the
        returned index must come from it."""
        raise NotImplementedError

    def _least_load(self, replicas: Sequence,
                    available: Sequence[int]) -> int:
        """Fewest pending seeds; ties rotate round-robin.  Queues are
        usually empty in the eager serving loop, so a static tie-break
        would starve every replica but the first — the cursor keeps the
        policy deterministic (no RNG, no clock) while spreading ties."""
        floor = min(replicas[i].pending_seeds for i in available)
        cands = [i for i in available
                 if replicas[i].pending_seeds == floor]
        pick = cands[self._rr % len(cands)]
        self._rr += 1
        return pick


class LeastLoadRouter(Router):
    """Route to the replica with the fewest queued seeds (deterministic
    round-robin among ties)."""

    name = "load"

    def pick(self, seeds, replicas, available):
        if not available:
            raise ValueError("no replica in rotation")
        return self._least_load(replicas, available)


class LocalityRouter(Router):
    """Seed-locality hashing with a load fallback.

    ``anchor(seeds) = argmin_s mix(s)`` is stable under sub/supersets, so
    the requests that repeatedly touch a hot node share an anchor and
    land on one home replica — whose layer-1 cache then most likely holds
    their frontier already.  The home replica is overridden only when it
    is out of rotation or its backlog exceeds the least-loaded replica's
    by more than ``load_slack`` full micro-batches.
    """

    name = "locality"

    def __init__(self, load_slack: float = 2.0):
        super().__init__()
        self.load_slack = float(load_slack)

    def pick(self, seeds, replicas, available):
        if not available:
            raise ValueError("no replica in rotation")
        seeds = np.asarray(seeds).ravel()
        anchor = min((int(s) for s in seeds), key=_mix)
        home = _mix(anchor) % len(replicas)
        floor = min(replicas[i].pending_seeds for i in available)
        slack = self.load_slack * replicas[home].slots
        if (home in available
                and replicas[home].pending_seeds <= floor + slack):
            return home
        # home unavailable/backlogged: prefer a replica that can serve the
        # request from its cache, then fall back to pure load
        ready = [i for i in available if replicas[i].cache.ready(seeds)]
        if ready:
            return self._least_load(replicas, ready)
        return self._least_load(replicas, available)


def make_router(name: str, **kwargs) -> Router:
    """Factory for the launcher / benchmarks: ``load`` or ``locality``."""
    if name == "load":
        return LeastLoadRouter()
    if name == "locality":
        return LocalityRouter(**kwargs)
    raise ValueError(f"unknown router policy {name!r} "
                     f"(expected 'load' or 'locality')")
