"""Multi-replica GNN serving: N tuned engines behind a locality-aware router.

MGG's intelligent runtime tunes one pipeline for one GPU group;
production traffic needs *many* tuned engines running concurrently and
sharing what the tuner learns.  :class:`ServeCluster` fronts N independent
:class:`~repro.serve.gnn.GNNServeEngine` replicas (each with its own
device mesh, feature table, layer-1 hot cache, and per-replica
:class:`~repro.serve.stats.WorkloadStats`) with a
:class:`~repro.serve.router.Router` and coordinates their drift-triggered
re-tunes so the cluster never stalls:

* **Routing** — least-pending-load or seed-locality hashing (see
  :mod:`repro.serve.router`); a replica that is draining for a retune is
  out of rotation and its traffic is absorbed by the others.
* **Staggered retunes** — a replica whose drift crosses the threshold
  asks the cluster (via the engine's ``retune_gate`` hook) for the single
  cluster-wide *retune token*.  With the token it goes through
  **drain → retune → rejoin**: new requests route elsewhere, its queue is
  served to empty under the old (fast, already-jitted) config, then the
  search re-opens and is fed *shadow traffic* — a replay of the replica's
  own recent seed batches (``WorkloadStats.recent_seed_batches``) — so
  the tuner measures the drifted workload without holding any live
  request hostage to re-jits.  At most one replica is ever re-searching;
  zero requests are dropped cluster-wide.
* **Shared ConfigCache** — replicas share one
  :class:`~repro.runtime.cache.ConfigCache` (concurrency-safe; see that
  module).  The first replica to retune after a drift pays the full
  re-search and commits its optimum; a later replica whose drift signal
  *overlapped* that search (it was already waiting when the commit
  landed — same traffic shift, not a stale epoch) *adopts* the committed
  entry with a single validation measurement
  (``DynamicGNNEngine.retune(force=True, from_cache=True)``), so its
  search visits strictly fewer configs.  A drift that fires fresh after
  the commit re-searches honestly.

**Latency semantics** — replicas model concurrent GPU groups, but the
repro runs them in one process, so the cluster gives each replica a
virtual clock: real wall time minus the time other replicas (or this
replica's own shadow tuning) spent serving.  Work on replica A therefore
never inflates replica B's reported latencies, and with a single replica
every offset is zero — ``ServeCluster([srv]).run_trace(events)`` is
*bitwise identical* to ``run_trace(srv, events)`` on a bare engine.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.obs import MetricsRegistry, NULL_TRACER
from repro.serve.gnn import GNNServeEngine, ServeResult
from repro.serve.router import LeastLoadRouter, Router
from repro.serve.traffic import TrafficEvent

__all__ = ["ServeCluster"]

# replica lifecycle within the cluster
_SERVING, _DRAINING, _TUNING = "serving", "draining", "tuning"


class ServeCluster:
    """N serving replicas, one router, one retune token, zero drops."""

    def __init__(
        self,
        replicas: Sequence[GNNServeEngine],
        router: Optional[Router] = None,
        *,
        max_shadow_batches: int = 64,
        shadow_window: int = 8,
        log_fn=lambda _s: None,
        tracer=None,
        metrics: Optional[MetricsRegistry] = None,
    ):
        if not replicas:
            raise ValueError("a cluster needs at least one replica")
        self.replicas: List[GNNServeEngine] = list(replicas)
        if any(r.batches or r.pending_requests for r in self.replicas):
            raise ValueError("replicas must join the cluster before "
                             "serving any traffic")
        self.router = router if router is not None else LeastLoadRouter()
        self.max_shadow_batches = int(max_shadow_batches)
        self.shadow_window = int(shadow_window)
        self.log = log_fn

        n = len(self.replicas)
        # virtual-parallelism clocks: replica i's timeline excludes time
        # the process spent serving on other replicas (offset[i] grows
        # whenever j != i runs).  n == 1 ⇒ offset stays 0 ⇒ bare-engine
        # clock, which is what makes the single-replica mode bitwise.
        self._offset = [0.0] * n
        for i, r in enumerate(self.replicas):
            r.clock = self._make_clock(i)
            if n > 1 and r.dynamic:
                r.retune_gate = self._make_gate(i)

        self._state = [_SERVING] * n
        self._token: Optional[int] = None      # replica holding the retune
        self._closing = False                  # drain(): no new retunes
        self._from_cache = [False] * n
        self._commit_seq = 0                   # committed coordinated retunes
        # commit_seq at the moment replica i's CURRENT drift signal first
        # fired (None ⇔ no retune pending).  A sibling entry is adopted
        # only when its commit landed AFTER that moment — i.e. the two
        # replicas' drift windows overlapped, so it was tuned under the
        # same traffic shift, not a stale epoch.  A live drift re-fires
        # the gate every check_every batches; a want whose last re-fire
        # is older than that (signal subsided without a retune) is a NEW
        # drift next time, not a continuation.
        self._want_seq: List[Optional[int]] = [None] * n
        self._want_batch = [0] * n             # srv.batches at last fire
        self._shadow_batches: List[np.ndarray] = []
        self._shadow_cursor = 0
        self._shadow_count = 0

        self._next_gid = 0
        self._gid: Dict[Tuple[int, int], int] = {}   # (replica, local) → gid
        self._gid_replica: Dict[int, int] = {}       # gid → replica
        self._last_routed = 0
        # cluster counters live in the registry (shared with the replicas
        # when the builder passes one everywhere); the legacy attributes
        # are read-through properties so report() stays a thin view.
        self.tracer = tracer if tracer is not None else NULL_TRACER
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self._c_user = self.metrics.counter("cluster.user_served")
        self._c_shadow = self.metrics.counter("cluster.shadow_served")
        self._c_staggered = self.metrics.counter("cluster.staggered_retunes")
        self._c_deferred = self.metrics.counter("cluster.deferred_retunes")
        self.retune_log: List[Dict] = []

    @property
    def user_served(self) -> int:
        return self._c_user.value

    @property
    def shadow_served(self) -> int:
        return self._c_shadow.value

    @property
    def staggered_retunes(self) -> int:
        return self._c_staggered.value

    @property
    def deferred_retunes(self) -> int:
        return self._c_deferred.value

    # -- clocks / accounting -------------------------------------------------

    def _make_clock(self, i: int):
        return lambda: time.perf_counter() - self._offset[i]

    def _charge(self, i: int, fn):
        """Run ``fn`` on replica ``i``'s dime: the elapsed wall time is
        added to every *other* replica's offset (their virtual clocks do
        not advance while i computes — the replicas are concurrent)."""
        t0 = time.perf_counter()
        try:
            return fn()
        finally:
            dt = time.perf_counter() - t0
            for j in range(len(self.replicas)):
                if j != i:
                    self._offset[j] += dt

    # -- retune token --------------------------------------------------------

    def _make_gate(self, i: int):
        def gate(srv, score: float) -> bool:
            if self._token == i:
                return False                   # already scheduled
            stale = (self._want_seq[i] is not None
                     and srv.batches - self._want_batch[i]
                     > 2 * srv.check_every)
            fresh = self._want_seq[i] is None or stale
            if fresh:
                self._want_seq[i] = self._commit_seq
            self._want_batch[i] = srv.batches
            if self._token is not None or self._closing:
                if fresh:
                    # one deferral per wait (re-asks while the same token
                    # holder searches are not new deferrals)
                    self._c_deferred.inc()
                    self.tracer.instant("cluster.retune_deferred",
                                        cat="cluster", replica=i,
                                        drift=float(score))
                return False
            self._token = i
            self._state[i] = _DRAINING
            self._from_cache[i] = self._commit_seq > self._want_seq[i]
            self._c_staggered.inc()
            self.tracer.instant("cluster.drain_begin", cat="cluster",
                                replica=i, drift=float(score),
                                from_cache=self._from_cache[i])
            self.log(f"[serve.cluster] replica {i} drift {score:.2f} → "
                     f"token acquired (drain → retune"
                     f"{' [adopt from shared cache]' if self._from_cache[i] else ''}"
                     f" → rejoin)")
            return False                       # never retune inline
        return gate

    def _rejoin(self, i: int) -> None:
        srv = self.replicas[i]
        committed = not srv._tuning
        if committed and self._state[i] == _TUNING and self._shadow_batches:
            # compile the committed config's serve steps (and refresh the
            # invalidated h₁ cache) on one more shadow batch, so the first
            # LIVE request after rejoin doesn't pay the re-jit — the whole
            # point of retuning off-rotation
            seeds = self._shadow_batches[
                self._shadow_cursor % len(self._shadow_batches)]
            srv.submit(seeds)
            self._step_replica(i)
        srv.record_stats = True
        if committed:
            self._commit_seq += 1
        self._want_seq[i] = None
        self._state[i] = _SERVING
        self._token = None
        self.retune_log.append(dict(
            replica=i, from_cache=self._from_cache[i],
            committed=committed, shadow_batches=self._shadow_count,
            search_size=srv.search_sizes[-1] if committed
            and srv.search_sizes else None))
        self.tracer.instant("cluster.rejoin", cat="cluster", replica=i,
                            committed=committed,
                            shadow_batches=self._shadow_count)
        self.log(f"[serve.cluster] replica {i} rejoined "
                 f"(config {srv.config}, "
                 f"{self._shadow_count} shadow batches)")

    # -- admission -----------------------------------------------------------

    @property
    def available(self) -> List[int]:
        """Replica indices currently in rotation."""
        out = [i for i, s in enumerate(self._state) if s == _SERVING]
        # a lone replica mid-retune still takes traffic (nothing can
        # absorb it); the inline tuning path handles it like a bare engine
        return out or list(range(len(self.replicas)))

    def submit(self, seeds: np.ndarray, t: Optional[float] = None) -> int:
        """Route + enqueue one request; returns its cluster-wide id."""
        seeds = np.asarray(seeds)
        i = self.router.pick(seeds, self.replicas, self.available)
        lid = self.replicas[i].submit(seeds, t=t)
        gid = self._next_gid
        self._next_gid += 1
        self._gid[(i, lid)] = gid
        self._gid_replica[gid] = i
        self._last_routed = i
        return gid

    def replica_of(self, request_id: int) -> int:
        """Which replica served (or will serve) this request."""
        return self._gid_replica[request_id]

    def update_features(self, node: int, value: np.ndarray) -> int:
        """Apply a feature write on EVERY replica (each keeps its own
        table + cache); returns total rows invalidated across replicas."""
        return sum(r.update_features(node, value) for r in self.replicas)

    # -- serving -------------------------------------------------------------

    def _collect(self, i: int, results: List[ServeResult]) -> \
            List[ServeResult]:
        out = []
        for r in results:
            gid = self._gid.pop((i, r.request_id), None)
            if gid is None:                    # shadow replay: discard
                self._c_shadow.inc()
                continue
            out.append(dataclasses.replace(r, request_id=gid))
        self._c_user.inc(len(out))
        return out

    def _step_replica(self, i: int) -> List[ServeResult]:
        return self._collect(i, self._charge(i, self.replicas[i].step))

    def pump(self) -> List[ServeResult]:
        """Advance the in-flight coordinated retune by ONE unit of work
        (one drain micro-batch or one shadow measurement batch), so the
        retune interleaves with live routing instead of stalling it.
        Returns any user results the drain produced."""
        i = self._token
        if i is None:
            return []
        srv = self.replicas[i]
        out: List[ServeResult] = []
        if self._state[i] == _DRAINING:
            if srv.pending_requests:
                out = self._step_replica(i)
            if not srv.pending_requests:
                self._begin_tuning(i)
            return out
        # _TUNING: feed one replayed batch to the open search
        if not srv._tuning or self._shadow_count >= self.max_shadow_batches:
            self._rejoin(i)
            return out
        seeds = self._shadow_batches[
            self._shadow_cursor % len(self._shadow_batches)]
        self._shadow_cursor += 1
        self._shadow_count += 1
        srv.submit(seeds)
        self._step_replica(i)                  # results are shadow: dropped
        if not srv._tuning:
            self._rejoin(i)
        return out

    def _begin_tuning(self, i: int) -> None:
        srv = self.replicas[i]
        self.tracer.instant("cluster.shadow_begin", cat="cluster",
                            replica=i)
        self._shadow_batches = srv.stats.recent_seed_batches(
            limit=self.shadow_window)
        self._shadow_cursor = 0
        self._shadow_count = 0
        self._charge(i, lambda: srv.force_retune(
            from_cache=self._from_cache[i]))
        if not srv._tuning or not self._shadow_batches:
            # degenerate space (nothing to measure) or no replayable
            # traffic: rejoin immediately — inline tuning takes over
            self._rejoin(i)
            return
        srv.record_stats = False
        self._state[i] = _TUNING

    def step(self) -> List[ServeResult]:
        """One cluster scheduling round: a micro-batch on every replica
        with queued work, plus one unit of retune progress."""
        out: List[ServeResult] = []
        for i, r in enumerate(self.replicas):
            if self._state[i] == _SERVING and r.pending_requests:
                out.extend(self._step_replica(i))
        out.extend(self.pump())
        return out

    def run_trace(self, events) -> List[ServeResult]:
        """Cluster mirror of :func:`repro.serve.gnn.run_trace`: updates
        fan out to every replica, requests route through the router, each
        replica serves whenever it can fill its slots, and the in-flight
        retune (if any) advances one unit per event.  Drains at the end —
        every request is answered."""
        results: List[ServeResult] = []
        for ev in events:
            if isinstance(ev, TrafficEvent) and ev.is_update:
                self.update_features(ev.update_node, ev.update_value)
                continue
            seeds = ev.seeds if isinstance(ev, TrafficEvent) else ev
            self.submit(seeds,
                        t=ev.t if isinstance(ev, TrafficEvent) else None)
            i = self._last_routed
            while self.replicas[i].pending_seeds >= self.replicas[i].slots:
                results.extend(self._step_replica(i))
            results.extend(self.pump())
        results.extend(self.drain())
        return results

    def drain(self) -> List[ServeResult]:
        """Finish the in-flight retune (bounded by ``max_shadow_batches``)
        and serve every queued request on every replica.  No NEW retune
        token is granted while draining — a drift that fires here has no
        live traffic for siblings to absorb, so it waits for the next
        serving phase (the un-reset baseline keeps the signal alive)."""
        out: List[ServeResult] = []
        self._closing = True
        try:
            guard = 4 * self.max_shadow_batches + 16
            while self._token is not None and guard > 0:
                out.extend(self.pump())
                guard -= 1
            for i, r in enumerate(self.replicas):
                while r.pending_requests:
                    out.extend(self._step_replica(i))
        finally:
            self._closing = False
        return out

    # -- reporting -----------------------------------------------------------

    def report(self) -> Dict[str, object]:
        """Thin view over the registry + per-replica reports (schema
        unchanged): every cluster counter is either its own registry
        series or the fold of the replicas' registry-backed series."""
        per = [r.report() for r in self.replicas]
        tiered = [p["tiers"] for p in per if p.get("tiers")]
        return dict(
            replicas=len(self.replicas),
            router=self.router.name,
            served=self._c_user.value,
            shadow_served=self._c_shadow.value,
            pending=sum(r.pending_requests for r in self.replicas),
            dropped=sum(p["dropped"] for p in per),
            staggered_retunes=self._c_staggered.value,
            deferred_retunes=self._c_deferred.value,
            retune_log=list(self.retune_log),
            # cluster-wide tiered-storage accounting (replicas each hold
            # their own hot cache over their own host store)
            host_rows_streamed=sum(t["host_rows_streamed"] for t in tiered),
            cache_rows_served=sum(t["cache_rows_served"] for t in tiered),
            per_replica=per,
        )
