"""Hot-node cache of layer-1 aggregates (MG-GCN-style feature caching).

MG-GCN's multi-GPU GCN throughput comes half from overlap and half from
*caching frequently-accessed vertex data* so hot neighborhoods skip the
gather.  The serving analogue here caches the **stage-0 output table** —
the layer-1 aggregate ``h₁ = stage₀(params, engine, x)`` in the padded
PGAS layout — because it is request-independent: any prediction for seed
``v`` only reads ``h₁`` rows of ``v``'s 1-hop in-frontier, so a micro-batch
whose frontier is fully cached runs *only* the remaining layers (the
expensive input-dimension aggregation is skipped entirely).

Validity is tracked **per node** and invalidation is explicit: a feature
update at ``u`` dirties exactly the rows that aggregate ``u``
(``graph.transpose().row(u)``).  At repro scale the full table fits in
memory, so unlike MG-GCN we do not evict by capacity pressure by default;
an optional ``capacity`` restricts validity to the currently-hottest nodes
to model the memory-bound regime.  Hit/miss accounting is per frontier
node, so the reported hit rate is meaningful under either policy.

The table itself is a device array (it feeds straight into the jitted
cached-serve step); the validity mask is host-side NumPy so lookups stay
off the device queue.
"""
from __future__ import annotations

from typing import Optional, Sequence

import numpy as np

__all__ = ["HotNodeCache"]


class HotNodeCache:
    """Layer-1 aggregate table with per-node validity + hit accounting."""

    def __init__(self, num_nodes: int, capacity: Optional[int] = None):
        self.num_nodes = int(num_nodes)
        self.capacity = None if capacity is None else int(capacity)
        self.table = None            # device array, padded PGAS layout
        self.valid = np.zeros(self.num_nodes, dtype=bool)
        self.hits = 0
        self.misses = 0
        self.stores = 0
        self.invalidations = 0

    # -- lookup / store ------------------------------------------------------

    def lookup(self, nodes: np.ndarray) -> int:
        """Count hits/misses for one frontier; returns the miss count."""
        nodes = np.asarray(nodes, dtype=np.int64)
        ok = self.valid[nodes] if self.table is not None \
            else np.zeros(nodes.shape, dtype=bool)
        n_hit = int(ok.sum())
        self.hits += n_hit
        self.misses += int(nodes.size) - n_hit
        return int(nodes.size) - n_hit

    def ready(self, nodes: np.ndarray) -> bool:
        """True iff every row this frontier needs is valid."""
        if self.table is None:
            return False
        return bool(self.valid[np.asarray(nodes, dtype=np.int64)].all())

    def store(self, table, hot_nodes: Optional[Sequence[int]] = None) -> None:
        """Install a freshly computed full table.

        With no ``capacity`` every node becomes valid (the table is the
        whole layer-1 state).  With a capacity, only the hottest
        ``capacity`` nodes (``hot_nodes``, hottest first) are marked valid —
        the stored rows exist either way, but cold rows are treated as
        evicted so the hit-rate reflects the memory-bound policy.  A
        capacity with NO hot list marks nothing valid: an empty histogram
        means nothing has earned admission yet, and falling back to
        all-valid would silently disable the memory bound.
        """
        self.table = table
        self.stores += 1
        if self.capacity is None:
            self.valid[:] = True
            return
        self.valid[:] = False
        if hot_nodes is None:
            return
        keep = np.asarray(list(hot_nodes)[: self.capacity],
                          dtype=np.int64)
        if keep.size:
            self.valid[keep] = True

    # -- invalidation --------------------------------------------------------

    def invalidate(self, nodes: Optional[np.ndarray] = None) -> int:
        """Mark ``nodes`` (or everything) dirty; returns rows invalidated."""
        self.invalidations += 1
        if nodes is None:
            n = int(self.valid.sum())
            self.valid[:] = False
            self.table = None
            return n
        # dedupe before counting: a node listed twice is still one row, and
        # the returned count feeds invalidation accounting (serve-properties
        # test pins it to actual rows dirtied)
        nodes = np.unique(np.asarray(nodes, dtype=np.int64))
        n = int(self.valid[nodes].sum())
        self.valid[nodes] = False
        return n

    # -- accounting ----------------------------------------------------------

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0
