"""whisper-base — encoder-decoder; conv/mel frontend is a STUB (input_specs
provides precomputed frame embeddings, n_frames=1500).
[arXiv:2212.04356; unverified] 6L(+6 enc) d_model=512 8H d_ff=2048
vocab=51865; GELU MLP, LayerNorm, sinusoidal positions.

vocab 51865 is not divisible by 16 — padded embedding rows (DESIGN.md).
The 32k decode cell is mechanical (real Whisper decodes ≤448 tokens)."""
import dataclasses
from .base import ModelConfig

N_FRAMES = 1500

CONFIG = ModelConfig(
    name="whisper-base", family="encdec",
    n_layers=6, n_enc_layers=6, d_model=512, n_heads=8, n_kv_heads=8,
    head_dim=64, d_ff=2048, vocab=51865, rope_theta=0.0, mlp_type="gelu",
    norm="ln", tie_embeddings=True,
)


def smoke() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, n_enc_layers=2, d_model=64, n_heads=4,
        n_kv_heads=4, head_dim=16, d_ff=96, vocab=128)
