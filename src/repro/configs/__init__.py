"""Architecture registry: the 10 assigned configs + the paper's own GNN
settings (which live in repro.core.gnn / repro.core.graph)."""
import importlib
from typing import Dict, List

from .base import ModelConfig, ShapeSpec, SHAPES, shape_applicable

ARCH_IDS: List[str] = [
    "codeqwen1.5-7b",
    "mistral-nemo-12b",
    "qwen3-32b",
    "starcoder2-15b",
    "zamba2-7b",
    "internvl2-76b",
    "mixtral-8x7b",
    "granite-moe-1b-a400m",
    "xlstm-125m",
    "whisper-base",
]

_MODULES: Dict[str, str] = {
    a: a.replace("-", "_").replace(".", "_") for a in ARCH_IDS
}


def _module(arch: str):
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {ARCH_IDS}")
    return importlib.import_module(f"repro.configs.{_MODULES[arch]}")


def get_config(arch: str) -> ModelConfig:
    return _module(arch).CONFIG


def get_smoke_config(arch: str) -> ModelConfig:
    return _module(arch).smoke()


__all__ = ["ModelConfig", "ShapeSpec", "SHAPES", "shape_applicable",
           "ARCH_IDS", "get_config", "get_smoke_config"]
