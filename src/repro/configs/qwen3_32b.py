"""qwen3-32b — dense GQA transformer with per-head qk RMSNorm.
[hf:Qwen/Qwen3-8B (family); hf] 64L d_model=5120 64H (GQA kv=8) d_ff=25600
vocab=151936; qk_norm."""
import dataclasses
from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-32b", family="dense",
    n_layers=64, d_model=5120, n_heads=64, n_kv_heads=8, head_dim=128,
    d_ff=25600, vocab=151936, rope_theta=1e6, qk_norm=True,
    tie_embeddings=False,
)


def smoke() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=96, vocab=128)
