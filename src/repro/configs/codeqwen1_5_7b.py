"""codeqwen1.5-7b — dense Qwen1.5-arch GQA transformer.
[hf:Qwen/CodeQwen1.5-7B; hf] 32L d_model=4096 32H (GQA kv=32) d_ff=13440
vocab=92416."""
import dataclasses
from .base import ModelConfig

CONFIG = ModelConfig(
    name="codeqwen1.5-7b", family="dense",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=32, head_dim=128,
    d_ff=13440, vocab=92416, rope_theta=1e6, tie_embeddings=False,
)


def smoke() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=96, vocab=128)
