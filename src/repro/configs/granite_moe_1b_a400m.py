"""granite-moe-1b-a400m — fine-grained MoE (32 experts, top-8).
[hf:ibm-granite/granite-3.0-1b-a400m-base; hf] 24L d_model=1024 16H
(GQA kv=8) d_ff=512 vocab=49155.

32 experts / 16-way model axis ⇒ expert_mode="ep": 2 experts per chip,
dispatch via the MGG-pipelined all_to_all (models/moe.py).  vocab 49155 is
not divisible by 16 — padded embedding rows (DESIGN.md)."""
import dataclasses
from .base import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-1b-a400m", family="moe",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=8, head_dim=64,
    d_ff=512, vocab=49155, rope_theta=1e4,
    n_experts=32, top_k=8, expert_mode="ep", tie_embeddings=True,
)


def smoke() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=32, vocab=130, n_experts=8, top_k=2)
