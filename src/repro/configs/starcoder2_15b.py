"""starcoder2-15b — dense GQA transformer (GELU MLP, LayerNorm, RoPE).
[arXiv:2402.19173; hf] 40L d_model=6144 48H (GQA kv=4) d_ff=24576
vocab=49152."""
import dataclasses
from .base import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-15b", family="dense",
    n_layers=40, d_model=6144, n_heads=48, n_kv_heads=4, head_dim=128,
    d_ff=24576, vocab=49152, rope_theta=1e5, mlp_type="gelu", norm="ln",
    tie_embeddings=False,
)


def smoke() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=96, vocab=128)
