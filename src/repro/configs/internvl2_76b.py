"""internvl2-76b — VLM: InternViT frontend (STUB per the brief; input_specs
provides projected patch embeddings) + 76B LM backbone.
[arXiv:2404.16821; unverified] 80L d_model=8192 64H (GQA kv=8) d_ff=28672
vocab=128256."""
import dataclasses
from .base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-76b", family="vlm",
    n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8, head_dim=128,
    d_ff=28672, vocab=128256, rope_theta=5e5, n_vis_tokens=256,
    tie_embeddings=False,
)


def smoke() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=96, vocab=128, n_vis_tokens=8)
