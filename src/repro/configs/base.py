"""Config system: architecture + input-shape declarations.

Every assigned architecture is a :class:`ModelConfig` in its own module
(``repro/configs/<id>.py``), exactly matching the published numbers, plus a
``smoke()`` reduction of the same family for CPU tests.  Input shapes are
the four assigned cells (train_4k / prefill_32k / decode_32k / long_500k)
with per-family applicability rules (DESIGN.md §Arch-applicability).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp

__all__ = ["ModelConfig", "ShapeSpec", "SHAPES", "shape_applicable"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | hybrid | xlstm | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0                # 0 ⇒ d_model // n_heads
    rope_theta: float = 1e4          # 0 ⇒ no RoPE
    qk_norm: bool = False
    sliding_window: int = 0          # 0 ⇒ full causal attention
    mlp_type: str = "swiglu"         # swiglu | gelu
    norm: str = "rms"                # rms | ln
    tie_embeddings: bool = True
    # MoE
    n_experts: int = 0
    top_k: int = 0
    expert_mode: str = "ep"          # ep (experts sharded) | tp (d_ff sharded)
    moe_capacity_factor: float = 1.25
    # SSM / hybrid
    ssm_state: int = 0
    ssm_headdim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 256
    attn_every: int = 0              # hybrid: shared attn block every k layers
    # xLSTM
    xlstm_pattern: Tuple[str, ...] = ()   # e.g. ("m", "s") repeated
    # encoder-decoder
    n_enc_layers: int = 0
    # VLM stub frontend
    n_vis_tokens: int = 0
    # numerics
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    norm_eps: float = 1e-6
    # distribution hints
    remat: bool = True
    # opt-in Pallas flash attention for train/prefill (contiguous
    # positions); decode keeps the ring-cache path
    use_flash_attention: bool = False
    notes: str = ""

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)

    @property
    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    @property
    def cdtype(self):
        return jnp.dtype(self.compute_dtype)

    @property
    def is_subquadratic(self) -> bool:
        """Can this arch serve a 500k-token context? (DESIGN.md rules)."""
        if self.family in ("hybrid", "xlstm"):
            return True
        return self.sliding_window > 0  # SWA bounds the KV cache

    @property
    def has_decode(self) -> bool:
        return True  # all assigned archs have a decoder

    def param_count(self) -> int:
        """Approximate parameter count (embeddings + blocks), for rooflines."""
        d, f, hd = self.d_model, self.d_ff, self.head_dim
        attn = d * hd * (self.n_heads + 2 * self.n_kv_heads) + self.n_heads * hd * d
        if self.family == "moe":
            per_e = 3 * d * f if self.mlp_type == "swiglu" else 2 * d * f
            mlp = self.n_experts * per_e + d * self.n_experts  # + router
        elif self.family == "hybrid":
            d_in = d * self.ssm_expand
            heads = d_in // self.ssm_headdim
            mlp = 3 * d * f if f else 0
            attn = (d * d_in * 2 + d_in * 4 + d_in * d  # in/out proj
                    + heads * self.ssm_state * 2) + (
                attn // max(1, self.attn_every) if self.attn_every else 0)
        elif self.family == "xlstm":
            dk = d
            mlp = 0
            attn = 4 * d * dk + 2 * d * d  # qkv/gates + in/out proj (approx)
        else:
            mlp = 3 * d * f if self.mlp_type == "swiglu" else 2 * d * f
        blocks = self.n_layers * (attn + mlp + 2 * d)
        if self.family == "encdec":
            blocks += self.n_enc_layers * (attn + mlp + 2 * d)
        return blocks + self.vocab * d * (1 if self.tie_embeddings else 2)


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


def shape_applicable(cfg: ModelConfig, shape: ShapeSpec) -> Tuple[bool, str]:
    """(runs?, reason) per the assignment rules."""
    if shape.name == "long_500k" and not cfg.is_subquadratic:
        return False, ("pure full-attention arch: 500k-token decode needs "
                       "sub-quadratic attention (skip per brief)")
    return True, ""
