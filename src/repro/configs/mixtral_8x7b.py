"""mixtral-8x7b — MoE (8 experts, top-2) with sliding-window attention.
[arXiv:2401.04088; hf] 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=32000.

8 experts < the 16-way model axis ⇒ expert_mode="tp": experts replicated,
d_ff sharded inside each expert (DESIGN.md §Arch-applicability)."""
import dataclasses
from .base import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x7b", family="moe",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=14336, vocab=32000, rope_theta=1e6, sliding_window=4096,
    n_experts=8, top_k=2, expert_mode="tp", tie_embeddings=False,
)


def smoke() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=96, vocab=128, n_experts=4, top_k=2, sliding_window=16)
