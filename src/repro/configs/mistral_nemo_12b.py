"""mistral-nemo-12b — dense GQA transformer, 128k context.
[hf:mistralai/Mistral-Nemo-Base-2407; hf] 40L d_model=5120 32H (GQA kv=8)
d_ff=14336 vocab=131072; head_dim=128 (explicit, != d_model/heads)."""
import dataclasses
from .base import ModelConfig

CONFIG = ModelConfig(
    name="mistral-nemo-12b", family="dense",
    n_layers=40, d_model=5120, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=14336, vocab=131072, rope_theta=1e6, tie_embeddings=False,
)


def smoke() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=96, vocab=128)
