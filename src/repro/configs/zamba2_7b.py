"""zamba2-7b — hybrid: Mamba2 backbone + ONE shared attention+MLP block
applied every ``attn_every`` layers (the Zamba2 weight-sharing trick).
[arXiv:2411.15242; unverified] 81L d_model=3584 32H (GQA kv=32) d_ff=14336
vocab=32000, ssm_state=64.

Adaptation noted in DESIGN.md: the shared attention carries a 4096-token
sliding window so the decode_32k / long_500k cells keep an O(window) KV
cache — the hybrid family's long-context selling point."""
import dataclasses
from .base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-7b", family="hybrid",
    n_layers=81, d_model=3584, n_heads=32, n_kv_heads=32, head_dim=112,
    d_ff=14336, vocab=32000, rope_theta=1e4, sliding_window=4096,
    ssm_state=64, ssm_headdim=64, ssm_expand=2, attn_every=6,
    tie_embeddings=True,
)


def smoke() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=7, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=96, vocab=128, ssm_state=16, ssm_headdim=16, attn_every=3,
        ssm_chunk=8)
