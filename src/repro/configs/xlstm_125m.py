"""xlstm-125m — alternating mLSTM/sLSTM blocks.
[arXiv:2405.04517; unverified] 12L d_model=768 4H d_ff=0 (blocks carry their
own projections) vocab=50304."""
import dataclasses
from .base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-125m", family="xlstm",
    n_layers=12, d_model=768, n_heads=4, n_kv_heads=4, head_dim=192,
    d_ff=0, vocab=50304, rope_theta=0.0, xlstm_pattern=("m", "s"),
    tie_embeddings=True,
)


def smoke() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=4, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        vocab=128, ssm_chunk=8)
