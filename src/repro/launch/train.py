"""Production-style training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch xlstm-125m \
        --steps 100 --seq 256 --batch 8 [--devices 8] [--smoke] \
        [--workdir /tmp/ckpt] [--accum 2] [--moe-pipeline-chunks 4]

``--devices N`` forces N host devices (set BEFORE jax import) and lays a
(data=N, model=1) mesh; on real TPU pods, omit it and the mesh comes from
launch/mesh.make_production_mesh.
"""
import os
import sys

# device forcing must precede the jax import
if "--devices" in sys.argv:
    _n = sys.argv[sys.argv.index("--devices") + 1]
    os.environ["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={_n}"

import argparse
import dataclasses

import jax
import jax.numpy as jnp

from repro import configs
from repro.dist import make_mesh
from repro.models import transformer as T
from repro.obs import MetricsRegistry, Tracer
from repro.train import (AdamWConfig, LMDataConfig, Trainer, TrainState,
                         adamw_init, lm_batch, make_train_step)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--devices", type=int, default=0)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--workdir", default="")
    ap.add_argument("--moe-pipeline-chunks", type=int, default=1)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--ef-bits", type=int, default=0,
                    help="int-N error-feedback gradient compression on the "
                         "wire (pure-DP meshes; 0 = off)")
    ap.add_argument("--ring-tp", action="store_true",
                    help="route TP matmuls through the ring-pipelined "
                         "collectives instead of XLA SPMD defaults")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="write a Chrome-trace JSON of the training loop "
                         "(per-step spans, straggler/restart/retune "
                         "instants — open in ui.perfetto.dev)")
    ap.add_argument("--metrics-json", default=None, metavar="PATH",
                    help="write the metrics-registry snapshot as JSON")
    args = ap.parse_args()

    tracer = Tracer() if args.trace else None
    registry = MetricsRegistry() if (args.trace or args.metrics_json) \
        else None

    cfg = (configs.get_smoke_config(args.arch) if args.smoke
           else configs.get_config(args.arch))
    cfg = dataclasses.replace(cfg, ssm_chunk=min(cfg.ssm_chunk, args.seq))
    n_dev = len(jax.devices())
    mesh = make_mesh((n_dev, 1), ("data", "model")) if n_dev > 1 else None
    ctx = (T.DistCtx(mesh=mesh,
                     moe_pipeline_chunks=args.moe_pipeline_chunks,
                     seq_shard_acts=cfg.family not in ("xlstm", "hybrid"),
                     use_ring_tp=args.ring_tp)
           if mesh else T.DistCtx())
    if args.ef_bits and mesh is None:
        print("[launch] --ef-bits ignored: single-device run has no "
              "gradient allreduce")
        args.ef_bits = 0
    print(f"arch={cfg.name} params≈{cfg.param_count()/1e6:.1f}M "
          f"devices={n_dev} seq={args.seq} batch={args.batch}")
    params = T.init_params(jax.random.key(0), cfg, vocab_multiple=16)
    opt = adamw_init(params)
    if args.ef_bits:
        from repro.dist import ef_state_init
        opt = (opt, ef_state_init(params))
    step_fn = jax.jit(make_train_step(
        cfg, ctx, AdamWConfig(lr=args.lr, warmup_steps=20,
                              total_steps=args.steps),
        accum_steps=args.accum, ef_bits=args.ef_bits),
        donate_argnums=(0, 1))
    dcfg = LMDataConfig(vocab=cfg.vocab, seq_len=args.seq,
                        global_batch=args.batch, doc_len=args.seq)

    def data_it():
        s = 0
        while True:
            b = lm_batch(dcfg, s,
                         n_vis=cfg.n_vis_tokens if cfg.family == "vlm" else 0,
                         d_model=cfg.d_model)
            yield {k: jnp.asarray(v) for k, v in b.items()}
            s += 1

    tr = Trainer(step_fn, data_it(), TrainState(params, opt),
                 workdir=args.workdir or None, ckpt_every=args.ckpt_every,
                 tracer=tracer, metrics=registry)
    tr.maybe_restore()
    losses = tr.run(args.steps)
    print(f"done: loss {losses[0]:.4f} -> {losses[-1]:.4f} "
          f"stragglers={tr.stragglers} restarts={tr.restarts}")
    if args.metrics_json:
        registry.dump_json(args.metrics_json)
        print(f"[launch] metrics snapshot: {args.metrics_json}")
    if tracer is not None:
        tracer.dump_chrome(args.trace)
        print(f"[launch] chrome trace: {args.trace} ({len(tracer)} events)")


if __name__ == "__main__":
    main()
