"""Production mesh definition (a FUNCTION so importing never touches jax
device state — required for the dry-run's forced 512-device config)."""
from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "SINGLE_POD_SHAPE", "MULTI_POD_SHAPE"]

SINGLE_POD_SHAPE = (16, 16)            # 256 chips: ("data", "model")
MULTI_POD_SHAPE = (2, 16, 16)          # 512 chips: ("pod", "data", "model")


def make_production_mesh(*, multi_pod: bool = False):
    shape = MULTI_POD_SHAPE if multi_pod else SINGLE_POD_SHAPE
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
    )


def data_axes(multi_pod: bool):
    return ("pod", "data") if multi_pod else ("data",)
